//! Serving example: the sharded engine pool over an OCS-quantized model
//! (paper §3.5 — OCS-transformed models are plain models, servable with
//! no custom runtime support, so they also *scale* like plain models).
//!
//! Starts a multi-worker pool (each worker thread owns its own PJRT
//! engine + prepared pipeline), fires concurrent clients at it under two
//! load patterns, and reports per-worker and aggregate behaviour.
//!
//! Run:  cargo run --release --example serve_quantized
//! (requires `make artifacts` + a `pjrt` build; trained weights
//! recommended: `ocs train`. Without artifacts, try
//! `cargo run --release -- serve --sim --sweep 1,2,4` instead.)

use std::time::{Duration, Instant};

use anyhow::Result;

use ocs::clip::ClipMethod;
use ocs::pipeline::QuantConfig;
use ocs::serve::{ServeConfig, Server};
use ocs::tensor::TensorF;
use ocs::train::data;

fn drive(server: &Server, clients: usize, per_client: usize, think: Duration) -> Result<f64> {
    let dataset = data::synth_images(256, 411);
    let row = dataset.x.len() / dataset.len();
    let xdata = std::sync::Arc::new(dataset.x.data().to_vec());
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let client = server.client();
        let xdata = xdata.clone();
        handles.push(std::thread::spawn(move || -> Result<()> {
            for i in 0..per_client {
                let idx = (c * per_client + i) % 256;
                let x =
                    TensorF::from_vec(&[1, 16, 16, 3], xdata[idx * row..(idx + 1) * row].to_vec())?;
                let logits = client.infer(x)?;
                assert_eq!(logits.len(), 10);
                if !think.is_zero() {
                    std::thread::sleep(think);
                }
            }
            Ok(())
        }));
    }
    for h in handles {
        h.join().expect("client thread")?;
    }
    Ok((clients * per_client) as f64 / t0.elapsed().as_secs_f64())
}

fn main() -> Result<()> {
    let model = "minivgg";
    // 5-bit weights with MSE clip + OCS r=0.02 — a Table-2 sweet spot —
    // except the boundary layers, which stay at 8 bits (recipe override)
    let quant = QuantConfig::weights_with_a8(5, ClipMethod::Mse, 0.02)
        .to_recipe()
        .edge_w_bits(8);
    println!("== serving {model} [{}] ==", quant.label());

    let cfg = ServeConfig {
        workers: 2,
        max_batch: 32,
        max_wait: Duration::from_millis(2),
        queue_cap: 1024,
        deadline: Some(Duration::from_secs(2)),
    };
    println!(
        "pool: {} workers, queue cap {}/worker, deadline {:?}",
        cfg.workers, cfg.queue_cap, cfg.deadline
    );
    let server = Server::start("artifacts", model, quant, cfg)?;

    println!("\n-- closed-loop burst (8 clients, no think time) --");
    let rps = drive(&server, 8, 128, Duration::ZERO)?;
    println!("{}", server.metrics().report());
    println!("throughput {rps:.0} req/s");

    println!("\n-- recipe hot-swap: drop middles to 4 bits, no restart --");
    server.swap_recipe(
        QuantConfig::weights_with_a8(4, ClipMethod::Mse, 0.02)
            .to_recipe()
            .edge_w_bits(8),
    );
    let t0 = Instant::now();
    while server.swaps_applied() < server.worker_count() as u64
        && t0.elapsed() < Duration::from_secs(5)
    {
        std::thread::sleep(Duration::from_millis(10));
    }
    println!("swaps applied: {}/{}", server.swaps_applied(), server.worker_count());

    println!("\n-- trickle (4 clients, 5 ms think time: batches stay small) --");
    let rps = drive(&server, 4, 64, Duration::from_millis(5))?;
    println!("{}", server.metrics().report());
    println!("throughput {rps:.0} req/s");

    server.shutdown()?;
    println!("\npool drained cleanly");
    Ok(())
}
