//! End-to-end quickstart — the full three-layer stack on one workload.
//!
//! 1. Train the ResNet-20 stand-in from scratch for a few hundred SGD
//!    steps *through the AOT-compiled `train_step` artifact* (L2 JAX
//!    graph + L1 Pallas kernels, driven from Rust over PJRT), logging
//!    the loss curve.
//! 2. Post-training-quantize the result to 5-bit weights four ways:
//!    plain linear, best clipping, OCS, OCS + clip (the paper's Table 2
//!    recipe), and print the accuracy ladder.
//!
//! Run:  cargo run --release --example quickstart
//! (requires `make artifacts` first)

use anyhow::Result;

use ocs::calib;
use ocs::clip::ClipMethod;
use ocs::eval;
use ocs::model::store::WeightStore;
use ocs::model::ModelSpec;
use ocs::pipeline::{self, QuantConfig};
use ocs::runtime::Engine;
use ocs::train::{self, data};

fn main() -> Result<()> {
    let model = "miniresnet";
    let steps = std::env::var("QUICKSTART_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400usize);

    println!("== quickstart: {model}, {steps} training steps ==\n");
    let spec = ModelSpec::load_named("artifacts", model)?;
    let engine = Engine::cpu()?;

    // ---- 1. train through the compiled train_step artifact -------------
    let init = WeightStore::load_init(&spec)?;
    let dataset = data::synth_images(8_000, 23);
    let t0 = std::time::Instant::now();
    let (trained, report) = train::train_cnn(&engine, &spec, &init, &dataset, steps, 0.04, 17)?;
    println!(
        "\ntrained {} params in {:.1}s ({:.0} ms/step); loss curve:",
        trained.param_count(),
        t0.elapsed().as_secs_f64(),
        t0.elapsed().as_millis() as f64 / steps as f64
    );
    for (s, l) in &report.losses {
        println!("  step {s:4}  loss {l:.4}");
    }

    // ---- 2. post-training quantization ladder ---------------------------
    let test = data::synth_images(2_000, 31);
    let calib_set = data::synth_images(256, 29);
    let calibration = calib::calibrate(&engine, &spec, &trained, &calib_set.x, 32)?;

    let bits = 5;
    let ladder = [
        ("float", QuantConfig::float()),
        (
            "linear (no clip)",
            QuantConfig::weights_with_a8(bits, ClipMethod::None, 0.0),
        ),
        (
            "MSE clip",
            QuantConfig::weights_with_a8(bits, ClipMethod::Mse, 0.0),
        ),
        (
            "OCS r=0.02",
            QuantConfig::weights_with_a8(bits, ClipMethod::None, 0.02),
        ),
        (
            "OCS r=0.02 + MSE clip",
            QuantConfig::weights_with_a8(bits, ClipMethod::Mse, 0.02),
        ),
    ];
    println!("\n{bits}-bit weight quantization ladder (acts 8-bit):");
    for (name, cfg) in ladder {
        let needs_calib = cfg.a_bits.is_some();
        let prep = pipeline::prepare(
            &spec,
            &trained,
            if needs_calib { Some(&calibration) } else { None },
            &cfg,
        )?;
        let acc = eval::accuracy(&engine, &spec, &prep, &test.x, &test.y, 128)?;
        println!(
            "  {name:<24} top-1 {:>6.2}%   (weight overhead {:.3}x)",
            acc * 100.0,
            prep.weight_overhead()
        );
    }
    println!("\nexpected shape: clip > linear; OCS ~ clip or better; OCS+clip best (paper §5.2)");
    Ok(())
}
