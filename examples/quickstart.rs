//! End-to-end quickstart for the public quantization API.
//!
//! Two modes:
//!
//! * **Full** (default when `artifacts/` exists): train the ResNet-20
//!   stand-in through the AOT-compiled `train_step` artifact (L2 JAX
//!   graph + L1 Pallas kernels over PJRT), then post-training-quantize
//!   it through a ladder of recipes — linear, clip, OCS, OCS + clip
//!   (the paper's Table 2 recipe), and a per-layer mixed-precision
//!   recipe — and print the accuracy ladder.
//! * **Sim** (`QUICKSTART_SIM=1`, or no artifacts): the same recipe
//!   API over an in-memory model, served on the artifact-free quant-sim
//!   pool — including the shared `PreparedCache` and a live recipe
//!   hot-swap. This is what CI runs on a clean checkout, so the public
//!   API shown here cannot rot.
//!
//! Run:  cargo run --release --example quickstart
//!       QUICKSTART_SIM=1 cargo run --release --example quickstart

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use ocs::calib::{self, Calibration, LayerCalib};
use ocs::clip::ClipMethod;
use ocs::stats::Histogram;
use ocs::eval;
use ocs::model::store::WeightStore;
use ocs::model::{LayerKind, LayerSpec, ModelSpec};
use ocs::pipeline::{self, PreparedCache, QuantConfig, QuantRecipe, ServeConfig};
use ocs::runtime::Engine;
use ocs::serve::backend::QuantSimFactory;
use ocs::serve::Server;
use ocs::tensor::TensorF;
use ocs::train::{self, data};
use ocs::util::rng::Rng;

fn main() -> Result<()> {
    let have_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    let force_sim = std::env::var("QUICKSTART_SIM").map(|v| v == "1").unwrap_or(false);
    if force_sim || !have_artifacts {
        if !have_artifacts && !force_sim {
            println!("(no artifacts/ found — running the sim quickstart; `make artifacts` enables the full one)\n");
        }
        sim_quickstart()
    } else {
        full_quickstart()
    }
}

/// The recipe ladder both modes walk: uniform configs lowered via
/// `to_recipe()`, plus genuinely per-layer recipes at the end.
fn ladder(bits: u32) -> Vec<(&'static str, QuantRecipe)> {
    vec![
        ("float", QuantConfig::float().to_recipe()),
        (
            "linear (no clip)",
            QuantConfig::weights_with_a8(bits, ClipMethod::None, 0.0).to_recipe(),
        ),
        (
            "MSE clip",
            QuantConfig::weights_with_a8(bits, ClipMethod::Mse, 0.0).to_recipe(),
        ),
        (
            "OCS r=0.02",
            QuantConfig::weights_with_a8(bits, ClipMethod::None, 0.02).to_recipe(),
        ),
        (
            "OCS r=0.02 + MSE clip",
            QuantConfig::weights_with_a8(bits, ClipMethod::Mse, 0.02).to_recipe(),
        ),
        (
            "mixed: 8-bit edges",
            QuantConfig::weights_with_a8(bits, ClipMethod::Mse, 0.02)
                .to_recipe()
                .edge_w_bits(8),
        ),
        (
            "skip first/last",
            QuantConfig::weights_with_a8(bits, ClipMethod::Mse, 0.02)
                .to_recipe()
                .skip_first_last(),
        ),
    ]
}

// ---------------------------------------------------------------------------
// Sim mode: recipes + cache + serving pool + hot-swap, no artifacts
// ---------------------------------------------------------------------------

fn sim_model() -> Result<(Arc<ModelSpec>, Arc<WeightStore>)> {
    let layer = |name: &str| LayerSpec {
        name: name.into(),
        kind: LayerKind::Fc,
        cin: 16,
        cin_pad: 20,
        cout: 8,
        ksize: 0,
        stride: 1,
        quantized: true,
        w_cin_axis: 0,
        w_shape: vec![16, 8],
        w_shape_pad: vec![20, 8],
    };
    let spec = ModelSpec {
        name: "quickstart_sim".into(),
        dir: std::path::PathBuf::new(),
        pad_factor: 1.25,
        num_classes: 10,
        img_hw: 0,
        img_c: 0,
        vocab: 0,
        seq_len: 0,
        momentum: 0.9,
        layers: vec![layer("fc1"), layer("fc2"), layer("fc3")],
        artifacts: Default::default(),
    };
    let mut rng = Rng::new(2024);
    let mut leaves = Vec::new();
    for name in ["fc1", "fc2", "fc3"] {
        let mut w = rng.normal_vec(16 * 8);
        w[3 * 8] = 9.0; // a weight outlier for OCS to split
        leaves.push((format!("{name}.W"), TensorF::from_vec(&[16, 8], w)?));
        leaves.push((format!("{name}.b"), TensorF::zeros(&[8])));
    }
    Ok((Arc::new(spec), Arc::new(WeightStore::from_leaves(leaves))))
}

/// Synthetic activation statistics standing in for a probe pass — the
/// a8 ladder entries quantize activations, which requires calibration.
fn sim_calibration(spec: &ModelSpec) -> Calibration {
    let data: Vec<f32> = (0..4096).map(|i| (i % 64) as f32 * 0.05).collect();
    let mut layers = std::collections::BTreeMap::new();
    for l in spec.quantized_layers() {
        let mut channel_max = vec![1.0f32; l.cin];
        channel_max[3] = 6.0; // one hot channel for activation OCS to pick
        let mut outlier_counts = vec![0u64; l.cin];
        outlier_counts[3] = 40;
        layers.insert(
            l.name.clone(),
            LayerCalib {
                hist: Histogram::from_slice(&data, 256),
                channel_max,
                outlier_counts,
            },
        );
    }
    Calibration { layers }
}

fn sim_quickstart() -> Result<()> {
    println!("== quickstart (sim): the recipe API without artifacts ==\n");
    let (spec, ws) = sim_model()?;
    let calibration = Arc::new(sim_calibration(&spec));

    // ---- 1. the recipe ladder, prepared through the shared cache -------
    println!("recipe ladder over '{}' (3 fc layers):", spec.name);
    let cache = Arc::new(PreparedCache::new());
    for (name, recipe) in ladder(5) {
        let prep = cache.get_or_prepare(&spec, &ws, Some(calibration.as_ref()), &recipe)?;
        let thr: Vec<String> = prep
            .layers
            .iter()
            .map(|l| format!("{:.3}", l.w_threshold))
            .collect();
        println!(
            "  {name:<22} [{}]  splits {}  overhead {:.3}x  w_thr [{}]  fp {}",
            recipe.label(),
            prep.total_splits(),
            prep.weight_overhead(),
            thr.join(", "),
            recipe.fingerprint(),
        );
    }
    // preparing the ladder twice demonstrates the cache: all hits
    for (_, recipe) in ladder(5) {
        cache.get_or_prepare(&spec, &ws, Some(calibration.as_ref()), &recipe)?;
    }
    println!(
        "prepared-cache: {} preps, {} hits ({} entries)\n",
        cache.misses(),
        cache.hits(),
        cache.len()
    );

    // ---- 2. serve the recipe on the sharded pool, then hot-swap --------
    let before_recipe = QuantConfig::weights_only(5, ClipMethod::Mse, 0.02).to_recipe();
    let after_recipe = QuantConfig::weights_only(4, ClipMethod::Mse, 0.02)
        .to_recipe()
        .edge_w_bits(8);
    let factory = Arc::new(QuantSimFactory {
        spec: spec.clone(),
        ws: ws.clone(),
        calib: Some(calibration.clone()),
        recipe: before_recipe,
        cache: cache.clone(),
    });
    let server = Server::start_with(
        factory,
        ServeConfig {
            workers: 2,
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            queue_cap: 64,
            deadline: None,
        },
    )?;
    let client = server.client();
    let x = TensorF::from_vec(&[1, 4], vec![0.1, 0.2, 0.3, 0.4])?;
    let before = client.infer(x.clone())?;
    println!("pool up (2 workers, one shared prep); logits[0] = {:.3}", before[0]);

    println!("hot-swapping to a mixed-precision recipe (no restart)...");
    server.swap_recipe(after_recipe);
    let t0 = Instant::now();
    while server.swaps_applied() < server.worker_count() as u64
        && t0.elapsed() < Duration::from_secs(5)
    {
        std::thread::sleep(Duration::from_millis(10));
    }
    let after = client.infer(x)?;
    println!(
        "swaps applied {}/{}; logits[0] now {:.3} (was {:.3})",
        server.swaps_applied(),
        server.worker_count(),
        after[0],
        before[0]
    );
    server.shutdown()?;
    println!("\npool drained; total preps this run: {}", cache.misses());
    Ok(())
}

// ---------------------------------------------------------------------------
// Full mode: train through PJRT, then the accuracy ladder
// ---------------------------------------------------------------------------

fn full_quickstart() -> Result<()> {
    let model = "miniresnet";
    let steps = std::env::var("QUICKSTART_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(400usize);

    println!("== quickstart: {model}, {steps} training steps ==\n");
    let spec = ModelSpec::load_named("artifacts", model)?;
    let engine = Engine::cpu()?;

    // ---- 1. train through the compiled train_step artifact -------------
    let init = WeightStore::load_init(&spec)?;
    let dataset = data::synth_images(8_000, 23);
    let t0 = std::time::Instant::now();
    let (trained, report) = train::train_cnn(&engine, &spec, &init, &dataset, steps, 0.04, 17)?;
    println!(
        "\ntrained {} params in {:.1}s ({:.0} ms/step); loss curve:",
        trained.param_count(),
        t0.elapsed().as_secs_f64(),
        t0.elapsed().as_millis() as f64 / steps as f64
    );
    for (s, l) in &report.losses {
        println!("  step {s:4}  loss {l:.4}");
    }

    // ---- 2. post-training quantization ladder ---------------------------
    let test = data::synth_images(2_000, 31);
    let calib_set = data::synth_images(256, 29);
    let calibration = calib::calibrate(&engine, &spec, &trained, &calib_set.x, 32)?;

    let bits = 5;
    println!("\n{bits}-bit weight quantization ladder (acts 8-bit):");
    for (name, recipe) in ladder(bits) {
        let calib_arg = if recipe.needs_calibration(&spec) {
            Some(&calibration)
        } else {
            None
        };
        let prep = pipeline::prepare_cached(&spec, &trained, calib_arg, &recipe)?;
        let acc = eval::accuracy(&engine, &spec, &prep, &test.x, &test.y, 128)?;
        println!(
            "  {name:<22} top-1 {:>6.2}%   (weight overhead {:.3}x)",
            acc * 100.0,
            prep.weight_overhead()
        );
    }
    println!("\nexpected shape: clip > linear; OCS ~ clip or better; OCS+clip best (paper §5.2)");
    Ok(())
}
