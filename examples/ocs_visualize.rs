//! Figure-1 companion: dump weight histograms (float vs quantized) for
//! the linear / clip / OCS treatments of one layer, as CSV for plotting,
//! plus the MSE ladder the figure annotates.
//!
//! Run:  cargo run --release --example ocs_visualize [-- <layer>]

use anyhow::{Context, Result};

use ocs::clip::ClipMethod;
use ocs::model::store::WeightStore;
use ocs::model::ModelSpec;
use ocs::ocs::{plan, weight_ocs, SplitMode};
use ocs::quant::{fake_quant_tensor, QuantSpec};
use ocs::stats::Histogram;
use ocs::tensor::TensorF;

fn dump(path: &str, data: &[f32]) -> Result<()> {
    let max = data.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-9);
    let bins = 101;
    let mut counts = vec![0u64; bins];
    for &v in data {
        let t = ((v + max) / (2.0 * max) * bins as f32) as usize;
        counts[t.min(bins - 1)] += 1;
    }
    let mut s = String::from("center,count\n");
    for (i, c) in counts.iter().enumerate() {
        let center = -max + (i as f32 + 0.5) * 2.0 * max / bins as f32;
        s.push_str(&format!("{center},{c}\n"));
    }
    std::fs::write(path, s)?;
    println!("  wrote {path}");
    Ok(())
}

fn main() -> Result<()> {
    let spec = ModelSpec::load_named("artifacts", "miniresnet")?;
    let (ws, _) = WeightStore::load_best(&spec)?;
    let layer_name = std::env::args().nth(1);
    let layer = match layer_name {
        Some(n) => spec.layer(&n)?.clone(),
        None => spec
            .quantized_layers()
            .max_by_key(|l| l.cin)
            .context("no quantized layers")?
            .clone(),
    };
    println!(
        "layer '{}': {}x{} channels",
        layer.name, layer.cin, layer.cout
    );
    let w = ws.weight(&layer.name)?;
    let qspec = QuantSpec::new(4);
    let hist = Histogram::from_slice(w.data(), 2048);
    std::fs::create_dir_all("results")?;

    // linear
    let t = hist.max_abs();
    let q = fake_quant_tensor(w, t, qspec);
    println!("linear:  threshold {t:.5}  MSE {:.3e}", w.mse(&q));
    dump("results/viz_float.csv", w.data())?;
    dump("results/viz_linear_quant.csv", q.data())?;

    // clip
    let tc = ClipMethod::Mse.threshold(&hist, qspec);
    let qc = fake_quant_tensor(w, tc, qspec);
    println!("clip:    threshold {tc:.5}  MSE {:.3e}", w.mse(&qc));
    dump("results/viz_clip_quant.csv", qc.data())?;

    // OCS
    let n = plan::splits_for(layer.cin, 0.05, layer.cin_pad);
    let hooks = weight_ocs(
        w,
        layer.w_cin_axis,
        layer.cin_pad,
        n,
        SplitMode::QuantAware,
        qspec.delta(t),
    )?;
    let active: Vec<f32> = (0..hooks.active)
        .flat_map(|s| hooks.w_expanded.axis_slice(layer.w_cin_axis, s).unwrap())
        .collect();
    let to = active.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let wo = TensorF::from_vec(&[active.len()], active)?;
    let qo = fake_quant_tensor(&wo, to, qspec);
    println!(
        "ocs:     threshold {to:.5}  MSE {:.3e}  ({} splits, range -{:.1}%)",
        wo.mse(&qo),
        hooks.splits.len(),
        100.0 * (1.0 - to / t)
    );
    dump("results/viz_ocs_float.csv", wo.data())?;
    dump("results/viz_ocs_quant.csv", qo.data())?;
    Ok(())
}
