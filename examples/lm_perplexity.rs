//! Language-model example (the paper's §6): train the 2-layer LSTM LM
//! on the synthetic corpus through the compiled train_step artifact,
//! then sweep weight quantization {6,5} bits × OCS ratios and print the
//! perplexity grid — a miniature of Table 6.
//!
//! Run:  cargo run --release --example lm_perplexity
//! Env:  LM_STEPS=N to override the training length (default 600).

use anyhow::Result;

use ocs::clip::ClipMethod;
use ocs::eval;
use ocs::model::store::WeightStore;
use ocs::model::ModelSpec;
use ocs::pipeline::{self, QuantConfig};
use ocs::runtime::Engine;
use ocs::train::{self, data};

fn main() -> Result<()> {
    let steps = std::env::var("LM_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(600usize);
    let spec = ModelSpec::load_named("artifacts", "lstmlm")?;
    let engine = Engine::cpu()?;

    // train (or reuse) — training the LSTM takes a few minutes on CPU
    let (ws, have_trained) = WeightStore::load_best(&spec)?;
    let ws = if have_trained {
        println!("using existing trained weights ({} params)", ws.param_count());
        ws
    } else {
        println!("training lstmlm for {steps} steps ...");
        let corpus = data::synth_corpus(200_000, spec.vocab, 91);
        let init = WeightStore::load_init(&spec)?;
        let (trained, report) = train::train_lm(&engine, &spec, &init, &corpus, steps, 0.5, 17)?;
        println!(
            "final training loss {:.3} (ppl {:.1})",
            report.final_loss,
            report.final_loss.exp()
        );
        trained.save(WeightStore::trained_path(&spec))?;
        trained
    };

    // held-out corpus (different seed from training)
    let eval_corpus = data::synth_corpus(40_000, spec.vocab, 92);
    let windows = data::token_windows(&eval_corpus, spec.seq_len, 32);
    println!(
        "evaluating on {} windows of {} tokens",
        windows.shape()[0],
        spec.seq_len
    );

    let float_ppl = {
        let prep = pipeline::prepare(&spec, &ws, None, &QuantConfig::float())?;
        eval::perplexity(&engine, &spec, &prep, &windows)?
    };
    println!("\nfloat perplexity: {float_ppl:.2}\n");
    println!(
        "{:>4} {:>6} | {:>8} {:>8} {:>8} {:>8}",
        "bits", "r", "none", "mse", "aciq", "kl"
    );
    for bits in [6u32, 5] {
        for r in [0.0, 0.02, 0.05] {
            let mut row = Vec::new();
            for clip in [
                ClipMethod::None,
                ClipMethod::Mse,
                ClipMethod::Aciq,
                ClipMethod::Kl,
            ] {
                let cfg = QuantConfig::weights_only(bits, clip, r);
                let prep = pipeline::prepare(&spec, &ws, None, &cfg)?;
                row.push(eval::perplexity(&engine, &spec, &prep, &windows)?);
            }
            println!(
                "{bits:>4} {r:>6} | {:>8.2} {:>8.2} {:>8.2} {:>8.2}",
                row[0], row[1], row[2], row[3]
            );
        }
    }
    println!("\nexpected shape (paper Table 6): clipping does not help; OCS improves with r");
    Ok(())
}
