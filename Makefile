# Repo-level entry points. The Rust crate lives under rust/; the JAX AOT
# lowering (which produces artifacts/) lives under python/compile/.

CARGO_DIR := rust

.PHONY: verify build test fmt lint artifacts serve-smoke loadtest chaos bench-record clean

# Tier-1 gate: the exact command CI runs on every push.
verify:
	cd $(CARGO_DIR) && cargo build --release && cargo test -q

build:
	cd $(CARGO_DIR) && cargo build --release

test:
	cd $(CARGO_DIR) && cargo test -q

fmt:
	cd $(CARGO_DIR) && cargo fmt --all -- --check

lint:
	cd $(CARGO_DIR) && cargo clippy --all-targets -- -D warnings

# AOT-lower the JAX models to HLO text under artifacts/ (needs jax).
artifacts:
	python3 python/compile/aot.py

# Serving smoke: the synthetic backend needs no artifacts, so this runs
# on a clean checkout. Emits BENCH_serving.json (CI uploads it).
serve-smoke:
	cd $(CARGO_DIR) && cargo run --release -- serve --sim \
		--workers 2 --requests 128 --sweep 1,2 --json ../BENCH_serving.json

# Closed-loop load harness over a two-tenant mix on the native integer
# datapath — the canonical invocation CI's loadtest-smoke job runs.
# Needs no artifacts. Emits BENCH_loadtest.json (CI gates on it).
loadtest:
	cd $(CARGO_DIR) && cargo run --release -- serve --loadtest \
		--backend native --sim-free --workers 2 --clients 1,2 \
		--requests 64 --tenants gold:1:8,bulk:3 \
		--json ../BENCH_loadtest.json

# Chaos drill: kill 1 of 4 sim workers mid-sweep and require contained
# failure + recovery — the canonical invocation CI's chaos-smoke job
# runs. Needs no artifacts. Emits BENCH_chaos.json (CI gates on it).
chaos:
	cd $(CARGO_DIR) && cargo run --release -- serve --loadtest --chaos \
		--sim --workers 4 --clients 8 --requests 96 --backoff-ms 5 \
		--json ../BENCH_chaos.json

# Refresh the committed perf baselines under records/ (quick mode, small
# shapes — the same settings CI's smoke jobs run, so `ocs bench diff`
# compares like against like). Each record is then schema-checked.
# Commit the results together with the PR that changed performance.
bench-record:
	cd $(CARGO_DIR) && OCS_BENCH_QUICK=1 cargo bench --bench hotpath -- \
		--shapes small --no-assert --json ../records/BENCH_quant.json
	cd $(CARGO_DIR) && OCS_BENCH_QUICK=1 cargo bench --bench gemm -- \
		--shapes small --no-assert --json ../records/BENCH_native.json
	cd $(CARGO_DIR) && OCS_BENCH_QUICK=1 cargo run --release -- serve --sim \
		--workers 2 --requests 128 --sweep 1,2 --json ../records/BENCH_serving.json
	cd $(CARGO_DIR) && OCS_BENCH_QUICK=1 cargo run --release -- serve --loadtest \
		--backend native --sim-free --workers 2 --clients 1,2 \
		--requests 64 --tenants gold:1:8,bulk:3 \
		--json ../records/BENCH_loadtest.json
	cd $(CARGO_DIR) && OCS_BENCH_QUICK=1 cargo run --release -- serve --loadtest \
		--chaos --sim --workers 4 --clients 8 --requests 96 --backoff-ms 5 \
		--json ../records/BENCH_chaos.json
	cd $(CARGO_DIR) && cargo run --release -- bench check ../records/BENCH_quant.json --bench quant
	cd $(CARGO_DIR) && cargo run --release -- bench check ../records/BENCH_native.json --bench native
	cd $(CARGO_DIR) && cargo run --release -- bench check ../records/BENCH_serving.json --bench serving
	cd $(CARGO_DIR) && cargo run --release -- bench check ../records/BENCH_loadtest.json --bench loadtest
	cd $(CARGO_DIR) && cargo run --release -- bench check ../records/BENCH_chaos.json --bench chaos
	cd $(CARGO_DIR) && cargo run --release -- bench history ../records

clean:
	cd $(CARGO_DIR) && cargo clean
