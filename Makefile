# Repo-level entry points. The Rust crate lives under rust/; the JAX AOT
# lowering (which produces artifacts/) lives under python/compile/.

CARGO_DIR := rust

.PHONY: verify build test fmt lint artifacts serve-smoke loadtest chaos \
	chaos-matrix slow-drill autotune bench-record bench-snapshot clean

# Tier-1 gate: the exact command CI runs on every push.
verify:
	cd $(CARGO_DIR) && cargo build --release && cargo test -q

build:
	cd $(CARGO_DIR) && cargo build --release

test:
	cd $(CARGO_DIR) && cargo test -q

fmt:
	cd $(CARGO_DIR) && cargo fmt --all -- --check

lint:
	cd $(CARGO_DIR) && cargo clippy --all-targets -- -D warnings

# AOT-lower the JAX models to HLO text under artifacts/ (needs jax).
artifacts:
	python3 python/compile/aot.py

# Serving smoke: the synthetic backend needs no artifacts, so this runs
# on a clean checkout. Emits BENCH_serving.json (CI uploads it).
serve-smoke:
	cd $(CARGO_DIR) && cargo run --release -- serve --sim \
		--workers 2 --requests 128 --sweep 1,2 --json ../BENCH_serving.json

# Closed-loop load harness over a two-tenant mix on the native integer
# datapath — the canonical invocation CI's loadtest-smoke job runs.
# Needs no artifacts. Emits BENCH_loadtest.json (CI gates on it).
loadtest:
	cd $(CARGO_DIR) && cargo run --release -- serve --loadtest \
		--backend native --sim-free --workers 2 --clients 1,2 \
		--requests 64 --tenants gold:1:8,bulk:3 \
		--json ../BENCH_loadtest.json

# Chaos drill: kill 1 of 4 sim workers mid-sweep and require contained
# failure + recovery — the canonical invocation CI's chaos-smoke job
# runs. Needs no artifacts. Emits BENCH_chaos.json (CI gates on it).
chaos:
	cd $(CARGO_DIR) && cargo run --release -- serve --loadtest --chaos \
		--sim --workers 4 --clients 8 --requests 96 --backoff-ms 5 \
		--json ../BENCH_chaos.json

# Chaos drill matrix: single-kill, concurrent multi-kill, a panic
# mid-hot-swap (rollback, not respawn), and a crash-looping tenant
# (quarantined by the per-tenant breaker) — each gated on containment.
# The canonical invocation CI's chaos-matrix-smoke job runs. Needs no
# artifacts. Emits BENCH_chaos_matrix.json (CI gates on it).
chaos-matrix:
	cd $(CARGO_DIR) && cargo run --release -- serve --loadtest --chaos-matrix \
		--sim --workers 4 --clients 8 --requests 96 --backoff-ms 5 \
		--json ../BENCH_chaos_matrix.json

# Slow-worker drill: healthy baseline, then every worker 10 ms slow with
# no deadline (collapse), then the same fault with the deadline armed —
# asserts the deadline path sheds load instead of queueing behind the
# slow engine. --max-batch 1 keeps the per-request slowdown real.
# Needs no artifacts. Emits BENCH_slow.json (CI gates on it).
slow-drill:
	cd $(CARGO_DIR) && cargo run --release -- serve --loadtest --slow-drill \
		--backend native --sim-free --workers 2 --max-batch 1 \
		--deadline-ms 15 --slow-us 10000 --requests 96 \
		--json ../BENCH_slow.json

# Budgeted mixed-precision recipe search on the built-in model — the
# canonical invocation CI's autotune-smoke job runs. Needs no artifacts.
# Emits the winning recipe TOML + a BENCH_autotune.json journal.
autotune:
	cd $(CARGO_DIR) && cargo run --release -- autotune --backend native \
		--sim-free --ladder 8,4 --test 256 --acc-drop 0.05 --allow-skip \
		--out ../recipe_autotuned.toml --json ../BENCH_autotune.json

# Refresh the committed perf baselines under records/ (quick mode, small
# shapes — the same settings CI's smoke jobs run, so `ocs bench diff`
# compares like against like). Each record is then schema-checked.
# Commit the results together with the PR that changed performance.
bench-record:
	cd $(CARGO_DIR) && OCS_BENCH_QUICK=1 cargo bench --bench hotpath -- \
		--shapes small --no-assert --json ../records/BENCH_quant.json
	cd $(CARGO_DIR) && OCS_BENCH_QUICK=1 cargo bench --bench gemm -- \
		--shapes small --no-assert --json ../records/BENCH_native.json
	cd $(CARGO_DIR) && OCS_BENCH_QUICK=1 cargo run --release -- serve --sim \
		--workers 2 --requests 128 --sweep 1,2 --json ../records/BENCH_serving.json
	cd $(CARGO_DIR) && OCS_BENCH_QUICK=1 cargo run --release -- serve --loadtest \
		--backend native --sim-free --workers 2 --clients 1,2 \
		--requests 64 --tenants gold:1:8,bulk:3 \
		--json ../records/BENCH_loadtest.json
	cd $(CARGO_DIR) && OCS_BENCH_QUICK=1 cargo run --release -- serve --loadtest \
		--chaos --sim --workers 4 --clients 8 --requests 96 --backoff-ms 5 \
		--json ../records/BENCH_chaos.json
	cd $(CARGO_DIR) && OCS_BENCH_QUICK=1 cargo run --release -- serve --loadtest \
		--chaos-matrix --sim --workers 4 --clients 8 --requests 96 --backoff-ms 5 \
		--json ../records/BENCH_chaos_matrix.json
	cd $(CARGO_DIR) && cargo run --release -- bench check ../records/BENCH_quant.json --bench quant
	cd $(CARGO_DIR) && cargo run --release -- bench check ../records/BENCH_native.json --bench native
	cd $(CARGO_DIR) && cargo run --release -- bench check ../records/BENCH_serving.json --bench serving
	cd $(CARGO_DIR) && cargo run --release -- bench check ../records/BENCH_loadtest.json --bench loadtest
	cd $(CARGO_DIR) && OCS_BENCH_QUICK=1 cargo run --release -- serve --loadtest \
		--slow-drill --backend native --sim-free --workers 2 --max-batch 1 \
		--deadline-ms 15 --slow-us 10000 --requests 96 \
		--json ../records/BENCH_slow.json
	cd $(CARGO_DIR) && OCS_BENCH_QUICK=1 cargo run --release -- autotune \
		--backend native --sim-free --ladder 8,4 --test 256 --acc-drop 0.05 \
		--allow-skip --out ../recipe_autotuned.toml \
		--json ../records/BENCH_autotune.json
	cd $(CARGO_DIR) && cargo run --release -- bench check ../records/BENCH_chaos.json --bench chaos
	cd $(CARGO_DIR) && cargo run --release -- bench check ../records/BENCH_chaos_matrix.json --bench chaos_matrix
	cd $(CARGO_DIR) && cargo run --release -- bench check ../records/BENCH_slow.json --bench slow
	cd $(CARGO_DIR) && cargo run --release -- bench check ../records/BENCH_autotune.json --bench autotune
	cd $(CARGO_DIR) && cargo run --release -- bench history ../records

# Archive the current committed baselines as a dated per-PR snapshot
# folder; `ocs bench history records/` then renders the trajectory with
# one column per snapshot. Usage: make bench-snapshot PR=9 [DATE=...]
DATE ?= $(shell date +%Y-%m-%d)
bench-snapshot:
	@test -n "$(PR)" || { echo "usage: make bench-snapshot PR=<n> [DATE=YYYY-MM-DD]"; exit 1; }
	mkdir -p records/history/$(DATE)-pr$(PR)
	cp records/BENCH_*.json records/history/$(DATE)-pr$(PR)/
	@echo "snapshot: records/history/$(DATE)-pr$(PR)"

clean:
	cd $(CARGO_DIR) && cargo clean
