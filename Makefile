# Repo-level entry points. The Rust crate lives under rust/; the JAX AOT
# lowering (which produces artifacts/) lives under python/compile/.

CARGO_DIR := rust

.PHONY: verify build test fmt lint artifacts serve-smoke clean

# Tier-1 gate: the exact command CI runs on every push.
verify:
	cd $(CARGO_DIR) && cargo build --release && cargo test -q

build:
	cd $(CARGO_DIR) && cargo build --release

test:
	cd $(CARGO_DIR) && cargo test -q

fmt:
	cd $(CARGO_DIR) && cargo fmt --all -- --check

lint:
	cd $(CARGO_DIR) && cargo clippy --all-targets -- -D warnings

# AOT-lower the JAX models to HLO text under artifacts/ (needs jax).
artifacts:
	python3 python/compile/aot.py

# Serving smoke: the synthetic backend needs no artifacts, so this runs
# on a clean checkout. Emits BENCH_serving.json (CI uploads it).
serve-smoke:
	cd $(CARGO_DIR) && cargo run --release -- serve --sim \
		--workers 2 --requests 128 --sweep 1,2 --json ../BENCH_serving.json

clean:
	cd $(CARGO_DIR) && cargo clean
