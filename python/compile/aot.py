"""AOT compile path: lower every model artifact to HLO text.

Run once by ``make artifacts``; python never runs on the request path.

Interchange format is HLO **text**, not a serialized HloModuleProto: the
xla crate's xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit
instruction ids, `proto.id() <= INT_MAX`); the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per model, under artifacts/<model>/:
    fwd_b{B}.hlo.txt     quantized inference at batch B
    probe_b{B}.hlo.txt   float inference + per-layer input activations
    train_b{B}.hlo.txt   fwd+bwd+SGD(momentum) step
    init.ocst            seeded initial float parameters
    meta.json            layer table + exact input/output signatures

The Rust coordinator discovers everything through meta.json; signatures
are recorded here (name/dtype/shape per input, in positional order) so
the two sides can never drift.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .ocst import write_ocst

CNN_FWD_BATCHES = [1, 2, 4, 8, 32, 128]
# probe artifacts: calibration uses b=32; Table 4 (Oracle OCS) sweeps all
# batch sizes on miniresnet + miniincept.
PROBE_BATCHES = {
    "minivgg": [32],
    "miniresnet": CNN_FWD_BATCHES,
    "miniincept": CNN_FWD_BATCHES,
}
CNN_TRAIN_BATCH = 64
LSTM_BATCH = 32
SEED = 20190613  # ICML 2019 week; fixed for reproducibility


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# ---------------------------------------------------------------------------
# Flat signatures: (name, dtype, shape) triples in positional order
# ---------------------------------------------------------------------------


def data_inputs(model, batch):
    if model.name == "lstmlm":
        return [("tokens", "i32", (batch, M.SEQ_LEN + 1))]
    return [("x", "f32", (batch, M.IMG_HW, M.IMG_HW, M.IMG_C))]


def fwd_signature(model, batch):
    sig = data_inputs(model, batch)
    for spec in model.specs:
        sig.append((f"{spec.name}.W", "f32", spec.w_shape(padded=True)))
        if spec.kind != "embed":
            sig.append((f"{spec.name}.b", "f32", (spec.cout,)))
        if spec.quantized:
            cp = spec.cin_pad
            sig += [
                (f"{spec.name}.idx", "i32", (cp,)),
                (f"{spec.name}.dscale", "f32", (cp,)),
                (f"{spec.name}.dbias", "f32", (cp,)),
                (f"{spec.name}.adelta", "f32", ()),
                (f"{spec.name}.aqmax", "f32", ()),
            ]
    return sig


def float_param_signature(model):
    sig = []
    for spec in model.specs:
        sig.append((f"{spec.name}.W", "f32", spec.w_shape(padded=False)))
        if spec.kind != "embed":
            sig.append((f"{spec.name}.b", "f32", (spec.cout,)))
    return sig


def probe_signature(model, batch):
    return float_param_signature(model) + data_inputs(model, batch)


def train_signature(model, batch):
    p = float_param_signature(model)
    mom = [("m." + n, d, s) for (n, d, s) in p]
    sig = p + mom + data_inputs(model, batch)
    if model.name != "lstmlm":
        sig.append(("y", "i32", (batch,)))
    sig.append(("lr", "f32", ()))
    return sig


def _unflatten_named(model, names, args, padded):
    """Rebuild params/hooks dicts from flat positional args."""
    byname = dict(zip(names, args))
    params, hooks = {}, {}
    for spec in model.specs:
        entry = {"W": byname[f"{spec.name}.W"]}
        if spec.kind != "embed":
            entry["b"] = byname[f"{spec.name}.b"]
        params[spec.name] = entry
        if padded and spec.quantized:
            hooks[spec.name] = {
                "idx": byname[f"{spec.name}.idx"],
                "dscale": byname[f"{spec.name}.dscale"],
                "dbias": byname[f"{spec.name}.dbias"],
                "adelta": byname[f"{spec.name}.adelta"],
                "aqmax": byname[f"{spec.name}.aqmax"],
            }
    return params, hooks


# ---------------------------------------------------------------------------
# Artifact builders
# ---------------------------------------------------------------------------


def build_fwd(model, batch):
    sig = fwd_signature(model, batch)
    names = [n for n, _, _ in sig]

    def fn(*args):
        byname = dict(zip(names, args))
        params, hooks = _unflatten_named(model, names, args, padded=True)
        data = byname["tokens"] if model.name == "lstmlm" else byname["x"]
        out = model.forward(params, data, hooks=hooks)
        if model.name == "lstmlm":
            return out  # (nll_sum, ntok)
        return (out,)

    if model.name == "lstmlm":
        outs = [("nll_sum", ()), ("ntok", ())]
    else:
        outs = [("logits", (batch, M.NUM_CLASSES))]
    return fn, sig, outs


def build_probe(model, batch):
    sig = probe_signature(model, batch)
    names = [n for n, _, _ in sig]
    qspecs = [s for s in model.specs if s.quantized]

    def fn(*args):
        byname = dict(zip(names, args))
        params, _ = _unflatten_named(model, names, args, padded=False)
        data = byname["tokens"] if model.name == "lstmlm" else byname["x"]
        probe = {}
        logits = model.forward(params, data, hooks=None, probe=probe)
        return (logits,) + tuple(probe[s.name] for s in qspecs)

    # output shapes via eval_shape
    example = [sds(s, jnp.int32 if d == "i32" else jnp.float32) for _, d, s in sig]
    shapes = jax.eval_shape(fn, *example)
    outs = [("logits", tuple(shapes[0].shape))]
    for s, sh in zip(qspecs, shapes[1:]):
        outs.append((f"act.{s.name}", tuple(sh.shape)))
    return fn, sig, outs


def build_train(model, batch):
    sig = train_signature(model, batch)
    names = [n for n, _, _ in sig]
    train_step = M.make_train_step(model)
    nparams = len(float_param_signature(model))

    def fn(*args):
        byname = dict(zip(names, args))
        pleaves = list(args[:nparams])
        mleaves = list(args[nparams : 2 * nparams])
        if model.name == "lstmlm":
            batch_data = byname["tokens"]
        else:
            batch_data = (byname["x"], byname["y"])
        new_p, new_m, loss = train_step(pleaves, mleaves, batch_data, byname["lr"])
        return tuple(new_p) + tuple(new_m) + (loss,)

    pnames = [n for n, _, _ in float_param_signature(model)]
    outs = [(n, s) for (n, _, s) in sig[:nparams]]
    outs += [("m." + n, s) for (n, s) in zip(pnames, [s for _, _, s in sig[:nparams]])]
    outs.append(("loss", ()))
    return fn, sig, outs


def lower_to_file(fn, sig, path):
    example = [sds(s, jnp.int32 if d == "i32" else jnp.float32) for _, d, s in sig]
    lowered = jax.jit(fn).lower(*example)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def sig_json(sig):
    return [{"name": n, "dtype": d, "shape": list(s)} for n, d, s in sig]


def outs_json(outs):
    return [{"name": n, "shape": list(s)} for n, s in outs]


def compile_model(name, out_dir, quick=False):
    model = M.get_model(name)
    mdir = os.path.join(out_dir, name)
    os.makedirs(mdir, exist_ok=True)

    if name == "lstmlm":
        fwd_batches = [LSTM_BATCH]
        probe_batches = []
        train_batch = LSTM_BATCH
    else:
        fwd_batches = CNN_FWD_BATCHES if not quick else [8]
        probe_batches = PROBE_BATCHES[name] if not quick else [8]
        train_batch = CNN_TRAIN_BATCH if not quick else 8

    artifacts = {}
    for b in fwd_batches:
        fn, sig, outs = build_fwd(model, b)
        fname = f"fwd_b{b}.hlo.txt"
        n = lower_to_file(fn, sig, os.path.join(mdir, fname))
        artifacts[f"fwd_b{b}"] = {
            "file": fname,
            "batch": b,
            "inputs": sig_json(sig),
            "outputs": outs_json(outs),
        }
        print(f"  {name}/{fname}: {n} chars")
    for b in probe_batches:
        fn, sig, outs = build_probe(model, b)
        fname = f"probe_b{b}.hlo.txt"
        n = lower_to_file(fn, sig, os.path.join(mdir, fname))
        artifacts[f"probe_b{b}"] = {
            "file": fname,
            "batch": b,
            "inputs": sig_json(sig),
            "outputs": outs_json(outs),
        }
        print(f"  {name}/{fname}: {n} chars")
    fn, sig, outs = build_train(model, train_batch)
    fname = f"train_b{train_batch}.hlo.txt"
    n = lower_to_file(fn, sig, os.path.join(mdir, fname))
    artifacts["train"] = {
        "file": fname,
        "batch": train_batch,
        "inputs": sig_json(sig),
        "outputs": outs_json(outs),
    }
    print(f"  {name}/{fname}: {n} chars")

    # initial parameters
    params = model.init_params(SEED)
    leaves = [(n, np.asarray(a)) for n, a in M.flatten_params(model, params)]
    write_ocst(os.path.join(mdir, "init.ocst"), leaves)

    meta = {
        "model": name,
        "pad_factor": M.PAD_FACTOR,
        "seed": SEED,
        "num_classes": M.NUM_CLASSES,
        "img_hw": M.IMG_HW,
        "img_c": M.IMG_C,
        "vocab": M.VOCAB,
        "seq_len": M.SEQ_LEN,
        "momentum": M.MOMENTUM,
        "layers": [s.meta() for s in model.specs],
        "artifacts": artifacts,
    }
    with open(os.path.join(mdir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="minivgg,miniresnet,miniincept,lstmlm")
    ap.add_argument(
        "--quick", action="store_true", help="single small batch per model (CI smoke)"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    names = [m for m in args.models.split(",") if m]
    for name in names:
        print(f"[aot] lowering {name} ...")
        compile_model(name, args.out_dir, quick=args.quick)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump({"models": names}, f)
    print("[aot] done")


if __name__ == "__main__":
    main()
