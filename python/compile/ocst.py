"""Writer for the `.ocst` tensor-bundle format.

A deliberately trivial binary container (little-endian) shared between
the python compile path and the Rust coordinator — no zip/npz machinery
so the Rust reader (rust/src/tensor/io.rs) stays dependency-free:

    magic   : 8 bytes  b"OCST0001"
    count   : u32      number of tensors
    entry   : u16 name_len | name utf-8
              u8  dtype (0 = f32, 1 = i32)
              u8  ndim
              u32 * ndim dims
              raw little-endian data (4 bytes/elem)
"""

import struct

import numpy as np

MAGIC = b"OCST0001"
DTYPE_F32 = 0
DTYPE_I32 = 1


def write_ocst(path, tensors):
    """tensors: list of (name, np.ndarray) with dtype float32 or int32."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            arr = np.asarray(arr)
            if arr.dtype == np.float32:
                dt = DTYPE_F32
            elif arr.dtype == np.int32:
                dt = DTYPE_I32
            else:
                raise ValueError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", dt, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(np.ascontiguousarray(arr).tobytes())


def read_ocst(path):
    """Inverse of write_ocst — used by the python-side round-trip tests."""
    out = []
    with open(path, "rb") as f:
        assert f.read(8) == MAGIC, "bad magic"
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            dt, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack("<" + "I" * ndim, f.read(4 * ndim))
            n = int(np.prod(dims)) if ndim else 1
            dtype = np.float32 if dt == DTYPE_F32 else np.int32
            data = np.frombuffer(f.read(4 * n), dtype=dtype).reshape(dims)
            out.append((name, data))
    return out
