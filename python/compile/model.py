"""Layer-2 JAX benchmark models with OCS quantization hooks.

Every model is built in three flavours, each AOT-lowered by ``aot.py``:

* ``fwd``   — quantized inference. Each quantizable layer consumes runtime
  inputs ``(W, b, idx, dscale, dbias, adelta, aqmax)``. The input-channel
  axis of quantized weights is padded to ``cin_pad = ceil(PAD_FACTOR*cin)``
  so a single artifact serves every OCS expand ratio r <= PAD_FACTOR-1:
  the Rust coordinator materializes duplicated channels into the padded
  slots and steers them with ``idx``/``dscale``/``dbias``
  (kernels.channel_dup). Activations are quantized by kernels.fake_quant
  (or fused inside kernels.qmatmul for FC layers) with runtime
  ``adelta``/``aqmax`` scalars — ``aqmax <= 0`` bypasses quantization.
* ``probe`` — float inference (unpadded weights, no hooks) that also
  returns every quantizable layer's *input* activation, used by the Rust
  calibrator to build per-layer histograms and by Oracle OCS (§5.3).
* ``train`` — float fwd+bwd+SGD(momentum) step, params/momentum in and
  out. The Rust trainer drives the whole training loop through this
  artifact; python never runs at training time.

Benchmark models (substitutes for the paper's ImageNet zoo — see
DESIGN.md §1): ``minivgg`` (plain stack), ``miniresnet`` (ResNet-20-like,
also Table 1's model), ``miniincept`` (parallel branches), ``lstmlm``
(2-layer LSTM LM, Table 6). First conv layers are left unquantized, as in
the paper (§5: 3 input channels would make OCS overhead huge).
"""

import dataclasses
import math
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import channel_dup, fake_quant, qmatmul

# One artifact serves every expand ratio up to PAD_FACTOR - 1 (the paper's
# largest evaluated ratio is r = 0.2; Table 1 needs up to 0.2).
PAD_FACTOR = 1.25

# Image task geometry (synthetic 10-class dataset, generated in Rust).
IMG_HW = 16
IMG_C = 3
NUM_CLASSES = 10

# LSTM LM geometry.
VOCAB = 2000
EMBED = 192
HIDDEN = 192
SEQ_LEN = 32

MOMENTUM = 0.9


def pad_channels(c: int) -> int:
    """Padded channel capacity reserved for OCS duplicates."""
    return int(math.ceil(PAD_FACTOR * c))


@dataclasses.dataclass
class LayerSpec:
    """One (potentially quantizable) parametric layer."""

    name: str
    kind: str  # 'conv' | 'fc' | 'embed'
    cin: int
    cout: int
    ksize: int = 3
    stride: int = 1
    quantized: bool = True

    @property
    def cin_pad(self) -> int:
        return pad_channels(self.cin) if self.quantized else self.cin

    def w_shape(self, padded: bool):
        cin = self.cin_pad if (padded and self.quantized) else self.cin
        if self.kind == "conv":
            return (self.ksize, self.ksize, cin, self.cout)
        return (cin, self.cout)  # fc / embed

    def meta(self) -> dict:
        return {
            "name": self.name,
            "kind": self.kind,
            "cin": self.cin,
            "cin_pad": self.cin_pad,
            "cout": self.cout,
            "ksize": self.ksize,
            "stride": self.stride,
            "quantized": self.quantized,
            # axis of the input-channel dim in the weight tensor
            "w_cin_axis": 2 if self.kind == "conv" else 0,
            "w_shape": list(self.w_shape(padded=False)),
            "w_shape_pad": list(self.w_shape(padded=True)),
        }


class ModelDef:
    """A benchmark model: layer table + forward topology."""

    def __init__(self, name: str, specs: List[LayerSpec]):
        self.name = name
        self.specs = specs
        self.by_name = {s.name: s for s in specs}

    # ---- parameter init (He normal, fixed seed per model) ----------------
    def init_params(self, seed: int) -> Dict[str, Dict[str, jnp.ndarray]]:
        key = jax.random.PRNGKey(seed)
        params = {}
        for spec in self.specs:
            key, k = jax.random.split(key)
            shape = spec.w_shape(padded=False)
            if spec.kind == "conv":
                fan_in = spec.ksize * spec.ksize * spec.cin
            else:
                fan_in = spec.cin
            if spec.kind == "embed":
                w = jax.random.normal(k, shape, jnp.float32) * 0.05
                params[spec.name] = {"W": w}
            else:
                std = math.sqrt(2.0 / fan_in)
                # Damp the final conv of each residual branch (BN-free
                # ResNet trick) so deep stacks start well-conditioned.
                if spec.name.endswith("c2"):
                    std *= 0.1
                w = jax.random.normal(k, shape, jnp.float32) * std
                params[spec.name] = {
                    "W": w,
                    "b": jnp.zeros((spec.cout,), jnp.float32),
                }
        return params

    # ---- forward topology — overridden per model --------------------------
    def forward(self, params, x, hooks=None, probe=None):
        raise NotImplementedError

    def loss(self, params, batch):
        raise NotImplementedError


# ---------------------------------------------------------------------------
# Layer application helpers
# ---------------------------------------------------------------------------


def _conv(x, w, b, stride):
    y = lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def _maxpool(x, k=2, s=2):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, k, k, 1), (1, s, s, 1), "SAME"
    )


def _maxpool_same(x, k=3):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, k, k, 1), (1, 1, 1, 1), "SAME"
    )


def apply_layer(spec, params, x, hooks, probe):
    """Apply one parametric layer in either float or quantized mode.

    hooks is None  -> float mode: unpadded weight, no dup/quant ops.
    hooks present  -> quantized mode: channel_dup + fake_quant in front.
    probe, if a dict, records the float input activation of quantized
    layers (the distribution the calibrator profiles).
    """
    p = params[spec.name]
    if probe is not None and spec.quantized:
        probe[spec.name] = x
    if hooks is None or not spec.quantized:
        if spec.kind == "conv":
            return _conv(x, p["W"], p["b"], spec.stride)
        return x @ p["W"] + p["b"]
    h = hooks[spec.name]
    xe = channel_dup(x, h["idx"], h["dscale"], h["dbias"])
    if spec.kind == "conv":
        xq = fake_quant(xe, h["adelta"], h["aqmax"])
        return _conv(xq, p["W"], p["b"], spec.stride)
    return qmatmul(xe, p["W"], h["adelta"], h["aqmax"]) + p["b"]


def _xent(logits, y):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


# ---------------------------------------------------------------------------
# MiniVGG — plain conv stack (stands in for VGG-16 BN)
# ---------------------------------------------------------------------------


class MiniVGG(ModelDef):
    def __init__(self):
        specs = [
            LayerSpec("c1", "conv", IMG_C, 24, quantized=False),
            LayerSpec("c2", "conv", 24, 32),
            LayerSpec("c3", "conv", 32, 48),
            LayerSpec("c4", "conv", 48, 64),
            LayerSpec("c5", "conv", 64, 96),
            LayerSpec("f1", "fc", 96 * 2 * 2, 128, ksize=0),
            LayerSpec("f2", "fc", 128, NUM_CLASSES, ksize=0),
        ]
        super().__init__("minivgg", specs)

    def forward(self, params, x, hooks=None, probe=None):
        s = self.by_name
        a = lambda n, v: apply_layer(s[n], params, v, hooks, probe)
        x = jax.nn.relu(a("c1", x))
        x = jax.nn.relu(a("c2", x))
        x = _maxpool(x)  # 8x8
        x = jax.nn.relu(a("c3", x))
        x = jax.nn.relu(a("c4", x))
        x = _maxpool(x)  # 4x4
        x = jax.nn.relu(a("c5", x))
        x = _maxpool(x)  # 2x2
        x = x.reshape(x.shape[0], -1)  # 384
        x = jax.nn.relu(a("f1", x))
        return a("f2", x)

    def loss(self, params, batch):
        x, y = batch
        return _xent(self.forward(params, x), y)


# ---------------------------------------------------------------------------
# MiniResNet — ResNet-20-like (stands in for ResNet-50; Table 1's model)
# ---------------------------------------------------------------------------


class MiniResNet(ModelDef):
    WIDTHS = (16, 32, 64)
    BLOCKS = 2

    def __init__(self):
        specs = [LayerSpec("stem", "conv", IMG_C, 16, quantized=False)]
        cin = 16
        for si, w in enumerate(self.WIDTHS):
            for bi in range(self.BLOCKS):
                stride = 2 if (si > 0 and bi == 0) else 1
                bname = f"s{si}b{bi}"
                specs.append(LayerSpec(f"{bname}c1", "conv", cin, w, stride=stride))
                specs.append(LayerSpec(f"{bname}c2", "conv", w, w))
                if cin != w:
                    specs.append(
                        LayerSpec(f"{bname}sc", "conv", cin, w, ksize=1, stride=stride)
                    )
                cin = w
        specs.append(LayerSpec("fc", "fc", 64, NUM_CLASSES, ksize=0))
        super().__init__("miniresnet", specs)

    def forward(self, params, x, hooks=None, probe=None):
        s = self.by_name
        a = lambda n, v: apply_layer(s[n], params, v, hooks, probe)
        x = jax.nn.relu(a("stem", x))
        cin = 16
        for si, w in enumerate(self.WIDTHS):
            for bi in range(self.BLOCKS):
                bname = f"s{si}b{bi}"
                h = jax.nn.relu(a(f"{bname}c1", x))
                h = a(f"{bname}c2", h)
                sc = a(f"{bname}sc", x) if cin != w else x
                x = jax.nn.relu(h + sc)
                cin = w
        x = jnp.mean(x, axis=(1, 2))  # GAP -> (B, 64)
        return a("fc", x)

    def loss(self, params, batch):
        x, y = batch
        return _xent(self.forward(params, x), y)


# ---------------------------------------------------------------------------
# MiniIncept — parallel-branch blocks (stands in for Inception-V3)
# ---------------------------------------------------------------------------


class MiniIncept(ModelDef):
    def __init__(self):
        specs = [
            LayerSpec("stem", "conv", IMG_C, 16, quantized=False),
            # block A over 16 channels @ 8x8
            LayerSpec("a_b1", "conv", 16, 12, ksize=1),
            LayerSpec("a_b2a", "conv", 16, 8, ksize=1),
            LayerSpec("a_b2b", "conv", 8, 16),
            LayerSpec("a_b3", "conv", 16, 8, ksize=1),
            # reduce to 4x4
            LayerSpec("red", "conv", 36, 48, stride=2),
            # block B over 48 channels @ 4x4
            LayerSpec("b_b1", "conv", 48, 16, ksize=1),
            LayerSpec("b_b2a", "conv", 48, 12, ksize=1),
            LayerSpec("b_b2b", "conv", 12, 24),
            LayerSpec("b_b3", "conv", 48, 12, ksize=1),
            LayerSpec("fc", "fc", 52, NUM_CLASSES, ksize=0),
        ]
        super().__init__("miniincept", specs)

    def forward(self, params, x, hooks=None, probe=None):
        s = self.by_name
        a = lambda n, v: apply_layer(s[n], params, v, hooks, probe)
        x = jax.nn.relu(a("stem", x))
        x = _maxpool(x)  # 8x8
        b1 = jax.nn.relu(a("a_b1", x))
        b2 = jax.nn.relu(a("a_b2b", jax.nn.relu(a("a_b2a", x))))
        b3 = jax.nn.relu(a("a_b3", _maxpool_same(x)))
        x = jnp.concatenate([b1, b2, b3], axis=-1)  # 36
        x = jax.nn.relu(a("red", x))  # 4x4 x 48
        b1 = jax.nn.relu(a("b_b1", x))
        b2 = jax.nn.relu(a("b_b2b", jax.nn.relu(a("b_b2a", x))))
        b3 = jax.nn.relu(a("b_b3", _maxpool_same(x)))
        x = jnp.concatenate([b1, b2, b3], axis=-1)  # 52
        x = jnp.mean(x, axis=(1, 2))
        return a("fc", x)

    def loss(self, params, batch):
        x, y = batch
        return _xent(self.forward(params, x), y)


# ---------------------------------------------------------------------------
# LstmLM — 2-layer LSTM language model (stands in for the WikiText-2 model)
# ---------------------------------------------------------------------------


class LstmLM(ModelDef):
    def __init__(self):
        specs = [
            LayerSpec("embed", "embed", VOCAB, EMBED, ksize=0, quantized=False),
            LayerSpec("l0", "fc", EMBED + HIDDEN, 4 * HIDDEN, ksize=0),
            LayerSpec("l1", "fc", 2 * HIDDEN, 4 * HIDDEN, ksize=0),
            LayerSpec("proj", "fc", HIDDEN, VOCAB, ksize=0),
        ]
        super().__init__("lstmlm", specs)

    def _gate(self, params, hooks, name, xh):
        spec = self.by_name[name]
        return apply_layer(spec, params, xh, hooks, None)

    def forward(self, params, tokens, hooks=None, probe=None):
        """tokens: (B, T+1) int32. Returns (nll_sum, ntok)."""
        inp = tokens[:, :-1]
        tgt = tokens[:, 1:]
        emb = jnp.take(params["embed"]["W"], inp, axis=0)  # (B,T,E)
        b = inp.shape[0]
        h0 = jnp.zeros((b, HIDDEN), jnp.float32)
        init = (h0, h0, h0, h0)

        def cell(gates, c):
            i, f, g, o = jnp.split(gates, 4, axis=-1)
            cn = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
            hn = jax.nn.sigmoid(o) * jnp.tanh(cn)
            return hn, cn

        def step(carry, xt):
            h0, c0, h1, c1 = carry
            g0 = self._gate(params, hooks, "l0", jnp.concatenate([xt, h0], -1))
            h0n, c0n = cell(g0, c0)
            g1 = self._gate(params, hooks, "l1", jnp.concatenate([h0n, h1], -1))
            h1n, c1n = cell(g1, c1)
            logits = self._gate(params, hooks, "proj", h1n)
            return (h0n, c0n, h1n, c1n), logits

        _, logits = lax.scan(step, init, emb.transpose(1, 0, 2))
        # logits: (T, B, V); targets transposed to (T, B)
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, tgt.T[..., None], axis=-1)[..., 0]
        return nll.sum(), jnp.float32(nll.size)

    def loss(self, params, batch):
        tokens = batch
        nll_sum, ntok = self.forward(params, tokens)
        return nll_sum / ntok


# ---------------------------------------------------------------------------
# Training step (shared)
# ---------------------------------------------------------------------------


def flatten_params(model: ModelDef, params):
    """Deterministic (name, leaf) flattening: spec order, W then b."""
    out = []
    for spec in model.specs:
        p = params[spec.name]
        out.append((f"{spec.name}.W", p["W"]))
        if "b" in p:
            out.append((f"{spec.name}.b", p["b"]))
    return out


def unflatten_params(model: ModelDef, leaves):
    params = {}
    i = 0
    for spec in model.specs:
        entry = {"W": leaves[i]}
        i += 1
        if spec.kind != "embed":
            entry["b"] = leaves[i]
            i += 1
        params[spec.name] = entry
    return params


def make_train_step(model: ModelDef):
    """Returns f(param_leaves, mom_leaves, batch..., lr) -> (new_p, new_m, loss).

    Plain SGD with momentum MOMENTUM; lr is a runtime scalar so the Rust
    trainer owns the schedule.
    """

    def train_step(param_leaves, mom_leaves, batch, lr):
        params = unflatten_params(model, param_leaves)

        def loss_fn(p):
            return model.loss(p, batch)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        gleaves = [g for _, g in flatten_params(model, grads)]
        new_m = [MOMENTUM * m + g for m, g in zip(mom_leaves, gleaves)]
        new_p = [p - lr * m for p, m in zip(param_leaves, new_m)]
        return new_p, new_m, loss

    return train_step


MODELS = {
    "minivgg": MiniVGG,
    "miniresnet": MiniResNet,
    "miniincept": MiniIncept,
    "lstmlm": LstmLM,
}


def get_model(name: str) -> ModelDef:
    return MODELS[name]()
