"""Pallas kernel: fused fake-quant + GEMM for fully-connected layers.

The activation tile is quantize-dequantized *inside* the matmul kernel so
the quantization pass adds no extra HBM round-trip — the TPU analogue of
the paper's GPU fake-quantized GEMM. Weights arrive already
fake-quantized (done offline by the Rust coordinator), so only the
activation side is quantized here.

Blocks are MXU-shaped (128 x 128 output tile, full-K panels); the K axis
is kept resident per block because every FC layer in the benchmark
models has K <= 2048 (VMEM budget ~= (BM + BN) * K * 4B + BM * BN * 4B).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 128
BN = 128


def _qmatmul_kernel(x_ref, w_ref, d_ref, q_ref, o_ref):
    x = x_ref[...]  # (BM, K)
    w = w_ref[...]  # (K, BN)
    delta = d_ref[0]
    qmax = q_ref[0]
    xq = jnp.clip(jnp.floor(x / delta + 0.5), -qmax, qmax) * delta
    xq = jnp.where(qmax > 0, xq, x)
    o_ref[...] = jnp.dot(xq, w, preferred_element_type=jnp.float32)


def qmatmul(x, w, delta, qmax):
    """Compute ``fake_quant(x, delta, qmax) @ w``.

    Args:
      x: (M, K) float32 activations.
      w: (K, N) float32 weights (already fake-quantized offline).
      delta, qmax: runtime scalars, as in :func:`fake_quant.fake_quant`.

    Returns:
      (M, N) float32.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    pad_m = (-m) % BM
    pad_n = (-n) % BN
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    if pad_n:
        w = jnp.pad(w, ((0, 0), (0, pad_n)))
    mp, np_ = x.shape[0], w.shape[1]
    delta = jnp.asarray(delta, jnp.float32).reshape(1)
    qmax = jnp.asarray(qmax, jnp.float32).reshape(1)
    grid = (mp // BM, np_ // BN)
    out = pl.pallas_call(
        _qmatmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, BN), lambda i, j: (0, j)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(x, w, delta, qmax)
    return out[:m, :n]
