"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest (plus hypothesis shape /
value sweeps) asserts the Pallas kernels match these to float32
tolerance. They also document the exact semantics the Rust quantizer
mirrors (same round-half-up rule, same sign-magnitude clip range).
"""

import jax.numpy as jnp


def round_half_up(v):
    """The paper's Q(x) = floor(x + 0.5) — halves round toward +inf."""
    return jnp.floor(v + 0.5)


def fake_quant_ref(x, delta, qmax):
    """Reference for kernels.fake_quant (Eq. 1 with clip)."""
    delta = jnp.asarray(delta, jnp.float32)
    qmax = jnp.asarray(qmax, jnp.float32)
    y = jnp.clip(round_half_up(x / delta), -qmax, qmax) * delta
    return jnp.where(qmax > 0, y, x)


def channel_dup_ref(x, idx, scale, bias):
    """Reference for kernels.channel_dup."""
    return jnp.take(x, idx, axis=-1) * scale + bias


def qmatmul_ref(x, w, delta, qmax):
    """Reference for kernels.qmatmul."""
    return fake_quant_ref(x, delta, qmax) @ w
