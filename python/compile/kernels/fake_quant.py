"""Pallas kernel: linear quantize-dequantize (paper Eq. 1).

``y = clamp(Q(x / delta), -qmax, qmax) * delta`` with the paper's
deterministic rounding ``Q(v) = floor(v + 0.5)`` (round-half-up — the
rounding rule the quantization-aware splitting proof of §3.3 relies on;
*not* banker's rounding).

``delta`` and ``qmax`` are runtime scalars so one AOT-compiled artifact
serves every bitwidth and clip threshold; ``qmax <= 0`` bypasses
quantization entirely (float passthrough), which is how the float
baseline and "weights-only" configurations run.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One grid step processes BLOCK contiguous elements. 8 * 128 * 8 = a whole
# number of (8, 128) f32 VREGs per step on TPU; on CPU (interpret) it is
# simply a cache-friendly tile.
BLOCK = 8 * 128 * 8


def _fake_quant_kernel(x_ref, d_ref, q_ref, o_ref):
    x = x_ref[...]
    delta = d_ref[0]
    qmax = q_ref[0]
    # Paper rounding: floor(v + 0.5), halves toward +inf.
    q = jnp.floor(x / delta + 0.5)
    y = jnp.clip(q, -qmax, qmax) * delta
    o_ref[...] = jnp.where(qmax > 0, y, x)


def fake_quant(x, delta, qmax):
    """Quantize-dequantize ``x`` on a symmetric linear grid.

    Args:
      x: any-shape float32 array.
      delta: scalar float32 — grid step (clip_threshold / qmax).
      qmax: scalar float32 — largest grid index, ``2^{k-1} - 1`` for k-bit
        sign-magnitude quantization. ``qmax <= 0`` disables quantization.

    Returns:
      Array of the same shape/dtype as ``x``.
    """
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    grid = (flat.shape[0] // BLOCK,)
    delta = jnp.asarray(delta, jnp.float32).reshape(1)
    qmax = jnp.asarray(qmax, jnp.float32).reshape(1)
    out = pl.pallas_call(
        _fake_quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, jnp.float32),
        interpret=True,
    )(flat, delta, qmax)
    if pad:
        out = out[:n]
    return out.reshape(shape)
