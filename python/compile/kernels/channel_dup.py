"""Pallas kernel: the OCS channel duplicate/scale layer (paper §3.5).

OCS cannot target single values — it duplicates whole channels. At run
time this is a gather along the channel axis plus an affine correction:

    y[..., j] = x[..., idx[j]] * scale[j] + bias[j]

* Weight OCS (Eq. 3): the duplicated activation channel is passed through
  unscaled (``scale = 1``) — the halving lives in the weights.
* Activation OCS (Eq. 4): both halves carry ``scale = 0.5``; with
  quantization-aware splitting (Eq. 6 applied to activations) the two
  halves additionally receive ``bias = ∓ delta/4``.
* Padded slots (the artifact reserves ``cin_pad = ceil(1.25 * cin)``
  channels): ``idx = 0, scale = 0, bias = 0`` — functionally inert.

On TPU this is a lane permute inside VMEM; here the gather runs under
``interpret=True``.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step; the full channel axes (C in, P out) stay resident.
ROW_BLOCK = 256


def _channel_dup_kernel(x_ref, idx_ref, s_ref, b_ref, o_ref):
    x = x_ref[...]  # (ROW_BLOCK, C)
    idx = idx_ref[...]  # (P,)
    y = jnp.take(x, idx, axis=1)
    o_ref[...] = y * s_ref[...][None, :] + b_ref[...][None, :]


def channel_dup(x, idx, scale, bias):
    """Expand the trailing channel axis of ``x`` from C to P = len(idx).

    Args:
      x: (..., C) float32.
      idx: (P,) int32 in [0, C) — source channel of each output slot.
      scale: (P,) float32.
      bias: (P,) float32.

    Returns:
      (..., P) float32.
    """
    lead = x.shape[:-1]
    c = x.shape[-1]
    p = idx.shape[0]
    rows = 1
    for d in lead:
        rows *= d
    flat = x.reshape(rows, c)
    pad = (-rows) % ROW_BLOCK
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    grid = (flat.shape[0] // ROW_BLOCK,)
    out = pl.pallas_call(
        _channel_dup_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, c), lambda i: (i, 0)),
            pl.BlockSpec((p,), lambda i: (0,)),
            pl.BlockSpec((p,), lambda i: (0,)),
            pl.BlockSpec((p,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((flat.shape[0], p), jnp.float32),
        interpret=True,
    )(flat, idx, scale, bias)
    if pad:
        out = out[:rows]
    return out.reshape(lead + (p,))
