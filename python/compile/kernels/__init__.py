"""Layer-1 Pallas kernels for the OCS quantization stack.

Three kernels cover the paper's runtime compute:

* :func:`fake_quant.fake_quant` — Eq. 1 linear quantize-dequantize with a
  runtime clip threshold (the simulated-quantization hot-spot).
* :func:`channel_dup.channel_dup` — the OCS "custom layer" of paper §3.5:
  duplicate + scale (+ bias, for quantization-aware activation splits)
  selected channels.
* :func:`qmatmul.qmatmul` — fused fake-quant + GEMM for FC layers.

All kernels run with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); block shapes are still chosen MXU/VREG-shaped so the same
code is TPU-credible. Pure-jnp oracles live in :mod:`ref`.
"""

from .fake_quant import fake_quant
from .channel_dup import channel_dup
from .qmatmul import qmatmul

__all__ = ["fake_quant", "channel_dup", "qmatmul"]
