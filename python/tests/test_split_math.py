"""Paper §3.3 math: quantization-aware splitting preserves Q(w).

Validates Eq. 7 (via Hermite's identity) for the rounding function
Q(x) = floor(x + 0.5). The Rust ocs::split module implements the same
formulas; these tests pin the python/jax side of the contract.
"""

import math

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import round_half_up


def Q(x):
    return math.floor(x + 0.5)


@settings(max_examples=200, deadline=None)
@given(st.floats(-1e5, 1e5))
def test_qa_split_preserves_quantized_value(w):
    # Q(w) == Q((w-0.5)/2) + Q((w+0.5)/2)   (Eq. 7)
    assert Q(w) == Q((w - 0.5) / 2) + Q((w + 0.5) / 2)


@settings(max_examples=200, deadline=None)
@given(st.floats(-1e5, 1e5), st.integers(2, 7))
def test_hermite_identity(x, n):
    # sum_{k=0}^{n-1} floor(x + k/n) == floor(n x)   (Eq. 8)
    lhs = sum(math.floor(x + k / n) for k in range(n))
    assert lhs == math.floor(n * x)


def test_naive_split_can_double_error():
    # the paper's w=3 example with a grid step of 2 (odd halves):
    # naive halves 1.5 + 1.5 round to 2 + 2 = 4 != Q(3) on that grid.
    w = 3.0
    naive = Q(w / 2) + Q(w / 2)
    assert naive == 4  # both halves rounded up -> error doubled
    qa = Q((w - 0.5) / 2) + Q((w + 0.5) / 2)
    assert qa == Q(w) == 3


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(-40000, 40000).map(lambda i: i / 4), min_size=1, max_size=64))
def test_vectorized_round_half_up_matches_scalar(vals):
    # quarter-integers are exact in f32, so f32 and f64 rounding agree
    arr = np.asarray(vals, np.float32)
    got = np.asarray(round_half_up(arr))
    want = np.asarray([math.floor(float(v) + 0.5) for v in arr], np.float32)
    np.testing.assert_array_equal(got, want)
