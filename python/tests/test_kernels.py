"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and values; targeted cases pin the quantization
semantics the whole stack depends on (round-half-up, sign-magnitude clip
range, qmax<=0 bypass).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import channel_dup, fake_quant, qmatmul
from compile.kernels.ref import (
    channel_dup_ref,
    fake_quant_ref,
    qmatmul_ref,
    round_half_up,
)

SETTINGS = dict(max_examples=25, deadline=None)


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# fake_quant
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    shape=st.lists(st.integers(1, 9), min_size=1, max_size=4),
    bits=st.integers(2, 8),
    seed=st.integers(0, 2**31 - 1),
    scale=st.floats(0.01, 10.0),
)
def test_fake_quant_matches_ref(shape, bits, seed, scale):
    x = (rng(seed).normal(size=shape) * scale).astype(np.float32)
    qmax = float(2 ** (bits - 1) - 1)
    delta = float(np.abs(x).max() / qmax + 1e-8)
    got = np.asarray(fake_quant(x, delta, qmax))
    want = np.asarray(fake_quant_ref(x, delta, qmax))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_fake_quant_bypass_identity():
    x = rng(1).normal(size=(33, 7)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(fake_quant(x, 0.123, -1.0)), x)
    np.testing.assert_array_equal(np.asarray(fake_quant(x, 0.123, 0.0)), x)


def test_fake_quant_grid_points_are_fixed():
    # values already on the grid must be unchanged
    delta = 0.25
    x = (np.arange(-7, 8) * delta).astype(np.float32)
    got = np.asarray(fake_quant(x, delta, 7.0))
    np.testing.assert_allclose(got, x, atol=1e-7)


def test_fake_quant_clips_outliers():
    got = np.asarray(fake_quant(np.float32([100.0, -100.0]), 1.0, 7.0))
    np.testing.assert_allclose(got, [7.0, -7.0])


def test_round_half_up_convention():
    # paper Q(x) = floor(x + 0.5): halves toward +inf, NOT banker's
    v = np.float32([0.5, 1.5, 2.5, -0.5, -1.5])
    np.testing.assert_array_equal(np.asarray(round_half_up(v)), [1, 2, 3, 0, -1])
    got = np.asarray(fake_quant(np.float32([0.5, 1.5, 2.5]), 1.0, 7.0))
    np.testing.assert_allclose(got, [1.0, 2.0, 3.0])


def test_fake_quant_large_unaligned_sizes():
    # crosses the BLOCK boundary (padding path)
    x = rng(2).normal(size=(8 * 128 * 8 + 37,)).astype(np.float32)
    got = np.asarray(fake_quant(x, 0.01, 127.0))
    want = np.asarray(fake_quant_ref(x, 0.01, 127.0))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# channel_dup
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    lead=st.lists(st.integers(1, 6), min_size=0, max_size=3),
    c=st.integers(1, 24),
    p=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_channel_dup_matches_ref(lead, c, p, seed):
    r = rng(seed)
    x = r.normal(size=tuple(lead) + (c,)).astype(np.float32)
    idx = r.integers(0, c, size=p).astype(np.int32)
    scale = r.normal(size=p).astype(np.float32)
    bias = r.normal(size=p).astype(np.float32)
    got = np.asarray(channel_dup(x, idx, scale, bias))
    want = np.asarray(channel_dup_ref(x, idx, scale, bias))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_channel_dup_weight_ocs_semantics():
    # weight OCS: duplicated slot carries scale 1 (halving lives in W)
    x = np.float32([[1.0, 2.0, 3.0]])
    idx = np.int32([0, 1, 2, 2])  # split channel 2
    scale = np.float32([1, 1, 1, 1])
    bias = np.zeros(4, np.float32)
    got = np.asarray(channel_dup(x, idx, scale, bias))
    np.testing.assert_allclose(got, [[1.0, 2.0, 3.0, 3.0]])


def test_channel_dup_activation_ocs_semantics():
    # activation OCS (Eq. 4): both halves scaled 0.5; QA bias ∓delta/4
    delta = 0.4
    x = np.float32([[1.0, 2.0, 6.0]])
    idx = np.int32([0, 1, 2, 2])
    scale = np.float32([1, 1, 0.5, 0.5])
    bias = np.float32([0, 0, -delta / 4, +delta / 4])
    got = np.asarray(channel_dup(x, idx, scale, bias))
    np.testing.assert_allclose(got, [[1.0, 2.0, 2.9, 3.1]])


def test_channel_dup_inert_padding_slot():
    x = np.float32([[5.0, -3.0]])
    idx = np.int32([0, 1, 0])
    scale = np.float32([1, 1, 0])  # padded slot: scale 0
    bias = np.zeros(3, np.float32)
    got = np.asarray(channel_dup(x, idx, scale, bias))
    np.testing.assert_allclose(got, [[5.0, -3.0, 0.0]])


def test_channel_dup_row_block_boundary():
    # rows not a multiple of ROW_BLOCK exercises the pad/slice path
    x = rng(3).normal(size=(257, 5)).astype(np.float32)
    idx = np.int32([4, 3, 2, 1, 0, 0])
    scale = np.ones(6, np.float32)
    bias = np.zeros(6, np.float32)
    got = np.asarray(channel_dup(x, idx, scale, bias))
    np.testing.assert_allclose(got, x[:, [4, 3, 2, 1, 0, 0]], atol=1e-7)


# ---------------------------------------------------------------------------
# qmatmul
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 48),
    n=st.integers(1, 40),
    bits=st.integers(2, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_qmatmul_matches_ref(m, k, n, bits, seed):
    r = rng(seed)
    x = r.normal(size=(m, k)).astype(np.float32)
    w = r.normal(size=(k, n)).astype(np.float32)
    qmax = float(2 ** (bits - 1) - 1)
    delta = float(np.abs(x).max() / qmax + 1e-8)
    got = np.asarray(qmatmul(x, w, delta, qmax))
    want = np.asarray(qmatmul_ref(x, w, delta, qmax))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_qmatmul_bypass_is_plain_matmul():
    r = rng(4)
    x = r.normal(size=(17, 9)).astype(np.float32)
    w = r.normal(size=(9, 13)).astype(np.float32)
    got = np.asarray(qmatmul(x, w, 1.0, -1.0))
    np.testing.assert_allclose(got, x @ w, rtol=1e-4, atol=1e-5)


def test_qmatmul_tile_boundary():
    # m, n > 128 exercises the multi-tile grid
    r = rng(5)
    x = r.normal(size=(130, 32)).astype(np.float32)
    w = r.normal(size=(32, 129)).astype(np.float32)
    got = np.asarray(qmatmul(x, w, 0.05, 7.0))
    want = np.asarray(qmatmul_ref(x, w, 0.05, 7.0))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
