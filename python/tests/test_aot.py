"""AOT path: signatures, HLO text emission, .ocst round-trip."""

import json
import os

import numpy as np
import pytest

from compile import aot, model as M
from compile.ocst import read_ocst, write_ocst


def test_ocst_roundtrip(tmp_path):
    r = np.random.default_rng(0)
    tensors = [
        ("a.W", r.normal(size=(3, 3, 4, 8)).astype(np.float32)),
        ("a.idx", r.integers(0, 4, size=(5,)).astype(np.int32)),
        ("scalar", np.float32(3.25).reshape(())),
    ]
    p = tmp_path / "t.ocst"
    write_ocst(p, tensors)
    back = read_ocst(p)
    assert [n for n, _ in back] == [n for n, _ in tensors]
    for (_, a), (_, b) in zip(tensors, back):
        np.testing.assert_array_equal(a, b)


def test_ocst_rejects_f64():
    with pytest.raises(ValueError):
        write_ocst("/tmp/bad.ocst", [("x", np.zeros(3, np.float64))])


def test_fwd_signature_covers_all_hooks():
    model = M.get_model("miniresnet")
    sig = aot.fwd_signature(model, 8)
    names = [n for n, _, _ in sig]
    assert names[0] == "x"
    for spec in model.specs:
        assert f"{spec.name}.W" in names
        if spec.quantized:
            for suffix in ["idx", "dscale", "dbias", "adelta", "aqmax"]:
                assert f"{spec.name}.{suffix}" in names
        else:
            assert f"{spec.name}.idx" not in names


def test_fwd_signature_padded_weight_shapes():
    model = M.get_model("minivgg")
    sig = {n: s for n, _, s in aot.fwd_signature(model, 4)}
    for spec in model.specs:
        if spec.quantized:
            assert sig[f"{spec.name}.W"] == spec.w_shape(padded=True)
            assert sig[f"{spec.name}.W"] != spec.w_shape(padded=False)


def test_train_signature_has_momentum_and_lr():
    model = M.get_model("minivgg")
    sig = [n for n, _, _ in aot.train_signature(model, 8)]
    assert "m.c1.W" in sig and sig[-1] == "lr" and "y" in sig


def test_lstm_train_signature_no_labels():
    model = M.get_model("lstmlm")
    sig = [n for n, _, _ in aot.train_signature(model, 4)]
    assert "tokens" in sig and "y" not in sig


@pytest.mark.slow
def test_quick_lowering_emits_parseable_hlo(tmp_path):
    aot.compile_model("minivgg", str(tmp_path), quick=True)
    mdir = tmp_path / "minivgg"
    meta = json.loads((mdir / "meta.json").read_text())
    assert meta["model"] == "minivgg"
    assert meta["pad_factor"] == M.PAD_FACTOR
    for key, art in meta["artifacts"].items():
        text = (mdir / art["file"]).read_text()
        assert "ENTRY" in text and "HloModule" in text
        # positional arity must match the recorded signature (count only
        # the ENTRY computation; nested computations also have parameters)
        entry = text[text.index("ENTRY") :]
        assert len(art["inputs"]) == entry.count("parameter(")
    leaves = read_ocst(mdir / "init.ocst")
    model = M.get_model("minivgg")
    want = [n for n, _, _ in aot.float_param_signature(model)]
    assert [n for n, _ in leaves] == want
