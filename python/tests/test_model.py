"""L2 correctness: model topology, OCS functional equivalence, training.

The central invariant (paper §3.2): a model with identity OCS hooks and
quantization bypassed is *functionally identical* to the float model —
channel padding, gather steering, and hook plumbing must be inert.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M


def identity_hooks(model):
    hooks = {}
    for spec in model.specs:
        if not spec.quantized:
            continue
        cp, c = spec.cin_pad, spec.cin
        idx = np.zeros(cp, np.int32)
        idx[:c] = np.arange(c)
        sc = np.zeros(cp, np.float32)
        sc[:c] = 1.0
        hooks[spec.name] = {
            "idx": jnp.asarray(idx),
            "dscale": jnp.asarray(sc),
            "dbias": jnp.zeros(cp, jnp.float32),
            "adelta": jnp.float32(1.0),
            "aqmax": jnp.float32(-1.0),
        }
    return hooks


def pad_params(model, params):
    out = {}
    for spec in model.specs:
        p = dict(params[spec.name])
        if spec.quantized:
            w = np.asarray(p["W"])
            ax = 2 if spec.kind == "conv" else 0
            padw = [(0, 0)] * w.ndim
            padw[ax] = (0, spec.cin_pad - spec.cin)
            p["W"] = jnp.asarray(np.pad(w, padw))
        out[spec.name] = p
    return out


def cnn_data(b=4, seed=0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.normal(size=(b, M.IMG_HW, M.IMG_HW, M.IMG_C)), jnp.float32)


def lm_data(b=4, seed=0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.integers(0, M.VOCAB, size=(b, M.SEQ_LEN + 1)), jnp.int32)


CNNS = ["minivgg", "miniresnet", "miniincept"]


@pytest.mark.parametrize("name", CNNS)
def test_cnn_output_shape(name):
    model = M.get_model(name)
    params = model.init_params(0)
    out = model.forward(params, cnn_data(3))
    assert out.shape == (3, M.NUM_CLASSES)


def test_lstm_output_is_nll_and_count():
    model = M.get_model("lstmlm")
    params = model.init_params(0)
    nll, ntok = model.forward(params, lm_data(2))
    assert nll.shape == () and ntok.shape == ()
    assert float(ntok) == 2 * M.SEQ_LEN
    assert float(nll) > 0


@pytest.mark.parametrize("name", CNNS)
def test_identity_hooks_equivalence_cnn(name):
    """Padded/hooked graph == float graph when hooks are identity."""
    model = M.get_model(name)
    params = model.init_params(2)
    data = cnn_data(4, seed=1)
    ref = np.asarray(model.forward(params, data))
    got = np.asarray(
        model.forward(pad_params(model, params), data, hooks=identity_hooks(model))
    )
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)


def test_identity_hooks_equivalence_lstm():
    model = M.get_model("lstmlm")
    params = model.init_params(2)
    data = lm_data(2, seed=1)
    ref, _ = model.forward(params, data)
    got, _ = model.forward(
        pad_params(model, params), data, hooks=identity_hooks(model)
    )
    np.testing.assert_allclose(float(got), float(ref), rtol=1e-4)


def test_probe_records_every_quantized_layer_input():
    model = M.get_model("miniresnet")
    params = model.init_params(0)
    probe = {}
    model.forward(params, cnn_data(2), probe=probe)
    qnames = [s.name for s in model.specs if s.quantized]
    assert sorted(probe.keys()) == sorted(qnames)
    for spec in model.specs:
        if spec.quantized:
            assert probe[spec.name].shape[-1] == spec.cin


@pytest.mark.parametrize("name", ["minivgg", "lstmlm"])
def test_train_step_reduces_loss(name):
    model = M.get_model(name)
    params = model.init_params(3)
    step = M.make_train_step(model)
    leaves = [a for _, a in M.flatten_params(model, params)]
    moms = [jnp.zeros_like(a) for a in leaves]
    if name == "lstmlm":
        batch = lm_data(4, seed=2)
    else:
        x = cnn_data(8, seed=2)
        y = jnp.asarray(np.arange(8) % M.NUM_CLASSES, jnp.int32)
        batch = (x, y)
    losses = []
    for _ in range(12):
        leaves, moms, loss = step(leaves, moms, batch, jnp.float32(0.02))
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"no learning: {losses[0]} -> {losses[-1]}"


def test_flatten_unflatten_roundtrip():
    model = M.get_model("miniincept")
    params = model.init_params(5)
    flat = M.flatten_params(model, params)
    back = M.unflatten_params(model, [a for _, a in flat])
    for spec in model.specs:
        np.testing.assert_array_equal(
            np.asarray(back[spec.name]["W"]), np.asarray(params[spec.name]["W"])
        )


def test_pad_channels_matches_expand_budget():
    # the padded capacity must fit the largest paper ratio r = 0.2
    for c in [3, 8, 16, 33, 64, 384, 650]:
        assert M.pad_channels(c) >= c + int(np.ceil(0.2 * c))


def test_first_layers_not_quantized():
    # paper §5: first conv stays unquantized
    for name in CNNS:
        model = M.get_model(name)
        assert not model.specs[0].quantized
