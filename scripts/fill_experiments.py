#!/usr/bin/env python
"""Inject the regenerated results/*.txt tables into EXPERIMENTS.md.

Each `<!-- TAG -->` placeholder is replaced by the corresponding
results file wrapped in a fenced code block. Idempotent: re-running
replaces previous injections (delimited by the tag comments).
"""

import re
import sys

MAP = {
    "FIG1": "results/fig1.txt",
    "TABLE1": "results/table1.txt",
    "TABLE2": "results/table2.txt",
    "TABLE3": "results/table3.txt",
    "TABLE4": "results/table4.txt",
    "TABLE5": "results/table5.txt",
    "TABLE6": "results/table6.txt",
    "PERF": "results/perf.txt",
}


def main():
    path = "EXPERIMENTS.md"
    text = open(path).read()
    for tag, src in MAP.items():
        try:
            body = open(src).read().rstrip()
        except FileNotFoundError:
            print(f"  [skip] {src} missing")
            continue
        block = f"<!-- {tag} -->\n```\n{body}\n```\n<!-- /{tag} -->"
        # replace an existing injected block or the bare placeholder
        pat = re.compile(
            rf"<!-- {tag} -->.*?<!-- /{tag} -->|<!-- {tag} -->", re.DOTALL
        )
        if not pat.search(text):
            print(f"  [warn] no placeholder for {tag}")
            continue
        text = pat.sub(lambda _: block, text, count=1)
        print(f"  [ok] {tag} <- {src}")
    open(path, "w").write(text)


if __name__ == "__main__":
    sys.exit(main())
