//! Criterion-lite benchmark harness (criterion is unavailable offline).
//!
//! Auto-calibrates iteration counts to a target measurement time, reports
//! mean / p50 / p95 / min / throughput, and honours the substring filter
//! cargo-bench passes through (`cargo bench -- <filter>`).

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchStats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns / 1e9)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Runner with cargo-bench-style substring filtering.
pub struct Runner {
    filter: Option<String>,
    pub target_time: Duration,
    pub warmup: Duration,
    results: Vec<BenchStats>,
}

impl Default for Runner {
    fn default() -> Self {
        Self::from_env()
    }
}

impl Runner {
    pub fn from_env() -> Self {
        // argv: bench binary receives [exe, <filter>?, --bench]
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with("--") && a != "bench");
        Self::with_filter(filter)
    }

    /// For harnesses that parse their own argv (e.g. `benches/hotpath.rs`
    /// takes `--json`/`--shapes` whose *values* would confuse the plain
    /// positional-filter scan above). Honours `OCS_BENCH_QUICK`.
    pub fn with_filter(filter: Option<String>) -> Self {
        let quick = std::env::var("OCS_BENCH_QUICK").is_ok();
        Runner {
            filter,
            target_time: if quick {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(1)
            },
            warmup: if quick {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(300)
            },
            results: Vec::new(),
        }
    }

    pub fn enabled(&self, name: &str) -> bool {
        match &self.filter {
            Some(f) => name.contains(f.as_str()),
            None => true,
        }
    }

    /// Time `f`, auto-calibrating the iteration count.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Option<BenchStats> {
        if !self.enabled(name) {
            return None;
        }
        // warmup + calibration
        let cal_start = Instant::now();
        let mut one = || {
            let t = Instant::now();
            f();
            t.elapsed()
        };
        let mut probe = one();
        while cal_start.elapsed() < self.warmup {
            probe = one();
        }
        let per_iter = probe.as_nanos().max(1) as f64;
        let iters = ((self.target_time.as_nanos() as f64 / per_iter) as usize).clamp(5, 10_000);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            samples.push(one().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let stats = BenchStats {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            p50_ns: samples[samples.len() / 2],
            p95_ns: samples[(samples.len() as f64 * 0.95) as usize % samples.len()],
            min_ns: samples[0],
            max_ns: *samples.last().unwrap(),
        };
        println!(
            "{:<44} {:>12} (p50 {:>12}, p95 {:>12}, min {:>12}, {} iters)",
            stats.name,
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.p50_ns),
            fmt_ns(stats.p95_ns),
            fmt_ns(stats.min_ns),
            stats.iters
        );
        self.results.push(stats.clone());
        Some(stats)
    }

    /// For benches that measure something other than wall-time per call
    /// (e.g. a whole table evaluation): run once, report the value.
    pub fn report_value(&mut self, name: &str, value: f64, unit: &str) {
        if !self.enabled(name) {
            return;
        }
        println!("{name:<44} {value:>12.4} {unit}");
    }

    pub fn section(&self, title: &str) {
        if self.filter.is_none() {
            println!("\n== {title} ==");
        }
    }

    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }
}

/// One row of `BENCH_quant.json` — the quant-side counterpart of a
/// `BENCH_serving.json` sweep point (same record style: a top-level
/// `bench` tag plus an array of flat measurement objects, so the same
/// tooling can track both trajectories run-over-run).
#[derive(Debug, Clone)]
pub struct CaseRecord {
    /// `group/variant`, e.g. `perchan_quant/fused_t4`.
    pub name: String,
    /// Tensor shape measured, e.g. `256x1024`.
    pub shape: String,
    /// Threads the variant ran with (1 = serial).
    pub threads: usize,
    pub mean_ns: f64,
    /// Millions of f32 elements processed per second.
    pub melems_per_s: f64,
    /// mean_ns(serial baseline of the group) / mean_ns(this variant);
    /// 1.0 for the baseline row itself.
    pub speedup_vs_serial: f64,
}

/// Serialize hot-path cases in the repo's BENCH json shape under an
/// arbitrary `bench` tag (`"quant"` → `BENCH_quant.json`, `"native"` →
/// `BENCH_native.json`, ...).
pub fn cases_json(
    bench: &str,
    backend: &str,
    threads_available: usize,
    cases: &[CaseRecord],
) -> String {
    use crate::util::json;
    json::obj(vec![
        ("bench", json::s(bench)),
        ("backend", json::s(backend)),
        ("threads_available", json::num(threads_available as f64)),
        (
            "cases",
            json::arr(
                cases
                    .iter()
                    .map(|c| {
                        json::obj(vec![
                            ("name", json::s(&c.name)),
                            ("shape", json::s(&c.shape)),
                            ("threads", json::num(c.threads as f64)),
                            ("mean_ns", json::num(c.mean_ns)),
                            ("melems_per_s", json::num(c.melems_per_s)),
                            ("speedup_vs_serial", json::num(c.speedup_vs_serial)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
    .to_string()
}

/// [`cases_json`] under the `"quant"` tag (`BENCH_quant.json`).
pub fn quant_json(backend: &str, threads_available: usize, cases: &[CaseRecord]) -> String {
    cases_json("quant", backend, threads_available, cases)
}

/// [`cases_json`] under the `"native"` tag (`BENCH_native.json`,
/// emitted by `benches/gemm.rs`).
pub fn native_json(backend: &str, threads_available: usize, cases: &[CaseRecord]) -> String {
    cases_json("native", backend, threads_available, cases)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_stats() {
        let mut r = Runner {
            filter: None,
            target_time: Duration::from_millis(20),
            warmup: Duration::from_millis(5),
            results: Vec::new(),
        };
        let mut acc = 0u64;
        let stats = r
            .bench("spin", || {
                for i in 0..1000 {
                    acc = acc.wrapping_add(i);
                }
            })
            .unwrap();
        assert!(stats.min_ns <= stats.p50_ns);
        assert!(stats.p50_ns <= stats.max_ns);
        assert!(stats.iters >= 5);
        assert!(acc > 0 || acc == 0); // keep the accumulator alive
    }

    #[test]
    fn filter_skips() {
        let mut r = Runner {
            filter: Some("xyz".into()),
            target_time: Duration::from_millis(5),
            warmup: Duration::from_millis(1),
            results: Vec::new(),
        };
        assert!(r.bench("other", || {}).is_none());
        assert!(r.bench("has_xyz_inside", || {}).is_some());
    }

    #[test]
    fn quant_json_roundtrips() {
        let cases = vec![
            CaseRecord {
                name: "perchan_quant/old_serial".into(),
                shape: "256x1024".into(),
                threads: 1,
                mean_ns: 2.0e6,
                melems_per_s: 131.0,
                speedup_vs_serial: 1.0,
            },
            CaseRecord {
                name: "perchan_quant/fused_t4".into(),
                shape: "256x1024".into(),
                threads: 4,
                mean_ns: 0.5e6,
                melems_per_s: 524.0,
                speedup_vs_serial: 4.0,
            },
        ];
        let text = quant_json("cpu", 4, &cases);
        let v = crate::util::json::Value::parse(&text).unwrap();
        assert_eq!(v.get("bench").unwrap().as_str().unwrap(), "quant");
        assert_eq!(v.get("threads_available").unwrap().as_usize().unwrap(), 4);
        let arr = v.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(
            arr[1].get("name").unwrap().as_str().unwrap(),
            "perchan_quant/fused_t4"
        );
        assert_eq!(arr[1].get("threads").unwrap().as_usize().unwrap(), 4);
        assert!(arr[1].get("speedup_vs_serial").unwrap().as_f64().unwrap() > 3.9);
    }

    #[test]
    fn cases_json_tags() {
        let text = native_json("cpu", 2, &[]);
        let v = crate::util::json::Value::parse(&text).unwrap();
        assert_eq!(v.get("bench").unwrap().as_str().unwrap(), "native");
        assert!(v.get("cases").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
