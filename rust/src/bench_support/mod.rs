//! Criterion-lite benchmark harness (criterion is unavailable offline).
//!
//! Auto-calibrates iteration counts to a target measurement time, reports
//! mean / p50 / p95 / min / throughput, and honours the substring filter
//! cargo-bench passes through (`cargo bench -- <filter>`).

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    /// Median absolute deviation from the p50 — a robust per-case
    /// noise width that one outlier sample cannot inflate (unlike
    /// stddev). Records carry it so `bench diff` can widen its noise
    /// threshold per case instead of applying one global number.
    pub mad_ns: f64,
}

impl BenchStats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns / 1e9)
    }
}

/// Human time formatting, shared with `bench_record`'s diff tables.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Sample floor: at 20 samples the ceil-rank p95 is the 19th sorted
/// sample, not the max — below ~20 a "p95" is just the worst
/// observation dressed up, which poisoned small-iter records.
const MIN_ITERS: usize = 20;

/// Ceil-rank (nearest-rank) percentile over ascending-sorted samples:
/// the smallest sample with at least fraction `p` of the mass at or
/// below it. The previous index `(len * p) as usize % len` silently
/// returned the max sample at the minimum iteration count and at any
/// length where `len * p` was exact — the modulo only masked an
/// off-by-one, it never implemented a percentile.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    debug_assert!(!sorted.is_empty());
    let rank = (p.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

/// Runner with cargo-bench-style substring filtering.
pub struct Runner {
    filter: Option<String>,
    pub target_time: Duration,
    pub warmup: Duration,
    results: Vec<BenchStats>,
}

impl Default for Runner {
    fn default() -> Self {
        Self::from_env()
    }
}

impl Runner {
    pub fn from_env() -> Self {
        // argv: bench binary receives [exe, <filter>?, --bench]
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with("--") && a != "bench");
        Self::with_filter(filter)
    }

    /// For harnesses that parse their own argv (e.g. `benches/hotpath.rs`
    /// takes `--json`/`--shapes` whose *values* would confuse the plain
    /// positional-filter scan above). Honours `OCS_BENCH_QUICK`.
    pub fn with_filter(filter: Option<String>) -> Self {
        let quick = std::env::var("OCS_BENCH_QUICK").is_ok();
        Runner {
            filter,
            target_time: if quick {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(1)
            },
            warmup: if quick {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(300)
            },
            results: Vec::new(),
        }
    }

    pub fn enabled(&self, name: &str) -> bool {
        match &self.filter {
            Some(f) => name.contains(f.as_str()),
            None => true,
        }
    }

    /// Time `f`, auto-calibrating the iteration count.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> Option<BenchStats> {
        if !self.enabled(name) {
            return None;
        }
        // warmup + calibration: every probe during the warmup window is
        // kept, and the iteration count derives from their *median* —
        // calibrating off the last probe alone let one slow outlier
        // (page fault, scheduler hiccup) collapse the sample count for
        // the whole measurement
        let cal_start = Instant::now();
        let mut one = || {
            let t = Instant::now();
            f();
            t.elapsed()
        };
        let mut probes_ns = vec![one().as_nanos().max(1) as f64];
        while cal_start.elapsed() < self.warmup {
            probes_ns.push(one().as_nanos().max(1) as f64);
        }
        probes_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let per_iter = probes_ns[probes_ns.len() / 2];
        let iters =
            ((self.target_time.as_nanos() as f64 / per_iter) as usize).clamp(MIN_ITERS, 10_000);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            samples.push(one().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let p50 = percentile(&samples, 0.50);
        let mut dev: Vec<f64> = samples.iter().map(|x| (x - p50).abs()).collect();
        dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = BenchStats {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            p50_ns: p50,
            p95_ns: percentile(&samples, 0.95),
            min_ns: samples[0],
            max_ns: *samples.last().unwrap(),
            mad_ns: percentile(&dev, 0.50),
        };
        println!(
            "{:<44} {:>12} (p50 {:>12}, p95 {:>12}, min {:>12}, {} iters)",
            stats.name,
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.p50_ns),
            fmt_ns(stats.p95_ns),
            fmt_ns(stats.min_ns),
            stats.iters
        );
        self.results.push(stats.clone());
        Some(stats)
    }

    /// For benches that measure something other than wall-time per call
    /// (e.g. a whole table evaluation): run once, report the value.
    pub fn report_value(&mut self, name: &str, value: f64, unit: &str) {
        if !self.enabled(name) {
            return;
        }
        println!("{name:<44} {value:>12.4} {unit}");
    }

    pub fn section(&self, title: &str) {
        if self.filter.is_none() {
            println!("\n== {title} ==");
        }
    }

    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }
}

/// One measured case of a kernel harness (`BENCH_quant` /
/// `BENCH_native`). Harnesses collect these and serialize through
/// [`crate::bench_record::BenchRecord::from_cases`] — the versioned
/// record format `ocs bench diff`/`check` read back.
#[derive(Debug, Clone)]
pub struct CaseRecord {
    /// `group/variant`, e.g. `perchan_quant/fused_t4`.
    pub name: String,
    /// Tensor shape measured, e.g. `256x1024`.
    pub shape: String,
    /// Threads the variant ran with (1 = serial).
    pub threads: usize,
    pub mean_ns: f64,
    /// Millions of f32 elements processed per second.
    pub melems_per_s: f64,
    /// mean_ns(serial baseline of the group) / mean_ns(this variant);
    /// 1.0 for the baseline row itself.
    pub speedup_vs_serial: f64,
    /// Dispersion secondaries (see [`BenchStats::mad_ns`]): the
    /// record row carries these so `bench diff` can derive a per-case
    /// noise threshold from the baseline's own measured spread.
    pub mad_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl CaseRecord {
    /// Fill name/shape/threads/speedup around measured stats.
    pub fn from_stats(
        name: &str,
        shape: &str,
        threads: usize,
        melems_per_s: f64,
        speedup_vs_serial: f64,
        stats: &BenchStats,
    ) -> CaseRecord {
        CaseRecord {
            name: name.to_string(),
            shape: shape.to_string(),
            threads,
            mean_ns: stats.mean_ns,
            melems_per_s,
            speedup_vs_serial,
            mad_ns: stats.mad_ns,
            min_ns: stats.min_ns,
            max_ns: stats.max_ns,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_stats() {
        let mut r = Runner {
            filter: None,
            target_time: Duration::from_millis(20),
            warmup: Duration::from_millis(5),
            results: Vec::new(),
        };
        let mut acc = 0u64;
        let stats = r
            .bench("spin", || {
                for i in 0..1000 {
                    acc = acc.wrapping_add(i);
                }
            })
            .unwrap();
        assert!(stats.min_ns <= stats.p50_ns);
        assert!(stats.p50_ns <= stats.p95_ns);
        assert!(stats.p95_ns <= stats.max_ns);
        assert!(stats.mad_ns >= 0.0);
        assert!(stats.mad_ns <= stats.max_ns - stats.min_ns);
        assert!(stats.iters >= MIN_ITERS);
        assert!(acc > 0 || acc == 0); // keep the accumulator alive
    }

    #[test]
    fn percentile_is_ceil_rank_not_max() {
        // regression: at the old minimum iteration count (5) the p95
        // index was `(5*0.95) as usize % 5 == 4` — always the max; and
        // at any length where len*p was exact (e.g. 20*0.95) the
        // truncation overshot by one rank
        let v: Vec<f64> = (1..=20).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.95), 19.0); // ceil(19.0)=19 → idx 18, not the max
        assert_eq!(percentile(&v, 0.50), 10.0);
        assert_eq!(percentile(&v, 1.0), 20.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
        let w = vec![1.0, 2.0, 3.0, 4.0, 100.0];
        // 5 samples genuinely cannot resolve a p95 below the max — the
        // honest ceil-rank answer; MIN_ITERS keeps real runs past this
        assert_eq!(percentile(&w, 0.95), 100.0);
        assert_eq!(percentile(&w, 0.50), 3.0);
        assert_eq!(percentile(&w, 0.75), 4.0);
    }

    #[test]
    fn small_iter_p95_below_max_at_floor() {
        // at the MIN_ITERS floor the p95 must be able to sit below the
        // max sample (the old code structurally never could)
        let mut v: Vec<f64> = vec![1.0; MIN_ITERS - 1];
        v.push(1000.0);
        assert_eq!(percentile(&v, 0.95), 1.0);
    }

    #[test]
    fn filter_skips() {
        let mut r = Runner {
            filter: Some("xyz".into()),
            target_time: Duration::from_millis(5),
            warmup: Duration::from_millis(1),
            results: Vec::new(),
        };
        assert!(r.bench("other", || {}).is_none());
        assert!(r.bench("has_xyz_inside", || {}).is_some());
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
