//! [`QuantRecipe`] — the paper's §5 recipe as a first-class, per-layer
//! policy object.
//!
//! A recipe is model-wide defaults (the old flat [`QuantConfig`] fields)
//! plus an ordered list of [`LayerOverride`]s. Each override pairs a
//! [`LayerMatch`] — layer-name glob, [`LayerKind`], and/or position
//! (first/last quantized layer) — with a partial [`LayerPolicy`].
//! Resolution folds every matching override onto the defaults in
//! declaration order (later overrides win on the fields they set),
//! yielding one fully-specified [`LayerRecipe`] per layer. That enables
//! mixed precision (8-bit first/last, 4-bit middle), per-layer OCS
//! ratios, and skip-first/last-layer policies — the per-layer knobs the
//! paper's first/last-layer observation and follow-ups like SplitQuant
//! make standard — without giving up the one-line uniform configs.
//!
//! Every recipe has a stable [`QuantRecipe::fingerprint`] derived from a
//! canonical text form; the process-wide [`super::PreparedCache`] keys
//! prepared models on it, and the serve router hot-swaps recipes by it.
//! Clip slots hold a [`ClipSpec`], so custom [`crate::clip::ClipStrategy`]
//! implementations participate in recipes (identified by their `name()`).
//!
//! Text forms:
//! * TOML — `[quant]` defaults plus `[[quant.layer]]` tables:
//!   `match = "fc*"`, `kind = "conv"`, `pos = "first"|"last"|"edge"`,
//!   and any of `skip`, `quantize`, `w_bits`, `a_bits` (0 = float),
//!   `w_clip`, `a_clip`, `ocs_ratio`, `ocs_target`, `split_mode`.
//! * CLI — `--layer "fc*:w_bits=4,ocs_ratio=0.1;%edge:w_bits=8"`:
//!   `;`-separated overrides, each `match:key=value,...` where match is
//!   a name glob or `%first`/`%last`/`%edge`/`%conv`/`%fc`/`%embed`
//!   (combinable with `+`).

use anyhow::{bail, Context, Result};

use crate::clip::{ClipMethod, ClipSpec};
use crate::model::{LayerKind, LayerSpec, ModelSpec};
use crate::ocs::{OcsTarget, SplitMode};
use crate::util::toml::Config;

use super::config::QuantConfig;

/// `*` / `?` glob match (no character classes — layer names are plain
/// identifiers). Iterative with single-star backtracking.
pub fn glob_match(pat: &str, text: &str) -> bool {
    let p: Vec<char> = pat.chars().collect();
    let t: Vec<char> = text.chars().collect();
    let (mut pi, mut ti) = (0usize, 0usize);
    let mut star: Option<(usize, usize)> = None; // (pat pos after '*', text mark)
    while ti < t.len() {
        if pi < p.len() && (p[pi] == '?' || p[pi] == t[ti]) {
            pi += 1;
            ti += 1;
        } else if pi < p.len() && p[pi] == '*' {
            star = Some((pi + 1, ti));
            pi += 1;
        } else if let Some((sp, mark)) = star {
            pi = sp;
            ti = mark + 1;
            star = Some((sp, mark + 1));
        } else {
            return false;
        }
    }
    while pi < p.len() && p[pi] == '*' {
        pi += 1;
    }
    pi == p.len()
}

/// Positional matcher relative to the model's *quantized* layer list.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerPos {
    First,
    Last,
    /// First or last (the "treat boundary layers differently" policy).
    Edge,
}

impl LayerPos {
    pub fn parse(s: &str) -> Option<LayerPos> {
        match s {
            "first" => Some(LayerPos::First),
            "last" => Some(LayerPos::Last),
            "edge" => Some(LayerPos::Edge),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LayerPos::First => "first",
            LayerPos::Last => "last",
            LayerPos::Edge => "edge",
        }
    }
}

fn parse_kind(s: &str) -> Option<LayerKind> {
    match s {
        "conv" => Some(LayerKind::Conv),
        "fc" => Some(LayerKind::Fc),
        "embed" => Some(LayerKind::Embed),
        _ => None,
    }
}

fn kind_name(k: LayerKind) -> &'static str {
    match k {
        LayerKind::Conv => "conv",
        LayerKind::Fc => "fc",
        LayerKind::Embed => "embed",
    }
}

/// Which layers an override applies to. All set conditions must hold;
/// an empty match (the default) matches every layer.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LayerMatch {
    pub name_glob: Option<String>,
    pub kind: Option<LayerKind>,
    pub pos: Option<LayerPos>,
}

impl LayerMatch {
    pub fn name(glob: impl Into<String>) -> LayerMatch {
        LayerMatch {
            name_glob: Some(glob.into()),
            ..LayerMatch::default()
        }
    }

    pub fn kind(kind: LayerKind) -> LayerMatch {
        LayerMatch {
            kind: Some(kind),
            ..LayerMatch::default()
        }
    }

    pub fn pos(pos: LayerPos) -> LayerMatch {
        LayerMatch {
            pos: Some(pos),
            ..LayerMatch::default()
        }
    }

    /// `is_first` / `is_last` are relative to the model's quantized
    /// layers (a model with one quantized layer is both).
    pub fn matches(&self, layer: &LayerSpec, is_first: bool, is_last: bool) -> bool {
        if let Some(glob) = &self.name_glob {
            if !glob_match(glob, &layer.name) {
                return false;
            }
        }
        if let Some(kind) = self.kind {
            if layer.kind != kind {
                return false;
            }
        }
        match self.pos {
            Some(LayerPos::First) if !is_first => false,
            Some(LayerPos::Last) if !is_last => false,
            Some(LayerPos::Edge) if !(is_first || is_last) => false,
            _ => true,
        }
    }

    /// CLI token: a name glob and/or `%first|%last|%edge|%conv|%fc|%embed`
    /// markers, combined with `+` (e.g. `fc*+%last`).
    pub fn parse(token: &str) -> Result<LayerMatch> {
        let token = token.trim();
        if token.is_empty() {
            bail!("empty layer match");
        }
        let mut m = LayerMatch::default();
        for part in token.split('+') {
            let part = part.trim();
            if let Some(marker) = part.strip_prefix('%') {
                if let Some(pos) = LayerPos::parse(marker) {
                    m.pos = Some(pos);
                } else if let Some(kind) = parse_kind(marker) {
                    m.kind = Some(kind);
                } else {
                    bail!("unknown layer matcher '%{marker}' (first|last|edge|conv|fc|embed)");
                }
            } else if part.is_empty() {
                bail!("empty component in layer match '{token}'");
            } else if let Some(prev) = &m.name_glob {
                // only one glob per match — a second is almost always a
                // typo ('+' for ';'), so refuse rather than silently
                // keeping the last one
                bail!("layer match '{token}' has two name globs ('{prev}' and '{part}'); use ';' to write separate overrides");
            } else {
                m.name_glob = Some(part.to_string());
            }
        }
        Ok(m)
    }

    fn canonical(&self) -> String {
        let mut parts = Vec::new();
        if let Some(g) = &self.name_glob {
            parts.push(format!("name={g}"));
        }
        if let Some(k) = self.kind {
            parts.push(format!("kind={}", kind_name(k)));
        }
        if let Some(p) = self.pos {
            parts.push(format!("pos={}", p.name()));
        }
        if parts.is_empty() {
            "*".into()
        } else {
            parts.join("&")
        }
    }
}

/// A partial policy: only the set fields override the recipe defaults.
/// Bit fields use `0` for "force float" (matching the TOML convention
/// where `w_bits = 0` means unquantized).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct LayerPolicy {
    /// `Some(false)` = keep the layer float entirely (skip).
    pub quantize: Option<bool>,
    pub w_bits: Option<u32>,
    pub a_bits: Option<u32>,
    pub w_clip: Option<ClipSpec>,
    pub a_clip: Option<ClipSpec>,
    pub ocs_ratio: Option<f64>,
    pub ocs_target: Option<OcsTarget>,
    pub split_mode: Option<SplitMode>,
}

impl LayerPolicy {
    pub fn skip() -> LayerPolicy {
        LayerPolicy {
            quantize: Some(false),
            ..LayerPolicy::default()
        }
    }

    pub fn w_bits(bits: u32) -> LayerPolicy {
        LayerPolicy {
            w_bits: Some(bits),
            ..LayerPolicy::default()
        }
    }

    pub fn with_w_bits(mut self, bits: u32) -> LayerPolicy {
        self.w_bits = Some(bits);
        self
    }

    pub fn with_a_bits(mut self, bits: u32) -> LayerPolicy {
        self.a_bits = Some(bits);
        self
    }

    pub fn with_w_clip(mut self, clip: impl Into<ClipSpec>) -> LayerPolicy {
        self.w_clip = Some(clip.into());
        self
    }

    pub fn with_a_clip(mut self, clip: impl Into<ClipSpec>) -> LayerPolicy {
        self.a_clip = Some(clip.into());
        self
    }

    pub fn with_ocs_ratio(mut self, ratio: f64) -> LayerPolicy {
        self.ocs_ratio = Some(ratio);
        self
    }

    pub fn with_ocs_target(mut self, target: OcsTarget) -> LayerPolicy {
        self.ocs_target = Some(target);
        self
    }

    pub fn with_split_mode(mut self, mode: SplitMode) -> LayerPolicy {
        self.split_mode = Some(mode);
        self
    }

    pub fn is_empty(&self) -> bool {
        *self == LayerPolicy::default()
    }

    /// Set one field from its text form (shared by the CLI and TOML
    /// parsers). `skip` accepts a bare key (value "true").
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "skip" => {
                let skip = parse_bool(value).context("bad 'skip' value")?;
                self.quantize = Some(!skip);
            }
            "quantize" => {
                self.quantize = Some(parse_bool(value).context("bad 'quantize' value")?);
            }
            "w_bits" => self.w_bits = Some(parse_bits(value).context("bad 'w_bits'")?),
            "a_bits" => self.a_bits = Some(parse_bits(value).context("bad 'a_bits'")?),
            "w_clip" => {
                self.w_clip =
                    Some(ClipSpec::parse(value).with_context(|| format!("bad w_clip '{value}'"))?)
            }
            "a_clip" => {
                self.a_clip =
                    Some(ClipSpec::parse(value).with_context(|| format!("bad a_clip '{value}'"))?)
            }
            "ocs_ratio" => {
                let r: f64 = value.parse().with_context(|| format!("bad ocs_ratio '{value}'"))?;
                if !(0.0..=1.0).contains(&r) {
                    bail!("ocs_ratio {r} outside [0, 1]");
                }
                self.ocs_ratio = Some(r);
            }
            "ocs_target" => {
                self.ocs_target = Some(match value {
                    "weights" => OcsTarget::Weights,
                    "activations" => OcsTarget::Activations,
                    other => bail!("bad ocs_target '{other}'"),
                })
            }
            "split_mode" | "split" => {
                self.split_mode = Some(
                    SplitMode::parse(value).with_context(|| format!("bad split_mode '{value}'"))?,
                )
            }
            other => bail!("unknown layer-policy key '{other}'"),
        }
        Ok(())
    }

    fn canonical(&self) -> String {
        let mut parts = Vec::new();
        if let Some(q) = self.quantize {
            parts.push(format!("quantize={q}"));
        }
        if let Some(b) = self.w_bits {
            parts.push(format!("w_bits={b}"));
        }
        if let Some(b) = self.a_bits {
            parts.push(format!("a_bits={b}"));
        }
        if let Some(c) = &self.w_clip {
            parts.push(format!("w_clip={}", c.name()));
        }
        if let Some(c) = &self.a_clip {
            parts.push(format!("a_clip={}", c.name()));
        }
        if let Some(r) = self.ocs_ratio {
            parts.push(format!("ocs_ratio={r}"));
        }
        if let Some(t) = self.ocs_target {
            parts.push(format!("ocs_target={}", target_name(t)));
        }
        if let Some(m) = self.split_mode {
            parts.push(format!("split_mode={}", m.name()));
        }
        parts.join(",")
    }
}

fn parse_bool(s: &str) -> Result<bool> {
    match s {
        "true" | "1" | "yes" => Ok(true),
        "false" | "0" | "no" => Ok(false),
        other => bail!("expected a bool, got '{other}'"),
    }
}

/// Bit fields: `0` = float (no quantization on that side); otherwise
/// the grid range [`crate::quant::QuantSpec`] supports (2..=16).
fn parse_bits(s: &str) -> Result<u32> {
    let b: u32 = s.parse().with_context(|| format!("expected bits, got '{s}'"))?;
    if b != 0 && !(2..=16).contains(&b) {
        bail!("bits {b} outside 0 (float) or 2..=16");
    }
    Ok(b)
}

fn target_name(t: OcsTarget) -> &'static str {
    match t {
        OcsTarget::Weights => "weights",
        OcsTarget::Activations => "activations",
    }
}

/// Quote + escape a string for the TOML-subset emitter (the inverse of
/// `util::toml::parse_value`'s unescaping, same two escapes).
fn toml_str(v: &str) -> String {
    format!("\"{}\"", v.replace('\\', "\\\\").replace('"', "\\\""))
}

fn bits_opt(b: u32) -> Option<u32> {
    if b == 0 {
        None
    } else {
        Some(b)
    }
}

/// One matcher + partial policy pair.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerOverride {
    pub matches: LayerMatch,
    pub policy: LayerPolicy,
}

impl LayerOverride {
    /// CLI form: `match:key=value,key=value` (bare `skip` allowed).
    /// Clip values may themselves contain `:` (`w_clip=percentile:0.99`)
    /// — only the first `:` separates the matcher.
    pub fn parse(spec: &str) -> Result<LayerOverride> {
        let (match_part, policy_part) = spec
            .split_once(':')
            .with_context(|| format!("layer override '{spec}': expected 'match:key=value,...'"))?;
        let matches = LayerMatch::parse(match_part)?;
        let mut policy = LayerPolicy::default();
        for kv in policy_part.split(',') {
            let kv = kv.trim();
            if kv.is_empty() {
                continue;
            }
            match kv.split_once('=') {
                Some((k, v)) => policy.set(k.trim(), v.trim())?,
                None => policy.set(kv, "true")?,
            }
        }
        if policy.is_empty() {
            bail!("layer override '{spec}' sets no policy fields");
        }
        Ok(LayerOverride { matches, policy })
    }

    fn canonical(&self) -> String {
        format!("{}=>{}", self.matches.canonical(), self.policy.canonical())
    }
}

/// The fully-resolved quantization policy for one layer (what the
/// pipeline passes actually consume).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerRecipe {
    /// `false` = serve this layer float even though the artifact marks
    /// it quantizable (skip-layer policy).
    pub quantize: bool,
    pub w_bits: Option<u32>,
    pub a_bits: Option<u32>,
    pub w_clip: ClipSpec,
    pub a_clip: ClipSpec,
    pub ocs_ratio: f64,
    pub ocs_target: OcsTarget,
    pub split_mode: SplitMode,
}

impl LayerRecipe {
    /// The resolved policy for a layer the recipe keeps float: identity
    /// hooks, quantization fully bypassed on both sides.
    pub fn skip() -> LayerRecipe {
        LayerRecipe {
            quantize: false,
            w_bits: None,
            a_bits: None,
            w_clip: ClipMethod::None.into(),
            a_clip: ClipMethod::None.into(),
            ocs_ratio: 0.0,
            ocs_target: OcsTarget::Weights,
            split_mode: SplitMode::QuantAware,
        }
    }

    pub fn needs_calibration(&self) -> bool {
        self.quantize && self.a_bits.is_some()
    }

    /// Compact per-layer tag (mirrors [`QuantConfig::label`]).
    pub fn label(&self) -> String {
        if !self.quantize {
            return "float(skip)".into();
        }
        let w = self
            .w_bits
            .map(|b| format!("w{b}:{}", self.w_clip.name()))
            .unwrap_or_else(|| "wf".into());
        let a = self
            .a_bits
            .map(|b| format!("a{b}:{}", self.a_clip.name()))
            .unwrap_or_else(|| "af".into());
        let ocs = if self.ocs_ratio > 0.0 {
            format!(
                " ocs[{} r={} {}]",
                target_name(self.ocs_target),
                self.ocs_ratio,
                self.split_mode.name()
            )
        } else {
            String::new()
        };
        format!("{w} {a}{ocs}")
    }
}

/// Model-wide defaults + ordered per-layer overrides. See the module
/// docs for the text forms; see [`QuantRecipe::resolve`] for semantics.
#[derive(Debug, Clone)]
pub struct QuantRecipe {
    pub w_bits: Option<u32>,
    pub a_bits: Option<u32>,
    pub w_clip: ClipSpec,
    pub a_clip: ClipSpec,
    pub ocs_ratio: f64,
    pub ocs_target: OcsTarget,
    pub split_mode: SplitMode,
    pub overrides: Vec<LayerOverride>,
}

impl Default for QuantRecipe {
    fn default() -> Self {
        QuantRecipe::float()
    }
}

impl From<QuantConfig> for QuantRecipe {
    fn from(cfg: QuantConfig) -> QuantRecipe {
        QuantRecipe::uniform(&cfg)
    }
}

impl QuantRecipe {
    /// Float baseline, no overrides.
    pub fn float() -> QuantRecipe {
        QuantRecipe::uniform(&QuantConfig::float())
    }

    /// Lower a flat [`QuantConfig`] to a uniform recipe: same policy for
    /// every layer, no overrides. `prepare` on this recipe is
    /// bit-identical to the pre-recipe pipeline on the config.
    pub fn uniform(cfg: &QuantConfig) -> QuantRecipe {
        QuantRecipe {
            w_bits: cfg.w_bits,
            a_bits: cfg.a_bits,
            w_clip: cfg.w_clip.into(),
            a_clip: cfg.a_clip.into(),
            ocs_ratio: cfg.ocs_ratio,
            ocs_target: cfg.ocs_target,
            split_mode: cfg.split_mode,
            overrides: Vec::new(),
        }
    }

    /// Append one override (later overrides win on conflicts).
    pub fn with_override(mut self, matches: LayerMatch, policy: LayerPolicy) -> QuantRecipe {
        self.overrides.push(LayerOverride { matches, policy });
        self
    }

    pub fn push_override(&mut self, ov: LayerOverride) {
        self.overrides.push(ov);
    }

    /// The paper's first/last-layer caution as a one-liner: keep the
    /// boundary layers float.
    pub fn skip_first_last(self) -> QuantRecipe {
        self.with_override(LayerMatch::pos(LayerPos::Edge), LayerPolicy::skip())
    }

    /// Mixed precision: boundary layers at `bits` weight bits, the
    /// defaults everywhere else.
    pub fn edge_w_bits(self, bits: u32) -> QuantRecipe {
        self.with_override(LayerMatch::pos(LayerPos::Edge), LayerPolicy::w_bits(bits))
    }

    /// Resolve the effective policy for one layer. `is_first`/`is_last`
    /// are relative to the model's quantized layers; overrides fold onto
    /// the defaults in declaration order, later ones winning on the
    /// fields they set.
    pub fn resolve(&self, layer: &LayerSpec, is_first: bool, is_last: bool) -> LayerRecipe {
        let mut rc = LayerRecipe {
            quantize: true,
            w_bits: self.w_bits,
            a_bits: self.a_bits,
            w_clip: self.w_clip.clone(),
            a_clip: self.a_clip.clone(),
            ocs_ratio: self.ocs_ratio,
            ocs_target: self.ocs_target,
            split_mode: self.split_mode,
        };
        for ov in &self.overrides {
            if !ov.matches.matches(layer, is_first, is_last) {
                continue;
            }
            let p = &ov.policy;
            if let Some(q) = p.quantize {
                rc.quantize = q;
            }
            if let Some(b) = p.w_bits {
                rc.w_bits = bits_opt(b);
            }
            if let Some(b) = p.a_bits {
                rc.a_bits = bits_opt(b);
            }
            if let Some(c) = &p.w_clip {
                rc.w_clip = c.clone();
            }
            if let Some(c) = &p.a_clip {
                rc.a_clip = c.clone();
            }
            if let Some(r) = p.ocs_ratio {
                rc.ocs_ratio = r;
            }
            if let Some(t) = p.ocs_target {
                rc.ocs_target = t;
            }
            if let Some(m) = p.split_mode {
                rc.split_mode = m;
            }
        }
        rc
    }

    /// True iff this recipe is a plain uniform config (no overrides).
    pub fn is_uniform(&self) -> bool {
        self.overrides.is_empty()
    }

    /// Does preparing `spec` under this recipe require activation
    /// calibration? True iff some quantized layer's resolved policy
    /// quantizes activations.
    pub fn needs_calibration(&self, spec: &ModelSpec) -> bool {
        let quantized: Vec<&LayerSpec> = spec.layers.iter().filter(|l| l.quantized).collect();
        let n = quantized.len();
        quantized.iter().enumerate().any(|(i, l)| {
            let rc = self.resolve(l, i == 0, i + 1 == n);
            rc.needs_calibration()
        })
    }

    /// Canonical text form — the fingerprint pre-image. Stable across
    /// processes and releases of this struct's field order (the format
    /// is versioned with a `q1|` prefix).
    pub fn canonical(&self) -> String {
        let bits = |b: Option<u32>| b.map(|b| b.to_string()).unwrap_or_else(|| "f".into());
        let mut s = format!(
            "q1|w:{}/{}|a:{}/{}|ocs:{}/{}/{}",
            bits(self.w_bits),
            self.w_clip.name(),
            bits(self.a_bits),
            self.a_clip.name(),
            self.ocs_ratio,
            target_name(self.ocs_target),
            self.split_mode.name(),
        );
        for ov in &self.overrides {
            s.push('|');
            s.push_str(&ov.canonical());
        }
        s
    }

    /// Stable 64-bit fingerprint (hex) of the canonical form — the
    /// [`super::PreparedCache`] key component and hot-swap identity.
    pub fn fingerprint(&self) -> String {
        format!(
            "{:016x}",
            crate::util::hash::Fnv1a::hash_bytes(self.canonical().as_bytes())
        )
    }

    /// Compact label for logs and bench records.
    pub fn label(&self) -> String {
        let base = LayerRecipe {
            quantize: true,
            w_bits: self.w_bits,
            a_bits: self.a_bits,
            w_clip: self.w_clip.clone(),
            a_clip: self.a_clip.clone(),
            ocs_ratio: self.ocs_ratio,
            ocs_target: self.ocs_target,
            split_mode: self.split_mode,
        }
        .label();
        if self.overrides.is_empty() {
            base
        } else {
            format!("{base} +{} layer override(s)", self.overrides.len())
        }
    }

    /// Parse a full recipe from a TOML section: flat defaults under
    /// `[section]` plus `[[section.layer]]` override tables.
    pub fn from_toml(c: &Config, section: &str) -> Result<QuantRecipe> {
        let mut recipe = QuantRecipe::uniform(&QuantConfig::from_toml(c, section)?);
        let arr = if section.is_empty() {
            "layer".to_string()
        } else {
            format!("{section}.layer")
        };
        for i in 0..c.array_len(&arr) {
            let key = |k: &str| format!("{arr}.{i}.{k}");
            let mut matches = LayerMatch::default();
            if c.get(&key("match")).is_some() {
                matches.name_glob = Some(c.str(&key("match"))?.to_string());
            }
            if c.get(&key("kind")).is_some() {
                let ks = c.str(&key("kind"))?;
                matches.kind =
                    Some(parse_kind(ks).with_context(|| format!("bad layer kind '{ks}'"))?);
            }
            if c.get(&key("pos")).is_some() {
                let ps = c.str(&key("pos"))?;
                matches.pos =
                    Some(LayerPos::parse(ps).with_context(|| format!("bad layer pos '{ps}'"))?);
            }
            let mut policy = LayerPolicy::default();
            // strict bool reads: `skip = "true"` (a string) must error,
            // not silently fall back to a default
            if c.get(&key("skip")).is_some() {
                policy.quantize = Some(!c.bool(&key("skip"))?);
            }
            if c.get(&key("quantize")).is_some() {
                policy.quantize = Some(c.bool(&key("quantize"))?);
            }
            for bits_key in ["w_bits", "a_bits"] {
                if c.get(&key(bits_key)).is_some() {
                    let v = c.int(&key(bits_key))?;
                    if v < 0 {
                        bail!("[[{arr}]] #{i}: {bits_key} {v} is negative");
                    }
                    policy
                        .set(bits_key, &v.to_string())
                        .with_context(|| format!("[[{arr}]] #{i}"))?;
                }
            }
            for str_key in ["w_clip", "a_clip", "ocs_target", "split_mode"] {
                if c.get(&key(str_key)).is_some() {
                    policy.set(str_key, c.str(&key(str_key))?)?;
                }
            }
            if c.get(&key("ocs_ratio")).is_some() {
                policy.set("ocs_ratio", &c.float(&key("ocs_ratio"))?.to_string())?;
            }
            if policy.is_empty() {
                bail!("[[{arr}]] #{i} sets no policy fields");
            }
            recipe.push_override(LayerOverride { matches, policy });
        }
        Ok(recipe)
    }

    /// Serialize back to the TOML text form [`QuantRecipe::from_toml`]
    /// parses: flat defaults under `[section]` plus one
    /// `[[section.layer]]` table per override, in declaration order
    /// (order is semantic — later overrides win). Parsing the emitted
    /// text yields an identical [`QuantRecipe::fingerprint`]; this is
    /// the emit path `ocs autotune` uses to hand a winning recipe to
    /// `serve`/`tables` unmodified.
    ///
    /// Custom [`ClipSpec`] strategies serialize by `name()`; only
    /// built-in clip names parse back, so a recipe carrying a custom
    /// strategy emits valid TOML that `from_toml` will reject.
    pub fn to_toml(&self, section: &str) -> String {
        let bits = |b: Option<u32>| b.unwrap_or(0);
        let mut s = String::new();
        if !section.is_empty() {
            s.push_str(&format!("[{section}]\n"));
        }
        s.push_str(&format!("w_bits = {}\n", bits(self.w_bits)));
        s.push_str(&format!("a_bits = {}\n", bits(self.a_bits)));
        s.push_str(&format!("w_clip = {}\n", toml_str(&self.w_clip.name())));
        s.push_str(&format!("a_clip = {}\n", toml_str(&self.a_clip.name())));
        s.push_str(&format!("ocs_ratio = {}\n", self.ocs_ratio));
        s.push_str(&format!("ocs_target = {}\n", toml_str(target_name(self.ocs_target))));
        s.push_str(&format!("split_mode = {}\n", toml_str(self.split_mode.name())));
        let table = if section.is_empty() {
            "[[layer]]".to_string()
        } else {
            format!("[[{section}.layer]]")
        };
        for ov in &self.overrides {
            s.push('\n');
            s.push_str(&table);
            s.push('\n');
            if let Some(g) = &ov.matches.name_glob {
                s.push_str(&format!("match = {}\n", toml_str(g)));
            }
            if let Some(k) = ov.matches.kind {
                s.push_str(&format!("kind = {}\n", toml_str(kind_name(k))));
            }
            if let Some(p) = ov.matches.pos {
                s.push_str(&format!("pos = {}\n", toml_str(p.name())));
            }
            let pol = &ov.policy;
            if let Some(q) = pol.quantize {
                s.push_str(&format!("quantize = {q}\n"));
            }
            if let Some(b) = pol.w_bits {
                s.push_str(&format!("w_bits = {b}\n"));
            }
            if let Some(b) = pol.a_bits {
                s.push_str(&format!("a_bits = {b}\n"));
            }
            if let Some(c) = &pol.w_clip {
                s.push_str(&format!("w_clip = {}\n", toml_str(&c.name())));
            }
            if let Some(c) = &pol.a_clip {
                s.push_str(&format!("a_clip = {}\n", toml_str(&c.name())));
            }
            if let Some(r) = pol.ocs_ratio {
                s.push_str(&format!("ocs_ratio = {r}\n"));
            }
            if let Some(t) = pol.ocs_target {
                s.push_str(&format!("ocs_target = {}\n", toml_str(target_name(t))));
            }
            if let Some(m) = pol.split_mode {
                s.push_str(&format!("split_mode = {}\n", toml_str(m.name())));
            }
        }
        s
    }

    /// Parse the CLI `--layer` flag value: `;`-separated
    /// [`LayerOverride::parse`] specs appended to `self`.
    pub fn with_cli_overrides(mut self, flag: &str) -> Result<QuantRecipe> {
        for spec in flag.split(';') {
            let spec = spec.trim();
            if spec.is_empty() {
                continue;
            }
            self.push_override(LayerOverride::parse(spec)?);
        }
        Ok(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clip::ClipMethod;

    fn layer(name: &str, kind: LayerKind) -> LayerSpec {
        LayerSpec {
            name: name.into(),
            kind,
            cin: 8,
            cin_pad: 10,
            cout: 4,
            ksize: 0,
            stride: 1,
            quantized: true,
            w_cin_axis: 0,
            w_shape: vec![8, 4],
            w_shape_pad: vec![10, 4],
        }
    }

    #[test]
    fn glob_matching() {
        assert!(glob_match("*", "anything"));
        assert!(glob_match("fc*", "fc1"));
        assert!(glob_match("fc*", "fc"));
        assert!(!glob_match("fc*", "conv1"));
        assert!(glob_match("*1", "fc1"));
        assert!(glob_match("c?nv*", "conv_stem"));
        assert!(glob_match("a*b*c", "a_x_b_y_c"));
        assert!(!glob_match("a*b*c", "a_x_b_y"));
        assert!(glob_match("", ""));
        assert!(!glob_match("", "x"));
        assert!(glob_match("**", "x"));
    }

    #[test]
    fn match_conditions_and_positions() {
        let fc = layer("fc1", LayerKind::Fc);
        let conv = layer("conv_stem", LayerKind::Conv);
        assert!(LayerMatch::name("fc*").matches(&fc, false, false));
        assert!(!LayerMatch::name("fc*").matches(&conv, false, false));
        assert!(LayerMatch::kind(LayerKind::Conv).matches(&conv, false, false));
        assert!(LayerMatch::pos(LayerPos::First).matches(&fc, true, false));
        assert!(!LayerMatch::pos(LayerPos::First).matches(&fc, false, true));
        assert!(LayerMatch::pos(LayerPos::Edge).matches(&fc, false, true));
        assert!(!LayerMatch::pos(LayerPos::Edge).matches(&fc, false, false));
        // conjunction: both conditions must hold
        let both = LayerMatch {
            name_glob: Some("fc*".into()),
            kind: Some(LayerKind::Conv),
            pos: None,
        };
        assert!(!both.matches(&fc, false, false));
        // the empty match matches everything
        assert!(LayerMatch::default().matches(&conv, false, false));
    }

    #[test]
    fn resolve_later_override_wins() {
        let cfg = QuantConfig::weights_only(8, ClipMethod::Mse, 0.0);
        let recipe = QuantRecipe::uniform(&cfg)
            .with_override(LayerMatch::name("fc*"), LayerPolicy::w_bits(4))
            .with_override(LayerMatch::name("fc9"), LayerPolicy::w_bits(2));
        let l = layer("fc1", LayerKind::Fc);
        let rc = recipe.resolve(&l, false, false);
        assert_eq!(rc.w_bits, Some(4));
        assert_eq!(rc.w_clip, ClipMethod::Mse.into(), "unset fields inherit");
        let l9 = layer("fc9", LayerKind::Fc);
        assert_eq!(recipe.resolve(&l9, false, false).w_bits, Some(2));
        let c = layer("conv1", LayerKind::Conv);
        assert_eq!(recipe.resolve(&c, false, false).w_bits, Some(8));
        // bits = 0 forces float; skip forces quantize = false
        let r2 = QuantRecipe::uniform(&cfg)
            .with_override(LayerMatch::name("fc*"), LayerPolicy::w_bits(0))
            .with_override(LayerMatch::name("conv*"), LayerPolicy::skip());
        assert_eq!(r2.resolve(&l, false, false).w_bits, None);
        assert!(!r2.resolve(&c, false, false).quantize);
        assert!(r2.resolve(&l, false, false).quantize);
    }

    #[test]
    fn skip_first_last_and_edge_bits() {
        let l = layer("f1", LayerKind::Fc);
        let r = QuantRecipe::uniform(&QuantConfig::weights_only(4, ClipMethod::None, 0.0))
            .skip_first_last();
        assert!(!r.resolve(&l, true, false).quantize);
        assert!(!r.resolve(&l, false, true).quantize);
        assert!(r.resolve(&l, false, false).quantize);
        let m = QuantRecipe::uniform(&QuantConfig::weights_only(4, ClipMethod::None, 0.0))
            .edge_w_bits(8);
        assert_eq!(m.resolve(&l, true, false).w_bits, Some(8));
        assert_eq!(m.resolve(&l, false, false).w_bits, Some(4));
    }

    #[test]
    fn fingerprint_stable_and_sensitive() {
        let a = QuantRecipe::uniform(&QuantConfig::weights_only(5, ClipMethod::Mse, 0.02));
        let b = QuantRecipe::uniform(&QuantConfig::weights_only(5, ClipMethod::Mse, 0.02));
        assert_eq!(a.fingerprint(), b.fingerprint(), "same recipe, same print");
        assert_eq!(a.fingerprint().len(), 16);
        let c = QuantRecipe::uniform(&QuantConfig::weights_only(4, ClipMethod::Mse, 0.02));
        assert_ne!(a.fingerprint(), c.fingerprint(), "bits change the print");
        let d = a.clone().with_override(LayerMatch::name("fc*"), LayerPolicy::w_bits(4));
        assert_ne!(a.fingerprint(), d.fingerprint(), "overrides change the print");
        // override *order* is semantic (later wins) and fingerprinted
        let e = QuantRecipe::uniform(&QuantConfig::float())
            .with_override(LayerMatch::name("a*"), LayerPolicy::w_bits(4))
            .with_override(LayerMatch::name("b*"), LayerPolicy::w_bits(5));
        let f = QuantRecipe::uniform(&QuantConfig::float())
            .with_override(LayerMatch::name("b*"), LayerPolicy::w_bits(5))
            .with_override(LayerMatch::name("a*"), LayerPolicy::w_bits(4));
        assert_ne!(e.fingerprint(), f.fingerprint());
        // float() is the Default
        assert_eq!(QuantRecipe::default().fingerprint(), QuantRecipe::float().fingerprint());
    }

    #[test]
    fn cli_override_parsing() {
        let ov = LayerOverride::parse("fc*:w_bits=4,ocs_ratio=0.1").unwrap();
        assert_eq!(ov.matches, LayerMatch::name("fc*"));
        assert_eq!(ov.policy.w_bits, Some(4));
        assert_eq!(ov.policy.ocs_ratio, Some(0.1));
        let skip = LayerOverride::parse("%edge:skip").unwrap();
        assert_eq!(skip.matches, LayerMatch::pos(LayerPos::Edge));
        assert_eq!(skip.policy.quantize, Some(false));
        let combo = LayerOverride::parse("fc*+%last:w_bits=8,w_clip=percentile:0.99").unwrap();
        assert_eq!(combo.matches.name_glob.as_deref(), Some("fc*"));
        assert_eq!(combo.matches.pos, Some(LayerPos::Last));
        assert_eq!(
            combo.policy.w_clip,
            Some(ClipMethod::Percentile(0.99).into()),
            "clip payloads keep their ':'"
        );
        let kinds = LayerOverride::parse("%conv:a_bits=8").unwrap();
        assert_eq!(kinds.matches.kind, Some(LayerKind::Conv));
        assert!(LayerOverride::parse("noseparator").is_err());
        assert!(LayerOverride::parse("fc*:").is_err(), "no policy fields");
        assert!(LayerOverride::parse("fc*:bogus_key=1").is_err());
        assert!(LayerOverride::parse("%bogus:skip").is_err());
        assert!(LayerOverride::parse("fc*:ocs_ratio=2.0").is_err(), "ratio > 1");
        assert!(
            LayerOverride::parse("conv*+fc*:w_bits=4").is_err(),
            "two globs in one match is a typo, not a union"
        );
        let recipe = QuantRecipe::float()
            .with_cli_overrides("fc*:w_bits=4; %edge:w_bits=8")
            .unwrap();
        assert_eq!(recipe.overrides.len(), 2);
    }

    #[test]
    fn toml_recipe_with_layer_tables() {
        let c = Config::parse(
            r#"
[quant]
w_bits = 5
w_clip = "mse"
ocs_ratio = 0.02

[[quant.layer]]
match = "fc*"
w_bits = 4
ocs_ratio = 0.1

[[quant.layer]]
pos = "edge"
w_bits = 8
skip = false

[[quant.layer]]
kind = "embed"
skip = true
"#,
        )
        .unwrap();
        let r = QuantRecipe::from_toml(&c, "quant").unwrap();
        assert_eq!(r.w_bits, Some(5));
        assert_eq!(r.overrides.len(), 3);
        let fc = layer("fc1", LayerKind::Fc);
        let rc = r.resolve(&fc, false, false);
        assert_eq!(rc.w_bits, Some(4));
        assert_eq!(rc.ocs_ratio, 0.1);
        assert_eq!(rc.w_clip, ClipMethod::Mse.into(), "defaults inherited");
        // edge override is later, so it beats the fc* one on w_bits
        let rc_edge = r.resolve(&fc, true, false);
        assert_eq!(rc_edge.w_bits, Some(8));
        assert!(rc_edge.quantize);
        let emb = layer("emb", LayerKind::Embed);
        assert!(!r.resolve(&emb, false, false).quantize);
        // an override table with no policy keys is an error
        let bad = Config::parse("[q]\n[[q.layer]]\nmatch = \"x\"\n").unwrap();
        assert!(QuantRecipe::from_toml(&bad, "q").is_err());
        // a mistyped bool must error loudly, not silently default
        let strbool = Config::parse("[q]\n[[q.layer]]\nskip = \"true\"\n").unwrap();
        assert!(QuantRecipe::from_toml(&strbool, "q").is_err());
        // no [[...layer]] tables -> plain uniform recipe
        let plain = Config::parse("[q]\nw_bits = 6\n").unwrap();
        let pr = QuantRecipe::from_toml(&plain, "q").unwrap();
        assert!(pr.is_uniform());
        assert_eq!(pr.w_bits, Some(6));
    }

    #[test]
    fn to_toml_round_trips_fingerprint() {
        let r = QuantRecipe::uniform(&QuantConfig::weights_with_a8(5, ClipMethod::Mse, 0.02))
            .with_override(
                LayerMatch::name("fc*"),
                LayerPolicy::w_bits(4)
                    .with_ocs_ratio(0.1)
                    .with_w_clip(ClipMethod::Percentile(0.995)),
            )
            .with_override(LayerMatch::pos(LayerPos::Edge), LayerPolicy::w_bits(8))
            .with_override(LayerMatch::kind(LayerKind::Embed), LayerPolicy::skip())
            .with_override(
                LayerMatch {
                    name_glob: Some("conv?".into()),
                    kind: Some(LayerKind::Conv),
                    pos: Some(LayerPos::Last),
                },
                LayerPolicy::default()
                    .with_a_bits(0)
                    .with_a_clip(ClipMethod::Kl)
                    .with_ocs_target(OcsTarget::Activations)
                    .with_split_mode(SplitMode::Naive),
            );
        let text = r.to_toml("quant");
        let back = QuantRecipe::from_toml(&Config::parse(&text).unwrap(), "quant").unwrap();
        assert_eq!(back.fingerprint(), r.fingerprint(), "emitted:\n{text}");
        assert_eq!(back.canonical(), r.canonical());
        // the empty section emits top-level keys + [[layer]] tables
        let flat = QuantRecipe::from_toml(&Config::parse(&r.to_toml("")).unwrap(), "").unwrap();
        assert_eq!(flat.fingerprint(), r.fingerprint());
        // a float recipe round-trips through all-default keys
        let f = QuantRecipe::float();
        let back = QuantRecipe::from_toml(&Config::parse(&f.to_toml("q")).unwrap(), "q").unwrap();
        assert_eq!(back.fingerprint(), f.fingerprint());
        // globs with TOML-special characters survive the escaping
        let odd = QuantRecipe::float()
            .with_override(LayerMatch::name(r#"we"ird\*"#), LayerPolicy::w_bits(4));
        let back =
            QuantRecipe::from_toml(&Config::parse(&odd.to_toml("q")).unwrap(), "q").unwrap();
        assert_eq!(back.fingerprint(), odd.fingerprint());
    }

    #[test]
    fn uniform_lowering_matches_config() {
        let cfg = QuantConfig::acts_only(6, ClipMethod::Kl, 0.05);
        let r = QuantRecipe::uniform(&cfg);
        let l = layer("any", LayerKind::Conv);
        let rc = r.resolve(&l, true, true);
        assert!(rc.quantize);
        assert_eq!(rc.w_bits, cfg.w_bits);
        assert_eq!(rc.a_bits, cfg.a_bits);
        assert_eq!(rc.w_clip, cfg.w_clip.into());
        assert_eq!(rc.a_clip, cfg.a_clip.into());
        assert_eq!(rc.ocs_ratio, cfg.ocs_ratio);
        assert_eq!(rc.ocs_target, cfg.ocs_target);
        assert_eq!(rc.split_mode, cfg.split_mode);
        assert!(rc.needs_calibration());
        assert!(r.label().contains("a6:kl"), "{}", r.label());
        let with_ov = r.with_override(LayerMatch::default(), LayerPolicy::skip());
        assert!(with_ov.label().contains("override"), "{}", with_ov.label());
        assert!(!with_ov.resolve(&l, false, false).needs_calibration());
    }
}
