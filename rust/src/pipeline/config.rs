//! Quantization run configuration — one value captures a full paper
//! experiment cell (bits × clip method × OCS ratio/target/mode).

use anyhow::{bail, Result};

use crate::clip::ClipMethod;
use crate::ocs::{OcsTarget, SplitMode};
use crate::util::toml::Config;

#[derive(Debug, Clone)]
pub struct QuantConfig {
    /// Weight bits (None = float weights).
    pub w_bits: Option<u32>,
    /// Activation bits (None = float activations).
    pub a_bits: Option<u32>,
    pub w_clip: ClipMethod,
    pub a_clip: ClipMethod,
    /// OCS expansion ratio r (0 = no OCS).
    pub ocs_ratio: f64,
    pub ocs_target: OcsTarget,
    pub split_mode: SplitMode,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig::float()
    }
}

impl QuantConfig {
    /// Float baseline — quantization fully bypassed.
    pub fn float() -> Self {
        QuantConfig {
            w_bits: None,
            a_bits: None,
            w_clip: ClipMethod::None,
            a_clip: ClipMethod::None,
            ocs_ratio: 0.0,
            ocs_target: OcsTarget::Weights,
            split_mode: SplitMode::QuantAware,
        }
    }

    /// Table 2/6 style: quantize weights, keep activations float.
    pub fn weights_only(bits: u32, clip: ClipMethod, ocs_ratio: f64) -> Self {
        QuantConfig {
            w_bits: Some(bits),
            w_clip: clip,
            ocs_ratio,
            ..Self::float()
        }
    }

    /// Table 2's full setting: weights at `bits`, activations at 8.
    pub fn weights_with_a8(bits: u32, clip: ClipMethod, ocs_ratio: f64) -> Self {
        QuantConfig {
            w_bits: Some(bits),
            a_bits: Some(8),
            w_clip: clip,
            a_clip: ClipMethod::None,
            ocs_ratio,
            ..Self::float()
        }
    }

    /// Table 3 style: weights at 8 (no clip), activations at `bits`.
    pub fn acts_only(bits: u32, clip: ClipMethod, ocs_ratio: f64) -> Self {
        QuantConfig {
            w_bits: Some(8),
            a_bits: Some(bits),
            w_clip: ClipMethod::None,
            a_clip: clip,
            ocs_ratio,
            ocs_target: OcsTarget::Activations,
            ..Self::float()
        }
    }

    pub fn with_mode(mut self, mode: SplitMode) -> Self {
        self.split_mode = mode;
        self
    }

    /// Compact label for table rows / logs.
    pub fn label(&self) -> String {
        let w = self
            .w_bits
            .map(|b| format!("w{b}:{}", self.w_clip.name()))
            .unwrap_or_else(|| "wf".into());
        let a = self
            .a_bits
            .map(|b| format!("a{b}:{}", self.a_clip.name()))
            .unwrap_or_else(|| "af".into());
        let ocs = if self.ocs_ratio > 0.0 {
            format!(
                " ocs[{:?} r={} {}]",
                self.ocs_target,
                self.ocs_ratio,
                self.split_mode.name()
            )
        } else {
            String::new()
        };
        format!("{w} {a}{ocs}")
    }

    /// Parse from a TOML config section (experiment files).
    pub fn from_toml(c: &Config, section: &str) -> Result<QuantConfig> {
        let key = |k: &str| {
            if section.is_empty() {
                k.to_string()
            } else {
                format!("{section}.{k}")
            }
        };
        let mut cfg = QuantConfig::float();
        let wb = c.int_or(&key("w_bits"), 0);
        if wb > 0 {
            cfg.w_bits = Some(wb as u32);
        }
        let ab = c.int_or(&key("a_bits"), 0);
        if ab > 0 {
            cfg.a_bits = Some(ab as u32);
        }
        let wclip = c.str_or(&key("w_clip"), "none");
        cfg.w_clip = match ClipMethod::parse(wclip) {
            Some(m) => m,
            None => bail!("bad w_clip '{wclip}'"),
        };
        let aclip = c.str_or(&key("a_clip"), "none");
        cfg.a_clip = match ClipMethod::parse(aclip) {
            Some(m) => m,
            None => bail!("bad a_clip '{aclip}'"),
        };
        cfg.ocs_ratio = c.float_or(&key("ocs_ratio"), 0.0);
        cfg.ocs_target = match c.str_or(&key("ocs_target"), "weights") {
            "weights" => OcsTarget::Weights,
            "activations" => OcsTarget::Activations,
            other => bail!("bad ocs_target '{other}'"),
        };
        cfg.split_mode = match SplitMode::parse(c.str_or(&key("split_mode"), "qa")) {
            Some(m) => m,
            None => bail!("bad split_mode"),
        };
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders() {
        let f = QuantConfig::float();
        assert!(f.w_bits.is_none() && f.a_bits.is_none());
        let w = QuantConfig::weights_only(5, ClipMethod::Mse, 0.02);
        assert_eq!(w.w_bits, Some(5));
        assert!(w.a_bits.is_none());
        let wa = QuantConfig::weights_with_a8(4, ClipMethod::Kl, 0.0);
        assert_eq!(wa.a_bits, Some(8));
        let a = QuantConfig::acts_only(6, ClipMethod::Mse, 0.01);
        assert_eq!(a.w_bits, Some(8));
        assert_eq!(a.ocs_target, OcsTarget::Activations);
    }

    #[test]
    fn labels_are_informative() {
        let cfg = QuantConfig::weights_only(5, ClipMethod::Mse, 0.02);
        let l = cfg.label();
        assert!(l.contains("w5:mse") && l.contains("r=0.02"), "{l}");
    }

    #[test]
    fn toml_roundtrip() {
        let c = Config::parse(
            r#"
[q]
w_bits = 5
a_bits = 8
w_clip = "kl"
ocs_ratio = 0.05
split_mode = "naive"
"#,
        )
        .unwrap();
        let cfg = QuantConfig::from_toml(&c, "q").unwrap();
        assert_eq!(cfg.w_bits, Some(5));
        assert_eq!(cfg.a_bits, Some(8));
        assert_eq!(cfg.w_clip, ClipMethod::Kl);
        assert_eq!(cfg.ocs_ratio, 0.05);
        assert_eq!(cfg.split_mode, SplitMode::Naive);
        assert!(QuantConfig::from_toml(
            &Config::parse("q.w_clip = \"zzz\"").unwrap(),
            "q"
        )
        .is_err());
    }
}
