//! Run configuration: [`QuantConfig`] captures a full paper experiment
//! cell (bits × clip method × OCS ratio/target/mode) and lowers to a
//! uniform [`super::QuantRecipe`] via [`QuantConfig::to_recipe`] — use a
//! recipe directly for per-layer overrides; [`ServeConfig`] captures the
//! serving-pool shape (worker shards, batching, admission control,
//! deadlines). Both parse from CLI flags and the TOML-subset experiment
//! files.

use std::time::Duration;

use anyhow::{bail, Result};

use crate::cli::Args;
use crate::clip::ClipMethod;
use crate::ocs::{OcsTarget, SplitMode};
use crate::util::toml::Config;

#[derive(Debug, Clone)]
pub struct QuantConfig {
    /// Weight bits (None = float weights).
    pub w_bits: Option<u32>,
    /// Activation bits (None = float activations).
    pub a_bits: Option<u32>,
    pub w_clip: ClipMethod,
    pub a_clip: ClipMethod,
    /// OCS expansion ratio r (0 = no OCS).
    pub ocs_ratio: f64,
    pub ocs_target: OcsTarget,
    pub split_mode: SplitMode,
}

impl Default for QuantConfig {
    fn default() -> Self {
        QuantConfig::float()
    }
}

impl QuantConfig {
    /// Float baseline — quantization fully bypassed.
    pub fn float() -> Self {
        QuantConfig {
            w_bits: None,
            a_bits: None,
            w_clip: ClipMethod::None,
            a_clip: ClipMethod::None,
            ocs_ratio: 0.0,
            ocs_target: OcsTarget::Weights,
            split_mode: SplitMode::QuantAware,
        }
    }

    /// Table 2/6 style: quantize weights, keep activations float.
    pub fn weights_only(bits: u32, clip: ClipMethod, ocs_ratio: f64) -> Self {
        QuantConfig {
            w_bits: Some(bits),
            w_clip: clip,
            ocs_ratio,
            ..Self::float()
        }
    }

    /// Table 2's full setting: weights at `bits`, activations at 8.
    pub fn weights_with_a8(bits: u32, clip: ClipMethod, ocs_ratio: f64) -> Self {
        QuantConfig {
            w_bits: Some(bits),
            a_bits: Some(8),
            w_clip: clip,
            a_clip: ClipMethod::None,
            ocs_ratio,
            ..Self::float()
        }
    }

    /// Table 3 style: weights at 8 (no clip), activations at `bits`.
    pub fn acts_only(bits: u32, clip: ClipMethod, ocs_ratio: f64) -> Self {
        QuantConfig {
            w_bits: Some(8),
            a_bits: Some(bits),
            w_clip: ClipMethod::None,
            a_clip: clip,
            ocs_ratio,
            ocs_target: OcsTarget::Activations,
            ..Self::float()
        }
    }

    pub fn with_mode(mut self, mode: SplitMode) -> Self {
        self.split_mode = mode;
        self
    }

    /// Lower to a uniform [`super::QuantRecipe`]: the same policy for
    /// every layer, no overrides. `QuantConfig` is the thin constructor;
    /// the recipe is what the pipeline actually consumes.
    pub fn to_recipe(&self) -> super::QuantRecipe {
        super::QuantRecipe::uniform(self)
    }

    /// Compact label for table rows / logs.
    pub fn label(&self) -> String {
        let w = self
            .w_bits
            .map(|b| format!("w{b}:{}", self.w_clip.name()))
            .unwrap_or_else(|| "wf".into());
        let a = self
            .a_bits
            .map(|b| format!("a{b}:{}", self.a_clip.name()))
            .unwrap_or_else(|| "af".into());
        let ocs = if self.ocs_ratio > 0.0 {
            format!(
                " ocs[{:?} r={} {}]",
                self.ocs_target,
                self.ocs_ratio,
                self.split_mode.name()
            )
        } else {
            String::new()
        };
        format!("{w} {a}{ocs}")
    }

    /// Parse from a TOML config section (experiment files).
    pub fn from_toml(c: &Config, section: &str) -> Result<QuantConfig> {
        let key = |k: &str| {
            if section.is_empty() {
                k.to_string()
            } else {
                format!("{section}.{k}")
            }
        };
        let mut cfg = QuantConfig::float();
        let wb = c.int_or(&key("w_bits"), 0);
        if wb > 0 {
            cfg.w_bits = Some(wb as u32);
        }
        let ab = c.int_or(&key("a_bits"), 0);
        if ab > 0 {
            cfg.a_bits = Some(ab as u32);
        }
        let wclip = c.str_or(&key("w_clip"), "none");
        cfg.w_clip = match ClipMethod::parse(wclip) {
            Some(m) => m,
            None => bail!("bad w_clip '{wclip}'"),
        };
        let aclip = c.str_or(&key("a_clip"), "none");
        cfg.a_clip = match ClipMethod::parse(aclip) {
            Some(m) => m,
            None => bail!("bad a_clip '{aclip}'"),
        };
        cfg.ocs_ratio = c.float_or(&key("ocs_ratio"), 0.0);
        cfg.ocs_target = match c.str_or(&key("ocs_target"), "weights") {
            "weights" => OcsTarget::Weights,
            "activations" => OcsTarget::Activations,
            other => bail!("bad ocs_target '{other}'"),
        };
        cfg.split_mode = match SplitMode::parse(c.str_or(&key("split_mode"), "qa")) {
            Some(m) => m,
            None => bail!("bad split_mode"),
        };
        Ok(cfg)
    }
}

/// Width of the kernel thread pool ([`crate::kernels::pool`]) that the
/// parallel statistics/quantization kernels run on. `0` means one
/// thread per core. Results are bit-identical at every width — the knob
/// trades wall-clock only (useful to pin core budgets when the serving
/// pool shares the machine, or `--threads 1` to force serial).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PerfConfig {
    /// Kernel-pool width; 0 (the default) = one thread per core.
    pub threads: usize,
}

impl PerfConfig {
    /// Parse `--threads N` (absent = auto).
    pub fn from_args(args: &Args) -> Result<PerfConfig> {
        Ok(PerfConfig {
            threads: args.parse_or("threads", 0usize)?,
        })
    }

    /// Parse the TOML `threads` key (absent = auto).
    pub fn from_toml(c: &Config, section: &str) -> Result<PerfConfig> {
        let key = if section.is_empty() {
            "threads".to_string()
        } else {
            format!("{section}.threads")
        };
        let v = c.int_or(&key, 0);
        if v < 0 {
            bail!("perf config: threads must be >= 0, got {v}");
        }
        Ok(PerfConfig { threads: v as usize })
    }

    /// Install as the process-wide kernel-pool width.
    pub fn apply(&self) {
        crate::kernels::pool::set_threads(self.threads);
    }
}

/// Which engine the serve pool builds on each worker thread.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ServeBackend {
    /// AOT artifacts through PJRT (the stub refuses to execute them on
    /// a default build — use `native` or `sim` there).
    #[default]
    Pjrt,
    /// Synthetic CPU-burning engine (router tests, CI serving smoke).
    Sim,
    /// Native integer backend: real quantized compute on the packed i8
    /// GEMM kernels, no artifacts or PJRT required
    /// ([`crate::serve::backend::NativeFactory`]).
    Native,
}

impl ServeBackend {
    pub fn parse(s: &str) -> Result<ServeBackend> {
        Ok(match s {
            "pjrt" => ServeBackend::Pjrt,
            "sim" => ServeBackend::Sim,
            "native" => ServeBackend::Native,
            other => bail!("bad backend '{other}' (pjrt|sim|native)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ServeBackend::Pjrt => "pjrt",
            ServeBackend::Sim => "sim",
            ServeBackend::Native => "native",
        }
    }

    /// Parse `--backend pjrt|sim|native`; the legacy `--sim` flag is an
    /// alias for `--backend sim` (and conflicts with an explicit
    /// different `--backend`).
    pub fn from_args(args: &Args) -> Result<ServeBackend> {
        let explicit = args.str("backend").map(ServeBackend::parse).transpose()?;
        if args.bool_or("sim", false) {
            return match explicit {
                None | Some(ServeBackend::Sim) => Ok(ServeBackend::Sim),
                Some(other) => {
                    bail!("--sim conflicts with --backend {}", other.name())
                }
            };
        }
        Ok(explicit.unwrap_or_default())
    }

    /// Parse the TOML `backend` key of a section (absent = pjrt).
    pub fn from_toml(c: &Config, section: &str) -> Result<ServeBackend> {
        let key = if section.is_empty() {
            "backend".to_string()
        } else {
            format!("{section}.backend")
        };
        ServeBackend::parse(c.str_or(&key, "pjrt"))
    }
}

/// Default worker-shard count: one per available core.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Tuning knobs for the sharded inference pool ([`crate::serve`]).
///
/// `workers` engine shards (each its own thread + PJRT engine, because
/// PJRT handles are `!Send`), each fed by its own bounded queue of
/// `queue_cap` jobs. The router rejects — never blocks — when every
/// queue is full, and jobs older than `deadline` are answered with an
/// error instead of being executed.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Engine shards (threads); default = available cores.
    pub workers: usize,
    /// Max jobs fused into one forward pass per shard.
    pub max_batch: usize,
    /// How long a shard waits to top up a non-full batch.
    pub max_wait: Duration,
    /// Per-shard queue bound (admission control).
    pub queue_cap: usize,
    /// Per-request deadline, measured from enqueue (`None` = unbounded).
    pub deadline: Option<Duration>,
    /// Per-tenant admission quota as a fraction of the pool's total
    /// admission bound (`workers × queue_cap`), in `(0, 1]`. A tenant
    /// with more than `ceil(quota × workers × queue_cap)` jobs queued
    /// or in flight has further submits rejected, so one bulk tenant
    /// cannot starve the others' queue slots. `None` = no quota.
    pub tenant_quota: Option<f64>,
    /// Supervisor respawn attempts per worker before giving up and
    /// opening that worker's breaker (0 = never respawn).
    pub restart_max: u32,
    /// Base respawn backoff; doubles per attempt, capped at 64×.
    pub backoff: Duration,
    /// Contained failures attributed to one tenant (panicking batch,
    /// aborted recipe sync) before that *tenant* is quarantined at the
    /// router, instead of letting it burn every worker's restart
    /// budget. Strikes decay over the quarantine window.
    pub tenant_restart_max: u32,
    /// When a tenant is quarantined, serve its requests through the
    /// default tenant's prep instead of rejecting them.
    pub tenant_fallback: bool,
    /// How long a quarantined tenant stays ejected before the breaker
    /// goes half-open and re-admits a single probe request.
    pub quarantine: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: default_workers(),
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            queue_cap: 1024,
            deadline: None,
            tenant_quota: None,
            restart_max: 3,
            backoff: Duration::from_millis(25),
            tenant_restart_max: 3,
            tenant_fallback: false,
            quarantine: Duration::from_millis(250),
        }
    }
}

impl ServeConfig {
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 {
            bail!("serve config: workers must be >= 1");
        }
        if self.max_batch == 0 {
            bail!("serve config: max_batch must be >= 1");
        }
        if self.queue_cap == 0 {
            bail!("serve config: queue_cap must be >= 1");
        }
        if self.deadline.is_some_and(|d| d.is_zero()) {
            bail!("serve config: deadline must be positive");
        }
        if let Some(q) = self.tenant_quota {
            if !(q > 0.0 && q <= 1.0) {
                bail!("serve config: tenant_quota must be in (0, 1], got {q}");
            }
        }
        if self.tenant_restart_max == 0 {
            bail!("serve config: tenant_restart_max must be >= 1");
        }
        if self.quarantine.is_zero() {
            bail!("serve config: quarantine_ms must be positive");
        }
        Ok(())
    }

    /// With a different worker count (sweeps), revalidated by `start`.
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Parse `--workers`, `--max-batch`, `--max-wait-us`, `--queue-cap`,
    /// `--deadline-ms`, `--tenant-quota`, `--restart-max`,
    /// `--backoff-ms`, `--tenant-restart-max`, `--tenant-fallback`,
    /// `--quarantine-ms`; anything absent keeps its default.
    pub fn from_args(args: &Args) -> Result<ServeConfig> {
        let d = ServeConfig::default();
        let cfg = ServeConfig {
            workers: args.parse_or("workers", d.workers)?,
            max_batch: args.parse_or("max-batch", d.max_batch)?,
            max_wait: match args.parse_opt::<u64>("max-wait-us")? {
                Some(us) => Duration::from_micros(us),
                None => d.max_wait,
            },
            queue_cap: args.parse_or("queue-cap", d.queue_cap)?,
            deadline: args
                .parse_opt::<u64>("deadline-ms")?
                .map(Duration::from_millis),
            tenant_quota: args.parse_opt::<f64>("tenant-quota")?,
            restart_max: args.parse_or("restart-max", d.restart_max)?,
            backoff: match args.parse_opt::<u64>("backoff-ms")? {
                Some(ms) => Duration::from_millis(ms),
                None => d.backoff,
            },
            tenant_restart_max: args.parse_or("tenant-restart-max", d.tenant_restart_max)?,
            tenant_fallback: args.bool_or("tenant-fallback", d.tenant_fallback),
            quarantine: match args.parse_opt::<u64>("quarantine-ms")? {
                Some(ms) => Duration::from_millis(ms),
                None => d.quarantine,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse from a TOML config section (`workers`, `max_batch`,
    /// `max_wait_us`, `queue_cap`, `deadline_ms`, `tenant_quota`,
    /// `restart_max`, `backoff_ms`, `tenant_restart_max`,
    /// `tenant_fallback`, `quarantine_ms`).
    pub fn from_toml(c: &Config, section: &str) -> Result<ServeConfig> {
        let key = |k: &str| {
            if section.is_empty() {
                k.to_string()
            } else {
                format!("{section}.{k}")
            }
        };
        let d = ServeConfig::default();
        let nonneg = |k: &str, v: i64| -> Result<u64> {
            if v < 0 {
                bail!("serve config: {k} must be >= 0, got {v}");
            }
            Ok(v as u64)
        };
        let cfg = ServeConfig {
            workers: nonneg("workers", c.int_or(&key("workers"), d.workers as i64))? as usize,
            max_batch: nonneg("max_batch", c.int_or(&key("max_batch"), d.max_batch as i64))?
                as usize,
            max_wait: Duration::from_micros(nonneg(
                "max_wait_us",
                c.int_or(&key("max_wait_us"), d.max_wait.as_micros() as i64),
            )?),
            queue_cap: nonneg("queue_cap", c.int_or(&key("queue_cap"), d.queue_cap as i64))?
                as usize,
            deadline: match c.get(&key("deadline_ms")) {
                Some(_) => Some(Duration::from_millis(nonneg(
                    "deadline_ms",
                    c.int(&key("deadline_ms"))?,
                )?)),
                None => None,
            },
            tenant_quota: c
                .get(&key("tenant_quota"))
                .map(|_| c.float(&key("tenant_quota")))
                .transpose()?,
            restart_max: nonneg(
                "restart_max",
                c.int_or(&key("restart_max"), d.restart_max as i64),
            )? as u32,
            backoff: Duration::from_millis(nonneg(
                "backoff_ms",
                c.int_or(&key("backoff_ms"), d.backoff.as_millis() as i64),
            )?),
            tenant_restart_max: nonneg(
                "tenant_restart_max",
                c.int_or(&key("tenant_restart_max"), d.tenant_restart_max as i64),
            )? as u32,
            tenant_fallback: c.bool_or(&key("tenant_fallback"), d.tenant_fallback),
            quarantine: Duration::from_millis(nonneg(
                "quarantine_ms",
                c.int_or(&key("quarantine_ms"), d.quarantine.as_millis() as i64),
            )?),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

/// One serving tenant: a named traffic class with its own quantization
/// policy and a share of the synthetic load-test mix. The pool always
/// has an implicit tenant 0 named `default` (the `--w-bits`/`--a-bits`
/// serve recipe); these specs describe the *additional* tenants.
///
/// Parsed from `--tenants name[:weight[:wbits]]` (comma-separated) or
/// TOML `[[serve.tenant]]` tables with keys `name`, `weight`, `w_bits`,
/// `a_bits`, `ocs_ratio`. Absent overrides inherit the serve defaults.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    /// Relative share of the load-test traffic mix (default 1.0).
    pub weight: f64,
    /// Weight bits override (None = the serve default, 5).
    pub w_bits: Option<u32>,
    /// Activation bits override (None = the backend's serve default;
    /// 0 = force float activations).
    pub a_bits: Option<u32>,
    /// OCS expansion-ratio override (None = the serve default, 0.02).
    pub ocs_ratio: Option<f64>,
}

impl TenantSpec {
    fn validate(tenants: &[TenantSpec]) -> Result<()> {
        for (i, t) in tenants.iter().enumerate() {
            if t.name.is_empty() {
                bail!("tenant {i}: name must be non-empty");
            }
            if t.name == "default" {
                bail!("tenant name 'default' is reserved for the implicit tenant 0");
            }
            if !(t.weight > 0.0 && t.weight.is_finite()) {
                bail!("tenant '{}': weight must be finite and > 0, got {}", t.name, t.weight);
            }
            if tenants[..i].iter().any(|o| o.name == t.name) {
                bail!("duplicate tenant name '{}'", t.name);
            }
        }
        Ok(())
    }

    /// Parse `--tenants a,b:2,c:1:4` — per entry `name[:weight[:wbits]]`.
    pub fn from_args(args: &Args) -> Result<Vec<TenantSpec>> {
        let mut out = Vec::new();
        for entry in args.list("tenants") {
            let mut parts = entry.split(':');
            let name = parts.next().unwrap_or("").to_string();
            let weight = match parts.next() {
                None | Some("") => 1.0,
                Some(w) => w
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--tenants '{entry}': bad weight '{w}'"))?,
            };
            let w_bits = match parts.next() {
                None | Some("") => None,
                Some(b) => Some(b.parse().map_err(|_| {
                    anyhow::anyhow!("--tenants '{entry}': bad w_bits '{b}'")
                })?),
            };
            if parts.next().is_some() {
                bail!("--tenants '{entry}': expected name[:weight[:wbits]]");
            }
            out.push(TenantSpec {
                name,
                weight,
                w_bits,
                a_bits: None,
                ocs_ratio: None,
            });
        }
        Self::validate(&out)?;
        Ok(out)
    }

    /// Parse `[[serve.tenant]]` tables from a TOML config.
    pub fn from_toml(c: &Config, section: &str) -> Result<Vec<TenantSpec>> {
        let base = if section.is_empty() {
            "tenant".to_string()
        } else {
            format!("{section}.tenant")
        };
        let mut out = Vec::new();
        for i in 0..c.array_len(&base) {
            let key = |k: &str| format!("{base}.{i}.{k}");
            let name = match c.get(&key("name")) {
                Some(_) => c.str(&key("name"))?.to_string(),
                None => bail!("[[{base}]] #{i}: missing required key 'name'"),
            };
            let opt_bits = |k: &str| -> Result<Option<u32>> {
                match c.get(&key(k)) {
                    None => Ok(None),
                    Some(_) => {
                        let v = c.int(&key(k))?;
                        if !(0..=32).contains(&v) {
                            bail!("tenant '{name}': {k} must be in 0..=32, got {v}");
                        }
                        Ok(Some(v as u32))
                    }
                }
            };
            out.push(TenantSpec {
                weight: c.float_or(&key("weight"), 1.0),
                w_bits: opt_bits("w_bits")?,
                a_bits: opt_bits("a_bits")?,
                ocs_ratio: c.get(&key("ocs_ratio")).map(|_| c.float(&key("ocs_ratio"))).transpose()?,
                name,
            });
        }
        Self::validate(&out)?;
        Ok(out)
    }

    /// Lower to this tenant's serving recipe. The baseline matches the
    /// default serve recipe (5-bit MSE-clipped weights, OCS r=0.02);
    /// `default_a_bits` is the backend's activation default (8 for
    /// native, 0 for PJRT) — see `serve_recipe` in the binary.
    pub fn to_recipe(&self, default_a_bits: u32) -> super::QuantRecipe {
        let mut cfg = QuantConfig::weights_only(
            self.w_bits.unwrap_or(5),
            ClipMethod::Mse,
            self.ocs_ratio.unwrap_or(0.02),
        );
        let ab = self.a_bits.unwrap_or(default_a_bits);
        if ab > 0 {
            cfg.a_bits = Some(ab);
        }
        cfg.to_recipe()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders() {
        let f = QuantConfig::float();
        assert!(f.w_bits.is_none() && f.a_bits.is_none());
        let w = QuantConfig::weights_only(5, ClipMethod::Mse, 0.02);
        assert_eq!(w.w_bits, Some(5));
        assert!(w.a_bits.is_none());
        let wa = QuantConfig::weights_with_a8(4, ClipMethod::Kl, 0.0);
        assert_eq!(wa.a_bits, Some(8));
        let a = QuantConfig::acts_only(6, ClipMethod::Mse, 0.01);
        assert_eq!(a.w_bits, Some(8));
        assert_eq!(a.ocs_target, OcsTarget::Activations);
    }

    #[test]
    fn labels_are_informative() {
        let cfg = QuantConfig::weights_only(5, ClipMethod::Mse, 0.02);
        let l = cfg.label();
        assert!(l.contains("w5:mse") && l.contains("r=0.02"), "{l}");
    }

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn perf_config_parses_and_applies() {
        assert_eq!(PerfConfig::default().threads, 0);
        let p = PerfConfig::from_args(&args("eval --threads 3")).unwrap();
        assert_eq!(p.threads, 3);
        assert!(PerfConfig::from_args(&args("eval --threads lots")).is_err());
        let c = Config::parse("[perf]\nthreads = 2\n").unwrap();
        assert_eq!(PerfConfig::from_toml(&c, "perf").unwrap().threads, 2);
        assert!(PerfConfig::from_toml(
            &Config::parse("[perf]\nthreads = -1\n").unwrap(),
            "perf"
        )
        .is_err());
        // apply installs the cap; restore auto afterwards so parallel
        // tests elsewhere keep their default width
        let _guard = crate::kernels::pool::test_cap_lock();
        PerfConfig { threads: 2 }.apply();
        assert_eq!(crate::kernels::pool::effective_threads(), 2);
        PerfConfig::default().apply();
    }

    #[test]
    fn serve_backend_parses() {
        assert_eq!(ServeBackend::from_args(&args("serve")).unwrap(), ServeBackend::Pjrt);
        assert_eq!(
            ServeBackend::from_args(&args("serve --backend native")).unwrap(),
            ServeBackend::Native
        );
        assert_eq!(
            ServeBackend::from_args(&args("serve --sim")).unwrap(),
            ServeBackend::Sim
        );
        // legacy --sim agrees with an explicit --backend sim
        assert_eq!(
            ServeBackend::from_args(&args("serve --sim --backend sim")).unwrap(),
            ServeBackend::Sim
        );
        assert!(ServeBackend::from_args(&args("serve --sim --backend native")).is_err());
        assert!(ServeBackend::from_args(&args("serve --backend warp")).is_err());
        let c = Config::parse("[serve]\nbackend = \"native\"\n").unwrap();
        assert_eq!(
            ServeBackend::from_toml(&c, "serve").unwrap(),
            ServeBackend::Native
        );
        assert_eq!(
            ServeBackend::from_toml(&Config::parse("").unwrap(), "serve").unwrap(),
            ServeBackend::Pjrt
        );
        assert_eq!(ServeBackend::Native.name(), "native");
    }

    #[test]
    fn serve_defaults_are_valid() {
        let d = ServeConfig::default();
        assert!(d.workers >= 1, "at least one shard");
        assert!(d.deadline.is_none());
        d.validate().unwrap();
    }

    #[test]
    fn serve_zero_workers_rejected_at_parse() {
        assert!(ServeConfig::from_args(&args("serve --workers 0")).is_err());
        assert!(ServeConfig::from_args(&args("serve --queue-cap 0")).is_err());
        assert!(ServeConfig::from_args(&args("serve --deadline-ms 0")).is_err());
        let c = Config::parse("[serve]\nworkers = 0\n").unwrap();
        assert!(ServeConfig::from_toml(&c, "serve").is_err());
    }

    #[test]
    fn serve_from_args_knobs() {
        let cfg = ServeConfig::from_args(&args(
            "serve --workers 4 --queue-cap 8 --deadline-ms 250 --max-batch 16 --max-wait-us 500 \
             --tenant-quota 0.25 --restart-max 5 --backoff-ms 10 \
             --tenant-restart-max 7 --tenant-fallback --quarantine-ms 40",
        ))
        .unwrap();
        assert_eq!(cfg.workers, 4);
        assert_eq!(cfg.queue_cap, 8);
        assert_eq!(cfg.max_batch, 16);
        assert_eq!(cfg.max_wait, Duration::from_micros(500));
        assert_eq!(cfg.deadline, Some(Duration::from_millis(250)));
        assert_eq!(cfg.tenant_quota, Some(0.25));
        assert_eq!(cfg.restart_max, 5);
        assert_eq!(cfg.backoff, Duration::from_millis(10));
        assert_eq!(cfg.tenant_restart_max, 7);
        assert!(cfg.tenant_fallback);
        assert_eq!(cfg.quarantine, Duration::from_millis(40));
        assert_eq!(cfg.with_workers(2).workers, 2);
        // fault knobs default off
        let d = ServeConfig::from_args(&args("serve")).unwrap();
        assert!(d.tenant_quota.is_none());
        assert_eq!(d.restart_max, 3);
        assert_eq!(d.tenant_restart_max, 3);
        assert!(!d.tenant_fallback);
        assert_eq!(d.quarantine, Duration::from_millis(250));
        // quota outside (0, 1] is rejected
        assert!(ServeConfig::from_args(&args("serve --tenant-quota 0")).is_err());
        assert!(ServeConfig::from_args(&args("serve --tenant-quota 1.5")).is_err());
        // a zero tenant breaker budget or quarantine window is rejected
        assert!(ServeConfig::from_args(&args("serve --tenant-restart-max 0")).is_err());
        assert!(ServeConfig::from_args(&args("serve --quarantine-ms 0")).is_err());
    }

    #[test]
    fn serve_from_toml_knobs() {
        let c = Config::parse(
            r#"
[serve]
workers = 3
max_batch = 8
queue_cap = 64
deadline_ms = 100
tenant_quota = 0.5
restart_max = 1
backoff_ms = 2
tenant_restart_max = 2
tenant_fallback = true
quarantine_ms = 30
"#,
        )
        .unwrap();
        let cfg = ServeConfig::from_toml(&c, "serve").unwrap();
        assert_eq!(cfg.workers, 3);
        assert_eq!(cfg.max_batch, 8);
        assert_eq!(cfg.queue_cap, 64);
        assert_eq!(cfg.deadline, Some(Duration::from_millis(100)));
        assert_eq!(cfg.tenant_quota, Some(0.5));
        assert_eq!(cfg.restart_max, 1);
        assert_eq!(cfg.backoff, Duration::from_millis(2));
        assert_eq!(cfg.tenant_restart_max, 2);
        assert!(cfg.tenant_fallback);
        assert_eq!(cfg.quarantine, Duration::from_millis(30));
        // absent section -> defaults
        let d = ServeConfig::from_toml(&Config::parse("").unwrap(), "serve").unwrap();
        assert!(d.deadline.is_none());
        assert!(ServeConfig::from_toml(
            &Config::parse("[serve]\ndeadline_ms = -5\n").unwrap(),
            "serve"
        )
        .is_err());
    }

    #[test]
    fn tenants_from_args() {
        assert!(TenantSpec::from_args(&args("serve")).unwrap().is_empty());
        let ts = TenantSpec::from_args(&args("serve --tenants gold,bulk:3,edge:1:4")).unwrap();
        assert_eq!(ts.len(), 3);
        assert_eq!(ts[0], TenantSpec {
            name: "gold".into(),
            weight: 1.0,
            w_bits: None,
            a_bits: None,
            ocs_ratio: None,
        });
        assert_eq!((ts[1].name.as_str(), ts[1].weight), ("bulk", 3.0));
        assert_eq!((ts[2].weight, ts[2].w_bits), (1.0, Some(4)));
        // malformed entries and reserved/duplicate names are rejected
        assert!(TenantSpec::from_args(&args("serve --tenants a:fast")).is_err());
        assert!(TenantSpec::from_args(&args("serve --tenants a:1:4:9")).is_err());
        assert!(TenantSpec::from_args(&args("serve --tenants a,a")).is_err());
        assert!(TenantSpec::from_args(&args("serve --tenants default")).is_err());
        assert!(TenantSpec::from_args(&args("serve --tenants a:0")).is_err());
        assert!(TenantSpec::from_args(&args("serve --tenants a:-1")).is_err());
    }

    #[test]
    fn tenants_from_toml() {
        let c = Config::parse(
            r#"
[serve]
workers = 2

[[serve.tenant]]
name = "gold"
w_bits = 8
ocs_ratio = 0.05

[[serve.tenant]]
name = "bulk"
weight = 3.0
w_bits = 4
a_bits = 0
"#,
        )
        .unwrap();
        let ts = TenantSpec::from_toml(&c, "serve").unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!((ts[0].name.as_str(), ts[0].w_bits, ts[0].ocs_ratio), ("gold", Some(8), Some(0.05)));
        assert_eq!((ts[1].weight, ts[1].w_bits, ts[1].a_bits), (3.0, Some(4), Some(0)));
        // no tables at all -> empty
        assert!(TenantSpec::from_toml(&Config::parse("").unwrap(), "serve").unwrap().is_empty());
        // a table without a name is rejected
        let bad = Config::parse("[[serve.tenant]]\nweight = 2.0\n").unwrap();
        assert!(TenantSpec::from_toml(&bad, "serve").is_err());
        let oob = Config::parse("[[serve.tenant]]\nname = \"x\"\nw_bits = 99\n").unwrap();
        assert!(TenantSpec::from_toml(&oob, "serve").is_err());
    }

    #[test]
    fn tenant_recipe_lowering() {
        let t = TenantSpec {
            name: "gold".into(),
            weight: 1.0,
            w_bits: Some(8),
            a_bits: None,
            ocs_ratio: None,
        };
        // native default a8; label carries the override
        let l = t.to_recipe(8).label();
        assert!(l.contains("w8:mse") && l.contains("a8"), "{l}");
        // pjrt default: float activations
        let l = t.to_recipe(0).label();
        assert!(l.contains("af"), "{l}");
        // explicit a_bits = 0 forces float even on native
        let t0 = TenantSpec { a_bits: Some(0), ..t };
        assert!(t0.to_recipe(8).label().contains("af"));
    }

    #[test]
    fn toml_roundtrip() {
        let c = Config::parse(
            r#"
[q]
w_bits = 5
a_bits = 8
w_clip = "kl"
ocs_ratio = 0.05
split_mode = "naive"
"#,
        )
        .unwrap();
        let cfg = QuantConfig::from_toml(&c, "q").unwrap();
        assert_eq!(cfg.w_bits, Some(5));
        assert_eq!(cfg.a_bits, Some(8));
        assert_eq!(cfg.w_clip, ClipMethod::Kl);
        assert_eq!(cfg.ocs_ratio, 0.05);
        assert_eq!(cfg.split_mode, SplitMode::Naive);
        assert!(QuantConfig::from_toml(
            &Config::parse("q.w_clip = \"zzz\"").unwrap(),
            "q"
        )
        .is_err());
    }
}
