//! Process-wide cache of prepared models, keyed by
//! `(model, recipe fingerprint, inputs token)` — the quantization-side
//! sibling of [`crate::runtime::HloTextCache`].
//!
//! `pipeline::prepare` is the expensive step of standing up a worker or
//! a table cell: OCS split planning, histogram builds, clip-threshold
//! sweeps, and fake-quantization over every layer. The sharded server
//! runs it once *per worker*, and table sweeps re-run it for every
//! repeated config — N workers × M sweep points of identical work. This
//! cache makes each distinct `(model, recipe, weights+calibration)`
//! combination prepare exactly once per process; all consumers share the
//! result via `Arc<PreparedModel>`.
//!
//! The recipe side of the key is [`QuantRecipe::fingerprint`]. Because a
//! model *name* does not pin the layer structure (two artifact dirs can
//! differ in padding or quantized flags), the weights (init vs trained),
//! or the calibration set (quick vs full, per-batch oracle), the key
//! also folds in an *inputs token*: an FNV-1a hash over the spec's layer
//! table, the weight-store contents, and the calibration statistics.
//! That keeps Table-4-style per-batch oracle preparations (and
//! structurally different same-name specs) from aliasing each other, at
//! the cost of one cheap hash pass over the weights per lookup (orders
//! of magnitude cheaper than `prepare` itself).
//!
//! Preparation happens under the cache lock, mirroring `HloTextCache`:
//! N workers racing on a cold key must produce exactly one prepare, and
//! serializing the racers *is* the win — the losers would otherwise
//! each burn a core redoing it.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::Result;

use crate::calib::Calibration;
use crate::model::store::WeightStore;
use crate::model::ModelSpec;

use super::recipe::QuantRecipe;
use super::{prepare_recipe, PreparedModel};

/// Shared prepared-model cache with hit/miss accounting.
#[derive(Default)]
pub struct PreparedCache {
    map: Mutex<HashMap<(String, String, u64), Arc<PreparedModel>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PreparedCache {
    pub fn new() -> PreparedCache {
        PreparedCache::default()
    }

    /// The process-wide instance ([`super::prepare_cached`] and the
    /// serving backends use this one).
    pub fn global() -> &'static PreparedCache {
        static GLOBAL: OnceLock<PreparedCache> = OnceLock::new();
        GLOBAL.get_or_init(PreparedCache::default)
    }

    /// Fetch the prepared model for `(spec, ws, calib, recipe)`, running
    /// [`prepare_recipe`] on the first request only.
    pub fn get_or_prepare(
        &self,
        spec: &ModelSpec,
        ws: &WeightStore,
        calib: Option<&Calibration>,
        recipe: &QuantRecipe,
    ) -> Result<Arc<PreparedModel>> {
        let key = (
            spec.name.clone(),
            recipe.fingerprint(),
            inputs_token(spec, ws, calib),
        );
        let mut map = self.map.lock().expect("prepared cache poisoned");
        if let Some(prep) = map.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(prep.clone());
        }
        // prepare under the lock: racing workers produce one prep
        let prep = Arc::new(prepare_recipe(spec, ws, calib, recipe)?);
        map.insert(key, prep.clone());
        self.misses.fetch_add(1, Ordering::Relaxed);
        Ok(prep)
    }

    pub fn len(&self) -> usize {
        self.map.lock().expect("prepared cache poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Drop every cached prep (tests; long-lived processes that retire
    /// weight sets can reclaim memory here).
    pub fn clear(&self) {
        self.map.lock().expect("prepared cache poisoned").clear();
    }
}

/// Hash of everything `prepare` consumes besides the recipe: the spec's
/// layer structure (a model *name* does not pin padded shapes or
/// quantized flags across artifact dirs), weight leaves (names, exact
/// f32 bits), and calibration statistics (per-layer histogram
/// counts/ranges, channel maxima, outlier counts).
fn inputs_token(spec: &ModelSpec, ws: &WeightStore, calib: Option<&Calibration>) -> u64 {
    let mut h = crate::util::hash::Fnv1a::new();
    for l in &spec.layers {
        h.str(&l.name);
        h.u64(l.cin as u64);
        h.u64(l.cin_pad as u64);
        h.u64(l.cout as u64);
        h.u64(l.w_cin_axis as u64);
        h.byte(l.quantized as u8);
        h.byte(match l.kind {
            crate::model::LayerKind::Conv => 0,
            crate::model::LayerKind::Fc => 1,
            crate::model::LayerKind::Embed => 2,
        });
        for &d in &l.w_shape_pad {
            h.u64(d as u64);
        }
    }
    for name in ws.names() {
        h.str(name);
        if let Some(t) = ws.bundle.f32s.get(name) {
            h.u64(t.len() as u64);
            for &v in t.data() {
                h.u32(v.to_bits());
            }
        }
    }
    match calib {
        None => h.u64(0),
        Some(c) => {
            h.u64(1 + c.layers.len() as u64);
            for (name, lc) in &c.layers {
                h.str(name);
                h.u64(lc.hist.count());
                h.u32(lc.hist.range().to_bits());
                for &m in &lc.channel_max {
                    h.u32(m.to_bits());
                }
                for &o in &lc.outlier_counts {
                    h.u64(o);
                }
            }
        }
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clip::ClipMethod;
    use crate::model::{LayerKind, LayerSpec};
    use crate::pipeline::QuantConfig;
    use crate::tensor::TensorF;
    use crate::util::rng::Rng;

    fn fake_spec() -> ModelSpec {
        ModelSpec {
            name: "fake".into(),
            dir: std::path::PathBuf::new(),
            pad_factor: 1.25,
            num_classes: 4,
            img_hw: 0,
            img_c: 0,
            vocab: 0,
            seq_len: 0,
            momentum: 0.9,
            layers: vec![LayerSpec {
                name: "f1".into(),
                kind: LayerKind::Fc,
                cin: 8,
                cin_pad: 10,
                cout: 4,
                ksize: 0,
                stride: 1,
                quantized: true,
                w_cin_axis: 0,
                w_shape: vec![8, 4],
                w_shape_pad: vec![10, 4],
            }],
            artifacts: Default::default(),
        }
    }

    fn fake_ws(seed: u64) -> WeightStore {
        let mut rng = Rng::new(seed);
        WeightStore::from_leaves(vec![
            ("f1.W".into(), TensorF::from_vec(&[8, 4], rng.normal_vec(32)).unwrap()),
            ("f1.b".into(), TensorF::zeros(&[4])),
        ])
    }

    #[test]
    fn second_prepare_hits_and_shares() {
        let cache = PreparedCache::new();
        let spec = fake_spec();
        let ws = fake_ws(1);
        let recipe = QuantRecipe::uniform(&QuantConfig::weights_only(4, ClipMethod::Mse, 0.0));
        let a = cache.get_or_prepare(&spec, &ws, None, &recipe).unwrap();
        let b = cache.get_or_prepare(&spec, &ws, None, &recipe).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "one prep, shared");
        assert_eq!((cache.misses(), cache.hits(), cache.len()), (1, 1, 1));
    }

    #[test]
    fn distinct_recipes_weights_do_not_alias() {
        let cache = PreparedCache::new();
        let spec = fake_spec();
        let ws = fake_ws(1);
        let r4 = QuantRecipe::uniform(&QuantConfig::weights_only(4, ClipMethod::None, 0.0));
        let r5 = QuantRecipe::uniform(&QuantConfig::weights_only(5, ClipMethod::None, 0.0));
        let a = cache.get_or_prepare(&spec, &ws, None, &r4).unwrap();
        let b = cache.get_or_prepare(&spec, &ws, None, &r5).unwrap();
        assert!(!Arc::ptr_eq(&a, &b));
        // same model name + recipe, different weights: the inputs token
        // keeps init-vs-trained (and oracle-calib) preps separate
        let c = cache.get_or_prepare(&spec, &fake_ws(2), None, &r4).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.misses(), 3);
        assert_eq!(cache.hits(), 0);
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn structural_spec_changes_do_not_alias() {
        // same model name, same weight bytes, different layer structure
        // (e.g. two artifact dirs with different pad factors) must not
        // share a prep
        let cache = PreparedCache::new();
        let ws = fake_ws(1);
        let recipe = QuantRecipe::uniform(&QuantConfig::weights_only(4, ClipMethod::None, 0.0));
        let a = cache.get_or_prepare(&fake_spec(), &ws, None, &recipe).unwrap();
        let mut spec2 = fake_spec();
        spec2.layers[0].cin_pad = 12;
        spec2.layers[0].w_shape_pad = vec![12, 4];
        let b = cache.get_or_prepare(&spec2, &ws, None, &recipe).unwrap();
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(b.layers[0].w.shape(), &[12, 4], "prep follows the new padding");
        assert_eq!(a.layers[0].w.shape(), &[10, 4]);
        assert_eq!(cache.misses(), 2);
    }

    #[test]
    fn concurrent_cold_key_prepares_once() {
        let cache = Arc::new(PreparedCache::new());
        let spec = Arc::new(fake_spec());
        let ws = Arc::new(fake_ws(3));
        let recipe = QuantRecipe::uniform(&QuantConfig::weights_only(4, ClipMethod::Kl, 0.05));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let (cache, spec, ws, recipe) =
                (cache.clone(), spec.clone(), ws.clone(), recipe.clone());
            handles.push(std::thread::spawn(move || {
                cache.get_or_prepare(&spec, &ws, None, &recipe).unwrap()
            }));
        }
        let preps: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for p in &preps[1..] {
            assert!(Arc::ptr_eq(&preps[0], p));
        }
        assert_eq!(cache.misses(), 1, "exactly one prepare ran");
        assert_eq!(cache.hits(), 7);
    }
}
