//! Process-wide cache of prepared models, keyed by
//! `(model, recipe fingerprint, inputs token)` — the quantization-side
//! sibling of [`crate::runtime::HloTextCache`].
//!
//! `pipeline::prepare` is the expensive step of standing up a worker or
//! a table cell: OCS split planning, histogram builds, clip-threshold
//! sweeps, and fake-quantization over every layer. The sharded server
//! runs it once *per worker*, and table sweeps re-run it for every
//! repeated config — N workers × M sweep points of identical work. This
//! cache makes each distinct `(model, recipe, weights+calibration)`
//! combination prepare exactly once per process; all consumers share the
//! result via `Arc<PreparedModel>`.
//!
//! The recipe side of the key is [`QuantRecipe::fingerprint`]. Because a
//! model *name* does not pin the layer structure (two artifact dirs can
//! differ in padding or quantized flags), the weights (init vs trained),
//! or the calibration set (quick vs full, per-batch oracle), the key
//! also folds in an *inputs token*: an FNV-1a hash over the spec's layer
//! table, the weight-store contents, and the calibration statistics.
//! That keeps Table-4-style per-batch oracle preparations (and
//! structurally different same-name specs) from aliasing each other, at
//! the cost of one cheap hash pass over the weights per lookup (orders
//! of magnitude cheaper than `prepare` itself).
//!
//! Preparation happens under the cache lock, mirroring `HloTextCache`:
//! N workers racing on a cold key must produce exactly one prepare, and
//! serializing the racers *is* the win — the losers would otherwise
//! each burn a core redoing it.
//!
//! The cache is **bounded**: at most [`PreparedCache::capacity`]
//! entries (default [`DEFAULT_CAP`], `--prep-cache-cap` / 0 =
//! unbounded), evicting the least-recently-used prep past the bound.
//! Per-tenant recipe serving cycles through arbitrarily many distinct
//! recipes on a long-lived process; before the bound the only recourse
//! was a manual [`PreparedCache::clear`]. Evicted preps still in use
//! stay alive through their `Arc`s — eviction drops the cache's
//! reference, never a worker's. Evictions are counted
//! ([`PreparedCache::evictions`]) next to hits/misses.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::Result;

use crate::calib::Calibration;
use crate::model::store::WeightStore;
use crate::model::ModelSpec;

use super::recipe::QuantRecipe;
use super::{prepare_recipe, PreparedModel};

/// Default entry bound — generous (a prep per distinct recipe; sweeps
/// and per-tenant pools rarely hold this many live at once).
pub const DEFAULT_CAP: usize = 64;

/// One cached prep plus its recency stamp.
struct Entry {
    prep: Arc<PreparedModel>,
    last_used: u64,
}

/// Shared prepared-model cache with hit/miss/eviction accounting and
/// LRU bounding.
pub struct PreparedCache {
    map: Mutex<HashMap<(String, String, u64), Entry>>,
    /// Entry bound; 0 = unbounded.
    cap: AtomicUsize,
    /// Monotonic recency clock (bumped under the map lock).
    tick: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl Default for PreparedCache {
    fn default() -> Self {
        PreparedCache {
            map: Mutex::new(HashMap::new()),
            cap: AtomicUsize::new(DEFAULT_CAP),
            tick: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }
}

impl PreparedCache {
    pub fn new() -> PreparedCache {
        PreparedCache::default()
    }

    /// The process-wide instance ([`super::prepare_cached`] and the
    /// serving backends use this one).
    pub fn global() -> &'static PreparedCache {
        static GLOBAL: OnceLock<PreparedCache> = OnceLock::new();
        GLOBAL.get_or_init(PreparedCache::default)
    }

    /// Fetch the prepared model for `(spec, ws, calib, recipe)`, running
    /// [`prepare_recipe`] on the first request only. Past the capacity,
    /// the least-recently-used entry is evicted.
    pub fn get_or_prepare(
        &self,
        spec: &ModelSpec,
        ws: &WeightStore,
        calib: Option<&Calibration>,
        recipe: &QuantRecipe,
    ) -> Result<Arc<PreparedModel>> {
        let key = (
            spec.name.clone(),
            recipe.fingerprint(),
            inputs_token(spec, ws, calib),
        );
        // poison-tolerant: the cache outlives any one panicked worker; the
        // map itself is always left consistent (inserts are atomic)
        let mut map = self.map.lock().unwrap_or_else(|e| e.into_inner());
        let now = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
        if let Some(e) = map.get_mut(&key) {
            e.last_used = now;
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(e.prep.clone());
        }
        // prepare under the lock: racing workers produce one prep
        let prep = Arc::new(prepare_recipe(spec, ws, calib, recipe)?);
        map.insert(
            key,
            Entry {
                prep: prep.clone(),
                last_used: now,
            },
        );
        self.misses.fetch_add(1, Ordering::Relaxed);
        let cap = self.cap.load(Ordering::Relaxed);
        while cap > 0 && map.len() > cap {
            // O(len) stale scan — the cache holds at most `cap` + 1
            // entries and evictions are rare next to a prepare's cost
            let oldest = map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
                .expect("non-empty map");
            map.remove(&oldest);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        Ok(prep)
    }

    /// Set the entry bound (0 = unbounded). Shrinking below the current
    /// population evicts LRU-first on the next insert, not eagerly.
    pub fn set_capacity(&self, cap: usize) {
        self.cap.store(cap, Ordering::Relaxed);
    }

    pub fn capacity(&self) -> usize {
        self.cap.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// One-line accounting summary for serve reports.
    pub fn stats_line(&self) -> String {
        format!(
            "prep cache: {} entries (cap {}), {} hits, {} misses, {} evictions",
            self.len(),
            self.capacity(),
            self.hits(),
            self.misses(),
            self.evictions()
        )
    }

    /// Drop every cached prep (tests; long-lived processes that retire
    /// weight sets can reclaim memory here).
    pub fn clear(&self) {
        self.map.lock().unwrap_or_else(|e| e.into_inner()).clear();
    }
}

/// Hash of everything `prepare` consumes besides the recipe: the spec's
/// layer structure (a model *name* does not pin padded shapes or
/// quantized flags across artifact dirs), weight leaves (names, exact
/// f32 bits), and calibration statistics (per-layer histogram
/// counts/ranges, channel maxima, outlier counts).
fn inputs_token(spec: &ModelSpec, ws: &WeightStore, calib: Option<&Calibration>) -> u64 {
    let mut h = crate::util::hash::Fnv1a::new();
    for l in &spec.layers {
        h.str(&l.name);
        h.u64(l.cin as u64);
        h.u64(l.cin_pad as u64);
        h.u64(l.cout as u64);
        h.u64(l.w_cin_axis as u64);
        h.byte(l.quantized as u8);
        h.byte(match l.kind {
            crate::model::LayerKind::Conv => 0,
            crate::model::LayerKind::Fc => 1,
            crate::model::LayerKind::Embed => 2,
        });
        for &d in &l.w_shape_pad {
            h.u64(d as u64);
        }
    }
    for name in ws.names() {
        h.str(name);
        if let Some(t) = ws.bundle.f32s.get(name) {
            h.u64(t.len() as u64);
            for &v in t.data() {
                h.u32(v.to_bits());
            }
        }
    }
    match calib {
        None => h.u64(0),
        Some(c) => {
            h.u64(1 + c.layers.len() as u64);
            for (name, lc) in &c.layers {
                h.str(name);
                h.u64(lc.hist.count());
                h.u32(lc.hist.range().to_bits());
                for &m in &lc.channel_max {
                    h.u32(m.to_bits());
                }
                for &o in &lc.outlier_counts {
                    h.u64(o);
                }
            }
        }
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clip::ClipMethod;
    use crate::model::{LayerKind, LayerSpec};
    use crate::pipeline::QuantConfig;
    use crate::tensor::TensorF;
    use crate::util::rng::Rng;

    fn fake_spec() -> ModelSpec {
        ModelSpec {
            name: "fake".into(),
            dir: std::path::PathBuf::new(),
            pad_factor: 1.25,
            num_classes: 4,
            img_hw: 0,
            img_c: 0,
            vocab: 0,
            seq_len: 0,
            momentum: 0.9,
            layers: vec![LayerSpec {
                name: "f1".into(),
                kind: LayerKind::Fc,
                cin: 8,
                cin_pad: 10,
                cout: 4,
                ksize: 0,
                stride: 1,
                quantized: true,
                w_cin_axis: 0,
                w_shape: vec![8, 4],
                w_shape_pad: vec![10, 4],
            }],
            artifacts: Default::default(),
        }
    }

    fn fake_ws(seed: u64) -> WeightStore {
        let mut rng = Rng::new(seed);
        WeightStore::from_leaves(vec![
            ("f1.W".into(), TensorF::from_vec(&[8, 4], rng.normal_vec(32)).unwrap()),
            ("f1.b".into(), TensorF::zeros(&[4])),
        ])
    }

    #[test]
    fn second_prepare_hits_and_shares() {
        let cache = PreparedCache::new();
        let spec = fake_spec();
        let ws = fake_ws(1);
        let recipe = QuantRecipe::uniform(&QuantConfig::weights_only(4, ClipMethod::Mse, 0.0));
        let a = cache.get_or_prepare(&spec, &ws, None, &recipe).unwrap();
        let b = cache.get_or_prepare(&spec, &ws, None, &recipe).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "one prep, shared");
        assert_eq!((cache.misses(), cache.hits(), cache.len()), (1, 1, 1));
    }

    #[test]
    fn distinct_recipes_weights_do_not_alias() {
        let cache = PreparedCache::new();
        let spec = fake_spec();
        let ws = fake_ws(1);
        let r4 = QuantRecipe::uniform(&QuantConfig::weights_only(4, ClipMethod::None, 0.0));
        let r5 = QuantRecipe::uniform(&QuantConfig::weights_only(5, ClipMethod::None, 0.0));
        let a = cache.get_or_prepare(&spec, &ws, None, &r4).unwrap();
        let b = cache.get_or_prepare(&spec, &ws, None, &r5).unwrap();
        assert!(!Arc::ptr_eq(&a, &b));
        // same model name + recipe, different weights: the inputs token
        // keeps init-vs-trained (and oracle-calib) preps separate
        let c = cache.get_or_prepare(&spec, &fake_ws(2), None, &r4).unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.misses(), 3);
        assert_eq!(cache.hits(), 0);
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn structural_spec_changes_do_not_alias() {
        // same model name, same weight bytes, different layer structure
        // (e.g. two artifact dirs with different pad factors) must not
        // share a prep
        let cache = PreparedCache::new();
        let ws = fake_ws(1);
        let recipe = QuantRecipe::uniform(&QuantConfig::weights_only(4, ClipMethod::None, 0.0));
        let a = cache.get_or_prepare(&fake_spec(), &ws, None, &recipe).unwrap();
        let mut spec2 = fake_spec();
        spec2.layers[0].cin_pad = 12;
        spec2.layers[0].w_shape_pad = vec![12, 4];
        let b = cache.get_or_prepare(&spec2, &ws, None, &recipe).unwrap();
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(b.layers[0].w.shape(), &[12, 4], "prep follows the new padding");
        assert_eq!(a.layers[0].w.shape(), &[10, 4]);
        assert_eq!(cache.misses(), 2);
    }

    fn recipe_bits(bits: u32) -> QuantRecipe {
        QuantRecipe::uniform(&QuantConfig::weights_only(bits, ClipMethod::None, 0.0))
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let cache = PreparedCache::new();
        cache.set_capacity(2);
        assert_eq!(cache.capacity(), 2);
        let spec = fake_spec();
        let ws = fake_ws(9);
        cache.get_or_prepare(&spec, &ws, None, &recipe_bits(4)).unwrap();
        cache.get_or_prepare(&spec, &ws, None, &recipe_bits(5)).unwrap();
        // touch the 4-bit prep so the 5-bit one is LRU
        cache.get_or_prepare(&spec, &ws, None, &recipe_bits(4)).unwrap();
        // inserting a third evicts the 5-bit prep, not the 4-bit one
        cache.get_or_prepare(&spec, &ws, None, &recipe_bits(6)).unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 1);
        let miss_before = cache.misses();
        cache.get_or_prepare(&spec, &ws, None, &recipe_bits(4)).unwrap();
        assert_eq!(cache.misses(), miss_before, "4-bit prep survived");
        cache.get_or_prepare(&spec, &ws, None, &recipe_bits(5)).unwrap();
        assert_eq!(cache.misses(), miss_before + 1, "5-bit prep was evicted");
        assert_eq!(cache.evictions(), 2, "re-inserting 5 evicted another");
        assert!(cache.stats_line().contains("evictions"), "{}", cache.stats_line());
    }

    #[test]
    fn unbounded_capacity_never_evicts() {
        let cache = PreparedCache::new();
        cache.set_capacity(0);
        let spec = fake_spec();
        let ws = fake_ws(10);
        for bits in 2..=8 {
            cache.get_or_prepare(&spec, &ws, None, &recipe_bits(bits)).unwrap();
        }
        assert_eq!(cache.len(), 7);
        assert_eq!(cache.evictions(), 0);
    }

    #[test]
    fn evicted_preps_stay_alive_through_arcs() {
        let cache = PreparedCache::new();
        cache.set_capacity(1);
        let spec = fake_spec();
        let ws = fake_ws(11);
        let held = cache.get_or_prepare(&spec, &ws, None, &recipe_bits(4)).unwrap();
        cache.get_or_prepare(&spec, &ws, None, &recipe_bits(5)).unwrap();
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.evictions(), 1);
        // the evicted prep is still usable by its holder
        assert_eq!(held.layers.len(), 1);
        // and re-requesting it is an honest miss, producing a new prep
        let again = cache.get_or_prepare(&spec, &ws, None, &recipe_bits(4)).unwrap();
        assert!(!Arc::ptr_eq(&held, &again));
    }

    #[test]
    fn concurrent_cold_key_prepares_once() {
        let cache = Arc::new(PreparedCache::new());
        let spec = Arc::new(fake_spec());
        let ws = Arc::new(fake_ws(3));
        let recipe = QuantRecipe::uniform(&QuantConfig::weights_only(4, ClipMethod::Kl, 0.05));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let (cache, spec, ws, recipe) =
                (cache.clone(), spec.clone(), ws.clone(), recipe.clone());
            handles.push(std::thread::spawn(move || {
                cache.get_or_prepare(&spec, &ws, None, &recipe).unwrap()
            }));
        }
        let preps: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for p in &preps[1..] {
            assert!(Arc::ptr_eq(&preps[0], p));
        }
        assert_eq!(cache.misses(), 1, "exactly one prepare ran");
        assert_eq!(cache.hits(), 7);
    }
}
