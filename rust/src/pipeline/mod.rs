//! The quantization pipeline: `QuantConfig` → per-layer clip/OCS plan →
//! the exact runtime inputs the AOT artifact consumes.
//!
//! This is where the paper's §5 experimental recipe lives:
//!
//! 1. **Weight OCS** (optional, §3.4): split `ceil(r * C)` channels,
//!    iteratively targeting the largest |w|. Quantization-aware splitting
//!    needs the final grid step, which itself depends on the post-split
//!    distribution — resolved with two passes (naive split → threshold →
//!    QA split on that grid → re-threshold).
//! 2. **Weight clipping + quantization**: threshold from the configured
//!    [`ClipMethod`] over the post-OCS histogram, then fake-quantize onto
//!    the Eq. 1 grid. Weights ship to the artifact already quantized.
//! 3. **Activation side**: clip threshold from [`calib`] histograms →
//!    runtime `(adelta, aqmax)` scalars; activation OCS (§5.3) splits the
//!    calibration-ranked outlier channels via `channel_dup` scales.
//!
//! The paper's Table-2 "OCS + Best Clip" recipe is just a `QuantConfig`
//! with both `ocs_ratio > 0` and a non-`None` `w_clip`.

pub mod config;

pub use config::{PerfConfig, QuantConfig, ServeConfig};

use anyhow::{bail, Context, Result};

use crate::calib::Calibration;
use crate::model::store::WeightStore;
use crate::model::{LayerKind, LayerSpec, ModelSpec};
use crate::ocs::{self, plan, OcsTarget, SplitMode};
use crate::quant::{fake_quant_tensor, QuantSpec};
use crate::runtime::{Input, Inputs};
use crate::stats::{Histogram, DEFAULT_BINS};
use crate::tensor::{TensorF, TensorI};

/// One quantized layer, fully prepared for execution.
#[derive(Debug, Clone)]
pub struct LayerPrep {
    pub name: String,
    /// Padded + OCS-split + fake-quantized weight.
    pub w: TensorF,
    pub b: TensorF,
    pub idx: TensorI,
    pub dscale: TensorF,
    pub dbias: TensorF,
    pub adelta: f32,
    pub aqmax: f32,
    /// Diagnostics (EXPERIMENTS.md, Table 5, Figure 1).
    pub w_threshold: f32,
    pub a_threshold: f32,
    pub cin: usize,
    pub active: usize,
    pub splits: usize,
}

/// A model with all runtime inputs resolved.
#[derive(Debug, Clone)]
pub struct PreparedModel {
    pub model: String,
    pub config: QuantConfig,
    pub layers: Vec<LayerPrep>,
    /// Unquantized layers: (name, W, Some(b)).
    pub raw: Vec<(String, TensorF, Option<TensorF>)>,
}

impl PreparedModel {
    /// Insert every model input (weights + hooks) into `inputs`; the
    /// caller adds the data tensor ("x"/"tokens").
    pub fn insert_inputs(&self, inputs: &mut Inputs) {
        for (name, w, b) in &self.raw {
            inputs.insert(format!("{name}.W"), Input::F32(w.clone()));
            if let Some(b) = b {
                inputs.insert(format!("{name}.b"), Input::F32(b.clone()));
            }
        }
        for l in &self.layers {
            inputs.insert(format!("{}.W", l.name), Input::F32(l.w.clone()));
            inputs.insert(format!("{}.b", l.name), Input::F32(l.b.clone()));
            inputs.insert(format!("{}.idx", l.name), Input::I32(l.idx.clone()));
            inputs.insert(format!("{}.dscale", l.name), Input::F32(l.dscale.clone()));
            inputs.insert(format!("{}.dbias", l.name), Input::F32(l.dbias.clone()));
            inputs.insert(format!("{}.adelta", l.name), Input::scalar_f32(l.adelta));
            inputs.insert(format!("{}.aqmax", l.name), Input::scalar_f32(l.aqmax));
        }
    }

    /// Relative weight-size overhead over the quantized layers (Table 5:
    /// "Rel. Weight Size"): extra channel slots / original channels,
    /// weighted by weight elements per channel.
    pub fn weight_overhead(&self) -> f64 {
        let mut base = 0usize;
        let mut extra = 0usize;
        for l in &self.layers {
            let wpc = l.w.len() / l.idx.len().max(1); // elements per channel slot
            base += wpc * l.cin;
            extra += wpc * (l.active - l.cin);
        }
        1.0 + extra as f64 / base.max(1) as f64
    }

    pub fn total_splits(&self) -> usize {
        self.layers.iter().map(|l| l.splits).sum()
    }
}

/// Histogram over the *active* channels of an expanded weight (padded
/// zero slots would pollute the distribution). Streams each channel's
/// strided runs straight into the histogram — no per-channel `Vec`.
pub fn active_weight_hist(hooks: &ocs::OcsHooks, cin_axis: usize) -> Histogram {
    let mut hist = Histogram::new(DEFAULT_BINS, hooks.w_expanded.max_abs().max(1e-9));
    for s in 0..hooks.active {
        for run in hooks.w_expanded.axis_chunks(cin_axis, s).expect("active slot") {
            hist.observe_all(run);
        }
    }
    hist
}

/// Prepare one quantizable layer.
fn prepare_layer(
    layer: &LayerSpec,
    ws: &WeightStore,
    calib: Option<&Calibration>,
    cfg: &QuantConfig,
) -> Result<LayerPrep> {
    let w = ws.weight(&layer.name)?;
    let b = ws.bias(&layer.name)?;
    let axis = layer.w_cin_axis;
    let cin_pad = layer.cin_pad;

    let w_spec = cfg.w_bits.map(QuantSpec::new);
    let a_spec = cfg.a_bits.map(QuantSpec::new);

    // ---- OCS ---------------------------------------------------------------
    let hooks = match (cfg.ocs_target, cfg.ocs_ratio > 0.0) {
        (OcsTarget::Weights, true) if w_spec.is_some() => {
            let n = plan::splits_for(layer.cin, cfg.ocs_ratio, cin_pad);
            // pass 1 (naive) to discover the post-split grid
            let h0 = ocs::weight_ocs(w, axis, cin_pad, n, SplitMode::Naive, 0.0)?;
            match cfg.split_mode {
                SplitMode::Naive => h0,
                SplitMode::QuantAware => {
                    let spec = w_spec.unwrap();
                    let thr0 = cfg.w_clip.threshold(&active_weight_hist(&h0, axis), spec);
                    let delta0 = spec.delta(thr0);
                    ocs::weight_ocs(w, axis, cin_pad, n, SplitMode::QuantAware, delta0)?
                }
            }
        }
        (OcsTarget::Activations, true) if a_spec.is_some() => {
            let calib = calib.context("activation OCS requires calibration")?;
            let lc = calib.layer(&layer.name)?;
            let n = plan::splits_for(layer.cin, cfg.ocs_ratio, cin_pad);
            let channels = crate::calib::top_k_channels(&lc.outlier_counts, n);
            // activation grid after splitting: split channels halve, so
            // the no-clip threshold is the post-split channel max
            let spec = a_spec.unwrap();
            let post_max = post_split_max(&lc.channel_max, &channels);
            let adelta = spec.delta(post_max.max(1e-12));
            ocs::activation_ocs(w, axis, cin_pad, &channels, cfg.split_mode, adelta)?
        }
        _ => ocs::identity_hooks(w, axis, cin_pad)?,
    };

    // ---- weight quantization -------------------------------------------------
    let (wq, w_threshold) = match w_spec {
        Some(spec) => {
            let hist = active_weight_hist(&hooks, axis);
            let thr = cfg.w_clip.threshold(&hist, spec);
            (fake_quant_tensor(&hooks.w_expanded, thr, spec), thr)
        }
        None => (hooks.w_expanded.clone(), 0.0),
    };

    // ---- activation quantization ----------------------------------------------
    let (adelta, aqmax, a_threshold) = match a_spec {
        Some(spec) => {
            let calib = calib.context("activation quantization requires calibration")?;
            let lc = calib.layer(&layer.name)?;
            let thr = if cfg.ocs_target == OcsTarget::Activations && cfg.ocs_ratio > 0.0 {
                // paper §5.3: activation OCS is evaluated without extra
                // clipping; the grid covers the post-split max
                let channels: Vec<usize> = hooks.splits.iter().map(|&(s, _)| s).collect();
                post_split_max(&lc.channel_max, &channels)
            } else {
                cfg.a_clip.threshold(&lc.hist, spec)
            };
            (spec.delta(thr.max(1e-12)), spec.qmax(), thr)
        }
        None => (1.0, -1.0, 0.0),
    };

    Ok(LayerPrep {
        name: layer.name.clone(),
        w: wq,
        b: b.clone(),
        idx: hooks.idx.clone(),
        dscale: hooks.dscale.clone(),
        dbias: hooks.dbias.clone(),
        adelta,
        aqmax,
        w_threshold,
        a_threshold,
        cin: layer.cin,
        active: hooks.active,
        splits: hooks.splits.len(),
    })
}

/// Max |x| per layer after halving the listed channels.
fn post_split_max(channel_max: &[f32], split: &[usize]) -> f32 {
    let mut m = 0.0f32;
    for (c, &v) in channel_max.iter().enumerate() {
        let v = if split.contains(&c) { v * 0.5 } else { v };
        m = m.max(v);
    }
    m
}

/// Prepare a whole model under `cfg`. `calib` is required iff
/// activations are quantized (or activation-OCS is requested).
pub fn prepare(
    spec: &ModelSpec,
    ws: &WeightStore,
    calib: Option<&Calibration>,
    cfg: &QuantConfig,
) -> Result<PreparedModel> {
    if cfg.a_bits.is_some() && calib.is_none() {
        bail!("QuantConfig quantizes activations but no calibration given");
    }
    let mut layers = Vec::new();
    let mut raw = Vec::new();
    for layer in &spec.layers {
        if layer.quantized {
            layers.push(prepare_layer(layer, ws, calib, cfg)?);
        } else {
            let w = ws.weight(&layer.name)?.clone();
            let b = match layer.kind {
                LayerKind::Embed => None,
                _ => Some(ws.bias(&layer.name)?.clone()),
            };
            raw.push((layer.name.clone(), w, b));
        }
    }
    Ok(PreparedModel {
        model: spec.name.clone(),
        config: cfg.clone(),
        layers,
        raw,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clip::ClipMethod;
    use crate::util::rng::Rng;

    fn fake_layer() -> LayerSpec {
        LayerSpec {
            name: "f1".into(),
            kind: LayerKind::Fc,
            cin: 8,
            cin_pad: 10,
            cout: 4,
            ksize: 0,
            stride: 1,
            quantized: true,
            w_cin_axis: 0,
            w_shape: vec![8, 4],
            w_shape_pad: vec![10, 4],
        }
    }

    fn fake_ws(seed: u64) -> WeightStore {
        let mut rng = Rng::new(seed);
        let mut w = rng.normal_vec(32);
        w[5 * 4] = 12.0; // outlier in channel 5
        WeightStore::from_leaves(vec![
            ("f1.W".into(), TensorF::from_vec(&[8, 4], w).unwrap()),
            ("f1.b".into(), TensorF::zeros(&[4])),
        ])
    }

    #[test]
    fn float_config_is_passthrough() {
        let cfg = QuantConfig::float();
        let prep = prepare_layer(&fake_layer(), &fake_ws(0), None, &cfg).unwrap();
        assert_eq!(prep.aqmax, -1.0);
        assert_eq!(prep.splits, 0);
        assert_eq!(prep.w.shape(), &[10, 4]);
        // padded rows are zero, original rows intact
        let ws = fake_ws(0);
        let orig = ws.weight("f1").unwrap();
        assert_eq!(&prep.w.data()[..32], orig.data());
        assert_eq!(&prep.w.data()[32..], &[0.0; 8]);
    }

    #[test]
    fn weight_quant_snaps_to_grid() {
        let cfg = QuantConfig::weights_only(4, ClipMethod::None, 0.0);
        let prep = prepare_layer(&fake_layer(), &fake_ws(1), None, &cfg).unwrap();
        let delta = prep.w_threshold / 7.0;
        for &v in prep.w.data() {
            let k = v / delta;
            assert!((k - k.round()).abs() < 1e-4, "{v} not on grid {delta}");
        }
    }

    #[test]
    fn weight_ocs_splits_outlier_and_reduces_threshold() {
        let no_ocs = QuantConfig::weights_only(4, ClipMethod::None, 0.0);
        let ocs = QuantConfig::weights_only(4, ClipMethod::None, 0.13); // ceil(.13*8)=2
        let p0 = prepare_layer(&fake_layer(), &fake_ws(2), None, &no_ocs).unwrap();
        let p1 = prepare_layer(&fake_layer(), &fake_ws(2), None, &ocs).unwrap();
        assert_eq!(p1.splits, 2);
        assert_eq!(p1.active, 10);
        assert!(
            p1.w_threshold < p0.w_threshold * 0.6,
            "threshold {} !< {}",
            p1.w_threshold,
            p0.w_threshold
        );
        // duplicated slots are live
        assert_eq!(p1.dscale.data()[8], 1.0);
        assert_eq!(p1.dscale.data()[9], 1.0);
    }

    #[test]
    fn prepared_inputs_cover_signature() {
        let cfg = QuantConfig::weights_only(5, ClipMethod::Mse, 0.01);
        let prep = PreparedModel {
            model: "fake".into(),
            config: cfg,
            layers: vec![prepare_layer(
                &fake_layer(),
                &fake_ws(3),
                None,
                &QuantConfig::weights_only(5, ClipMethod::Mse, 0.01),
            )
            .unwrap()],
            raw: vec![("stem".into(), TensorF::zeros(&[3, 3, 3, 8]), Some(TensorF::zeros(&[8])))],
        };
        let mut inputs: Inputs = Default::default();
        prep.insert_inputs(&mut inputs);
        for key in [
            "stem.W", "stem.b", "f1.W", "f1.b", "f1.idx", "f1.dscale", "f1.dbias",
            "f1.adelta", "f1.aqmax",
        ] {
            assert!(inputs.contains_key(key), "missing {key}");
        }
    }

    #[test]
    fn overhead_counts_extra_channels() {
        let prep_l = prepare_layer(
            &fake_layer(),
            &fake_ws(4),
            None,
            &QuantConfig::weights_only(4, ClipMethod::None, 0.25), // 2 splits
        )
        .unwrap();
        let pm = PreparedModel {
            model: "fake".into(),
            config: QuantConfig::float(),
            layers: vec![prep_l],
            raw: vec![],
        };
        let ov = pm.weight_overhead();
        assert!((ov - 1.25).abs() < 1e-6, "overhead {ov}");
    }

    #[test]
    fn post_split_max_halves_selected() {
        assert_eq!(post_split_max(&[1.0, 8.0, 3.0], &[1]), 4.0);
        assert_eq!(post_split_max(&[1.0, 8.0, 3.0], &[]), 8.0);
    }
}
