//! The quantization pipeline: a [`QuantRecipe`] → per-layer resolved
//! policies → composable per-layer passes → the exact runtime inputs the
//! AOT artifact consumes.
//!
//! ## Recipes
//!
//! The paper's §5 experimental recipe ("OCS + Best Clip") was originally
//! one flat [`QuantConfig`] applied uniformly to every layer. The API is
//! now built around [`QuantRecipe`]: model-wide defaults plus ordered
//! per-layer overrides (layer-name glob / [`crate::model::LayerKind`] /
//! first-last position), resolved to one [`recipe::LayerRecipe`] per
//! layer. `QuantConfig` remains as the thin uniform constructor — it
//! lowers via [`QuantRecipe::uniform`] and [`prepare`] stays
//! bit-identical to the pre-recipe pipeline for uniform configs. Clip
//! thresholds go through [`crate::clip::ClipSpec`], so custom
//! [`crate::clip::ClipStrategy`] implementations participate without
//! touching `clip/`.
//!
//! ## Passes
//!
//! [`prepare_recipe`] runs three composable passes per quantized layer
//! over a shared [`LayerCtx`]:
//!
//! 1. [`pass_ocs`] (optional, §3.4): split `ceil(r * C)` channels,
//!    iteratively targeting the largest |w|. Quantization-aware
//!    splitting needs the final grid step, which itself depends on the
//!    post-split distribution — resolved with two passes (naive split →
//!    threshold → QA split on that grid → re-threshold). Activation OCS
//!    (§5.3) instead splits the calibration-ranked outlier channels via
//!    `channel_dup` scales; the selected channels are recorded on the
//!    ctx as a mark vector.
//! 2. [`pass_weight_quant`]: threshold from the resolved clip strategy
//!    over the post-OCS histogram, then fake-quantize onto the Eq. 1
//!    grid. Weights ship to the artifact already quantized.
//! 3. [`pass_activation`]: clip threshold from [`crate::calib`]
//!    histograms →
//!    runtime `(adelta, aqmax)` scalars; under activation OCS the grid
//!    covers the post-split channel max (paper §5.3: no extra clipping).
//!
//! The paper's Table-2 "OCS + Best Clip" recipe is just a uniform
//! recipe with both `ocs_ratio > 0` and a non-`None` `w_clip`; mixed
//! precision, per-layer OCS ratios, and skip-first/last policies are
//! one override away. See `pipeline/README.md` for matching,
//! fingerprinting, cache, and hot-swap semantics.
//!
//! ## Caching
//!
//! Preparation is memoizable: a resolved recipe has a stable
//! [`QuantRecipe::fingerprint`], and [`prepare_cached`] routes through
//! the process-wide [`PreparedCache`] so all serve workers share one
//! prep per distinct (model, recipe, inputs); table sweeps get the same
//! sharing from a ctx-scoped instance owned by `tables::TableCtx`.

pub mod cache;
pub mod config;
pub mod recipe;

pub use cache::PreparedCache;
pub use config::{PerfConfig, QuantConfig, ServeBackend, ServeConfig, TenantSpec};
pub use recipe::{LayerMatch, LayerOverride, LayerPolicy, LayerPos, LayerRecipe, QuantRecipe};

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::calib::Calibration;
use crate::model::store::WeightStore;
use crate::model::{LayerKind, LayerSpec, ModelSpec};
use crate::ocs::{self, plan, OcsTarget, SplitMode};
use crate::quant::{fake_quant_tensor, QuantSpec};
use crate::runtime::{Input, Inputs};
use crate::stats::{Histogram, DEFAULT_BINS};
use crate::tensor::{TensorF, TensorI};

/// One quantized layer, fully prepared for execution.
#[derive(Debug, Clone)]
pub struct LayerPrep {
    pub name: String,
    /// Padded + OCS-split + fake-quantized weight.
    pub w: TensorF,
    pub b: TensorF,
    pub idx: TensorI,
    pub dscale: TensorF,
    pub dbias: TensorF,
    pub adelta: f32,
    pub aqmax: f32,
    /// Diagnostics (EXPERIMENTS.md, Table 5, Figure 1).
    pub w_threshold: f32,
    pub a_threshold: f32,
    pub cin: usize,
    pub active: usize,
    pub splits: usize,
}

/// A model with all runtime inputs resolved.
#[derive(Debug, Clone)]
pub struct PreparedModel {
    pub model: String,
    /// The recipe this prep was resolved from (uniform for plain
    /// [`QuantConfig`] call sites).
    pub recipe: QuantRecipe,
    pub layers: Vec<LayerPrep>,
    /// Unquantized layers: (name, W, Some(b)).
    pub raw: Vec<(String, TensorF, Option<TensorF>)>,
}

impl PreparedModel {
    /// Insert every model input (weights + hooks) into `inputs`; the
    /// caller adds the data tensor ("x"/"tokens").
    pub fn insert_inputs(&self, inputs: &mut Inputs) {
        for (name, w, b) in &self.raw {
            inputs.insert(format!("{name}.W"), Input::F32(w.clone()));
            if let Some(b) = b {
                inputs.insert(format!("{name}.b"), Input::F32(b.clone()));
            }
        }
        for l in &self.layers {
            inputs.insert(format!("{}.W", l.name), Input::F32(l.w.clone()));
            inputs.insert(format!("{}.b", l.name), Input::F32(l.b.clone()));
            inputs.insert(format!("{}.idx", l.name), Input::I32(l.idx.clone()));
            inputs.insert(format!("{}.dscale", l.name), Input::F32(l.dscale.clone()));
            inputs.insert(format!("{}.dbias", l.name), Input::F32(l.dbias.clone()));
            inputs.insert(format!("{}.adelta", l.name), Input::scalar_f32(l.adelta));
            inputs.insert(format!("{}.aqmax", l.name), Input::scalar_f32(l.aqmax));
        }
    }

    /// Relative weight-size overhead over the quantized layers (Table 5:
    /// "Rel. Weight Size"): extra channel slots / original channels,
    /// weighted by weight elements per channel.
    pub fn weight_overhead(&self) -> f64 {
        let mut base = 0usize;
        let mut extra = 0usize;
        for l in &self.layers {
            let wpc = l.w.len() / l.idx.len().max(1); // elements per channel slot
            base += wpc * l.cin;
            extra += wpc * (l.active - l.cin);
        }
        1.0 + extra as f64 / base.max(1) as f64
    }

    pub fn total_splits(&self) -> usize {
        self.layers.iter().map(|l| l.splits).sum()
    }
}

/// Histogram over the *active* channels of an expanded weight (padded
/// zero slots would pollute the distribution). Streams each channel's
/// strided runs straight into the histogram — no per-channel `Vec`.
pub fn active_weight_hist(hooks: &ocs::OcsHooks, cin_axis: usize) -> Histogram {
    let mut hist = Histogram::new(DEFAULT_BINS, hooks.w_expanded.max_abs().max(1e-9));
    for s in 0..hooks.active {
        for run in hooks.w_expanded.axis_chunks(cin_axis, s).expect("active slot") {
            hist.observe_all(run);
        }
    }
    hist
}

/// Shared state the per-layer passes read and write: the resolved
/// policy, the layer's tensors, and every intermediate the passes hand
/// to each other (OCS hooks, split marks, quantized weight, activation
/// grid). [`LayerCtx::finish`] folds it into a [`LayerPrep`].
pub struct LayerCtx<'a> {
    pub layer: &'a LayerSpec,
    pub rc: &'a LayerRecipe,
    calib: Option<&'a Calibration>,
    w: &'a TensorF,
    b: &'a TensorF,
    /// Set by [`pass_ocs`].
    hooks: Option<ocs::OcsHooks>,
    /// Which original channels were split (activation OCS): one flag per
    /// calibration channel, so downstream max scans are O(C) instead of
    /// the old O(C×S) `contains` walk.
    split_marks: Vec<bool>,
    /// Set by [`pass_weight_quant`].
    wq: Option<TensorF>,
    w_threshold: f32,
    /// `(adelta, aqmax, a_threshold)`, set by [`pass_activation`]
    /// (`(1.0, -1.0, 0.0)` when activations stay float).
    a_grid: Option<(f32, f32, f32)>,
}

impl<'a> LayerCtx<'a> {
    pub fn new(
        layer: &'a LayerSpec,
        ws: &'a WeightStore,
        calib: Option<&'a Calibration>,
        rc: &'a LayerRecipe,
    ) -> Result<LayerCtx<'a>> {
        Ok(LayerCtx {
            layer,
            rc,
            calib,
            w: ws.weight(&layer.name)?,
            b: ws.bias(&layer.name)?,
            hooks: None,
            split_marks: Vec::new(),
            wq: None,
            w_threshold: 0.0,
            a_grid: None,
        })
    }

    fn w_spec(&self) -> Option<QuantSpec> {
        self.rc.w_bits.map(QuantSpec::new)
    }

    fn a_spec(&self) -> Option<QuantSpec> {
        self.rc.a_bits.map(QuantSpec::new)
    }

    fn hooks(&self) -> Result<&ocs::OcsHooks> {
        self.hooks.as_ref().context("pass_ocs must run first")
    }

    /// Consume the ctx into the runtime-ready layer prep. All three
    /// passes must have run (enforced — a skipped pass is an error, not
    /// a silently-float layer).
    pub fn finish(self) -> Result<LayerPrep> {
        let hooks = self.hooks.context("pass_ocs did not run")?;
        let wq = self.wq.context("pass_weight_quant did not run")?;
        let (adelta, aqmax, a_threshold) = self.a_grid.context("pass_activation did not run")?;
        Ok(LayerPrep {
            name: self.layer.name.clone(),
            w: wq,
            b: self.b.clone(),
            idx: hooks.idx.clone(),
            dscale: hooks.dscale.clone(),
            dbias: hooks.dbias.clone(),
            adelta,
            aqmax,
            w_threshold: self.w_threshold,
            a_threshold,
            cin: self.layer.cin,
            active: hooks.active,
            splits: hooks.splits.len(),
        })
    }
}

/// Mark vector over `len` channels with the listed indices set
/// (out-of-range indices — expanded slots — are ignored).
fn mark_channels<I: IntoIterator<Item = usize>>(indices: I, len: usize) -> Vec<bool> {
    let mut marks = vec![false; len];
    for i in indices {
        if i < len {
            marks[i] = true;
        }
    }
    marks
}

/// Max |x| per layer after halving the marked channels. O(C) over the
/// [`LayerCtx`] mark vector (the pre-refactor list scan was O(C×S)).
fn post_split_max(channel_max: &[f32], split_marks: &[bool]) -> f32 {
    debug_assert_eq!(channel_max.len(), split_marks.len());
    let mut m = 0.0f32;
    for (&v, &split) in channel_max.iter().zip(split_marks) {
        m = m.max(if split { v * 0.5 } else { v });
    }
    m
}

/// Pass 1 — OCS. Builds the layer's [`ocs::OcsHooks`] (identity hooks
/// when OCS is off or inapplicable) and, for activation OCS, the
/// split-channel mark vector the activation pass reuses.
pub fn pass_ocs(cx: &mut LayerCtx) -> Result<()> {
    let layer = cx.layer;
    let rc = cx.rc;
    let axis = layer.w_cin_axis;
    let cin_pad = layer.cin_pad;
    let hooks = match (rc.ocs_target, rc.ocs_ratio > 0.0) {
        (OcsTarget::Weights, true) if cx.w_spec().is_some() => {
            let n = plan::splits_for(layer.cin, rc.ocs_ratio, cin_pad);
            // pass 1 (naive) to discover the post-split grid
            let h0 = ocs::weight_ocs(cx.w, axis, cin_pad, n, SplitMode::Naive, 0.0)?;
            match rc.split_mode {
                SplitMode::Naive => h0,
                SplitMode::QuantAware => {
                    let spec = cx.w_spec().unwrap();
                    let thr0 = rc.w_clip.threshold(&active_weight_hist(&h0, axis), spec);
                    let delta0 = spec.delta(thr0);
                    ocs::weight_ocs(cx.w, axis, cin_pad, n, SplitMode::QuantAware, delta0)?
                }
            }
        }
        (OcsTarget::Activations, true) if cx.a_spec().is_some() => {
            let calib = cx.calib.context("activation OCS requires calibration")?;
            let lc = calib.layer(&layer.name)?;
            let n = plan::splits_for(layer.cin, rc.ocs_ratio, cin_pad);
            let channels = crate::calib::top_k_channels(&lc.outlier_counts, n);
            // activation grid after splitting: split channels halve, so
            // the no-clip threshold is the post-split channel max
            let spec = cx.a_spec().unwrap();
            let marks = mark_channels(channels.iter().copied(), lc.channel_max.len());
            let post_max = post_split_max(&lc.channel_max, &marks);
            let adelta = spec.delta(post_max.max(1e-12));
            let hooks =
                ocs::activation_ocs(cx.w, axis, cin_pad, &channels, rc.split_mode, adelta)?;
            // the performed splits (src slots) drive the final grid
            cx.split_marks = mark_channels(
                hooks.splits.iter().map(|&(s, _)| s),
                lc.channel_max.len(),
            );
            hooks
        }
        _ => ocs::identity_hooks(cx.w, axis, cin_pad)?,
    };
    cx.hooks = Some(hooks);
    Ok(())
}

/// Pass 2 — weight clip + fake-quantization onto the Eq. 1 grid
/// (pass-through clone when weights stay float).
pub fn pass_weight_quant(cx: &mut LayerCtx) -> Result<()> {
    let (wq, w_threshold) = match cx.w_spec() {
        Some(spec) => {
            let hooks = cx.hooks()?;
            let hist = active_weight_hist(hooks, cx.layer.w_cin_axis);
            let thr = cx.rc.w_clip.threshold(&hist, spec);
            (fake_quant_tensor(&hooks.w_expanded, thr, spec), thr)
        }
        None => (cx.hooks()?.w_expanded.clone(), 0.0),
    };
    cx.wq = Some(wq);
    cx.w_threshold = w_threshold;
    Ok(())
}

/// Pass 3 — activation grid: clip threshold from calibration (or the
/// post-split channel max under activation OCS) → `(adelta, aqmax)`.
pub fn pass_activation(cx: &mut LayerCtx) -> Result<()> {
    let grid = match cx.a_spec() {
        Some(spec) => {
            let calib = cx
                .calib
                .context("activation quantization requires calibration")?;
            let lc = calib.layer(&cx.layer.name)?;
            let thr = if cx.rc.ocs_target == OcsTarget::Activations && cx.rc.ocs_ratio > 0.0 {
                // paper §5.3: activation OCS is evaluated without extra
                // clipping; the grid covers the post-split max
                post_split_max(&lc.channel_max, &cx.split_marks)
            } else {
                cx.rc.a_clip.threshold(&lc.hist, spec)
            };
            (spec.delta(thr.max(1e-12)), spec.qmax(), thr)
        }
        None => (1.0, -1.0, 0.0),
    };
    cx.a_grid = Some(grid);
    Ok(())
}

/// Prepare one quantizable layer under its resolved policy: the three
/// passes in order, then fold.
fn prepare_layer(
    layer: &LayerSpec,
    ws: &WeightStore,
    calib: Option<&Calibration>,
    rc: &LayerRecipe,
) -> Result<LayerPrep> {
    let mut cx = LayerCtx::new(layer, ws, calib, rc)?;
    pass_ocs(&mut cx)?;
    pass_weight_quant(&mut cx)?;
    pass_activation(&mut cx)?;
    cx.finish()
}

/// Prepare a whole model under `recipe`. `calib` is required iff some
/// resolved layer quantizes activations (or requests activation OCS).
///
/// A layer the recipe skips (`quantize = false`) still yields a
/// [`LayerPrep`] — the artifact consumes its hook inputs regardless —
/// but with identity hooks and quantization fully bypassed, exactly as
/// a float config would produce.
pub fn prepare_recipe(
    spec: &ModelSpec,
    ws: &WeightStore,
    calib: Option<&Calibration>,
    recipe: &QuantRecipe,
) -> Result<PreparedModel> {
    let first = spec.quantized_layers().next().map(|l| l.name.clone());
    let last = spec.quantized_layers().last().map(|l| l.name.clone());
    let mut layers = Vec::new();
    let mut raw = Vec::new();
    for layer in &spec.layers {
        if layer.quantized {
            let is_first = first.as_deref() == Some(layer.name.as_str());
            let is_last = last.as_deref() == Some(layer.name.as_str());
            let rc = recipe.resolve(layer, is_first, is_last);
            let rc = if rc.quantize { rc } else { LayerRecipe::skip() };
            if rc.needs_calibration() && calib.is_none() {
                bail!(
                    "recipe quantizes activations of layer '{}' but no calibration given",
                    layer.name
                );
            }
            layers.push(prepare_layer(layer, ws, calib, &rc)?);
        } else {
            let w = ws.weight(&layer.name)?.clone();
            let b = match layer.kind {
                LayerKind::Embed => None,
                _ => Some(ws.bias(&layer.name)?.clone()),
            };
            raw.push((layer.name.clone(), w, b));
        }
    }
    Ok(PreparedModel {
        model: spec.name.clone(),
        recipe: recipe.clone(),
        layers,
        raw,
    })
}

/// Prepare under a flat uniform [`QuantConfig`] — the thin compat
/// constructor. Bit-identical to [`prepare_recipe`] on
/// [`QuantRecipe::uniform`] (it *is* that call).
pub fn prepare(
    spec: &ModelSpec,
    ws: &WeightStore,
    calib: Option<&Calibration>,
    cfg: &QuantConfig,
) -> Result<PreparedModel> {
    if cfg.a_bits.is_some() && calib.is_none() {
        bail!("QuantConfig quantizes activations but no calibration given");
    }
    prepare_recipe(spec, ws, calib, &QuantRecipe::uniform(cfg))
}

/// [`prepare_recipe`] through the process-wide [`PreparedCache`]: one
/// prep per distinct (model, recipe fingerprint, weights+calibration),
/// shared via `Arc` across table sweeps and serve workers.
pub fn prepare_cached(
    spec: &ModelSpec,
    ws: &WeightStore,
    calib: Option<&Calibration>,
    recipe: &QuantRecipe,
) -> Result<Arc<PreparedModel>> {
    PreparedCache::global().get_or_prepare(spec, ws, calib, recipe)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib::LayerCalib;
    use crate::clip::ClipMethod;
    use crate::util::rng::Rng;

    fn fake_layer() -> LayerSpec {
        LayerSpec {
            name: "f1".into(),
            kind: LayerKind::Fc,
            cin: 8,
            cin_pad: 10,
            cout: 4,
            ksize: 0,
            stride: 1,
            quantized: true,
            w_cin_axis: 0,
            w_shape: vec![8, 4],
            w_shape_pad: vec![10, 4],
        }
    }

    fn fake_ws(seed: u64) -> WeightStore {
        let mut rng = Rng::new(seed);
        let mut w = rng.normal_vec(32);
        w[5 * 4] = 12.0; // outlier in channel 5
        WeightStore::from_leaves(vec![
            ("f1.W".into(), TensorF::from_vec(&[8, 4], w).unwrap()),
            ("f1.b".into(), TensorF::zeros(&[4])),
        ])
    }

    /// Resolve a uniform config against the fake layer (what the old
    /// flat-config `prepare_layer` consumed).
    fn rc_of(cfg: &QuantConfig) -> LayerRecipe {
        QuantRecipe::uniform(cfg).resolve(&fake_layer(), false, false)
    }

    fn prep_one(cfg: &QuantConfig, ws: &WeightStore, calib: Option<&Calibration>) -> LayerPrep {
        prepare_layer(&fake_layer(), ws, calib, &rc_of(cfg)).unwrap()
    }

    #[test]
    fn float_config_is_passthrough() {
        let prep = prep_one(&QuantConfig::float(), &fake_ws(0), None);
        assert_eq!(prep.aqmax, -1.0);
        assert_eq!(prep.splits, 0);
        assert_eq!(prep.w.shape(), &[10, 4]);
        // padded rows are zero, original rows intact
        let ws = fake_ws(0);
        let orig = ws.weight("f1").unwrap();
        assert_eq!(&prep.w.data()[..32], orig.data());
        assert_eq!(&prep.w.data()[32..], &[0.0; 8]);
    }

    #[test]
    fn weight_quant_snaps_to_grid() {
        let cfg = QuantConfig::weights_only(4, ClipMethod::None, 0.0);
        let prep = prep_one(&cfg, &fake_ws(1), None);
        let delta = prep.w_threshold / 7.0;
        for &v in prep.w.data() {
            let k = v / delta;
            assert!((k - k.round()).abs() < 1e-4, "{v} not on grid {delta}");
        }
    }

    #[test]
    fn weight_ocs_splits_outlier_and_reduces_threshold() {
        let no_ocs = QuantConfig::weights_only(4, ClipMethod::None, 0.0);
        let ocs = QuantConfig::weights_only(4, ClipMethod::None, 0.13); // ceil(.13*8)=2
        let p0 = prep_one(&no_ocs, &fake_ws(2), None);
        let p1 = prep_one(&ocs, &fake_ws(2), None);
        assert_eq!(p1.splits, 2);
        assert_eq!(p1.active, 10);
        assert!(
            p1.w_threshold < p0.w_threshold * 0.6,
            "threshold {} !< {}",
            p1.w_threshold,
            p0.w_threshold
        );
        // duplicated slots are live
        assert_eq!(p1.dscale.data()[8], 1.0);
        assert_eq!(p1.dscale.data()[9], 1.0);
    }

    #[test]
    fn prepared_inputs_cover_signature() {
        let cfg = QuantConfig::weights_only(5, ClipMethod::Mse, 0.01);
        let prep = PreparedModel {
            model: "fake".into(),
            recipe: QuantRecipe::uniform(&cfg),
            layers: vec![prep_one(&cfg, &fake_ws(3), None)],
            raw: vec![("stem".into(), TensorF::zeros(&[3, 3, 3, 8]), Some(TensorF::zeros(&[8])))],
        };
        let mut inputs: Inputs = Default::default();
        prep.insert_inputs(&mut inputs);
        for key in [
            "stem.W", "stem.b", "f1.W", "f1.b", "f1.idx", "f1.dscale", "f1.dbias",
            "f1.adelta", "f1.aqmax",
        ] {
            assert!(inputs.contains_key(key), "missing {key}");
        }
    }

    #[test]
    fn overhead_counts_extra_channels() {
        let prep_l = prep_one(
            &QuantConfig::weights_only(4, ClipMethod::None, 0.25), // 2 splits
            &fake_ws(4),
            None,
        );
        let pm = PreparedModel {
            model: "fake".into(),
            recipe: QuantRecipe::float(),
            layers: vec![prep_l],
            raw: vec![],
        };
        let ov = pm.weight_overhead();
        assert!((ov - 1.25).abs() < 1e-6, "overhead {ov}");
    }

    #[test]
    fn post_split_max_halves_marked() {
        assert_eq!(
            post_split_max(&[1.0, 8.0, 3.0], &mark_channels([1], 3)),
            4.0
        );
        assert_eq!(
            post_split_max(&[1.0, 8.0, 3.0], &mark_channels([], 3)),
            8.0
        );
        // out-of-range (expanded-slot) indices are ignored
        assert_eq!(
            post_split_max(&[1.0, 8.0, 3.0], &mark_channels([1, 9], 3)),
            4.0
        );
    }

    /// Synthetic calibration for the fake layer: channel 2 dominates the
    /// range, channels 2 and 5 have the most outliers.
    fn fake_calib() -> Calibration {
        let mut channel_max = vec![1.0f32; 8];
        channel_max[2] = 10.0;
        channel_max[5] = 1.0;
        let mut outlier_counts = vec![0u64; 8];
        outlier_counts[2] = 50;
        outlier_counts[5] = 20;
        let data: Vec<f32> = (0..4096).map(|i| (i % 100) as f32 * 0.1).collect();
        let mut layers = std::collections::BTreeMap::new();
        layers.insert(
            "f1".into(),
            LayerCalib {
                hist: Histogram::from_slice(&data, 256),
                channel_max,
                outlier_counts,
            },
        );
        Calibration { layers }
    }

    #[test]
    fn activation_ocs_prepares_with_post_split_grid() {
        // acts_only(4, ..., 0.25): 8-bit weights, 4-bit acts, activation
        // OCS splitting ceil(0.25 * 8) = 2 channels
        let cfg = QuantConfig::acts_only(4, ClipMethod::None, 0.25);
        let calib = fake_calib();
        let prep = prep_one(&cfg, &fake_ws(5), Some(&calib));
        assert_eq!(prep.splits, 2, "two outlier channels split");
        assert_eq!(prep.active, 10);
        // grid: channel 2 (max 10) halves to 5, everything else <= 1
        assert!((prep.a_threshold - 5.0).abs() < 1e-6, "{}", prep.a_threshold);
        let spec = QuantSpec::new(4);
        assert!((prep.adelta - spec.delta(5.0)).abs() < 1e-9);
        assert_eq!(prep.aqmax, spec.qmax());
        // the duplicated slots carry halved activation scales
        let halved = prep.dscale.data().iter().filter(|&&s| s == 0.5).count();
        assert!(halved >= 2, "split slots must halve: {:?}", prep.dscale.data());
        // weights still got their 8-bit treatment
        assert!(prep.w_threshold > 0.0);
    }

    #[test]
    fn activation_ocs_requires_calibration() {
        let cfg = QuantConfig::acts_only(4, ClipMethod::None, 0.1);
        let spec = ModelSpec {
            name: "fake".into(),
            dir: std::path::PathBuf::new(),
            pad_factor: 1.25,
            num_classes: 4,
            img_hw: 0,
            img_c: 0,
            vocab: 0,
            seq_len: 0,
            momentum: 0.9,
            layers: vec![fake_layer()],
            artifacts: Default::default(),
        };
        let err = prepare(&spec, &fake_ws(6), None, &cfg).unwrap_err();
        assert!(err.to_string().contains("calibration"), "{err:#}");
        // recipe path reports the same constraint per-layer
        let err2 =
            prepare_recipe(&spec, &fake_ws(6), None, &QuantRecipe::uniform(&cfg)).unwrap_err();
        assert!(err2.to_string().contains("f1"), "{err2:#}");
    }

    fn three_layer_spec() -> ModelSpec {
        let mut layers = Vec::new();
        for name in ["f1", "f2", "f3"] {
            let mut l = fake_layer();
            l.name = name.into();
            layers.push(l);
        }
        ModelSpec {
            name: "trio".into(),
            dir: std::path::PathBuf::new(),
            pad_factor: 1.25,
            num_classes: 4,
            img_hw: 0,
            img_c: 0,
            vocab: 0,
            seq_len: 0,
            momentum: 0.9,
            layers,
            artifacts: Default::default(),
        }
    }

    fn three_layer_ws(seed: u64) -> WeightStore {
        let mut rng = Rng::new(seed);
        let mut leaves = Vec::new();
        for name in ["f1", "f2", "f3"] {
            leaves.push((
                format!("{name}.W"),
                TensorF::from_vec(&[8, 4], rng.normal_vec(32)).unwrap(),
            ));
            leaves.push((format!("{name}.b"), TensorF::zeros(&[4])));
        }
        WeightStore::from_leaves(leaves)
    }

    #[test]
    fn mixed_precision_recipe_resolves_per_layer() {
        // 4-bit middle, 8-bit first/last — the classic mixed recipe
        let recipe =
            QuantRecipe::uniform(&QuantConfig::weights_only(4, ClipMethod::None, 0.0))
                .edge_w_bits(8);
        let spec = three_layer_spec();
        let ws = three_layer_ws(7);
        let prep = prepare_recipe(&spec, &ws, None, &recipe).unwrap();
        assert_eq!(prep.layers.len(), 3);
        // every weight sits on its layer's grid: qmax 127 for the edges,
        // 7 for the middle
        for (l, qmax) in prep.layers.iter().zip([127.0f32, 7.0, 127.0]) {
            let delta = l.w_threshold / qmax;
            for &v in l.w.data() {
                let k = v / delta;
                assert!(
                    (k - k.round()).abs() < 1e-3,
                    "{}: {v} not on the {qmax}-level grid",
                    l.name
                );
            }
        }
        // the middle layer's coarse grid must differ from the edges'
        let d_mid = prep.layers[1].w_threshold / 7.0;
        let d_edge = prep.layers[0].w_threshold / 127.0;
        assert!(d_mid > d_edge * 2.0, "4-bit grid must be coarser");
    }

    #[test]
    fn skip_override_keeps_layer_float_but_hooked() {
        let recipe = QuantRecipe::uniform(&QuantConfig::weights_only(4, ClipMethod::Mse, 0.1))
            .with_override(LayerMatch::name("f2"), LayerPolicy::skip());
        let spec = three_layer_spec();
        let ws = three_layer_ws(8);
        let prep = prepare_recipe(&spec, &ws, None, &recipe).unwrap();
        // the skipped layer still produces hook inputs (the artifact
        // needs them) but carries the original float weights, unsplit
        let f2 = &prep.layers[1];
        assert_eq!(f2.splits, 0);
        assert_eq!(f2.w_threshold, 0.0);
        assert_eq!(&f2.w.data()[..32], ws.weight("f2").unwrap().data());
        // its neighbours are quantized and OCS-split
        assert!(prep.layers[0].splits > 0);
        assert!(prep.layers[0].w_threshold > 0.0);
        let mut inputs: Inputs = Default::default();
        prep.insert_inputs(&mut inputs);
        assert!(inputs.contains_key("f2.idx"), "skipped layer keeps hooks");
    }

    #[test]
    fn uniform_recipe_prepares_bit_identical_to_config() {
        // the compat guarantee: QuantConfig call sites see the exact
        // same PreparedModel the pre-recipe pipeline produced
        let spec = three_layer_spec();
        let ws = three_layer_ws(9);
        let calib = {
            let mut c = fake_calib();
            let f1 = c.layers["f1"].clone();
            c.layers.insert("f2".into(), f1.clone());
            c.layers.insert("f3".into(), f1);
            c
        };
        for cfg in [
            QuantConfig::float(),
            QuantConfig::weights_only(5, ClipMethod::Mse, 0.05),
            QuantConfig::weights_with_a8(4, ClipMethod::Kl, 0.02),
            QuantConfig::acts_only(6, ClipMethod::Aciq, 0.1),
        ] {
            let a = prepare(&spec, &ws, Some(&calib), &cfg).unwrap();
            let b =
                prepare_recipe(&spec, &ws, Some(&calib), &QuantRecipe::uniform(&cfg)).unwrap();
            assert_eq!(a.layers.len(), b.layers.len());
            for (x, y) in a.layers.iter().zip(&b.layers) {
                assert_eq!(x.w.data(), y.w.data(), "{}: weights differ", x.name);
                assert_eq!(x.idx.data(), y.idx.data());
                assert_eq!(x.dscale.data(), y.dscale.data());
                assert_eq!(x.dbias.data(), y.dbias.data());
                assert_eq!(x.adelta.to_bits(), y.adelta.to_bits());
                assert_eq!(x.aqmax.to_bits(), y.aqmax.to_bits());
                assert_eq!(x.w_threshold.to_bits(), y.w_threshold.to_bits());
                assert_eq!(x.a_threshold.to_bits(), y.a_threshold.to_bits());
            }
        }
    }

    #[test]
    fn passes_enforce_ordering() {
        let ws = fake_ws(10);
        let layer = fake_layer();
        let rc = rc_of(&QuantConfig::weights_only(4, ClipMethod::None, 0.0));
        let mut cx = LayerCtx::new(&layer, &ws, None, &rc).unwrap();
        assert!(pass_weight_quant(&mut cx).is_err(), "needs pass_ocs first");
        pass_ocs(&mut cx).unwrap();
        pass_weight_quant(&mut cx).unwrap();
        pass_activation(&mut cx).unwrap();
        let prep = cx.finish().unwrap();
        assert_eq!(prep.name, "f1");
        // finish without the weight pass is an error, not a panic
        let mut cx2 = LayerCtx::new(&layer, &ws, None, &rc).unwrap();
        pass_ocs(&mut cx2).unwrap();
        assert!(cx2.finish().is_err());
        // ... and so is finish without the activation pass (a skipped
        // pass must never silently serve float activations)
        let mut cx3 = LayerCtx::new(&layer, &ws, None, &rc).unwrap();
        pass_ocs(&mut cx3).unwrap();
        pass_weight_quant(&mut cx3).unwrap();
        let err = cx3.finish().unwrap_err();
        assert!(err.to_string().contains("pass_activation"), "{err:#}");
    }
}
