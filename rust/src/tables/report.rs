//! `ocs report` — a per-layer quantization diagnosis for one model,
//! the kind of tool an ML service provider (the paper's §1 deployment
//! story) would run before committing to a bitwidth:
//!
//! * per-layer weight statistics (range, std, kurtosis proxy, outlier
//!   channel concentration),
//! * every clip method's threshold + resulting SQNR at the target bits,
//! * OCS headroom: how much the range shrinks after ceil(r·C) splits,
//! * per-channel vs per-tensor grid gain,
//! * a recommendation line per layer.
//!
//! Text to stdout, machine-readable JSON next to it in `results/`.

use std::fmt::Write as _;

use anyhow::{Context, Result};

use crate::clip::ClipMethod;
use crate::model::store::WeightStore;
use crate::model::ModelSpec;
use crate::ocs::{plan, weight_ocs, SplitMode};
use crate::quant::channelwise::per_channel_mse_gain;
use crate::quant::error::{sqnr_db, tensor_quant_mse};
use crate::quant::QuantSpec;
use crate::stats::Histogram;
use crate::util::json::{arr, num, obj, s, Value};

pub struct LayerReport {
    pub name: String,
    pub cin: usize,
    pub cout: usize,
    pub params: usize,
    pub max_abs: f32,
    pub std: f64,
    /// max channel |w| / median channel |w| — outlier concentration.
    pub channel_skew: f64,
    /// (method, threshold, sqnr_db) at the target bits.
    pub clips: Vec<(String, f32, f64)>,
    /// range reduction from OCS at r (fraction of original max).
    pub ocs_range_left: f64,
    /// (per-tensor MSE, per-channel MSE) at best clip.
    pub grid_gain: (f64, f64),
    pub recommendation: String,
}

pub fn report(
    spec: &ModelSpec,
    ws: &WeightStore,
    bits: u32,
    ratio: f64,
) -> Result<(String, Value)> {
    let qspec = QuantSpec::new(bits);
    let mut layers = Vec::new();
    for layer in spec.quantized_layers() {
        let w = ws.weight(&layer.name)?;
        let hist = Histogram::from_slice(w.data(), 2048);
        let maxes = w.max_abs_per_axis(layer.w_cin_axis)?;
        let mut sorted = maxes.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[sorted.len() / 2].max(1e-12);
        let channel_skew = (hist.max_abs() / median) as f64;

        let mut clips = Vec::new();
        let mut best: (f64, ClipMethod, f32) = (f64::NEG_INFINITY, ClipMethod::None, 0.0);
        for m in [
            ClipMethod::None,
            ClipMethod::Mse,
            ClipMethod::Aciq,
            ClipMethod::Kl,
        ] {
            let t = m.threshold(&hist, qspec);
            let sq = sqnr_db(w, t, qspec);
            if sq > best.0 {
                best = (sq, m, t);
            }
            clips.push((m.name(), t, sq));
        }

        // OCS headroom at the requested ratio
        let n = plan::splits_for(layer.cin, ratio, layer.cin_pad);
        let hooks = weight_ocs(w, layer.w_cin_axis, layer.cin_pad, n, SplitMode::QuantAware, 0.0)?;
        let ocs_range_left = (hooks.w_expanded.max_abs() / hist.max_abs().max(1e-12)) as f64;

        let cout_axis = if layer.w_cin_axis == 0 { 1 } else { 3 };
        let grid_gain = per_channel_mse_gain(w, cout_axis, qspec, ClipMethod::None);

        // crude but useful advice
        let recommendation = if channel_skew > 3.0 && ocs_range_left < 0.7 {
            format!("OCS r={ratio} (+{} ch) — outliers concentrated, splits pay", n)
        } else if best.1 != ClipMethod::None {
            format!("clip {} @ {:.4}", best.1.name(), best.2)
        } else {
            "plain linear grid is fine at this bitwidth".to_string()
        };

        layers.push(LayerReport {
            name: layer.name.clone(),
            cin: layer.cin,
            cout: layer.cout,
            params: w.len(),
            max_abs: hist.max_abs(),
            std: hist.std(),
            channel_skew,
            clips,
            ocs_range_left,
            grid_gain,
            recommendation,
        });
        // keep the unused exact-MSE helper wired for doc purposes
        let _ = tensor_quant_mse;
    }

    // ---- text ----
    let mut out = String::new();
    let _ = writeln!(
        out,
        "Quantization report — {} at {bits}-bit weights (OCS probe r={ratio})",
        spec.name
    );
    let _ = writeln!(
        out,
        "{:<10} {:>7} {:>9} {:>7} {:>6} | {:>22} | {:>6} {:>9} | {}",
        "layer", "params", "max|w|", "std", "skew", "best clip (thr, SQNR)", "ocs->", "pc-gain", "recommendation"
    );
    for l in &layers {
        let best = l
            .clips
            .iter()
            .max_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
            .unwrap();
        let pc_gain = if l.grid_gain.1 > 0.0 {
            l.grid_gain.0 / l.grid_gain.1
        } else {
            f64::INFINITY
        };
        let _ = writeln!(
            out,
            "{:<10} {:>7} {:>9.4} {:>7.4} {:>6.1} | {:>6} {:>7.4} {:>6.1}dB | {:>5.0}% {:>8.1}x | {}",
            l.name,
            l.params,
            l.max_abs,
            l.std,
            l.channel_skew,
            best.0,
            best.1,
            best.2,
            l.ocs_range_left * 100.0,
            pc_gain,
            l.recommendation
        );
    }

    // ---- json ----
    let json = obj(vec![
        ("model", s(&spec.name)),
        ("bits", num(bits as f64)),
        ("ocs_ratio", num(ratio)),
        (
            "layers",
            arr(layers
                .iter()
                .map(|l| {
                    obj(vec![
                        ("name", s(&l.name)),
                        ("params", num(l.params as f64)),
                        ("max_abs", num(l.max_abs as f64)),
                        ("std", num(l.std)),
                        ("channel_skew", num(l.channel_skew)),
                        (
                            "clips",
                            arr(l.clips
                                .iter()
                                .map(|(m, t, sq)| {
                                    obj(vec![
                                        ("method", s(m)),
                                        ("threshold", num(*t as f64)),
                                        ("sqnr_db", num(*sq)),
                                    ])
                                })
                                .collect()),
                        ),
                        ("ocs_range_left", num(l.ocs_range_left)),
                        ("per_tensor_mse", num(l.grid_gain.0)),
                        ("per_channel_mse", num(l.grid_gain.1)),
                        ("recommendation", s(&l.recommendation)),
                    ])
                })
                .collect()),
        ),
    ]);
    Ok((out, json))
}

/// CLI entry: print + write results/report_<model>.json.
pub fn run(artifacts: &str, results: &str, model: &str, bits: u32, ratio: f64) -> Result<()> {
    let spec = ModelSpec::load_named(artifacts, model)?;
    let (ws, trained) = WeightStore::load_best(&spec)?;
    if !trained {
        crate::warnln!("{model}: reporting on init weights (run `ocs train` first)");
    }
    let (text, json) = report(&spec, &ws, bits, ratio)?;
    println!("{text}");
    std::fs::create_dir_all(results)?;
    let path = std::path::Path::new(results).join(format!("report_{model}.json"));
    std::fs::write(&path, json.to_string()).with_context(|| path.display().to_string())?;
    println!("[json written to {}]", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{LayerKind, LayerSpec};
    use crate::tensor::TensorF;
    use crate::util::rng::Rng;

    fn fake_spec_and_ws() -> (ModelSpec, WeightStore) {
        let layer = LayerSpec {
            name: "f1".into(),
            kind: LayerKind::Fc,
            cin: 8,
            cin_pad: 10,
            cout: 6,
            ksize: 0,
            stride: 1,
            quantized: true,
            w_cin_axis: 0,
            w_shape: vec![8, 6],
            w_shape_pad: vec![10, 6],
        };
        let spec = ModelSpec {
            name: "fake".into(),
            dir: std::path::PathBuf::from("/tmp"),
            pad_factor: 1.25,
            num_classes: 10,
            img_hw: 16,
            img_c: 3,
            vocab: 0,
            seq_len: 0,
            momentum: 0.9,
            layers: vec![layer],
            artifacts: Default::default(),
        };
        let mut rng = Rng::new(5);
        let mut data = rng.normal_vec(48);
        data[0] = 9.0; // outlier in channel 0
        let ws = WeightStore::from_leaves(vec![
            ("f1.W".into(), TensorF::from_vec(&[8, 6], data).unwrap()),
            ("f1.b".into(), TensorF::zeros(&[6])),
        ]);
        (spec, ws)
    }

    #[test]
    fn report_covers_layers_and_emits_json() {
        let (spec, ws) = fake_spec_and_ws();
        let (text, json) = report(&spec, &ws, 4, 0.05).unwrap();
        assert!(text.contains("f1"), "{text}");
        let layers = json.get("layers").unwrap().as_arr().unwrap();
        assert_eq!(layers.len(), 1);
        let l = &layers[0];
        assert_eq!(l.get("name").unwrap().as_str().unwrap(), "f1");
        assert_eq!(l.get("clips").unwrap().as_arr().unwrap().len(), 4);
        // skew must flag the planted outlier
        assert!(l.get("channel_skew").unwrap().as_f64().unwrap() > 3.0);
        // OCS probe must show range reduction
        assert!(l.get("ocs_range_left").unwrap().as_f64().unwrap() < 0.8);
        // json round-trips
        let back = Value::parse(&json.to_string()).unwrap();
        assert_eq!(back.get("model").unwrap().as_str().unwrap(), "fake");
    }
}
