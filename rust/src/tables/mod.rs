//! Regeneration harness for every table and figure in the paper's
//! evaluation (see DESIGN.md §4 for the experiment index).
//!
//! Absolute numbers differ from the paper (our benchmark models are
//! trained in-repo on synthetic data — DESIGN.md §1); what must
//! reproduce is the *shape*: who wins, by roughly what factor, where the
//! crossovers fall. EXPERIMENTS.md records paper-vs-measured per table.

pub mod report;

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::calib::{self, Calibration, LayerCalib};
use crate::clip::ClipMethod;
use crate::eval;
use crate::model::store::WeightStore;
use crate::model::ModelSpec;
use crate::ocs::SplitMode;
use crate::pipeline::{self, QuantConfig};
use crate::quant::QuantSpec;
use crate::runtime::Engine;
use crate::stats::Histogram;
use crate::tensor::TensorF;
use crate::train::data::{self, ImageDataset};

pub const CNN_MODELS: [&str; 3] = ["minivgg", "miniresnet", "miniincept"];
/// The model standing in for ResNet-20/CIFAR in Table 1 and Figure 1.
pub const T1_MODEL: &str = "miniresnet";
pub const PAPER_CLIPS: [ClipMethod; 4] = [
    ClipMethod::None,
    ClipMethod::Mse,
    ClipMethod::Aciq,
    ClipMethod::Kl,
];

/// Shared state for one table run.
pub struct TableCtx {
    pub artifacts: String,
    pub results: String,
    pub quick: bool,
    engine: Engine,
    envs: std::cell::RefCell<BTreeMap<String, std::rc::Rc<ModelEnv>>>,
    /// Ctx-scoped (not the process-global) prepared-model cache: sweep
    /// cells that repeat share one prep, and everything is dropped with
    /// the ctx instead of pinning every sweep cell for the process
    /// lifetime.
    prep_cache: pipeline::PreparedCache,
}

/// Everything cached per model: spec, weights, calibration, test data.
pub struct ModelEnv {
    pub spec: ModelSpec,
    pub ws: WeightStore,
    pub trained: bool,
    pub calib: Option<Calibration>,
    pub test: Option<ImageDataset>,
}

impl TableCtx {
    pub fn new(artifacts: &str, results: &str, quick: bool) -> Result<TableCtx> {
        std::fs::create_dir_all(results)?;
        Ok(TableCtx {
            artifacts: artifacts.to_string(),
            results: results.to_string(),
            quick,
            engine: Engine::cpu()?,
            envs: Default::default(),
            prep_cache: pipeline::PreparedCache::new(),
        })
    }

    /// The ctx-scoped prepared-model cache (hit/miss accounting for
    /// sweeps).
    pub fn prep_cache(&self) -> &pipeline::PreparedCache {
        &self.prep_cache
    }

    fn test_n(&self) -> usize {
        if self.quick {
            512
        } else {
            2000
        }
    }
    fn calib_n(&self) -> usize {
        if self.quick {
            64
        } else {
            256
        }
    }

    /// Load (and cache) the full evaluation environment for a model.
    pub fn env(&self, model: &str) -> Result<std::rc::Rc<ModelEnv>> {
        if let Some(e) = self.envs.borrow().get(model) {
            return Ok(e.clone());
        }
        let spec = ModelSpec::load_named(&self.artifacts, model)?;
        let (ws, trained) = WeightStore::load_best(&spec)?;
        if !trained {
            crate::warnln!(
                "{model}: no trained weights found — run `ocs train --model {model}` for meaningful tables"
            );
        }
        let (calib, test) = if spec.is_lm() {
            (None, None)
        } else {
            let calib_set = data::synth_images(self.calib_n(), 29);
            let c = calib::calibrate(&self.engine, &spec, &ws, &calib_set.x, 32)?;
            (Some(c), Some(data::synth_images(self.test_n(), 31)))
        };
        let env = std::rc::Rc::new(ModelEnv {
            spec,
            ws,
            trained,
            calib,
            test,
        });
        self.envs
            .borrow_mut()
            .insert(model.to_string(), env.clone());
        Ok(env)
    }

    /// Accuracy (%) of one CNN quantization config (uniform recipe).
    pub fn acc(&self, env: &ModelEnv, cfg: &QuantConfig) -> Result<f64> {
        self.acc_recipe(env, &cfg.to_recipe())
    }

    /// Accuracy (%) of one CNN quantization recipe. Preparation goes
    /// through the ctx's [`pipeline::PreparedCache`]: sweeps that
    /// revisit a cell (table 1 and table 2 share several, and every
    /// "best clip" re-run repeats a sweep point) prepare it once.
    pub fn acc_recipe(&self, env: &ModelEnv, recipe: &pipeline::QuantRecipe) -> Result<f64> {
        let test = env.test.as_ref().context("CNN env")?;
        let prep = self
            .prep_cache
            .get_or_prepare(&env.spec, &env.ws, env.calib.as_ref(), recipe)?;
        Ok(eval::accuracy(&self.engine, &env.spec, &prep, &test.x, &test.y, 128)? * 100.0)
    }

    /// Perplexity of one LSTM config (uniform recipe).
    pub fn ppl(&self, env: &ModelEnv, cfg: &QuantConfig) -> Result<f64> {
        self.ppl_recipe(env, &cfg.to_recipe())
    }

    /// Perplexity of one LSTM recipe, prepared through the ctx cache.
    pub fn ppl_recipe(&self, env: &ModelEnv, recipe: &pipeline::QuantRecipe) -> Result<f64> {
        let corpus = data::synth_corpus(if self.quick { 20_000 } else { 40_000 }, env.spec.vocab, 92);
        let windows = data::token_windows(&corpus, env.spec.seq_len, 32);
        let prep = self.prep_cache.get_or_prepare(&env.spec, &env.ws, None, recipe)?;
        eval::perplexity(&self.engine, &env.spec, &prep, &windows)
    }

    fn emit(&self, name: &str, text: &str) -> Result<()> {
        let path = std::path::Path::new(&self.results).join(format!("{name}.txt"));
        std::fs::write(&path, text)?;
        println!("{text}");
        println!("[written to {}]", path.display());
        Ok(())
    }

    pub fn run(&self, id: &str) -> Result<()> {
        let t0 = Instant::now();
        match id {
            "fig1" => fig1(self)?,
            "1" => table1(self)?,
            "2" => table2(self)?,
            "3" => table3(self)?,
            "4" => table4(self)?,
            "5" => table5(self)?,
            "6" => table6(self)?,
            "all" => {
                for id in ["fig1", "1", "2", "3", "4", "5", "6"] {
                    self.run(id)?;
                }
            }
            other => bail!("unknown table id '{other}' (1-6, fig1, all)"),
        }
        crate::info!("table {id} done in {:.1}s", t0.elapsed().as_secs_f64());
        Ok(())
    }

    /// `ocs table --recipe FILE` — score one recipe file (e.g. the
    /// `ocs autotune` winner) against the float baseline on `model`.
    /// The file goes through the same `[quant]` loader as
    /// `serve --recipe`, unmodified — this is the emit path's second
    /// consumer.
    pub fn recipe_report(
        &self,
        model: &str,
        recipe: &pipeline::QuantRecipe,
        source: &str,
    ) -> Result<()> {
        let env = self.env(model)?;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "Recipe report — {model}, fingerprint {} ({} override(s), from {source})",
            recipe.fingerprint(),
            recipe.overrides.len()
        );
        if env.spec.is_lm() {
            let float_ppl = self.ppl(&env, &QuantConfig::float())?;
            let ppl = self.ppl_recipe(&env, recipe)?;
            let _ = writeln!(
                out,
                "{:>12} {:>8.1}\n{:>12} {:>8.1}   (perplexity; lower is better)",
                "float", float_ppl, "recipe", ppl
            );
        } else {
            let float_acc = self.acc(&env, &QuantConfig::float())?;
            let acc = self.acc_recipe(&env, recipe)?;
            let _ = writeln!(
                out,
                "{:>12} {:>7.1}%\n{:>12} {:>7.1}%   (top-1 accuracy)",
                "float", float_acc, "recipe", acc
            );
        }
        self.emit("recipe_report", &out)
    }
}

// ---------------------------------------------------------------------------
// Figure 1 — weight histograms: linear vs clip vs OCS
// ---------------------------------------------------------------------------

/// Signed histogram as CSV rows "center,count".
fn signed_hist_csv(data: &[f32], bins: usize) -> String {
    let max = data.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-9);
    let mut counts = vec![0u64; bins];
    for &v in data {
        let t = ((v + max) / (2.0 * max) * bins as f32) as usize;
        counts[t.min(bins - 1)] += 1;
    }
    let mut s = String::from("center,count\n");
    for (i, c) in counts.iter().enumerate() {
        let center = -max + (i as f32 + 0.5) * 2.0 * max / bins as f32;
        let _ = writeln!(s, "{center},{c}");
    }
    s
}

pub fn fig1(ctx: &TableCtx) -> Result<()> {
    let env = ctx.env(T1_MODEL)?;
    // the widest conv layer of the ResNet-20 stand-in
    let layer = env
        .spec
        .quantized_layers()
        .max_by_key(|l| l.cin)
        .context("no quantized layers")?;
    let w = env.ws.weight(&layer.name)?;
    let bits = 4;
    let spec4 = QuantSpec::new(bits);
    let hist = Histogram::from_slice(w.data(), 2048);

    // (a) linear: grid to max
    let t_lin = hist.max_abs();
    let q_lin = crate::quant::fake_quant_tensor(w, t_lin, spec4);
    // (b) clip (MSE threshold)
    let t_clip = ClipMethod::Mse.threshold(&hist, spec4);
    let q_clip = crate::quant::fake_quant_tensor(w, t_clip, spec4);
    // (c) OCS r=0.05 then linear
    let n = crate::ocs::plan::splits_for(layer.cin, 0.05, layer.cin_pad);
    let hooks = crate::ocs::weight_ocs(w, layer.w_cin_axis, layer.cin_pad, n, SplitMode::QuantAware, spec4.delta(t_lin))?;
    let active: Vec<f32> = (0..hooks.active)
        .flat_map(|s| hooks.w_expanded.axis_slice(layer.w_cin_axis, s).unwrap())
        .collect();
    let t_ocs = active.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let wo = TensorF::from_vec(&[active.len()], active.clone())?;
    let q_ocs = crate::quant::fake_quant_tensor(&wo, t_ocs, spec4);

    let mse_lin = w.mse(&q_lin);
    let mse_clip = w.mse(&q_clip);
    let mse_ocs = wo.mse(&q_ocs);

    for (tag, float_data, quant) in [
        ("linear", w.data(), &q_lin),
        ("clip", w.data(), &q_clip),
        ("ocs", &active[..], &q_ocs),
    ] {
        std::fs::write(
            std::path::Path::new(&ctx.results).join(format!("fig1_{tag}_float.csv")),
            signed_hist_csv(float_data, 101),
        )?;
        std::fs::write(
            std::path::Path::new(&ctx.results).join(format!("fig1_{tag}_quant.csv")),
            signed_hist_csv(quant.data(), 101),
        )?;
    }

    let mut out = String::new();
    let _ = writeln!(out, "Figure 1 — {T1_MODEL} layer '{}' at {bits}-bit (CSV histograms in {}/fig1_*.csv)", layer.name, ctx.results);
    let _ = writeln!(out, "  {:<8} threshold {:>9.5}  MSE {:.3e}", "linear", t_lin, mse_lin);
    let _ = writeln!(out, "  {:<8} threshold {:>9.5}  MSE {:.3e}", "clip", t_clip, mse_clip);
    let _ = writeln!(out, "  {:<8} threshold {:>9.5}  MSE {:.3e}  (range shrunk {:.1}%, {} extra ch)", "ocs", t_ocs, mse_ocs, 100.0 * (1.0 - t_ocs / t_lin), hooks.splits.len());
    let _ = writeln!(out, "  shape check: MSE(clip) < MSE(linear): {}; OCS range < linear range: {}",
        mse_clip < mse_lin, t_ocs < t_lin);
    ctx.emit("fig1", &out)
}

// ---------------------------------------------------------------------------
// Table 1 — QA vs naive splitting (ResNet-20 stand-in)
// ---------------------------------------------------------------------------

pub fn table1(ctx: &TableCtx) -> Result<()> {
    let env = ctx.env(T1_MODEL)?;
    let bits = [5u32, 4, 3, 2];
    let ratios = [0.01, 0.05, 0.1, 0.2];
    let mut out = String::new();
    let _ = writeln!(
        out,
        "Table 1 — QA / naive splitting, {T1_MODEL} (top-1 %, weights quantized, acts 8-bit)"
    );
    let _ = write!(out, "{:>4} |", "bits");
    for r in ratios {
        let _ = write!(out, " {:>13} |", format!("r={r}"));
    }
    let _ = writeln!(out);
    for b in bits {
        let _ = write!(out, "{b:>4} |");
        for r in ratios {
            let qa = ctx.acc(
                &env,
                &QuantConfig::weights_with_a8(b, ClipMethod::None, r)
                    .with_mode(SplitMode::QuantAware),
            )?;
            let naive = ctx.acc(
                &env,
                &QuantConfig::weights_with_a8(b, ClipMethod::None, r)
                    .with_mode(SplitMode::Naive),
            )?;
            let _ = write!(out, " {qa:>5.1} / {naive:>5.1} |");
        }
        let _ = writeln!(out);
    }
    let _ = writeln!(out, "(each cell: QA / naive — QA should match or beat naive, gap widening at low bits)");
    ctx.emit("table1", &out)
}

// ---------------------------------------------------------------------------
// Table 2 — weight quantization across clip methods and OCS
// ---------------------------------------------------------------------------

pub fn table2(ctx: &TableCtx) -> Result<()> {
    let bits = [8u32, 5, 4, 3, 2];
    let ratios = [0.01, 0.02, 0.05];
    let mut out = String::new();
    let _ = writeln!(out, "Table 2 — ImageNet-stand-in top-1 (%) with weight quantization (acts 8-bit)");
    let _ = writeln!(
        out,
        "{:<12} {:>4} | {:>6} {:>6} {:>6} {:>6} {:>7} | {:>6} {:>6} {:>6} | {:>6} {:>6} {:>6} | {:>6}",
        "model", "bits", "none", "mse", "aciq", "kl", "pct*", "ocs.01", "ocs.02", "ocs.05", "+c.01", "+c.02", "+c.05", "best"
    );
    for model in CNN_MODELS {
        let env = ctx.env(model)?;
        let float_acc = ctx.acc(&env, &QuantConfig::float())?;
        let _ = writeln!(out, "{model} (float {float_acc:.1})");
        for b in bits {
            // clip sweep
            let mut best = (f64::MIN, ClipMethod::None);
            let mut clip_accs = Vec::new();
            for m in PAPER_CLIPS {
                let a = ctx.acc(&env, &QuantConfig::weights_with_a8(b, m, 0.0))?;
                if a > best.0 {
                    best = (a, m);
                }
                clip_accs.push(a);
            }
            // percentile extension (not part of the paper's four)
            let pct = ctx.acc(&env, &QuantConfig::weights_with_a8(b, ClipMethod::Percentile(0.0), 0.0))?;
            // OCS with no clipping
            let mut ocs_accs = Vec::new();
            for r in ratios {
                ocs_accs.push(ctx.acc(&env, &QuantConfig::weights_with_a8(b, ClipMethod::None, r))?);
            }
            // OCS + best clip
            let mut comb_accs = Vec::new();
            for r in ratios {
                comb_accs.push(ctx.acc(&env, &QuantConfig::weights_with_a8(b, best.1, r))?);
            }
            let _ = writeln!(
                out,
                "{:<12} {b:>4} | {:>6.1} {:>6.1} {:>6.1} {:>6.1} {:>7.1} | {:>6.1} {:>6.1} {:>6.1} | {:>6.1} {:>6.1} {:>6.1} | {:>6.1} ({})",
                "", clip_accs[0], clip_accs[1], clip_accs[2], clip_accs[3], pct,
                ocs_accs[0], ocs_accs[1], ocs_accs[2],
                comb_accs[0], comb_accs[1], comb_accs[2],
                best.0, best.1.name()
            );
        }
    }
    let _ = writeln!(out, "(* percentile clipping is our extension beyond the paper's four methods)");
    ctx.emit("table2", &out)
}

// ---------------------------------------------------------------------------
// Table 3 — activation quantization
// ---------------------------------------------------------------------------

pub fn table3(ctx: &TableCtx) -> Result<()> {
    let bits = [8u32, 6, 5, 4, 3];
    let ratios = [0.01, 0.02, 0.05];
    let mut out = String::new();
    let _ = writeln!(out, "Table 3 — top-1 (%) with activation quantization (weights 8-bit)");
    let _ = writeln!(
        out,
        "{:<12} {:>4} | {:>6} {:>6} {:>6} {:>6} | {:>6} {:>6} {:>6}",
        "model", "bits", "none", "mse", "aciq", "kl", "ocs.01", "ocs.02", "ocs.05"
    );
    for model in CNN_MODELS {
        let env = ctx.env(model)?;
        let _ = writeln!(out, "{model}");
        for b in bits {
            let mut clip_accs = Vec::new();
            for m in PAPER_CLIPS {
                clip_accs.push(ctx.acc(&env, &QuantConfig::acts_only(b, m, 0.0))?);
            }
            let mut ocs_accs = Vec::new();
            for r in ratios {
                ocs_accs.push(ctx.acc(&env, &QuantConfig::acts_only(b, ClipMethod::None, r))?);
            }
            let _ = writeln!(
                out,
                "{:<12} {b:>4} | {:>6.1} {:>6.1} {:>6.1} {:>6.1} | {:>6.1} {:>6.1} {:>6.1}",
                "", clip_accs[0], clip_accs[1], clip_accs[2], clip_accs[3],
                ocs_accs[0], ocs_accs[1], ocs_accs[2]
            );
        }
    }
    let _ = writeln!(out, "(expected shape: clipping wins on activations; static OCS does not — see Table 4 for the oracle)");
    ctx.emit("table3", &out)
}

// ---------------------------------------------------------------------------
// Table 4 — Oracle OCS on activations vs batch size
// ---------------------------------------------------------------------------

/// Build a per-batch Calibration from the probe activations of exactly
/// this batch — the paper's "exact knowledge of the activations". Uses
/// the same fused kernel as the real `calibrate()` pass so both share
/// one statistics (and non-finite) policy; the exact-range hint keeps
/// the oracle histogram at full bin resolution like the old
/// `Histogram::from_slice` build.
fn batch_calibration(acts: &BTreeMap<String, TensorF>) -> Calibration {
    let mut layers = BTreeMap::new();
    for (name, a) in acts {
        let s = crate::kernels::stats::layer_stats_hinted(
            std::slice::from_ref(a),
            2048,
            calib::OUTLIER_PERCENTILE,
            0,
            a.max_abs().max(1e-12),
        );
        layers.insert(
            name.clone(),
            LayerCalib {
                channel_max: s.channel_max,
                outlier_counts: s.outlier_counts,
                hist: s.hist,
            },
        );
    }
    Calibration { layers }
}

pub fn table4(ctx: &TableCtx) -> Result<()> {
    let models = ["miniresnet", "miniincept"];
    let batches = [1usize, 2, 4, 8, 32, 128];
    let abits = 4;
    let r = 0.02;
    let n_eval = if ctx.quick { 256 } else { 1024 };
    let mut out = String::new();
    let _ = writeln!(out, "Table 4 — Oracle OCS on activations ({abits}-bit acts, r={r}, top-1 %)");
    let _ = writeln!(out, "{:<10} | {:>10} {:>10}", "batch", models[0], models[1]);
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    for &bsz in &batches {
        let mut cols = Vec::new();
        for model in models {
            let env = ctx.env(model)?;
            let test = env.test.as_ref().unwrap();
            let n = n_eval.min(test.len()) / bsz * bsz;
            let mut correct = 0usize;
            let cfg = QuantConfig::acts_only(abits, ClipMethod::None, r);
            let mut i = 0;
            while i < n {
                let xb = calib::slice_rows(&test.x, i, bsz)?;
                // oracle: probe THIS batch, select channels from it.
                // Deliberately uncached: every batch is a distinct
                // calibration, so cache entries would never be revisited.
                let acts = calib::probe_batch(&ctx.engine, &env.spec, &env.ws, &xb)?;
                let oracle = batch_calibration(&acts);
                let prep = pipeline::prepare(&env.spec, &env.ws, Some(&oracle), &cfg)?;
                let acc = eval::accuracy(&ctx.engine, &env.spec, &prep, &xb, &test.y[i..i + bsz], bsz)?;
                correct += (acc * bsz as f64).round() as usize;
                i += bsz;
            }
            cols.push(correct as f64 / n as f64 * 100.0);
        }
        rows.push((format!("{bsz}"), cols));
    }
    // reference rows: static no-OCS and best clip at these bits
    let mut no_ocs = Vec::new();
    let mut clip_best = Vec::new();
    for model in models {
        let env = ctx.env(model)?;
        no_ocs.push(ctx.acc(&env, &QuantConfig::acts_only(abits, ClipMethod::None, 0.0))?);
        let mut best = f64::MIN;
        for m in [ClipMethod::Mse, ClipMethod::Aciq, ClipMethod::Kl] {
            best = best.max(ctx.acc(&env, &QuantConfig::acts_only(abits, m, 0.0))?);
        }
        clip_best.push(best);
    }
    rows.push(("No OCS".into(), no_ocs));
    rows.push(("Clip Best".into(), clip_best));
    for (label, cols) in rows {
        let _ = writeln!(out, "{label:<10} | {:>10.1} {:>10.1}", cols[0], cols[1]);
    }
    let _ = writeln!(out, "(oracle accuracy should rise as batch shrinks and beat Clip Best at small batches)");
    ctx.emit("table4", &out)
}

// ---------------------------------------------------------------------------
// Table 5 — model size overhead
// ---------------------------------------------------------------------------

pub fn table5(ctx: &TableCtx) -> Result<()> {
    let env = ctx.env(T1_MODEL)?;
    let ratios = [0.01, 0.02, 0.05, 0.1];
    let mut out = String::new();
    let _ = writeln!(out, "Table 5 — {T1_MODEL} relative size overhead vs expand ratio");
    let _ = write!(out, "{:<22} |", "");
    for r in ratios {
        let _ = write!(out, " {:>6} |", format!("r={r}"));
    }
    let _ = writeln!(out);
    // weight overhead
    let _ = write!(out, "{:<22} |", "Rel. Weight Size");
    for r in ratios {
        let cfg = QuantConfig::weights_only(8, ClipMethod::None, r);
        let prep = ctx
            .prep_cache
            .get_or_prepare(&env.spec, &env.ws, None, &cfg.to_recipe())?;
        let _ = write!(out, " {:>6.3} |", prep.weight_overhead());
    }
    let _ = writeln!(out);
    // activation overhead: extra channels weighted by activation elements
    // per channel (from the probe artifact's recorded output shapes)
    let probe = env.spec.probe_for_batch(32)?;
    let act_elems: BTreeMap<String, usize> = probe
        .outputs
        .iter()
        .filter_map(|o| {
            o.name.strip_prefix("act.").map(|n| {
                let per_image: usize = o.shape[1..].iter().product();
                let channels = *o.shape.last().unwrap();
                (n.to_string(), per_image / channels)
            })
        })
        .collect();
    let _ = write!(out, "{:<22} |", "Rel. Activation Size");
    for r in ratios {
        let cfg = QuantConfig::acts_only(8, ClipMethod::None, r);
        let prep =
            ctx.prep_cache
                .get_or_prepare(&env.spec, &env.ws, env.calib.as_ref(), &cfg.to_recipe())?;
        let mut base = 0usize;
        let mut extra = 0usize;
        for l in &prep.layers {
            let epc = act_elems.get(&l.name).copied().unwrap_or(1);
            base += epc * l.cin;
            extra += epc * (l.active - l.cin);
        }
        let _ = write!(out, " {:>6.3} |", 1.0 + extra as f64 / base.max(1) as f64);
    }
    let _ = writeln!(out);
    let _ = writeln!(out, "(paper: overhead tracks r very closely)");
    ctx.emit("table5", &out)
}

// ---------------------------------------------------------------------------
// Table 6 — LSTM LM perplexity under weight quantization
// ---------------------------------------------------------------------------

pub fn table6(ctx: &TableCtx) -> Result<()> {
    let env = ctx.env("lstmlm")?;
    let float_ppl = ctx.ppl(&env, &QuantConfig::float())?;
    let bits = [5u32, 4];
    let ratios = [0.0, 0.01, 0.02, 0.05];
    let mut out = String::new();
    let _ = writeln!(out, "Table 6 — LM perplexity with quantized weights (float baseline {float_ppl:.1}; lower is better)");
    let _ = writeln!(
        out,
        "{:>4} {:>6} | {:>7} {:>7} {:>7} {:>7}",
        "bits", "ratio", "none", "mse", "aciq", "kl"
    );
    for b in bits {
        for r in ratios {
            let mut cols = Vec::new();
            for m in PAPER_CLIPS {
                cols.push(ctx.ppl(&env, &QuantConfig::weights_only(b, m, r))?);
            }
            let _ = writeln!(
                out,
                "{b:>4} {r:>6} | {:>7.1} {:>7.1} {:>7.1} {:>7.1}",
                cols[0], cols[1], cols[2], cols[3]
            );
        }
    }
    let _ = writeln!(out, "(expected shape: clipping does not help this model; OCS lowers perplexity with growing r)");
    ctx.emit("table6", &out)
}
