//! `.ocst` tensor-bundle IO — the weight interchange format shared with
//! `python/compile/ocst.py` (see that file for the byte layout).

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{TensorF, TensorI};

const MAGIC: &[u8; 8] = b"OCST0001";

/// A named collection of tensors (f32 or i32), order-preserving.
#[derive(Debug, Clone, Default)]
pub struct Bundle {
    pub order: Vec<String>,
    pub f32s: BTreeMap<String, TensorF>,
    pub i32s: BTreeMap<String, TensorI>,
}

impl Bundle {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push_f32(&mut self, name: &str, t: TensorF) {
        self.order.push(name.to_string());
        self.f32s.insert(name.to_string(), t);
    }

    pub fn push_i32(&mut self, name: &str, t: TensorI) {
        self.order.push(name.to_string());
        self.i32s.insert(name.to_string(), t);
    }

    pub fn f32(&self, name: &str) -> Result<&TensorF> {
        self.f32s
            .get(name)
            .with_context(|| format!("bundle missing f32 tensor '{name}'"))
    }

    pub fn i32(&self, name: &str) -> Result<&TensorI> {
        self.i32s
            .get(name)
            .with_context(|| format!("bundle missing i32 tensor '{name}'"))
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }
    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    // ---- serialization -----------------------------------------------------

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&(self.order.len() as u32).to_le_bytes());
        for name in &self.order {
            let nb = name.as_bytes();
            buf.extend_from_slice(&(nb.len() as u16).to_le_bytes());
            buf.extend_from_slice(nb);
            if let Some(t) = self.f32s.get(name) {
                buf.push(0u8);
                buf.push(t.rank() as u8);
                for &d in t.shape() {
                    buf.extend_from_slice(&(d as u32).to_le_bytes());
                }
                for &v in t.data() {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            } else if let Some(t) = self.i32s.get(name) {
                buf.push(1u8);
                buf.push(t.rank() as u8);
                for &d in t.shape() {
                    buf.extend_from_slice(&(d as u32).to_le_bytes());
                }
                for &v in t.data() {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            } else {
                bail!("bundle entry '{name}' listed in order but not stored");
            }
        }
        let mut f = std::fs::File::create(path.as_ref())
            .with_context(|| format!("create {}", path.as_ref().display()))?;
        f.write_all(&buf)?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Bundle> {
        let mut bytes = Vec::new();
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("open {}", path.as_ref().display()))?
            .read_to_end(&mut bytes)?;
        Self::from_bytes(&bytes)
    }

    pub fn from_bytes(b: &[u8]) -> Result<Bundle> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > b.len() {
                bail!("truncated .ocst at byte {pos}");
            }
            let s = &b[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 8)? != MAGIC {
            bail!("bad .ocst magic");
        }
        let count = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        let mut bundle = Bundle::new();
        for _ in 0..count {
            let nlen = u16::from_le_bytes(take(&mut pos, 2)?.try_into().unwrap()) as usize;
            let name = String::from_utf8(take(&mut pos, nlen)?.to_vec())
                .context("invalid utf8 tensor name")?;
            let hdr = take(&mut pos, 2)?;
            let (dt, ndim) = (hdr[0], hdr[1] as usize);
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize);
            }
            let n: usize = shape.iter().product();
            let raw = take(&mut pos, 4 * n)?;
            match dt {
                0 => {
                    let data: Vec<f32> = raw
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    bundle.push_f32(&name, TensorF::from_vec(&shape, data)?);
                }
                1 => {
                    let data: Vec<i32> = raw
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    bundle.push_i32(&name, TensorI::from_vec(&shape, data)?);
                }
                d => bail!("unknown dtype tag {d} for tensor '{name}'"),
            }
        }
        if pos != b.len() {
            bail!("trailing {} bytes after .ocst payload", b.len() - pos);
        }
        Ok(bundle)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("ocst_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.ocst");

        let mut b = Bundle::new();
        b.push_f32(
            "w",
            TensorF::from_vec(&[2, 3], vec![1.0, -2.5, 3.0, 0.0, 5.5, -6.0]).unwrap(),
        );
        b.push_i32("idx", TensorI::from_vec(&[4], vec![0, 1, 1, 3]).unwrap());
        b.push_f32("scalar", TensorF::scalar(7.25));
        b.save(&path).unwrap();

        let r = Bundle::load(&path).unwrap();
        assert_eq!(r.order, vec!["w", "idx", "scalar"]);
        assert_eq!(r.f32("w").unwrap(), b.f32("w").unwrap());
        assert_eq!(r.i32("idx").unwrap(), b.i32("idx").unwrap());
        assert_eq!(r.f32("scalar").unwrap().data(), &[7.25]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_corruption() {
        assert!(Bundle::from_bytes(b"NOTMAGIC").is_err());
        let mut b = Bundle::new();
        b.push_f32("x", TensorF::zeros(&[3]));
        let dir = std::env::temp_dir().join(format!("ocst_corrupt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.ocst");
        b.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.truncate(bytes.len() - 3);
        assert!(Bundle::from_bytes(&bytes).is_err());
        bytes.extend_from_slice(&[0u8; 20]);
        assert!(Bundle::from_bytes(&bytes).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Byte-level compatibility with the python writer: the layout below
    /// was produced by `python/compile/ocst.py::write_ocst` for
    /// [("a", float32 [1.5, -2.0])].
    #[test]
    fn python_layout_compat() {
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(b"OCST0001");
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&1u16.to_le_bytes());
        bytes.push(b'a');
        bytes.push(0); // f32
        bytes.push(1); // ndim
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&1.5f32.to_le_bytes());
        bytes.extend_from_slice(&(-2.0f32).to_le_bytes());
        let b = Bundle::from_bytes(&bytes).unwrap();
        assert_eq!(b.f32("a").unwrap().data(), &[1.5, -2.0]);
    }
}
