//! Elementwise and reduction helpers over `TensorF` used across the
//! quantization pipeline and evaluators.

use super::TensorF;

impl TensorF {
    pub fn map(&self, f: impl Fn(f32) -> f32) -> TensorF {
        TensorF::from_vec(self.shape(), self.data().iter().map(|&v| f(v)).collect())
            .expect("same shape")
    }

    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for v in self.data_mut() {
            *v = f(*v);
        }
    }

    pub fn sum(&self) -> f64 {
        self.data().iter().map(|&v| v as f64).sum()
    }

    pub fn mean(&self) -> f64 {
        if self.is_empty() {
            return 0.0;
        }
        self.sum() / self.len() as f64
    }

    /// Mean squared difference against another tensor of the same shape.
    pub fn mse(&self, other: &TensorF) -> f64 {
        assert_eq!(self.shape(), other.shape(), "mse: shape mismatch");
        if self.is_empty() {
            return 0.0;
        }
        let s: f64 = self
            .data()
            .iter()
            .zip(other.data())
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum();
        s / self.len() as f64
    }

    /// argmax over the trailing axis; returns one index per leading row.
    /// Used for top-1 accuracy over (batch, classes) logits.
    pub fn argmax_rows(&self) -> Vec<usize> {
        let cols = *self.shape().last().expect("argmax_rows needs rank >= 1");
        self.data()
            .chunks_exact(cols)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_and_mean() {
        let t = TensorF::from_vec(&[4], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(t.map(|v| v * 2.0).data(), &[2.0, 4.0, 6.0, 8.0]);
        assert!((t.mean() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn mse() {
        let a = TensorF::from_vec(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = TensorF::from_vec(&[3], vec![1.0, 0.0, 3.0]).unwrap();
        assert!((a.mse(&b) - 4.0 / 3.0).abs() < 1e-9);
        assert_eq!(a.mse(&a), 0.0);
    }

    #[test]
    fn argmax_rows() {
        let t = TensorF::from_vec(&[2, 3], vec![0.1, 0.9, 0.5, 2.0, -1.0, 1.0]).unwrap();
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }
}
