//! Dense row-major tensors — the coordinator's in-memory array substrate.
//!
//! Deliberately minimal: contiguous storage, shape + derived strides,
//! axis-wise channel views (everything OCS needs is "iterate / mutate the
//! slice where `index[axis] == i`"), and the `.ocst` binary IO used to
//! exchange weights with the python compile path ([`io`]).

pub mod io;
pub mod ops;

use thiserror::Error;

#[derive(Debug, Error)]
pub enum TensorError {
    #[error("shape {shape:?} implies {expected} elements, data has {got}")]
    ShapeMismatch {
        shape: Vec<usize>,
        expected: usize,
        got: usize,
    },
    #[error("axis {axis} out of range for rank {rank}")]
    BadAxis { axis: usize, rank: usize },
    #[error("index {index} out of range for axis of length {len}")]
    BadIndex { index: usize, len: usize },
}

/// Contiguous row-major tensor over `f32` or `i32`.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor<T> {
    shape: Vec<usize>,
    data: Vec<T>,
}

/// Zero-copy view of the slice `index[axis] == i`: iterates the `outer`
/// contiguous runs of length `inner` (stride `axis_len * inner` apart)
/// without materializing anything. `Clone` so two-pass consumers (range
/// scan, then binning) can walk it twice; see
/// [`crate::stats::Histogram::from_chunks`].
#[derive(Debug, Clone)]
pub struct AxisChunks<'a, T> {
    data: &'a [T],
    inner: usize,
    step: usize,
    pos: usize,
    remaining: usize,
}

impl<'a, T> Iterator for AxisChunks<'a, T> {
    type Item = &'a [T];

    fn next(&mut self) -> Option<&'a [T]> {
        if self.remaining == 0 {
            return None;
        }
        let run = &self.data[self.pos..self.pos + self.inner];
        self.pos += self.step;
        self.remaining -= 1;
        Some(run)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        (self.remaining, Some(self.remaining))
    }
}

impl<T> ExactSizeIterator for AxisChunks<'_, T> {}

pub type TensorF = Tensor<f32>;
pub type TensorI = Tensor<i32>;

impl<T: Copy + Default> Tensor<T> {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![T::default(); n],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Result<Self, TensorError> {
        let expected: usize = shape.iter().product();
        if expected != data.len() {
            return Err(TensorError::ShapeMismatch {
                shape: shape.to_vec(),
                expected,
                got: data.len(),
            });
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn scalar(v: T) -> Self {
        Tensor {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn full(shape: &[usize], v: T) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; n],
        }
    }

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }
    #[inline]
    pub fn rank(&self) -> usize {
        self.shape.len()
    }
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
    #[inline]
    pub fn data(&self) -> &[T] {
        &self.data
    }
    #[inline]
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }
    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(mut self, shape: &[usize]) -> Result<Self, TensorError> {
        let expected: usize = shape.iter().product();
        if expected != self.data.len() {
            return Err(TensorError::ShapeMismatch {
                shape: shape.to_vec(),
                expected,
                got: self.data.len(),
            });
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// (outer, axis_len, inner) decomposition around `axis`: element
    /// `(o, i, k)` lives at offset `(o * axis_len + i) * inner + k`.
    pub fn axis_geometry(&self, axis: usize) -> Result<(usize, usize, usize), TensorError> {
        if axis >= self.shape.len() {
            return Err(TensorError::BadAxis {
                axis,
                rank: self.shape.len(),
            });
        }
        let outer: usize = self.shape[..axis].iter().product();
        let alen = self.shape[axis];
        let inner: usize = self.shape[axis + 1..].iter().product();
        Ok((outer, alen, inner))
    }

    /// Borrow the slice `index[axis] == i` as strided runs — the
    /// zero-copy sibling of [`Self::axis_slice`] for consumers that only
    /// iterate (histograms, maxima): no per-channel `Vec` allocation.
    pub fn axis_chunks(&self, axis: usize, i: usize) -> Result<AxisChunks<'_, T>, TensorError> {
        let (outer, alen, inner) = self.axis_geometry(axis)?;
        if i >= alen {
            return Err(TensorError::BadIndex { index: i, len: alen });
        }
        Ok(AxisChunks {
            data: &self.data,
            inner,
            step: alen * inner,
            pos: i * inner,
            remaining: outer,
        })
    }

    /// Copy out the slice `index[axis] == i` (length outer*inner).
    pub fn axis_slice(&self, axis: usize, i: usize) -> Result<Vec<T>, TensorError> {
        let (outer, alen, inner) = self.axis_geometry(axis)?;
        if i >= alen {
            return Err(TensorError::BadIndex { index: i, len: alen });
        }
        let mut out = Vec::with_capacity(outer * inner);
        for o in 0..outer {
            let base = (o * alen + i) * inner;
            out.extend_from_slice(&self.data[base..base + inner]);
        }
        Ok(out)
    }

    /// Apply `f` to every element of the slice `index[axis] == i`.
    pub fn axis_map_mut<F: FnMut(&mut T)>(
        &mut self,
        axis: usize,
        i: usize,
        mut f: F,
    ) -> Result<(), TensorError> {
        let (outer, alen, inner) = self.axis_geometry(axis)?;
        if i >= alen {
            return Err(TensorError::BadIndex { index: i, len: alen });
        }
        for o in 0..outer {
            let base = (o * alen + i) * inner;
            for v in &mut self.data[base..base + inner] {
                f(v);
            }
        }
        Ok(())
    }

    /// Copy the slice at `src` (along `axis`) into the slice at `dst`,
    /// transforming each element with `f`.
    pub fn axis_copy_with<F: FnMut(T) -> T>(
        &mut self,
        axis: usize,
        src: usize,
        dst: usize,
        mut f: F,
    ) -> Result<(), TensorError> {
        let (outer, alen, inner) = self.axis_geometry(axis)?;
        if src >= alen {
            return Err(TensorError::BadIndex { index: src, len: alen });
        }
        if dst >= alen {
            return Err(TensorError::BadIndex { index: dst, len: alen });
        }
        for o in 0..outer {
            let sbase = (o * alen + src) * inner;
            let dbase = (o * alen + dst) * inner;
            for k in 0..inner {
                self.data[dbase + k] = f(self.data[sbase + k]);
            }
        }
        Ok(())
    }

    /// Grow `axis` to `new_len`, zero/default-filling new slices.
    pub fn pad_axis(&self, axis: usize, new_len: usize) -> Result<Self, TensorError> {
        let (outer, alen, inner) = self.axis_geometry(axis)?;
        assert!(new_len >= alen, "pad_axis cannot shrink");
        let mut shape = self.shape.clone();
        shape[axis] = new_len;
        let mut out = Tensor::zeros(&shape);
        for o in 0..outer {
            for i in 0..alen {
                let sbase = (o * alen + i) * inner;
                let dbase = (o * new_len + i) * inner;
                out.data[dbase..dbase + inner]
                    .copy_from_slice(&self.data[sbase..sbase + inner]);
            }
        }
        Ok(out)
    }
}

impl TensorF {
    /// Largest |x| over the whole tensor.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// Largest |x| within the slice `index[axis] == i`.
    pub fn axis_max_abs(&self, axis: usize, i: usize) -> Result<f32, TensorError> {
        let (outer, alen, inner) = self.axis_geometry(axis)?;
        if i >= alen {
            return Err(TensorError::BadIndex { index: i, len: alen });
        }
        let mut m = 0.0f32;
        for o in 0..outer {
            let base = (o * alen + i) * inner;
            for &v in &self.data[base..base + inner] {
                m = m.max(v.abs());
            }
        }
        Ok(m)
    }

    /// Per-channel max-abs along `axis` (the OCS channel statistic).
    pub fn max_abs_per_axis(&self, axis: usize) -> Result<Vec<f32>, TensorError> {
        let (outer, alen, inner) = self.axis_geometry(axis)?;
        let mut out = vec![0.0f32; alen];
        for o in 0..outer {
            for i in 0..alen {
                let base = (o * alen + i) * inner;
                for &v in &self.data[base..base + inner] {
                    if v.abs() > out[i] {
                        out[i] = v.abs();
                    }
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t3() -> TensorF {
        // shape (2, 3, 2): values 0..12
        TensorF::from_vec(&[2, 3, 2], (0..12).map(|v| v as f32).collect()).unwrap()
    }

    #[test]
    fn from_vec_validates() {
        assert!(TensorF::from_vec(&[2, 2], vec![1.0; 3]).is_err());
        assert!(TensorF::from_vec(&[2, 2], vec![1.0; 4]).is_ok());
    }

    #[test]
    fn axis_slice_middle_axis() {
        let t = t3();
        // axis 1 index 1 -> elements with middle index 1: [2,3, 8,9]
        assert_eq!(t.axis_slice(1, 1).unwrap(), vec![2.0, 3.0, 8.0, 9.0]);
        // axis 0 index 0 -> first 6
        assert_eq!(
            t.axis_slice(0, 0).unwrap(),
            vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]
        );
        // axis 2 index 1 -> odd offsets
        assert_eq!(
            t.axis_slice(2, 1).unwrap(),
            vec![1.0, 3.0, 5.0, 7.0, 9.0, 11.0]
        );
    }

    #[test]
    fn axis_chunks_match_axis_slice() {
        let t = t3();
        for axis in 0..3 {
            let len = t.shape()[axis];
            for i in 0..len {
                let flat: Vec<f32> = t
                    .axis_chunks(axis, i)
                    .unwrap()
                    .flat_map(|run| run.iter().copied())
                    .collect();
                assert_eq!(flat, t.axis_slice(axis, i).unwrap(), "axis {axis} i {i}");
            }
        }
        // cloneable: a second pass sees the same runs
        let view = t.axis_chunks(1, 1).unwrap();
        let a: Vec<&[f32]> = view.clone().collect();
        let b: Vec<&[f32]> = view.collect();
        assert_eq!(a, b);
        assert!(t.axis_chunks(5, 0).is_err());
        assert!(t.axis_chunks(1, 3).is_err());
    }

    #[test]
    fn axis_map_and_copy() {
        let mut t = t3();
        t.axis_map_mut(1, 0, |v| *v *= 10.0).unwrap();
        assert_eq!(t.axis_slice(1, 0).unwrap(), vec![0.0, 10.0, 60.0, 70.0]);
        t.axis_copy_with(1, 0, 2, |v| v / 2.0).unwrap();
        assert_eq!(t.axis_slice(1, 2).unwrap(), vec![0.0, 5.0, 30.0, 35.0]);
    }

    #[test]
    fn pad_axis_preserves_content() {
        let t = t3();
        let p = t.pad_axis(1, 5).unwrap();
        assert_eq!(p.shape(), &[2, 5, 2]);
        for i in 0..3 {
            assert_eq!(p.axis_slice(1, i).unwrap(), t.axis_slice(1, i).unwrap());
        }
        assert_eq!(p.axis_slice(1, 3).unwrap(), vec![0.0; 4]);
        assert_eq!(p.axis_slice(1, 4).unwrap(), vec![0.0; 4]);
    }

    #[test]
    fn max_abs_per_axis() {
        let t = TensorF::from_vec(&[2, 2], vec![1.0, -5.0, 3.0, 2.0]).unwrap();
        assert_eq!(t.max_abs(), 5.0);
        assert_eq!(t.max_abs_per_axis(1).unwrap(), vec![3.0, 5.0]);
        assert_eq!(t.max_abs_per_axis(0).unwrap(), vec![5.0, 3.0]);
        assert_eq!(t.axis_max_abs(1, 1).unwrap(), 5.0);
    }

    #[test]
    fn errors() {
        let t = t3();
        assert!(t.axis_slice(5, 0).is_err());
        assert!(t.axis_slice(1, 3).is_err());
        assert!(t.clone().reshape(&[5]).is_err());
        assert!(t.reshape(&[12]).is_ok());
    }

    #[test]
    fn scalar_and_full() {
        let s = TensorF::scalar(3.5);
        assert_eq!(s.shape(), &[] as &[usize]);
        assert_eq!(s.data(), &[3.5]);
        let f = TensorI::full(&[3], 7);
        assert_eq!(f.data(), &[7, 7, 7]);
    }
}
