//! # OCS — Outlier Channel Splitting, reproduced as a deployable stack
//!
//! Rust implementation of *"Improving Neural Network Quantization without
//! Retraining using Outlier Channel Splitting"* (Zhao et al., ICML 2019),
//! structured as the Layer-3 coordinator of a three-layer Rust + JAX +
//! Pallas system:
//!
//! * **L1** (`python/compile/kernels/`) — Pallas kernels: `fake_quant`
//!   (Eq. 1), `channel_dup` (the OCS runtime layer, §3.5), `qmatmul`.
//! * **L2** (`python/compile/model.py`) — JAX benchmark models with OCS
//!   hooks, AOT-lowered once to HLO text under `artifacts/`.
//! * **L3** (this crate) — everything at run time: the quantization
//!   toolchain ([`quant`], [`clip`], [`ocs`]), activation calibration
//!   ([`calib`]), the fused/parallel compute kernels under all of them
//!   ([`kernels`]: single-sweep statistics, channel-parallel
//!   quantization on a process-wide thread pool, bit-identical to
//!   serial at any width), the PJRT runtime ([`runtime`]),
//!   training/eval harness ([`train`], [`eval`]), the sharded inference
//!   pool ([`serve`]) and the paper-table regeneration harness
//!   ([`tables`]).
//!
//! Python never runs on the request path: after `make artifacts` the
//! `ocs` binary is self-contained.
//!
//! ## Quantization recipes
//!
//! The quantization API is built around [`pipeline::QuantRecipe`]:
//! model-wide defaults plus ordered per-layer overrides matched by
//! layer-name glob, [`model::LayerKind`], or first/last position —
//! mixed precision, per-layer OCS ratios, and skip-first/last policies
//! in one object. [`pipeline::QuantConfig`] remains the thin uniform
//! constructor (`cfg.to_recipe()`), clip thresholds plug in through the
//! [`clip::ClipStrategy`] trait, and [`pipeline::prepare_recipe`] runs
//! composable per-layer passes (OCS → weight clip/quant → activation)
//! over a shared [`pipeline::LayerCtx`]. Every recipe has a stable
//! fingerprint; [`pipeline::prepare_cached`] memoizes preparation in
//! the process-wide [`pipeline::PreparedCache`] so all serve workers
//! share one prep (table sweeps share through a ctx-scoped instance),
//! and the serve router hot-swaps recipes into a live pool
//! ([`serve::Server::swap_recipe`]). See
//! `pipeline/README.md` for the override grammar (TOML `[[quant.layer]]`
//! tables, CLI `--layer`), matching and fingerprint semantics.
//!
//! ## Serving architecture (the §3.5 deployment claim, at pool scale)
//!
//! An OCS-split model is a *plain* model, so it scales the way plain
//! models scale. [`serve`] shards the server into N worker threads, each
//! owning a full engine + prepared quantization pipeline — PJRT handles
//! are `!Send`, so shard-per-thread is the only correct scaling shape; a
//! shared engine behind a lock would serialize exactly the work we are
//! trying to parallelize. A router performs bounded-queue admission
//! control (full queues reject, they never block), least-outstanding-work
//! dispatch, per-request deadlines, and graceful drain on shutdown.
//! Artifact HLO text is cached and validated once per process
//! ([`runtime::HloTextCache`]) no matter how many workers compile it.
//! Knobs: `--workers`,
//! `--queue-cap`, `--deadline-ms`, `--max-batch`, `--max-wait-us` (see
//! `ocs serve`), or [`pipeline::ServeConfig`] in code/TOML.
//!
//! ## The native integer backend
//!
//! The paper's deployment pitch is that an OCS model is a plain
//! *integer* model. [`runtime::native`] executes it as one: prepared
//! models lower to true `i8` payloads ([`quant::pack`], round-trip
//! exactness asserted against the Eq. 1 grid), activations quantize to
//! their grid integers, and the hot path is a packed, cache-blocked,
//! pool-parallel i8×i8→i32 GEMM with a fused per-output-channel
//! dequant + bias epilogue ([`kernels::gemm`]) — FC layers directly,
//! conv via im2col. No artifacts, no PJRT: `ocs eval --backend native`
//! and `ocs serve --backend native` run real quantized compute on
//! every build (`--sim-free` serves a built-in model on a clean
//! checkout), and `benches/gemm.rs` tracks the kernel per PR
//! (`BENCH_native.json`).
//!
//! ## Benchmark records and regression gating
//!
//! Every perf harness emits a versioned [`bench_record::BenchRecord`]
//! (schema version, bench tag, host metadata, flat measurement rows):
//! `benches/hotpath.rs` → `BENCH_quant.json`, `benches/gemm.rs` →
//! `BENCH_native.json`, the serve worker sweep → `BENCH_serving.json`.
//! Per-PR baselines are committed under `records/` (refresh with
//! `make bench-record`); `ocs bench diff OLD NEW` reports per-case
//! ratios under a noise threshold and exits nonzero on regression,
//! `ocs bench check FILE` validates a record, and CI gates every fresh
//! record against the committed baseline (see `docs/BENCH_FORMAT.md`).
//!
//! ## Build modes
//!
//! The default build has **no PJRT dependency**: [`runtime`] compiles
//! against an API-identical stub, artifact execution reports a clear
//! error, and the serving stack runs on a synthetic engine
//! ([`serve::backend::SimFactory`]) or the native integer backend
//! ([`serve::backend::NativeFactory`]) — this is what CI builds and
//! tests on every push. Building with `--features pjrt` (and the
//! vendored `xla` crate) enables real artifact execution; no other
//! code changes.
//!
//! ## Quick start
//!
//! ```bash
//! make artifacts && cargo build --release --features pjrt
//! target/release/ocs train --model miniresnet   # train through PJRT
//! target/release/ocs table --id 2               # reproduce Table 2
//! target/release/ocs serve --model minivgg --workers 4 --sweep 1,2,4
//! # per-layer recipe: 4-bit middles, 8-bit boundary layers
//! target/release/ocs eval --model minivgg --w-bits 4 \
//!     --layer "%edge:w_bits=8"
//! cargo run --release --example quickstart
//! # no artifacts? the pool and the recipe API run on the sim backends:
//! cargo run --release -- serve --sim --workers 2 --json BENCH_serving.json
//! QUICKSTART_SIM=1 cargo run --release --example quickstart
//! ```

// CI runs `cargo clippy -- -D warnings`. Correctness lints stay hard
// errors; these style lints are deliberate idioms in this codebase
// (hand-rolled JSON writer, index-heavy tensor kernels, ...).
#![allow(
    clippy::inherent_to_string,
    clippy::needless_range_loop,
    clippy::manual_div_ceil,
    clippy::manual_memcpy
)]

pub mod autotune;
pub mod bench_record;
pub mod bench_support;
pub mod calib;
pub mod cli;
pub mod clip;
pub mod eval;
pub mod kernels;
pub mod miniprop;
pub mod model;
pub mod ocs;
pub mod pipeline;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod stats;
pub mod tables;
pub mod tensor;
pub mod train;
pub mod util;
