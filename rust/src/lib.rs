//! # OCS — Outlier Channel Splitting, reproduced as a deployable stack
//!
//! Rust implementation of *"Improving Neural Network Quantization without
//! Retraining using Outlier Channel Splitting"* (Zhao et al., ICML 2019),
//! structured as the Layer-3 coordinator of a three-layer Rust + JAX +
//! Pallas system:
//!
//! * **L1** (`python/compile/kernels/`) — Pallas kernels: `fake_quant`
//!   (Eq. 1), `channel_dup` (the OCS runtime layer, §3.5), `qmatmul`.
//! * **L2** (`python/compile/model.py`) — JAX benchmark models with OCS
//!   hooks, AOT-lowered once to HLO text under `artifacts/`.
//! * **L3** (this crate) — everything at run time: the quantization
//!   toolchain ([`quant`], [`clip`], [`ocs`]), activation calibration
//!   ([`calib`]), the PJRT runtime ([`runtime`]), training/eval harness
//!   ([`train`], [`eval`]), a dynamic-batching inference server
//!   ([`serve`]) and the paper-table regeneration harness ([`tables`]).
//!
//! Python never runs on the request path: after `make artifacts` the
//! `ocs` binary is self-contained.
//!
//! ## Quick start
//!
//! ```bash
//! make artifacts && cargo build --release
//! target/release/ocs train --model miniresnet   # train through PJRT
//! target/release/ocs table --id 2               # reproduce Table 2
//! cargo run --release --example quickstart
//! ```

pub mod bench_support;
pub mod calib;
pub mod cli;
pub mod clip;
pub mod eval;
pub mod miniprop;
pub mod model;
pub mod ocs;
pub mod pipeline;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod stats;
pub mod tables;
pub mod tensor;
pub mod train;
pub mod util;
