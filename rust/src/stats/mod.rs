//! Streaming statistics: magnitude histograms + running moments.
//!
//! Every clip-threshold optimizer ([`crate::clip`]) works on a
//! [`Histogram`] of absolute values, exactly like the reference
//! implementations (Distiller's MSE sweep, MXNet's KL calibration work on
//! value histograms, ACIQ on fitted moments). The histogram is streaming
//! (activations arrive batch by batch) with power-of-two range doubling
//! so early small-range estimates survive later outliers.

/// Histogram over |x| with linear bins in [0, max], plus running moments
/// of the signed values.
#[derive(Debug, Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    max: f32,
    n: u64,
    sum: f64,
    sumsq: f64,
    sum_abs: f64,
    max_abs: f32,
}

pub const DEFAULT_BINS: usize = 2048;

impl Histogram {
    /// `range_hint` sizes the initial bucket range; it grows on demand.
    pub fn new(bins: usize, range_hint: f32) -> Self {
        assert!(bins >= 2);
        Histogram {
            counts: vec![0; bins],
            max: range_hint.max(1e-12),
            n: 0,
            sum: 0.0,
            sumsq: 0.0,
            sum_abs: 0.0,
            max_abs: 0.0,
        }
    }

    pub fn from_slice(data: &[f32], bins: usize) -> Self {
        Self::from_chunks(std::iter::once(data), bins)
    }

    /// Build from a cloneable iterator of contiguous runs (e.g. a
    /// zero-copy [`crate::tensor::AxisChunks`] channel view) without
    /// materializing them: one pass for the exact range, one to bin.
    /// Non-finite values are skipped in *both* passes — a stray Inf must
    /// not blow up the range (NaN never could: `f32::max` ignores it),
    /// and [`Self::observe`] already refuses them.
    pub fn from_chunks<'a, I>(chunks: I, bins: usize) -> Self
    where
        I: Iterator<Item = &'a [f32]> + Clone,
    {
        let mut max = 0.0f32;
        for run in chunks.clone() {
            for &v in run {
                if v.is_finite() {
                    max = max.max(v.abs());
                }
            }
        }
        let mut h = Histogram::new(bins, max);
        for run in chunks {
            h.observe_all(run);
        }
        h
    }

    pub fn bins(&self) -> usize {
        self.counts.len()
    }
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }
    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn range(&self) -> f32 {
        self.max
    }
    pub fn max_abs(&self) -> f32 {
        self.max_abs
    }
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
    pub fn std(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let m = self.mean();
        (self.sumsq / self.n as f64 - m * m).max(0.0).sqrt()
    }
    /// E|x| — the Laplace scale estimator ACIQ uses is E|x - mu|, but the
    /// benchmark distributions are zero-centred so E|x| suffices; the
    /// signed mean is available for callers that need to re-centre.
    pub fn mean_abs(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum_abs / self.n as f64
        }
    }

    /// Bin width under the current range.
    pub fn bin_width(&self) -> f32 {
        self.max / self.counts.len() as f32
    }

    /// Midpoint magnitude of bin i.
    pub fn bin_center(&self, i: usize) -> f32 {
        (i as f32 + 0.5) * self.bin_width()
    }

    #[inline]
    pub fn observe(&mut self, v: f32) {
        if !v.is_finite() {
            return;
        }
        let a = v.abs();
        self.n += 1;
        self.sum += v as f64;
        self.sumsq += (v as f64) * (v as f64);
        self.sum_abs += a as f64;
        if a > self.max_abs {
            self.max_abs = a;
        }
        while a > self.max {
            self.double_range();
        }
        let bins = self.counts.len();
        let mut idx = (a / self.max * bins as f32) as usize;
        if idx >= bins {
            idx = bins - 1; // a == max edge case
        }
        self.counts[idx] += 1;
    }

    pub fn observe_all(&mut self, data: &[f32]) {
        for &v in data {
            self.observe(v);
        }
    }

    /// Double the range, folding pairs of bins together (halves
    /// resolution of the existing mass but keeps it countable).
    fn double_range(&mut self) {
        let bins = self.counts.len();
        let mut folded = vec![0u64; bins];
        for i in 0..bins {
            folded[i / 2] += self.counts[i];
        }
        self.counts = folded;
        self.max *= 2.0;
    }

    /// Merge another histogram (e.g. per-batch partials). The receiver's
    /// range grows (by doubling) until it covers the other's, then the
    /// other's mass is re-binned by bin center — ranges that grew from
    /// different starting points never align exactly, so proportional
    /// re-binning (error <= the other's bin width) is the correct move.
    pub fn merge(&mut self, other: &Histogram) {
        assert_eq!(self.bins(), other.bins(), "merge: bin count mismatch");
        if other.n == 0 {
            // an empty partial carries no mass but may carry a large
            // `range_hint` — growing to cover it would halve the
            // receiver's resolution for nothing (the batch-parallel
            // calibration path hands out empty tail partials routinely)
            return;
        }
        while self.max < other.max {
            self.double_range();
        }
        let bins = self.counts.len();
        for (i, &c) in other.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let center = other.bin_center(i);
            let mut idx = (center / self.max * bins as f32) as usize;
            if idx >= bins {
                idx = bins - 1;
            }
            self.counts[idx] += c;
        }
        self.n += other.n;
        self.sum += other.sum;
        self.sumsq += other.sumsq;
        self.sum_abs += other.sum_abs;
        self.max_abs = self.max_abs.max(other.max_abs);
    }

    /// Magnitude below which fraction `p` (0..1) of samples fall
    /// (linear interpolation inside the bin).
    pub fn percentile_abs(&self, p: f64) -> f32 {
        if self.n == 0 {
            return 0.0;
        }
        let target = p.clamp(0.0, 1.0) * self.n as f64;
        let mut acc = 0.0f64;
        for (i, &c) in self.counts.iter().enumerate() {
            let next = acc + c as f64;
            if next >= target && c > 0 {
                let frac = ((target - acc) / c as f64).clamp(0.0, 1.0);
                return (i as f64 + frac) as f32 * self.bin_width();
            }
            acc = next;
        }
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments() {
        let data = vec![1.0, -1.0, 3.0, -3.0];
        let h = Histogram::from_slice(&data, 64);
        assert_eq!(h.count(), 4);
        assert!((h.mean() - 0.0).abs() < 1e-9);
        assert!((h.mean_abs() - 2.0).abs() < 1e-9);
        assert!((h.std() - (5.0f64).sqrt()).abs() < 1e-6);
        assert_eq!(h.max_abs(), 3.0);
    }

    #[test]
    fn binning_and_range() {
        let h = Histogram::from_slice(&[0.1, 0.5, 0.9, 1.0], 10);
        assert_eq!(h.range(), 1.0);
        assert_eq!(h.counts().iter().sum::<u64>(), 4);
        // 0.9 and the 1.0 range-edge value both land in the last bin
        assert_eq!(h.counts()[9], 2);
    }

    #[test]
    fn streaming_range_doubling_preserves_mass() {
        let mut h = Histogram::new(16, 1.0);
        for i in 0..100 {
            h.observe(i as f32 * 0.01); // within [0,1)
        }
        h.observe(7.3); // forces doubling to 8.0
        assert_eq!(h.count(), 101);
        assert_eq!(h.counts().iter().sum::<u64>(), 101);
        assert!(h.range() >= 7.3);
        assert_eq!(h.max_abs(), 7.3);
    }

    #[test]
    fn merge_aligns_ranges() {
        let a_data: Vec<f32> = (0..50).map(|i| i as f32 * 0.01).collect();
        let b_data: Vec<f32> = (0..50).map(|i| i as f32 * 0.1).collect();
        let mut a = Histogram::from_slice(&a_data, 32);
        let b = Histogram::from_slice(&b_data, 32);
        let an = a.count();
        a.merge(&b);
        assert_eq!(a.count(), an + b.count());
        assert_eq!(a.counts().iter().sum::<u64>(), 100);
        assert!(a.range() >= 4.9);
    }

    #[test]
    fn merging_empty_partial_keeps_resolution() {
        // regression: merging an empty partial whose range_hint exceeded
        // the receiver's range doubled the receiver until it covered the
        // hint — zero new samples, resolution halved six times here.
        let data: Vec<f32> = (0..64).map(|i| i as f32 / 64.0).collect();
        let mut h = Histogram::from_slice(&data, 32);
        let range = h.range();
        let width = h.bin_width();
        let counts = h.counts().to_vec();
        h.merge(&Histogram::new(32, 64.0)); // empty, big hint
        assert_eq!(h.range(), range);
        assert_eq!(h.bin_width(), width);
        assert_eq!(h.counts(), counts.as_slice());
        assert_eq!(h.count(), 64);
        // a *non-empty* partial with a larger range must still grow it
        let mut tail = Histogram::new(32, 64.0);
        tail.observe(48.0);
        h.merge(&tail);
        assert!(h.range() >= 48.0);
        assert_eq!(h.count(), 65);
    }

    #[test]
    fn percentile() {
        let data: Vec<f32> = (1..=1000).map(|i| i as f32 / 1000.0).collect();
        let h = Histogram::from_slice(&data, 2048);
        let p50 = h.percentile_abs(0.5);
        let p99 = h.percentile_abs(0.99);
        assert!((p50 - 0.5).abs() < 0.01, "p50 {p50}");
        assert!((p99 - 0.99).abs() < 0.01, "p99 {p99}");
        assert!(h.percentile_abs(1.0) >= 0.999);
    }

    #[test]
    fn ignores_non_finite() {
        let mut h = Histogram::new(8, 1.0);
        h.observe(f32::NAN);
        h.observe(f32::INFINITY);
        h.observe(0.5);
        assert_eq!(h.count(), 1);
    }

    #[test]
    fn from_slice_survives_non_finite_range_scan() {
        // regression: an Inf in the data used to poison the range pass
        // (max became Inf, so bin_width and every percentile were NaN);
        // a NaN was survivable only by accident of f32::max semantics.
        let data = vec![0.1f32, f32::INFINITY, 0.9, f32::NAN, -0.5, f32::NEG_INFINITY];
        let h = Histogram::from_slice(&data, 64);
        assert_eq!(h.count(), 3);
        assert_eq!(h.range(), 0.9);
        assert_eq!(h.max_abs(), 0.9);
        assert!(h.bin_width().is_finite());
        assert!(h.percentile_abs(0.99) <= 0.9 + 1e-6);
        assert_eq!(h.counts().iter().sum::<u64>(), 3);
    }

    #[test]
    fn from_chunks_equals_from_slice() {
        let data: Vec<f32> = (0..96).map(|i| (i as f32 * 0.37).sin() * 3.0).collect();
        let whole = Histogram::from_slice(&data, 128);
        let runs: Vec<&[f32]> = data.chunks(7).collect();
        let chunked = Histogram::from_chunks(runs.iter().copied(), 128);
        assert_eq!(whole.counts(), chunked.counts());
        assert_eq!(whole.count(), chunked.count());
        assert_eq!(whole.range(), chunked.range());
        assert_eq!(whole.max_abs(), chunked.max_abs());
    }
}
