//! Fused calibration statistics.
//!
//! The calibration pass used to sweep each activation batch three times
//! (streaming histogram, per-channel max, per-channel outlier counts —
//! the last one computing `i % c` per element). [`fused_stats`] does the
//! histogram and channel maxima in one row-chunked sweep, and
//! [`outlier_counts`] replaces the modulo walk with `chunks_exact(c)`
//! rows so the channel index is just the position inside the row.
//!
//! Non-finite values are skipped *everywhere*: a single NaN or Inf in an
//! activation batch must not poison the histogram range, the channel
//! maxima, or the outlier ranking (regression-tested here and in
//! [`crate::stats`]).
//!
//! [`layer_stats`] is the per-layer calibration aggregate: phase A runs
//! the fused sweep per batch in parallel on the kernel pool and folds
//! the partials **in batch order** (histograms all start from the same
//! power-of-two range ladder, so the merge is exact); phase B counts
//! outliers per batch against the layer-wide percentile threshold and
//! folds in batch order too. The fold order is what makes `threads = 1`
//! and `threads = N` produce bit-identical results.

use crate::kernels::pool;
use crate::stats::Histogram;
use crate::tensor::TensorF;

/// Single-sweep statistics over one `(rows, c)`-shaped buffer.
#[derive(Debug, Clone)]
pub struct FusedStats {
    pub hist: Histogram,
    /// max |x| per trailing channel (finite values only).
    pub channel_max: Vec<f32>,
    /// Per-channel count of finite |x| > thr; `None` when no threshold
    /// was supplied.
    pub outlier_counts: Option<Vec<u64>>,
}

/// One chunked sweep over `data` (laid out as rows of `c` trailing
/// channels): magnitude histogram + moments, per-channel maxima, and —
/// when `outlier_thr` is known up front — per-channel outlier counts.
pub fn fused_stats(
    data: &[f32],
    c: usize,
    bins: usize,
    range_hint: f32,
    outlier_thr: Option<f32>,
) -> FusedStats {
    assert!(c > 0, "fused_stats: zero channels");
    let mut hist = Histogram::new(bins, range_hint);
    let mut channel_max = vec![0.0f32; c];
    // counts are only touched under `Some(thr)`; skip the allocation on
    // the common phase-A path where the threshold is not yet known
    let mut counts = vec![0u64; if outlier_thr.is_some() { c } else { 0 }];
    let mut rows = data.chunks_exact(c);
    for row in rows.by_ref() {
        fused_row(row, &mut hist, &mut channel_max, &mut counts, outlier_thr);
    }
    // ragged tail — activations are (batch.., c) so this is normally empty
    fused_row(
        rows.remainder(),
        &mut hist,
        &mut channel_max,
        &mut counts,
        outlier_thr,
    );
    FusedStats {
        hist,
        channel_max,
        outlier_counts: outlier_thr.map(|_| counts),
    }
}

#[inline]
fn fused_row(
    row: &[f32],
    hist: &mut Histogram,
    channel_max: &mut [f32],
    counts: &mut [u64],
    outlier_thr: Option<f32>,
) {
    for (j, &v) in row.iter().enumerate() {
        if !v.is_finite() {
            continue;
        }
        let a = v.abs();
        if a > channel_max[j] {
            channel_max[j] = a;
        }
        if let Some(t) = outlier_thr {
            if a > t {
                counts[j] += 1;
            }
        }
        hist.observe(v);
    }
}

/// Per-trailing-channel count of finite |x| > thr, row-chunked — the
/// channel index is the position inside each `chunks_exact(c)` row, not
/// an `i % c` per element.
pub fn outlier_counts(data: &[f32], c: usize, thr: f32) -> Vec<u64> {
    assert!(c > 0, "outlier_counts: zero channels");
    let mut counts = vec![0u64; c];
    let mut rows = data.chunks_exact(c);
    for row in rows.by_ref() {
        for (j, &v) in row.iter().enumerate() {
            if v.is_finite() && v.abs() > thr {
                counts[j] += 1;
            }
        }
    }
    for (j, &v) in rows.remainder().iter().enumerate() {
        if v.is_finite() && v.abs() > thr {
            counts[j] += 1;
        }
    }
    counts
}

/// Per-layer calibration aggregate (the §5.3 statistics).
#[derive(Debug, Clone)]
pub struct LayerStats {
    pub hist: Histogram,
    pub channel_max: Vec<f32>,
    pub outlier_counts: Vec<u64>,
    /// The layer-wide percentile magnitude the counts were taken at.
    pub outlier_threshold: f32,
}

/// Two-phase layer statistics over calibration `batches` (each shaped
/// `(.., c)`), parallel across batches with deterministic batch-order
/// merges: identical results at any thread count (0 = default width).
/// Uses range hint 1.0, matching the pre-kernels streaming pass.
pub fn layer_stats(
    batches: &[TensorF],
    bins: usize,
    outlier_pct: f64,
    threads: usize,
) -> LayerStats {
    layer_stats_hinted(batches, bins, outlier_pct, threads, 1.0)
}

/// [`layer_stats`] with an explicit histogram range hint. Pass the
/// exact max |x| for single-batch "oracle" statistics (full bin
/// resolution, like a `Histogram::from_slice` on the batch); for
/// multi-batch runs keep one shared hint — the exact power-of-two merge
/// alignment only holds when every partial grows from the same hint.
pub fn layer_stats_hinted(
    batches: &[TensorF],
    bins: usize,
    outlier_pct: f64,
    threads: usize,
    range_hint: f32,
) -> LayerStats {
    assert!(!batches.is_empty(), "layer_stats: no batches");
    let c = *batches[0].shape().last().expect("rank >= 1");
    // phase A: fused histogram + channel maxima per batch. Every partial
    // histogram starts from the same range hint, so all ranges live on
    // one power-of-two ladder and the merges below re-bin exactly.
    let partials = pool::map_indexed_with(threads, batches.len(), |i| {
        debug_assert_eq!(*batches[i].shape().last().unwrap(), c);
        fused_stats(batches[i].data(), c, bins, range_hint, None)
    });
    let mut iter = partials.into_iter();
    let first = iter.next().expect("at least one batch");
    let mut hist = first.hist;
    let mut channel_max = first.channel_max;
    for p in iter {
        hist.merge(&p.hist);
        for (m, v) in channel_max.iter_mut().zip(&p.channel_max) {
            *m = m.max(*v);
        }
    }
    let thr = hist.percentile_abs(outlier_pct);
    // phase B: outlier counts per batch at the layer threshold
    let per_batch = pool::map_indexed_with(threads, batches.len(), |i| {
        outlier_counts(batches[i].data(), c, thr)
    });
    let mut counts = vec![0u64; c];
    for cb in per_batch {
        for (a, b) in counts.iter_mut().zip(&cb) {
            *a += *b;
        }
    }
    LayerStats {
        hist,
        channel_max,
        outlier_counts: counts,
        outlier_threshold: thr,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn fused_matches_separate_sweeps() {
        let mut rng = Rng::new(11);
        let data = rng.normal_vec(32 * 12);
        let t = TensorF::from_vec(&[32, 12], data.clone()).unwrap();
        let fused = fused_stats(&data, 12, 256, 1.0, Some(0.9));
        assert_eq!(fused.channel_max, t.max_abs_per_axis(1).unwrap());
        assert_eq!(
            fused.outlier_counts.as_deref().unwrap(),
            &outlier_counts(&data, 12, 0.9)[..]
        );
        assert_eq!(fused.hist.count(), data.len() as u64);
        let mut reference = Histogram::new(256, 1.0);
        reference.observe_all(&data);
        assert_eq!(fused.hist.counts(), reference.counts());
    }

    #[test]
    fn outlier_counts_equal_modulo_walk_including_ragged_tail() {
        let mut rng = Rng::new(12);
        for len in [60usize, 61, 64, 7] {
            let data = rng.normal_vec(len);
            let c = 5;
            let got = outlier_counts(&data, c, 0.5);
            let mut want = vec![0u64; c];
            for (i, &v) in data.iter().enumerate() {
                if v.abs() > 0.5 {
                    want[i % c] += 1;
                }
            }
            assert_eq!(got, want, "len {len}");
        }
    }

    #[test]
    fn non_finite_values_are_skipped() {
        let data = vec![1.0f32, f32::NAN, f32::INFINITY, -2.0, f32::NEG_INFINITY, 0.5];
        let s = fused_stats(&data, 3, 64, 1.0, Some(0.75));
        // channels: [1.0, NAN, INF] / [-2.0, -INF, 0.5]
        assert_eq!(s.channel_max, vec![2.0, 0.0, 0.5]);
        assert_eq!(s.outlier_counts.unwrap(), vec![2, 0, 0]);
        assert_eq!(s.hist.count(), 3, "only the three finite values count");
        assert!(s.hist.range().is_finite());
        assert_eq!(outlier_counts(&data, 3, 0.75), vec![2, 0, 0]);
    }

    #[test]
    fn layer_stats_aggregates_batches() {
        let mut rng = Rng::new(13);
        let mut batches = Vec::new();
        for _ in 0..4 {
            let mut v = rng.normal_vec(16 * 8);
            v[3] = 40.0; // channel-3 outlier in every batch
            batches.push(TensorF::from_vec(&[16, 8], v).unwrap());
        }
        let s = layer_stats(&batches, 512, 0.99, 1);
        assert_eq!(s.channel_max.len(), 8);
        assert_eq!(s.outlier_counts.len(), 8);
        assert_eq!(s.hist.count(), (4 * 16 * 8) as u64);
        assert!(s.channel_max[3] >= 40.0);
        let top = crate::calib::top_k_channels(&s.outlier_counts, 1);
        assert_eq!(top, vec![3], "planted outlier channel must rank first");
        assert!(s.outlier_threshold > 0.0);
    }

    #[test]
    fn layer_stats_bit_identical_across_thread_counts() {
        let mut rng = Rng::new(14);
        let batches: Vec<TensorF> = (0..6)
            .map(|_| TensorF::from_vec(&[8, 16], rng.normal_vec(8 * 16)).unwrap())
            .collect();
        let s1 = layer_stats(&batches, 256, 0.99, 1);
        for threads in [2usize, 4, 8] {
            let sn = layer_stats(&batches, 256, 0.99, threads);
            assert_eq!(s1.hist.counts(), sn.hist.counts(), "threads {threads}");
            assert_eq!(s1.hist.count(), sn.hist.count());
            assert_eq!(s1.hist.mean().to_bits(), sn.hist.mean().to_bits());
            assert_eq!(s1.hist.std().to_bits(), sn.hist.std().to_bits());
            let b1: Vec<u32> = s1.channel_max.iter().map(|v| v.to_bits()).collect();
            let bn: Vec<u32> = sn.channel_max.iter().map(|v| v.to_bits()).collect();
            assert_eq!(b1, bn);
            assert_eq!(s1.outlier_counts, sn.outlier_counts);
            assert_eq!(
                s1.outlier_threshold.to_bits(),
                sn.outlier_threshold.to_bits()
            );
        }
    }
}
