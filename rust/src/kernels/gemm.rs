//! Native integer GEMM — the i8×i8→i32 matrix kernel under the native
//! inference backend ([`crate::runtime::native`]).
//!
//! The paper's deployment pitch is that an OCS-split model is a plain
//! quantized model, servable on commodity integer hardware. This module
//! is that datapath in Rust: weights land as packed `i8` panels
//! ([`PackedB`], built once per prepared layer by
//! [`crate::quant::pack`]), activations arrive as `i8` rows, and the
//! kernel accumulates in `i32` with a fused per-output-channel
//! dequantize + bias epilogue — one pass from integer accumulators back
//! to `f32` activations.
//!
//! ## Blocking and packing
//!
//! * **B panels** ([`PackedB`]): the weight matrix `(k, n)` is repacked
//!   into column panels of width [`NR`], each panel laid out k-major
//!   (`panel[kk * NR + j]`), so the microkernel streams both operands
//!   contiguously. Ragged right edges are zero-padded — `0 * x == 0`
//!   in integer arithmetic, so padding never changes a result.
//! * **Row blocks** (`MB` rows): the parallel unit. Each block owns a
//!   disjoint slice of the output, so blocks run race-free on the
//!   kernel pool ([`super::pool`]); integer accumulation is exact, so
//!   any thread count is bit-identical to serial *by arithmetic*, not
//!   just by ordering discipline.
//! * **K blocks** (`KC` deep): panels are walked in depth slices so
//!   the active panel slice plus the A row block stay cache-resident on
//!   long inner dimensions.
//!
//! The f32 twins ([`gemm_f32_ref`], [`gemm_f32`]) carry the layers the
//! integer path cannot (float activations, >8-bit weights) and serve as
//! the bit-exactness reference for the parallel split: the parallel f32
//! kernel keeps the serial per-row accumulation order, so it too is
//! bit-identical at every width.
//!
//! Overflow: each product is at most `127² = 16129`, so `i32`
//! accumulators are exact for any `k <= 133_000` — far beyond every
//! layer in this repo ([`PackedB::pack`] asserts the bound).

use super::pool;

/// Packed panel width (output channels per microkernel tile).
pub const NR: usize = 16;
/// Depth of one K block (i8 panel slice: `KC * NR` = 4 KiB).
const KC: usize = 256;
/// Rows of A per parallel work item.
const MB: usize = 32;

/// Largest inner dimension the i32 accumulator provably cannot
/// overflow: `k * 127 * 127 <= i32::MAX`.
pub const MAX_K: usize = (i32::MAX / (127 * 127)) as usize;

/// Raw output pointer smuggled into the per-block closures. Safety
/// rests on the disjoint row-block partition at each use site.
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Weight matrix `(k, n)` repacked into k-major column panels of width
/// [`NR`], ready for [`gemm_i8`] / [`gemm_i8_dequant`]. Built once per
/// prepared layer, reused for every batch.
#[derive(Debug, Clone)]
pub struct PackedB {
    pub k: usize,
    pub n: usize,
    /// `ceil(n / NR)` panels, each `k * NR` bytes; ragged columns zero.
    data: Vec<i8>,
}

impl PackedB {
    /// Pack a row-major `(k, n)` i8 matrix.
    pub fn pack(b: &[i8], k: usize, n: usize) -> PackedB {
        assert_eq!(b.len(), k * n, "pack_b geometry mismatch");
        assert!(k <= MAX_K, "inner dim {k} risks i32 overflow");
        let panels = n.div_ceil(NR);
        let mut data = vec![0i8; panels * k * NR];
        for p in 0..panels {
            let j0 = p * NR;
            let w = NR.min(n - j0);
            let base = p * k * NR;
            for kk in 0..k {
                for jj in 0..w {
                    data[base + kk * NR + jj] = b[kk * n + j0 + jj];
                }
            }
        }
        PackedB { k, n, data }
    }

    #[inline]
    fn panel(&self, p: usize) -> &[i8] {
        &self.data[p * self.k * NR..(p + 1) * self.k * NR]
    }

    /// Packed payload size in bytes (diagnostics).
    pub fn packed_bytes(&self) -> usize {
        self.data.len()
    }
}

/// Naive serial reference: `out[i][j] = Σ_k a[i][k] * b[k][j]` in i32.
/// This is the ground truth the packed/parallel kernel must match
/// exactly (and the fixed baseline `benches/gemm.rs` times against).
pub fn gemm_i8_ref(a: &[i8], b: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    assert_eq!(a.len(), m * k, "A geometry mismatch");
    assert_eq!(b.len(), k * n, "B geometry mismatch");
    let mut out = vec![0i32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0i32;
            for kk in 0..k {
                acc += a[i * k + kk] as i32 * b[kk * n + j] as i32;
            }
            out[i * n + j] = acc;
        }
    }
    out
}

/// One row block `[i0, i1)` of A against every panel of B, accumulated
/// into `out` (the block's `(i1 - i0) * n` slice, assumed zeroed).
fn gemm_i8_block(a: &[i8], i0: usize, i1: usize, pb: &PackedB, out: &mut [i32]) {
    let (k, n) = (pb.k, pb.n);
    let panels = n.div_ceil(NR);
    let mut kc0 = 0usize;
    while kc0 < k {
        let kc1 = k.min(kc0 + KC);
        for p in 0..panels {
            let panel = pb.panel(p);
            let j0 = p * NR;
            let w = NR.min(n - j0);
            for i in i0..i1 {
                let arow = &a[i * k + kc0..i * k + kc1];
                let mut acc = [0i32; NR];
                for (kk, &av) in arow.iter().enumerate() {
                    let av = av as i32;
                    let prow = &panel[(kc0 + kk) * NR..(kc0 + kk) * NR + NR];
                    for jj in 0..NR {
                        acc[jj] += av * prow[jj] as i32;
                    }
                }
                let orow = &mut out[(i - i0) * n + j0..(i - i0) * n + j0 + w];
                for jj in 0..w {
                    orow[jj] += acc[jj];
                }
            }
        }
        kc0 = kc1;
    }
}

/// Packed, row-block-parallel i8 GEMM: `(m, k) × (k, n) → (m, n)` i32.
/// Bit-identical to [`gemm_i8_ref`] at every thread count (`threads`
/// = 0 for the pool's default width) — integer accumulation is exact.
pub fn gemm_i8(a: &[i8], pb: &PackedB, m: usize, threads: usize) -> Vec<i32> {
    let n = pb.n;
    assert_eq!(a.len(), m * pb.k, "A geometry mismatch");
    let mut out = vec![0i32; m * n];
    if m == 0 || n == 0 {
        return out;
    }
    let nblocks = m.div_ceil(MB);
    let base = SendPtr(out.as_mut_ptr());
    pool::map_indexed_with(threads, nblocks, |blk| {
        let i0 = blk * MB;
        let i1 = m.min(i0 + MB);
        // SAFETY: `out` is exclusively borrowed for the whole call and
        // row blocks tile it without overlap; block `blk` is the only
        // task touching rows [i0, i1).
        let out_blk =
            unsafe { std::slice::from_raw_parts_mut(base.0.add(i0 * n), (i1 - i0) * n) };
        gemm_i8_block(a, i0, i1, pb, out_blk);
    });
    out
}

/// [`gemm_i8`] with the dequantize + bias epilogue fused per row block:
/// `out[i][j] = acc[i][j] as f32 * scales[j] + bias[j]`.
///
/// `scales[j]` is the combined grid step of output channel `j`
/// (activation delta × weight delta); the i32 accumulators never
/// round-trip through memory as a full matrix — each block dequantizes
/// its own rows while they are still cache-hot.
pub fn gemm_i8_dequant(
    a: &[i8],
    pb: &PackedB,
    m: usize,
    scales: &[f32],
    bias: &[f32],
    threads: usize,
) -> Vec<f32> {
    let n = pb.n;
    assert_eq!(a.len(), m * pb.k, "A geometry mismatch");
    assert_eq!(scales.len(), n, "scales per output channel");
    assert_eq!(bias.len(), n, "bias per output channel");
    let mut out = vec![0.0f32; m * n];
    if m == 0 || n == 0 {
        return out;
    }
    let nblocks = m.div_ceil(MB);
    let base = SendPtr(out.as_mut_ptr());
    pool::map_indexed_with(threads, nblocks, |blk| {
        let i0 = blk * MB;
        let i1 = m.min(i0 + MB);
        let rows = i1 - i0;
        let mut acc = vec![0i32; rows * n];
        gemm_i8_block(a, i0, i1, pb, &mut acc);
        // SAFETY: disjoint row blocks, as in `gemm_i8`.
        let out_blk = unsafe { std::slice::from_raw_parts_mut(base.0.add(i0 * n), rows * n) };
        for r in 0..rows {
            for j in 0..n {
                out_blk[r * n + j] = acc[r * n + j] as f32 * scales[j] + bias[j];
            }
        }
    });
    out
}

/// Naive serial f32 reference GEMM (`bias` broadcast per output column
/// when given). Kept for bit-exactness checks of the parallel split.
pub fn gemm_f32_ref(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    bias: Option<&[f32]>,
) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "A geometry mismatch");
    assert_eq!(b.len(), k * n, "B geometry mismatch");
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let orow = &mut out[i * n..(i + 1) * n];
        if let Some(bias) = bias {
            orow.copy_from_slice(bias);
        }
        for kk in 0..k {
            let av = a[i * k + kk];
            let brow = &b[kk * n..(kk + 1) * n];
            for j in 0..n {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// Row-block-parallel f32 GEMM for the layers the integer path cannot
/// carry (float activations, >8-bit weight grids). The inner loop is
/// the exact per-row accumulation order of [`gemm_f32_ref`], so every
/// thread count is bit-identical to the serial reference.
pub fn gemm_f32(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    bias: Option<&[f32]>,
    threads: usize,
) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "A geometry mismatch");
    assert_eq!(b.len(), k * n, "B geometry mismatch");
    let mut out = vec![0.0f32; m * n];
    if m == 0 || n == 0 {
        return out;
    }
    let nblocks = m.div_ceil(MB);
    let base = SendPtr(out.as_mut_ptr());
    pool::map_indexed_with(threads, nblocks, |blk| {
        let i0 = blk * MB;
        let i1 = m.min(i0 + MB);
        // SAFETY: disjoint row blocks, as in `gemm_i8`.
        let out_blk =
            unsafe { std::slice::from_raw_parts_mut(base.0.add(i0 * n), (i1 - i0) * n) };
        for i in i0..i1 {
            let orow = &mut out_blk[(i - i0) * n..(i - i0 + 1) * n];
            if let Some(bias) = bias {
                orow.copy_from_slice(bias);
            }
            for kk in 0..k {
                let av = a[i * k + kk];
                let brow = &b[kk * n..(kk + 1) * n];
                for j in 0..n {
                    orow[j] += av * brow[j];
                }
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_i8(rng: &mut Rng, n: usize) -> Vec<i8> {
        (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect()
    }

    #[test]
    fn pack_roundtrips_all_columns() {
        let mut rng = Rng::new(1);
        for &(k, n) in &[(3usize, 1usize), (5, 16), (7, 17), (4, 33)] {
            let b = rand_i8(&mut rng, k * n);
            let pb = PackedB::pack(&b, k, n);
            for j in 0..n {
                let p = j / NR;
                let jj = j % NR;
                for kk in 0..k {
                    assert_eq!(
                        pb.panel(p)[kk * NR + jj],
                        b[kk * n + j],
                        "k={k} n={n} ({kk},{j})"
                    );
                }
            }
            assert_eq!(pb.packed_bytes(), n.div_ceil(NR) * k * NR);
        }
    }

    #[test]
    fn packed_matches_naive_exactly() {
        let mut rng = Rng::new(2);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (2, 3, 5), (17, 40, 19), (33, 300, 37)] {
            let a = rand_i8(&mut rng, m * k);
            let b = rand_i8(&mut rng, k * n);
            let want = gemm_i8_ref(&a, &b, m, k, n);
            let pb = PackedB::pack(&b, k, n);
            for threads in [1usize, 4] {
                assert_eq!(gemm_i8(&a, &pb, m, threads), want, "{m}x{k}x{n} t{threads}");
            }
        }
    }

    #[test]
    fn kc_blocking_boundary_is_exact() {
        // k straddling the KC block edge exercises the partial-block path
        let mut rng = Rng::new(3);
        let (m, k, n) = (3usize, KC + 7, 5usize);
        let a = rand_i8(&mut rng, m * k);
        let b = rand_i8(&mut rng, k * n);
        let pb = PackedB::pack(&b, k, n);
        assert_eq!(gemm_i8(&a, &pb, m, 1), gemm_i8_ref(&a, &b, m, k, n));
    }

    #[test]
    fn dequant_epilogue_scales_per_channel() {
        let mut rng = Rng::new(4);
        let (m, k, n) = (5usize, 12usize, 9usize);
        let a = rand_i8(&mut rng, m * k);
        let b = rand_i8(&mut rng, k * n);
        let scales: Vec<f32> = (0..n).map(|j| 0.01 + j as f32 * 0.001).collect();
        let bias: Vec<f32> = (0..n).map(|j| j as f32 * 0.5).collect();
        let pb = PackedB::pack(&b, k, n);
        let acc = gemm_i8_ref(&a, &b, m, k, n);
        for threads in [1usize, 4] {
            let out = gemm_i8_dequant(&a, &pb, m, &scales, &bias, threads);
            for i in 0..m {
                for j in 0..n {
                    let want = acc[i * n + j] as f32 * scales[j] + bias[j];
                    assert_eq!(out[i * n + j].to_bits(), want.to_bits(), "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn f32_parallel_bit_identical_to_ref() {
        let mut rng = Rng::new(5);
        let (m, k, n) = (70usize, 33usize, 21usize);
        let a = rng.normal_vec(m * k);
        let b = rng.normal_vec(k * n);
        let bias = rng.normal_vec(n);
        let want = gemm_f32_ref(&a, &b, m, k, n, Some(bias.as_slice()));
        for threads in [1usize, 2, 8] {
            let got = gemm_f32(&a, &b, m, k, n, Some(bias.as_slice()), threads);
            let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(wb, gb, "threads {threads}");
        }
    }

    #[test]
    fn empty_shapes() {
        let pb = PackedB::pack(&[], 0, 4);
        assert!(gemm_i8(&[], &pb, 0, 4).is_empty());
        let pb2 = PackedB::pack(&[1, 2, 3], 3, 1);
        assert_eq!(gemm_i8(&[], &pb2, 0, 1), Vec::<i32>::new());
        assert!(gemm_f32(&[], &[], 0, 0, 0, None, 2).is_empty());
    }

    #[test]
    fn saturated_inputs_do_not_overflow() {
        // worst case: every operand at ±127 over a long k
        let (m, k, n) = (2usize, 4096usize, 3usize);
        let a = vec![127i8; m * k];
        let b = vec![-127i8; k * n];
        let pb = PackedB::pack(&b, k, n);
        let out = gemm_i8(&a, &pb, m, 2);
        assert!(out.iter().all(|&v| v == -(127 * 127 * k as i32)));
    }
}
