//! Process-wide scoped thread pool for the compute kernels (std-only;
//! rayon is unavailable offline).
//!
//! One pool per process, spun up lazily like
//! [`crate::runtime::HloTextCache`]: `cores - 1` detached workers plus
//! the calling thread, so a `map_indexed` at the default width uses
//! exactly one thread per core. The only parallel primitive is
//! [`map_indexed`] — run `f(i)` for `i in 0..n` across the pool and
//! return the results **in index order** — because index-ordered results
//! are what make every parallel kernel bit-identical to its serial run:
//! work distribution is racy (an atomic cursor), but merges downstream
//! always fold in index order, so thread count never changes a result.
//!
//! Nested calls are safe: a caller waiting on its helpers drains other
//! queued jobs instead of blocking, so `map_indexed` inside `map_indexed`
//! cannot deadlock the pool. Panics inside `f` are caught on whichever
//! thread they hit and re-thrown on the caller after the batch quiesces.

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Process-wide parallelism cap: 0 = auto (one thread per core). Set
/// from `--threads` / TOML via [`crate::pipeline::PerfConfig`].
static THREAD_CAP: AtomicUsize = AtomicUsize::new(0);

/// Cap the default width used by [`map_indexed`] (0 = one per core).
/// `set_threads(1)` forces every kernel serial — results do not change
/// (that is tested), only wall-clock does.
pub fn set_threads(n: usize) {
    THREAD_CAP.store(n, Ordering::Relaxed);
}

/// The width [`map_indexed`] uses when no explicit count is given.
/// Deliberately avoids touching the pool: a serial run (`--threads 1`)
/// must never spawn worker threads just to learn its width.
pub fn effective_threads() -> usize {
    match THREAD_CAP.load(Ordering::Relaxed) {
        0 => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
        n => n,
    }
}

/// Maximum concurrent participants: pool workers + the calling thread.
pub fn available() -> usize {
    ThreadPool::global().workers() + 1
}

/// Unit tests that mutate the process-wide cap serialize on this so the
/// default-width assertions cannot race each other.
#[cfg(test)]
pub(crate) fn test_cap_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

struct PoolShared {
    queue: Mutex<VecDeque<Job>>,
    ready: Condvar,
}

/// The pool itself. Construction is private: use [`ThreadPool::global`].
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    workers: usize,
}

impl ThreadPool {
    /// The process-wide instance (workers = cores - 1, spawned once).
    pub fn global() -> &'static ThreadPool {
        static POOL: OnceLock<ThreadPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let cores = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1);
            ThreadPool::new(cores.saturating_sub(1))
        })
    }

    fn new(workers: usize) -> ThreadPool {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
        });
        for i in 0..workers {
            let s = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("ocs-kernel-{i}"))
                .spawn(move || worker_loop(&s))
                .expect("spawn kernel-pool worker");
        }
        ThreadPool { shared, workers }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    fn try_pop(&self) -> Option<Job> {
        self.shared
            .queue
            .lock()
            .expect("kernel pool poisoned")
            .pop_front()
    }
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let job = {
            let mut q = shared.queue.lock().expect("kernel pool poisoned");
            loop {
                match q.pop_front() {
                    Some(j) => break j,
                    None => q = shared.ready.wait(q).expect("kernel pool poisoned"),
                }
            }
        };
        // A panicking job is recorded by its batch; never kill the worker.
        let _ = catch_unwind(AssertUnwindSafe(job));
    }
}

/// One `map_indexed` invocation: an atomic work cursor plus a check-out
/// latch the caller waits on before its stack frame may be reused.
struct Batch<'f, T, F> {
    next: AtomicUsize,
    n: usize,
    f: &'f F,
    results: Mutex<Vec<(usize, T)>>,
    pending: Mutex<usize>,
    done: Condvar,
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

impl<T, F> Batch<'_, T, F>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    /// Pull indices off the cursor until exhausted. The caller runs this
    /// too, so a batch completes even if no helper is ever scheduled.
    fn drain(&self) {
        let mut local: Vec<(usize, T)> = Vec::new();
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                break;
            }
            local.push((i, (self.f)(i)));
        }
        if !local.is_empty() {
            self.results
                .lock()
                .expect("kernel batch poisoned")
                .append(&mut local);
        }
    }

    fn run_helper(&self) {
        if let Err(p) = catch_unwind(AssertUnwindSafe(|| self.drain())) {
            let mut slot = self.panic.lock().expect("kernel batch poisoned");
            if slot.is_none() {
                *slot = Some(p);
            }
        }
        // Checking out is the LAST touch of the batch: the caller frees
        // the batch only after observing pending == 0 under this mutex,
        // which cannot happen before this guard unlocks.
        let mut pending = self.pending.lock().expect("kernel batch poisoned");
        *pending -= 1;
        self.done.notify_all();
    }

    /// Block until every submitted helper job has checked out. While
    /// waiting, drain other queued jobs: our helpers may sit behind a
    /// different batch's jobs (nested maps), and a blind block here
    /// would deadlock the pool.
    fn wait(&self, pool: &ThreadPool) {
        loop {
            if *self.pending.lock().expect("kernel batch poisoned") == 0 {
                return;
            }
            if let Some(job) = pool.try_pop() {
                let _ = catch_unwind(AssertUnwindSafe(job));
                continue;
            }
            let pending = self.pending.lock().expect("kernel batch poisoned");
            if *pending == 0 {
                return;
            }
            let (guard, _timed_out) = self
                .done
                .wait_timeout(pending, Duration::from_millis(1))
                .expect("kernel batch poisoned");
            drop(guard);
        }
    }
}

/// [`map_indexed_with`] at the configured default width.
pub fn map_indexed<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    map_indexed_with(0, n, f)
}

/// Run `f(i)` for `i in 0..n` on up to `threads` threads (0 = default
/// width) and return the results in index order. `threads == 1` runs
/// inline with no pool traffic; any other width is bit-identical to it
/// because each index is computed independently and the results are
/// reassembled by index, never by completion order.
pub fn map_indexed_with<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let requested = if threads == 0 {
        effective_threads()
    } else {
        threads
    };
    // serial runs never instantiate the pool (no idle worker threads)
    if requested <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let pool = ThreadPool::global();
    let participants = requested.clamp(1, n.max(1)).min(pool.workers() + 1);
    if participants <= 1 {
        return (0..n).map(f).collect();
    }
    let helpers = participants - 1;
    let batch = Batch {
        next: AtomicUsize::new(0),
        n,
        f: &f,
        results: Mutex::new(Vec::with_capacity(n)),
        pending: Mutex::new(helpers),
        done: Condvar::new(),
        panic: Mutex::new(None),
    };
    {
        let mut q = pool.shared.queue.lock().expect("kernel pool poisoned");
        for _ in 0..helpers {
            let r: &Batch<'_, T, F> = &batch;
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || r.run_helper());
            // SAFETY: the lifetime is erased to queue the job on the
            // process-wide pool, but `batch.wait` below does not return
            // until every helper has checked out, and a helper's
            // check-out is its final access to the batch — the borrow
            // cannot dangle. Caller-side panics are deferred until after
            // the wait for the same reason.
            let job: Job = unsafe { std::mem::transmute(job) };
            q.push_back(job);
        }
        pool.shared.ready.notify_all();
    }
    let caller = catch_unwind(AssertUnwindSafe(|| batch.drain()));
    batch.wait(pool);
    if let Err(p) = caller {
        resume_unwind(p);
    }
    if let Some(p) = batch.panic.lock().expect("kernel batch poisoned").take() {
        resume_unwind(p);
    }
    let mut slots: Vec<Option<T>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    let mut results = batch.results.into_inner().expect("kernel batch poisoned");
    for (i, v) in results.drain(..) {
        slots[i] = Some(v);
    }
    slots
        .into_iter()
        .map(|v| v.expect("kernel pool lost a result"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_matches_serial_in_order() {
        let serial: Vec<u64> = (0..257).map(|i| (i as u64) * (i as u64)).collect();
        for threads in [1usize, 2, 3, 8] {
            let par = map_indexed_with(threads, 257, |i| (i as u64) * (i as u64));
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_single() {
        assert!(map_indexed_with(4, 0, |i| i).is_empty());
        assert_eq!(map_indexed_with(4, 1, |i| i + 10), vec![10]);
    }

    #[test]
    fn nested_maps_complete() {
        let out = map_indexed_with(4, 6, |i| {
            map_indexed_with(4, 8, move |j| (i * 8 + j) as u64)
                .into_iter()
                .sum::<u64>()
        });
        let expect: Vec<u64> = (0..6)
            .map(|i| (0..8).map(|j| (i * 8 + j) as u64).sum())
            .collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn panic_propagates_and_pool_survives() {
        let r = catch_unwind(|| {
            map_indexed_with(4, 64, |i| {
                if i == 33 {
                    panic!("boom");
                }
                i
            })
        });
        assert!(r.is_err(), "panic in f must reach the caller");
        // the pool still works afterwards
        let v = map_indexed_with(4, 10, |i| i * 2);
        assert_eq!(v, (0..10).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn thread_cap_controls_default_width() {
        let _guard = test_cap_lock();
        set_threads(3);
        assert_eq!(effective_threads(), 3);
        set_threads(0);
        assert_eq!(effective_threads(), available());
        assert!(available() >= 1);
    }
}
