//! Fused, parallel compute kernels — the hot-path layer under the
//! quantization toolchain.
//!
//! Everything per-layer work in [`crate::pipeline`] reduces to runs over
//! the same few access patterns, and this module owns them:
//!
//! * [`stats`] — one-sweep calibration statistics (histogram + channel
//!   maxima + outlier counts) with batch-parallel, deterministic merges.
//! * [`pool`] — the process-wide scoped thread pool (std-only, reused
//!   like [`crate::runtime::HloTextCache`]); its one primitive returns
//!   results in index order so parallel runs are bit-identical to
//!   serial.
//! * [`for_each_channel_chunk_mut`] — channel-parallel in-place
//!   mutation: channels partition the buffer into disjoint strided runs,
//!   so per-channel quantization parallelizes race-free with no copies.
//! * [`split_channel`] — the fused OCS split: one strided pass writes
//!   both halves and returns both post-split maxima, replacing the old
//!   copy + rewrite + two max sweeps (4 passes over the channel → 1).
//! * [`gemm`] — the native integer datapath: packed, row-block-parallel
//!   i8×i8→i32 GEMM with a fused per-output-channel dequantize + bias
//!   epilogue (plus f32 twins for the layers integers cannot carry).
//!   [`crate::runtime::native`] executes whole models on it.
//!
//! Design notes and benchmark methodology: see `README.md` in this
//! directory, `rust/benches/hotpath.rs` (`BENCH_quant.json`), and
//! `rust/benches/gemm.rs` (`BENCH_native.json`).

pub mod gemm;
pub mod pool;
pub mod stats;

use crate::ocs::split::{split_value, SplitMode};

/// Raw base pointer smuggled into the per-channel closures. Safety rests
/// on the channel partition argument in [`for_each_channel_chunk_mut`],
/// not on this wrapper.
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Apply `f(c, run)` to every contiguous run of every channel `c` of a
/// row-major buffer with axis geometry `(outer, alen, inner)` (channel
/// `c` owns the `outer` runs of length `inner` starting at
/// `(o * alen + c) * inner`), with channels dispatched in parallel on
/// the kernel pool (`threads` = 0 for the default width).
///
/// Distinct channels touch disjoint index sets, so the parallel
/// mutation is race-free; within one channel the runs are visited in
/// ascending `o`, exactly like the serial loop it replaces.
pub fn for_each_channel_chunk_mut<F>(
    data: &mut [f32],
    outer: usize,
    alen: usize,
    inner: usize,
    threads: usize,
    f: F,
) where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert_eq!(
        data.len(),
        outer * alen * inner,
        "channel geometry mismatch"
    );
    if outer == 0 || alen == 0 || inner == 0 {
        return;
    }
    let base = SendPtr(data.as_mut_ptr());
    pool::map_indexed_with(threads, alen, |c| {
        for o in 0..outer {
            let start = (o * alen + c) * inner;
            // SAFETY: `data` is exclusively borrowed for the whole call;
            // the (o, c) runs tile it without overlap and this task is
            // the only one touching channel c, so each run is accessed
            // by exactly one thread.
            let run = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), inner) };
            f(c, run);
        }
    });
}

/// Fused OCS channel split (the §3.3 halving, materialized): one strided
/// pass reads channel `src`, writes `dst = hi(w)` and `src = lo(w)`, and
/// accumulates both post-split max |x| on the way through. Bit-identical
/// to the former `axis_copy_with` + `axis_map_mut` + two `axis_max_abs`
/// sweeps, in a quarter of the memory traffic.
#[allow(clippy::too_many_arguments)]
pub fn split_channel(
    data: &mut [f32],
    outer: usize,
    alen: usize,
    inner: usize,
    src: usize,
    dst: usize,
    delta: f32,
    mode: SplitMode,
) -> (f32, f32) {
    assert_eq!(
        data.len(),
        outer * alen * inner,
        "channel geometry mismatch"
    );
    assert!(src < alen && dst < alen, "split channel out of range");
    assert_ne!(src, dst, "split onto itself");
    let mut max_lo = 0.0f32;
    let mut max_hi = 0.0f32;
    for o in 0..outer {
        let sbase = (o * alen + src) * inner;
        let dbase = (o * alen + dst) * inner;
        for k in 0..inner {
            let (lo, hi) = split_value(data[sbase + k], delta, mode);
            data[sbase + k] = lo;
            data[dbase + k] = hi;
            let la = lo.abs();
            if la > max_lo {
                max_lo = la;
            }
            let ha = hi.abs();
            if ha > max_hi {
                max_hi = ha;
            }
        }
    }
    (max_lo, max_hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::TensorF;
    use crate::util::rng::Rng;

    #[test]
    fn channel_partition_touches_every_element_once() {
        // write channel index + visit count into each slot
        let (outer, alen, inner) = (3usize, 5usize, 4usize);
        let mut data = vec![0.0f32; outer * alen * inner];
        for threads in [1usize, 4] {
            data.iter_mut().for_each(|v| *v = 0.0);
            for_each_channel_chunk_mut(&mut data, outer, alen, inner, threads, |c, run| {
                for v in run {
                    *v += 1.0 + c as f32;
                }
            });
            for (i, &v) in data.iter().enumerate() {
                let c = (i / inner) % alen;
                assert_eq!(v, 1.0 + c as f32, "slot {i}");
            }
        }
    }

    #[test]
    fn split_channel_matches_generic_ops() {
        let mut rng = Rng::new(21);
        for mode in [SplitMode::Naive, SplitMode::QuantAware] {
            let w = TensorF::from_vec(&[4, 6, 3], rng.normal_vec(4 * 6 * 3)).unwrap();
            let delta = 0.07f32;
            // reference: the pre-kernels op sequence
            let mut want = w.clone();
            want.axis_copy_with(1, 2, 5, |v| split_value(v, delta, mode).1)
                .unwrap();
            want.axis_map_mut(1, 2, |v| *v = split_value(*v, delta, mode).0)
                .unwrap();
            let want_src = want.axis_max_abs(1, 2).unwrap();
            let want_dst = want.axis_max_abs(1, 5).unwrap();
            // fused
            let mut got = w.clone();
            let (m_src, m_dst) = split_channel(got.data_mut(), 4, 6, 3, 2, 5, delta, mode);
            assert_eq!(got.data(), want.data(), "{mode:?}");
            assert_eq!(m_src.to_bits(), want_src.to_bits());
            assert_eq!(m_dst.to_bits(), want_dst.to_bits());
        }
    }
}
