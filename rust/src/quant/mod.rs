//! Symmetric linear quantization (paper Eq. 1, Distiller-compatible).
//!
//! A `k`-bit sign-magnitude grid has `2^k - 1` points: integers
//! `-qmax ..= qmax` with `qmax = 2^(k-1) - 1`, scaled by
//! `delta = threshold / qmax`. Rounding is the paper's
//! `Q(x) = floor(x + 0.5)` ([`crate::util::round_half_up`]), matching the
//! Pallas kernels bit-for-bit so weights fake-quantized here and
//! activations fake-quantized inside the artifact live on identical
//! grids.

pub mod channelwise;
pub mod error;
pub mod pack;

use crate::tensor::TensorF;
use crate::util::round_half_up;

/// Bitwidth descriptor for symmetric sign-magnitude quantization.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QuantSpec {
    pub bits: u32,
}

impl QuantSpec {
    pub fn new(bits: u32) -> Self {
        assert!((2..=16).contains(&bits), "bits {bits} out of range");
        QuantSpec { bits }
    }

    /// Largest grid index: `2^(k-1) - 1`.
    #[inline]
    pub fn qmax(&self) -> f32 {
        ((1u32 << (self.bits - 1)) - 1) as f32
    }

    /// Grid points on each side plus zero: `2^k - 1` total.
    pub fn levels(&self) -> u32 {
        (1u32 << self.bits) - 1
    }

    /// Grid step for a clip threshold.
    #[inline]
    pub fn delta(&self, threshold: f32) -> f32 {
        threshold / self.qmax()
    }
}

/// Quantize-dequantize one value on the grid `(delta, qmax)`.
#[inline]
pub fn fake_quant_val(x: f32, delta: f32, qmax: f32) -> f32 {
    if delta <= 0.0 {
        return 0.0;
    }
    round_half_up(x / delta).clamp(-qmax, qmax) * delta
}

/// Quantize-dequantize a slice in place.
pub fn fake_quant_slice(xs: &mut [f32], delta: f32, qmax: f32) {
    for x in xs {
        *x = fake_quant_val(*x, delta, qmax);
    }
}

/// Quantize-dequantize a tensor onto a `spec`-bit grid clipped at
/// `threshold`. This is the weight-side quantizer — the Rust twin of the
/// Pallas `fake_quant` kernel (which handles the activation side at run
/// time).
pub fn fake_quant_tensor(t: &TensorF, threshold: f32, spec: QuantSpec) -> TensorF {
    let delta = spec.delta(threshold);
    let qmax = spec.qmax();
    t.map(|v| fake_quant_val(v, delta, qmax))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_grid_counts() {
        assert_eq!(QuantSpec::new(8).qmax(), 127.0);
        assert_eq!(QuantSpec::new(4).qmax(), 7.0);
        assert_eq!(QuantSpec::new(2).qmax(), 1.0);
        assert_eq!(QuantSpec::new(8).levels(), 255);
        assert_eq!(QuantSpec::new(4).levels(), 15);
    }

    #[test]
    fn grid_points_are_fixed_points() {
        let spec = QuantSpec::new(4);
        let delta = spec.delta(7.0); // = 1.0
        for i in -7..=7 {
            let v = i as f32 * delta;
            assert_eq!(fake_quant_val(v, delta, spec.qmax()), v);
        }
    }

    #[test]
    fn clipping_saturates() {
        assert_eq!(fake_quant_val(100.0, 1.0, 7.0), 7.0);
        assert_eq!(fake_quant_val(-100.0, 1.0, 7.0), -7.0);
    }

    #[test]
    fn rounding_is_half_up() {
        // matches python/compile/kernels/ref.py::round_half_up
        assert_eq!(fake_quant_val(0.5, 1.0, 7.0), 1.0);
        assert_eq!(fake_quant_val(2.5, 1.0, 7.0), 3.0);
        assert_eq!(fake_quant_val(-0.5, 1.0, 7.0), 0.0);
        assert_eq!(fake_quant_val(-1.5, 1.0, 7.0), -1.0);
    }

    #[test]
    fn max_error_is_half_delta_inside_range() {
        let spec = QuantSpec::new(5);
        let t = 2.0f32;
        let delta = spec.delta(t);
        let mut x = -t;
        while x <= t {
            let q = fake_quant_val(x, delta, spec.qmax());
            assert!(
                (q - x).abs() <= delta / 2.0 + 1e-6,
                "x={x} q={q} delta={delta}"
            );
            x += 0.01;
        }
    }

    #[test]
    fn zero_threshold_yields_zero()
    {
        let t = TensorF::from_vec(&[3], vec![1.0, -2.0, 3.0]).unwrap();
        let q = fake_quant_tensor(&t, 0.0, QuantSpec::new(8));
        assert_eq!(q.data(), &[0.0, 0.0, 0.0]);
    }
}
