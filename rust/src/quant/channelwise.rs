//! Per-output-channel weight quantization — a standard post-training
//! extension beyond the paper's per-tensor grid (the paper's §7 future
//! work points toward richer grids; TensorRT and ONNX Runtime both ship
//! per-channel). Each output channel c gets its own threshold from the
//! configured clip method, so one channel's outlier no longer widens
//! every other channel's grid.
//!
//! Interaction with OCS: per-channel grids along the *output* axis are
//! orthogonal to OCS splits along the *input* axis — both compose, and
//! `rust/benches/ablations.rs` measures how much of OCS's win
//! per-channel grids already capture (a question the paper leaves open).

use crate::clip::ClipMethod;
use crate::kernels::{self, pool};
use crate::quant::{fake_quant_slice, QuantSpec};
use crate::stats::Histogram;
use crate::tensor::TensorF;

/// Bins for the per-channel threshold histograms (channels hold far
/// fewer samples than a whole layer, so 512 bins suffice).
const CHANNEL_BINS: usize = 512;

/// Quantize `w` with an independent symmetric grid per slice along
/// `cout_axis`. Returns the quantized tensor and per-channel thresholds.
///
/// Runs at the kernel pool's default width; see
/// [`fake_quant_per_channel_with`] for an explicit thread count.
pub fn fake_quant_per_channel(
    w: &TensorF,
    cout_axis: usize,
    spec: QuantSpec,
    clip: ClipMethod,
) -> (TensorF, Vec<f32>) {
    fake_quant_per_channel_with(w, cout_axis, spec, clip, 0)
}

/// [`fake_quant_per_channel`] at an explicit thread count (0 = default
/// width). Channels are independent — each builds its histogram over a
/// zero-copy strided view (no per-channel `Vec` materialization), picks
/// its threshold, and quantizes its own disjoint runs — so the result
/// is bit-identical at every `threads` value.
pub fn fake_quant_per_channel_with(
    w: &TensorF,
    cout_axis: usize,
    spec: QuantSpec,
    clip: ClipMethod,
    threads: usize,
) -> (TensorF, Vec<f32>) {
    let (outer, alen, inner) = w
        .axis_geometry(cout_axis)
        .expect("cout_axis within rank");
    // Two pool dispatches (threshold search, then quantization) rather
    // than one fused per-channel job: it keeps the unsafe surface
    // confined to `for_each_channel_chunk_mut` and the histogram on the
    // shared safe `from_chunks` path, at the cost of one extra barrier
    // and cache pass per layer.
    // per-channel threshold search, channels in parallel (index-ordered
    // results keep the thresholds vector deterministic)
    let thresholds: Vec<f32> = pool::map_indexed_with(threads, alen, |c| {
        let view = w.axis_chunks(cout_axis, c).expect("channel");
        let hist = Histogram::from_chunks(view, CHANNEL_BINS);
        clip.threshold(&hist, spec)
    });
    // quantize each channel's strided runs in place, channels in parallel
    let mut out = w.clone();
    let qmax = spec.qmax();
    kernels::for_each_channel_chunk_mut(out.data_mut(), outer, alen, inner, threads, |c, run| {
        fake_quant_slice(run, spec.delta(thresholds[c].max(1e-12)), qmax);
    });
    (out, thresholds)
}

/// Mean per-channel SQNR gain of per-channel over per-tensor grids —
/// the ablation statistic.
pub fn per_channel_mse_gain(
    w: &TensorF,
    cout_axis: usize,
    spec: QuantSpec,
    clip: ClipMethod,
) -> (f64, f64) {
    let hist = Histogram::from_slice(w.data(), 2048);
    let t = clip.threshold(&hist, spec);
    let per_tensor = crate::quant::fake_quant_tensor(w, t, spec);
    let (per_channel, _) = fake_quant_per_channel(w, cout_axis, spec, clip);
    (w.mse(&per_tensor), w.mse(&per_channel))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn weight_with_hot_channel(seed: u64) -> TensorF {
        let mut rng = Rng::new(seed);
        let mut data = rng.normal_vec(16 * 8);
        // output channel 3 is 10x hotter than the rest
        for o in 0..16 {
            data[o * 8 + 3] *= 10.0;
        }
        TensorF::from_vec(&[16, 8], data).unwrap()
    }

    #[test]
    fn per_channel_beats_per_tensor_on_heterogeneous_scales() {
        let w = weight_with_hot_channel(1);
        let spec = QuantSpec::new(4);
        let (mse_t, mse_c) = per_channel_mse_gain(&w, 1, spec, ClipMethod::None);
        assert!(
            mse_c < mse_t * 0.5,
            "per-channel {mse_c} should be far below per-tensor {mse_t}"
        );
    }

    #[test]
    fn per_channel_thresholds_match_channel_maxes() {
        let w = weight_with_hot_channel(2);
        let spec = QuantSpec::new(6);
        let (_, thresholds) = fake_quant_per_channel(&w, 1, spec, ClipMethod::None);
        let maxes = w.max_abs_per_axis(1).unwrap();
        for (t, m) in thresholds.iter().zip(&maxes) {
            assert!((t - m).abs() < 1e-5, "{t} vs {m}");
        }
    }

    #[test]
    fn per_channel_values_on_their_grids() {
        let w = weight_with_hot_channel(3);
        let spec = QuantSpec::new(4);
        let (q, thresholds) = fake_quant_per_channel(&w, 1, spec, ClipMethod::None);
        for c in 0..8 {
            let delta = spec.delta(thresholds[c].max(1e-12));
            for v in q.axis_slice(1, c).unwrap() {
                let k = v / delta;
                assert!((k - k.round()).abs() < 1e-3, "ch {c}: {v} not on grid");
            }
        }
    }

    #[test]
    fn parallel_is_bit_identical_to_serial() {
        let w = weight_with_hot_channel(5);
        let spec = QuantSpec::new(4);
        for clip in [ClipMethod::None, ClipMethod::Mse] {
            let (q1, t1) = fake_quant_per_channel_with(&w, 1, spec, clip, 1);
            for threads in [2usize, 4, 8] {
                let (qn, tn) = fake_quant_per_channel_with(&w, 1, spec, clip, threads);
                let b1: Vec<u32> = q1.data().iter().map(|v| v.to_bits()).collect();
                let bn: Vec<u32> = qn.data().iter().map(|v| v.to_bits()).collect();
                assert_eq!(b1, bn, "threads {threads} ({clip:?})");
                let tb1: Vec<u32> = t1.iter().map(|v| v.to_bits()).collect();
                let tbn: Vec<u32> = tn.iter().map(|v| v.to_bits()).collect();
                assert_eq!(tb1, tbn, "thresholds at threads {threads}");
            }
        }
    }

    #[test]
    fn uniform_scales_make_both_equal() {
        // when all channels share the same scale, per-channel == per-tensor
        let mut rng = Rng::new(4);
        let w = TensorF::from_vec(&[8, 4], rng.normal_vec(32)).unwrap();
        let spec = QuantSpec::new(8);
        let (mse_t, mse_c) = per_channel_mse_gain(&w, 1, spec, ClipMethod::None);
        // per-channel can only be equal or better, but not dramatically so
        assert!(mse_c <= mse_t * 1.001);
        assert!(mse_c > mse_t * 0.1);
    }
}
