//! Quantization error metrics, both exact (tensor vs tensor) and
//! expected-over-histogram (the form the clip optimizers minimize,
//! paper Eq. 9).

use crate::quant::{fake_quant_val, QuantSpec};
use crate::stats::Histogram;
use crate::tensor::TensorF;

/// Exact MSE between a tensor and its quantized image.
pub fn tensor_quant_mse(t: &TensorF, threshold: f32, spec: QuantSpec) -> f64 {
    let delta = spec.delta(threshold);
    let qmax = spec.qmax();
    if t.is_empty() {
        return 0.0;
    }
    let s: f64 = t
        .data()
        .iter()
        .map(|&v| {
            let d = (v - fake_quant_val(v, delta, qmax)) as f64;
            d * d
        })
        .sum();
    s / t.len() as f64
}

/// Expected MSE over a magnitude histogram for a candidate clip
/// threshold (paper Eq. 9 with h(x_i) weights). Uses bin centers as
/// representative values — the same approximation the reference MSE
/// clipping implementations make.
pub fn hist_quant_mse(hist: &Histogram, threshold: f32, spec: QuantSpec) -> f64 {
    if hist.count() == 0 || threshold <= 0.0 {
        return f64::INFINITY;
    }
    let delta = spec.delta(threshold);
    let qmax = spec.qmax();
    let mut err = 0.0f64;
    for (i, &c) in hist.counts().iter().enumerate() {
        if c == 0 {
            continue;
        }
        let x = hist.bin_center(i);
        let d = (x - fake_quant_val(x, delta, qmax)) as f64;
        err += c as f64 * d * d;
    }
    err / hist.count() as f64
}

/// Signal-to-quantization-noise ratio in dB (10 log10 E[x^2]/MSE).
pub fn sqnr_db(t: &TensorF, threshold: f32, spec: QuantSpec) -> f64 {
    let mse = tensor_quant_mse(t, threshold, spec);
    if mse <= 0.0 {
        return f64::INFINITY;
    }
    let power: f64 = t.data().iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>()
        / t.len().max(1) as f64;
    10.0 * (power / mse).log10()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn exact_mse_zero_on_grid() {
        let spec = QuantSpec::new(4);
        let t = TensorF::from_vec(&[3], vec![1.0, -3.0, 7.0]).unwrap();
        assert_eq!(tensor_quant_mse(&t, 7.0, spec), 0.0);
    }

    #[test]
    fn clipping_tradeoff_visible_in_hist_mse() {
        // bell-shaped body + one outlier: some clipping must beat both
        // no-clipping and extreme clipping (the paper's Figure 1 story).
        let mut rng = Rng::new(0);
        let mut data: Vec<f32> = (0..20_000).map(|_| rng.normal()).collect();
        data.push(30.0);
        let hist = Histogram::from_slice(&data, 2048);
        let spec = QuantSpec::new(4);
        let full = hist_quant_mse(&hist, hist.max_abs(), spec);
        let clipped = hist_quant_mse(&hist, 4.0, spec);
        let extreme = hist_quant_mse(&hist, 0.2, spec);
        assert!(clipped < full, "clipped {clipped} !< full {full}");
        assert!(clipped < extreme, "clipped {clipped} !< extreme {extreme}");
    }

    #[test]
    fn hist_mse_tracks_exact_mse() {
        let mut rng = Rng::new(7);
        let data: Vec<f32> = (0..50_000).map(|_| rng.normal()).collect();
        let t = TensorF::from_vec(&[data.len()], data.clone()).unwrap();
        let hist = Histogram::from_slice(&data, 2048);
        let spec = QuantSpec::new(6);
        for thr in [1.0f32, 2.0, 3.0, 4.0] {
            let exact = tensor_quant_mse(&t, thr, spec);
            let approx = hist_quant_mse(&hist, thr, spec);
            let rel = (exact - approx).abs() / exact.max(1e-12);
            assert!(rel < 0.15, "thr {thr}: exact {exact} approx {approx}");
        }
    }

    #[test]
    fn sqnr_improves_with_bits() {
        let mut rng = Rng::new(3);
        let data: Vec<f32> = (0..10_000).map(|_| rng.normal()).collect();
        let t = TensorF::from_vec(&[data.len()], data).unwrap();
        let thr = t.max_abs();
        let s4 = sqnr_db(&t, thr, QuantSpec::new(4));
        let s8 = sqnr_db(&t, thr, QuantSpec::new(8));
        assert!(s8 > s4 + 10.0, "s4 {s4} s8 {s8}");
    }
}
