//! Lower a fake-quantized [`PreparedModel`] into true integer payloads —
//! the bridge between the simulation-grade pipeline (f32 values that
//! merely *sit on* a quantization grid) and the native integer datapath
//! ([`crate::kernels::gemm`], [`crate::runtime::native`]).
//!
//! [`pass_weight_quant`](crate::pipeline::pass_weight_quant) ships
//! weights as f32 tensors whose every value is `q * delta` for an
//! integer `q` with `|q| <= qmax` (Eq. 1). This module recovers those
//! integers, **asserting bit-exact round-trip** per element — the grid
//! guarantees `(q as f32) * delta` reproduces the prepared value
//! exactly, so a mismatch means the prep was not actually on its grid
//! and packing refuses rather than serving silently-wrong integers.
//!
//! OCS interacts trivially by design: splits are materialized into the
//! padded channel slots *before* weight quantization, so the packed
//! matrix simply carries `cin_pad` input channels (duplicated channels
//! included) and the `idx`/`dscale`/`dbias` steering vectors ride along
//! for the activation-side `channel_dup`.
//!
//! A layer takes the [`LayerBody::Int`] lowering only when the whole
//! datapath is integer-representable: weights on a <= 8-bit grid *and*
//! activations quantized to <= 8 bits (`0 < aqmax <= 127`). Everything
//! else — float layers, skipped layers, float activations, >8-bit
//! grids — keeps its f32 body and runs on the f32 reference GEMM; the
//! native engine mixes both per layer.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::kernels::gemm::PackedB;
use crate::model::{LayerKind, LayerSpec, ModelSpec};
use crate::pipeline::{LayerPrep, PreparedModel};
use crate::quant::QuantSpec;
use crate::tensor::TensorF;
use crate::util::round_half_up;

/// The execution body of one packed layer.
#[derive(Debug, Clone)]
pub enum LayerBody {
    /// Full integer datapath: packed i8 weights (`K × cout`), the
    /// per-output-channel dequant scales (activation delta × weight
    /// delta), and the f32 bias the epilogue adds.
    Int {
        wq: PackedB,
        /// `dequant[j] = adelta * wdelta` — vector-shaped so per-channel
        /// weight grids slot in without touching the kernel.
        dequant: Vec<f32>,
        bias: Vec<f32>,
        /// The weight grid step the integers were recovered on.
        wdelta: f32,
    },
    /// f32 fallback: the (possibly fake-quantized) weight matrix
    /// row-major `(K, cout)` plus bias, run on the f32 GEMM.
    Float { w: Vec<f32>, bias: Vec<f32> },
}

/// One layer lowered for native execution. `K` is the GEMM inner dim:
/// `ksize² * cin_eff` for conv (HWIO row-major is already `(K, cout)`),
/// `cin_eff` for fc — where `cin_eff` is `cin_pad` for hooked layers
/// and the raw `cin` for unquantized ones.
#[derive(Debug, Clone)]
pub struct PackedLayer {
    pub name: String,
    pub kind: LayerKind,
    pub ksize: usize,
    pub stride: usize,
    pub cin: usize,
    /// Input channels the GEMM consumes (`cin_pad` when hooked).
    pub cin_eff: usize,
    pub cout: usize,
    /// `true` when the artifact feeds this layer through `channel_dup`
    /// (quantizable layers, even when a recipe skips them).
    pub hooked: bool,
    /// Channel-dup steering (length `cin_eff` when hooked, empty
    /// otherwise): `x_exp[j] = x[idx[j]] * dscale[j] + dbias[j]`.
    pub idx: Vec<i32>,
    pub dscale: Vec<f32>,
    pub dbias: Vec<f32>,
    /// Activation grid (`aqmax <= 0` = float activations).
    pub adelta: f32,
    pub aqmax: f32,
    /// Bit width of the integer weight grid (0 on the f32 fallback) —
    /// the width a wire format would store the payload at, even though
    /// the in-memory [`PackedB`] widens every element to i8 for the
    /// kernel.
    pub w_bits: u32,
    pub body: LayerBody,
}

impl PackedLayer {
    /// Whether this layer runs on the integer kernel.
    pub fn is_int(&self) -> bool {
        matches!(self.body, LayerBody::Int { .. })
    }

    /// GEMM inner dimension.
    pub fn gemm_k(&self) -> usize {
        match self.kind {
            LayerKind::Conv => self.ksize * self.ksize * self.cin_eff,
            _ => self.cin_eff,
        }
    }

    /// Serving footprint of the layer body in bytes: the weight payload
    /// at its quantized width (`w_bits` bits per element on the integer
    /// path, 32 on the f32 fallback) plus the epilogue vectors. Logical
    /// bytes — what a wire format or weight cache would hold — not
    /// allocator overhead, the packer's cache-blocking padding, or the
    /// i8 widening the compute kernel works on. Bit-true on purpose:
    /// this is the axis the autotune bit ladder descends, so a 4-bit
    /// grid must cost half an 8-bit one.
    pub fn body_bytes(&self) -> usize {
        match &self.body {
            LayerBody::Int { dequant, bias, .. } => {
                (self.gemm_k() * self.cout * self.w_bits as usize + 7) / 8
                    + (dequant.len() + bias.len()) * 4
                    + 4
            }
            LayerBody::Float { w, bias } => (w.len() + bias.len()) * 4,
        }
    }

    /// Channel-dup steering vectors (`idx`/`dscale`/`dbias`, 12 bytes
    /// per effective input slot when hooked; 0 otherwise).
    pub fn steering_bytes(&self) -> usize {
        (self.idx.len() + self.dscale.len() + self.dbias.len()) * 4
    }

    /// Body + steering.
    pub fn total_bytes(&self) -> usize {
        self.body_bytes() + self.steering_bytes()
    }
}

/// A whole model lowered for the native backend.
#[derive(Debug, Clone)]
pub struct PackedModel {
    pub model: String,
    pub layers: BTreeMap<String, PackedLayer>,
    /// Layers on the integer datapath / on the f32 fallback.
    pub int_layers: usize,
    pub float_layers: usize,
}

impl PackedModel {
    pub fn layer(&self, name: &str) -> Result<&PackedLayer> {
        self.layers
            .get(name)
            .with_context(|| format!("packed model {}: no layer '{name}'", self.model))
    }

    /// Compact tag for logs: `native[5i/2f]`.
    pub fn label(&self) -> String {
        format!("native[{}i/{}f]", self.int_layers, self.float_layers)
    }

    /// Whole-model serving footprint in bytes (sum of
    /// [`PackedLayer::total_bytes`]) — the cost axis `ocs autotune`
    /// budgets candidate recipes on. Lowering a layer from the f32
    /// fallback to a `b`-bit body shrinks its payload `32/b`×; OCS
    /// duplicate slots grow it (wider `cin_eff` payload + steering), so
    /// the ratio/bits trade is visible in one number.
    pub fn footprint_bytes(&self) -> usize {
        self.layers.values().map(|l| l.total_bytes()).sum()
    }
}

/// Recover the integer grid points of a fake-quantized weight tensor.
/// Returns the i8 payload, or an error naming the first off-grid value
/// (which would mean the prep was not produced by the Eq. 1 quantizer).
fn lower_ints(w: &TensorF, delta: f32, qmax: f32, layer: &str) -> Result<Vec<i8>> {
    let mut out = Vec::with_capacity(w.len());
    if delta <= 0.0 {
        // degenerate grid: every value must be exactly zero
        for (i, &v) in w.data().iter().enumerate() {
            if v != 0.0 {
                bail!("layer {layer}: value {v} at {i} on a zero-width grid");
            }
            out.push(0i8);
        }
        return Ok(out);
    }
    for (i, &v) in w.data().iter().enumerate() {
        let q = round_half_up(v / delta);
        if q.abs() > qmax || q.abs() > 127.0 {
            bail!("layer {layer}: grid index {q} at {i} exceeds qmax {qmax}");
        }
        // the round-trip exactness the grid guarantees — checked, not
        // assumed: a single ulp of drift here would silently corrupt
        // every logit downstream
        if (q * delta).to_bits() != v.to_bits() {
            bail!(
                "layer {layer}: value {v} at {i} does not round-trip on grid delta {delta} \
                 (got {})",
                q * delta
            );
        }
        out.push(q as i8);
    }
    Ok(out)
}

/// Lower one prepared (hooked) layer.
fn pack_layer(
    layer: &LayerSpec,
    prep: &LayerPrep,
    w_bits: Option<u32>,
) -> Result<PackedLayer> {
    let cout = layer.cout;
    let cin_eff = layer.cin_pad;
    let kk = prep.w.len() / cout.max(1);
    if kk * cout != prep.w.len() {
        bail!("layer {}: weight {} not divisible by cout {cout}", layer.name, prep.w.len());
    }
    let bias = prep.b.data().to_vec();
    if bias.len() != cout {
        bail!("layer {}: bias {} != cout {cout}", layer.name, bias.len());
    }
    // integer-eligible: weight grid <= 8 bits AND activations quantized
    // to <= 8 bits — only then is the whole layer an i8×i8 product
    let int_ok = matches!(w_bits, Some(b) if (2..=8).contains(&b))
        && prep.aqmax > 0.0
        && prep.aqmax <= 127.0;
    let body = if int_ok {
        let spec = QuantSpec::new(w_bits.unwrap());
        let wdelta = spec.delta(prep.w_threshold);
        let ints = lower_ints(&prep.w, wdelta, spec.qmax(), &layer.name)?;
        let wq = PackedB::pack(&ints, kk, cout);
        let dequant = vec![prep.adelta * wdelta; cout];
        LayerBody::Int {
            wq,
            dequant,
            bias,
            wdelta,
        }
    } else {
        LayerBody::Float {
            w: prep.w.data().to_vec(),
            bias,
        }
    };
    Ok(PackedLayer {
        name: layer.name.clone(),
        kind: layer.kind,
        ksize: layer.ksize,
        stride: layer.stride,
        cin: layer.cin,
        cin_eff,
        cout,
        hooked: true,
        idx: prep.idx.data().to_vec(),
        dscale: prep.dscale.data().to_vec(),
        dbias: prep.dbias.data().to_vec(),
        adelta: prep.adelta,
        aqmax: prep.aqmax,
        w_bits: if int_ok { w_bits.unwrap() } else { 0 },
        body,
    })
}

/// Lower a whole [`PreparedModel`]: hooked layers through their
/// resolved per-layer recipes (integer where the datapath allows, f32
/// otherwise), raw unquantized layers as plain f32 bodies.
pub fn pack_prepared(spec: &ModelSpec, prep: &PreparedModel) -> Result<PackedModel> {
    let first = spec.quantized_layers().next().map(|l| l.name.clone());
    let last = spec.quantized_layers().last().map(|l| l.name.clone());
    let mut layers = BTreeMap::new();
    let mut int_layers = 0usize;
    let mut float_layers = 0usize;
    for lp in &prep.layers {
        let layer = spec.layer(&lp.name)?;
        let is_first = first.as_deref() == Some(layer.name.as_str());
        let is_last = last.as_deref() == Some(layer.name.as_str());
        let rc = prep.recipe.resolve(layer, is_first, is_last);
        let w_bits = if rc.quantize { rc.w_bits } else { None };
        let packed = pack_layer(layer, lp, w_bits)?;
        if packed.is_int() {
            int_layers += 1;
        } else {
            float_layers += 1;
        }
        layers.insert(packed.name.clone(), packed);
    }
    for (name, w, b) in &prep.raw {
        let layer = spec.layer(name)?;
        let cout = layer.cout;
        let bias = match b {
            Some(b) => b.data().to_vec(),
            None => vec![0.0f32; if layer.kind == LayerKind::Embed { 0 } else { cout }],
        };
        float_layers += 1;
        layers.insert(
            name.clone(),
            PackedLayer {
                name: name.clone(),
                kind: layer.kind,
                ksize: layer.ksize,
                stride: layer.stride,
                cin: layer.cin,
                cin_eff: layer.cin,
                cout,
                hooked: false,
                idx: Vec::new(),
                dscale: Vec::new(),
                dbias: Vec::new(),
                adelta: 1.0,
                aqmax: -1.0,
                w_bits: 0,
                body: LayerBody::Float {
                    w: w.data().to_vec(),
                    bias,
                },
            },
        );
    }
    Ok(PackedModel {
        model: prep.model.clone(),
        layers,
        int_layers,
        float_layers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clip::ClipMethod;
    use crate::kernels::gemm;
    use crate::model::store::WeightStore;
    use crate::pipeline::{self, QuantConfig};
    use crate::util::rng::Rng;

    fn fc_layer(name: &str, cin: usize, cin_pad: usize, cout: usize) -> LayerSpec {
        LayerSpec {
            name: name.into(),
            kind: LayerKind::Fc,
            cin,
            cin_pad,
            cout,
            ksize: 0,
            stride: 1,
            quantized: true,
            w_cin_axis: 0,
            w_shape: vec![cin, cout],
            w_shape_pad: vec![cin_pad, cout],
        }
    }

    fn mlp_spec() -> ModelSpec {
        ModelSpec {
            name: "packer".into(),
            dir: std::path::PathBuf::new(),
            pad_factor: 1.25,
            num_classes: 4,
            img_hw: 0,
            img_c: 0,
            vocab: 0,
            seq_len: 0,
            momentum: 0.9,
            layers: vec![fc_layer("f1", 8, 10, 6), fc_layer("f2", 6, 8, 4)],
            artifacts: Default::default(),
        }
    }

    fn mlp_ws(seed: u64) -> WeightStore {
        let mut rng = Rng::new(seed);
        let mut w1 = rng.normal_vec(48);
        w1[5 * 6] = 7.0; // outlier channel
        WeightStore::from_leaves(vec![
            ("f1.W".into(), TensorF::from_vec(&[8, 6], w1).unwrap()),
            ("f1.b".into(), TensorF::from_vec(&[6], rng.normal_vec(6)).unwrap()),
            ("f2.W".into(), TensorF::from_vec(&[6, 4], rng.normal_vec(24)).unwrap()),
            ("f2.b".into(), TensorF::zeros(&[4])),
        ])
    }

    /// 4-bit weights + 8-bit activations + OCS: the full integer path.
    fn int_recipe() -> pipeline::QuantRecipe {
        QuantConfig {
            w_bits: Some(4),
            a_bits: Some(8),
            w_clip: ClipMethod::None,
            a_clip: ClipMethod::None,
            ocs_ratio: 0.13,
            ..QuantConfig::float()
        }
        .to_recipe()
    }

    fn calib_for(spec: &ModelSpec) -> crate::calib::Calibration {
        let mut layers = std::collections::BTreeMap::new();
        for l in &spec.layers {
            let data: Vec<f32> = (0..1024).map(|i| ((i % 64) as f32 - 32.0) * 0.05).collect();
            layers.insert(
                l.name.clone(),
                crate::calib::LayerCalib {
                    hist: crate::stats::Histogram::from_slice(&data, 256),
                    channel_max: vec![1.5f32; l.cin],
                    outlier_counts: vec![1u64; l.cin],
                },
            );
        }
        crate::calib::Calibration { layers }
    }

    #[test]
    fn int_lowering_roundtrips_and_multiplies_exactly() {
        let spec = mlp_spec();
        let ws = mlp_ws(3);
        let calib = calib_for(&spec);
        let prep = pipeline::prepare_recipe(&spec, &ws, Some(&calib), &int_recipe()).unwrap();
        let pm = pack_prepared(&spec, &prep).unwrap();
        assert_eq!(pm.int_layers, 2);
        assert_eq!(pm.float_layers, 0);
        let f1 = pm.layer("f1").unwrap();
        assert!(f1.is_int());
        assert_eq!(f1.cin_eff, 10);
        assert_eq!(f1.gemm_k(), 10);
        // OCS-duplicated slots are packed post-split: steering has live
        // duplicate slots beyond cin
        assert!(f1.idx.len() == 10 && f1.dscale[8] == 1.0);
        // the packed ints reproduce the fake-quantized weight exactly
        let LayerBody::Int { wq, wdelta, .. } = &f1.body else {
            panic!("expected int body");
        };
        let wprep = &prep.layers[0].w;
        // dequantize via a GEMM against identity-ish probes: column j of
        // an identity A picks out weight row j
        let m = f1.gemm_k();
        let mut eye = vec![0i8; m * m];
        for i in 0..m {
            eye[i * m + i] = 1;
        }
        let acc = gemm::gemm_i8(&eye, wq, m, 1);
        for (i, &v) in wprep.data().iter().enumerate() {
            let got = acc[i] as f32 * wdelta;
            assert_eq!(got.to_bits(), v.to_bits(), "element {i}");
        }
    }

    #[test]
    fn float_acts_fall_back_to_f32_body() {
        let spec = mlp_spec();
        let ws = mlp_ws(4);
        // weights-only: no activation grid, so no integer datapath
        let recipe = QuantConfig::weights_only(4, ClipMethod::None, 0.0).to_recipe();
        let prep = pipeline::prepare_recipe(&spec, &ws, None, &recipe).unwrap();
        let pm = pack_prepared(&spec, &prep).unwrap();
        assert_eq!(pm.int_layers, 0);
        assert_eq!(pm.float_layers, 2);
        let f1 = pm.layer("f1").unwrap();
        assert!(!f1.is_int());
        assert!(f1.hooked);
        assert_eq!(f1.aqmax, -1.0);
    }

    #[test]
    fn wide_grids_fall_back_to_f32_body() {
        let spec = mlp_spec();
        let ws = mlp_ws(5);
        let calib = calib_for(&spec);
        // 12-bit weights exceed i8 — must stay f32 even with 8-bit acts
        let recipe = QuantConfig {
            w_bits: Some(12),
            a_bits: Some(8),
            ..QuantConfig::float()
        }
        .to_recipe();
        let prep = pipeline::prepare_recipe(&spec, &ws, Some(&calib), &recipe).unwrap();
        let pm = pack_prepared(&spec, &prep).unwrap();
        assert_eq!(pm.int_layers, 0);
        assert!(pm.label().contains("0i/2f"), "{}", pm.label());
    }

    #[test]
    fn off_grid_weights_are_refused() {
        let t = TensorF::from_vec(&[2], vec![0.35, 0.1]).unwrap();
        // delta 0.1: 0.35 is not a grid multiple bit-for-bit
        let err = lower_ints(&t, 0.1, 7.0, "bad").unwrap_err();
        assert!(err.to_string().contains("round-trip"), "{err:#}");
        // zero-width grid accepts only exact zeros
        let z = TensorF::zeros(&[3]);
        assert_eq!(lower_ints(&z, 0.0, 7.0, "z").unwrap(), vec![0, 0, 0]);
        let nz = TensorF::from_vec(&[1], vec![0.5]).unwrap();
        assert!(lower_ints(&nz, 0.0, 7.0, "nz").is_err());
    }

    #[test]
    fn grid_values_always_roundtrip() {
        // every representable grid point must lower exactly
        let spec = QuantSpec::new(8);
        for &thr in &[0.37f32, 1.0, 12.5, 1e-3] {
            let delta = spec.delta(thr);
            let vals: Vec<f32> = (-127..=127).map(|q| q as f32 * delta).collect();
            let t = TensorF::from_vec(&[vals.len()], vals.clone()).unwrap();
            let ints = lower_ints(&t, delta, spec.qmax(), "grid").unwrap();
            for (q, &v) in ints.iter().zip(&vals) {
                assert_eq!((*q as f32 * delta).to_bits(), v.to_bits());
            }
        }
    }

    #[test]
    fn footprint_counts_payload_widths() {
        let spec = mlp_spec();
        let ws = mlp_ws(7);
        let calib = calib_for(&spec);
        // full integer path: i8 bodies
        let int_pm = pack_prepared(
            &spec,
            &pipeline::prepare_recipe(&spec, &ws, Some(&calib), &int_recipe()).unwrap(),
        )
        .unwrap();
        // float fallback on the same shapes
        let f_pm = pack_prepared(
            &spec,
            &pipeline::prepare_recipe(&spec, &ws, None, &pipeline::QuantRecipe::float()).unwrap(),
        )
        .unwrap();
        let f1 = int_pm.layer("f1").unwrap();
        // 4-bit body: ceil(K*cout*4/8) weight bytes + 4B dequant/bias
        // per cout + wdelta
        assert_eq!(f1.w_bits, 4);
        assert_eq!(f1.body_bytes(), (10 * 6 * 4 + 7) / 8 + (6 + 6) * 4 + 4);
        assert_eq!(f1.steering_bytes(), 10 * 12);
        assert_eq!(f1.total_bytes(), f1.body_bytes() + f1.steering_bytes());
        let f1f = f_pm.layer("f1").unwrap();
        // f32 body on the same padded shape: 4 bytes per element
        assert_eq!(f1f.body_bytes(), (10 * 6 + 6) * 4);
        assert!(
            int_pm.footprint_bytes() < f_pm.footprint_bytes(),
            "i8 lowering must shrink the model: {} vs {}",
            int_pm.footprint_bytes(),
            f_pm.footprint_bytes()
        );
        assert_eq!(
            int_pm.footprint_bytes(),
            int_pm.layers.values().map(|l| l.total_bytes()).sum::<usize>()
        );
    }

    #[test]
    fn skipped_layer_packs_float_but_hooked() {
        let spec = mlp_spec();
        let ws = mlp_ws(6);
        let calib = calib_for(&spec);
        let recipe = int_recipe().with_override(
            pipeline::LayerMatch::name("f2"),
            pipeline::LayerPolicy::skip(),
        );
        let prep = pipeline::prepare_recipe(&spec, &ws, Some(&calib), &recipe).unwrap();
        let pm = pack_prepared(&spec, &prep).unwrap();
        assert_eq!(pm.int_layers, 1);
        let f2 = pm.layer("f2").unwrap();
        assert!(!f2.is_int() && f2.hooked);
    }
}
