//! Training harness: drives the AOT-compiled `train_step` artifact
//! (fwd + bwd + SGD-momentum update, lowered once by python) from a pure
//! Rust loop. This is how the benchmark models acquire realistic
//! post-training weight distributions without any Python at run time.

pub mod data;

use anyhow::{bail, Context, Result};

use crate::model::store::WeightStore;
use crate::model::ModelSpec;
use crate::runtime::{Engine, Input, Inputs};
use crate::tensor::{TensorF, TensorI};
use crate::train::data::ImageDataset;
use crate::util::rng::Rng;

/// Loss curve + final stats for EXPERIMENTS.md.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    /// (step, loss) samples.
    pub losses: Vec<(usize, f32)>,
    pub final_loss: f32,
    pub steps: usize,
}

/// Step-decay learning-rate schedule with linear warmup.
pub fn lr_schedule(step: usize, total: usize, base: f32) -> f32 {
    let warmup = (total / 20).max(1);
    if step < warmup {
        return base * (step + 1) as f32 / warmup as f32;
    }
    // cosine decay to 5% of base
    let t = (step - warmup) as f32 / (total - warmup).max(1) as f32;
    let cos = 0.5 * (1.0 + (std::f32::consts::PI * t).cos());
    base * (0.05 + 0.95 * cos)
}

/// Shared trainer state: named param + momentum leaves in artifact order.
struct Leaves {
    names: Vec<String>,
    params: Vec<TensorF>,
    moms: Vec<TensorF>,
}

impl Leaves {
    fn init(spec: &ModelSpec, ws: &WeightStore) -> Result<Leaves> {
        let art = spec.train_artifact()?;
        // param inputs come first, then "m."-prefixed momentum, then data
        let mut names = Vec::new();
        for io in &art.inputs {
            if io.name.starts_with("m.") {
                break;
            }
            if io.name == "x" || io.name == "y" || io.name == "tokens" || io.name == "lr" {
                break;
            }
            names.push(io.name.clone());
        }
        if names.is_empty() {
            bail!("train artifact has no parameter inputs");
        }
        let mut params = Vec::new();
        for n in &names {
            params.push(
                ws.bundle
                    .f32(n)
                    .with_context(|| format!("init weight '{n}'"))?
                    .clone(),
            );
        }
        let moms = params.iter().map(|p| TensorF::zeros(p.shape())).collect();
        Ok(Leaves {
            names,
            params,
            moms,
        })
    }

    fn insert(&self, inputs: &mut Inputs) {
        for (n, p) in self.names.iter().zip(&self.params) {
            inputs.insert(n.clone(), Input::F32(p.clone()));
        }
        for (n, m) in self.names.iter().zip(&self.moms) {
            inputs.insert(format!("m.{n}"), Input::F32(m.clone()));
        }
    }

    fn update_from(&mut self, out: &mut crate::runtime::Outputs) -> Result<()> {
        for (i, n) in self.names.iter().enumerate() {
            self.params[i] = out.take(n)?;
            self.moms[i] = out.take(&format!("m.{n}"))?;
        }
        Ok(())
    }

    fn into_store(self) -> WeightStore {
        WeightStore::from_leaves(self.names.into_iter().zip(self.params).collect())
    }
}

/// Train a CNN benchmark model for `steps` SGD steps.
pub fn train_cnn(
    engine: &Engine,
    spec: &ModelSpec,
    ws: &WeightStore,
    dataset: &ImageDataset,
    steps: usize,
    base_lr: f32,
    seed: u64,
) -> Result<(WeightStore, TrainReport)> {
    let art = spec.train_artifact()?;
    let exe = engine.load(art)?;
    let b = art.batch;
    let mut leaves = Leaves::init(spec, ws)?;
    let mut rng = Rng::new(seed);
    let mut report = TrainReport::default();

    for step in 0..steps {
        let idx: Vec<usize> = (0..b).map(|_| rng.below(dataset.len())).collect();
        let (x, y) = dataset.gather(&idx);
        let mut inputs: Inputs = Default::default();
        leaves.insert(&mut inputs);
        inputs.insert("x".into(), Input::F32(x));
        inputs.insert("y".into(), Input::I32(TensorI::from_vec(&[b], y)?));
        inputs.insert(
            "lr".into(),
            Input::scalar_f32(lr_schedule(step, steps, base_lr)),
        );
        let mut out = exe.execute(&inputs)?;
        let loss = out.scalar("loss")?;
        leaves.update_from(&mut out)?;
        if step % 20 == 0 || step + 1 == steps {
            report.losses.push((step, loss));
            crate::info!("[train {}] step {step:4} loss {loss:.4}", spec.name);
        }
        report.final_loss = loss;
    }
    report.steps = steps;
    Ok((leaves.into_store(), report))
}

/// Train the LSTM LM for `steps` BPTT steps over `corpus`.
pub fn train_lm(
    engine: &Engine,
    spec: &ModelSpec,
    ws: &WeightStore,
    corpus: &[i32],
    steps: usize,
    base_lr: f32,
    seed: u64,
) -> Result<(WeightStore, TrainReport)> {
    let art = spec.train_artifact()?;
    let exe = engine.load(art)?;
    let b = art.batch;
    let w = spec.seq_len + 1;
    if corpus.len() < b * w {
        bail!("corpus too small: {} < {}", corpus.len(), b * w);
    }
    let mut leaves = Leaves::init(spec, ws)?;
    let mut rng = Rng::new(seed);
    let mut report = TrainReport::default();

    for step in 0..steps {
        let mut data = Vec::with_capacity(b * w);
        for _ in 0..b {
            let start = rng.below(corpus.len() - w);
            data.extend_from_slice(&corpus[start..start + w]);
        }
        let mut inputs: Inputs = Default::default();
        leaves.insert(&mut inputs);
        inputs.insert("tokens".into(), Input::I32(TensorI::from_vec(&[b, w], data)?));
        inputs.insert(
            "lr".into(),
            Input::scalar_f32(lr_schedule(step, steps, base_lr)),
        );
        let mut out = exe.execute(&inputs)?;
        let loss = out.scalar("loss")?;
        leaves.update_from(&mut out)?;
        if step % 20 == 0 || step + 1 == steps {
            report.losses.push((step, loss));
            crate::info!(
                "[train {}] step {step:4} loss {loss:.4} (ppl {:.1})",
                spec.name,
                loss.exp()
            );
        }
        report.final_loss = loss;
    }
    report.steps = steps;
    Ok((leaves.into_store(), report))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let total = 400;
        let base = 0.1;
        // warmup ramps
        assert!(lr_schedule(0, total, base) < lr_schedule(10, total, base));
        // peak near base after warmup
        let peak = lr_schedule(total / 20, total, base);
        assert!((peak - base).abs() / base < 0.06, "peak {peak}");
        // decays to ~5%
        let tail = lr_schedule(total - 1, total, base);
        assert!(tail < 0.08 * base + 1e-4, "tail {tail}");
        assert!(tail > 0.0);
    }
}
