//! Synthetic datasets — the stand-ins for ImageNet and WikiText-2
//! (neither is available offline; see DESIGN.md §1 for why these
//! substitutions preserve the quantization behaviour under study).
//!
//! * **Images**: a 10-class 16×16×3 task where each class is an oriented
//!   sinusoidal grating with class-specific frequency plus a
//!   class-anchored Gaussian blob, under per-sample random phase, shift
//!   and pixel noise. Orientation/frequency discrimination is exactly
//!   the kind of feature a small conv net learns, so post-training
//!   weights develop the bell-shaped, outlier-bearing distributions OCS
//!   targets.
//! * **Text**: a Zipf-marginal Markov chain over a 2 000-word vocabulary
//!   with state-dependent successor sets — enough sequential structure
//!   that a 2-layer LSTM meaningfully beats the unigram baseline, giving
//!   perplexity headroom for quantization to damage.

use crate::tensor::{TensorF, TensorI};
use crate::util::rng::{Rng, ZipfTable};

pub const IMG_HW: usize = 16;
pub const IMG_C: usize = 3;
pub const NUM_CLASSES: usize = 10;

/// Images (N, 16, 16, 3) + labels.
#[derive(Debug, Clone)]
pub struct ImageDataset {
    pub x: TensorF,
    pub y: Vec<i32>,
}

impl ImageDataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }
    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// Gather a batch by indices.
    pub fn gather(&self, idx: &[usize]) -> (TensorF, Vec<i32>) {
        let row = IMG_HW * IMG_HW * IMG_C;
        let mut data = Vec::with_capacity(idx.len() * row);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            data.extend_from_slice(&self.x.data()[i * row..(i + 1) * row]);
            labels.push(self.y[i]);
        }
        (
            TensorF::from_vec(&[idx.len(), IMG_HW, IMG_HW, IMG_C], data).unwrap(),
            labels,
        )
    }
}

/// Render one sample of class `k`.
fn render(rng: &mut Rng, k: usize, out: &mut [f32]) {
    let theta = std::f32::consts::PI * k as f32 / NUM_CLASSES as f32;
    let freq = 1.5 + (k % 5) as f32 * 0.7;
    let phase = rng.range_f32(0.0, std::f32::consts::TAU);
    let (ct, st) = (theta.cos(), theta.sin());
    // class-anchored blob centre (jittered)
    let quad = k % 4;
    let bx = if quad % 2 == 0 { 4.0 } else { 12.0 } + rng.normal() * 1.0;
    let by = if quad / 2 == 0 { 4.0 } else { 12.0 } + rng.normal() * 1.0;
    let blob_ch = (k + 1) % IMG_C;
    let grat_ch = k % IMG_C;
    for yy in 0..IMG_HW {
        for xx in 0..IMG_HW {
            let u = xx as f32 * ct + yy as f32 * st;
            let g = (std::f32::consts::TAU * freq * u / IMG_HW as f32 + phase).sin();
            let d2 = (xx as f32 - bx).powi(2) + (yy as f32 - by).powi(2);
            let blob = (-d2 / 8.0).exp();
            for c in 0..IMG_C {
                // heavy pixel noise keeps float accuracy in the low-90s:
                // leaves headroom for quantization damage to show at
                // mid bitwidths (a 100%-accurate task would flatten the
                // top rows of Tables 1-3)
                let mut v = 0.55 * rng.normal();
                if c == grat_ch {
                    v += 0.6 * g;
                }
                if c == blob_ch {
                    v += 0.9 * blob;
                }
                v += 0.15 * g; // weak copy everywhere
                out[(yy * IMG_HW + xx) * IMG_C + c] = v;
            }
        }
    }
}

/// Generate `n` samples with balanced classes (deterministic per seed).
pub fn synth_images(n: usize, seed: u64) -> ImageDataset {
    let mut rng = Rng::new(seed);
    let row = IMG_HW * IMG_HW * IMG_C;
    let mut data = vec![0.0f32; n * row];
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let k = i % NUM_CLASSES;
        render(&mut rng, k, &mut data[i * row..(i + 1) * row]);
        labels.push(k as i32);
    }
    // shuffle samples so eval subsets stay balanced
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut sdata = vec![0.0f32; n * row];
    let mut slabels = vec![0i32; n];
    for (dst, &src) in order.iter().enumerate() {
        sdata[dst * row..(dst + 1) * row].copy_from_slice(&data[src * row..(src + 1) * row]);
        slabels[dst] = labels[src];
    }
    ImageDataset {
        x: TensorF::from_vec(&[n, IMG_HW, IMG_HW, IMG_C], sdata).unwrap(),
        y: slabels,
    }
}

// ---------------------------------------------------------------------------
// Text
// ---------------------------------------------------------------------------

/// Markov/Zipf corpus: each state has `FANOUT` preferred successors
/// (hash-derived); with prob `P_MARKOV` the next token comes from them,
/// otherwise from the global Zipf marginal.
pub const FANOUT: usize = 4;
pub const P_MARKOV: f64 = 0.65;

pub fn synth_corpus(len: usize, vocab: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    let zipf = ZipfTable::new(vocab, 1.05);
    let mut out = Vec::with_capacity(len);
    let mut state = zipf.sample(&mut rng);
    for _ in 0..len {
        out.push(state as i32);
        state = if rng.next_f64() < P_MARKOV {
            // deterministic successor set of the current state
            let j = rng.below(FANOUT);
            successor(state, j, vocab)
        } else {
            zipf.sample(&mut rng)
        };
    }
    out
}

/// j-th preferred successor of `state` (fixed hash structure).
pub fn successor(state: usize, j: usize, vocab: usize) -> usize {
    let h = (state as u64)
        .wrapping_mul(6364136223846793005)
        .wrapping_add(1442695040888963407u64.wrapping_add((j as u64).wrapping_mul(0x9E3779B97F4A7C15)));
    ((h >> 33) as usize) % vocab
}

/// Cut a corpus into non-overlapping (seq_len + 1)-token windows,
/// truncated to a multiple of `batch` windows.
pub fn token_windows(corpus: &[i32], seq_len: usize, batch: usize) -> TensorI {
    let w = seq_len + 1;
    let count = (corpus.len() / w) / batch * batch;
    let mut data = Vec::with_capacity(count * w);
    for i in 0..count {
        data.extend_from_slice(&corpus[i * w..(i + 1) * w]);
    }
    TensorI::from_vec(&[count, w], data).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn images_balanced_and_deterministic() {
        let a = synth_images(100, 7);
        let b = synth_images(100, 7);
        assert_eq!(a.x.data(), b.x.data());
        assert_eq!(a.y, b.y);
        let mut counts = [0usize; NUM_CLASSES];
        for &y in &a.y {
            counts[y as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
        // values are bounded, non-degenerate
        let m = a.x.max_abs();
        assert!(m > 0.5 && m < 6.0, "max {m}");
    }

    #[test]
    fn different_seeds_differ() {
        let a = synth_images(10, 1);
        let b = synth_images(10, 2);
        assert_ne!(a.x.data(), b.x.data());
    }

    #[test]
    fn gather_batches() {
        let d = synth_images(20, 3);
        let (x, y) = d.gather(&[0, 5, 7]);
        assert_eq!(x.shape(), &[3, IMG_HW, IMG_HW, IMG_C]);
        assert_eq!(y.len(), 3);
        assert_eq!(y[1], d.y[5]);
    }

    #[test]
    fn corpus_statistics() {
        let corpus = synth_corpus(50_000, 200, 11);
        assert_eq!(corpus.len(), 50_000);
        assert!(corpus.iter().all(|&t| (0..200).contains(&t)));
        // Markov structure: successor bigrams should be far more common
        // than chance (1/200 per successor)
        let mut hit = 0usize;
        for w in corpus.windows(2) {
            let (s, t) = (w[0] as usize, w[1] as usize);
            if (0..FANOUT).any(|j| successor(s, j, 200) == t) {
                hit += 1;
            }
        }
        let rate = hit as f64 / (corpus.len() - 1) as f64;
        assert!(rate > 0.5, "markov hit rate {rate}");
    }

    #[test]
    fn windows_shape_and_multiple() {
        let corpus: Vec<i32> = (0..1000).map(|i| i % 50).collect();
        let w = token_windows(&corpus, 32, 4);
        assert_eq!(w.shape()[1], 33);
        assert_eq!(w.shape()[0] % 4, 0);
        assert_eq!(&w.data()[..5], &[0, 1, 2, 3, 4]);
    }
}
