//! Float parameter storage: the `init.ocst` seed weights from the
//! compile path and the `artifacts/trained/<model>.ocst` weights the
//! Rust trainer writes.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::ModelSpec;
use crate::tensor::io::Bundle;
use crate::tensor::TensorF;

/// Named float parameter leaves (`<layer>.W`, `<layer>.b`) in the
/// positional order of the float-param signature.
#[derive(Debug, Clone)]
pub struct WeightStore {
    pub bundle: Bundle,
}

impl WeightStore {
    pub fn load(path: impl AsRef<Path>) -> Result<WeightStore> {
        Ok(WeightStore {
            bundle: Bundle::load(path)?,
        })
    }

    /// The seed parameters written by `aot.py`.
    pub fn load_init(model: &ModelSpec) -> Result<WeightStore> {
        Self::load(model.dir.join("init.ocst"))
    }

    /// Conventional location of trained weights.
    pub fn trained_path(model: &ModelSpec) -> PathBuf {
        model
            .dir
            .parent()
            .unwrap_or(&model.dir)
            .join("trained")
            .join(format!("{}.ocst", model.name))
    }

    /// Trained weights if present, else the init seed (so every command
    /// works out of the box; tables warn when falling back).
    pub fn load_best(model: &ModelSpec) -> Result<(WeightStore, bool)> {
        let trained = Self::trained_path(model);
        if trained.exists() {
            Ok((Self::load(trained)?, true))
        } else {
            Ok((Self::load_init(model)?, false))
        }
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        self.bundle.save(path)
    }

    /// `<layer>.W`
    pub fn weight(&self, layer: &str) -> Result<&TensorF> {
        self.bundle
            .f32(&format!("{layer}.W"))
            .with_context(|| format!("weights for layer '{layer}'"))
    }

    /// `<layer>.b`
    pub fn bias(&self, layer: &str) -> Result<&TensorF> {
        self.bundle
            .f32(&format!("{layer}.b"))
            .with_context(|| format!("bias for layer '{layer}'"))
    }

    pub fn names(&self) -> &[String] {
        &self.bundle.order
    }

    /// Build from named leaves (trainer output).
    pub fn from_leaves(leaves: Vec<(String, TensorF)>) -> WeightStore {
        let mut bundle = Bundle::new();
        for (n, t) in leaves {
            bundle.push_f32(&n, t);
        }
        WeightStore { bundle }
    }

    /// Total parameter count (Table 5 denominators).
    pub fn param_count(&self) -> usize {
        self.bundle.f32s.values().map(|t| t.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_leaves_and_access() {
        let ws = WeightStore::from_leaves(vec![
            ("a.W".into(), TensorF::zeros(&[2, 3])),
            ("a.b".into(), TensorF::zeros(&[3])),
        ]);
        assert_eq!(ws.weight("a").unwrap().shape(), &[2, 3]);
        assert_eq!(ws.bias("a").unwrap().shape(), &[3]);
        assert!(ws.weight("zz").is_err());
        assert_eq!(ws.param_count(), 9);
        assert_eq!(ws.names(), &["a.W", "a.b"]);
    }
}
