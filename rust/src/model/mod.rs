//! Model metadata — the Rust view of `artifacts/<model>/meta.json`.
//!
//! The python compile path (`python/compile/aot.py`) records the exact
//! layer table and the positional input/output signature of every AOT
//! artifact; this module parses them so the two sides cannot drift.

pub mod store;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Value;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    Fc,
    Embed,
}

impl LayerKind {
    fn parse(s: &str) -> Result<LayerKind> {
        Ok(match s {
            "conv" => LayerKind::Conv,
            "fc" => LayerKind::Fc,
            "embed" => LayerKind::Embed,
            other => bail!("unknown layer kind '{other}'"),
        })
    }
}

/// One parametric layer of a benchmark model.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub name: String,
    pub kind: LayerKind,
    pub cin: usize,
    pub cin_pad: usize,
    pub cout: usize,
    pub ksize: usize,
    pub stride: usize,
    pub quantized: bool,
    /// Axis of the input-channel dim in the weight tensor (2 for HWIO
    /// conv, 0 for fc).
    pub w_cin_axis: usize,
    pub w_shape: Vec<usize>,
    pub w_shape_pad: Vec<usize>,
}

impl LayerSpec {
    fn from_json(v: &Value) -> Result<LayerSpec> {
        Ok(LayerSpec {
            name: v.get("name")?.as_str()?.to_string(),
            kind: LayerKind::parse(v.get("kind")?.as_str()?)?,
            cin: v.get("cin")?.as_usize()?,
            cin_pad: v.get("cin_pad")?.as_usize()?,
            cout: v.get("cout")?.as_usize()?,
            ksize: v.get("ksize")?.as_usize()?,
            stride: v.get("stride")?.as_usize()?,
            quantized: v.get("quantized")?.as_bool()?,
            w_cin_axis: v.get("w_cin_axis")?.as_usize()?,
            w_shape: v.get("w_shape")?.as_shape()?,
            w_shape_pad: v.get("w_shape_pad")?.as_shape()?,
        })
    }

    /// Weight elements per input channel (the knapsack cost unit).
    pub fn weights_per_channel(&self) -> usize {
        self.w_shape.iter().product::<usize>() / self.cin.max(1)
    }
}

/// dtype of an artifact input.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// One positional input/output of an artifact.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

/// One AOT-compiled HLO artifact (fwd / probe / train at some batch).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub key: String,
    pub file: PathBuf,
    pub batch: usize,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactSpec {
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|i| i.name == name)
            .with_context(|| format!("artifact {}: no input '{name}'", self.key))
    }
}

/// A benchmark model: layer table + artifact index + task constants.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub dir: PathBuf,
    pub pad_factor: f64,
    pub num_classes: usize,
    pub img_hw: usize,
    pub img_c: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub momentum: f32,
    pub layers: Vec<LayerSpec>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl ModelSpec {
    pub fn load(dir: impl AsRef<Path>) -> Result<ModelSpec> {
        let dir = dir.as_ref().to_path_buf();
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("read {}", meta_path.display()))?;
        let v = Value::parse(&text).with_context(|| format!("parse {}", meta_path.display()))?;

        let mut layers = Vec::new();
        for l in v.get("layers")?.as_arr()? {
            layers.push(LayerSpec::from_json(l)?);
        }

        let mut artifacts = BTreeMap::new();
        for (key, a) in v.get("artifacts")?.as_obj()? {
            let parse_ios = |field: &str| -> Result<Vec<IoSpec>> {
                let mut out = Vec::new();
                for io in a.get(field)?.as_arr()? {
                    let dtype = match io.get_opt("dtype").map(|d| d.as_str()).transpose()? {
                        Some("i32") => DType::I32,
                        _ => DType::F32,
                    };
                    out.push(IoSpec {
                        name: io.get("name")?.as_str()?.to_string(),
                        dtype,
                        shape: io.get("shape")?.as_shape()?,
                    });
                }
                Ok(out)
            };
            artifacts.insert(
                key.clone(),
                ArtifactSpec {
                    key: key.clone(),
                    file: dir.join(a.get("file")?.as_str()?),
                    batch: a.get("batch")?.as_usize()?,
                    inputs: parse_ios("inputs")?,
                    outputs: parse_ios("outputs")?,
                },
            );
        }

        Ok(ModelSpec {
            name: v.get("model")?.as_str()?.to_string(),
            dir,
            pad_factor: v.get("pad_factor")?.as_f64()?,
            num_classes: v.get("num_classes")?.as_usize()?,
            img_hw: v.get("img_hw")?.as_usize()?,
            img_c: v.get("img_c")?.as_usize()?,
            vocab: v.get("vocab")?.as_usize()?,
            seq_len: v.get("seq_len")?.as_usize()?,
            momentum: v.get("momentum")?.as_f64()? as f32,
            layers,
            artifacts,
        })
    }

    /// Load a model from the conventional `artifacts/<name>` location.
    pub fn load_named(artifacts_dir: impl AsRef<Path>, name: &str) -> Result<ModelSpec> {
        Self::load(artifacts_dir.as_ref().join(name))
    }

    pub fn is_lm(&self) -> bool {
        self.name == "lstmlm"
    }

    pub fn layer(&self, name: &str) -> Result<&LayerSpec> {
        self.layers
            .iter()
            .find(|l| l.name == name)
            .with_context(|| format!("model {}: no layer '{name}'", self.name))
    }

    pub fn quantized_layers(&self) -> impl Iterator<Item = &LayerSpec> {
        self.layers.iter().filter(|l| l.quantized)
    }

    pub fn artifact(&self, key: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(key)
            .with_context(|| format!("model {}: no artifact '{key}'", self.name))
    }

    /// Smallest fwd artifact whose batch >= n (serving picks this and
    /// pads); falls back to the largest available.
    pub fn fwd_for_batch(&self, n: usize) -> Result<&ArtifactSpec> {
        let mut best: Option<&ArtifactSpec> = None;
        let mut largest: Option<&ArtifactSpec> = None;
        for (k, a) in &self.artifacts {
            if !k.starts_with("fwd_b") {
                continue;
            }
            if largest.map_or(true, |l| a.batch > l.batch) {
                largest = Some(a);
            }
            if a.batch >= n && best.map_or(true, |b| a.batch < b.batch) {
                best = Some(a);
            }
        }
        best.or(largest)
            .with_context(|| format!("model {}: no fwd artifacts", self.name))
    }

    pub fn fwd_batches(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .keys()
            .filter_map(|k| k.strip_prefix("fwd_b").and_then(|b| b.parse().ok()))
            .collect();
        v.sort();
        v
    }

    pub fn train_artifact(&self) -> Result<&ArtifactSpec> {
        self.artifact("train")
    }

    pub fn probe_for_batch(&self, n: usize) -> Result<&ArtifactSpec> {
        self.artifact(&format!("probe_b{n}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// meta.json fixtures require `make artifacts`; integration tests in
    /// rust/tests cover the real files. Here: a synthetic meta.
    fn fake_meta(dir: &Path) {
        std::fs::create_dir_all(dir).unwrap();
        let meta = r#"{
 "model": "fake", "pad_factor": 1.25, "seed": 1, "num_classes": 10,
 "img_hw": 16, "img_c": 3, "vocab": 2000, "seq_len": 32, "momentum": 0.9,
 "layers": [
  {"name": "c1", "kind": "conv", "cin": 3, "cin_pad": 3, "cout": 8,
   "ksize": 3, "stride": 1, "quantized": false, "w_cin_axis": 2,
   "w_shape": [3,3,3,8], "w_shape_pad": [3,3,3,8]},
  {"name": "f1", "kind": "fc", "cin": 8, "cin_pad": 10, "cout": 10,
   "ksize": 0, "stride": 1, "quantized": true, "w_cin_axis": 0,
   "w_shape": [8,10], "w_shape_pad": [10,10]}
 ],
 "artifacts": {
  "fwd_b4": {"file": "fwd_b4.hlo.txt", "batch": 4,
    "inputs": [{"name": "x", "dtype": "f32", "shape": [4,16,16,3]}],
    "outputs": [{"name": "logits", "shape": [4,10]}]},
  "fwd_b32": {"file": "fwd_b32.hlo.txt", "batch": 32,
    "inputs": [], "outputs": []},
  "train": {"file": "train_b8.hlo.txt", "batch": 8,
    "inputs": [], "outputs": []}
 }}"#;
        std::fs::write(dir.join("meta.json"), meta).unwrap();
    }

    #[test]
    fn load_and_query() {
        let dir = std::env::temp_dir().join(format!("ocs_meta_{}", std::process::id()));
        fake_meta(&dir);
        let m = ModelSpec::load(&dir).unwrap();
        assert_eq!(m.name, "fake");
        assert_eq!(m.layers.len(), 2);
        assert!(!m.layer("c1").unwrap().quantized);
        let f1 = m.layer("f1").unwrap();
        assert_eq!(f1.cin_pad, 10);
        assert_eq!(f1.w_cin_axis, 0);
        assert_eq!(f1.weights_per_channel(), 10);
        assert_eq!(m.quantized_layers().count(), 1);
        assert_eq!(m.fwd_batches(), vec![4, 32]);
        assert_eq!(m.fwd_for_batch(3).unwrap().batch, 4);
        assert_eq!(m.fwd_for_batch(5).unwrap().batch, 32);
        assert_eq!(m.fwd_for_batch(99).unwrap().batch, 32); // fallback
        assert!(m.artifact("nope").is_err());
        let fwd = m.artifact("fwd_b4").unwrap();
        assert_eq!(fwd.input_index("x").unwrap(), 0);
        assert_eq!(fwd.inputs[0].dtype, DType::F32);
        std::fs::remove_dir_all(&dir).ok();
    }
}
