//! Tiny argv parser (clap is unavailable offline).
//!
//! Grammar: `ocs <command> [--key value | --key=value | --flag] [pos...]`.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub cmd: Option<String>,
    flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(item) = iter.next() {
            if let Some(rest) = item.strip_prefix("--") {
                if let Some(eq) = rest.find('=') {
                    out.flags
                        .insert(rest[..eq].to_string(), rest[eq + 1..].to_string());
                } else {
                    // value = next token unless it is another flag
                    match iter.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = iter.next().unwrap();
                            out.flags.insert(rest.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(rest.to_string(), "true".to_string());
                        }
                    }
                }
            } else if out.cmd.is_none() {
                out.cmd = Some(item);
            } else {
                out.positional.push(item);
            }
        }
        out
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn str(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.str(key).unwrap_or(default)
    }

    pub fn req(&self, key: &str) -> Result<&str> {
        self.str(key)
            .with_context(|| format!("missing required flag --{key}"))
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.str(key) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(x) => Ok(x),
                Err(_) => bail!("flag --{key}: cannot parse '{v}'"),
            },
        }
    }

    /// Optional typed flag: `None` when absent, error when unparseable.
    pub fn parse_opt<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>> {
        match self.str(key) {
            None => Ok(None),
            Some(v) => match v.parse() {
                Ok(x) => Ok(Some(x)),
                Err(_) => bail!("flag --{key}: cannot parse '{v}'"),
            },
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.str(key) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) => default,
            None => default,
        }
    }

    /// Comma-separated list flag.
    pub fn list(&self, key: &str) -> Vec<String> {
        self.str(key)
            .map(|v| {
                v.split(',')
                    .filter(|s| !s.is_empty())
                    .map(|s| s.to_string())
                    .collect()
            })
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn command_flags_positional() {
        let a = parse("table extra --id 2 --models miniresnet,minivgg --quick");
        assert_eq!(a.cmd.as_deref(), Some("table"));
        assert_eq!(a.str("id"), Some("2"));
        assert_eq!(a.list("models"), vec!["miniresnet", "minivgg"]);
        // a bare trailing flag is boolean; `--quick extra` would instead
        // bind "extra" as its value (use --quick=true in that position)
        assert!(a.bool_or("quick", false));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_form_and_numbers() {
        let a = parse("bench --ratio=0.05 --steps 200");
        assert_eq!(a.parse_or("ratio", 0.0f64).unwrap(), 0.05);
        assert_eq!(a.parse_or("steps", 0usize).unwrap(), 200);
        assert_eq!(a.parse_or("missing", 7i32).unwrap(), 7);
        assert!(a.parse_or("ratio", 0usize).is_err());
    }

    #[test]
    fn required() {
        let a = parse("x");
        assert!(a.req("model").is_err());
        let b = parse("x --model lstm");
        assert_eq!(b.req("model").unwrap(), "lstm");
    }

    #[test]
    fn optional_typed_flag() {
        let a = parse("serve --deadline-ms 250");
        assert_eq!(a.parse_opt::<u64>("deadline-ms").unwrap(), Some(250));
        assert_eq!(a.parse_opt::<u64>("queue-cap").unwrap(), None);
        let b = parse("serve --deadline-ms soon");
        assert!(b.parse_opt::<u64>("deadline-ms").is_err());
    }

    #[test]
    fn boolean_flag_before_flag() {
        let a = parse("serve --verbose --port 8");
        assert!(a.bool_or("verbose", false));
        assert_eq!(a.str("port"), Some("8"));
    }
}
