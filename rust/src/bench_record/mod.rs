//! Versioned benchmark records — the measurement format every harness
//! emits and every perf gate reads.
//!
//! The repo tracks seven trajectories (`BENCH_quant`, `BENCH_native`,
//! `BENCH_serving`, `BENCH_loadtest`, `BENCH_chaos`, `BENCH_slow`,
//! `BENCH_autotune`). Before this module each harness
//! wrote its own ad-hoc JSON that CI uploaded and nothing ever read
//! back; the records could not be compared run-over-run, so the paper's
//! "negligible overhead" claim (§3.5/§5.4) and every kernel PR were
//! optimized against nothing. Following rebar's methodology (captured
//! measurements as committed data files, explicit noise handling,
//! diff-based comparison), a [`BenchRecord`] is now:
//!
//! * **versioned** — [`SCHEMA_VERSION`] is embedded and checked on
//!   parse, so a stale baseline fails loudly instead of diffing
//!   garbage;
//! * **self-describing** — a `bench` tag, backend label, host metadata
//!   (OS, arch, thread count) and a quick-mode flag travel with the
//!   measurements, so a diff can warn when it compares across hosts;
//! * **flat** — one [`Row`] per measured case, each with a single
//!   primary metric (`value` + `unit` + direction) that `bench diff`
//!   gates on, plus free-form secondary metrics under `extra`.
//!
//! [`diff::diff`] compares two records case-by-case, applies a
//! configurable noise threshold, and reports per-case ratios; the
//! `ocs bench diff OLD NEW` subcommand exits nonzero on any regression
//! past the threshold, and `ocs bench check FILE` validates a single
//! record (CI runs both — see `.github/workflows/ci.yml` and
//! `docs/BENCH_FORMAT.md`). Baselines live under `records/` and are
//! regenerated with `make bench-record`.

pub mod diff;
pub mod history;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::bench_support::CaseRecord;
use crate::autotune::SearchOutcome;
use crate::serve::{ChaosMatrixReport, ChaosReport, LoadPoint, SlowReport, SweepPoint};
use crate::util::json::{self, Value};

/// Bump when the record shape changes incompatibly; `parse` rejects
/// records written by any other version so stale committed baselines
/// fail loudly instead of producing nonsense ratios.
pub const SCHEMA_VERSION: u32 = 1;

/// Machine metadata captured at emit time. A diff across differing
/// hosts still runs — CI baselines and runners rarely match — but the
/// report carries a noise warning.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HostMeta {
    pub os: String,
    pub arch: String,
    pub threads_available: usize,
}

impl HostMeta {
    /// The current process's host, `threads` from the kernel pool.
    pub fn current(threads_available: usize) -> HostMeta {
        HostMeta {
            os: std::env::consts::OS.to_string(),
            arch: std::env::consts::ARCH.to_string(),
            threads_available,
        }
    }
}

/// One flat measurement: a unique case name, the primary metric the
/// diff gates on, and any number of secondary metrics under `extra`
/// (recorded for the trajectory, never gated).
#[derive(Debug, Clone, PartialEq)]
pub struct Row {
    /// Unique within the record, e.g. `i8_gemm/packed_t4/128x288x96`.
    pub name: String,
    /// Primary metric (what `bench diff` compares).
    pub value: f64,
    /// Unit of `value`, e.g. `ns` or `req/s`.
    pub unit: String,
    /// Direction of goodness: throughput rows set this, latency rows
    /// don't. The diff's regression factor respects it.
    pub higher_is_better: bool,
    /// Secondary metrics (thread counts, percentiles, speedups, ...).
    pub extra: BTreeMap<String, f64>,
}

/// A complete versioned benchmark record — see the module docs.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchRecord {
    pub schema: u32,
    /// Trajectory tag: `quant`, `native`, or `serving`.
    pub bench: String,
    /// Backend label (`cpu`, `sim`, `native:...`).
    pub backend: String,
    /// True when the record was taken under `OCS_BENCH_QUICK` — quick
    /// runs are noisier, and the diff warns when quick flags differ.
    pub quick: bool,
    pub host: HostMeta,
    pub rows: Vec<Row>,
}

impl BenchRecord {
    /// Fresh record for the current host; `quick` is read from the
    /// environment so it always reflects how the harness actually ran.
    pub fn new(bench: &str, backend: &str, threads_available: usize) -> BenchRecord {
        BenchRecord {
            schema: SCHEMA_VERSION,
            bench: bench.to_string(),
            backend: backend.to_string(),
            quick: std::env::var("OCS_BENCH_QUICK").is_ok(),
            host: HostMeta::current(threads_available),
            rows: Vec::new(),
        }
    }

    /// Unify the kernel-harness case records (`BENCH_quant` /
    /// `BENCH_native`): one row per case+shape, primary metric mean
    /// wall time, throughput and speedup carried as secondaries.
    pub fn from_cases(
        bench: &str,
        backend: &str,
        threads_available: usize,
        cases: &[CaseRecord],
    ) -> BenchRecord {
        let mut rec = BenchRecord::new(bench, backend, threads_available);
        for c in cases {
            let mut extra = BTreeMap::new();
            extra.insert("threads".to_string(), c.threads as f64);
            extra.insert("melems_per_s".to_string(), c.melems_per_s);
            extra.insert("speedup_vs_serial".to_string(), c.speedup_vs_serial);
            // dispersion secondaries in the primary metric's unit —
            // `mad` is reserved: diff() derives a per-case noise
            // threshold from the *baseline* row's measured spread
            extra.insert("mad".to_string(), c.mad_ns);
            extra.insert("min".to_string(), c.min_ns);
            extra.insert("max".to_string(), c.max_ns);
            rec.rows.push(Row {
                name: format!("{}/{}", c.name, c.shape),
                value: c.mean_ns,
                unit: "ns".to_string(),
                higher_is_better: false,
                extra,
            });
        }
        rec
    }

    /// Unify the serving worker sweep (`BENCH_serving`): one row per
    /// swept worker count, primary metric sustained throughput,
    /// latency percentiles and admission counters as secondaries.
    pub fn from_sweep(backend: &str, points: &[SweepPoint]) -> BenchRecord {
        let mut rec = BenchRecord::new("serving", backend, crate::kernels::pool::available());
        for p in points {
            let base = format!("serve/w{}", p.workers);
            // a sweep may legitimately revisit a worker count; keep
            // names unique so validate() and diff() stay well-defined
            let mut name = base.clone();
            let mut k = 2usize;
            while rec.rows.iter().any(|r| r.name == name) {
                name = format!("{base}#{k}");
                k += 1;
            }
            let mut extra = BTreeMap::new();
            extra.insert("workers".to_string(), p.workers as f64);
            extra.insert("requests".to_string(), p.requests as f64);
            extra.insert("ok".to_string(), p.ok as f64);
            extra.insert("errors".to_string(), p.errors as f64);
            extra.insert("secs".to_string(), p.secs);
            extra.insert("mean_latency_ms".to_string(), p.mean_latency_ms);
            extra.insert("p50_ms".to_string(), p.p50_ms);
            extra.insert("p99_ms".to_string(), p.p99_ms);
            extra.insert("mean_batch".to_string(), p.mean_batch);
            extra.insert("rejected".to_string(), p.rejected as f64);
            extra.insert("deadline_exceeded".to_string(), p.deadline_exceeded as f64);
            extra.insert("panics".to_string(), p.panics as f64);
            extra.insert("restarts".to_string(), p.restarts as f64);
            extra.insert("jobs_failed".to_string(), p.jobs_failed as f64);
            extra.insert("dead_workers".to_string(), p.dead_workers as f64);
            rec.rows.push(Row {
                name,
                value: p.rps,
                unit: "req/s".to_string(),
                higher_is_better: true,
                extra,
            });
        }
        rec
    }

    /// Unify the closed-loop load harness (`BENCH_loadtest`): one row
    /// per offered-load step (client concurrency), primary metric
    /// sustained throughput, client-side latency percentiles and
    /// per-tenant traffic split as secondaries, plus a final
    /// `loadtest/saturation` row carrying the peak-throughput step.
    pub fn from_loadtest(backend: &str, points: &[LoadPoint]) -> BenchRecord {
        let mut rec = BenchRecord::new("loadtest", backend, crate::kernels::pool::available());
        for p in points {
            let base = format!("loadtest/c{}", p.clients);
            // a sweep may legitimately revisit a client count; keep
            // names unique so validate() and diff() stay well-defined
            let mut name = base.clone();
            let mut k = 2usize;
            while rec.rows.iter().any(|r| r.name == name) {
                name = format!("{base}#{k}");
                k += 1;
            }
            let mut extra = BTreeMap::new();
            extra.insert("clients".to_string(), p.clients as f64);
            extra.insert("requests".to_string(), p.requests as f64);
            extra.insert("ok".to_string(), p.ok as f64);
            extra.insert("errors".to_string(), p.errors as f64);
            extra.insert("secs".to_string(), p.secs);
            extra.insert("mean_ms".to_string(), p.mean_ms);
            extra.insert("p50_ms".to_string(), p.p50_ms);
            extra.insert("p95_ms".to_string(), p.p95_ms);
            extra.insert("p99_ms".to_string(), p.p99_ms);
            extra.insert("rejected".to_string(), p.rejected as f64);
            extra.insert("deadline_exceeded".to_string(), p.deadline_exceeded as f64);
            extra.insert("panics".to_string(), p.panics as f64);
            extra.insert("restarts".to_string(), p.restarts as f64);
            extra.insert("jobs_failed".to_string(), p.jobs_failed as f64);
            extra.insert("dead_workers".to_string(), p.dead_workers as f64);
            for (tenant, ok, rejected) in &p.tenants {
                extra.insert(format!("tenant_{tenant}_ok"), *ok as f64);
                extra.insert(format!("tenant_{tenant}_rejected"), *rejected as f64);
            }
            rec.rows.push(Row {
                name,
                value: p.rps,
                unit: "req/s".to_string(),
                higher_is_better: true,
                extra,
            });
        }
        if let Some(sat) = points.iter().max_by(|a, b| a.rps.total_cmp(&b.rps)) {
            let mut extra = BTreeMap::new();
            extra.insert("clients".to_string(), sat.clients as f64);
            rec.rows.push(Row {
                name: "loadtest/saturation".to_string(),
                value: sat.rps,
                unit: "req/s".to_string(),
                higher_is_better: true,
                extra,
            });
        }
        rec
    }

    /// Unify the chaos gate (`BENCH_chaos`): one row per phase
    /// (healthy / degraded / recovered), primary metric throughput, with
    /// the fault bookkeeping as secondaries — `chaos/recovered` is the
    /// row regression gates should pin.
    pub fn from_chaos(backend: &str, report: &ChaosReport) -> BenchRecord {
        let mut rec = BenchRecord::new("chaos", backend, crate::kernels::pool::available());
        let phases: [(&str, &LoadPoint); 3] = [
            ("chaos/healthy", &report.healthy),
            ("chaos/degraded", &report.degraded),
            ("chaos/recovered", &report.recovered),
        ];
        for (name, p) in phases {
            let mut extra = BTreeMap::new();
            extra.insert("clients".to_string(), p.clients as f64);
            extra.insert("requests".to_string(), p.requests as f64);
            extra.insert("ok".to_string(), p.ok as f64);
            extra.insert("errors".to_string(), p.errors as f64);
            extra.insert("secs".to_string(), p.secs);
            extra.insert("p50_ms".to_string(), p.p50_ms);
            extra.insert("p99_ms".to_string(), p.p99_ms);
            extra.insert("rejected".to_string(), p.rejected as f64);
            if name == "chaos/degraded" {
                extra.insert("panics".to_string(), p.panics as f64);
                extra.insert("jobs_failed".to_string(), p.jobs_failed as f64);
                extra.insert("killed_worker".to_string(), report.killed_worker as f64);
            }
            if name == "chaos/recovered" {
                extra.insert("restarts".to_string(), report.restarts as f64);
                extra.insert(
                    "recovery_ratio".to_string(),
                    p.rps / report.healthy.rps.max(1e-9),
                );
            }
            rec.rows.push(Row {
                name: name.to_string(),
                value: p.rps,
                unit: "req/s".to_string(),
                higher_is_better: true,
                extra,
            });
        }
        rec
    }

    /// Journal one autotune search (`BENCH_autotune`): what the search
    /// found (winner vs uniform baseline on the accuracy/footprint
    /// axes), what it cost (candidates evaluated, prep-cache behavior),
    /// and the Pareto frontier it traced. `autotune/winner_footprint`
    /// (lower is better) and `autotune/search` (evals, lower is better)
    /// are the rows regression gates should pin; frontier rows are
    /// indexed, so a frontier that changes shape appears as added /
    /// removed rows rather than a gate failure.
    pub fn from_autotune(backend: &str, out: &SearchOutcome) -> BenchRecord {
        let mut rec = BenchRecord::new("autotune", backend, crate::kernels::pool::available());
        let pct = |f: f64| (f * 100.0).max(0.01); // primaries must be > 0
        let mut extra = BTreeMap::new();
        extra.insert("float_accuracy_pct".to_string(), out.float_accuracy * 100.0);
        extra.insert("acc_floor_pct".to_string(), out.acc_floor * 100.0);
        rec.rows.push(Row {
            name: "autotune/baseline_accuracy".to_string(),
            value: pct(out.baseline.score.accuracy),
            unit: "pct".to_string(),
            higher_is_better: true,
            extra,
        });
        let mut extra = BTreeMap::new();
        extra.insert("agreement_pct".to_string(), out.winner.score.agreement * 100.0);
        rec.rows.push(Row {
            name: "autotune/winner_accuracy".to_string(),
            value: pct(out.winner.score.accuracy),
            unit: "pct".to_string(),
            higher_is_better: true,
            extra,
        });
        let mut extra = BTreeMap::new();
        extra.insert(
            "baseline_footprint_bytes".to_string(),
            out.baseline.score.footprint as f64,
        );
        extra.insert(
            "footprint_ratio".to_string(),
            out.winner.score.footprint as f64 / (out.baseline.score.footprint as f64).max(1.0),
        );
        extra.insert(
            "est_latency_us".to_string(),
            out.winner.score.est_latency_us,
        );
        rec.rows.push(Row {
            name: "autotune/winner_footprint".to_string(),
            value: (out.winner.score.footprint as f64).max(1.0),
            unit: "bytes".to_string(),
            higher_is_better: false,
            extra,
        });
        let mut extra = BTreeMap::new();
        extra.insert("scored_total".to_string(), out.scored_total as f64);
        extra.insert("cache_hits".to_string(), out.cache_hits as f64);
        extra.insert("cache_misses".to_string(), out.cache_misses as f64);
        extra.insert("cache_hit_rate".to_string(), out.cache_hit_rate());
        extra.insert("cache_evictions".to_string(), out.cache_evictions as f64);
        extra.insert("beam".to_string(), out.beam as f64);
        extra.insert("groups".to_string(), out.groups as f64);
        rec.rows.push(Row {
            name: "autotune/search".to_string(),
            value: (out.evaluated as f64).max(1.0),
            unit: "evals".to_string(),
            higher_is_better: false,
            extra,
        });
        for (i, (footprint, accuracy)) in out.pareto.iter().enumerate() {
            let mut extra = BTreeMap::new();
            extra.insert("accuracy_pct".to_string(), accuracy * 100.0);
            rec.rows.push(Row {
                name: format!("autotune/pareto/{i}"),
                value: (*footprint as f64).max(1.0),
                unit: "bytes".to_string(),
                higher_is_better: false,
                extra,
            });
        }
        rec
    }

    /// Journal the slow-worker drill (`BENCH_slow`): one row per phase
    /// (healthy / slow with no deadline / slow with the deadline
    /// shedding), primary metric throughput. `slow/shed` is the row to
    /// pin — the deadline path must keep shedding work instead of
    /// letting queueing collapse the pool.
    pub fn from_slow(backend: &str, report: &SlowReport) -> BenchRecord {
        let mut rec = BenchRecord::new("slow", backend, crate::kernels::pool::available());
        let phases: [(&str, &LoadPoint); 3] = [
            ("slow/healthy", &report.healthy),
            ("slow/slow", &report.slow),
            ("slow/shed", &report.shed),
        ];
        for (name, p) in phases {
            let mut extra = BTreeMap::new();
            extra.insert("clients".to_string(), p.clients as f64);
            extra.insert("requests".to_string(), p.requests as f64);
            extra.insert("ok".to_string(), p.ok as f64);
            extra.insert("errors".to_string(), p.errors as f64);
            extra.insert("secs".to_string(), p.secs);
            extra.insert("p50_ms".to_string(), p.p50_ms);
            extra.insert("p99_ms".to_string(), p.p99_ms);
            extra.insert("rejected".to_string(), p.rejected as f64);
            extra.insert("deadline_exceeded".to_string(), p.deadline_exceeded as f64);
            if name == "slow/slow" {
                extra.insert("slow_us".to_string(), report.slow_us as f64);
            }
            if name == "slow/shed" {
                extra.insert("deadline_ms".to_string(), report.deadline_ms as f64);
                extra.insert(
                    "shed_ratio".to_string(),
                    p.deadline_exceeded as f64 / (p.requests as f64).max(1.0),
                );
            }
            rec.rows.push(Row {
                name: name.to_string(),
                value: p.rps,
                unit: "req/s".to_string(),
                higher_is_better: true,
                extra,
            });
        }
        rec
    }

    /// Journal the chaos drill matrix (`BENCH_chaos_matrix`): one row
    /// per scenario phase (`chaos_matrix/<scenario>/<phase>`), with the
    /// scenario's containment counters (restarts, swap aborts,
    /// quarantine rejections, dead workers) and recovery ratio on the
    /// `recovered` row.
    pub fn from_chaos_matrix(backend: &str, report: &ChaosMatrixReport) -> BenchRecord {
        let mut rec = BenchRecord::new("chaos_matrix", backend, crate::kernels::pool::available());
        for s in &report.scenarios {
            let phases: [(&str, &LoadPoint); 3] = [
                ("healthy", &s.healthy),
                ("degraded", &s.degraded),
                ("recovered", &s.recovered),
            ];
            for (phase, p) in phases {
                let mut extra = BTreeMap::new();
                extra.insert("clients".to_string(), p.clients as f64);
                extra.insert("requests".to_string(), p.requests as f64);
                extra.insert("ok".to_string(), p.ok as f64);
                extra.insert("errors".to_string(), p.errors as f64);
                extra.insert("secs".to_string(), p.secs);
                extra.insert("p50_ms".to_string(), p.p50_ms);
                extra.insert("p99_ms".to_string(), p.p99_ms);
                extra.insert("rejected".to_string(), p.rejected as f64);
                if phase == "degraded" {
                    extra.insert("panics".to_string(), p.panics as f64);
                    extra.insert("jobs_failed".to_string(), p.jobs_failed as f64);
                }
                if phase == "recovered" {
                    extra.insert("restarts".to_string(), s.restarts as f64);
                    extra.insert("swap_aborts".to_string(), s.swap_aborts as f64);
                    extra.insert("quarantined".to_string(), s.quarantined as f64);
                    extra.insert("dead_workers".to_string(), s.dead_workers as f64);
                    extra.insert(
                        "recovery_ratio".to_string(),
                        p.rps / s.healthy.rps.max(1e-9),
                    );
                }
                rec.rows.push(Row {
                    name: format!("chaos_matrix/{}/{phase}", s.name),
                    value: p.rps,
                    unit: "req/s".to_string(),
                    higher_is_better: true,
                    extra,
                });
            }
        }
        rec
    }

    pub fn row(&self, name: &str) -> Option<&Row> {
        self.rows.iter().find(|r| r.name == name)
    }

    // ---- serialization ----------------------------------------------------

    pub fn to_json(&self) -> String {
        json::obj(vec![
            ("schema", json::num(self.schema as f64)),
            ("bench", json::s(&self.bench)),
            ("backend", json::s(&self.backend)),
            ("quick", Value::Bool(self.quick)),
            (
                "host",
                json::obj(vec![
                    ("os", json::s(&self.host.os)),
                    ("arch", json::s(&self.host.arch)),
                    (
                        "threads_available",
                        json::num(self.host.threads_available as f64),
                    ),
                ]),
            ),
            (
                "rows",
                json::arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            json::obj(vec![
                                ("name", json::s(&r.name)),
                                ("value", json::num(r.value)),
                                ("unit", json::s(&r.unit)),
                                ("higher_is_better", Value::Bool(r.higher_is_better)),
                                (
                                    "extra",
                                    Value::Obj(
                                        r.extra
                                            .iter()
                                            .map(|(k, v)| (k.clone(), json::num(*v)))
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
        .to_string()
    }

    /// Parse a record, rejecting missing fields and foreign schema
    /// versions. Structural only — run [`BenchRecord::validate`] for
    /// the sanity gates (`ocs bench check` does both).
    pub fn parse(text: &str) -> Result<BenchRecord> {
        let v = Value::parse(text).context("bench record is not valid JSON")?;
        let schema = v
            .get_opt("schema")
            .and_then(|s| s.as_f64().ok())
            .map(|s| s as u32)
            .context("bench record has no 'schema' field (pre-versioning record? regenerate with `make bench-record`)")?;
        if schema != SCHEMA_VERSION {
            bail!(
                "bench record schema v{schema} but this build reads v{SCHEMA_VERSION} — \
                 regenerate the record with `make bench-record`"
            );
        }
        let host = v.get("host")?;
        let mut rows = Vec::new();
        for rv in v.get("rows")?.as_arr()? {
            let mut extra = BTreeMap::new();
            if let Some(ev) = rv.get_opt("extra") {
                for (k, x) in ev.as_obj()? {
                    extra.insert(k.clone(), x.as_f64()?);
                }
            }
            rows.push(Row {
                name: rv.get("name")?.as_str()?.to_string(),
                value: rv.get("value")?.as_f64()?,
                unit: rv.get("unit")?.as_str()?.to_string(),
                higher_is_better: rv.get("higher_is_better")?.as_bool()?,
                extra,
            });
        }
        Ok(BenchRecord {
            schema,
            bench: v.get("bench")?.as_str()?.to_string(),
            backend: v.get("backend")?.as_str()?.to_string(),
            quick: v.get("quick")?.as_bool()?,
            host: HostMeta {
                os: host.get("os")?.as_str()?.to_string(),
                arch: host.get("arch")?.as_str()?.to_string(),
                threads_available: host.get("threads_available")?.as_usize()?,
            },
            rows,
        })
    }

    /// Sanity gates beyond structure: at least one row, unique names,
    /// finite positive primary metrics, finite secondaries, a sane
    /// thread count. This is what `ocs bench check` enforces on every
    /// fresh record before CI will diff it.
    pub fn validate(&self) -> Result<()> {
        if self.bench.is_empty() {
            bail!("empty bench tag");
        }
        if self.host.threads_available == 0 {
            bail!("host.threads_available must be >= 1");
        }
        if self.rows.is_empty() {
            bail!("record has no measurement rows");
        }
        let mut seen = std::collections::BTreeSet::new();
        for r in &self.rows {
            if r.name.is_empty() {
                bail!("row with an empty name");
            }
            if !seen.insert(&r.name) {
                bail!("duplicate row name '{}'", r.name);
            }
            if r.unit.is_empty() {
                bail!("row '{}': empty unit", r.name);
            }
            if !r.value.is_finite() || r.value <= 0.0 {
                bail!("row '{}': non-positive or non-finite value {}", r.name, r.value);
            }
            for (k, x) in &r.extra {
                if !x.is_finite() {
                    bail!("row '{}': non-finite extra metric '{k}'", r.name);
                }
            }
        }
        Ok(())
    }

    /// Read + parse (no sanity validation; see [`BenchRecord::validate`]).
    pub fn load(path: &Path) -> Result<BenchRecord> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read bench record {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parse bench record {}", path.display()))
    }

    /// Serialize and write to `path`.
    pub fn write(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json())
            .with_context(|| format!("write bench record {}", path.display()))
    }

    /// Max `speedup_vs_serial` over rows whose name starts with
    /// `prefix` and that ran with more than one thread — the
    /// machine-relative gate CI applies to the kernel harnesses
    /// (`ocs bench check --speedup-prefix P --min-speedup X`).
    pub fn best_parallel_speedup(&self, prefix: &str) -> Option<f64> {
        self.rows
            .iter()
            .filter(|r| {
                r.name.starts_with(prefix) && r.extra.get("threads").copied().unwrap_or(1.0) > 1.0
            })
            .filter_map(|r| r.extra.get("speedup_vs_serial").copied())
            .max_by(|a, b| a.total_cmp(b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn case(name: &str, shape: &str, threads: usize, mean_ns: f64, speedup: f64) -> CaseRecord {
        CaseRecord {
            name: name.to_string(),
            shape: shape.to_string(),
            threads,
            mean_ns,
            melems_per_s: 100.0,
            speedup_vs_serial: speedup,
            mad_ns: mean_ns * 0.05,
            min_ns: mean_ns * 0.9,
            max_ns: mean_ns * 1.3,
        }
    }

    #[test]
    fn roundtrip_from_cases() {
        let cases = vec![
            case("perchan_quant/old_serial", "256x256", 1, 2.0e6, 1.0),
            case("perchan_quant/fused_t4", "256x256", 4, 0.5e6, 4.0),
        ];
        let rec = BenchRecord::from_cases("quant", "cpu", 8, &cases);
        rec.validate().unwrap();
        let back = BenchRecord::parse(&rec.to_json()).unwrap();
        assert_eq!(rec, back);
        assert_eq!(back.schema, SCHEMA_VERSION);
        assert_eq!(back.bench, "quant");
        assert_eq!(back.host.threads_available, 8);
        let row = back.row("perchan_quant/fused_t4/256x256").unwrap();
        assert_eq!(row.value, 0.5e6);
        assert_eq!(row.unit, "ns");
        assert!(!row.higher_is_better);
        assert_eq!(row.extra["threads"], 4.0);
        assert_eq!(row.extra["speedup_vs_serial"], 4.0);
        assert_eq!(row.extra["mad"], 0.5e6 * 0.05);
        assert_eq!(row.extra["min"], 0.5e6 * 0.9);
        assert_eq!(row.extra["max"], 0.5e6 * 1.3);
    }

    #[test]
    fn roundtrip_from_sweep() {
        let points = vec![
            SweepPoint {
                workers: 1,
                requests: 128,
                ok: 128,
                errors: 0,
                secs: 0.5,
                rps: 256.0,
                mean_latency_ms: 1.5,
                p50_ms: 1.0,
                p99_ms: 4.0,
                mean_batch: 2.0,
                rejected: 0,
                deadline_exceeded: 0,
                panics: 0,
                restarts: 0,
                jobs_failed: 0,
                dead_workers: 0,
            },
            SweepPoint {
                workers: 2,
                requests: 128,
                ok: 128,
                errors: 0,
                secs: 0.25,
                rps: 512.0,
                mean_latency_ms: 0.9,
                p50_ms: 0.7,
                p99_ms: 2.0,
                mean_batch: 1.5,
                rejected: 0,
                deadline_exceeded: 0,
                panics: 0,
                restarts: 0,
                jobs_failed: 0,
                dead_workers: 0,
            },
        ];
        let rec = BenchRecord::from_sweep("sim", &points);
        rec.validate().unwrap();
        let back = BenchRecord::parse(&rec.to_json()).unwrap();
        assert_eq!(rec, back);
        assert_eq!(back.bench, "serving");
        let w2 = back.row("serve/w2").unwrap();
        assert!(w2.higher_is_better);
        assert_eq!(w2.value, 512.0);
        assert_eq!(w2.extra["p99_ms"], 2.0);
    }

    #[test]
    fn roundtrip_from_loadtest() {
        let point = |clients: usize, rps: f64| LoadPoint {
            clients,
            requests: 256,
            ok: 250,
            errors: 6,
            secs: 1.0,
            rps,
            mean_ms: 2.0,
            p50_ms: 1.5,
            p95_ms: 4.0,
            p99_ms: 8.0,
            rejected: 6,
            deadline_exceeded: 0,
            panics: 0,
            restarts: 0,
            jobs_failed: 0,
            dead_workers: 0,
            tenants: vec![
                ("default".to_string(), 120, 2),
                ("gold".to_string(), 130, 4),
            ],
        };
        let rec = BenchRecord::from_loadtest("sim", &[point(1, 100.0), point(4, 320.0)]);
        rec.validate().unwrap();
        let back = BenchRecord::parse(&rec.to_json()).unwrap();
        assert_eq!(rec, back);
        assert_eq!(back.bench, "loadtest");
        let c4 = back.row("loadtest/c4").unwrap();
        assert!(c4.higher_is_better);
        assert_eq!(c4.value, 320.0);
        assert_eq!(c4.unit, "req/s");
        assert_eq!(c4.extra["p95_ms"], 4.0);
        assert_eq!(c4.extra["tenant_gold_ok"], 130.0);
        assert_eq!(c4.extra["tenant_default_rejected"], 2.0);
        let sat = back.row("loadtest/saturation").unwrap();
        assert_eq!(sat.value, 320.0);
        assert_eq!(sat.extra["clients"], 4.0);
        // revisited client counts stay unique
        let rec = BenchRecord::from_loadtest("sim", &[point(2, 100.0), point(2, 101.0)]);
        rec.validate().unwrap();
        assert!(rec.row("loadtest/c2").is_some());
        assert!(rec.row("loadtest/c2#2").is_some());
    }

    #[test]
    fn roundtrip_from_chaos() {
        let phase = |rps: f64, panics: u64, jobs_failed: u64| LoadPoint {
            clients: 8,
            requests: 256,
            ok: 250,
            errors: 6,
            secs: 1.0,
            rps,
            mean_ms: 2.0,
            p50_ms: 1.5,
            p95_ms: 4.0,
            p99_ms: 8.0,
            rejected: 6,
            deadline_exceeded: 0,
            panics,
            restarts: 0,
            jobs_failed,
            dead_workers: 0,
            tenants: vec![],
        };
        let report = ChaosReport {
            healthy: phase(400.0, 0, 0),
            degraded: phase(300.0, 1, 5),
            recovered: phase(380.0, 0, 0),
            killed_worker: 3,
            panics: 1,
            restarts: 1,
            jobs_failed: 5,
        };
        let rec = BenchRecord::from_chaos("sim+fault", &report);
        rec.validate().unwrap();
        let back = BenchRecord::parse(&rec.to_json()).unwrap();
        assert_eq!(rec, back);
        assert_eq!(back.bench, "chaos");
        let degraded = back.row("chaos/degraded").unwrap();
        assert_eq!(degraded.value, 300.0);
        assert_eq!(degraded.extra["panics"], 1.0);
        assert_eq!(degraded.extra["killed_worker"], 3.0);
        let recovered = back.row("chaos/recovered").unwrap();
        assert_eq!(recovered.extra["restarts"], 1.0);
        assert_eq!(recovered.extra["recovery_ratio"], 380.0 / 400.0);
        assert!(back.row("chaos/healthy").is_some());
    }

    #[test]
    fn roundtrip_from_chaos_matrix() {
        use crate::serve::ChaosScenario;
        let phase = |rps: f64| LoadPoint {
            clients: 8,
            requests: 96,
            ok: 90,
            errors: 6,
            secs: 1.0,
            rps,
            mean_ms: 2.0,
            p50_ms: 1.5,
            p95_ms: 4.0,
            p99_ms: 8.0,
            rejected: 6,
            deadline_exceeded: 0,
            panics: 0,
            restarts: 0,
            jobs_failed: 0,
            dead_workers: 0,
            tenants: vec![],
        };
        let scenario = |name: &str, aborts: u64, quarantined: u64| ChaosScenario {
            name: name.to_string(),
            healthy: phase(400.0),
            degraded: phase(250.0),
            recovered: phase(360.0),
            panics: 1,
            restarts: 1,
            jobs_failed: 4,
            swap_aborts: aborts,
            quarantined,
            dead_workers: 0,
        };
        let report = ChaosMatrixReport {
            scenarios: vec![
                scenario("single-kill", 0, 0),
                scenario("swap-crash", 1, 0),
                scenario("crash-loop-tenant", 0, 7),
            ],
        };
        let rec = BenchRecord::from_chaos_matrix("sim+fault", &report);
        rec.validate().unwrap();
        let back = BenchRecord::parse(&rec.to_json()).unwrap();
        assert_eq!(rec, back);
        assert_eq!(back.bench, "chaos_matrix");
        assert_eq!(back.rows.len(), 9, "three phases per scenario");
        let rec_row = back.row("chaos_matrix/swap-crash/recovered").unwrap();
        assert_eq!(rec_row.extra["swap_aborts"], 1.0);
        assert_eq!(rec_row.extra["recovery_ratio"], 360.0 / 400.0);
        let q = back.row("chaos_matrix/crash-loop-tenant/recovered").unwrap();
        assert_eq!(q.extra["quarantined"], 7.0);
        assert!(back.row("chaos_matrix/single-kill/degraded").is_some());
    }

    #[test]
    fn sweep_revisit_keeps_names_unique() {
        let p = SweepPoint {
            workers: 2,
            requests: 64,
            ok: 64,
            errors: 0,
            secs: 0.1,
            rps: 640.0,
            mean_latency_ms: 1.0,
            p50_ms: 1.0,
            p99_ms: 2.0,
            mean_batch: 1.0,
            rejected: 0,
            deadline_exceeded: 0,
            panics: 0,
            restarts: 0,
            jobs_failed: 0,
            dead_workers: 0,
        };
        let rec = BenchRecord::from_sweep("sim", &[p.clone(), p.clone(), p]);
        rec.validate().unwrap();
        assert!(rec.row("serve/w2").is_some());
        assert!(rec.row("serve/w2#2").is_some());
        assert!(rec.row("serve/w2#3").is_some());
    }

    #[test]
    fn stale_schema_is_rejected() {
        let rec = BenchRecord::from_cases("quant", "cpu", 4, &[case("a", "s", 1, 1.0, 1.0)]);
        let stale = rec.to_json().replacen("\"schema\":1", "\"schema\":0", 1);
        let err = BenchRecord::parse(&stale).unwrap_err().to_string();
        assert!(err.contains("schema v0"), "{err}");
        // keys serialize sorted, so "schema" is last: strip ",\"schema\":1"
        let missing = rec.to_json().replacen(",\"schema\":1", "", 1);
        assert!(missing.len() < rec.to_json().len(), "strip failed");
        assert!(BenchRecord::parse(&missing).is_err());
    }

    #[test]
    fn malformed_records_are_rejected() {
        assert!(BenchRecord::parse("not json").is_err());
        assert!(BenchRecord::parse("{}").is_err());
        // structurally fine, semantically empty → validate refuses
        let empty = BenchRecord::new("quant", "cpu", 4);
        assert!(BenchRecord::parse(&empty.to_json()).is_ok());
        assert!(empty.validate().is_err());
    }

    #[test]
    fn validate_catches_bad_rows() {
        let mut rec = BenchRecord::from_cases("quant", "cpu", 4, &[case("a", "s", 1, 1.0, 1.0)]);
        rec.rows[0].value = 0.0;
        assert!(rec.validate().is_err());
        rec.rows[0].value = f64::NAN;
        assert!(rec.validate().is_err());
        rec.rows[0].value = 1.0;
        rec.validate().unwrap();
        // duplicate names
        let dup = rec.rows[0].clone();
        rec.rows.push(dup);
        assert!(rec.validate().is_err());
        // non-finite secondary
        rec.rows.pop();
        rec.rows[0].extra.insert("x".into(), f64::INFINITY);
        assert!(rec.validate().is_err());
    }

    #[test]
    fn best_parallel_speedup_ignores_serial_rows() {
        let rec = BenchRecord::from_cases(
            "native",
            "cpu",
            4,
            &[
                case("i8_gemm/packed_t1", "s", 1, 4.0, 9.9),
                case("i8_gemm/packed_t2", "s", 2, 2.0, 2.0),
                case("i8_gemm/packed_t4", "s", 4, 1.0, 3.5),
                case("other/fused", "s", 4, 1.0, 50.0),
            ],
        );
        assert_eq!(rec.best_parallel_speedup("i8_gemm/packed_t"), Some(3.5));
        assert_eq!(rec.best_parallel_speedup("nope"), None);
    }
}
