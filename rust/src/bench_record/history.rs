//! Trajectory view over a directory of records — `ocs bench history DIR`.
//!
//! `bench diff` answers "did this PR regress?"; history answers "where
//! has this metric been going?". Point it at a directory of record
//! files (e.g. `records/`, or a `records/history/` folder of dated
//! snapshots named `BENCH_quant_2026-08-01.json`) and it renders one
//! table per bench tag: a row per case, a column per record file in
//! filename order (date-stamped names therefore sort chronologically).
//! Immediate subdirectories are scanned too (`fixtures/` and dot-dirs
//! excepted) — `make bench-snapshot` archives one
//! `records/history/<date>-pr<N>/` folder per PR, so
//! `ocs bench history records/history` renders the per-PR trajectory
//! with each snapshot's folder name as the column label (dated folder
//! names sort before bare top-level records, so columns read oldest →
//! current left to right). Files that
//! fail to parse — foreign schema versions, fixtures, stray JSON — are
//! skipped and listed, never fatal: a history view over a mixed
//! directory should show what it can.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::diff::fmt_value;
use super::BenchRecord;

/// One trajectory: every record in the directory sharing a bench tag.
#[derive(Debug, Clone)]
pub struct Group {
    pub bench: String,
    /// Column headers: file stems in filename order, `*` when quick.
    pub columns: Vec<String>,
    /// `(case name, unit, one cell per column)` — `None` where the
    /// case is absent from that record.
    pub rows: Vec<(String, String, Vec<Option<f64>>)>,
}

#[derive(Debug, Clone)]
pub struct History {
    pub groups: Vec<Group>,
    /// Files in the directory that did not parse as bench records.
    pub skipped: Vec<String>,
}

/// `*.json` names directly inside `dir`, unsorted.
fn json_names(dir: &Path) -> Result<Vec<String>> {
    let entries =
        std::fs::read_dir(dir).with_context(|| format!("read directory {}", dir.display()))?;
    Ok(entries
        .filter_map(|e| e.ok())
        .filter(|e| e.path().is_file())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.ends_with(".json"))
        .collect())
}

/// Load every `*.json` in `dir` plus its immediate subdirectories
/// (snapshot folders; one level, not recursive) and group by bench tag.
pub fn load_dir(dir: &Path) -> Result<History> {
    let mut files: Vec<(String, BenchRecord)> = Vec::new();
    let mut skipped = Vec::new();
    let mut names: Vec<String> = json_names(dir)?;
    let subdirs: Vec<String> = std::fs::read_dir(dir)
        .with_context(|| format!("read directory {}", dir.display()))?
        .filter_map(|e| e.ok())
        .filter(|e| e.path().is_dir())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        // `fixtures/` holds pinned test inputs, not trajectory data, and
        // dot-dirs are never snapshots
        .filter(|n| n != "fixtures" && !n.starts_with('.'))
        .collect();
    for sub in subdirs {
        for n in json_names(&dir.join(&sub)).unwrap_or_default() {
            names.push(format!("{sub}/{n}"));
        }
    }
    names.sort();
    for name in names {
        match BenchRecord::load(&dir.join(&name)) {
            Ok(rec) => files.push((name, rec)),
            Err(_) => skipped.push(name),
        }
    }
    if files.is_empty() {
        bail!(
            "no readable bench records in {} ({} file(s) skipped)",
            dir.display(),
            skipped.len()
        );
    }
    // group by bench tag, preserving the per-tag filename order
    let mut by_tag: BTreeMap<String, Vec<&(String, BenchRecord)>> = BTreeMap::new();
    for f in &files {
        by_tag.entry(f.1.bench.clone()).or_default().push(f);
    }
    let mut groups = Vec::new();
    for (bench, recs) in by_tag {
        let columns = recs
            .iter()
            .map(|(name, rec)| {
                let stem = name.strip_suffix(".json").unwrap_or(name);
                // a snapshot record named for its tag is fully described
                // by its folder: `2026-08-08-pr9/BENCH_quant` → the
                // folder IS the column
                let tag_file = format!("BENCH_{}", rec.bench);
                let stem = match stem.split_once('/') {
                    Some((sub, file)) if file == tag_file => sub,
                    _ => stem,
                };
                if rec.quick {
                    format!("{stem}*")
                } else {
                    stem.to_string()
                }
            })
            .collect();
        // case order: first appearance across records in column order
        let mut case_order: Vec<(String, String)> = Vec::new();
        for (_, rec) in &recs {
            for row in &rec.rows {
                if !case_order.iter().any(|(n, _)| n == &row.name) {
                    case_order.push((row.name.clone(), row.unit.clone()));
                }
            }
        }
        let rows = case_order
            .into_iter()
            .map(|(case, unit)| {
                let cells = recs
                    .iter()
                    .map(|(_, rec)| rec.row(&case).map(|r| r.value))
                    .collect();
                (case, unit, cells)
            })
            .collect();
        groups.push(Group {
            bench,
            columns,
            rows,
        });
    }
    Ok(History { groups, skipped })
}

impl History {
    /// Plain-text tables, one per bench tag (what `ocs bench history`
    /// prints).
    pub fn table(&self) -> String {
        let mut out = String::new();
        for g in &self.groups {
            let _ = writeln!(
                out,
                "bench history [{}]: {} record(s), {} case(s)",
                g.bench,
                g.columns.len(),
                g.rows.len()
            );
            let _ = write!(out, "  {:<52}", "case");
            for c in &g.columns {
                let _ = write!(out, " {c:>20}");
            }
            out.push('\n');
            for (case, unit, cells) in &g.rows {
                let _ = write!(out, "  {case:<52}");
                for cell in cells {
                    match cell {
                        Some(v) => {
                            let _ = write!(out, " {:>20}", fmt_value(*v, unit));
                        }
                        None => {
                            let _ = write!(out, " {:>20}", "—");
                        }
                    }
                }
                out.push('\n');
            }
        }
        if self.groups.iter().any(|g| g.columns.iter().any(|c| c.ends_with('*'))) {
            out.push_str("(* = record taken in quick mode)\n");
        }
        if !self.skipped.is_empty() {
            let _ = writeln!(out, "skipped (not bench records): {}", self.skipped.join(", "));
        }
        out
    }

    /// GitHub-flavored markdown (CI appends this to the `bench-gate`
    /// job summary next to the diff ratio tables).
    pub fn markdown(&self) -> String {
        let mut out = String::new();
        for g in &self.groups {
            let _ = writeln!(
                out,
                "### bench history: `{}` — {} record(s)\n",
                g.bench,
                g.columns.len()
            );
            let _ = write!(out, "| case |");
            for c in &g.columns {
                let _ = write!(out, " {c} |");
            }
            out.push('\n');
            out.push_str("|---|");
            out.push_str(&"---:|".repeat(g.columns.len()));
            out.push('\n');
            for (case, unit, cells) in &g.rows {
                let _ = write!(out, "| `{case}` |");
                for cell in cells {
                    match cell {
                        Some(v) => {
                            let _ = write!(out, " {} |", fmt_value(*v, unit));
                        }
                        None => {
                            let _ = write!(out, " — |");
                        }
                    }
                }
                out.push('\n');
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_record::Row;

    fn rec(bench: &str, rows: &[(&str, f64)]) -> BenchRecord {
        let mut r = BenchRecord::new(bench, "cpu", 4);
        for (name, value) in rows {
            r.rows.push(Row {
                name: name.to_string(),
                value: *value,
                unit: "ns".to_string(),
                higher_is_better: false,
                extra: Default::default(),
            });
        }
        r
    }

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("ocs_hist_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn directory_renders_grouped_trajectories() {
        let d = tmpdir("grouped");
        rec("quant", &[("a", 100.0), ("b", 2.0e6)])
            .write(&d.join("BENCH_quant_2026-01.json"))
            .unwrap();
        rec("quant", &[("a", 120.0), ("c", 5.0)])
            .write(&d.join("BENCH_quant_2026-02.json"))
            .unwrap();
        rec("native", &[("g", 1.0)])
            .write(&d.join("BENCH_native.json"))
            .unwrap();
        std::fs::write(d.join("junk.json"), "not a record").unwrap();
        let h = load_dir(&d).unwrap();
        assert_eq!(h.groups.len(), 2); // native, quant (tag-sorted)
        assert_eq!(h.skipped, vec!["junk.json".to_string()]);
        let quant = h.groups.iter().find(|g| g.bench == "quant").unwrap();
        assert_eq!(
            quant.columns,
            vec!["BENCH_quant_2026-01", "BENCH_quant_2026-02"]
        );
        // case "a" in both columns, "b" only first, "c" only second
        let a = quant.rows.iter().find(|r| r.0 == "a").unwrap();
        assert_eq!(a.2, vec![Some(100.0), Some(120.0)]);
        let b = quant.rows.iter().find(|r| r.0 == "b").unwrap();
        assert_eq!(b.2, vec![Some(2.0e6), None]);
        let t = h.table();
        assert!(t.contains("bench history [quant]"), "{t}");
        assert!(t.contains("2.000 ms"), "{t}");
        assert!(t.contains("junk.json"), "{t}");
        let md = h.markdown();
        assert!(md.contains("### bench history: `native`"), "{md}");
        assert!(md.contains("| `a` | 100.0 ns | 120.0 ns |"), "{md}");
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn snapshot_subfolders_join_the_trajectory() {
        let d = tmpdir("snap");
        rec("quant", &[("a", 120.0)])
            .write(&d.join("BENCH_quant.json"))
            .unwrap();
        let snap = d.join("2026-08-01-pr8");
        std::fs::create_dir_all(&snap).unwrap();
        rec("quant", &[("a", 100.0)])
            .write(&snap.join("BENCH_quant.json"))
            .unwrap();
        rec("quant", &[("a", 90.0)])
            .write(&snap.join("BENCH_quant_quick.json"))
            .unwrap();
        let h = load_dir(&d).unwrap();
        let quant = h.groups.iter().find(|g| g.bench == "quant").unwrap();
        // dated folder sorts before the bare record; the tag-named
        // snapshot collapses to its folder, others keep the full path
        assert_eq!(
            quant.columns,
            vec![
                "2026-08-01-pr8",
                "2026-08-01-pr8/BENCH_quant_quick",
                "BENCH_quant"
            ]
        );
        let a = quant.rows.iter().find(|r| r.0 == "a").unwrap();
        assert_eq!(a.2, vec![Some(100.0), Some(90.0), Some(120.0)]);
        std::fs::remove_dir_all(&d).unwrap();
    }

    #[test]
    fn empty_or_missing_directory_errors() {
        let d = tmpdir("empty");
        assert!(load_dir(&d).is_err());
        std::fs::write(d.join("junk.json"), "{}").unwrap();
        let err = load_dir(&d).unwrap_err().to_string();
        assert!(err.contains("no readable bench records"), "{err}");
        assert!(load_dir(&d.join("does_not_exist")).is_err());
        std::fs::remove_dir_all(&d).unwrap();
    }
}
