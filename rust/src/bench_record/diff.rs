//! Record-vs-record comparison with explicit noise handling — the
//! engine behind `ocs bench diff OLD NEW`.
//!
//! Cases are matched by row name; each common case gets a **regression
//! factor** that respects the metric's direction (`> 1` is always
//! "worse", whether the metric is wall time or throughput). A
//! configurable noise threshold `t` splits verdicts three ways:
//!
//! * `factor > 1 + t`        → [`Verdict::Regressed`]
//! * `factor < 1 / (1 + t)`  → [`Verdict::Improved`]
//! * otherwise               → [`Verdict::WithinNoise`]
//!
//! The threshold is **per-case** when the baseline row carries a `mad`
//! secondary (the harnesses record the median absolute deviation of
//! their samples, in the primary metric's unit): the effective
//! threshold for that case is `max(t, MAD_SIGMAS · mad / old)`. A
//! genuinely noisy case (high measured spread) therefore stops
//! tripping the gate on wobble, while tight cases keep the global
//! bound — MAD can only *widen* a case's band, never tighten it below
//! the CLI threshold, so cross-host tripwires stay safe.
//!
//! Cases present on only one side are reported as added/removed, never
//! failed — CI runners have varying core counts, so thread-sweep rows
//! legitimately come and go. Host or quick-mode mismatches likewise
//! produce a warning (ratios across hosts are noise-dominated; see
//! `docs/BENCH_FORMAT.md` for the thresholds each context uses), not an
//! error.

use std::fmt::Write as _;

use anyhow::{bail, Result};

use super::BenchRecord;

/// How many baseline MADs of drift count as noise. For a symmetric
/// distribution ±3 MADs covers roughly what ±2 standard deviations
/// would; wider would start hiding real regressions behind one noisy
/// baseline run.
pub const MAD_SIGMAS: f64 = 3.0;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    Improved,
    WithinNoise,
    Regressed,
}

impl Verdict {
    pub fn label(&self) -> &'static str {
        match self {
            Verdict::Improved => "improved",
            Verdict::WithinNoise => "within noise",
            Verdict::Regressed => "REGRESSED",
        }
    }
}

/// One matched case.
#[derive(Debug, Clone)]
pub struct DiffRow {
    pub name: String,
    pub old: f64,
    pub new: f64,
    pub unit: String,
    /// Direction-normalized: `> 1` is worse, `< 1` is better.
    pub factor: f64,
    /// Effective noise threshold applied to this case: the global one,
    /// widened to `MAD_SIGMAS · mad / old` when the baseline row
    /// recorded a `mad` secondary larger than that.
    pub threshold: f64,
    pub verdict: Verdict,
}

/// Full comparison of two records of the same bench tag.
#[derive(Debug, Clone)]
pub struct Diff {
    pub bench: String,
    /// Allowed relative regression (0.25 = new may be up to 25% worse).
    pub threshold: f64,
    pub rows: Vec<DiffRow>,
    /// Case names only in the new record.
    pub added: Vec<String>,
    /// Case names only in the old record.
    pub removed: Vec<String>,
    /// Set when host metadata or quick flags differ — ratios are then
    /// noise-dominated and only a generous threshold is meaningful.
    pub host_note: Option<String>,
}

/// Compare `new` against `old` under noise threshold `threshold`.
/// Records must share a bench tag and both pass
/// [`BenchRecord::validate`]; a unit change for the same case name is
/// treated as a remove+add (the metric is no longer comparable).
pub fn diff(old: &BenchRecord, new: &BenchRecord, threshold: f64) -> Result<Diff> {
    if old.bench != new.bench {
        bail!(
            "bench tag mismatch: old is '{}', new is '{}' — these are different trajectories",
            old.bench,
            new.bench
        );
    }
    if !threshold.is_finite() || threshold <= 0.0 {
        bail!("noise threshold must be a positive number, got {threshold}");
    }
    old.validate()?;
    new.validate()?;
    let mut host_note = None;
    if old.host != new.host || old.quick != new.quick {
        host_note = Some(format!(
            "records were taken on different setups (old: {}/{} {}t{}, new: {}/{} {}t{}) — \
             ratios include host noise",
            old.host.os,
            old.host.arch,
            old.host.threads_available,
            if old.quick { " quick" } else { "" },
            new.host.os,
            new.host.arch,
            new.host.threads_available,
            if new.quick { " quick" } else { "" },
        ));
    }
    let mut rows = Vec::new();
    let mut removed = Vec::new();
    let mut added: Vec<String> = Vec::new();
    for o in &old.rows {
        match new.row(&o.name) {
            Some(n) if n.unit == o.unit && n.higher_is_better == o.higher_is_better => {
                // validate() guarantees both values are finite and > 0
                let factor = if o.higher_is_better {
                    o.value / n.value
                } else {
                    n.value / o.value
                };
                // per-case band: the baseline's own measured spread may
                // widen (never tighten) the global threshold
                let t_case = match o.extra.get("mad") {
                    Some(m) if m.is_finite() && *m > 0.0 => {
                        threshold.max(MAD_SIGMAS * m / o.value)
                    }
                    _ => threshold,
                };
                let verdict = if factor > 1.0 + t_case {
                    Verdict::Regressed
                } else if factor < 1.0 / (1.0 + t_case) {
                    Verdict::Improved
                } else {
                    Verdict::WithinNoise
                };
                rows.push(DiffRow {
                    name: o.name.clone(),
                    old: o.value,
                    new: n.value,
                    unit: o.unit.clone(),
                    factor,
                    threshold: t_case,
                    verdict,
                });
            }
            Some(_) => {
                // same name, different metric: not comparable
                removed.push(o.name.clone());
                added.push(o.name.clone());
            }
            None => removed.push(o.name.clone()),
        }
    }
    for n in &new.rows {
        if old.row(&n.name).is_none() {
            added.push(n.name.clone());
        }
    }
    Ok(Diff {
        bench: old.bench.clone(),
        threshold,
        rows,
        added,
        removed,
        host_note,
    })
}

pub(crate) fn fmt_value(v: f64, unit: &str) -> String {
    if unit == "ns" {
        crate::bench_support::fmt_ns(v)
    } else if v >= 100.0 {
        format!("{v:.0} {unit}")
    } else {
        format!("{v:.2} {unit}")
    }
}

impl Diff {
    pub fn regressions(&self) -> impl Iterator<Item = &DiffRow> {
        self.rows.iter().filter(|r| r.verdict == Verdict::Regressed)
    }

    pub fn has_regressions(&self) -> bool {
        self.regressions().next().is_some()
    }

    /// Human-readable per-case ratio table (what `ocs bench diff`
    /// prints).
    pub fn table(&self) -> String {
        let mut out = format!(
            "bench diff [{}]: {} common case(s), {} added, {} removed, \
             noise threshold {:.0}%\n",
            self.bench,
            self.rows.len(),
            self.added.len(),
            self.removed.len(),
            self.threshold * 100.0
        );
        if let Some(note) = &self.host_note {
            let _ = writeln!(out, "note: {note}");
        }
        let _ = writeln!(
            out,
            "  {:<52} {:>14} {:>14} {:>8}  {}",
            "case", "old", "new", "factor", "verdict"
        );
        for r in &self.rows {
            let _ = writeln!(
                out,
                "  {:<52} {:>14} {:>14} {:>7.2}x  {}{}",
                r.name,
                fmt_value(r.old, &r.unit),
                fmt_value(r.new, &r.unit),
                r.factor,
                r.verdict.label(),
                if r.threshold > self.threshold {
                    format!(" (mad band ±{:.0}%)", r.threshold * 100.0)
                } else {
                    String::new()
                }
            );
        }
        for name in &self.added {
            let _ = writeln!(out, "  + {name} (new case, no baseline)");
        }
        for name in &self.removed {
            let _ = writeln!(out, "  - {name} (in baseline only)");
        }
        let n_reg = self.regressions().count();
        if n_reg > 0 {
            let _ = writeln!(
                out,
                "{n_reg} case(s) regressed past the {:.0}% threshold",
                self.threshold * 100.0
            );
        } else {
            let _ = writeln!(
                out,
                "no regression past the {:.0}% threshold",
                self.threshold * 100.0
            );
        }
        out
    }

    /// GitHub-flavored markdown ratio table (CI appends this to the job
    /// summary).
    pub fn markdown(&self) -> String {
        let n_reg = self.regressions().count();
        let mut out = format!(
            "### bench diff: `{}` — {}\n\n",
            self.bench,
            if n_reg > 0 {
                format!("**{n_reg} regression(s)** past {:.0}%", self.threshold * 100.0)
            } else {
                format!("no regression past {:.0}%", self.threshold * 100.0)
            }
        );
        if let Some(note) = &self.host_note {
            let _ = writeln!(out, "> ⚠ {note}\n");
        }
        out.push_str("| case | old | new | factor | verdict |\n|---|---:|---:|---:|---|\n");
        for r in &self.rows {
            let _ = writeln!(
                out,
                "| `{}` | {} | {} | {:.2}x | {}{} |",
                r.name,
                fmt_value(r.old, &r.unit),
                fmt_value(r.new, &r.unit),
                r.factor,
                r.verdict.label(),
                if r.threshold > self.threshold {
                    format!(" (mad band ±{:.0}%)", r.threshold * 100.0)
                } else {
                    String::new()
                }
            );
        }
        for name in &self.added {
            let _ = writeln!(out, "| `{name}` | — | added | — | no baseline |");
        }
        for name in &self.removed {
            let _ = writeln!(out, "| `{name}` | removed | — | — | baseline only |");
        }
        out.push('\n');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_record::{BenchRecord, Row};
    use std::collections::BTreeMap;

    fn rec(bench: &str, rows: &[(&str, f64, &str, bool)]) -> BenchRecord {
        let mut r = BenchRecord::new(bench, "cpu", 4);
        for (name, value, unit, hib) in rows {
            r.rows.push(Row {
                name: name.to_string(),
                value: *value,
                unit: unit.to_string(),
                higher_is_better: *hib,
                extra: BTreeMap::new(),
            });
        }
        r
    }

    #[test]
    fn verdicts_respect_direction_and_threshold() {
        let old = rec(
            "t",
            &[
                ("lat/a", 100.0, "ns", false),
                ("lat/b", 100.0, "ns", false),
                ("lat/c", 100.0, "ns", false),
                ("thr/d", 100.0, "req/s", true),
            ],
        );
        let new = rec(
            "t",
            &[
                ("lat/a", 140.0, "ns", false),  // 1.40x worse → regressed
                ("lat/b", 108.0, "ns", false),  // 1.08x → within noise
                ("lat/c", 50.0, "ns", false),   // 0.50x → improved
                ("thr/d", 60.0, "req/s", true), // throughput drop → 1.67x worse
            ],
        );
        let d = diff(&old, &new, 0.25).unwrap();
        assert_eq!(d.rows.len(), 4);
        let by = |n: &str| d.rows.iter().find(|r| r.name == n).unwrap();
        assert_eq!(by("lat/a").verdict, Verdict::Regressed);
        assert_eq!(by("lat/b").verdict, Verdict::WithinNoise);
        assert_eq!(by("lat/c").verdict, Verdict::Improved);
        assert_eq!(by("thr/d").verdict, Verdict::Regressed);
        assert!((by("thr/d").factor - 100.0 / 60.0).abs() < 1e-9);
        assert!(d.has_regressions());
        assert_eq!(d.regressions().count(), 2);
    }

    #[test]
    fn baseline_mad_widens_the_noise_band_per_case() {
        let mut old = rec(
            "t",
            &[
                ("wobbly", 100.0, "ns", false),
                ("steady", 100.0, "ns", false),
                ("thr/wobbly", 100.0, "req/s", true),
            ],
        );
        // wobbly cases measured a wide spread: 3·20/100 = ±60% band
        old.rows[0].extra.insert("mad".into(), 20.0);
        old.rows[1].extra.insert("mad".into(), 0.5);
        old.rows[2].extra.insert("mad".into(), 20.0);
        let new = rec(
            "t",
            &[
                ("wobbly", 135.0, "ns", false),     // 1.35x: past global, inside mad band
                ("steady", 135.0, "ns", false),     // 1.35x: tight case still regresses
                ("thr/wobbly", 70.0, "req/s", true), // 1.43x drop: inside mad band
            ],
        );
        let d = diff(&old, &new, 0.25).unwrap();
        let by = |n: &str| d.rows.iter().find(|r| r.name == n).unwrap();
        assert_eq!(by("wobbly").verdict, Verdict::WithinNoise);
        assert!((by("wobbly").threshold - 0.6).abs() < 1e-12);
        assert_eq!(by("steady").verdict, Verdict::Regressed);
        assert_eq!(by("steady").threshold, 0.25); // mad below global → global holds
        assert_eq!(by("thr/wobbly").verdict, Verdict::WithinNoise);
        assert_eq!(d.regressions().count(), 1);
        // the widened band is visible in both reports
        assert!(d.table().contains("mad band ±60%"), "{}", d.table());
        assert!(d.markdown().contains("mad band ±60%"), "{}", d.markdown());
    }

    #[test]
    fn mad_widens_improvement_band_too() {
        // inside the widened band, a big apparent *improvement* is also
        // just noise — the verdict must stay symmetric
        let mut old = rec("t", &[("wobbly", 100.0, "ns", false)]);
        old.rows[0].extra.insert("mad".into(), 20.0);
        let new = rec("t", &[("wobbly", 65.0, "ns", false)]);
        let d = diff(&old, &new, 0.25).unwrap();
        assert_eq!(d.rows[0].verdict, Verdict::WithinNoise);
        // and a bogus mad (non-finite / zero) falls back to the global
        let mut bad = rec("t", &[("wobbly", 100.0, "ns", false)]);
        bad.rows[0].extra.insert("mad".into(), 0.0);
        let d2 = diff(&bad, &new, 0.25).unwrap();
        assert_eq!(d2.rows[0].verdict, Verdict::Improved);
        assert_eq!(d2.rows[0].threshold, 0.25);
    }

    #[test]
    fn added_and_removed_cases_do_not_fail() {
        let old = rec("t", &[("a", 1.0, "ns", false), ("gone", 1.0, "ns", false)]);
        let new = rec("t", &[("a", 1.0, "ns", false), ("fresh", 1.0, "ns", false)]);
        let d = diff(&old, &new, 0.25).unwrap();
        assert_eq!(d.rows.len(), 1);
        assert_eq!(d.added, vec!["fresh".to_string()]);
        assert_eq!(d.removed, vec!["gone".to_string()]);
        assert!(!d.has_regressions());
    }

    #[test]
    fn unit_change_is_not_comparable() {
        let old = rec("t", &[("a", 100.0, "ns", false)]);
        let new = rec("t", &[("a", 1.0, "req/s", true)]);
        let d = diff(&old, &new, 0.25).unwrap();
        assert!(d.rows.is_empty());
        assert_eq!(d.added, vec!["a".to_string()]);
        assert_eq!(d.removed, vec!["a".to_string()]);
    }

    #[test]
    fn mismatched_bench_tags_error() {
        let old = rec("quant", &[("a", 1.0, "ns", false)]);
        let new = rec("native", &[("a", 1.0, "ns", false)]);
        assert!(diff(&old, &new, 0.25).is_err());
        assert!(diff(&old, &old, 0.0).is_err());
        assert!(diff(&old, &old, f64::NAN).is_err());
    }

    #[test]
    fn host_mismatch_warns_in_reports() {
        let old = rec("t", &[("a", 100.0, "ns", false)]);
        let mut new = rec("t", &[("a", 100.0, "ns", false)]);
        new.host.threads_available = 16;
        let d = diff(&old, &new, 0.25).unwrap();
        assert!(d.host_note.is_some());
        assert!(d.table().contains("host noise"));
        assert!(d.markdown().contains("host noise"));
    }

    #[test]
    fn reports_render_all_sections() {
        let old = rec("t", &[("slow", 100.0, "ns", false), ("gone", 1.0, "ns", false)]);
        let new = rec("t", &[("slow", 200.0, "ns", false), ("fresh", 1.0, "ns", false)]);
        let d = diff(&old, &new, 0.25).unwrap();
        let t = d.table();
        assert!(t.contains("2.00x"), "{t}");
        assert!(t.contains("REGRESSED"), "{t}");
        assert!(t.contains("+ fresh"), "{t}");
        assert!(t.contains("- gone"), "{t}");
        let md = d.markdown();
        assert!(md.contains("| `slow` |"), "{md}");
        assert!(md.contains("**1 regression(s)**"), "{md}");
    }
}
