//! Evaluators: top-1 accuracy (CNN benchmarks) and perplexity (LSTM LM),
//! running entirely through the AOT-compiled fwd artifacts.

use anyhow::{bail, Result};

use crate::calib::slice_rows;
use crate::model::ModelSpec;
use crate::pipeline::PreparedModel;
use crate::runtime::{Engine, Input, Inputs};
use crate::tensor::{TensorF, TensorI};

/// Top-1 accuracy of a prepared model over `(images, labels)`.
/// Uses the largest fwd artifact <= requested batch; the final partial
/// chunk is zero-padded and its padded rows excluded from scoring.
pub fn accuracy(
    engine: &Engine,
    spec: &ModelSpec,
    prep: &PreparedModel,
    images: &TensorF,
    labels: &[i32],
    batch: usize,
) -> Result<f64> {
    let n = images.shape()[0];
    if n != labels.len() {
        bail!("images ({n}) vs labels ({}) mismatch", labels.len());
    }
    let art = spec.fwd_for_batch(batch)?;
    let exe = engine.load(art)?;
    let b = art.batch;
    let mut base: Inputs = Default::default();
    prep.insert_inputs(&mut base);

    let mut correct = 0usize;
    let mut seen = 0usize;
    let mut i = 0usize;
    while i < n {
        let take = (n - i).min(b);
        let xb = if take == b {
            slice_rows(images, i, b)?
        } else {
            pad_rows(&slice_rows(images, i, take)?, b)?
        };
        base.insert("x".into(), Input::F32(xb));
        let out = exe.execute(&base)?;
        let logits = out.get("logits")?;
        for (row, pred) in logits.argmax_rows().into_iter().enumerate().take(take) {
            if pred as i32 == labels[i + row] {
                correct += 1;
            }
        }
        seen += take;
        i += take;
    }
    Ok(correct as f64 / seen.max(1) as f64)
}

/// Perplexity of the LSTM LM over token windows `(N, seq_len + 1)`.
/// N must be a multiple of the fwd artifact batch (the datasets this
/// repo generates are sized accordingly).
pub fn perplexity(
    engine: &Engine,
    spec: &ModelSpec,
    prep: &PreparedModel,
    windows: &TensorI,
) -> Result<f64> {
    let n = windows.shape()[0];
    let art = spec.fwd_for_batch(1)?;
    let b = art.batch;
    if n % b != 0 {
        bail!("window count {n} must be a multiple of the artifact batch {b}");
    }
    let exe = engine.load(art)?;
    let mut base: Inputs = Default::default();
    prep.insert_inputs(&mut base);

    let row: usize = windows.shape()[1..].iter().product();
    let mut nll = 0.0f64;
    let mut ntok = 0.0f64;
    for chunk in 0..(n / b) {
        let start = chunk * b * row;
        let tb = TensorI::from_vec(
            &[b, windows.shape()[1]],
            windows.data()[start..start + b * row].to_vec(),
        )?;
        base.insert("tokens".into(), Input::I32(tb));
        let out = exe.execute(&base)?;
        nll += out.scalar("nll_sum")? as f64;
        ntok += out.scalar("ntok")? as f64;
    }
    if ntok == 0.0 {
        bail!("no tokens evaluated");
    }
    Ok((nll / ntok).exp())
}

/// Zero-pad the leading (batch) axis to `b` rows.
pub fn pad_rows(t: &TensorF, b: usize) -> Result<TensorF> {
    t.pad_axis(0, b).map_err(Into::into)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_rows_zero_fills() {
        let t = TensorF::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let p = pad_rows(&t, 4).unwrap();
        assert_eq!(p.shape(), &[4, 3]);
        assert_eq!(&p.data()[6..], &[0.0; 6]);
    }
}
