//! Evaluators: top-1 accuracy (CNN benchmarks) and perplexity (LSTM LM).
//!
//! Accuracy is backend-agnostic: it chunks the dataset through a
//! [`ForwardPass`], which is either the AOT artifact path
//! ([`accuracy`] — pads each chunk to the artifact batch) or the native
//! integer backend ([`accuracy_native`] — any batch, no artifacts, real
//! quantized arithmetic). Perplexity drives the LM artifact; window
//! counts that are not a multiple of the artifact batch are zero-padded
//! and the padding's contribution is masked back out of `nll`/`ntok`
//! (LM rows are independent — fresh `h0`/`c0` per row — so the pad
//! rows contribute exactly the all-zero batch's per-row share, measured
//! once and subtracted).

use anyhow::{bail, Result};

use crate::calib::slice_rows;
use crate::model::ModelSpec;
use crate::pipeline::PreparedModel;
use crate::runtime::native::NativeExecutable;
use crate::runtime::{Engine, Input, Inputs};
use crate::tensor::{TensorF, TensorI};

/// One evaluation backend: a forward pass at some preferred chunk size.
pub trait ForwardPass {
    /// Rows the evaluator should feed per call.
    fn batch(&self) -> usize;

    /// Logits `(m, classes)` for `x` `(rows, ...)` with `m >= rows`;
    /// rows beyond the input are padding and ignored by callers.
    fn forward(&mut self, x: &TensorF) -> Result<TensorF>;
}

/// The artifact path: pads every chunk to the fwd artifact's batch.
struct ArtifactForward {
    exe: std::rc::Rc<crate::runtime::Executable>,
    base: Inputs,
}

impl ForwardPass for ArtifactForward {
    fn batch(&self) -> usize {
        self.exe.batch()
    }

    fn forward(&mut self, x: &TensorF) -> Result<TensorF> {
        let b = self.exe.batch();
        let xb = if x.shape()[0] == b {
            x.clone()
        } else {
            pad_rows(x, b)?
        };
        self.base.insert("x".into(), Input::F32(xb));
        let mut out = self.exe.execute(&self.base)?;
        out.take("logits")
    }
}

/// The native integer path: any chunk size, no padding needed.
struct NativeForward<'a> {
    exe: &'a NativeExecutable,
    batch: usize,
}

impl ForwardPass for NativeForward<'_> {
    fn batch(&self) -> usize {
        self.batch
    }

    fn forward(&mut self, x: &TensorF) -> Result<TensorF> {
        self.exe.infer(x)
    }
}

/// Top-1 accuracy over `(images, labels)` through any backend.
pub fn accuracy_with(
    fp: &mut dyn ForwardPass,
    images: &TensorF,
    labels: &[i32],
) -> Result<f64> {
    let n = images.shape()[0];
    if n != labels.len() {
        bail!("images ({n}) vs labels ({}) mismatch", labels.len());
    }
    let b = fp.batch().max(1);
    let mut correct = 0usize;
    let mut seen = 0usize;
    let mut i = 0usize;
    while i < n {
        let take = (n - i).min(b);
        let xb = slice_rows(images, i, take)?;
        let logits = fp.forward(&xb)?;
        for (row, pred) in logits.argmax_rows().into_iter().enumerate().take(take) {
            if pred as i32 == labels[i + row] {
                correct += 1;
            }
        }
        seen += take;
        i += take;
    }
    Ok(correct as f64 / seen.max(1) as f64)
}

/// Top-1 accuracy through the AOT fwd artifact (largest batch <=
/// requested; partial chunks are zero-padded, padded rows excluded).
pub fn accuracy(
    engine: &Engine,
    spec: &ModelSpec,
    prep: &PreparedModel,
    images: &TensorF,
    labels: &[i32],
    batch: usize,
) -> Result<f64> {
    let art = spec.fwd_for_batch(batch)?;
    let exe = engine.load(art)?;
    let mut base: Inputs = Default::default();
    prep.insert_inputs(&mut base);
    accuracy_with(&mut ArtifactForward { exe, base }, images, labels)
}

/// Top-1 accuracy through the native integer backend — real quantized
/// compute, no artifacts or PJRT involved.
pub fn accuracy_native(
    exe: &NativeExecutable,
    images: &TensorF,
    labels: &[i32],
    batch: usize,
) -> Result<f64> {
    accuracy_with(
        &mut NativeForward { exe, batch },
        images,
        labels,
    )
}

/// Top-1 *agreement* between two backends over the same inputs: the
/// fraction of rows where both argmax to the same class. Unlike
/// accuracy this needs no labels, so it measures pure quantization
/// fidelity against a float reference — the logit-agreement signal
/// `ocs autotune` scores candidates with (a candidate can keep accuracy
/// by luck while disagreeing everywhere; agreement catches that).
pub fn agreement_with(
    a: &mut dyn ForwardPass,
    b: &mut dyn ForwardPass,
    images: &TensorF,
) -> Result<f64> {
    let n = images.shape()[0];
    if n == 0 {
        bail!("no rows to compare");
    }
    let chunk = a.batch().min(b.batch()).max(1);
    let mut same = 0usize;
    let mut i = 0usize;
    while i < n {
        let take = (n - i).min(chunk);
        let xb = slice_rows(images, i, take)?;
        let pa = a.forward(&xb)?.argmax_rows();
        let pb = b.forward(&xb)?.argmax_rows();
        same += pa.iter().zip(pb.iter()).take(take).filter(|(x, y)| x == y).count();
        i += take;
    }
    Ok(same as f64 / n as f64)
}

/// Top-1 agreement between two native executables (candidate vs float
/// reference), chunked at `batch`.
pub fn agreement_native(
    cand: &NativeExecutable,
    reference: &NativeExecutable,
    images: &TensorF,
    batch: usize,
) -> Result<f64> {
    agreement_with(
        &mut NativeForward { exe: cand, batch },
        &mut NativeForward { exe: reference, batch },
        images,
    )
}

/// Rows `[start, start + rows)` of `windows`, zero-padded to `b` rows.
pub(crate) fn pad_chunk(windows: &TensorI, start: usize, rows: usize, b: usize) -> Result<TensorI> {
    let row: usize = windows.shape()[1..].iter().product();
    if start + rows > windows.shape()[0] {
        bail!("pad_chunk: {start}+{rows} > {}", windows.shape()[0]);
    }
    if rows > b {
        bail!("pad_chunk: {rows} rows exceed batch {b}");
    }
    let mut data = windows.data()[start * row..(start + rows) * row].to_vec();
    data.resize(b * row, 0);
    Ok(TensorI::from_vec(&[b, windows.shape()[1]], data)?)
}

/// Perplexity of the LSTM LM over token windows `(N, seq_len + 1)`.
/// Any `N >= 1`: full chunks run as-is; a final partial chunk is
/// zero-padded to the artifact batch and the padding's `nll`/`ntok`
/// share (the all-zero batch's, scaled by the pad fraction) is
/// subtracted — the LM treats batch rows independently, so this masks
/// the pad rows exactly, mirroring `accuracy`'s partial-chunk handling.
pub fn perplexity(
    engine: &Engine,
    spec: &ModelSpec,
    prep: &PreparedModel,
    windows: &TensorI,
) -> Result<f64> {
    let n = windows.shape()[0];
    if n == 0 {
        bail!("no token windows to evaluate");
    }
    let art = spec.fwd_for_batch(1)?;
    let b = art.batch;
    let exe = engine.load(art)?;
    let mut base: Inputs = Default::default();
    prep.insert_inputs(&mut base);

    let mut nll = 0.0f64;
    let mut ntok = 0.0f64;
    let full = n / b;
    for chunk in 0..full {
        let tb = pad_chunk(windows, chunk * b, b, b)?;
        base.insert("tokens".into(), Input::I32(tb));
        let out = exe.execute(&base)?;
        nll += out.scalar("nll_sum")? as f64;
        ntok += out.scalar("ntok")? as f64;
    }
    let rem = n % b;
    if rem > 0 {
        let tb = pad_chunk(windows, full * b, rem, b)?;
        base.insert("tokens".into(), Input::I32(tb));
        let out = exe.execute(&base)?;
        let (nll_p, ntok_p) = (out.scalar("nll_sum")? as f64, out.scalar("ntok")? as f64);
        // the pad rows are all-zero windows; measure a full zero batch
        // once and subtract the pad fraction of it
        base.insert("tokens".into(), Input::I32(TensorI::zeros(&[b, windows.shape()[1]])));
        let zout = exe.execute(&base)?;
        let pad_frac = (b - rem) as f64 / b as f64;
        nll += nll_p - zout.scalar("nll_sum")? as f64 * pad_frac;
        ntok += ntok_p - zout.scalar("ntok")? as f64 * pad_frac;
    }
    if ntok <= 0.0 {
        bail!("no tokens evaluated");
    }
    Ok((nll / ntok).exp())
}

/// Zero-pad the leading (batch) axis to `b` rows.
pub fn pad_rows(t: &TensorF, b: usize) -> Result<TensorF> {
    t.pad_axis(0, b).map_err(Into::into)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_rows_zero_fills() {
        let t = TensorF::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let p = pad_rows(&t, 4).unwrap();
        assert_eq!(p.shape(), &[4, 3]);
        assert_eq!(&p.data()[6..], &[0.0; 6]);
    }

    #[test]
    fn pad_chunk_fills_and_bounds() {
        let w = TensorI::from_vec(&[3, 2], vec![1, 2, 3, 4, 5, 6]).unwrap();
        let c = pad_chunk(&w, 1, 2, 4).unwrap();
        assert_eq!(c.shape(), &[4, 2]);
        assert_eq!(c.data(), &[3, 4, 5, 6, 0, 0, 0, 0]);
        // exact chunk: no padding
        let e = pad_chunk(&w, 0, 3, 3).unwrap();
        assert_eq!(e.data(), w.data());
        assert!(pad_chunk(&w, 2, 2, 4).is_err(), "out of range");
        assert!(pad_chunk(&w, 0, 3, 2).is_err(), "rows > batch");
    }

    #[test]
    fn agreement_counts_matching_argmax() {
        // backend whose prediction is (first feature + shift) mod 3
        struct Shift {
            shift: usize,
        }
        impl ForwardPass for Shift {
            fn batch(&self) -> usize {
                3
            }
            fn forward(&mut self, x: &TensorF) -> Result<TensorF> {
                let rows = x.shape()[0];
                let stride = x.len() / rows;
                let mut data = Vec::new();
                for r in 0..rows {
                    let cls = (x.data()[r * stride] as usize + self.shift) % 3;
                    for c in 0..3 {
                        data.push(if c == cls { 1.0 } else { 0.0 });
                    }
                }
                Ok(TensorF::from_vec(&[rows, 3], data)?)
            }
        }
        let images =
            TensorF::from_vec(&[4, 2], vec![0., 0., 1., 0., 2., 0., 0., 0.]).unwrap();
        let same = agreement_with(&mut Shift { shift: 0 }, &mut Shift { shift: 0 }, &images)
            .unwrap();
        assert_eq!(same, 1.0, "identical backends agree everywhere");
        let none = agreement_with(&mut Shift { shift: 0 }, &mut Shift { shift: 1 }, &images)
            .unwrap();
        assert_eq!(none, 0.0, "shifted predictions never agree");
        assert!(agreement_with(
            &mut Shift { shift: 0 },
            &mut Shift { shift: 0 },
            &TensorF::zeros(&[0, 2])
        )
        .is_err());
    }

    #[test]
    fn accuracy_with_masks_partial_chunks() {
        // a fake backend that doubles as a padding probe: it must never
        // see more than `batch` rows, and the evaluator must ignore
        // every row beyond the real ones
        struct Fake {
            calls: usize,
        }
        impl ForwardPass for Fake {
            fn batch(&self) -> usize {
                4
            }
            fn forward(&mut self, x: &TensorF) -> Result<TensorF> {
                self.calls += 1;
                let rows = x.shape()[0];
                assert!(rows <= 4);
                // logits: class = round(first feature); one extra
                // padding row of garbage to prove callers ignore it
                let mut data = Vec::new();
                for r in 0..rows {
                    let cls = x.data()[r * x.len() / rows] as usize;
                    for c in 0..3 {
                        data.push(if c == cls { 1.0 } else { 0.0 });
                    }
                }
                data.extend_from_slice(&[9.0, 0.0, 0.0]);
                Ok(TensorF::from_vec(&[rows + 1, 3], data)?)
            }
        }
        // 6 samples: batches of 4 + partial 2
        let images = TensorF::from_vec(
            &[6, 2],
            vec![0., 0., 1., 0., 2., 0., 0., 0., 1., 0., 2., 0.],
        )
        .unwrap();
        let labels = vec![0, 1, 2, 0, 1, 0]; // last label wrong on purpose
        let mut fp = Fake { calls: 0 };
        let acc = accuracy_with(&mut fp, &images, &labels).unwrap();
        assert_eq!(fp.calls, 2, "4-row chunk + 2-row partial");
        assert!((acc - 5.0 / 6.0).abs() < 1e-9, "{acc}");
    }
}
