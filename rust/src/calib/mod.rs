//! Activation calibration (paper §3.4/§5: "we use a small number of
//! training images to sample the activations in each layer", the
//! TensorRT-style profiling pass).
//!
//! Runs the float `probe` artifact over a calibration set and collects,
//! per quantizable layer: a magnitude [`Histogram`] (for the clip
//! optimizers), per-channel max values, and per-channel *outlier counts*
//! — the number of values above the layer's 99th percentile, the paper's
//! §5.3 criterion for choosing which activation channels OCS splits.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::kernels::stats as kernels;
use crate::model::store::WeightStore;
use crate::model::ModelSpec;
use crate::runtime::{Engine, Input, Inputs, Outputs};
use crate::stats::{Histogram, DEFAULT_BINS};
use crate::tensor::TensorF;

/// The percentile above which a value counts as an outlier (§5.3: "we
/// used values greater than the 99'th percentile").
pub const OUTLIER_PERCENTILE: f64 = 0.99;

/// Per-layer calibration statistics.
#[derive(Debug, Clone)]
pub struct LayerCalib {
    pub hist: Histogram,
    /// max |x| per input channel.
    pub channel_max: Vec<f32>,
    /// values above the layer's 99th percentile, per channel (§5.3).
    pub outlier_counts: Vec<u64>,
}

#[derive(Debug, Clone, Default)]
pub struct Calibration {
    pub layers: BTreeMap<String, LayerCalib>,
}

impl Calibration {
    pub fn layer(&self, name: &str) -> Result<&LayerCalib> {
        self.layers
            .get(name)
            .with_context(|| format!("no calibration for layer '{name}'"))
    }

    /// Top-k channels by outlier count (the activation-OCS selection).
    pub fn split_channels(&self, layer: &str, k: usize) -> Result<Vec<usize>> {
        Ok(top_k_channels(&self.layer(layer)?.outlier_counts, k))
    }
}

/// Indices of the k largest values (stable order by count desc).
pub fn top_k_channels(counts: &[u64], k: usize) -> Vec<usize> {
    let mut order: Vec<usize> = (0..counts.len()).collect();
    order.sort_by(|&a, &b| counts[b].cmp(&counts[a]).then(a.cmp(&b)));
    order.truncate(k);
    order
}

/// Per-trailing-channel max |x|. Unlike the fused calibration kernel
/// ([`crate::kernels::stats`]), this generic tensor reduction does not
/// filter non-finite values — prefer the kernel for calibration data.
pub fn channel_max(act: &TensorF) -> Vec<f32> {
    let axis = act.rank() - 1;
    act.max_abs_per_axis(axis).expect("rank >= 1")
}

/// Per-trailing-channel count of finite |x| > thr. Row-chunked: the
/// channel index is the position inside each `chunks_exact(c)` row —
/// the old walk computed `i % c` for every element. Non-finite values
/// are excluded from *all* calibration statistics by design (an Inf
/// would otherwise poison the histogram range the threshold comes
/// from); a saturating channel still ranks high through its finite
/// near-saturation magnitudes.
pub fn channel_outlier_counts(act: &TensorF, thr: f32) -> Vec<u64> {
    let c = *act.shape().last().expect("rank >= 1");
    crate::kernels::stats::outlier_counts(act.data(), c, thr)
}

/// Run the float probe on one batch; returns `layer name -> activation`.
pub fn probe_batch(
    engine: &Engine,
    spec: &ModelSpec,
    ws: &WeightStore,
    x: &TensorF,
) -> Result<BTreeMap<String, TensorF>> {
    let batch = x.shape()[0];
    let art = spec.probe_for_batch(batch)?;
    let exe = engine.load(art)?;
    let mut inputs: Inputs = Default::default();
    for io in &art.inputs {
        if io.name == "x" {
            inputs.insert("x".into(), Input::F32(x.clone()));
        } else {
            inputs.insert(io.name.clone(), Input::F32(ws.bundle.f32(&io.name)?.clone()));
        }
    }
    let out = exe.execute(&inputs)?;
    Ok(acts_of(out))
}

fn acts_of(out: Outputs) -> BTreeMap<String, TensorF> {
    out.into_map()
        .into_iter()
        .filter_map(|(k, v)| k.strip_prefix("act.").map(|n| (n.to_string(), v)))
        .collect()
}

/// Full calibration pass: probe `images` in batches, build per-layer
/// statistics. `images` count must cover at least one probe batch.
pub fn calibrate(
    engine: &Engine,
    spec: &ModelSpec,
    ws: &WeightStore,
    images: &TensorF,
    batch: usize,
) -> Result<Calibration> {
    if spec.is_lm() {
        bail!("activation calibration targets CNN models (the paper keeps LSTM activations float)");
    }
    let n = images.shape()[0];
    if n < batch {
        bail!("calibration set ({n}) smaller than probe batch ({batch})");
    }
    // pass 1: gather activations per layer (calibration sets are small —
    // a few hundred images — so holding them is cheap and lets us do the
    // exact two-phase percentile/count computation)
    let mut acts: BTreeMap<String, Vec<TensorF>> = BTreeMap::new();
    let mut i = 0;
    while i + batch <= n {
        let xb = slice_rows(images, i, batch)?;
        for (layer, a) in probe_batch(engine, spec, ws, &xb)? {
            acts.entry(layer).or_default().push(a);
        }
        i += batch;
    }
    Ok(statistics(acts))
}

/// Fold gathered per-layer activation batches into the calibration
/// statistics — one fused sweep per batch (histogram + channel maxima
/// together), batches in parallel on the kernel pool, partials folded
/// in batch order so any thread count is bit-identical to serial; then
/// the outlier-count sweep at the layer-wide percentile threshold (see
/// `kernels::stats::layer_stats`). Shared by the PJRT probe above and
/// the native probe ([`crate::runtime::native::native_calibrate`]).
pub fn statistics(acts: BTreeMap<String, Vec<TensorF>>) -> Calibration {
    let mut layers = BTreeMap::new();
    for (layer, batches) in acts {
        let s = kernels::layer_stats(&batches, DEFAULT_BINS, OUTLIER_PERCENTILE, 0);
        layers.insert(
            layer,
            LayerCalib {
                hist: s.hist,
                channel_max: s.channel_max,
                outlier_counts: s.outlier_counts,
            },
        );
    }
    Calibration { layers }
}

/// Copy rows [start, start+count) of a batch-major tensor.
pub fn slice_rows(t: &TensorF, start: usize, count: usize) -> Result<TensorF> {
    let shape = t.shape();
    let row: usize = shape[1..].iter().product();
    if start + count > shape[0] {
        bail!("slice_rows: {start}+{count} > {}", shape[0]);
    }
    let mut new_shape = shape.to_vec();
    new_shape[0] = count;
    Ok(TensorF::from_vec(
        &new_shape,
        t.data()[start * row..(start + count) * row].to_vec(),
    )?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_orders_by_count() {
        assert_eq!(top_k_channels(&[5, 1, 9, 9, 0], 3), vec![2, 3, 0]);
        assert_eq!(top_k_channels(&[1, 2], 5), vec![1, 0]);
        assert!(top_k_channels(&[], 3).is_empty());
    }

    #[test]
    fn channel_stats() {
        // (2, 3): channels are the trailing axis
        let a = TensorF::from_vec(&[2, 3], vec![1.0, -5.0, 0.1, 2.0, 0.5, -0.2]).unwrap();
        assert_eq!(channel_max(&a), vec![2.0, 5.0, 0.2]);
        assert_eq!(channel_outlier_counts(&a, 0.9), vec![2, 1, 0]);
    }

    #[test]
    fn slice_rows_bounds() {
        let t = TensorF::from_vec(&[4, 2], (0..8).map(|v| v as f32).collect()).unwrap();
        let s = slice_rows(&t, 1, 2).unwrap();
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.data(), &[2.0, 3.0, 4.0, 5.0]);
        assert!(slice_rows(&t, 3, 2).is_err());
    }
}
