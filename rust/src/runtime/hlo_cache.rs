//! Process-wide, read-once cache for AOT artifact HLO text.
//!
//! The sharded server ([`crate::serve`]) builds one [`super::Engine`] per
//! worker thread (PJRT handles are `!Send`), and every engine needs the
//! same artifact files. Without sharing, N workers would each re-read and
//! re-validate every artifact at startup. This cache makes the read and
//! the structural validation happen exactly once per process; workers
//! share the text via `Arc<str>`, and the stub backend parses directly
//! from it. One caveat: the *real* PJRT text parser (`pjrt` feature)
//! only accepts a file path, so that parser re-reads the file it
//! compiles — the read-once guarantee covers this cache's own consumers.
//!
//! Compiled executables can NOT be shared at all — they wrap
//! thread-bound PJRT handles — so per-engine compilation remains.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{bail, Context, Result};

/// Shared artifact-text cache with hit/miss accounting.
#[derive(Debug, Default)]
pub struct HloTextCache {
    map: Mutex<HashMap<PathBuf, Arc<str>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl HloTextCache {
    /// The process-wide instance used by [`super::Engine::load`].
    pub fn global() -> &'static HloTextCache {
        static GLOBAL: OnceLock<HloTextCache> = OnceLock::new();
        GLOBAL.get_or_init(HloTextCache::default)
    }

    /// Fetch the HLO text for `path`, reading and validating it on the
    /// first request only.
    pub fn get(&self, path: &Path) -> Result<Arc<str>> {
        // poison-tolerant: a panicked worker must not wedge artifact reads
        let mut map = self.map.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(text) = map.get(path) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(text.clone());
        }
        // The read happens under the lock: N workers racing on a cold
        // cache must still produce exactly one disk read per artifact.
        // Startup is the only contended window, and reads are small.
        let raw = std::fs::read_to_string(path)
            .with_context(|| format!("read HLO artifact {}", path.display()))?;
        if !raw.contains("HloModule") {
            bail!(
                "artifact {} does not look like HLO text (no 'HloModule' header)",
                path.display()
            );
        }
        let text: Arc<str> = Arc::from(raw);
        map.insert(path.to_path_buf(), text.clone());
        self.misses.fetch_add(1, Ordering::Relaxed);
        Ok(text)
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_artifact(name: &str, body: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("ocs_hlo_cache_{}_{name}", std::process::id()));
        std::fs::write(&p, body).unwrap();
        p
    }

    #[test]
    fn second_read_hits_and_shares() {
        let cache = HloTextCache::default();
        let p = temp_artifact("share.hlo", "HloModule m\nENTRY e {}\n");
        let a = cache.get(&p).unwrap();
        let b = cache.get(&p).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "both readers must share one copy");
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.len(), 1);
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    fn rejects_non_hlo_and_missing_files() {
        let cache = HloTextCache::default();
        let p = temp_artifact("garbage.hlo", "not an artifact");
        assert!(cache.get(&p).is_err());
        let _ = std::fs::remove_file(&p);
        assert!(cache.get(Path::new("/nonexistent/ocs.hlo")).is_err());
        assert!(cache.is_empty());
    }

    #[test]
    fn concurrent_readers_one_disk_read() {
        let cache = Arc::new(HloTextCache::default());
        let p = temp_artifact("conc.hlo", "HloModule m\n");
        let mut handles = Vec::new();
        for _ in 0..8 {
            let cache = cache.clone();
            let p = p.clone();
            handles.push(std::thread::spawn(move || cache.get(&p).unwrap()));
        }
        for h in handles {
            let text = h.join().unwrap();
            assert!(text.contains("HloModule"));
        }
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.misses(), 1, "exactly one disk read");
        assert_eq!(cache.hits(), 7);
        let _ = std::fs::remove_file(&p);
    }
}
