//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO **text** is the interchange format — jax ≥ 0.5 serialized protos
//! use 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The engine caches compiled executables by artifact path, validates
//! every input against the artifact's recorded positional signature
//! (name/dtype/shape), and unpacks the returned tuple into named
//! tensors.
//!
//! PJRT handles are not `Send`; the serving layer ([`crate::serve`])
//! owns one engine per worker thread instead of sharing one. Artifact
//! *text* is shared across those engines through the process-wide
//! [`HloTextCache`]: N workers validate and cache each artifact exactly
//! once. (On a `pjrt` build the PJRT text parser only accepts a file
//! path, so that parser performs its own read per engine; the stub
//! build parses straight from the shared cache.)
//!
//! Built without the `pjrt` feature (the default — CI, and any machine
//! without the vendored xla crate), the identically-shaped stub backend
//! in [`stub`] takes the place of the `xla` crate: literal marshalling
//! works, compilation/execution return a descriptive error, and the
//! serving stack uses its synthetic backend instead.
//!
//! [`native`] is the artifact-free sibling: the same models executed on
//! the CPU integer datapath (packed i8 GEMM with a per-channel dequant
//! epilogue), with real quantized arithmetic on *every* build — the
//! stub build included.

pub mod hlo_cache;
pub mod native;
#[cfg(not(feature = "pjrt"))]
pub(crate) mod stub;

pub use hlo_cache::HloTextCache;
pub use native::{NativeEngine, NativeExecutable, Scratch};

#[cfg(not(feature = "pjrt"))]
use self::stub as xla;

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::model::{ArtifactSpec, DType, IoSpec};
use crate::tensor::{TensorF, TensorI};

/// A named input value for an artifact call.
#[derive(Debug, Clone)]
pub enum Input {
    F32(TensorF),
    I32(TensorI),
}

impl Input {
    pub fn scalar_f32(v: f32) -> Input {
        Input::F32(TensorF::scalar(v))
    }

    fn shape(&self) -> &[usize] {
        match self {
            Input::F32(t) => t.shape(),
            Input::I32(t) => t.shape(),
        }
    }

    fn dtype(&self) -> DType {
        match self {
            Input::F32(_) => DType::F32,
            Input::I32(_) => DType::I32,
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Input::F32(t) => {
                if t.rank() == 0 {
                    return Ok(xla::Literal::scalar(t.data()[0]));
                }
                xla::Literal::vec1(t.data()).reshape(&dims)?
            }
            Input::I32(t) => {
                if t.rank() == 0 {
                    return Ok(xla::Literal::scalar(t.data()[0]));
                }
                xla::Literal::vec1(t.data()).reshape(&dims)?
            }
        };
        Ok(lit)
    }
}

/// Name → value map consumed by [`Executable::execute`].
pub type Inputs = BTreeMap<String, Input>;

/// Named outputs of one execution.
#[derive(Debug)]
pub struct Outputs {
    map: BTreeMap<String, TensorF>,
}

impl Outputs {
    pub fn get(&self, name: &str) -> Result<&TensorF> {
        self.map
            .get(name)
            .with_context(|| format!("no output '{name}'"))
    }

    pub fn take(&mut self, name: &str) -> Result<TensorF> {
        self.map
            .remove(name)
            .with_context(|| format!("no output '{name}'"))
    }

    pub fn scalar(&self, name: &str) -> Result<f32> {
        let t = self.get(name)?;
        if t.len() != 1 {
            bail!("output '{name}' is not scalar (shape {:?})", t.shape());
        }
        Ok(t.data()[0])
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    pub fn into_map(self) -> BTreeMap<String, TensorF> {
        self.map
    }
}

/// One compiled artifact, ready to execute.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with named inputs; validates the full positional
    /// signature before touching PJRT.
    pub fn execute(&self, inputs: &Inputs) -> Result<Outputs> {
        let mut literals = Vec::with_capacity(self.spec.inputs.len());
        for io in &self.spec.inputs {
            let input = inputs.get(&io.name).with_context(|| {
                format!("artifact {}: missing input '{}'", self.spec.key, io.name)
            })?;
            validate(io, input)
                .with_context(|| format!("artifact {}", self.spec.key))?;
            literals.push(input.to_literal()?);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("execute {}", self.spec.key))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetch result tuple")?;
        // artifacts are lowered with return_tuple=True
        let elems = tuple.to_tuple().context("decompose result tuple")?;
        if elems.len() != self.spec.outputs.len() {
            bail!(
                "artifact {}: {} outputs returned, {} in signature",
                self.spec.key,
                elems.len(),
                self.spec.outputs.len()
            );
        }
        let mut map = BTreeMap::new();
        for (io, lit) in self.spec.outputs.iter().zip(elems) {
            let data: Vec<f32> = lit
                .to_vec()
                .with_context(|| format!("output '{}' to f32", io.name))?;
            map.insert(io.name.clone(), TensorF::from_vec(&io.shape, data)?);
        }
        Ok(Outputs { map })
    }

    pub fn batch(&self) -> usize {
        self.spec.batch
    }
}

fn validate(io: &IoSpec, input: &Input) -> Result<()> {
    if input.dtype() != io.dtype {
        bail!(
            "input '{}': dtype {:?} != expected {:?}",
            io.name,
            input.dtype(),
            io.dtype
        );
    }
    if input.shape() != io.shape.as_slice() {
        bail!(
            "input '{}': shape {:?} != expected {:?}",
            io.name,
            input.shape(),
            io.shape
        );
    }
    Ok(())
}

/// PJRT client + executable cache. `!Send` by construction — one engine
/// per thread.
pub struct Engine {
    client: xla::PjRtClient,
    cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        crate::debugln!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Engine {
            client,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Load + compile an artifact. Compiled executables are cached per
    /// engine (PJRT handles are thread-bound); the HLO text itself comes
    /// from the process-wide [`HloTextCache`], shared by all engines.
    pub fn load(&self, spec: &ArtifactSpec) -> Result<Rc<Executable>> {
        let key = spec.file.display().to_string();
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let text = HloTextCache::global().get(&spec.file)?;
        crate::debugln!(
            "artifact {}: {} bytes of HLO text (shared cache: {} entries)",
            spec.key,
            text.len(),
            HloTextCache::global().len()
        );
        let t0 = std::time::Instant::now();
        let path = spec.file.to_str().context("artifact path not utf-8")?;
        // The PJRT text parser only takes a file path, so with the real
        // backend the shared text serves as read-once validation; the
        // stub parses from the cached text directly.
        #[cfg(feature = "pjrt")]
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse HLO text {path}"))?;
        #[cfg(not(feature = "pjrt"))]
        let proto = xla::HloModuleProto::from_text(&text)
            .with_context(|| format!("parse HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA compile {}", spec.key))?;
        crate::debugln!(
            "compiled {} in {:.2}s",
            spec.key,
            t0.elapsed().as_secs_f64()
        );
        let executable = Rc::new(Executable {
            spec: spec.clone(),
            exe,
        });
        self.cache.borrow_mut().insert(key, executable.clone());
        Ok(executable)
    }

    pub fn cached_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn input_shape_dtype_validation() {
        let io = IoSpec {
            name: "x".into(),
            dtype: DType::F32,
            shape: vec![2, 2],
        };
        assert!(validate(&io, &Input::F32(TensorF::zeros(&[2, 2]))).is_ok());
        assert!(validate(&io, &Input::F32(TensorF::zeros(&[2, 3]))).is_err());
        assert!(validate(&io, &Input::I32(TensorI::zeros(&[2, 2]))).is_err());
    }

    #[test]
    fn scalar_literal_roundtrip() {
        let lit = Input::scalar_f32(3.5).to_literal().unwrap();
        assert_eq!(lit.element_count(), 1);
        let v: Vec<f32> = lit.to_vec().unwrap();
        assert_eq!(v, vec![3.5]);
    }

    #[test]
    fn tensor_literal_roundtrip() {
        let t = TensorF::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let lit = Input::F32(t.clone()).to_literal().unwrap();
        assert_eq!(lit.element_count(), 6);
        let back: Vec<f32> = lit.to_vec().unwrap();
        assert_eq!(back, t.data());
    }
}
