//! Pure-Rust stand-in for the vendored `xla` crate, compiled when the
//! `pjrt` feature is off (the default, and what CI builds).
//!
//! It mirrors exactly the API surface [`crate::runtime`] consumes.
//! Literal packing/unpacking is fully functional — the input-marshalling
//! code and its tests run unchanged — while anything that would need the
//! PJRT C++ runtime (`HloModuleProto::from_text`, `PjRtClient::compile`)
//! returns a descriptive error. Artifact-gated tests skip before hitting
//! those paths, and the serving stack falls back to
//! [`crate::serve::backend::SimFactory`].

use anyhow::{bail, Result};

const NO_PJRT: &str =
    "ocs was built without the `pjrt` feature; PJRT execution is unavailable \
     (rebuild with `cargo build --features pjrt` and the vendored xla crate)";

/// Element payload of a [`Literal`].
#[derive(Debug, Clone)]
pub enum Elems {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Elems {
    fn len(&self) -> usize {
        match self {
            Elems::F32(v) => v.len(),
            Elems::I32(v) => v.len(),
        }
    }
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> Elems;
    fn unwrap(elems: &Elems) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: Vec<Self>) -> Elems {
        Elems::F32(data)
    }
    fn unwrap(elems: &Elems) -> Option<Vec<Self>> {
        match elems {
            Elems::F32(v) => Some(v.clone()),
            Elems::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<Self>) -> Elems {
        Elems::I32(data)
    }
    fn unwrap(elems: &Elems) -> Option<Vec<Self>> {
        match elems {
            Elems::I32(v) => Some(v.clone()),
            Elems::F32(_) => None,
        }
    }
}

/// Host-side tensor value (the xla crate's literal type).
#[derive(Debug, Clone)]
pub struct Literal {
    elems: Elems,
    dims: Vec<i64>,
}

impl Literal {
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal {
            elems: T::wrap(vec![v]),
            dims: Vec::new(),
        }
    }

    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        let dims = vec![data.len() as i64];
        Literal {
            elems: T::wrap(data.to_vec()),
            dims,
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want < 0 || want as usize != self.elems.len() {
            bail!(
                "reshape to {dims:?} ({want} elems) from {} elems",
                self.elems.len()
            );
        }
        Ok(Literal {
            elems: self.elems.clone(),
            dims: dims.to_vec(),
        })
    }

    pub fn element_count(&self) -> usize {
        self.elems.len()
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match T::unwrap(&self.elems) {
            Some(v) => Ok(v),
            None => bail!("literal element type mismatch"),
        }
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        bail!(NO_PJRT)
    }
}

/// Parsed HLO module (never constructible without PJRT).
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text(_text: &str) -> Result<HloModuleProto> {
        bail!(NO_PJRT)
    }
}

/// Computation wrapper accepted by [`PjRtClient::compile`].
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

/// Compiled executable (never constructible without PJRT).
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        bail!(NO_PJRT)
    }
}

/// Client handle. Construction succeeds so `Engine::cpu()` keeps working
/// everywhere; only compilation/execution require the real runtime.
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient {})
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        bail!(NO_PJRT)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_checks_count() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn literal_type_mismatch_errors() {
        let l = Literal::vec1(&[1i32, 2]);
        assert!(l.to_vec::<f32>().is_err());
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2]);
    }

    #[test]
    fn pjrt_paths_error_without_feature() {
        assert!(HloModuleProto::from_text("HloModule m").is_err());
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.device_count(), 0);
        assert!(client.compile(&XlaComputation {}).is_err());
    }
}
