//! Native integer inference backend — real quantized compute with no
//! PJRT and no AOT artifacts.
//!
//! The PJRT path executes the models as pre-lowered HLO; on the default
//! (stub) build that path cannot run at all, and even with PJRT the
//! "quantized" arithmetic is simulated in f32. This module executes the
//! same models directly on the CPU integer datapath:
//!
//! * weights come from [`crate::quant::pack`] as true `i8` payloads
//!   with per-output-channel dequant scales (OCS duplicates already
//!   materialized into the padded slots);
//! * activations run `channel_dup` (`x_exp[j] = x[idx[j]] * dscale[j] +
//!   dbias[j]`) and Eq. 1 fake-quant between layers with exactly the
//!   artifact semantics (`aqmax <= 0` bypasses, round-half-up,
//!   clamp to ±aqmax) — but the quantized values stay *integers* and
//!   feed the packed i8 GEMM ([`crate::kernels::gemm`]) instead of
//!   being dequantized back to f32 first;
//! * FC layers are direct GEMMs; conv layers lower to GEMM via im2col
//!   (SAME padding, NHWC × HWIO, matching the XLA lowering);
//! * layers the integer datapath cannot carry (float activations,
//!   >8-bit grids, recipe-skipped or unquantized layers) run on the f32
//!   reference GEMM — the two body kinds mix freely per layer.
//!
//! Topology comes from [`NativeGraph`]: the three CNN benchmark models
//! are mirrored from `python/compile/model.py` node for node, and any
//! all-FC spec (tests, the [`synthetic_mlp`] serving model) gets a
//! generic flatten → fc/relu chain. The LSTM LM stays artifact-only.
//!
//! [`NativeEngine`] mirrors the PJRT [`super::Engine`] shape — build
//! once, `load` per prepared model with a fingerprint-keyed executable
//! cache — `ocs eval --backend native` drives it exactly as the PJRT
//! eval drives `Engine` (the serve workers hold one
//! [`NativeExecutable`] each and rebuild on hot-swap instead).
//! A float-recipe executable doubles as the calibration probe
//! ([`native_calibrate`]): it records each quantizable layer's input
//! activation, which makes activation-quantizing recipes fully
//! self-sufficient without PJRT.

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::calib::{self, Calibration};
use crate::kernels::gemm;
use crate::model::store::WeightStore;
use crate::model::{LayerKind, LayerSpec, ModelSpec};
use crate::pipeline::{self, PreparedModel, QuantRecipe};
use crate::quant::pack::{pack_prepared, LayerBody, PackedLayer, PackedModel};
use crate::tensor::TensorF;
use crate::util::round_half_up;

/// One node of a native execution graph. Nodes reference earlier nodes
/// by index; the last node's activation is the model output.
#[derive(Debug, Clone)]
enum Node {
    /// The network input batch.
    Input,
    /// Parametric layer (conv / fc) applied to `src`.
    Layer { name: String, src: usize },
    Relu { src: usize },
    /// SAME-padded max-pool (`k`×`k`, stride `s`).
    MaxPool { src: usize, k: usize, s: usize },
    Add { a: usize, b: usize },
    /// Concatenate along the trailing channel axis.
    ConcatC { srcs: Vec<usize> },
    GlobalAvgPool { src: usize },
    Flatten { src: usize },
}

/// The forward topology of one model, mirrored from
/// `python/compile/model.py`.
#[derive(Debug, Clone)]
pub struct NativeGraph {
    nodes: Vec<Node>,
}

impl NativeGraph {
    fn new() -> NativeGraph {
        NativeGraph {
            nodes: vec![Node::Input],
        }
    }

    fn push(&mut self, n: Node) -> usize {
        self.nodes.push(n);
        self.nodes.len() - 1
    }

    fn layer(&mut self, spec: &ModelSpec, name: &str, src: usize) -> Result<usize> {
        spec.layer(name)?; // existence check at build time, not run time
        Ok(self.push(Node::Layer {
            name: name.to_string(),
            src,
        }))
    }

    /// Build the graph for `spec`, or explain why it has none.
    pub fn for_model(spec: &ModelSpec) -> Result<NativeGraph> {
        if spec.is_lm() {
            bail!(
                "native backend: model '{}' is the LSTM LM — recurrent topology runs through \
                 the PJRT artifacts only",
                spec.name
            );
        }
        match spec.name.as_str() {
            "minivgg" => Self::minivgg(spec),
            "miniresnet" => Self::miniresnet(spec),
            "miniincept" => Self::miniincept(spec),
            _ if !spec.layers.is_empty()
                && spec.layers.iter().all(|l| l.kind == LayerKind::Fc) =>
            {
                Self::mlp(spec)
            }
            other => bail!(
                "native backend has no graph for model '{other}' (known: minivgg, miniresnet, \
                 miniincept, and all-FC specs)"
            ),
        }
    }

    /// Plain conv stack (`python/compile/model.py::MiniVGG::forward`).
    fn minivgg(spec: &ModelSpec) -> Result<NativeGraph> {
        let mut g = NativeGraph::new();
        let mut x = g.layer(spec, "c1", 0)?;
        x = g.push(Node::Relu { src: x });
        x = g.layer(spec, "c2", x)?;
        x = g.push(Node::Relu { src: x });
        x = g.push(Node::MaxPool { src: x, k: 2, s: 2 });
        x = g.layer(spec, "c3", x)?;
        x = g.push(Node::Relu { src: x });
        x = g.layer(spec, "c4", x)?;
        x = g.push(Node::Relu { src: x });
        x = g.push(Node::MaxPool { src: x, k: 2, s: 2 });
        x = g.layer(spec, "c5", x)?;
        x = g.push(Node::Relu { src: x });
        x = g.push(Node::MaxPool { src: x, k: 2, s: 2 });
        x = g.push(Node::Flatten { src: x });
        x = g.layer(spec, "f1", x)?;
        x = g.push(Node::Relu { src: x });
        g.layer(spec, "f2", x)?;
        Ok(g)
    }

    /// ResNet-20-like residual stack (`MiniResNet::forward`).
    fn miniresnet(spec: &ModelSpec) -> Result<NativeGraph> {
        const WIDTHS: [usize; 3] = [16, 32, 64];
        const BLOCKS: usize = 2;
        let mut g = NativeGraph::new();
        let mut x = g.layer(spec, "stem", 0)?;
        x = g.push(Node::Relu { src: x });
        let mut cin = 16usize;
        for (si, &w) in WIDTHS.iter().enumerate() {
            for bi in 0..BLOCKS {
                let bname = format!("s{si}b{bi}");
                let mut h = g.layer(spec, &format!("{bname}c1"), x)?;
                h = g.push(Node::Relu { src: h });
                h = g.layer(spec, &format!("{bname}c2"), h)?;
                let sc = if cin != w {
                    g.layer(spec, &format!("{bname}sc"), x)?
                } else {
                    x
                };
                let sum = g.push(Node::Add { a: h, b: sc });
                x = g.push(Node::Relu { src: sum });
                cin = w;
            }
        }
        x = g.push(Node::GlobalAvgPool { src: x });
        g.layer(spec, "fc", x)?;
        Ok(g)
    }

    /// Parallel-branch blocks (`MiniIncept::forward`).
    fn miniincept(spec: &ModelSpec) -> Result<NativeGraph> {
        let mut g = NativeGraph::new();
        let mut x = g.layer(spec, "stem", 0)?;
        x = g.push(Node::Relu { src: x });
        x = g.push(Node::MaxPool { src: x, k: 2, s: 2 });
        for (block, reduce) in [("a", Some("red")), ("b", None)] {
            let mut b1 = g.layer(spec, &format!("{block}_b1"), x)?;
            b1 = g.push(Node::Relu { src: b1 });
            let mut b2 = g.layer(spec, &format!("{block}_b2a"), x)?;
            b2 = g.push(Node::Relu { src: b2 });
            b2 = g.layer(spec, &format!("{block}_b2b"), b2)?;
            b2 = g.push(Node::Relu { src: b2 });
            let pooled = g.push(Node::MaxPool { src: x, k: 3, s: 1 });
            let mut b3 = g.layer(spec, &format!("{block}_b3"), pooled)?;
            b3 = g.push(Node::Relu { src: b3 });
            x = g.push(Node::ConcatC {
                srcs: vec![b1, b2, b3],
            });
            if let Some(red) = reduce {
                x = g.layer(spec, red, x)?;
                x = g.push(Node::Relu { src: x });
            }
        }
        x = g.push(Node::GlobalAvgPool { src: x });
        g.layer(spec, "fc", x)?;
        Ok(g)
    }

    /// Generic all-FC chain: flatten, then fc/relu per layer (no relu
    /// after the last). Carries test specs and [`synthetic_mlp`].
    fn mlp(spec: &ModelSpec) -> Result<NativeGraph> {
        let mut g = NativeGraph::new();
        let mut x = g.push(Node::Flatten { src: 0 });
        let n = spec.layers.len();
        for (i, l) in spec.layers.iter().enumerate() {
            x = g.layer(spec, &l.name, x)?;
            if i + 1 < n {
                x = g.push(Node::Relu { src: x });
            }
        }
        Ok(g)
    }

    /// Names of every parametric layer the graph executes.
    pub fn layer_names(&self) -> Vec<&str> {
        self.nodes
            .iter()
            .filter_map(|n| match n {
                Node::Layer { name, .. } => Some(name.as_str()),
                _ => None,
            })
            .collect()
    }
}

/// A model lowered and ready to execute natively: topology + packed
/// integer/f32 layer payloads.
pub struct NativeExecutable {
    graph: NativeGraph,
    packed: PackedModel,
    /// Kernel-pool width for the GEMMs (0 = default).
    threads: usize,
}

impl NativeExecutable {
    /// Lower `prep` for native execution. Fails when the model has no
    /// native graph or a layer is off its quantization grid.
    pub fn build(spec: &ModelSpec, prep: &PreparedModel) -> Result<NativeExecutable> {
        let graph = NativeGraph::for_model(spec)?;
        let packed = pack_prepared(spec, prep)?;
        for name in graph.layer_names() {
            packed.layer(name)?; // every graph layer must have a payload
        }
        Ok(NativeExecutable {
            graph,
            packed,
            threads: 0,
        })
    }

    /// Pin the GEMM thread width (0 = pool default). Results are
    /// bit-identical at every width.
    pub fn with_threads(mut self, threads: usize) -> NativeExecutable {
        self.threads = threads;
        self
    }

    /// Layers running on the integer datapath / the f32 fallback.
    pub fn int_layers(&self) -> usize {
        self.packed.int_layers
    }

    pub fn float_layers(&self) -> usize {
        self.packed.float_layers
    }

    pub fn label(&self) -> String {
        format!("{}:{}", self.packed.model, self.packed.label())
    }

    /// Forward pass: `(B, ...)` input → `(B, classes)` logits. Any
    /// batch size — the native path has no artifact batch grid.
    /// Allocates fresh scratch buffers; hot loops should hold a
    /// [`Scratch`] and call [`NativeExecutable::infer_with`] instead.
    pub fn infer(&self, x: &TensorF) -> Result<TensorF> {
        self.run(x, None, &mut Scratch::default())
    }

    /// Forward pass reusing caller-owned [`Scratch`] arenas for the
    /// per-layer temporaries (channel expansion, activation quant,
    /// im2col patches). Bit-identical to [`NativeExecutable::infer`];
    /// the buffers grow to the model's high-water mark and stay, so
    /// steady-state serving stops allocating them per request.
    pub fn infer_with(&self, x: &TensorF, scratch: &mut Scratch) -> Result<TensorF> {
        self.run(x, None, scratch)
    }

    /// Forward pass that also records each hooked layer's *input*
    /// activation (the distribution calibration profiles) — the native
    /// twin of the `probe` artifact. Meaningful on a float-recipe
    /// executable, where hooks are identity.
    pub fn infer_probe(&self, x: &TensorF) -> Result<(TensorF, BTreeMap<String, TensorF>)> {
        let mut probe = BTreeMap::new();
        let out = self.run(x, Some(&mut probe), &mut Scratch::default())?;
        Ok((out, probe))
    }

    fn run(
        &self,
        x: &TensorF,
        mut probe: Option<&mut BTreeMap<String, TensorF>>,
        scratch: &mut Scratch,
    ) -> Result<TensorF> {
        if x.rank() < 2 || x.shape()[0] == 0 {
            bail!("native infer: batch input required, got shape {:?}", x.shape());
        }
        let mut vals: Vec<Option<TensorF>> = Vec::with_capacity(self.graph.nodes.len());
        vals.resize_with(self.graph.nodes.len(), || None);
        for i in 0..self.graph.nodes.len() {
            let v = match &self.graph.nodes[i] {
                Node::Input => x.clone(),
                Node::Layer { name, src } => {
                    let pl = self.packed.layer(name)?;
                    let xin = node_val(&vals, *src)?;
                    if pl.hooked {
                        if let Some(p) = probe.as_mut() {
                            p.insert(name.clone(), xin.clone());
                        }
                    }
                    self.apply_layer(pl, xin, scratch)
                        .with_context(|| format!("layer {name}"))?
                }
                Node::Relu { src } => node_val(&vals, *src)?.map(|v| v.max(0.0)),
                Node::MaxPool { src, k, s } => maxpool_same(node_val(&vals, *src)?, *k, *s)?,
                Node::Add { a, b } => {
                    let ta = node_val(&vals, *a)?;
                    let tb = node_val(&vals, *b)?;
                    if ta.shape() != tb.shape() {
                        bail!("add shape mismatch: {:?} vs {:?}", ta.shape(), tb.shape());
                    }
                    let data = ta
                        .data()
                        .iter()
                        .zip(tb.data())
                        .map(|(&u, &v)| u + v)
                        .collect();
                    TensorF::from_vec(ta.shape(), data)?
                }
                Node::ConcatC { srcs } => {
                    let parts: Vec<&TensorF> = srcs
                        .iter()
                        .map(|&s| node_val(&vals, s))
                        .collect::<Result<_>>()?;
                    concat_channels(&parts)?
                }
                Node::GlobalAvgPool { src } => global_avg_pool(node_val(&vals, *src)?)?,
                Node::Flatten { src } => {
                    let t = node_val(&vals, *src)?;
                    let b = t.shape()[0];
                    let rest: usize = t.shape()[1..].iter().product();
                    t.clone().reshape(&[b, rest])?
                }
            };
            vals[i] = Some(v);
        }
        Ok(vals
            .pop()
            .flatten()
            .expect("graph has at least the input node"))
    }

    /// One parametric layer: channel_dup → activation quant → GEMM
    /// (integer or f32 body), conv via im2col. All temporaries live in
    /// `scratch`.
    fn apply_layer(&self, pl: &PackedLayer, x: &TensorF, scratch: &mut Scratch) -> Result<TensorF> {
        match pl.kind {
            LayerKind::Fc => self.fc(pl, x, scratch),
            LayerKind::Conv => self.conv(pl, x, scratch),
            LayerKind::Embed => bail!("embed layers are artifact-only"),
        }
    }

    fn fc(&self, pl: &PackedLayer, x: &TensorF, s: &mut Scratch) -> Result<TensorF> {
        if x.rank() != 2 {
            bail!("fc expects (B, cin), got {:?}", x.shape());
        }
        let b = x.shape()[0];
        let (xe, _) = expand_channels_into(x, pl, &mut s.expand)?;
        let out = match &pl.body {
            LayerBody::Int {
                wq, dequant, bias, ..
            } => {
                quantize_acts_into(xe, pl.adelta, pl.aqmax, &mut s.qacts);
                gemm::gemm_i8_dequant(&s.qacts, wq, b, dequant, bias, self.threads)
            }
            LayerBody::Float { w, bias } => {
                fake_quant_into(xe, pl.adelta, pl.aqmax, &mut s.facts);
                gemm::gemm_f32(
                    &s.facts,
                    w,
                    b,
                    pl.gemm_k(),
                    pl.cout,
                    Some(bias.as_slice()),
                    self.threads,
                )
            }
        };
        Ok(TensorF::from_vec(&[b, pl.cout], out)?)
    }

    fn conv(&self, pl: &PackedLayer, x: &TensorF, s: &mut Scratch) -> Result<TensorF> {
        if x.rank() != 4 {
            bail!("conv expects (B, H, W, C), got {:?}", x.shape());
        }
        let (bsz, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
        let (xe, c) = expand_channels_into(x, pl, &mut s.expand)?;
        let (k, st) = (pl.ksize, pl.stride);
        let (oh, ow) = (h.div_ceil(st), w.div_ceil(st));
        let pad_h = ((oh - 1) * st + k).saturating_sub(h);
        let pad_w = ((ow - 1) * st + k).saturating_sub(w);
        let (pt, plft) = (pad_h / 2, pad_w / 2);
        let m = bsz * oh * ow;
        let out = match &pl.body {
            LayerBody::Int {
                wq, dequant, bias, ..
            } => {
                quantize_acts_into(xe, pl.adelta, pl.aqmax, &mut s.qacts);
                im2col_into(&s.qacts, bsz, h, w, c, k, st, pt, plft, oh, ow, &mut s.icols);
                gemm::gemm_i8_dequant(&s.icols, wq, m, dequant, bias, self.threads)
            }
            LayerBody::Float { w: wt, bias } => {
                fake_quant_into(xe, pl.adelta, pl.aqmax, &mut s.facts);
                im2col_into(&s.facts, bsz, h, w, c, k, st, pt, plft, oh, ow, &mut s.fcols);
                gemm::gemm_f32(
                    &s.fcols,
                    wt,
                    m,
                    pl.gemm_k(),
                    pl.cout,
                    Some(bias.as_slice()),
                    self.threads,
                )
            }
        };
        Ok(TensorF::from_vec(&[bsz, oh, ow, pl.cout], out)?)
    }
}

/// Reusable per-worker scratch arenas for the native forward pass.
///
/// A forward needs several large temporaries — the `channel_dup`
/// expansion, the quantized activation grid, the im2col patch matrix —
/// that used to be allocated fresh per layer per request. A serve
/// worker owns one `Scratch` and threads it through
/// [`NativeExecutable::infer_with`], so steady-state serving does not
/// allocate these buffers at all: they grow to the model's high-water
/// mark on the first pass and are reused after that. Every `_into`
/// fill clears and fully (re)initializes the region it uses, so
/// results are bit-identical to the allocating
/// [`NativeExecutable::infer`] path (asserted in tests; stale contents
/// can never leak into a later forward).
#[derive(Debug, Default)]
pub struct Scratch {
    /// `channel_dup` expanded activations.
    expand: Vec<f32>,
    /// Integer-grid activations feeding the packed i8 GEMM.
    qacts: Vec<i8>,
    /// Fake-quantized f32 activations (float-body layers).
    facts: Vec<f32>,
    /// im2col patch matrix, integer body.
    icols: Vec<i8>,
    /// im2col patch matrix, f32 body.
    fcols: Vec<f32>,
}

fn node_val(vals: &[Option<TensorF>], i: usize) -> Result<&TensorF> {
    vals.get(i)
        .and_then(|v| v.as_ref())
        .context("graph node referenced before evaluation")
}

/// `channel_dup` on the trailing axis into a reusable arena:
/// `(… , cin)` → `(… , cin_eff)`. Returns the activation slice and its
/// trailing channel count; unhooked layers borrow straight from `x`
/// (no copy at all, where the allocating path used to clone).
fn expand_channels_into<'a>(
    x: &'a TensorF,
    pl: &PackedLayer,
    buf: &'a mut Vec<f32>,
) -> Result<(&'a [f32], usize)> {
    let c = *x.shape().last().context("rank >= 1")?;
    if c != pl.cin {
        bail!(
            "layer {}: input has {c} channels, expected {}",
            pl.name,
            pl.cin
        );
    }
    if !pl.hooked {
        return Ok((x.data(), c));
    }
    let ce = pl.cin_eff;
    let rows = x.len() / c.max(1);
    buf.clear();
    buf.resize(rows * ce, 0.0);
    for r in 0..rows {
        let xr = &x.data()[r * c..(r + 1) * c];
        let or = &mut buf[r * ce..(r + 1) * ce];
        for j in 0..ce {
            or[j] = xr[pl.idx[j] as usize] * pl.dscale[j] + pl.dbias[j];
        }
    }
    Ok((buf.as_slice(), ce))
}

/// Quantize activations straight to their grid integers (the values
/// Eq. 1 fake-quant would dequantize back): `clamp(Q(x/Δ), ±aqmax)`.
fn quantize_acts_into(xs: &[f32], adelta: f32, aqmax: f32, out: &mut Vec<i8>) {
    out.clear();
    if adelta <= 0.0 {
        out.resize(xs.len(), 0);
        return;
    }
    out.extend(
        xs.iter()
            .map(|&x| round_half_up(x / adelta).clamp(-aqmax, aqmax) as i8),
    );
}

/// Allocating wrapper around [`quantize_acts_into`] (tests).
#[cfg(test)]
fn quantize_acts(xs: &[f32], adelta: f32, aqmax: f32) -> Vec<i8> {
    let mut out = Vec::new();
    quantize_acts_into(xs, adelta, aqmax, &mut out);
    out
}

/// Artifact-exact f32 fake-quant for the f32 body (`aqmax <= 0`
/// bypasses, as in the Pallas kernel), copied into a reusable arena.
fn fake_quant_into(xs: &[f32], adelta: f32, aqmax: f32, out: &mut Vec<f32>) {
    out.clear();
    out.extend_from_slice(xs);
    if aqmax > 0.0 {
        crate::quant::fake_quant_slice(out, adelta, aqmax);
    }
}

/// im2col for SAME-padded NHWC conv into a reusable arena: row
/// `(b, oy, ox)` holds the `k*k*c` patch in `(ky, kx, c)` order —
/// exactly the HWIO weight layout, so the conv is one GEMM.
/// Out-of-image taps stay `T::default()` (zero — identical in integer
/// and f32 space); the clear + resize below re-zeroes the whole
/// buffer, so padding taps from a previous forward can never leak in.
#[allow(clippy::too_many_arguments)]
fn im2col_into<T: Copy + Default>(
    x: &[T],
    bsz: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    s: usize,
    pad_top: usize,
    pad_left: usize,
    oh: usize,
    ow: usize,
    out: &mut Vec<T>,
) {
    let kk = k * k * c;
    out.clear();
    out.resize(bsz * oh * ow * kk, T::default());
    let mut row = 0usize;
    for b in 0..bsz {
        for oy in 0..oh {
            for ox in 0..ow {
                let rbase = row * kk;
                row += 1;
                let mut col = 0usize;
                for ky in 0..k {
                    let iy = (oy * s + ky) as isize - pad_top as isize;
                    let in_y = iy >= 0 && (iy as usize) < h;
                    for kx in 0..k {
                        let ix = (ox * s + kx) as isize - pad_left as isize;
                        if in_y && ix >= 0 && (ix as usize) < w {
                            let src = ((b * h + iy as usize) * w + ix as usize) * c;
                            out[rbase + col..rbase + col + c]
                                .copy_from_slice(&x[src..src + c]);
                        }
                        col += c;
                    }
                }
            }
        }
    }
}

/// SAME-padded max-pool over `(B, H, W, C)`; padding taps are -inf
/// (never selected — every SAME window overlaps the image).
fn maxpool_same(x: &TensorF, k: usize, s: usize) -> Result<TensorF> {
    if x.rank() != 4 {
        bail!("maxpool expects (B, H, W, C), got {:?}", x.shape());
    }
    let (bsz, h, w, c) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let (oh, ow) = (h.div_ceil(s), w.div_ceil(s));
    let pad_h = ((oh - 1) * s + k).saturating_sub(h);
    let pad_w = ((ow - 1) * s + k).saturating_sub(w);
    let (pt, pl) = (pad_h / 2, pad_w / 2);
    let data = x.data();
    let mut out = vec![f32::NEG_INFINITY; bsz * oh * ow * c];
    for b in 0..bsz {
        for oy in 0..oh {
            for ox in 0..ow {
                let obase = ((b * oh + oy) * ow + ox) * c;
                for ky in 0..k {
                    let iy = (oy * s + ky) as isize - pt as isize;
                    if iy < 0 || iy as usize >= h {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = (ox * s + kx) as isize - pl as isize;
                        if ix < 0 || ix as usize >= w {
                            continue;
                        }
                        let ibase = ((b * h + iy as usize) * w + ix as usize) * c;
                        for ch in 0..c {
                            let v = data[ibase + ch];
                            if v > out[obase + ch] {
                                out[obase + ch] = v;
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(TensorF::from_vec(&[bsz, oh, ow, c], out)?)
}

/// Mean over the spatial axes: `(B, H, W, C)` → `(B, C)`.
fn global_avg_pool(x: &TensorF) -> Result<TensorF> {
    if x.rank() != 4 {
        bail!("global_avg_pool expects (B, H, W, C), got {:?}", x.shape());
    }
    let (bsz, h, w, c) = (x.shape()[0], x.shape()[1], x.shape()[2], x.shape()[3]);
    let hw = (h * w).max(1);
    let mut out = vec![0.0f32; bsz * c];
    for b in 0..bsz {
        for p in 0..h * w {
            let ibase = (b * h * w + p) * c;
            for ch in 0..c {
                out[b * c + ch] += x.data()[ibase + ch];
            }
        }
        for ch in 0..c {
            out[b * c + ch] /= hw as f32;
        }
    }
    Ok(TensorF::from_vec(&[bsz, c], out)?)
}

/// Concat along the trailing channel axis (all leading dims equal).
fn concat_channels(parts: &[&TensorF]) -> Result<TensorF> {
    let first = parts.first().context("concat of nothing")?;
    let lead = &first.shape()[..first.rank() - 1];
    let mut ctot = 0usize;
    for p in parts {
        if &p.shape()[..p.rank() - 1] != lead {
            bail!("concat leading-shape mismatch: {:?} vs {:?}", p.shape(), first.shape());
        }
        ctot += *p.shape().last().unwrap();
    }
    let rows: usize = lead.iter().product();
    let mut out = vec![0.0f32; rows * ctot];
    for r in 0..rows {
        let mut off = 0usize;
        for p in parts {
            let pc = *p.shape().last().unwrap();
            out[r * ctot + off..r * ctot + off + pc]
                .copy_from_slice(&p.data()[r * pc..(r + 1) * pc]);
            off += pc;
        }
    }
    let mut shape = lead.to_vec();
    shape.push(ctot);
    Ok(TensorF::from_vec(&shape, out)?)
}

/// Native engine: the [`super::Engine`]-shaped entry point for the
/// integer backend. Holds the model spec and a per-engine executable
/// cache keyed by recipe fingerprint (one engine serves one weight
/// set, exactly like a PJRT engine serves one artifact dir).
pub struct NativeEngine {
    spec: ModelSpec,
    cache: RefCell<HashMap<String, Rc<NativeExecutable>>>,
}

impl NativeEngine {
    pub fn new(spec: ModelSpec) -> NativeEngine {
        NativeEngine {
            spec,
            cache: RefCell::new(HashMap::new()),
        }
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    /// Lower + cache an executable for `prep` (keyed by its recipe
    /// fingerprint — an engine serves one weight/calibration set, so
    /// the fingerprint pins the prep).
    pub fn load(&self, prep: &PreparedModel) -> Result<Rc<NativeExecutable>> {
        let key = prep.recipe.fingerprint();
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let exe = Rc::new(NativeExecutable::build(&self.spec, prep)?);
        crate::debugln!(
            "native executable ready: {} ({} int / {} f32 layers)",
            exe.label(),
            exe.int_layers(),
            exe.float_layers()
        );
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    pub fn cached_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

/// Activation calibration through the native float forward — the
/// artifact-free twin of [`crate::calib::calibrate`]: run a
/// float-recipe executable as the probe, collect every quantizable
/// layer's input activation, fold the fused statistics.
pub fn native_calibrate(
    spec: &ModelSpec,
    ws: &WeightStore,
    images: &TensorF,
    batch: usize,
) -> Result<Calibration> {
    if spec.is_lm() {
        bail!("activation calibration targets CNN models");
    }
    let n = images.shape()[0];
    if n < batch || batch == 0 {
        bail!("calibration set ({n}) smaller than probe batch ({batch})");
    }
    let float_prep = pipeline::prepare_recipe(spec, ws, None, &QuantRecipe::float())?;
    let exe = NativeExecutable::build(spec, &float_prep)?;
    let mut acts: BTreeMap<String, Vec<TensorF>> = BTreeMap::new();
    let mut i = 0usize;
    while i + batch <= n {
        let xb = calib::slice_rows(images, i, batch)?;
        let (_, probe) = exe.infer_probe(&xb)?;
        for (layer, a) in probe {
            acts.entry(layer).or_default().push(a);
        }
        i += batch;
    }
    Ok(calib::statistics(acts))
}

/// A small in-memory quantizable MLP (`(B, 16, 16, 3)` images →
/// 10 classes) with outlier-bearing weights — the built-in model behind
/// artifact-free native serving (`ocs serve --backend native
/// --sim-free`) and the native integration tests. Deterministic per
/// seed.
pub fn synthetic_mlp(seed: u64) -> (ModelSpec, WeightStore) {
    use crate::util::rng::Rng;
    let dims = [(768usize, 64usize), (64, 10)];
    let pad = |c: usize| (c as f64 * 1.25).ceil() as usize;
    let mut layers = Vec::new();
    let mut leaves = Vec::new();
    let mut rng = Rng::new(seed);
    for (i, &(cin, cout)) in dims.iter().enumerate() {
        let name = format!("f{}", i + 1);
        layers.push(LayerSpec {
            name: name.clone(),
            kind: LayerKind::Fc,
            cin,
            cin_pad: pad(cin),
            cout,
            ksize: 0,
            stride: 1,
            quantized: true,
            w_cin_axis: 0,
            w_shape: vec![cin, cout],
            w_shape_pad: vec![pad(cin), cout],
        });
        let std = (2.0f32 / cin as f32).sqrt();
        let mut w: Vec<f32> = rng.normal_vec(cin * cout).iter().map(|v| v * std).collect();
        // a few hot input channels, like trained weights (what OCS splits)
        for hot in 0..3 {
            let ch = (hot * 31 + 7) % cin;
            for j in 0..cout {
                w[ch * cout + j] *= 6.0;
            }
        }
        leaves.push((
            format!("{name}.W"),
            TensorF::from_vec(&[cin, cout], w).expect("synthetic weight"),
        ));
        leaves.push((
            format!("{name}.b"),
            TensorF::from_vec(&[cout], rng.normal_vec(cout).iter().map(|v| v * 0.05).collect())
                .expect("synthetic bias"),
        ));
    }
    let spec = ModelSpec {
        name: "native-mlp".into(),
        dir: std::path::PathBuf::new(),
        pad_factor: 1.25,
        num_classes: 10,
        img_hw: 16,
        img_c: 3,
        vocab: 0,
        seq_len: 0,
        momentum: 0.9,
        layers,
        artifacts: BTreeMap::new(),
    };
    (spec, WeightStore::from_leaves(leaves))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clip::ClipMethod;
    use crate::pipeline::QuantConfig;
    use crate::util::rng::Rng;

    fn small_images(n: usize, seed: u64) -> TensorF {
        let mut rng = Rng::new(seed);
        TensorF::from_vec(&[n, 16, 16, 3], rng.normal_vec(n * 16 * 16 * 3)).unwrap()
    }

    #[test]
    fn synthetic_mlp_floats_through() {
        let (spec, ws) = synthetic_mlp(1);
        let prep =
            pipeline::prepare_recipe(&spec, &ws, None, &QuantRecipe::float()).unwrap();
        let exe = NativeExecutable::build(&spec, &prep).unwrap();
        assert_eq!(exe.int_layers(), 0);
        let x = small_images(3, 2);
        let y = exe.infer(&x).unwrap();
        assert_eq!(y.shape(), &[3, 10]);
        assert!(y.data().iter().all(|v| v.is_finite()));
        // determinism + batch-independence: row 0 alone == row 0 of 3
        let x1 = calib::slice_rows(&x, 0, 1).unwrap();
        let y1 = exe.infer(&x1).unwrap();
        for j in 0..10 {
            assert_eq!(y1.data()[j].to_bits(), y.data()[j].to_bits());
        }
    }

    #[test]
    fn int_path_tracks_float_path() {
        let (spec, ws) = synthetic_mlp(3);
        let images = small_images(32, 4);
        let calib = native_calibrate(&spec, &ws, &images, 8).unwrap();
        let cfg = QuantConfig {
            w_bits: Some(8),
            a_bits: Some(8),
            w_clip: ClipMethod::None,
            a_clip: ClipMethod::None,
            ..QuantConfig::float()
        };
        let prep =
            pipeline::prepare_recipe(&spec, &ws, Some(&calib), &cfg.to_recipe()).unwrap();
        let exe = NativeExecutable::build(&spec, &prep).unwrap();
        assert_eq!(exe.int_layers(), 2, "{}", exe.label());
        let float_prep =
            pipeline::prepare_recipe(&spec, &ws, None, &QuantRecipe::float()).unwrap();
        let fexe = NativeExecutable::build(&spec, &float_prep).unwrap();
        let x = small_images(4, 5);
        let yq = exe.infer(&x).unwrap();
        let yf = fexe.infer(&x).unwrap();
        assert_eq!(yq.shape(), yf.shape());
        // 8/8 quantization: logits close but not identical to float
        let max_abs = yf.data().iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let mut max_err = 0.0f32;
        for (a, b) in yq.data().iter().zip(yf.data()) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(
            max_err < 0.1 * max_abs.max(1.0),
            "int path drifted: err {max_err}, scale {max_abs}"
        );
        assert_ne!(yq.data(), yf.data(), "quantization must be observable");
    }

    #[test]
    fn native_threads_bit_identical() {
        let (spec, ws) = synthetic_mlp(6);
        let images = small_images(16, 7);
        let calib = native_calibrate(&spec, &ws, &images, 8).unwrap();
        let cfg = QuantConfig {
            w_bits: Some(4),
            a_bits: Some(8),
            ocs_ratio: 0.1,
            ..QuantConfig::float()
        };
        let prep =
            pipeline::prepare_recipe(&spec, &ws, Some(&calib), &cfg.to_recipe()).unwrap();
        let x = small_images(9, 8);
        let e1 = NativeExecutable::build(&spec, &prep).unwrap().with_threads(1);
        let y1 = e1.infer(&x).unwrap();
        for t in [2usize, 8] {
            let et = NativeExecutable::build(&spec, &prep).unwrap().with_threads(t);
            let yt = et.infer(&x).unwrap();
            let b1: Vec<u32> = y1.data().iter().map(|v| v.to_bits()).collect();
            let bt: Vec<u32> = yt.data().iter().map(|v| v.to_bits()).collect();
            assert_eq!(b1, bt, "threads {t}");
        }
    }

    #[test]
    fn scratch_reuse_bit_identical_to_allocating_path() {
        let (spec, ws) = synthetic_mlp(6);
        let images = small_images(16, 7);
        let calib = native_calibrate(&spec, &ws, &images, 8).unwrap();
        let cfg = QuantConfig {
            w_bits: Some(4),
            a_bits: Some(8),
            ocs_ratio: 0.1,
            ..QuantConfig::float()
        };
        let prep =
            pipeline::prepare_recipe(&spec, &ws, Some(&calib), &cfg.to_recipe()).unwrap();
        let exe = NativeExecutable::build(&spec, &prep).unwrap();
        // one arena reused across growing AND shrinking batches: stale
        // high-water contents must never show through
        let mut s = Scratch::default();
        for (i, b) in [3usize, 1, 9, 2].into_iter().enumerate() {
            let x = small_images(b, 20 + i as u64);
            let fresh = exe.infer(&x).unwrap();
            let reused = exe.infer_with(&x, &mut s).unwrap();
            let fb: Vec<u32> = fresh.data().iter().map(|v| v.to_bits()).collect();
            let rb: Vec<u32> = reused.data().iter().map(|v| v.to_bits()).collect();
            assert_eq!(fb, rb, "batch {b}");
        }
    }

    #[test]
    fn scratch_reuse_rezeroes_im2col_padding() {
        // a conv whose SAME padding taps must be zero: run a forward
        // with large-magnitude activations to dirty the arena, then a
        // second forward and demand bitwise equality with a fresh one
        let mut rng = Rng::new(31);
        let (h, w, cin, cout, k, s) = (5usize, 5usize, 2usize, 3usize, 3usize, 1usize);
        let wt = rng.normal_vec(k * k * cin * cout);
        let bias = rng.normal_vec(cout);
        let pl = PackedLayer {
            name: "c".into(),
            kind: LayerKind::Conv,
            ksize: k,
            stride: s,
            cin,
            cin_eff: cin,
            cout,
            hooked: false,
            idx: vec![],
            dscale: vec![],
            dbias: vec![],
            adelta: 1.0,
            aqmax: -1.0,
            body: LayerBody::Float { w: wt, bias },
        };
        let exe = NativeExecutable {
            graph: NativeGraph::new(),
            packed: PackedModel {
                model: "conv-test".into(),
                layers: BTreeMap::new(),
                int_layers: 0,
                float_layers: 1,
            },
            threads: 1,
        };
        let hot: Vec<f32> = rng.normal_vec(2 * h * w * cin).iter().map(|v| v * 1e6).collect();
        let dirty = TensorF::from_vec(&[2, h, w, cin], hot).unwrap();
        let x = TensorF::from_vec(&[1, h, w, cin], rng.normal_vec(h * w * cin)).unwrap();
        let mut arena = Scratch::default();
        exe.conv(&pl, &dirty, &mut arena).unwrap();
        let reused = exe.conv(&pl, &x, &mut arena).unwrap();
        let fresh = exe.conv(&pl, &x, &mut Scratch::default()).unwrap();
        let fb: Vec<u32> = fresh.data().iter().map(|v| v.to_bits()).collect();
        let rb: Vec<u32> = reused.data().iter().map(|v| v.to_bits()).collect();
        assert_eq!(fb, rb);
    }

    #[test]
    fn engine_caches_by_fingerprint() {
        let (spec, ws) = synthetic_mlp(9);
        let engine = NativeEngine::new(spec.clone());
        let r4 = QuantConfig::weights_only(4, ClipMethod::None, 0.0).to_recipe();
        let r5 = QuantConfig::weights_only(5, ClipMethod::None, 0.0).to_recipe();
        let p4 = pipeline::prepare_recipe(&spec, &ws, None, &r4).unwrap();
        let p5 = pipeline::prepare_recipe(&spec, &ws, None, &r5).unwrap();
        let a = engine.load(&p4).unwrap();
        let b = engine.load(&p4).unwrap();
        assert!(Rc::ptr_eq(&a, &b));
        let c = engine.load(&p5).unwrap();
        assert!(!Rc::ptr_eq(&a, &c));
        assert_eq!(engine.cached_count(), 2);
        assert_eq!(engine.spec().name, "native-mlp");
    }

    #[test]
    fn probe_records_hooked_layer_inputs() {
        let (spec, ws) = synthetic_mlp(10);
        let prep =
            pipeline::prepare_recipe(&spec, &ws, None, &QuantRecipe::float()).unwrap();
        let exe = NativeExecutable::build(&spec, &prep).unwrap();
        let x = small_images(2, 11);
        let (_, probe) = exe.infer_probe(&x).unwrap();
        assert_eq!(probe.len(), 2);
        // f1 sees the flattened image, f2 sees the 64-wide hidden act
        assert_eq!(probe["f1"].shape(), &[2, 768]);
        assert_eq!(probe["f2"].shape(), &[2, 64]);
    }

    #[test]
    fn lm_and_unknown_models_are_refused() {
        let (mut spec, _) = synthetic_mlp(12);
        spec.name = "lstmlm".into();
        let err = NativeGraph::for_model(&spec).unwrap_err();
        assert!(err.to_string().contains("LSTM"), "{err:#}");
        let mut spec2 = spec.clone();
        spec2.name = "mystery".into();
        spec2.layers[0].kind = LayerKind::Conv;
        assert!(NativeGraph::for_model(&spec2).is_err());
    }

    #[test]
    fn conv_im2col_matches_direct_conv() {
        // a tiny unhooked conv layer vs a naive direct convolution
        let mut rng = Rng::new(13);
        let (h, w, cin, cout, k, s) = (5usize, 6usize, 3usize, 4usize, 3usize, 2usize);
        let x = TensorF::from_vec(&[2, h, w, cin], rng.normal_vec(2 * h * w * cin)).unwrap();
        let wt = rng.normal_vec(k * k * cin * cout);
        let bias = rng.normal_vec(cout);
        let pl = PackedLayer {
            name: "c".into(),
            kind: LayerKind::Conv,
            ksize: k,
            stride: s,
            cin,
            cin_eff: cin,
            cout,
            hooked: false,
            idx: vec![],
            dscale: vec![],
            dbias: vec![],
            adelta: 1.0,
            aqmax: -1.0,
            body: LayerBody::Float {
                w: wt.clone(),
                bias: bias.clone(),
            },
        };
        let exe = NativeExecutable {
            graph: NativeGraph::new(),
            packed: PackedModel {
                model: "conv-test".into(),
                layers: BTreeMap::new(),
                int_layers: 0,
                float_layers: 1,
            },
            threads: 1,
        };
        let got = exe.conv(&pl, &x, &mut Scratch::default()).unwrap();
        // direct SAME conv reference
        let (oh, ow) = (h.div_ceil(s), w.div_ceil(s));
        assert_eq!(got.shape(), &[2, oh, ow, cout]);
        let pad_h = ((oh - 1) * s + k).saturating_sub(h);
        let pad_w = ((ow - 1) * s + k).saturating_sub(w);
        let (pt, plft) = (pad_h / 2, pad_w / 2);
        for b in 0..2 {
            for oy in 0..oh {
                for ox in 0..ow {
                    for co in 0..cout {
                        let mut acc = bias[co];
                        for ky in 0..k {
                            for kx in 0..k {
                                let iy = (oy * s + ky) as isize - pt as isize;
                                let ix = (ox * s + kx) as isize - plft as isize;
                                if iy < 0 || iy as usize >= h || ix < 0 || ix as usize >= w {
                                    continue;
                                }
                                for ci in 0..cin {
                                    let xv = x.data()
                                        [((b * h + iy as usize) * w + ix as usize) * cin + ci];
                                    let wv = wt[((ky * k + kx) * cin + ci) * cout + co];
                                    acc += xv * wv;
                                }
                            }
                        }
                        let gv = got.data()[((b * oh + oy) * ow + ox) * cout + co];
                        assert!(
                            (gv - acc).abs() < 1e-4,
                            "({b},{oy},{ox},{co}): {gv} vs {acc}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn maxpool_matches_reference() {
        // 1x4x4x1, k=2 s=2: plain 2x2 windows
        let x = TensorF::from_vec(
            &[1, 4, 4, 1],
            vec![
                1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0, 11.0, 12.0, 13.0, 14.0,
                15.0, 16.0,
            ],
        )
        .unwrap();
        let y = maxpool_same(&x, 2, 2).unwrap();
        assert_eq!(y.shape(), &[1, 2, 2, 1]);
        assert_eq!(y.data(), &[6.0, 8.0, 14.0, 16.0]);
        // k=3 s=1 SAME keeps the shape; corners see a 2x2 window
        let y2 = maxpool_same(&x, 3, 1).unwrap();
        assert_eq!(y2.shape(), &[1, 4, 4, 1]);
        assert_eq!(y2.data()[0], 6.0, "corner window = max of 2x2");
        assert_eq!(y2.data()[5], 11.0, "interior window = max of 3x3");
    }

    #[test]
    fn gap_and_concat() {
        let x = TensorF::from_vec(&[1, 2, 2, 2], vec![1., 2., 3., 4., 5., 6., 7., 8.]).unwrap();
        let g = global_avg_pool(&x).unwrap();
        assert_eq!(g.shape(), &[1, 2]);
        assert_eq!(g.data(), &[4.0, 5.0]);
        let a = TensorF::from_vec(&[1, 1, 1, 2], vec![1., 2.]).unwrap();
        let b = TensorF::from_vec(&[1, 1, 1, 1], vec![3.]).unwrap();
        let cat = concat_channels(&[&a, &b]).unwrap();
        assert_eq!(cat.shape(), &[1, 1, 1, 3]);
        assert_eq!(cat.data(), &[1., 2., 3.]);
    }

    #[test]
    fn quantize_acts_matches_fake_quant() {
        let mut rng = Rng::new(14);
        let xs = rng.normal_vec(256);
        let (adelta, aqmax) = (0.03f32, 127.0f32);
        let q = quantize_acts(&xs, adelta, aqmax);
        for (&x, &qi) in xs.iter().zip(&q) {
            let fq = crate::quant::fake_quant_val(x, adelta, aqmax);
            assert_eq!(
                (qi as f32 * adelta).to_bits(),
                fq.to_bits(),
                "x={x} q={qi}"
            );
        }
        assert!(quantize_acts(&xs, 0.0, 127.0).iter().all(|&q| q == 0));
    }
}
