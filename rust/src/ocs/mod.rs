//! Outlier Channel Splitting — the paper's §3 contribution.
//!
//! * [`split`] — the value-level split functions: naive halving (Eq. 5,
//!   Net2WiderNet) and quantization-aware splitting (Eq. 6, the paper's
//!   novel formula that preserves `Q(w)` exactly, proven via Hermite's
//!   identity in Eq. 7/8).
//! * [`plan`] — how many channels each layer splits: the simple
//!   `ceil(r * C)` rule (§3.4) plus the knapsack allocator the paper
//!   mentions trying (kept as an ablation).
//! * [`transform`] — whole-layer transforms: duplicate the selected
//!   channels into the artifact's padded slots and emit the
//!   `(W_expanded, idx, dscale, dbias)` inputs the AOT-compiled graph
//!   consumes. Covers weight OCS (Eq. 3: halve the weights) and
//!   activation OCS (Eq. 4: halve the activations via `channel_dup`
//!   scales).

pub mod plan;
pub mod split;
pub mod transform;

pub use split::SplitMode;
pub use transform::{activation_ocs, identity_hooks, weight_ocs, OcsHooks};

/// Which tensor class OCS splits (paper evaluates both; §5.2 vs §5.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OcsTarget {
    Weights,
    Activations,
}

/// Full OCS configuration for one quantization run.
#[derive(Debug, Clone, Copy)]
pub struct OcsConfig {
    /// Expansion ratio r: each layer splits ceil(r * C) channels (§3.4).
    pub ratio: f64,
    pub mode: SplitMode,
    pub target: OcsTarget,
}

impl OcsConfig {
    pub fn weights(ratio: f64) -> Self {
        OcsConfig {
            ratio,
            mode: SplitMode::QuantAware,
            target: OcsTarget::Weights,
        }
    }

    pub fn activations(ratio: f64) -> Self {
        OcsConfig {
            ratio,
            mode: SplitMode::QuantAware,
            target: OcsTarget::Activations,
        }
    }
}
