//! Whole-layer OCS transforms (paper §3.2, §3.4, §3.5).
//!
//! A quantizable layer in the AOT artifact reserves `cin_pad` input
//! channels; the runtime inputs `(W, idx, dscale, dbias)` steer them:
//! activations are expanded by the `channel_dup` Pallas kernel as
//! `x_exp[j] = x[idx[j]] * dscale[j] + dbias[j]`, then multiply
//! `W_expanded`. OCS materializes splits into the padded slots:
//!
//! * **Weight OCS** (Eq. 3): the activation channel is *duplicated*
//!   (`dscale` stays), and the weight channel is split in half with the
//!   naive or quantization-aware rule. Channel choice: iteratively split
//!   the channel holding the layer's current largest |w| (§3.4).
//! * **Activation OCS** (Eq. 4): the weight channel is duplicated
//!   unchanged and the activation halves via `dscale`; QA splitting adds
//!   the ∓delta/4 offsets through `dbias`. Channel choice: the
//!   calibration-ranked outlier channels (§5.3).

use anyhow::{bail, Result};

use super::split::SplitMode;
use crate::kernels;
use crate::tensor::{TensorF, TensorI};

/// Everything the runtime needs to drive one quantizable layer.
#[derive(Debug, Clone)]
pub struct OcsHooks {
    /// Weight with the input-channel axis grown to `cin_pad`; split
    /// channels already materialized (still float — quantize after).
    pub w_expanded: TensorF,
    /// Source channel per padded slot (into the *original* cin).
    pub idx: TensorI,
    /// Per-slot activation scale (1 normally, 0 for inert slots, 0.5^k
    /// after k activation splits).
    pub dscale: TensorF,
    /// Per-slot activation bias (QA activation splitting's ∓delta/4).
    pub dbias: TensorF,
    /// Slots in use: cin + performed splits.
    pub active: usize,
    /// Original channel count.
    pub cin: usize,
    /// (src_slot, new_slot) per performed split, in order.
    pub splits: Vec<(usize, usize)>,
}

impl OcsHooks {
    /// The functionally-equivalent unpadded weight: folding every slot
    /// back onto its source channel (`eff[c] = sum_{idx[s]=c} dscale[s] *
    /// W[s]`). For naive splits this must equal the original weight
    /// exactly; for QA weight splits too (the ± delta/4 cancel).
    pub fn effective_weight(&self, cin_axis: usize) -> TensorF {
        let mut shape = self.w_expanded.shape().to_vec();
        shape[cin_axis] = self.cin;
        let mut eff = TensorF::zeros(&shape);
        let (outer, alen_pad, inner) = self.w_expanded.axis_geometry(cin_axis).unwrap();
        let alen = self.cin;
        let wdata = self.w_expanded.data();
        let idx = self.idx.data();
        let scale = self.dscale.data();
        let edata = eff.data_mut();
        for s in 0..self.active.min(alen_pad) {
            let c = idx[s] as usize;
            let sc = scale[s];
            if sc == 0.0 {
                continue;
            }
            for o in 0..outer {
                let sbase = (o * alen_pad + s) * inner;
                let dbase = (o * alen + c) * inner;
                for k in 0..inner {
                    edata[dbase + k] += sc * wdata[sbase + k];
                }
            }
        }
        eff
    }

    /// Relative model-size overhead of this layer's expansion (Table 5).
    pub fn overhead(&self) -> f64 {
        self.active as f64 / self.cin as f64
    }
}

/// No-op hooks: original channels pass through, padded slots inert.
pub fn identity_hooks(w: &TensorF, cin_axis: usize, cin_pad: usize) -> Result<OcsHooks> {
    let cin = w.shape()[cin_axis];
    if cin_pad < cin {
        bail!("cin_pad {cin_pad} < cin {cin}");
    }
    let w_expanded = w.pad_axis(cin_axis, cin_pad)?;
    let mut idx = vec![0i32; cin_pad];
    let mut dscale = vec![0.0f32; cin_pad];
    for c in 0..cin {
        idx[c] = c as i32;
        dscale[c] = 1.0;
    }
    Ok(OcsHooks {
        w_expanded,
        idx: TensorI::from_vec(&[cin_pad], idx)?,
        dscale: TensorF::from_vec(&[cin_pad], dscale)?,
        dbias: TensorF::zeros(&[cin_pad]),
        active: cin,
        cin,
        splits: Vec::new(),
    })
}

/// Weight OCS (§3.2 Eq. 3 + §3.4 selection): perform `n_splits` splits,
/// each time picking the channel containing the layer's largest |w|.
/// `delta` is the weight-grid step used by QA splitting (pass the final
/// quantization delta; `<= 0` or `Naive` degrades to plain halving).
pub fn weight_ocs(
    w: &TensorF,
    cin_axis: usize,
    cin_pad: usize,
    n_splits: usize,
    mode: SplitMode,
    delta: f32,
) -> Result<OcsHooks> {
    let mut hooks = identity_hooks(w, cin_axis, cin_pad)?;
    let (outer, alen_pad, inner) = hooks.w_expanded.axis_geometry(cin_axis)?;
    // per-slot current max |w|
    let mut maxes: Vec<f32> = (0..hooks.active)
        .map(|i| hooks.w_expanded.axis_max_abs(cin_axis, i).unwrap())
        .collect();
    for _ in 0..n_splits {
        if hooks.active >= cin_pad {
            break; // out of padded capacity
        }
        // §3.4: always split the channel with the current largest value
        let (src, _) = maxes
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .expect("at least one channel");
        let dst = hooks.active;
        // fused kernel: one strided pass writes dst = (w + delta/2)/2
        // and src = (w - delta/2)/2 and yields both post-split maxes
        // (formerly a copy, a rewrite, and two max sweeps)
        let (max_src, max_dst) = kernels::split_channel(
            hooks.w_expanded.data_mut(),
            outer,
            alen_pad,
            inner,
            src,
            dst,
            delta,
            mode,
        );
        // the activation channel is duplicated as-is (Eq. 3: halving
        // lives in the weights) — inherit the source slot's steering
        hooks.idx.data_mut()[dst] = hooks.idx.data()[src];
        hooks.dscale.data_mut()[dst] = hooks.dscale.data()[src];
        hooks.dbias.data_mut()[dst] = hooks.dbias.data()[src];
        maxes[src] = max_src;
        maxes.push(max_dst);
        hooks.splits.push((src, dst));
        hooks.active += 1;
    }
    Ok(hooks)
}

/// Activation OCS (§3.2 Eq. 4 + §5.3 selection): split each listed
/// original channel once. Weights duplicate unchanged; activations halve
/// via `dscale`, with QA's ∓`act_delta`/4 offsets in `dbias`.
pub fn activation_ocs(
    w: &TensorF,
    cin_axis: usize,
    cin_pad: usize,
    channels: &[usize],
    mode: SplitMode,
    act_delta: f32,
) -> Result<OcsHooks> {
    let mut hooks = identity_hooks(w, cin_axis, cin_pad)?;
    for &c in channels {
        if hooks.active >= cin_pad {
            break;
        }
        if c >= hooks.cin {
            bail!("activation split channel {c} out of range (cin {})", hooks.cin);
        }
        let src = c; // primary slot of original channel c
        let dst = hooks.active;
        // duplicate the weight channel unchanged
        hooks.w_expanded.axis_copy_with(cin_axis, src, dst, |v| v)?;
        hooks.idx.data_mut()[dst] = hooks.idx.data()[src];
        // halve the activation: new scale = old/2 on both slots
        let old_scale = hooks.dscale.data()[src];
        let old_bias = hooks.dbias.data()[src];
        let half = old_scale * 0.5;
        let (qa_lo, qa_hi) = match mode {
            SplitMode::Naive => (0.0, 0.0),
            SplitMode::QuantAware => (-act_delta / 4.0, act_delta / 4.0),
        };
        hooks.dscale.data_mut()[src] = half;
        hooks.dscale.data_mut()[dst] = half;
        hooks.dbias.data_mut()[src] = old_bias * 0.5 + qa_lo;
        hooks.dbias.data_mut()[dst] = old_bias * 0.5 + qa_hi;
        hooks.splits.push((src, dst));
        hooks.active += 1;
    }
    Ok(hooks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::miniprop::{check, ensure, gen_usize};
    use crate::util::rng::Rng;

    fn conv_weight(rng: &mut Rng, k: usize, cin: usize, cout: usize) -> TensorF {
        TensorF::from_vec(&[k, k, cin, cout], rng.normal_vec(k * k * cin * cout)).unwrap()
    }

    #[test]
    fn identity_hooks_are_inert() {
        let mut rng = Rng::new(0);
        let w = conv_weight(&mut rng, 3, 8, 4);
        let h = identity_hooks(&w, 2, 10).unwrap();
        assert_eq!(h.w_expanded.shape(), &[3, 3, 10, 4]);
        assert_eq!(h.active, 8);
        let eff = h.effective_weight(2);
        assert_eq!(eff.data(), w.data());
        // padded slots: scale 0
        assert_eq!(h.dscale.data()[8], 0.0);
        assert_eq!(h.dscale.data()[9], 0.0);
    }

    #[test]
    fn weight_ocs_reduces_max_abs() {
        let mut rng = Rng::new(1);
        let mut w = conv_weight(&mut rng, 3, 8, 4);
        // plant an outlier in channel 5
        let o = w.axis_geometry(2).unwrap();
        assert_eq!(o.1, 8);
        w.axis_map_mut(2, 5, |v| *v *= 10.0).unwrap();
        let before = w.max_abs();
        let h = weight_ocs(&w, 2, 10, 1, SplitMode::Naive, 0.0).unwrap();
        let after = h.w_expanded.max_abs();
        assert!(
            (after - before / 2.0).abs() < 1e-5,
            "first split must halve the outlier: {before} -> {after}"
        );
        assert_eq!(h.splits.len(), 1);
        assert_eq!(h.splits[0].0, 5, "must split the outlier channel");
    }

    #[test]
    fn weight_ocs_naive_preserves_function_exactly() {
        let mut rng = Rng::new(2);
        let w = conv_weight(&mut rng, 3, 6, 5);
        let h = weight_ocs(&w, 2, 8, 2, SplitMode::Naive, 0.0).unwrap();
        let eff = h.effective_weight(2);
        for (a, b) in eff.data().iter().zip(w.data()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn weight_ocs_qa_preserves_function_exactly() {
        // QA offsets are ±delta/4 and cancel in the sum
        let mut rng = Rng::new(3);
        let w = conv_weight(&mut rng, 1, 6, 5);
        let h = weight_ocs(&w, 2, 8, 2, SplitMode::QuantAware, 0.05).unwrap();
        let eff = h.effective_weight(2);
        for (a, b) in eff.data().iter().zip(w.data()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn weight_ocs_can_resplit_same_channel() {
        // one dominant channel keeps winning the argmax
        let w = TensorF::from_vec(&[4, 1], vec![100.0, 1.0, 1.0, 1.0]).unwrap();
        let h = weight_ocs(&w, 0, 8, 3, SplitMode::Naive, 0.0).unwrap();
        // 100 -> 50+50 -> 25+25+50/... all splits chase channel-0 mass
        for &(src, _) in &h.splits {
            assert_eq!(h.idx.data()[src], 0);
        }
        assert!(h.w_expanded.max_abs() <= 50.0);
    }

    #[test]
    fn weight_ocs_respects_capacity() {
        let mut rng = Rng::new(4);
        let w = conv_weight(&mut rng, 3, 6, 2);
        let h = weight_ocs(&w, 2, 8, 100, SplitMode::Naive, 0.0).unwrap();
        assert_eq!(h.active, 8);
        assert_eq!(h.splits.len(), 2);
    }

    #[test]
    fn activation_ocs_naive_preserves_function() {
        // eff weight counts dscale: dup slot 0.5*W + primary 0.5*W == W
        let mut rng = Rng::new(5);
        let w = conv_weight(&mut rng, 3, 6, 5);
        let h = activation_ocs(&w, 2, 8, &[2, 4], SplitMode::Naive, 0.0).unwrap();
        let eff = h.effective_weight(2);
        for (a, b) in eff.data().iter().zip(w.data()) {
            assert!((a - b).abs() < 1e-6);
        }
        // split channels have halved activation scales
        assert_eq!(h.dscale.data()[2], 0.5);
        assert_eq!(h.dscale.data()[6], 0.5);
        assert_eq!(h.idx.data()[6], 2);
    }

    #[test]
    fn activation_ocs_qa_biases() {
        let mut rng = Rng::new(6);
        let w = conv_weight(&mut rng, 1, 4, 3);
        let delta = 0.2;
        let h = activation_ocs(&w, 2, 6, &[1], SplitMode::QuantAware, delta).unwrap();
        assert!((h.dbias.data()[1] + delta / 4.0).abs() < 1e-7);
        assert!((h.dbias.data()[4] - delta / 4.0).abs() < 1e-7);
        // x*0.5 - d/4 + x*0.5 + d/4 == x : biases cancel
        let sum: f32 = h.dbias.data().iter().sum();
        assert!(sum.abs() < 1e-6);
    }

    #[test]
    fn activation_ocs_rejects_bad_channel() {
        let mut rng = Rng::new(7);
        let w = conv_weight(&mut rng, 1, 4, 3);
        assert!(activation_ocs(&w, 2, 6, &[9], SplitMode::Naive, 0.0).is_err());
    }

    #[test]
    fn property_effective_weight_invariant() {
        check("weight-ocs-equivalence", |rng| {
            let cin = gen_usize(rng, 2, 10);
            let cout = gen_usize(rng, 1, 6);
            let cin_pad = cin + gen_usize(rng, 1, 4);
            let n = gen_usize(rng, 0, 5);
            let w = TensorF::from_vec(&[cin, cout], rng.normal_vec(cin * cout)).unwrap();
            let mode = if rng.next_f32() < 0.5 {
                SplitMode::Naive
            } else {
                SplitMode::QuantAware
            };
            let h = weight_ocs(&w, 0, cin_pad, n, mode, 0.1).map_err(|e| e.to_string())?;
            let eff = h.effective_weight(0);
            for (i, (a, b)) in eff.data().iter().zip(w.data()).enumerate() {
                ensure(
                    (a - b).abs() < 1e-5,
                    format!("eff[{i}] {a} != {b} (mode {mode:?}, n {n})"),
                )?;
            }
            ensure(h.active <= cin_pad, "active within capacity")
        });
    }

    #[test]
    fn property_split_ordering_minimizes_range() {
        // after n splits, the residual max is <= any single-channel
        // alternative strategy's residual max for the same n (greedy
        // argmax halving is optimal for minimizing the max)
        check("greedy-range-optimal-vs-random", |rng| {
            let cin = gen_usize(rng, 3, 8);
            let cout = gen_usize(rng, 1, 4);
            let w = TensorF::from_vec(&[cin, cout], rng.normal_vec(cin * cout)).unwrap();
            let n = gen_usize(rng, 1, 3);
            let greedy = weight_ocs(&w, 0, cin + n, n, SplitMode::Naive, 0.0)
                .map_err(|e| e.to_string())?;
            // random alternative: split arbitrary channels
            let mut alt = identity_hooks(&w, 0, cin + n).map_err(|e| e.to_string())?;
            for _ in 0..n {
                let src = rng.below(alt.active);
                let dst = alt.active;
                alt.w_expanded
                    .axis_copy_with(0, src, dst, |v| v * 0.5)
                    .map_err(|e| e.to_string())?;
                alt.w_expanded
                    .axis_map_mut(0, src, |v| *v *= 0.5)
                    .map_err(|e| e.to_string())?;
                alt.idx.data_mut()[dst] = alt.idx.data()[src];
                alt.dscale.data_mut()[dst] = alt.dscale.data()[src];
                alt.active += 1;
            }
            ensure(
                greedy.w_expanded.max_abs() <= alt.w_expanded.max_abs() + 1e-6,
                format!(
                    "greedy {} > random {}",
                    greedy.w_expanded.max_abs(),
                    alt.w_expanded.max_abs()
                ),
            )
        });
    }
}
