//! Value-level splitting math (paper §3.3).
//!
//! Splitting `w` into `(a, b)` with `a + b == w` moves an outlier toward
//! the distribution center. The naive Net2WiderNet split `(w/2, w/2)`
//! can *double* the quantization error (both halves round the same way);
//! the paper's quantization-aware (QA) split
//!
//! ```text
//! OCS_QA(w) = ((w - delta/2) / 2, (w + delta/2) / 2)
//! ```
//!
//! (Eq. 6, generalized from grid units to a grid of step `delta`)
//! guarantees `Q(a) + Q(b) == Q(w)` for the round-half-up quantizer —
//! Eq. 7, a consequence of Hermite's identity (Eq. 8).

use crate::util::round_half_up;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SplitMode {
    /// Eq. 5 — plain halving (Net2WiderNet).
    Naive,
    /// Eq. 6 — quantization-aware; preserves the quantized value exactly.
    QuantAware,
}

impl SplitMode {
    pub fn parse(s: &str) -> Option<SplitMode> {
        match s {
            "naive" => Some(SplitMode::Naive),
            "qa" | "quant-aware" => Some(SplitMode::QuantAware),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SplitMode::Naive => "naive",
            SplitMode::QuantAware => "qa",
        }
    }
}

/// Split one value on a grid of step `delta` (`delta <= 0` degrades QA
/// to naive — used by the first pass before the final grid is known).
#[inline]
pub fn split_value(w: f32, delta: f32, mode: SplitMode) -> (f32, f32) {
    match mode {
        SplitMode::Naive => (w * 0.5, w * 0.5),
        SplitMode::QuantAware => {
            if delta <= 0.0 {
                (w * 0.5, w * 0.5)
            } else {
                ((w - 0.5 * delta) * 0.5, (w + 0.5 * delta) * 0.5)
            }
        }
    }
}

/// Grid-units quantizer used in the Eq. 7 identity checks.
#[inline]
pub fn q_grid(x: f32, delta: f32) -> f32 {
    round_half_up(x / delta) * delta
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::miniprop::{check, ensure, ensure_close, gen_usize};

    #[test]
    fn halves_always_sum_to_original() {
        for mode in [SplitMode::Naive, SplitMode::QuantAware] {
            for w in [-7.3f32, -0.5, 0.0, 0.1, 3.0, 42.5] {
                let (a, b) = split_value(w, 0.25, mode);
                assert!((a + b - w).abs() < 1e-6, "{mode:?} w={w}");
            }
        }
    }

    #[test]
    fn qa_preserves_quantized_value_paper_example() {
        // the paper's w = 3 example on an integer grid: naive halves are
        // 1.5 + 1.5 -> 2 + 2 = 4 (error doubled); QA gives 1 + 2 = 3.
        let delta = 1.0;
        let w = 3.0f32;
        let (na, nb) = split_value(w, delta, SplitMode::Naive);
        assert_eq!(q_grid(na, delta) + q_grid(nb, delta), 4.0);
        let (qa, qb) = split_value(w, delta, SplitMode::QuantAware);
        assert_eq!(q_grid(qa, delta) + q_grid(qb, delta), 3.0);
    }

    #[test]
    fn qa_identity_property() {
        // Eq. 7: Q(a) + Q(b) == Q(w) for all w and grid steps
        check("qa-split-preserves-Q", |rng| {
            let w = rng.normal() * 10.0;
            let delta = 0.01 + rng.next_f32() * 2.0;
            let (a, b) = split_value(w, delta, SplitMode::QuantAware);
            ensure_close(
                (q_grid(a, delta) + q_grid(b, delta)) as f64,
                q_grid(w, delta) as f64,
                1e-4,
                &format!("w={w} delta={delta}"),
            )
        });
    }

    #[test]
    fn naive_error_at_most_delta_qa_at_most_half() {
        check("split-error-bounds", |rng| {
            let w = rng.normal() * 8.0;
            let delta = 0.05 + rng.next_f32();
            let (na, nb) = split_value(w, delta, SplitMode::Naive);
            let nerr = (q_grid(na, delta) + q_grid(nb, delta) - w).abs();
            ensure(nerr <= delta + 1e-5, format!("naive err {nerr} > delta {delta}"))?;
            let (qa, qb) = split_value(w, delta, SplitMode::QuantAware);
            let qerr = (q_grid(qa, delta) + q_grid(qb, delta) - w).abs();
            ensure(
                qerr <= 0.5 * delta + 1e-5,
                format!("qa err {qerr} > delta/2 {}", delta / 2.0),
            )
        });
    }

    #[test]
    fn hermite_identity_integer_grid() {
        // Eq. 8 with n in 2..=6 on random rationals
        check("hermite", |rng| {
            let x = rng.normal() * 100.0;
            let n = gen_usize(rng, 2, 6) as i64;
            let lhs: f64 = (0..n)
                .map(|k| ((x as f64) + k as f64 / n as f64).floor())
                .sum();
            ensure_close(lhs, ((n as f64) * x as f64).floor(), 1e-9, "hermite")
        });
    }

    #[test]
    fn qa_with_zero_delta_degrades_to_naive() {
        let (a, b) = split_value(5.0, 0.0, SplitMode::QuantAware);
        assert_eq!((a, b), (2.5, 2.5));
    }
}
