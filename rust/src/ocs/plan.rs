//! Split-budget allocation across layers (paper §3.4).
//!
//! The paper's default is the *simple* rule — every layer splits
//! `ceil(r * C)` channels regardless of its distribution. The paper also
//! reports trying a knapsack formulation (reward = % reduction of the
//! layer's dynamic range, cost = added memory) that "is experimentally
//! not better"; we implement it anyway as an ablation
//! (`rust/benches/ablations.rs` reproduces that finding).

use crate::util::ceil_div;

/// ceil(r * C); never exceeds the padded capacity headroom.
pub fn splits_for(channels: usize, ratio: f64, capacity: usize) -> usize {
    if ratio <= 0.0 || channels == 0 {
        return 0;
    }
    let want = (ratio * channels as f64).ceil() as usize;
    want.min(capacity.saturating_sub(channels))
}

/// Simple per-layer allocation: `ceil(r * C)` each (paper default).
pub fn plan_uniform(layers: &[(usize, usize)], ratio: f64) -> Vec<usize> {
    layers
        .iter()
        .map(|&(c, cap)| splits_for(c, ratio, cap))
        .collect()
}

/// One layer's marginal-range-reduction profile: `maxes` are per-channel
/// max-abs values. Simulates the paper's iterative split rule (always
/// split the current largest channel, halving it) and returns, for each
/// successive split k, the fractional reduction of the layer range.
pub fn range_reduction_profile(maxes: &[f32], max_splits: usize) -> Vec<f64> {
    if maxes.is_empty() {
        return vec![];
    }
    let mut vals: Vec<f32> = maxes.to_vec();
    let full: f32 = vals.iter().cloned().fold(0.0, f32::max);
    if full <= 0.0 {
        return vec![0.0; max_splits];
    }
    let mut out = Vec::with_capacity(max_splits);
    for _ in 0..max_splits {
        // split the argmax channel: its magnitude halves, duplicate appears
        let (i, &m) = vals
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        vals[i] = m * 0.5;
        vals.push(m * 0.5);
        let now = vals.iter().cloned().fold(0.0, f32::max);
        out.push(1.0 - (now / full) as f64);
    }
    out
}

/// Knapsack allocation: given each layer's `(channels, capacity,
/// per-channel maxes, bytes_per_channel)`, distribute a global budget of
/// extra bytes to maximize total range reduction. Marginal rewards are
/// non-increasing, so the greedy reward/cost ordering is optimal for the
/// fractional relaxation and near-optimal here.
pub struct KnapsackLayer {
    pub channels: usize,
    pub capacity: usize,
    pub maxes: Vec<f32>,
    pub bytes_per_channel: usize,
}

pub fn plan_knapsack(layers: &[KnapsackLayer], budget_bytes: usize) -> Vec<usize> {
    // candidate items: (layer, k-th split) with marginal reward
    struct Item {
        layer: usize,
        k: usize,
        reward_per_byte: f64,
    }
    let mut items: Vec<Item> = Vec::new();
    for (li, l) in layers.iter().enumerate() {
        let headroom = l.capacity.saturating_sub(l.channels);
        let profile = range_reduction_profile(&l.maxes, headroom);
        let mut prev = 0.0;
        for (k, &cum) in profile.iter().enumerate() {
            let marginal = (cum - prev).max(0.0);
            prev = cum;
            items.push(Item {
                layer: li,
                k,
                reward_per_byte: marginal / l.bytes_per_channel.max(1) as f64,
            });
        }
    }
    items.sort_by(|a, b| b.reward_per_byte.partial_cmp(&a.reward_per_byte).unwrap());
    let mut plan = vec![0usize; layers.len()];
    let mut spent = 0usize;
    for item in items {
        // splits must be taken in order within a layer
        if plan[item.layer] != item.k {
            continue;
        }
        let cost = layers[item.layer].bytes_per_channel;
        if spent + cost > budget_bytes {
            continue;
        }
        spent += cost;
        plan[item.layer] += 1;
    }
    plan
}

/// Memory overhead (relative) for a given plan — Table 5's statistic.
pub fn relative_overhead(layers: &[(usize, usize)], plan: &[usize], weights_per_channel: &[usize]) -> f64 {
    let base: usize = layers
        .iter()
        .zip(weights_per_channel)
        .map(|(&(c, _), &w)| c * w)
        .sum();
    let extra: usize = plan
        .iter()
        .zip(weights_per_channel)
        .map(|(&k, &w)| k * w)
        .sum();
    if base == 0 {
        return 1.0;
    }
    1.0 + extra as f64 / base as f64
}

/// Convenience: ceil(a*r) without fp drift for tests.
pub fn ceil_ratio(c: usize, num: usize, den: usize) -> usize {
    ceil_div(c * num, den)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_for_matches_paper_rule() {
        // ceil(r*C): r=0.01 on tens-to-hundreds of channels = 1 split
        assert_eq!(splits_for(64, 0.01, 80), 1);
        assert_eq!(splits_for(100, 0.01, 125), 1);
        assert_eq!(splits_for(128, 0.02, 160), 3);
        assert_eq!(splits_for(64, 0.05, 80), 4);
        assert_eq!(splits_for(64, 0.0, 80), 0);
        // capped by padded capacity
        assert_eq!(splits_for(64, 0.5, 70), 6);
    }

    #[test]
    fn profile_is_monotone_and_bounded() {
        let maxes = vec![1.0, 2.0, 8.0, 3.0];
        let prof = range_reduction_profile(&maxes, 6);
        for w in prof.windows(2) {
            assert!(w[1] >= w[0] - 1e-9, "profile must be non-decreasing");
        }
        // first split halves the single 8.0 outlier: range 8 -> 4
        assert!((prof[0] - 0.5).abs() < 1e-6);
        assert!(prof.iter().all(|&p| (0.0..1.0).contains(&p)));
    }

    #[test]
    fn knapsack_prefers_outlier_layer() {
        let layers = vec![
            KnapsackLayer {
                channels: 4,
                capacity: 8,
                maxes: vec![1.0, 1.0, 1.0, 1.01], // flat — splitting useless
                bytes_per_channel: 100,
            },
            KnapsackLayer {
                channels: 4,
                capacity: 8,
                maxes: vec![1.0, 1.0, 1.0, 10.0], // one big outlier
                bytes_per_channel: 100,
            },
        ];
        let plan = plan_knapsack(&layers, 200);
        assert!(plan[1] >= 1, "outlier layer must get budget: {plan:?}");
        assert!(plan[1] >= plan[0]);
    }

    #[test]
    fn knapsack_respects_budget_and_capacity() {
        let layers = vec![KnapsackLayer {
            channels: 4,
            capacity: 6,
            maxes: vec![8.0, 4.0, 2.0, 1.0],
            bytes_per_channel: 50,
        }];
        let plan = plan_knapsack(&layers, 1000);
        assert!(plan[0] <= 2, "capacity cap: {plan:?}");
        let plan2 = plan_knapsack(&layers, 49);
        assert_eq!(plan2[0], 0, "budget cap");
    }

    #[test]
    fn overhead_tracks_ratio() {
        // Table 5: overhead ~= r
        let layers = vec![(100, 125), (200, 250)];
        let wpc = vec![900, 900];
        let plan = plan_uniform(&layers, 0.05);
        let ov = relative_overhead(&layers, &plan, &wpc);
        assert!((ov - 1.05).abs() < 0.01, "overhead {ov}");
    }
}
