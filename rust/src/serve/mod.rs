//! Dynamic-batching inference server over a quantized model.
//!
//! The OCS paper's deployment story (§3.5) is that an OCS-quantized
//! model is a *plain* model — servable on commodity hardware with no
//! custom ops beyond channel duplication, which lives inside the AOT
//! artifact. This module is the L3 serving loop proving that: a
//! vLLM-router-flavoured request queue + dynamic batcher + PJRT executor.
//!
//! PJRT handles are not `Send`, so the executor thread *owns* the engine
//! and prepared model; clients talk over channels. Batches are formed by
//! draining the queue up to `max_batch` or until `max_wait` expires,
//! then padded up to the nearest compiled fwd artifact batch size.

pub mod metrics;

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::eval::pad_rows;
use crate::model::store::WeightStore;
use crate::model::ModelSpec;
use crate::pipeline::{self, QuantConfig};
use crate::runtime::{Engine, Input, Inputs};
use crate::tensor::TensorF;

pub use metrics::Metrics;

/// Server tuning knobs.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
    pub queue_cap: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            queue_cap: 1024,
        }
    }
}

struct Job {
    /// (1, H, W, C) image.
    x: TensorF,
    enqueued: Instant,
    resp: SyncSender<Result<Vec<f32>>>,
}

/// Client handle (cheaply cloneable).
#[derive(Clone)]
pub struct Client {
    tx: SyncSender<Job>,
    metrics: Arc<Metrics>,
}

impl Client {
    /// Synchronous single-image inference; returns the logits row.
    pub fn infer(&self, x: TensorF) -> Result<Vec<f32>> {
        let (tx, rx) = sync_channel(1);
        let job = Job {
            x,
            enqueued: Instant::now(),
            resp: tx,
        };
        self.tx.send(job).context("server is down")?;
        rx.recv().context("server dropped the request")?
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }
}

/// Running server: executor thread + client factory.
pub struct Server {
    tx: Option<SyncSender<Job>>,
    handle: Option<JoinHandle<Result<()>>>,
    metrics: Arc<Metrics>,
    stop: Arc<std::sync::atomic::AtomicBool>,
}

impl Server {
    /// Build the whole stack inside the executor thread (engine, spec,
    /// weights, quantization pipeline) and start serving.
    pub fn start(
        artifacts_dir: &str,
        model: &str,
        quant: QuantConfig,
        cfg: ServeConfig,
    ) -> Result<Server> {
        let (tx, rx) = sync_channel::<Job>(cfg.queue_cap);
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let s2 = stop.clone();
        let artifacts_dir = artifacts_dir.to_string();
        let model = model.to_string();
        // readiness gate: surface setup errors to the caller
        let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);
        let handle = std::thread::Builder::new()
            .name("ocs-executor".into())
            .spawn(move || executor(&artifacts_dir, &model, quant, cfg, rx, m2, s2, ready_tx))
            .context("spawn executor")?;
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(e),
            Err(_) => bail!("executor died during startup"),
        }
        Ok(Server {
            tx: Some(tx),
            handle: Some(handle),
            metrics,
            stop,
        })
    }

    pub fn client(&self) -> Client {
        Client {
            tx: self.tx.clone().expect("server running"),
            metrics: self.metrics.clone(),
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Graceful shutdown: stop accepting, drain, join the executor.
    /// Safe even while `Client` handles are still alive — the executor
    /// also watches a stop flag, not just channel disconnection.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop.store(true, std::sync::atomic::Ordering::SeqCst);
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            h.join().map_err(|_| anyhow::anyhow!("executor panicked"))??;
        }
        Ok(())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, std::sync::atomic::Ordering::SeqCst);
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn executor(
    artifacts_dir: &str,
    model: &str,
    quant: QuantConfig,
    cfg: ServeConfig,
    rx: Receiver<Job>,
    metrics: Arc<Metrics>,
    stop: Arc<std::sync::atomic::AtomicBool>,
    ready: SyncSender<Result<()>>,
) -> Result<()> {
    // full stack setup on this thread (PJRT handles are !Send)
    let setup = (|| -> Result<_> {
        let spec = ModelSpec::load_named(artifacts_dir, model)?;
        if spec.is_lm() {
            bail!("serving demo targets the CNN models");
        }
        let (ws, _) = WeightStore::load_best(&spec)?;
        let engine = Engine::cpu()?;
        let calib = if quant.a_bits.is_some() {
            let calib_set = crate::train::data::synth_images(64, 929);
            Some(crate::calib::calibrate(&engine, &spec, &ws, &calib_set.x, 32)?)
        } else {
            None
        };
        let prep = pipeline::prepare(&spec, &ws, calib.as_ref(), &quant)?;
        let mut base: Inputs = Default::default();
        prep.insert_inputs(&mut base);
        // pre-compile every batch size we may route to
        for b in spec.fwd_batches() {
            if b <= cfg.max_batch.max(1) * 2 {
                engine.load(spec.fwd_for_batch(b)?)?;
            }
        }
        Ok((spec, engine, base))
    })();
    let (spec, engine, mut base) = match setup {
        Ok(v) => {
            let _ = ready.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return Ok(());
        }
    };

    crate::info!("serving {model} (max_batch {})", cfg.max_batch);
    loop {
        // wait for the first job of a batch; wake periodically to honour
        // the stop flag even while Client handles keep the channel open
        let first = match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(j) => j,
            Err(RecvTimeoutError::Timeout) => {
                if stop.load(std::sync::atomic::Ordering::SeqCst) {
                    break;
                }
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => break, // all clients gone
        };
        let mut jobs = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        while jobs.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(j) => jobs.push(j),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        let n = jobs.len();
        let art = spec.fwd_for_batch(n)?;
        let exe = engine.load(art)?;
        // assemble (n, H, W, C) then pad to the artifact batch
        let mut data = Vec::with_capacity(n * jobs[0].x.len());
        for j in &jobs {
            data.extend_from_slice(j.x.data());
        }
        let mut shape = jobs[0].x.shape().to_vec();
        shape[0] = n;
        let xb = TensorF::from_vec(&shape, data)?;
        let xb = if n == art.batch {
            xb
        } else {
            pad_rows(&xb, art.batch)?
        };
        base.insert("x".into(), Input::F32(xb));
        let t0 = Instant::now();
        let result = exe.execute(&base);
        let exec_us = t0.elapsed().as_micros() as u64;
        match result {
            Ok(out) => {
                let logits = out.get("logits")?;
                let classes = logits.shape()[1];
                for (row, job) in jobs.into_iter().enumerate() {
                    let slice =
                        logits.data()[row * classes..(row + 1) * classes].to_vec();
                    metrics.record(job.enqueued.elapsed(), exec_us, n);
                    let _ = job.resp.send(Ok(slice));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for job in jobs {
                    let _ = job.resp.send(Err(anyhow::anyhow!(msg.clone())));
                }
            }
        }
    }
    crate::info!("executor drained, shutting down");
    Ok(())
}

/// End-to-end self-test used by `ocs serve`: spin the server, drive it
/// from several client threads, print the latency/throughput report.
pub fn self_test(artifacts_dir: &str, model: &str, quant: QuantConfig, requests: usize) -> Result<()> {
    let server = Server::start(artifacts_dir, model, quant, ServeConfig::default())?;
    let dataset = crate::train::data::synth_images(256, 411);
    let row = dataset.x.len() / dataset.len();
    let t0 = Instant::now();
    let clients = 4;
    let mut handles = Vec::new();
    for c in 0..clients {
        let client = server.client();
        let per = requests / clients;
        let xdata = dataset.x.data().to_vec();
        let shape = [1usize, 16, 16, 3];
        handles.push(std::thread::spawn(move || -> Result<usize> {
            let mut ok = 0;
            for i in 0..per {
                let idx = (c * per + i) % 256;
                let x = TensorF::from_vec(&shape, xdata[idx * row..(idx + 1) * row].to_vec())?;
                let logits = client.infer(x)?;
                if logits.len() == 10 {
                    ok += 1;
                }
            }
            Ok(ok)
        }));
    }
    let mut ok = 0;
    for h in handles {
        ok += h.join().map_err(|_| anyhow::anyhow!("client panicked"))??;
    }
    let secs = t0.elapsed().as_secs_f64();
    println!("{}", server.metrics().report());
    println!(
        "self-test: {ok}/{requests} ok in {secs:.2}s = {:.0} req/s",
        ok as f64 / secs
    );
    server.shutdown()
}
