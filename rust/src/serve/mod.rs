//! Sharded multi-worker inference engine pool.
//!
//! The OCS paper's deployment story (§3.5) is that an OCS-quantized
//! model is a *plain* model — servable on commodity hardware with no
//! custom ops beyond channel duplication, which lives inside the AOT
//! artifact. This module proves it at pool scale.
//!
//! ## Shape
//!
//! ```text
//!             Client::infer ──┐
//!             Client::infer ──┤  least-outstanding-work dispatch,
//!             Client::infer ──┤  bounded queues, reject-not-block
//!                             ▼
//!                   ┌──── Router ────┐
//!              try_send          try_send
//!                   ▼                ▼
//!          [queue cap=Q]      [queue cap=Q]        ... × workers
//!            worker 0           worker 1
//!          Engine+pipeline    Engine+pipeline      (one per thread)
//!          dynamic batcher    dynamic batcher
//! ```
//!
//! PJRT handles are `!Send`, so scaling *cannot* share one engine across
//! threads: the only correct shape is shard-per-thread, each worker
//! owning its whole stack (engine, prepared pipeline, executable cache).
//! Workers build those stacks concurrently at startup; artifact text is
//! read once per process via [`crate::runtime::HloTextCache`], and the
//! prepared quantization pipeline once per distinct recipe via the
//! process-wide [`crate::pipeline::PreparedCache`] — worker 2..N share
//! worker 1's prep through an `Arc`.
//!
//! ## Admission control and deadlines
//!
//! Dispatch walks workers in ascending outstanding-work order and
//! `try_send`s into the first bounded queue with room. When every queue
//! is full the request is **rejected immediately** — clients get an
//! error, never a silent hang. A configured deadline
//! ([`ServeConfig::deadline`]) is checked when a job is pulled into a
//! batch: expired jobs are answered with an error instead of wasting a
//! forward pass.
//!
//! ## Recipe hot-swap
//!
//! [`Server::swap_recipe`] publishes a new [`QuantRecipe`] to every
//! worker without restarting the pool. Workers notice between batches
//! (or within one idle-poll tick, ~50 ms) and re-prepare through the
//! process-wide [`crate::pipeline::PreparedCache`] — so N workers
//! swapping to the same recipe still prepare once. In-flight and
//! already-batched requests drain on the old prep; a worker whose swap
//! fails keeps serving the old prep and counts a `swap_error`. Poll
//! [`Server::swaps_applied`] to observe roll-out across the pool.
//!
//! ## Tenants
//!
//! Requests may carry a tenant key ([`Client::infer_tenant`]). The
//! pool's [`TenantTable`] maps each name to a tenant id whose recipe
//! the engines serve: recipe-aware backends build one prep per tenant
//! (lazily, through the shared [`crate::pipeline::PreparedCache`]),
//! workers partition every pull into single-tenant batches, and every
//! tenant gets its own request/reject/deadline counters and latency
//! histogram in [`PoolMetrics`] alongside the pool aggregates. Unknown
//! tenant keys fall back to the default recipe (tenant 0, counted);
//! [`Server::swap_tenant_recipe`] hot-swaps one tenant without
//! disturbing the others.
//!
//! ## Shutdown
//!
//! [`Server::shutdown`] flips the stop flag: the router rejects new
//! work, each worker drains everything already queued (every admitted
//! job gets a response), then exits; `shutdown` joins them all.
//!
//! ## Load testing
//!
//! [`loadtest`] drives a *closed-loop* offered-load sweep over a tenant
//! mix: each step pins the worker count and raises the client
//! concurrency, clients measure their own end-to-end latencies, and the
//! sweep reports saturation throughput plus per-step latency
//! percentiles as a versioned `BENCH_loadtest.json` record
//! (`ocs serve --loadtest`).

pub mod backend;
pub mod metrics;

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::pipeline::QuantRecipe;
use crate::tensor::TensorF;

use backend::{EngineFactory, PjrtFactory, SimFactory, WorkerEngine};

pub use crate::pipeline::ServeConfig;
pub use metrics::{Metrics, PoolMetrics, Snapshot};

/// Initial description of one additional tenant for
/// [`TenantTable::new`]: its routing key, its share of the load-test
/// traffic mix, and (on recipe-carrying backends) its own
/// [`QuantRecipe`].
#[derive(Debug, Clone)]
pub struct TenantInit {
    pub name: String,
    pub weight: f64,
    pub recipe: Option<QuantRecipe>,
}

/// One tenant's slot: identity plus the published-recipe cell its
/// workers poll between batches. The epoch counter tells a worker
/// *that* something changed without holding the lock; the recipe
/// itself is read under it.
struct TenantSlot {
    name: String,
    weight: f64,
    epoch: AtomicU64,
    /// The tenant's *current* recipe. Tenant 0 keeps `None` until a
    /// pool-wide swap is published — the default tenant serves whatever
    /// the factory built.
    recipe: Mutex<Option<QuantRecipe>>,
}

/// The pool's tenant registry. Tenant 0 is always `default` — the
/// recipe the factory was built with, and the fallback for requests
/// naming an unknown tenant; additional tenants carry their own recipe
/// and a weight used by the load-test traffic mix. Each entry doubles
/// as a per-tenant hot-swap slot, so swapping one tenant never
/// disturbs the others.
pub struct TenantTable {
    slots: Vec<TenantSlot>,
}

impl TenantTable {
    /// The single-tenant table every non-tenant entry point uses.
    pub fn default_only() -> TenantTable {
        Self::new(&[]).expect("the empty tenant list is always valid")
    }

    /// `default` plus one slot per entry of `extra` (tenant ids follow
    /// the given order, starting at 1).
    pub fn new(extra: &[TenantInit]) -> Result<TenantTable> {
        let mut slots = vec![TenantSlot {
            name: "default".to_string(),
            weight: 1.0,
            epoch: AtomicU64::new(0),
            recipe: Mutex::new(None),
        }];
        for (i, t) in extra.iter().enumerate() {
            if t.name.is_empty() {
                bail!("tenant {i}: name must be non-empty");
            }
            if !(t.weight > 0.0 && t.weight.is_finite()) {
                bail!("tenant '{}': weight must be finite and > 0", t.name);
            }
            if slots.iter().any(|s| s.name == t.name) {
                bail!("duplicate tenant name '{}'", t.name);
            }
            slots.push(TenantSlot {
                name: t.name.clone(),
                weight: t.weight,
                epoch: AtomicU64::new(0),
                recipe: Mutex::new(t.recipe.clone()),
            });
        }
        Ok(TenantTable { slots })
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        false // tenant 0 always exists
    }

    pub fn name(&self, id: usize) -> &str {
        &self.slots[id].name
    }

    pub fn names(&self) -> Vec<String> {
        self.slots.iter().map(|s| s.name.clone()).collect()
    }

    pub fn weight(&self, id: usize) -> f64 {
        self.slots[id].weight
    }

    pub fn id_of(&self, name: &str) -> Option<usize> {
        self.slots.iter().position(|s| s.name == name)
    }

    /// Publish a new recipe to tenant `id`'s slot (the epoch bump
    /// happens under the lock, so a worker that sees the new epoch
    /// always reads at least this recipe).
    fn publish(&self, id: usize, recipe: QuantRecipe) {
        let slot = &self.slots[id];
        let mut guard = slot.recipe.lock().expect("tenant slot poisoned");
        *guard = Some(recipe);
        slot.epoch.fetch_add(1, Ordering::Release);
    }

    fn epoch(&self, id: usize) -> u64 {
        self.slots[id].epoch.load(Ordering::Acquire)
    }

    /// Consistent `(epoch, recipe)` snapshot, read under the lock.
    fn read(&self, id: usize) -> (u64, Option<QuantRecipe>) {
        let slot = &self.slots[id];
        let guard = slot.recipe.lock().expect("tenant slot poisoned");
        (slot.epoch.load(Ordering::Acquire), guard.clone())
    }
}

/// One queued inference request.
struct Job {
    /// (1, H, W, C) image.
    x: TensorF,
    /// Tenant id (index into the pool's [`TenantTable`]).
    tenant: usize,
    enqueued: Instant,
    deadline: Option<Instant>,
    resp: SyncSender<Result<Vec<f32>>>,
}

/// One worker's intake, as seen by the router.
struct Shard {
    tx: SyncSender<Job>,
    /// Queued + in-flight gauge (shared with [`PoolMetrics`]).
    outstanding: Arc<AtomicUsize>,
}

/// Shared dispatch state: admission control + shard selection.
struct Router {
    shards: Vec<Shard>,
    queue_cap: usize,
    deadline: Option<Duration>,
    stop: Arc<AtomicBool>,
    metrics: Arc<PoolMetrics>,
    tenants: Arc<TenantTable>,
}

impl Router {
    /// Admit a request: pick the least-loaded shard with queue room and
    /// hand back the response channel. Errors instead of blocking when
    /// the pool is stopping or every queue is full.
    fn dispatch(&self, x: TensorF, tenant: usize) -> Result<Receiver<Result<Vec<f32>>>> {
        if self.stop.load(Ordering::SeqCst) {
            bail!("server is shutting down");
        }
        let (tx, rx) = sync_channel(1);
        let now = Instant::now();
        let mut job = Job {
            x,
            tenant,
            enqueued: now,
            deadline: self.deadline.map(|d| now + d),
            resp: tx,
        };
        // least-outstanding-work dispatch, allocation-free on the hot
        // path: start at the least-loaded shard, walk the rest as
        // fallback when its queue is full
        let n = self.shards.len();
        let mut start = 0usize;
        let mut least = usize::MAX;
        for (i, shard) in self.shards.iter().enumerate() {
            let o = shard.outstanding.load(Ordering::Relaxed);
            if o < least {
                least = o;
                start = i;
            }
        }
        for offset in 0..n {
            let i = (start + offset) % n;
            let shard = &self.shards[i];
            // count before send: the worker may answer (and decrement)
            // before try_send even returns
            shard.outstanding.fetch_add(1, Ordering::Relaxed);
            match shard.tx.try_send(job) {
                Ok(()) => {
                    self.metrics.dispatched.fetch_add(1, Ordering::Relaxed);
                    return Ok(rx);
                }
                Err(TrySendError::Full(j)) | Err(TrySendError::Disconnected(j)) => {
                    shard.outstanding.fetch_sub(1, Ordering::Relaxed);
                    job = j;
                }
            }
        }
        self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
        self.metrics.record_tenant_rejected(tenant);
        bail!(
            "server overloaded: all {} worker queues full (cap {} each)",
            self.shards.len(),
            self.queue_cap
        )
    }
}

/// Client handle (cheaply cloneable, shareable across threads).
#[derive(Clone)]
pub struct Client {
    router: Arc<Router>,
    metrics: Arc<PoolMetrics>,
}

impl Client {
    /// Synchronous single-image inference as the default tenant;
    /// returns the logits row.
    pub fn infer(&self, x: TensorF) -> Result<Vec<f32>> {
        self.infer_id(0, x)
    }

    /// Tenant-keyed inference: the request is metered, admission-
    /// controlled, and executed under `tenant`'s recipe. A name the
    /// pool does not know falls back to the default tenant's recipe
    /// (counted in [`PoolMetrics::unknown_tenant`]) — clients are never
    /// rejected for a typo'd key, they just get the default policy.
    pub fn infer_tenant(&self, tenant: &str, x: TensorF) -> Result<Vec<f32>> {
        let id = match self.router.tenants.id_of(tenant) {
            Some(id) => id,
            None => {
                self.metrics.record_unknown_tenant();
                0
            }
        };
        self.infer_id(id, x)
    }

    fn infer_id(&self, tenant: usize, x: TensorF) -> Result<Vec<f32>> {
        let rx = self.router.dispatch(x, tenant)?;
        rx.recv().context("server dropped the request")?
    }

    /// Resolve a tenant name (`None` = unknown, would fall back).
    pub fn tenant_id(&self, tenant: &str) -> Option<usize> {
        self.router.tenants.id_of(tenant)
    }

    pub fn metrics(&self) -> &PoolMetrics {
        &self.metrics
    }
}

/// Running pool: N worker threads + router + client factory.
pub struct Server {
    router: Arc<Router>,
    handles: Vec<JoinHandle<()>>,
    metrics: Arc<PoolMetrics>,
    stop: Arc<AtomicBool>,
    tenants: Arc<TenantTable>,
}

impl Server {
    /// Production entry point: PJRT engines over the AOT artifacts.
    /// `recipe` may be uniform (`QuantConfig::to_recipe()`) or carry
    /// per-layer overrides.
    pub fn start(
        artifacts_dir: &str,
        model: &str,
        recipe: QuantRecipe,
        cfg: ServeConfig,
    ) -> Result<Server> {
        let factory = Arc::new(PjrtFactory {
            artifacts_dir: artifacts_dir.to_string(),
            model: model.to_string(),
            recipe,
            max_batch: cfg.max_batch,
        });
        Server::start_with(factory, cfg)
    }

    /// Start the pool over any backend (tests/CI use [`SimFactory`])
    /// with the single implicit `default` tenant.
    pub fn start_with(factory: Arc<dyn EngineFactory>, cfg: ServeConfig) -> Result<Server> {
        Self::start_tenants(factory, cfg, TenantTable::default_only())
    }

    /// Start the pool with a tenant table: requests carry a tenant key,
    /// each tenant serves its own recipe (on recipe-aware backends) and
    /// is metered separately, and per-tenant hot-swap never disturbs
    /// the other tenants.
    ///
    /// All workers build their engines concurrently; startup fails as a
    /// whole (with every thread joined) if any worker fails to come up.
    pub fn start_tenants(
        factory: Arc<dyn EngineFactory>,
        cfg: ServeConfig,
        tenants: TenantTable,
    ) -> Result<Server> {
        cfg.validate()?;
        let tenants = Arc::new(tenants);
        let metrics = Arc::new(PoolMetrics::with_tenants(cfg.workers, tenants.names()));
        let stop = Arc::new(AtomicBool::new(false));
        let mut shards = Vec::with_capacity(cfg.workers);
        let mut handles = Vec::with_capacity(cfg.workers);
        let mut readies = Vec::with_capacity(cfg.workers);
        for id in 0..cfg.workers {
            let (tx, rx) = sync_channel::<Job>(cfg.queue_cap);
            let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);
            let outstanding = metrics.outstanding_handle(id);
            let worker_metrics = metrics.worker(id).clone();
            let worker_pool_metrics = metrics.clone();
            let worker_outstanding = outstanding.clone();
            let worker_factory = factory.clone();
            let worker_stop = stop.clone();
            let worker_tenants = tenants.clone();
            let worker_cfg = cfg.clone();
            let handle = std::thread::Builder::new()
                .name(format!("ocs-worker-{id}"))
                .spawn(move || {
                    worker_loop(
                        id,
                        worker_factory,
                        worker_cfg,
                        rx,
                        worker_metrics,
                        worker_pool_metrics,
                        worker_outstanding,
                        worker_stop,
                        worker_tenants,
                        ready_tx,
                    )
                })
                .context("spawn worker thread")?;
            shards.push(Shard { tx, outstanding });
            handles.push(handle);
            readies.push(ready_rx);
        }
        // readiness gate: surface any worker's setup error to the caller
        let mut first_err: Option<anyhow::Error> = None;
        for (id, ready) in readies.into_iter().enumerate() {
            let status = match ready.recv() {
                Ok(Ok(())) => Ok(()),
                Ok(Err(e)) => Err(e.context(format!("worker {id} setup"))),
                Err(_) => Err(anyhow!("worker {id} died during startup")),
            };
            if let Err(e) = status {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
        if let Some(e) = first_err {
            stop.store(true, Ordering::SeqCst);
            drop(shards); // disconnect every queue
            for h in handles {
                let _ = h.join();
            }
            return Err(e);
        }
        crate::info!(
            "engine pool up: {} × {} (queue cap {}/worker, max batch {}, deadline {:?}{})",
            cfg.workers,
            factory.label(),
            cfg.queue_cap,
            cfg.max_batch,
            cfg.deadline,
            if tenants.len() > 1 {
                format!(", tenants {:?}", tenants.names())
            } else {
                String::new()
            }
        );
        let router = Arc::new(Router {
            shards,
            queue_cap: cfg.queue_cap,
            deadline: cfg.deadline,
            stop: stop.clone(),
            metrics: metrics.clone(),
            tenants: tenants.clone(),
        });
        Ok(Server {
            router,
            handles,
            metrics,
            stop,
            tenants,
        })
    }

    /// Publish a new quantization recipe to every worker without
    /// restarting the pool. Workers apply it between batches (idle
    /// workers within one poll tick); requests already admitted or in
    /// flight drain on the old prep. Re-preparation goes through the
    /// process-wide [`crate::pipeline::PreparedCache`], so the pool
    /// pays one prepare per distinct recipe. A worker whose backend
    /// rejects the swap (or whose re-prepare fails) keeps serving the
    /// old prep and records a swap error.
    ///
    /// Returns immediately; poll [`Server::swaps_applied`] (against
    /// [`Server::worker_count`]) to observe the roll-out.
    ///
    /// Every distinct recipe ever served stays in the prepared-model
    /// cache (that is what makes swap-back instant); an operator cycling
    /// through many recipes on a long-lived process can reclaim the
    /// memory with [`crate::pipeline::PreparedCache::clear`] — in-flight
    /// preps stay alive through their `Arc`s.
    pub fn swap_recipe(&self, recipe: QuantRecipe) {
        crate::info!("publishing recipe swap: {}", recipe.label());
        self.tenants.publish(0, recipe);
    }

    /// Publish a new recipe to *one* tenant's slot. Workers rebuild
    /// exactly that tenant's prep (between batches, lazily for workers
    /// that never served it); every other tenant keeps serving its
    /// current prep undisturbed. Unknown tenant names are an error —
    /// unlike request routing, a swap has no sensible fallback.
    pub fn swap_tenant_recipe(&self, tenant: &str, recipe: QuantRecipe) -> Result<()> {
        let id = self
            .tenants
            .id_of(tenant)
            .with_context(|| format!("unknown tenant '{tenant}'"))?;
        crate::info!("publishing recipe swap for tenant {tenant}: {}", recipe.label());
        self.tenants.publish(id, recipe);
        Ok(())
    }

    /// The pool's tenant registry.
    pub fn tenants(&self) -> &TenantTable {
        &self.tenants
    }

    /// Total recipe swaps applied across all workers (each successful
    /// [`Server::swap_recipe`] roll-out adds `worker_count()`).
    pub fn swaps_applied(&self) -> u64 {
        self.metrics.aggregate().recipe_swaps
    }

    pub fn client(&self) -> Client {
        Client {
            router: self.router.clone(),
            metrics: self.metrics.clone(),
        }
    }

    pub fn metrics(&self) -> &PoolMetrics {
        &self.metrics
    }

    pub fn worker_count(&self) -> usize {
        self.metrics.worker_count()
    }

    /// Graceful shutdown: reject new work, drain every admitted job,
    /// join all workers. Safe while `Client` handles are still alive —
    /// workers watch the stop flag, not just channel disconnection.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop.store(true, Ordering::SeqCst);
        let mut panicked = 0usize;
        for h in self.handles.drain(..) {
            if h.join().is_err() {
                panicked += 1;
            }
        }
        if panicked > 0 {
            bail!("{panicked} worker(s) panicked");
        }
        Ok(())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Worker-local tenant state: last-seen epoch and a local clone of the
/// current recipe per tenant, so the batch hot path builds
/// [`TenantCtx`]s without ever touching the table's locks.
struct TenantView {
    table: Arc<TenantTable>,
    epochs: Vec<u64>,
    recipes: Vec<Option<QuantRecipe>>,
}

impl TenantView {
    /// Snapshot the table's construction-time recipes. Epochs are read
    /// *before* the recipes (under each slot's lock), so a swap racing
    /// this snapshot is re-applied by the first [`TenantView::sync`] —
    /// possibly redundantly, never missed.
    fn new(table: Arc<TenantTable>) -> TenantView {
        let mut epochs = Vec::with_capacity(table.len());
        let mut recipes = Vec::with_capacity(table.len());
        for id in 0..table.len() {
            let (epoch, recipe) = table.read(id);
            epochs.push(epoch);
            recipes.push(recipe);
        }
        TenantView {
            table,
            epochs,
            recipes,
        }
    }

    /// Apply every recipe published since the last sync, strictly
    /// between batches. Tenant 0 is the pool-wide swap of old; other
    /// tenants rebuild through [`WorkerEngine::swap_tenant`], which
    /// touches only that tenant's prep. A failed swap keeps the old
    /// prep and counts a swap error.
    fn sync(&mut self, worker_id: usize, engine: &mut dyn WorkerEngine, metrics: &Metrics) {
        for id in 0..self.epochs.len() {
            if self.table.epoch(id) == self.epochs[id] {
                continue;
            }
            // re-read under the lock: the recipe a worker acts on is
            // always at least as new as the epoch it records
            let (epoch, recipe) = self.table.read(id);
            self.epochs[id] = epoch;
            self.recipes[id] = recipe.clone();
            if let Some(recipe) = recipe {
                let ctx = self.ctx(id);
                match engine.swap_tenant(&ctx, &recipe) {
                    Ok(()) => {
                        metrics.record_recipe_swap();
                        crate::debugln!(
                            "worker {worker_id}: tenant {} swapped to {}",
                            self.table.name(id),
                            recipe.label()
                        );
                    }
                    Err(e) => {
                        metrics.record_swap_error();
                        crate::warnln!(
                            "worker {worker_id}: tenant {} swap failed, keeping the old prep: {e:#}",
                            self.table.name(id)
                        );
                    }
                }
            }
        }
    }

    /// The per-tenant view engines receive. Tenant 0's recipe is always
    /// `None`: the default tenant serves the factory build (plus any
    /// pool-wide swap already applied through [`WorkerEngine::swap`]).
    fn ctx(&self, id: usize) -> backend::TenantCtx<'_> {
        backend::TenantCtx {
            id,
            name: self.table.name(id),
            recipe: if id == 0 {
                None
            } else {
                self.recipes[id].as_ref()
            },
        }
    }
}

/// One worker: build the engine on this thread, then batch-and-serve
/// until stopped (draining the queue first) or disconnected.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    id: usize,
    factory: Arc<dyn EngineFactory>,
    cfg: ServeConfig,
    rx: Receiver<Job>,
    metrics: Arc<Metrics>,
    pool: Arc<PoolMetrics>,
    outstanding: Arc<AtomicUsize>,
    stop: Arc<AtomicBool>,
    tenants: Arc<TenantTable>,
    ready: SyncSender<Result<()>>,
) {
    let mut engine = match factory.build(id) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    // the view starts from the table's construction-time recipes; a
    // swap published while this worker was still building is applied on
    // its first loop iteration, not missed
    let mut view = TenantView::new(tenants);
    loop {
        // apply any published recipe swaps strictly between batches, so
        // in-flight work always completes on the prep it started with
        view.sync(id, engine.as_mut(), &metrics);
        // wait for the first job of a batch; wake periodically to honour
        // the stop flag (and recipe swaps) even while clients keep the
        // channel open. Jobs still queued at stop are returned by
        // recv_timeout before it ever times out, so the queue fully
        // drains first.
        let first = match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(j) => j,
            Err(RecvTimeoutError::Timeout) => {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => break, // all clients gone
        };
        let mut jobs = vec![first];
        let top_up_until = Instant::now() + cfg.max_wait;
        while jobs.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= top_up_until {
                break;
            }
            match rx.recv_timeout(top_up_until - now) {
                Ok(j) => jobs.push(j),
                Err(_) => break,
            }
        }
        run_batch(engine.as_mut(), &view, jobs, &metrics, &pool, &outstanding);
    }
    // Final sweep: a dispatch that passed its stop check can still land
    // a job between our last empty recv and the channel teardown below;
    // answer it rather than dropping it with the queue.
    while let Ok(job) = rx.try_recv() {
        outstanding.fetch_sub(1, Ordering::Relaxed);
        let _ = job.resp.send(Err(anyhow!("server is shutting down")));
    }
    crate::debugln!("worker {id}: drained, exiting");
}

/// Answer expired jobs, partition the rest into single-tenant batches
/// (batches never mix recipes), execute each, respond to every job, and
/// keep the outstanding gauge exact.
fn run_batch(
    engine: &mut dyn WorkerEngine,
    view: &TenantView,
    jobs: Vec<Job>,
    metrics: &Metrics,
    pool: &PoolMetrics,
    outstanding: &AtomicUsize,
) {
    let now = Instant::now();
    let mut live = Vec::with_capacity(jobs.len());
    for job in jobs {
        match job.deadline {
            Some(d) if now >= d => {
                metrics.record_deadline_exceeded();
                pool.tenant(job.tenant).record_deadline_exceeded();
                let waited_ms = job.enqueued.elapsed().as_millis();
                let err = anyhow!("deadline exceeded after {waited_ms} ms in queue");
                // gauge drops before the send: the client unblocks on
                // the send, and must never observe a stale depth
                outstanding.fetch_sub(1, Ordering::Relaxed);
                let _ = job.resp.send(Err(err));
            }
            _ => live.push(job),
        }
    }
    if live.is_empty() {
        return;
    }
    // partition by tenant, order-stable; the single-tenant pool is one
    // group and pays nothing beyond this scan
    let mut groups: Vec<(usize, Vec<Job>)> = Vec::new();
    for job in live {
        match groups.iter_mut().find(|(t, _)| *t == job.tenant) {
            Some((_, g)) => g.push(job),
            None => {
                let t = job.tenant;
                groups.push((t, vec![job]));
            }
        }
    }
    for (tenant, group) in groups {
        run_tenant_batch(engine, view, tenant, group, metrics, pool, outstanding);
    }
}

/// Execute one single-tenant group as a fused forward pass.
fn run_tenant_batch(
    engine: &mut dyn WorkerEngine,
    view: &TenantView,
    tenant: usize,
    live: Vec<Job>,
    metrics: &Metrics,
    pool: &PoolMetrics,
    outstanding: &AtomicUsize,
) {
    let n = live.len();
    let result = (|| -> Result<TensorF> {
        for j in &live[1..] {
            if j.x.shape() != live[0].x.shape() {
                bail!(
                    "mixed input shapes in one batch: {:?} vs {:?}",
                    j.x.shape(),
                    live[0].x.shape()
                );
            }
        }
        let mut data = Vec::with_capacity(n * live[0].x.len());
        for j in &live {
            data.extend_from_slice(j.x.data());
        }
        let mut shape = live[0].x.shape().to_vec();
        shape[0] = n;
        let xb = TensorF::from_vec(&shape, data)?;
        let ctx = view.ctx(tenant);
        let t0 = Instant::now();
        let out = engine.infer_tenant(&ctx, &xb)?;
        metrics.record_batch(n, t0.elapsed().as_micros() as u64);
        Ok(out)
    })();
    match result {
        Ok(logits) => {
            let classes = logits.shape().get(1).copied().unwrap_or(0);
            for (row, job) in live.into_iter().enumerate() {
                let resp = if classes == 0 || (row + 1) * classes > logits.len() {
                    Err(anyhow!("engine returned too few logit rows"))
                } else {
                    Ok(logits.data()[row * classes..(row + 1) * classes].to_vec())
                };
                if resp.is_ok() {
                    let latency = job.enqueued.elapsed();
                    metrics.record_request(latency);
                    pool.tenant(tenant).record_request(latency);
                }
                outstanding.fetch_sub(1, Ordering::Relaxed);
                let _ = job.resp.send(resp);
            }
        }
        Err(e) => {
            metrics.record_exec_error();
            pool.tenant(tenant).record_exec_error();
            let msg = format!("{e:#}");
            for job in live {
                outstanding.fetch_sub(1, Ordering::Relaxed);
                let _ = job.resp.send(Err(anyhow!(msg.clone())));
            }
        }
    }
}

/// One worker-sweep measurement (a row of `BENCH_serving.json`).
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub workers: usize,
    pub requests: usize,
    pub ok: usize,
    pub errors: usize,
    pub secs: f64,
    pub rps: f64,
    pub mean_latency_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_batch: f64,
    pub rejected: u64,
    pub deadline_exceeded: u64,
}

/// Start a pool at `workers` shards, drive `requests` synthetic-image
/// requests through closed-loop clients, and collect the measurements.
pub fn run_point(
    factory: Arc<dyn EngineFactory>,
    cfg: &ServeConfig,
    workers: usize,
    requests: usize,
) -> Result<SweepPoint> {
    let server = Server::start_with(factory, cfg.clone().with_workers(workers))?;
    let dataset = crate::train::data::synth_images(256, 411);
    let row = dataset.x.len() / dataset.len();
    let mut req_shape = dataset.x.shape().to_vec();
    req_shape[0] = 1;
    let xdata = Arc::new(dataset.x.data().to_vec());
    let clients = (workers * 4).clamp(4, 32);
    let per = (requests / clients).max(1);
    let t0 = Instant::now();
    let mut client_threads = Vec::new();
    for c in 0..clients {
        let client = server.client();
        let xdata = xdata.clone();
        let shape = req_shape.clone();
        client_threads.push(std::thread::spawn(move || -> (usize, usize) {
            let mut ok = 0usize;
            let mut errors = 0usize;
            for i in 0..per {
                let idx = (c * per + i) % 256;
                let x = TensorF::from_vec(&shape, xdata[idx * row..(idx + 1) * row].to_vec());
                match x.map_err(anyhow::Error::from).and_then(|x| client.infer(x)) {
                    Ok(logits) if !logits.is_empty() => ok += 1,
                    _ => errors += 1,
                }
            }
            (ok, errors)
        }));
    }
    let mut ok = 0usize;
    let mut errors = 0usize;
    for h in client_threads {
        let (o, e) = h.join().map_err(|_| anyhow!("client thread panicked"))?;
        ok += o;
        errors += e;
    }
    let secs = t0.elapsed().as_secs_f64();
    let agg = server.metrics().aggregate();
    let point = SweepPoint {
        workers,
        requests: clients * per,
        ok,
        errors,
        secs,
        rps: ok as f64 / secs.max(1e-9),
        mean_latency_ms: agg.mean_latency_us() / 1e3,
        p50_ms: agg.latency_percentile_us(0.5) as f64 / 1e3,
        p99_ms: agg.latency_percentile_us(0.99) as f64 / 1e3,
        mean_batch: agg.mean_batch(),
        rejected: server.metrics().rejected_count(),
        deadline_exceeded: agg.deadline_exceeded,
    };
    println!("{}", server.metrics().report());
    server.shutdown()?;
    Ok(point)
}

/// Serialize sweep results as a versioned [`BenchRecord`] (`serving`
/// tag) — the format `ocs bench diff`/`check` read back; one row per
/// swept worker count with throughput as the gated metric.
///
/// [`BenchRecord`]: crate::bench_record::BenchRecord
pub fn sweep_json(backend_label: &str, points: &[SweepPoint]) -> String {
    crate::bench_record::BenchRecord::from_sweep(backend_label, points).to_json()
}

/// Drive a worker sweep over any backend; prints one line per point and
/// optionally writes `BENCH_serving.json`-style output.
pub fn self_test_with(
    factory: Arc<dyn EngineFactory>,
    cfg: &ServeConfig,
    requests: usize,
    sweep: &[usize],
    json_out: Option<&Path>,
) -> Result<Vec<SweepPoint>> {
    let sweep: Vec<usize> = if sweep.is_empty() {
        vec![cfg.workers]
    } else {
        sweep.to_vec()
    };
    let label = factory.label();
    let mut points = Vec::with_capacity(sweep.len());
    for &workers in &sweep {
        let p = run_point(factory.clone(), cfg, workers, requests)?;
        println!(
            "self-test[workers={workers}]: {}/{} ok in {:.2}s = {:.0} req/s \
             (p50 {:.2} ms, p99 {:.2} ms, mean batch {:.1})",
            p.ok, p.requests, p.secs, p.rps, p.p50_ms, p.p99_ms, p.mean_batch
        );
        points.push(p);
    }
    if let Some(path) = json_out {
        std::fs::write(path, sweep_json(&label, &points))
            .with_context(|| format!("write {}", path.display()))?;
        println!("wrote {}", path.display());
    }
    Ok(points)
}

/// End-to-end self-test over the real PJRT stack (used by `ocs serve`).
pub fn self_test(
    artifacts_dir: &str,
    model: &str,
    recipe: QuantRecipe,
    requests: usize,
    cfg: &ServeConfig,
    sweep: &[usize],
    json_out: Option<&Path>,
) -> Result<()> {
    let factory = Arc::new(PjrtFactory {
        artifacts_dir: artifacts_dir.to_string(),
        model: model.to_string(),
        recipe,
        max_batch: cfg.max_batch,
    });
    self_test_with(factory, cfg, requests, sweep, json_out).map(|_| ())
}

/// Self-test over the synthetic backend — no artifacts or PJRT needed
/// (this is what CI's serving smoke job runs).
pub fn self_test_sim(
    requests: usize,
    cfg: &ServeConfig,
    sweep: &[usize],
    json_out: Option<&Path>,
) -> Result<()> {
    let factory = Arc::new(SimFactory::default());
    self_test_with(factory, cfg, requests, sweep, json_out).map(|_| ())
}

/// One offered-load step of the closed-loop load test: `clients`
/// concurrent closed-loop client threads over the weighted tenant mix,
/// with latencies measured *client-side* (send → response, queueing
/// included) and percentiles taken over the merged exact samples — not
/// the pool's bucketed histogram.
#[derive(Debug, Clone)]
pub struct LoadPoint {
    pub clients: usize,
    pub requests: usize,
    pub ok: usize,
    pub errors: usize,
    pub secs: f64,
    pub rps: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub rejected: u64,
    pub deadline_exceeded: u64,
    /// Per-tenant `(name, requests served, rejected)` for this step.
    pub tenants: Vec<(String, u64, u64)>,
}

/// Ceil-rank percentile over an ascending sample (the convention
/// `bench_support` uses).
fn percentile_ms(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Deterministic weighted tenant pick for global request index `k`: a
/// golden-ratio low-discrepancy walk over the cumulative weights, so
/// every prefix of the request stream carries (approximately) the
/// configured mix and every run offers the identical schedule.
fn pick_tenant(table: &TenantTable, k: usize) -> usize {
    let total: f64 = (0..table.len()).map(|id| table.weight(id)).sum();
    let u = ((k as f64 + 1.0) * 0.618_033_988_749_895).fract() * total;
    let mut acc = 0.0;
    for id in 0..table.len() {
        acc += table.weight(id);
        if u < acc {
            return id;
        }
    }
    table.len() - 1
}

/// Run one offered-load step: start a fresh pool (fresh metrics, fixed
/// worker count from `cfg`), drive ~`requests` requests through
/// `clients` closed-loop threads over the weighted tenant mix, and
/// collect the measurements. Rejections and deadline misses count as
/// client errors — a closed-loop client immediately offers its next
/// request, which is what pushes the pool to saturation.
pub fn run_load_point(
    factory: Arc<dyn EngineFactory>,
    cfg: &ServeConfig,
    tenants: &[TenantInit],
    clients: usize,
    requests: usize,
) -> Result<LoadPoint> {
    if clients == 0 {
        bail!("loadtest: client counts must be >= 1");
    }
    let server = Server::start_tenants(factory, cfg.clone(), TenantTable::new(tenants)?)?;
    let dataset = crate::train::data::synth_images(256, 411);
    let row = dataset.x.len() / dataset.len();
    let mut req_shape = dataset.x.shape().to_vec();
    req_shape[0] = 1;
    let xdata = Arc::new(dataset.x.data().to_vec());
    let names = Arc::new(server.tenants().names());
    let per = (requests / clients).max(1);
    // the deterministic tenant schedule, one id per global request index
    let schedule: Arc<Vec<usize>> = Arc::new(
        (0..clients * per)
            .map(|k| pick_tenant(server.tenants(), k))
            .collect(),
    );
    let t0 = Instant::now();
    let mut threads = Vec::new();
    for c in 0..clients {
        let client = server.client();
        let xdata = xdata.clone();
        let shape = req_shape.clone();
        let names = names.clone();
        let schedule = schedule.clone();
        threads.push(std::thread::spawn(move || -> (usize, usize, Vec<f64>) {
            let mut ok = 0usize;
            let mut errors = 0usize;
            let mut lat = Vec::with_capacity(per);
            for i in 0..per {
                let k = c * per + i;
                let idx = k % 256;
                let tenant = names[schedule[k]].as_str();
                let x = TensorF::from_vec(&shape, xdata[idx * row..(idx + 1) * row].to_vec());
                let sent = Instant::now();
                match x
                    .map_err(anyhow::Error::from)
                    .and_then(|x| client.infer_tenant(tenant, x))
                {
                    Ok(logits) if !logits.is_empty() => {
                        ok += 1;
                        lat.push(sent.elapsed().as_secs_f64() * 1e3);
                    }
                    _ => errors += 1,
                }
            }
            (ok, errors, lat)
        }));
    }
    let mut ok = 0usize;
    let mut errors = 0usize;
    let mut lat: Vec<f64> = Vec::new();
    for h in threads {
        let (o, e, l) = h.join().map_err(|_| anyhow!("load client panicked"))?;
        ok += o;
        errors += e;
        lat.extend(l);
    }
    let secs = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.total_cmp(b));
    let mean_ms = if lat.is_empty() {
        0.0
    } else {
        lat.iter().sum::<f64>() / lat.len() as f64
    };
    let agg = server.metrics().aggregate();
    let point = LoadPoint {
        clients,
        requests: clients * per,
        ok,
        errors,
        secs,
        rps: ok as f64 / secs.max(1e-9),
        mean_ms,
        p50_ms: percentile_ms(&lat, 0.50),
        p95_ms: percentile_ms(&lat, 0.95),
        p99_ms: percentile_ms(&lat, 0.99),
        rejected: server.metrics().rejected_count(),
        deadline_exceeded: agg.deadline_exceeded,
        tenants: (0..server.tenants().len())
            .map(|id| {
                (
                    server.tenants().name(id).to_string(),
                    server.metrics().tenant(id).snapshot().requests,
                    server.metrics().tenant_rejected_count(id),
                )
            })
            .collect(),
    };
    println!("{}", server.metrics().report());
    server.shutdown()?;
    Ok(point)
}

/// The closed-loop load harness behind `ocs serve --loadtest`: sweep
/// offered load (client concurrency) at a fixed worker count over a
/// tenant mix, print one line per step, report the saturation point
/// (the step with peak throughput), and optionally write a versioned
/// `BENCH_loadtest.json` record for `ocs bench check`/`diff`.
pub fn loadtest(
    factory: Arc<dyn EngineFactory>,
    cfg: &ServeConfig,
    tenants: &[TenantInit],
    clients_sweep: &[usize],
    requests: usize,
    json_out: Option<&Path>,
) -> Result<Vec<LoadPoint>> {
    let sweep: Vec<usize> = if clients_sweep.is_empty() {
        vec![1, 2, 4, 8]
    } else {
        clients_sweep.to_vec()
    };
    let label = factory.label();
    let mut points = Vec::with_capacity(sweep.len());
    for &clients in &sweep {
        let p = run_load_point(factory.clone(), cfg, tenants, clients, requests)?;
        println!(
            "loadtest[clients={clients}]: {}/{} ok in {:.2}s = {:.0} req/s \
             (p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms, rejected {}, deadline-exceeded {})",
            p.ok,
            p.requests,
            p.secs,
            p.rps,
            p.p50_ms,
            p.p95_ms,
            p.p99_ms,
            p.rejected,
            p.deadline_exceeded
        );
        points.push(p);
    }
    if let Some(sat) = points.iter().max_by(|a, b| a.rps.total_cmp(&b.rps)) {
        println!(
            "loadtest: saturation ~{:.0} req/s at {} client(s) \
             ({} worker(s), {} tenant(s) in the mix)",
            sat.rps,
            sat.clients,
            cfg.workers,
            tenants.len() + 1
        );
    }
    if let Some(path) = json_out {
        crate::bench_record::BenchRecord::from_loadtest(&label, &points)
            .write(path)
            .with_context(|| format!("write {}", path.display()))?;
        println!("wrote {}", path.display());
    }
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn init(name: &str, weight: f64) -> TenantInit {
        TenantInit {
            name: name.into(),
            weight,
            recipe: None,
        }
    }

    #[test]
    fn tenant_table_basics() {
        let t = TenantTable::default_only();
        assert_eq!(t.len(), 1);
        assert!(!t.is_empty());
        assert_eq!(t.id_of("default"), Some(0));
        let t = TenantTable::new(&[init("gold", 1.0), init("bulk", 3.0)]).unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t.id_of("bulk"), Some(2));
        assert_eq!(t.id_of("nope"), None);
        assert_eq!(t.name(1), "gold");
        assert_eq!(t.weight(2), 3.0);
        assert!(TenantTable::new(&[init("default", 1.0)]).is_err(), "reserved name");
        assert!(TenantTable::new(&[init("a", 1.0), init("a", 1.0)]).is_err());
        assert!(TenantTable::new(&[init("", 1.0)]).is_err());
        assert!(TenantTable::new(&[init("a", 0.0)]).is_err());
        assert!(TenantTable::new(&[init("a", f64::NAN)]).is_err());
    }

    #[test]
    fn tenant_slots_publish_per_tenant() {
        let t = TenantTable::new(&[init("a", 1.0)]).unwrap();
        let (e, r) = t.read(1);
        assert_eq!(e, 0);
        assert!(r.is_none());
        t.publish(1, QuantRecipe::float());
        let (e, r) = t.read(1);
        assert_eq!(e, 1);
        assert!(r.is_some());
        assert_eq!(t.epoch(0), 0, "other slots stay untouched");
    }

    #[test]
    fn tenant_schedule_is_deterministic_and_proportional() {
        let t = TenantTable::new(&[init("gold", 1.0), init("bulk", 2.0)]).unwrap();
        // weights: default 1, gold 1, bulk 2 -> shares 25% / 25% / 50%
        let mut counts = [0usize; 3];
        for k in 0..1000 {
            let a = pick_tenant(&t, k);
            assert_eq!(a, pick_tenant(&t, k), "schedule must be deterministic");
            counts[a] += 1;
        }
        assert!((200..300).contains(&counts[0]), "{counts:?}");
        assert!((200..300).contains(&counts[1]), "{counts:?}");
        assert!((450..550).contains(&counts[2]), "{counts:?}");
    }

    #[test]
    fn percentile_ms_is_ceil_rank() {
        assert_eq!(percentile_ms(&[], 0.5), 0.0);
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_ms(&v, 0.0), 1.0);
        assert_eq!(percentile_ms(&v, 0.5), 2.0);
        assert_eq!(percentile_ms(&v, 0.95), 4.0);
        assert_eq!(percentile_ms(&v, 1.0), 4.0);
    }
}
