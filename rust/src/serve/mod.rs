//! Sharded multi-worker inference engine pool.
//!
//! The OCS paper's deployment story (§3.5) is that an OCS-quantized
//! model is a *plain* model — servable on commodity hardware with no
//! custom ops beyond channel duplication, which lives inside the AOT
//! artifact. This module proves it at pool scale.
//!
//! ## Shape
//!
//! ```text
//!             Client::infer ──┐
//!             Client::infer ──┤  least-outstanding-work dispatch,
//!             Client::infer ──┤  bounded queues, reject-not-block
//!                             ▼
//!                   ┌──── Router ────┐
//!              try_send          try_send
//!                   ▼                ▼
//!          [queue cap=Q]      [queue cap=Q]        ... × workers
//!            worker 0           worker 1
//!          Engine+pipeline    Engine+pipeline      (one per thread)
//!          dynamic batcher    dynamic batcher
//! ```
//!
//! PJRT handles are `!Send`, so scaling *cannot* share one engine across
//! threads: the only correct shape is shard-per-thread, each worker
//! owning its whole stack (engine, prepared pipeline, executable cache).
//! Workers build those stacks concurrently at startup; artifact text is
//! read once per process via [`crate::runtime::HloTextCache`], and the
//! prepared quantization pipeline once per distinct recipe via the
//! process-wide [`crate::pipeline::PreparedCache`] — worker 2..N share
//! worker 1's prep through an `Arc`.
//!
//! ## Admission control and deadlines
//!
//! Dispatch walks workers in ascending outstanding-work order and
//! `try_send`s into the first bounded queue with room. When every queue
//! is full the request is **rejected immediately** — clients get an
//! error, never a silent hang. A configured deadline
//! ([`ServeConfig::deadline`]) is checked when a job is pulled into a
//! batch: expired jobs are answered with an error instead of wasting a
//! forward pass.
//!
//! ## Recipe hot-swap
//!
//! [`Server::swap_recipe`] publishes a new [`QuantRecipe`] to every
//! worker without restarting the pool. Workers notice between batches
//! (or within one idle-poll tick, ~50 ms) and re-prepare through the
//! process-wide [`crate::pipeline::PreparedCache`] — so N workers
//! swapping to the same recipe still prepare once. In-flight and
//! already-batched requests drain on the old prep; a worker whose swap
//! fails keeps serving the old prep and counts a `swap_error`. Poll
//! [`Server::swaps_applied`] to observe roll-out across the pool.
//!
//! ## Tenants
//!
//! Requests may carry a tenant key ([`Client::infer_tenant`]). The
//! pool's [`TenantTable`] maps each name to a tenant id whose recipe
//! the engines serve: recipe-aware backends build one prep per tenant
//! (lazily, through the shared [`crate::pipeline::PreparedCache`]),
//! workers partition every pull into single-tenant batches, and every
//! tenant gets its own request/reject/deadline counters and latency
//! histogram in [`PoolMetrics`] alongside the pool aggregates. Unknown
//! tenant keys fall back to the default recipe (tenant 0, counted);
//! [`Server::swap_tenant_recipe`] hot-swaps one tenant without
//! disturbing the others.
//!
//! ## Fault tolerance
//!
//! A worker whose engine panics (build or infer) does not strand its
//! shard: the panic is contained with `catch_unwind`, every in-flight
//! and queued job is answered with an explicit error, and a
//! [`DeathEvent`] hands the shard's still-connected queue to the pool
//! supervisor. The supervisor respawns the worker (fresh engine via the
//! same [`EngineFactory`]; the prep comes back cheap through the shared
//! [`crate::pipeline::PreparedCache`]) with capped exponential backoff
//! ([`ServeConfig::backoff`]), re-applying every published recipe so
//! the replacement serves current policy. After
//! [`ServeConfig::restart_max`] respawns it gives up: the worker's
//! breaker opens ([`PoolMetrics::dead_workers`]), its queue drains as
//! errors, and the router stops dispatching to it. Per-worker
//! panic/restart/failed-job counters live in [`Metrics`]. Deterministic
//! failure schedules for testing all of this live in [`faults`].
//!
//! ## Per-tenant admission quotas
//!
//! With [`ServeConfig::tenant_quota`] set, each tenant's queued+
//! in-flight jobs are capped at that fraction of the pool's total
//! admission bound — a bulk tenant saturating its share is rejected
//! (counted per tenant) while its siblings' slots stay admittable.
//!
//! ## Shutdown
//!
//! [`Server::shutdown`] flips the stop flag: the router rejects new
//! work, each worker drains everything already queued (every admitted
//! job gets a response), then exits; `shutdown` joins them all (and the
//! supervisor).
//!
//! ## Load testing
//!
//! [`loadtest`] drives a *closed-loop* offered-load sweep over a tenant
//! mix: each step pins the worker count and raises the client
//! concurrency, clients measure their own end-to-end latencies, and the
//! sweep reports saturation throughput plus per-step latency
//! percentiles as a versioned `BENCH_loadtest.json` record
//! (`ocs serve --loadtest`).

pub mod backend;
pub mod breaker;
pub mod faults;
pub mod metrics;

use std::any::Any;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::pipeline::QuantRecipe;
use crate::tensor::TensorF;

use backend::{EngineFactory, PjrtFactory, SimFactory, WorkerEngine};
use breaker::{Admission, TenantBreaker};

pub use crate::pipeline::ServeConfig;
pub use metrics::{Metrics, PoolMetrics, Snapshot};

/// Initial description of one additional tenant for
/// [`TenantTable::new`]: its routing key, its share of the load-test
/// traffic mix, and (on recipe-carrying backends) its own
/// [`QuantRecipe`].
#[derive(Debug, Clone)]
pub struct TenantInit {
    pub name: String,
    pub weight: f64,
    pub recipe: Option<QuantRecipe>,
}

/// One tenant's slot: identity plus the published-recipe cell its
/// workers poll between batches. The epoch counter tells a worker
/// *that* something changed without holding the lock; the recipe
/// itself is read under it.
struct TenantSlot {
    name: String,
    weight: f64,
    epoch: AtomicU64,
    /// The tenant's *current* recipe. Tenant 0 keeps `None` until a
    /// pool-wide swap is published — the default tenant serves whatever
    /// the factory built.
    recipe: Mutex<Option<QuantRecipe>>,
}

/// The pool's tenant registry. Tenant 0 is always `default` — the
/// recipe the factory was built with, and the fallback for requests
/// naming an unknown tenant; additional tenants carry their own recipe
/// and a weight used by the load-test traffic mix. Each entry doubles
/// as a per-tenant hot-swap slot, so swapping one tenant never
/// disturbs the others.
pub struct TenantTable {
    slots: Vec<TenantSlot>,
}

impl TenantTable {
    /// The single-tenant table every non-tenant entry point uses.
    pub fn default_only() -> TenantTable {
        Self::new(&[]).expect("the empty tenant list is always valid")
    }

    /// `default` plus one slot per entry of `extra` (tenant ids follow
    /// the given order, starting at 1).
    pub fn new(extra: &[TenantInit]) -> Result<TenantTable> {
        let mut slots = vec![TenantSlot {
            name: "default".to_string(),
            weight: 1.0,
            epoch: AtomicU64::new(0),
            recipe: Mutex::new(None),
        }];
        for (i, t) in extra.iter().enumerate() {
            if t.name.is_empty() {
                bail!("tenant {i}: name must be non-empty");
            }
            if !(t.weight > 0.0 && t.weight.is_finite()) {
                bail!("tenant '{}': weight must be finite and > 0", t.name);
            }
            if slots.iter().any(|s| s.name == t.name) {
                bail!("duplicate tenant name '{}'", t.name);
            }
            slots.push(TenantSlot {
                name: t.name.clone(),
                weight: t.weight,
                epoch: AtomicU64::new(0),
                recipe: Mutex::new(t.recipe.clone()),
            });
        }
        Ok(TenantTable { slots })
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        false // tenant 0 always exists
    }

    pub fn name(&self, id: usize) -> &str {
        &self.slots[id].name
    }

    pub fn names(&self) -> Vec<String> {
        self.slots.iter().map(|s| s.name.clone()).collect()
    }

    pub fn weight(&self, id: usize) -> f64 {
        self.slots[id].weight
    }

    pub fn id_of(&self, name: &str) -> Option<usize> {
        self.slots.iter().position(|s| s.name == name)
    }

    /// Publish a new recipe to tenant `id`'s slot (the epoch bump
    /// happens under the lock, so a worker that sees the new epoch
    /// always reads at least this recipe).
    fn publish(&self, id: usize, recipe: QuantRecipe) {
        let slot = &self.slots[id];
        // poison-tolerant: a worker that panicked mid-publish can only
        // have left a fully written recipe or the old one, both valid —
        // swaps must keep working after a contained engine panic
        let mut guard = slot.recipe.lock().unwrap_or_else(|e| e.into_inner());
        *guard = Some(recipe);
        slot.epoch.fetch_add(1, Ordering::Release);
    }

    fn epoch(&self, id: usize) -> u64 {
        self.slots[id].epoch.load(Ordering::Acquire)
    }

    /// Consistent `(epoch, recipe)` snapshot, read under the lock.
    fn read(&self, id: usize) -> (u64, Option<QuantRecipe>) {
        let slot = &self.slots[id];
        let guard = slot.recipe.lock().unwrap_or_else(|e| e.into_inner());
        (slot.epoch.load(Ordering::Acquire), guard.clone())
    }
}

/// One queued inference request.
struct Job {
    /// (1, H, W, C) image.
    x: TensorF,
    /// Tenant id (index into the pool's [`TenantTable`]).
    tenant: usize,
    /// This job is a half-open circuit-breaker probe: its outcome is
    /// reported to the [`TenantBreaker`] when it is answered.
    probe: bool,
    enqueued: Instant,
    deadline: Option<Instant>,
    resp: SyncSender<Result<Vec<f32>>>,
}

/// One worker's intake, as seen by the router.
struct Shard {
    tx: SyncSender<Job>,
    /// Queued + in-flight gauge (shared with [`PoolMetrics`]).
    outstanding: Arc<AtomicUsize>,
    /// Breaker (shared with [`PoolMetrics`]): set when the supervisor
    /// gives up on this worker; the router stops dispatching to it.
    dead: Arc<AtomicBool>,
}

/// Shared dispatch state: admission control + shard selection.
struct Router {
    shards: Vec<Shard>,
    queue_cap: usize,
    deadline: Option<Duration>,
    /// Per-tenant cap on queued+in-flight jobs (from
    /// [`ServeConfig::tenant_quota`]); `None` = no quota.
    tenant_cap: Option<usize>,
    stop: Arc<AtomicBool>,
    metrics: Arc<PoolMetrics>,
    tenants: Arc<TenantTable>,
    /// Per-tenant circuit breaker (shared with every worker, which
    /// records the strikes).
    breaker: Arc<TenantBreaker>,
    /// Serve a quarantined tenant's requests on the default prep
    /// instead of rejecting them ([`ServeConfig::tenant_fallback`]).
    fallback: bool,
}

impl Router {
    /// Admit a request: pick the least-loaded live shard with queue
    /// room and hand back the response channel. Errors instead of
    /// blocking when the pool is stopping, the tenant is over quota, or
    /// every queue is full.
    fn dispatch(&self, x: TensorF, tenant: usize) -> Result<Receiver<Result<Vec<f32>>>> {
        if self.stop.load(Ordering::SeqCst) {
            bail!("server is shutting down");
        }
        // tenant breaker gate, before any gauge is touched: a
        // quarantined tenant is rejected (or rerouted to the default
        // prep under fallback) without occupying queue slots; a
        // half-open breaker re-admits exactly this request as the probe
        let mut tenant = tenant;
        let mut probe = false;
        match self.breaker.admit(tenant) {
            Admission::Admit => {}
            Admission::Probe => probe = true,
            Admission::Quarantined => {
                if self.fallback && tenant != 0 {
                    // metered as a fallback (not a rejection) on the
                    // quarantined tenant's shard, then executed — and
                    // quota-metered — as default-tenant traffic
                    self.metrics.record_tenant_quarantined(tenant, false);
                    tenant = 0;
                } else {
                    self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                    self.metrics.record_tenant_quarantined(tenant, true);
                    bail!(
                        "tenant '{}' quarantined: circuit breaker open after repeated failures",
                        self.tenants.name(tenant)
                    );
                }
            }
        }
        // per-tenant quota gate: increment-then-check, so two racing
        // submits can never both slip under the cap. The gauge is
        // always maintained (workers decrement it when answering);
        // only the cap check is conditional.
        let tenant_gauge = self.metrics.tenant_outstanding_gauge(tenant);
        let held = tenant_gauge.fetch_add(1, Ordering::Relaxed);
        if let Some(cap) = self.tenant_cap {
            if held >= cap {
                tenant_gauge.fetch_sub(1, Ordering::Relaxed);
                if probe {
                    // the probe never reached a worker; count it as a
                    // failed probe so the breaker can't leak a
                    // permanently-in-flight probe
                    self.breaker.resolve_probe(tenant, false);
                }
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                self.metrics.record_tenant_quota_rejected(tenant);
                bail!(
                    "tenant '{}' over admission quota ({held} outstanding, cap {cap})",
                    self.tenants.name(tenant)
                );
            }
        }
        let (tx, rx) = sync_channel(1);
        let now = Instant::now();
        let mut job = Job {
            x,
            tenant,
            probe,
            enqueued: now,
            deadline: self.deadline.map(|d| now + d),
            resp: tx,
        };
        // least-outstanding-work dispatch, allocation-free on the hot
        // path: start at the least-loaded live shard, walk the rest as
        // fallback when its queue is full
        let n = self.shards.len();
        let mut start = 0usize;
        let mut least = usize::MAX;
        let mut live = 0usize;
        for (i, shard) in self.shards.iter().enumerate() {
            if shard.dead.load(Ordering::SeqCst) {
                continue;
            }
            live += 1;
            let o = shard.outstanding.load(Ordering::Relaxed);
            if o < least {
                least = o;
                start = i;
            }
        }
        if live == 0 {
            tenant_gauge.fetch_sub(1, Ordering::Relaxed);
            if job.probe {
                self.breaker.resolve_probe(job.tenant, false);
            }
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            self.metrics.record_tenant_rejected(tenant);
            bail!(
                "no live workers: all {} worker(s) gave up after repeated failures",
                self.shards.len()
            );
        }
        for offset in 0..n {
            let i = (start + offset) % n;
            let shard = &self.shards[i];
            if shard.dead.load(Ordering::SeqCst) {
                continue;
            }
            // count before send: the worker may answer (and decrement)
            // before try_send even returns
            shard.outstanding.fetch_add(1, Ordering::Relaxed);
            match shard.tx.try_send(job) {
                Ok(()) => {
                    self.metrics.dispatched.fetch_add(1, Ordering::Relaxed);
                    return Ok(rx);
                }
                // Disconnected = the supervisor dropped a dead worker's
                // queue (or shutdown teardown won a race): fall through
                // to the next shard — a clean rejection, never an unwrap
                Err(TrySendError::Full(j)) | Err(TrySendError::Disconnected(j)) => {
                    shard.outstanding.fetch_sub(1, Ordering::Relaxed);
                    job = j;
                }
            }
        }
        tenant_gauge.fetch_sub(1, Ordering::Relaxed);
        if job.probe {
            self.breaker.resolve_probe(job.tenant, false);
        }
        self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
        self.metrics.record_tenant_rejected(tenant);
        bail!(
            "server overloaded: all {} worker queues full (cap {} each)",
            self.shards.len(),
            self.queue_cap
        )
    }
}

/// Answer one job and keep every gauge exact: the worker/tenant
/// outstanding gauges drop *before* the send, so a client unblocked by
/// the response never observes a stale depth. Every terminal path —
/// success, engine error, contained panic, deadline expiry, dead-shard
/// drain, shutdown sweep — funnels through here, which is also what
/// guarantees a half-open probe is always resolved exactly once.
fn answer_job(ctx: &WorkerCtx, job: Job, result: Result<Vec<f32>>) {
    ctx.outstanding.fetch_sub(1, Ordering::Relaxed);
    ctx.pool.tenant_outstanding_gauge(job.tenant).fetch_sub(1, Ordering::Relaxed);
    if job.probe {
        ctx.breaker.resolve_probe(job.tenant, result.is_ok());
    }
    let _ = job.resp.send(result);
}

/// Client handle (cheaply cloneable, shareable across threads).
#[derive(Clone)]
pub struct Client {
    router: Arc<Router>,
    metrics: Arc<PoolMetrics>,
}

impl Client {
    /// Synchronous single-image inference as the default tenant;
    /// returns the logits row.
    pub fn infer(&self, x: TensorF) -> Result<Vec<f32>> {
        self.infer_id(0, x)
    }

    /// Tenant-keyed inference: the request is metered, admission-
    /// controlled, and executed under `tenant`'s recipe. A name the
    /// pool does not know falls back to the default tenant's recipe
    /// (counted in [`PoolMetrics::unknown_tenant`]) — clients are never
    /// rejected for a typo'd key, they just get the default policy.
    pub fn infer_tenant(&self, tenant: &str, x: TensorF) -> Result<Vec<f32>> {
        let id = match self.router.tenants.id_of(tenant) {
            Some(id) => id,
            None => {
                self.metrics.record_unknown_tenant();
                0
            }
        };
        self.infer_id(id, x)
    }

    fn infer_id(&self, tenant: usize, x: TensorF) -> Result<Vec<f32>> {
        let rx = self.router.dispatch(x, tenant)?;
        rx.recv().context("server dropped the request")?
    }

    /// Resolve a tenant name (`None` = unknown, would fall back).
    pub fn tenant_id(&self, tenant: &str) -> Option<usize> {
        self.router.tenants.id_of(tenant)
    }

    pub fn metrics(&self) -> &PoolMetrics {
        &self.metrics
    }
}

/// A worker's death notice to the supervisor. The shard's queue
/// receiver rides along, still connected, so jobs admitted while the
/// worker is down wait (bounded by `queue_cap`, still deadline-checked)
/// for the replacement instead of being dropped.
struct DeathEvent {
    id: usize,
    rx: Receiver<Job>,
    reason: String,
}

/// Everything one worker thread needs, cloneable so the supervisor can
/// stamp out replacement workers from the same context.
#[derive(Clone)]
struct WorkerCtx {
    id: usize,
    factory: Arc<dyn EngineFactory>,
    cfg: ServeConfig,
    /// This worker's own metrics shard.
    metrics: Arc<Metrics>,
    pool: Arc<PoolMetrics>,
    outstanding: Arc<AtomicUsize>,
    stop: Arc<AtomicBool>,
    tenants: Arc<TenantTable>,
    breaker: Arc<TenantBreaker>,
    sup_tx: SyncSender<DeathEvent>,
}

/// Running pool: N worker threads + supervisor + router + client
/// factory. Worker handles live behind a shared mutex so the
/// supervisor can join dead workers and install their replacements.
pub struct Server {
    router: Arc<Router>,
    handles: Arc<Mutex<Vec<Option<JoinHandle<()>>>>>,
    supervisor: Option<JoinHandle<()>>,
    metrics: Arc<PoolMetrics>,
    stop: Arc<AtomicBool>,
    tenants: Arc<TenantTable>,
}

impl Server {
    /// Production entry point: PJRT engines over the AOT artifacts.
    /// `recipe` may be uniform (`QuantConfig::to_recipe()`) or carry
    /// per-layer overrides.
    pub fn start(
        artifacts_dir: &str,
        model: &str,
        recipe: QuantRecipe,
        cfg: ServeConfig,
    ) -> Result<Server> {
        let factory = Arc::new(PjrtFactory {
            artifacts_dir: artifacts_dir.to_string(),
            model: model.to_string(),
            recipe,
            max_batch: cfg.max_batch,
        });
        Server::start_with(factory, cfg)
    }

    /// Start the pool over any backend (tests/CI use [`SimFactory`])
    /// with the single implicit `default` tenant.
    pub fn start_with(factory: Arc<dyn EngineFactory>, cfg: ServeConfig) -> Result<Server> {
        Self::start_tenants(factory, cfg, TenantTable::default_only())
    }

    /// Start the pool with a tenant table: requests carry a tenant key,
    /// each tenant serves its own recipe (on recipe-aware backends) and
    /// is metered separately, and per-tenant hot-swap never disturbs
    /// the other tenants.
    ///
    /// All workers build their engines concurrently; startup fails as a
    /// whole (with every thread joined) if any worker fails to come up.
    pub fn start_tenants(
        factory: Arc<dyn EngineFactory>,
        cfg: ServeConfig,
        tenants: TenantTable,
    ) -> Result<Server> {
        cfg.validate()?;
        let tenants = Arc::new(tenants);
        let metrics = Arc::new(PoolMetrics::with_tenants(cfg.workers, tenants.names()));
        let stop = Arc::new(AtomicBool::new(false));
        // Strikes decay over 8× the quarantine window: long enough that
        // a genuine crash loop trips the breaker across respawn
        // backoffs, short enough that a rare sporadic fault never
        // accumulates into a quarantine.
        let breaker = Arc::new(TenantBreaker::new(
            tenants.len(),
            cfg.tenant_restart_max,
            cfg.quarantine.saturating_mul(8),
            cfg.quarantine,
        ));
        // Buffered to hold one death notice per worker so a dying
        // worker never blocks on its own obituary.
        let (sup_tx, sup_rx) = sync_channel::<DeathEvent>(cfg.workers.max(1));
        let mut shards = Vec::with_capacity(cfg.workers);
        let mut handle_slots = Vec::with_capacity(cfg.workers);
        let mut readies = Vec::with_capacity(cfg.workers);
        let mut ctxs = Vec::with_capacity(cfg.workers);
        for id in 0..cfg.workers {
            let (tx, rx) = sync_channel::<Job>(cfg.queue_cap);
            let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);
            let outstanding = metrics.outstanding_handle(id);
            let ctx = WorkerCtx {
                id,
                factory: factory.clone(),
                cfg: cfg.clone(),
                metrics: metrics.worker(id).clone(),
                pool: metrics.clone(),
                outstanding: outstanding.clone(),
                stop: stop.clone(),
                tenants: tenants.clone(),
                breaker: breaker.clone(),
                sup_tx: sup_tx.clone(),
            };
            let handle = spawn_worker(ctx.clone(), rx, Some(ready_tx))?;
            shards.push(Shard {
                tx,
                outstanding,
                dead: metrics.dead_handle(id),
            });
            handle_slots.push(Some(handle));
            readies.push(ready_rx);
            ctxs.push(ctx);
        }
        drop(sup_tx); // supervisor's receiver is fed only by worker clones
        // readiness gate: surface any worker's setup error to the caller
        let mut first_err: Option<anyhow::Error> = None;
        for (id, ready) in readies.into_iter().enumerate() {
            let status = match ready.recv() {
                Ok(Ok(())) => Ok(()),
                Ok(Err(e)) => Err(e.context(format!("worker {id} setup"))),
                Err(_) => Err(anyhow!("worker {id} died during startup")),
            };
            if let Err(e) = status {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
        if let Some(e) = first_err {
            stop.store(true, Ordering::SeqCst);
            drop(shards); // disconnect every queue
            drop(sup_rx); // no supervisor was spawned; nothing to respawn
            for h in handle_slots.into_iter().flatten() {
                let _ = h.join();
            }
            return Err(e);
        }
        crate::info!(
            "engine pool up: {} × {} (queue cap {}/worker, max batch {}, deadline {:?}{})",
            cfg.workers,
            factory.label(),
            cfg.queue_cap,
            cfg.max_batch,
            cfg.deadline,
            if tenants.len() > 1 {
                format!(", tenants {:?}", tenants.names())
            } else {
                String::new()
            }
        );
        // A tenant's admission cap is its share of the pool's total
        // queue slots, rounded up, never below one slot.
        let tenant_cap = cfg.tenant_quota.map(|q| {
            let slots = (cfg.workers * cfg.queue_cap) as f64;
            ((slots * q).ceil() as usize).max(1)
        });
        let router = Arc::new(Router {
            shards,
            queue_cap: cfg.queue_cap,
            deadline: cfg.deadline,
            tenant_cap,
            stop: stop.clone(),
            metrics: metrics.clone(),
            tenants: tenants.clone(),
            breaker,
            fallback: cfg.tenant_fallback,
        });
        let handles = Arc::new(Mutex::new(handle_slots));
        let supervisor = {
            let handles = handles.clone();
            let stop = stop.clone();
            Some(
                std::thread::Builder::new()
                    .name("ocs-supervisor".into())
                    .spawn(move || supervisor_loop(sup_rx, ctxs, handles, stop))
                    .context("spawn supervisor thread")?,
            )
        };
        Ok(Server {
            router,
            handles,
            supervisor,
            metrics,
            stop,
            tenants,
        })
    }

    /// Publish a new quantization recipe to every worker without
    /// restarting the pool. Workers apply it between batches (idle
    /// workers within one poll tick); requests already admitted or in
    /// flight drain on the old prep. Re-preparation goes through the
    /// process-wide [`crate::pipeline::PreparedCache`], so the pool
    /// pays one prepare per distinct recipe. A worker whose backend
    /// rejects the swap (or whose re-prepare fails) keeps serving the
    /// old prep and records a swap error.
    ///
    /// Returns immediately; poll [`Server::swaps_applied`] (against
    /// [`Server::worker_count`]) to observe the roll-out.
    ///
    /// Every distinct recipe ever served stays in the prepared-model
    /// cache (that is what makes swap-back instant); an operator cycling
    /// through many recipes on a long-lived process can reclaim the
    /// memory with [`crate::pipeline::PreparedCache::clear`] — in-flight
    /// preps stay alive through their `Arc`s.
    pub fn swap_recipe(&self, recipe: QuantRecipe) {
        crate::info!("publishing recipe swap: {}", recipe.label());
        self.tenants.publish(0, recipe);
    }

    /// Publish a new recipe to *one* tenant's slot. Workers rebuild
    /// exactly that tenant's prep (between batches, lazily for workers
    /// that never served it); every other tenant keeps serving its
    /// current prep undisturbed. Unknown tenant names are an error —
    /// unlike request routing, a swap has no sensible fallback.
    pub fn swap_tenant_recipe(&self, tenant: &str, recipe: QuantRecipe) -> Result<()> {
        let id = self
            .tenants
            .id_of(tenant)
            .with_context(|| format!("unknown tenant '{tenant}'"))?;
        crate::info!("publishing recipe swap for tenant {tenant}: {}", recipe.label());
        self.tenants.publish(id, recipe);
        Ok(())
    }

    /// The pool's tenant registry.
    pub fn tenants(&self) -> &TenantTable {
        &self.tenants
    }

    /// Total recipe swaps applied across all workers (each successful
    /// [`Server::swap_recipe`] roll-out adds `worker_count()`).
    pub fn swaps_applied(&self) -> u64 {
        self.metrics.aggregate().recipe_swaps
    }

    pub fn client(&self) -> Client {
        Client {
            router: self.router.clone(),
            metrics: self.metrics.clone(),
        }
    }

    pub fn metrics(&self) -> &PoolMetrics {
        &self.metrics
    }

    pub fn worker_count(&self) -> usize {
        self.metrics.worker_count()
    }

    /// Graceful shutdown: reject new work, drain every admitted job,
    /// join all workers. Safe while `Client` handles are still alive —
    /// workers watch the stop flag, not just channel disconnection.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop.store(true, Ordering::SeqCst);
        // Supervisor first: it drains the queues of any worker that died
        // right at shutdown, then stops touching the handle slots.
        if let Some(s) = self.supervisor.take() {
            let _ = s.join();
        }
        let mut panicked = 0usize;
        let mut handles = self.handles.lock().unwrap_or_else(|e| e.into_inner());
        for slot in handles.iter_mut() {
            if let Some(h) = slot.take() {
                if h.join().is_err() {
                    panicked += 1;
                }
            }
        }
        drop(handles);
        if panicked > 0 {
            // Contained panics exit the thread cleanly; a join error here
            // means a panic escaped containment entirely.
            bail!("{panicked} worker(s) panicked");
        }
        Ok(())
    }

    /// Workers the supervisor has given up on (breaker open).
    pub fn dead_workers(&self) -> usize {
        self.metrics.dead_workers()
    }

    /// The pool's per-tenant circuit breaker (observability/drills).
    pub fn tenant_breaker(&self) -> &TenantBreaker {
        &self.router.breaker
    }

    /// Whether `tenant`'s circuit breaker is currently open (unknown
    /// names are never quarantined — they route to the default tenant).
    pub fn tenant_quarantined(&self, tenant: &str) -> bool {
        match self.tenants.id_of(tenant) {
            Some(id) => self.router.breaker.is_open(id),
            None => false,
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(s) = self.supervisor.take() {
            let _ = s.join();
        }
        let mut handles = self.handles.lock().unwrap_or_else(|e| e.into_inner());
        for slot in handles.iter_mut() {
            if let Some(h) = slot.take() {
                let _ = h.join();
            }
        }
    }
}

/// Spawn one worker thread (startup passes a readiness channel; the
/// supervisor's respawns pass `None` and learn of failures via
/// [`DeathEvent`]s instead).
fn spawn_worker(
    ctx: WorkerCtx,
    rx: Receiver<Job>,
    ready: Option<SyncSender<Result<()>>>,
) -> Result<JoinHandle<()>> {
    let id = ctx.id;
    std::thread::Builder::new()
        .name(format!("ocs-worker-{id}"))
        .spawn(move || worker_loop(ctx, rx, ready))
        .context("spawn worker thread")
}

/// Best-effort panic payload → string. Payloads are `&str` or `String`
/// in practice; anything else gets a generic tag.
fn panic_msg(p: &(dyn Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Fail (or shutdown-answer) every job still sitting in a dead worker's
/// queue. `count_failed` distinguishes fault collateral (counted in
/// `jobs_failed`) from ordinary shutdown drains.
fn drain_queue(ctx: &WorkerCtx, rx: &Receiver<Job>, msg: &str, count_failed: bool) {
    while let Ok(job) = rx.try_recv() {
        if count_failed {
            ctx.metrics.record_job_failed();
        }
        let err = anyhow!(msg.to_string());
        answer_job(ctx, job, Err(err));
    }
}

/// Supervisor: joins dead workers, respawns them with capped
/// exponential backoff, and opens the per-worker breaker once
/// `restart_max` respawns have been burned. Holding `ctxs` (each with a
/// `sup_tx` clone) keeps the death channel connected for its lifetime.
fn supervisor_loop(
    sup_rx: Receiver<DeathEvent>,
    ctxs: Vec<WorkerCtx>,
    handles: Arc<Mutex<Vec<Option<JoinHandle<()>>>>>,
    stop: Arc<AtomicBool>,
) {
    let mut restarts = vec![0u32; ctxs.len()];
    loop {
        match sup_rx.recv_timeout(Duration::from_millis(50)) {
            Ok(ev) => handle_death(ev, &ctxs, &handles, &stop, &mut restarts),
            Err(RecvTimeoutError::Timeout) => {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
            }
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    // Shutdown sweep: a death notice still queued carries a shard queue
    // whose jobs must be answered before the channel tears down.
    while let Ok(ev) = sup_rx.try_recv() {
        if let Some(h) = handles.lock().unwrap_or_else(|e| e.into_inner())[ev.id].take() {
            let _ = h.join();
        }
        drain_queue(&ctxs[ev.id], &ev.rx, "server is shutting down", false);
    }
}

fn handle_death(
    ev: DeathEvent,
    ctxs: &[WorkerCtx],
    handles: &Mutex<Vec<Option<JoinHandle<()>>>>,
    stop: &AtomicBool,
    restarts: &mut [u32],
) {
    let id = ev.id;
    let ctx = &ctxs[id];
    let (restart_max, backoff) = (ctx.cfg.restart_max, ctx.cfg.backoff);
    // The dying thread sent this notice on its way out; join it so the
    // slot is free for the replacement.
    if let Some(h) = handles.lock().unwrap_or_else(|e| e.into_inner())[id].take() {
        let _ = h.join();
    }
    if stop.load(Ordering::SeqCst) {
        drain_queue(ctx, &ev.rx, "server is shutting down", false);
        return;
    }
    if restarts[id] >= restart_max {
        // Give up: open the breaker, fail everything still queued, drop
        // the queue so the router sees a dead shard from here on.
        ctx.pool.dead_handle(id).store(true, Ordering::SeqCst);
        crate::warnln!(
            "worker {id}: giving up after {} restart(s) ({}); breaker open",
            restarts[id],
            ev.reason
        );
        let msg = format!(
            "worker {id} is dead (gave up after {} restart(s))",
            restarts[id]
        );
        drain_queue(ctx, &ev.rx, &msg, true);
        return;
    }
    restarts[id] += 1;
    ctx.metrics.record_restart();
    let delay = respawn_delay(backoff, id, restarts[id]);
    crate::warnln!(
        "worker {id} died ({}); respawn {}/{restart_max} in {delay:?}",
        ev.reason,
        restarts[id]
    );
    let t0 = Instant::now();
    while t0.elapsed() < delay {
        if stop.load(Ordering::SeqCst) {
            drain_queue(ctx, &ev.rx, "server is shutting down", false);
            return;
        }
        let left = delay.saturating_sub(t0.elapsed());
        std::thread::sleep(left.min(Duration::from_millis(10)));
    }
    match spawn_worker(ctx.clone(), ev.rx, None) {
        Ok(h) => handles.lock().unwrap_or_else(|e| e.into_inner())[id] = Some(h),
        Err(e) => {
            // The OS refused the thread itself; the queue receiver died
            // with the failed spawn, so open the breaker — the router
            // turns the disconnect into clean rejections either way.
            ctx.pool.dead_handle(id).store(true, Ordering::SeqCst);
            crate::warnln!("worker {id}: respawn failed ({e:#}); breaker open");
        }
    }
}

/// splitmix64 finalizer: a full-avalanche integer mix, used to derive
/// deterministic respawn jitter without any RNG state.
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Respawn delay for attempt `n` (1-based) of worker `id`: capped
/// exponential backoff (base × 2^(n-1), capped at 64×) scaled by a
/// deterministic ±25% jitter seeded from `(id, n)`. Without the jitter,
/// workers killed by the same fault (a multi-worker kill, a poisoned
/// pool-wide swap) respawn in lockstep and slam the factory — and any
/// shared cache behind it — at the exact same instant, every attempt.
fn respawn_delay(backoff: Duration, id: usize, attempt: u32) -> Duration {
    let exp = backoff.saturating_mul(1u32 << (attempt.saturating_sub(1)).min(6));
    let h = splitmix64(((id as u64) << 32) ^ u64::from(attempt));
    let factor = 0.75 + (h % 1024) as f64 / 1024.0 * 0.5;
    exp.mul_f64(factor)
}

/// Worker-local tenant state: last-seen epoch and a local clone of the
/// current recipe per tenant, so the batch hot path builds
/// [`TenantCtx`]s without ever touching the table's locks.
struct TenantView {
    table: Arc<TenantTable>,
    epochs: Vec<u64>,
    recipes: Vec<Option<QuantRecipe>>,
}

impl TenantView {
    /// Snapshot the table's construction-time recipes. Epochs are read
    /// *before* the recipes (under each slot's lock), so a swap racing
    /// this snapshot is re-applied by the first [`TenantView::sync`] —
    /// possibly redundantly, never missed.
    fn new(table: Arc<TenantTable>) -> TenantView {
        let mut epochs = Vec::with_capacity(table.len());
        let mut recipes = Vec::with_capacity(table.len());
        for id in 0..table.len() {
            let (epoch, recipe) = table.read(id);
            epochs.push(epoch);
            recipes.push(recipe);
        }
        TenantView {
            table,
            epochs,
            recipes,
        }
    }

    /// Apply every recipe published since the last sync, strictly
    /// between batches. Tenant 0 is the pool-wide swap of old; other
    /// tenants rebuild through [`WorkerEngine::swap_tenant`], which
    /// touches only that tenant's prep. The swap is transactional per
    /// worker: a failed swap keeps the old prep and counts a swap
    /// error, and a *panicking* swap is contained right here — the view
    /// rolls back to the previous recipe clone (the engine never
    /// installed the new prep) and counts a swap abort, instead of
    /// killing the worker or leaving it serving a half-applied prep.
    fn sync(&mut self, ctx: &WorkerCtx, engine: &mut dyn WorkerEngine) {
        let worker_id = ctx.id;
        for id in 0..self.epochs.len() {
            if self.table.epoch(id) == self.epochs[id] {
                continue;
            }
            // re-read under the lock: the recipe a worker acts on is
            // always at least as new as the epoch it records
            let (epoch, recipe) = self.table.read(id);
            let prev = std::mem::replace(&mut self.recipes[id], recipe.clone());
            self.epochs[id] = epoch;
            if let Some(recipe) = recipe {
                let tctx = self.ctx(id);
                match catch_unwind(AssertUnwindSafe(|| engine.swap_tenant(&tctx, &recipe))) {
                    Ok(Ok(())) => {
                        ctx.metrics.record_recipe_swap();
                        crate::debugln!(
                            "worker {worker_id}: tenant {} swapped to {}",
                            self.table.name(id),
                            recipe.label()
                        );
                    }
                    Ok(Err(e)) => {
                        ctx.metrics.record_swap_error();
                        crate::warnln!(
                            "worker {worker_id}: tenant {} swap failed, keeping the old prep: {e:#}",
                            self.table.name(id)
                        );
                    }
                    Err(p) => {
                        // Roll this worker back to the previous recipe
                        // (engines install the new prep only as their
                        // last step, so the old executable is intact)
                        // but KEEP the new epoch: retrying the same
                        // panicking recipe every sync would be a crash
                        // loop in slow motion. The abort also strikes
                        // the tenant — a recipe that panics the swap on
                        // every worker quarantines itself.
                        self.recipes[id] = prev;
                        ctx.metrics.record_panic();
                        ctx.metrics.record_swap_abort();
                        if ctx.breaker.record_strike(id) {
                            eprintln!(
                                "serve: tenant '{}' quarantined after repeated contained failures",
                                self.table.name(id)
                            );
                        }
                        crate::warnln!(
                            "worker {worker_id}: tenant {} swap panicked (contained: {}); \
                             rolled back to the previous prep",
                            self.table.name(id),
                            panic_msg(p.as_ref())
                        );
                    }
                }
            }
        }
    }

    /// Force the next [`TenantView::sync`] to re-examine every slot.
    /// Used after a respawn: a fresh engine serves factory state, not
    /// the swaps its predecessor applied, so every *published* recipe
    /// must be re-applied (never-published slots stay untouched —
    /// `sync` only acts on `Some` recipes).
    fn mark_all_stale(&mut self) {
        for e in &mut self.epochs {
            *e = e.wrapping_sub(1);
        }
    }

    /// The per-tenant view engines receive. Tenant 0's recipe is always
    /// `None`: the default tenant serves the factory build (plus any
    /// pool-wide swap already applied through [`WorkerEngine::swap`]).
    fn ctx(&self, id: usize) -> backend::TenantCtx<'_> {
        backend::TenantCtx {
            id,
            name: self.table.name(id),
            recipe: if id == 0 {
                None
            } else {
                self.recipes[id].as_ref()
            },
        }
    }
}

/// Contained-death exit path: fail everything already queued (the
/// fault's collateral), then hand the still-connected queue to the
/// supervisor as a [`DeathEvent`].
fn die(ctx: WorkerCtx, rx: Receiver<Job>, reason: String) {
    let id = ctx.id;
    crate::warnln!("worker {id}: {reason}");
    let msg = format!("worker {id} died: {reason}; queued job failed");
    drain_queue(&ctx, &rx, &msg, true);
    let _ = ctx.sup_tx.send(DeathEvent { id, rx, reason });
}

/// One worker: build the engine on this thread, then batch-and-serve
/// until stopped (draining the queue first) or disconnected. Engine
/// build and every batch run under `catch_unwind`: a panicking engine
/// kills this worker *cleanly* — queued jobs answered, supervisor
/// notified — never the process, and never a hung client.
fn worker_loop(ctx: WorkerCtx, rx: Receiver<Job>, ready: Option<SyncSender<Result<()>>>) {
    let respawn = ready.is_none();
    let id = ctx.id;
    // At startup a build failure feeds the readiness gate (the pool
    // fails as a whole); on respawn it becomes another death event for
    // the supervisor to back off on.
    let mut engine = match catch_unwind(AssertUnwindSafe(|| ctx.factory.build(id))) {
        Ok(Ok(e)) => {
            if let Some(r) = &ready {
                let _ = r.send(Ok(()));
            }
            e
        }
        Ok(Err(e)) => {
            match &ready {
                Some(r) => {
                    let _ = r.send(Err(e));
                }
                None => die(ctx, rx, format!("engine rebuild failed: {e:#}")),
            }
            return;
        }
        Err(p) => {
            ctx.metrics.record_panic();
            let reason = format!("engine build panicked: {}", panic_msg(p.as_ref()));
            match &ready {
                Some(r) => {
                    let _ = r.send(Err(anyhow!(reason.clone())));
                }
                None => die(ctx, rx, reason),
            }
            return;
        }
    };
    // the view starts from the table's construction-time recipes; a
    // swap published while this worker was still building is applied on
    // its first loop iteration, not missed
    let mut view = TenantView::new(ctx.tenants.clone());
    if respawn {
        view.mark_all_stale();
    }
    loop {
        // apply any published recipe swaps strictly between batches, so
        // in-flight work always completes on the prep it started with.
        // Per-tenant swap panics are contained (and rolled back) inside
        // sync itself; this outer guard is the last resort for a panic
        // in the sync machinery proper, which still kills the worker.
        if let Err(p) = catch_unwind(AssertUnwindSafe(|| view.sync(&ctx, engine.as_mut()))) {
            ctx.metrics.record_panic();
            let reason = format!("recipe swap panicked: {}", panic_msg(p.as_ref()));
            die(ctx, rx, reason);
            return;
        }
        // wait for the first job of a batch; wake periodically to honour
        // the stop flag (and recipe swaps) even while clients keep the
        // channel open. Jobs still queued at stop are returned by
        // recv_timeout before it ever times out, so the queue fully
        // drains first.
        let first = match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(j) => j,
            Err(RecvTimeoutError::Timeout) => {
                if ctx.stop.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => break, // all clients gone
        };
        let mut jobs = vec![first];
        let top_up_until = Instant::now() + ctx.cfg.max_wait;
        while jobs.len() < ctx.cfg.max_batch {
            let now = Instant::now();
            if now >= top_up_until {
                break;
            }
            match rx.recv_timeout(top_up_until - now) {
                Ok(j) => jobs.push(j),
                Err(_) => break,
            }
        }
        match run_batch(engine.as_mut(), &view, jobs, &ctx) {
            BatchOutcome::Ok => {}
            BatchOutcome::Panicked(reason) => {
                die(ctx, rx, reason);
                return;
            }
        }
    }
    // Final sweep: a dispatch that passed its stop check can still land
    // a job between our last empty recv and the channel teardown below;
    // answer it rather than dropping it with the queue.
    while let Ok(job) = rx.try_recv() {
        answer_job(&ctx, job, Err(anyhow!("server is shutting down")));
    }
    crate::debugln!("worker {id}: drained, exiting");
}

/// How a batch ended: normally (including engine *errors*, which are
/// answered and survivable) or with a contained panic that must kill
/// the worker.
enum BatchOutcome {
    Ok,
    Panicked(String),
}

/// Answer expired jobs, partition the rest into single-tenant batches
/// (batches never mix recipes), execute each, respond to every job, and
/// keep the outstanding gauge exact.
fn run_batch(
    engine: &mut dyn WorkerEngine,
    view: &TenantView,
    jobs: Vec<Job>,
    ctx: &WorkerCtx,
) -> BatchOutcome {
    let now = Instant::now();
    let mut live = Vec::with_capacity(jobs.len());
    for job in jobs {
        match job.deadline {
            Some(d) if now >= d => {
                ctx.metrics.record_deadline_exceeded();
                ctx.pool.tenant(job.tenant).record_deadline_exceeded();
                let waited_ms = job.enqueued.elapsed().as_millis();
                let err = anyhow!("deadline exceeded after {waited_ms} ms in queue");
                answer_job(ctx, job, Err(err));
            }
            _ => live.push(job),
        }
    }
    if live.is_empty() {
        return BatchOutcome::Ok;
    }
    // partition by tenant, order-stable; the single-tenant pool is one
    // group and pays nothing beyond this scan
    let mut groups: Vec<(usize, Vec<Job>)> = Vec::new();
    for job in live {
        match groups.iter_mut().find(|(t, _)| *t == job.tenant) {
            Some((_, g)) => g.push(job),
            None => {
                let t = job.tenant;
                groups.push((t, vec![job]));
            }
        }
    }
    for gi in 0..groups.len() {
        let (tenant, group) = std::mem::take(&mut groups[gi]);
        if let Some(reason) = run_tenant_batch(engine, view, tenant, group, ctx) {
            // the panic's blast radius includes the groups not yet run:
            // the engine is gone, so their jobs fail here, explicitly
            let msg = format!("worker engine panicked (contained): {reason}");
            for (_, group) in groups.drain(gi + 1..) {
                for job in group {
                    ctx.metrics.record_job_failed();
                    answer_job(ctx, job, Err(anyhow!(msg.clone())));
                }
            }
            return BatchOutcome::Panicked(reason);
        }
    }
    BatchOutcome::Ok
}

/// Execute one single-tenant group as a fused forward pass. Returns
/// `Some(reason)` when the engine panicked (contained): every job in
/// the group has been answered with an error and the worker must die.
fn run_tenant_batch(
    engine: &mut dyn WorkerEngine,
    view: &TenantView,
    tenant: usize,
    live: Vec<Job>,
    ctx: &WorkerCtx,
) -> Option<String> {
    let n = live.len();
    let result = catch_unwind(AssertUnwindSafe(|| -> Result<TensorF> {
        for j in &live[1..] {
            if j.x.shape() != live[0].x.shape() {
                bail!(
                    "mixed input shapes in one batch: {:?} vs {:?}",
                    j.x.shape(),
                    live[0].x.shape()
                );
            }
        }
        let mut data = Vec::with_capacity(n * live[0].x.len());
        for j in &live {
            data.extend_from_slice(j.x.data());
        }
        let mut shape = live[0].x.shape().to_vec();
        shape[0] = n;
        let xb = TensorF::from_vec(&shape, data)?;
        let tctx = view.ctx(tenant);
        let t0 = Instant::now();
        let out = engine.infer_tenant(&tctx, &xb)?;
        ctx.metrics.record_batch(n, t0.elapsed().as_micros() as u64);
        Ok(out)
    }));
    match result {
        Ok(Ok(logits)) => {
            let classes = logits.shape().get(1).copied().unwrap_or(0);
            for (row, job) in live.into_iter().enumerate() {
                let resp = if classes == 0 || (row + 1) * classes > logits.len() {
                    Err(anyhow!("engine returned too few logit rows"))
                } else {
                    Ok(logits.data()[row * classes..(row + 1) * classes].to_vec())
                };
                if resp.is_ok() {
                    let latency = job.enqueued.elapsed();
                    ctx.metrics.record_request(latency);
                    ctx.pool.tenant(tenant).record_request(latency);
                }
                answer_job(ctx, job, resp);
            }
            None
        }
        Ok(Err(e)) => {
            // engine *errors* are survivable: answered and counted, the
            // worker keeps serving
            ctx.metrics.record_exec_error();
            ctx.pool.tenant(tenant).record_exec_error();
            let msg = format!("{e:#}");
            for job in live {
                answer_job(ctx, job, Err(anyhow!(msg.clone())));
            }
            None
        }
        Err(p) => {
            let reason = panic_msg(p.as_ref());
            ctx.metrics.record_panic();
            ctx.pool.tenant(tenant).record_exec_error();
            // the panic happened while executing THIS tenant's group:
            // strike it, so a crash-looping tenant is quarantined at
            // the router before it can burn every worker's restarts
            if ctx.breaker.record_strike(tenant) {
                eprintln!(
                    "serve: tenant '{}' quarantined after repeated contained failures",
                    ctx.tenants.name(tenant)
                );
            }
            let msg = format!("worker engine panicked (contained): {reason}");
            for job in live {
                ctx.metrics.record_job_failed();
                answer_job(ctx, job, Err(anyhow!(msg.clone())));
            }
            Some(reason)
        }
    }
}

/// One worker-sweep measurement (a row of `BENCH_serving.json`).
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub workers: usize,
    pub requests: usize,
    pub ok: usize,
    pub errors: usize,
    pub secs: f64,
    pub rps: f64,
    pub mean_latency_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_batch: f64,
    pub rejected: u64,
    pub deadline_exceeded: u64,
    pub panics: u64,
    pub restarts: u64,
    pub jobs_failed: u64,
    pub dead_workers: u64,
}

/// Start a pool at `workers` shards, drive `requests` synthetic-image
/// requests through closed-loop clients, and collect the measurements.
pub fn run_point(
    factory: Arc<dyn EngineFactory>,
    cfg: &ServeConfig,
    workers: usize,
    requests: usize,
) -> Result<SweepPoint> {
    let server = Server::start_with(factory, cfg.clone().with_workers(workers))?;
    let dataset = crate::train::data::synth_images(256, 411);
    let row = dataset.x.len() / dataset.len();
    let mut req_shape = dataset.x.shape().to_vec();
    req_shape[0] = 1;
    let xdata = Arc::new(dataset.x.data().to_vec());
    let clients = (workers * 4).clamp(4, 32);
    let per = (requests / clients).max(1);
    let t0 = Instant::now();
    let mut client_threads = Vec::new();
    for c in 0..clients {
        let client = server.client();
        let xdata = xdata.clone();
        let shape = req_shape.clone();
        client_threads.push(std::thread::spawn(move || -> (usize, usize) {
            let mut ok = 0usize;
            let mut errors = 0usize;
            for i in 0..per {
                let idx = (c * per + i) % 256;
                let x = TensorF::from_vec(&shape, xdata[idx * row..(idx + 1) * row].to_vec());
                match x.map_err(anyhow::Error::from).and_then(|x| client.infer(x)) {
                    Ok(logits) if !logits.is_empty() => ok += 1,
                    _ => errors += 1,
                }
            }
            (ok, errors)
        }));
    }
    let mut ok = 0usize;
    let mut errors = 0usize;
    for h in client_threads {
        let (o, e) = h.join().map_err(|_| anyhow!("client thread panicked"))?;
        ok += o;
        errors += e;
    }
    let secs = t0.elapsed().as_secs_f64();
    let agg = server.metrics().aggregate();
    let point = SweepPoint {
        workers,
        requests: clients * per,
        ok,
        errors,
        secs,
        rps: ok as f64 / secs.max(1e-9),
        mean_latency_ms: agg.mean_latency_us() / 1e3,
        p50_ms: agg.latency_percentile_us(0.5) as f64 / 1e3,
        p99_ms: agg.latency_percentile_us(0.99) as f64 / 1e3,
        mean_batch: agg.mean_batch(),
        rejected: server.metrics().rejected_count(),
        deadline_exceeded: agg.deadline_exceeded,
        panics: agg.panics,
        restarts: agg.restarts,
        jobs_failed: agg.jobs_failed,
        dead_workers: server.metrics().dead_workers() as u64,
    };
    println!("{}", server.metrics().report());
    server.shutdown()?;
    Ok(point)
}

/// Serialize sweep results as a versioned [`BenchRecord`] (`serving`
/// tag) — the format `ocs bench diff`/`check` read back; one row per
/// swept worker count with throughput as the gated metric.
///
/// [`BenchRecord`]: crate::bench_record::BenchRecord
pub fn sweep_json(backend_label: &str, points: &[SweepPoint]) -> String {
    crate::bench_record::BenchRecord::from_sweep(backend_label, points).to_json()
}

/// Drive a worker sweep over any backend; prints one line per point and
/// optionally writes `BENCH_serving.json`-style output.
pub fn self_test_with(
    factory: Arc<dyn EngineFactory>,
    cfg: &ServeConfig,
    requests: usize,
    sweep: &[usize],
    json_out: Option<&Path>,
) -> Result<Vec<SweepPoint>> {
    let sweep: Vec<usize> = if sweep.is_empty() {
        vec![cfg.workers]
    } else {
        sweep.to_vec()
    };
    let label = factory.label();
    let mut points = Vec::with_capacity(sweep.len());
    for &workers in &sweep {
        let p = run_point(factory.clone(), cfg, workers, requests)?;
        println!(
            "self-test[workers={workers}]: {}/{} ok in {:.2}s = {:.0} req/s \
             (p50 {:.2} ms, p99 {:.2} ms, mean batch {:.1})",
            p.ok, p.requests, p.secs, p.rps, p.p50_ms, p.p99_ms, p.mean_batch
        );
        points.push(p);
    }
    if let Some(path) = json_out {
        std::fs::write(path, sweep_json(&label, &points))
            .with_context(|| format!("write {}", path.display()))?;
        println!("wrote {}", path.display());
    }
    Ok(points)
}

/// End-to-end self-test over the real PJRT stack (used by `ocs serve`).
pub fn self_test(
    artifacts_dir: &str,
    model: &str,
    recipe: QuantRecipe,
    requests: usize,
    cfg: &ServeConfig,
    sweep: &[usize],
    json_out: Option<&Path>,
) -> Result<()> {
    let factory = Arc::new(PjrtFactory {
        artifacts_dir: artifacts_dir.to_string(),
        model: model.to_string(),
        recipe,
        max_batch: cfg.max_batch,
    });
    self_test_with(factory, cfg, requests, sweep, json_out).map(|_| ())
}

/// Self-test over the synthetic backend — no artifacts or PJRT needed
/// (this is what CI's serving smoke job runs).
pub fn self_test_sim(
    requests: usize,
    cfg: &ServeConfig,
    sweep: &[usize],
    json_out: Option<&Path>,
) -> Result<()> {
    let factory = Arc::new(SimFactory::default());
    self_test_with(factory, cfg, requests, sweep, json_out).map(|_| ())
}

/// One offered-load step of the closed-loop load test: `clients`
/// concurrent closed-loop client threads over the weighted tenant mix,
/// with latencies measured *client-side* (send → response, queueing
/// included) and percentiles taken over the merged exact samples — not
/// the pool's bucketed histogram.
#[derive(Debug, Clone)]
pub struct LoadPoint {
    pub clients: usize,
    pub requests: usize,
    pub ok: usize,
    pub errors: usize,
    pub secs: f64,
    pub rps: f64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub rejected: u64,
    pub deadline_exceeded: u64,
    pub panics: u64,
    pub restarts: u64,
    pub jobs_failed: u64,
    pub dead_workers: u64,
    /// Per-tenant `(name, requests served, rejected)` for this step.
    pub tenants: Vec<(String, u64, u64)>,
}

/// Ceil-rank percentile over an ascending sample (the convention
/// `bench_support` uses).
fn percentile_ms(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Deterministic weighted tenant pick for global request index `k`: a
/// golden-ratio low-discrepancy walk over the cumulative weights, so
/// every prefix of the request stream carries (approximately) the
/// configured mix and every run offers the identical schedule.
fn pick_tenant(table: &TenantTable, k: usize) -> usize {
    let total: f64 = (0..table.len()).map(|id| table.weight(id)).sum();
    let u = ((k as f64 + 1.0) * 0.618_033_988_749_895).fract() * total;
    let mut acc = 0.0;
    for id in 0..table.len() {
        acc += table.weight(id);
        if u < acc {
            return id;
        }
    }
    table.len() - 1
}

/// Run one offered-load step: start a fresh pool (fresh metrics, fixed
/// worker count from `cfg`), drive ~`requests` requests through
/// `clients` closed-loop threads over the weighted tenant mix, and
/// collect the measurements. Rejections and deadline misses count as
/// client errors — a closed-loop client immediately offers its next
/// request, which is what pushes the pool to saturation.
pub fn run_load_point(
    factory: Arc<dyn EngineFactory>,
    cfg: &ServeConfig,
    tenants: &[TenantInit],
    clients: usize,
    requests: usize,
) -> Result<LoadPoint> {
    let server = Server::start_tenants(factory, cfg.clone(), TenantTable::new(tenants)?)?;
    let point = drive_on(&server, clients, requests, None)?;
    println!("{}", server.metrics().report());
    server.shutdown()?;
    Ok(point)
}

/// Server-side counters sampled before/after one [`drive_on`] phase, so
/// consecutive phases against the *same* pool report exact deltas.
struct CounterBase {
    rejected: u64,
    deadline_exceeded: u64,
    panics: u64,
    restarts: u64,
    jobs_failed: u64,
    tenants: Vec<(u64, u64)>,
}

fn counter_base(server: &Server) -> CounterBase {
    let agg = server.metrics().aggregate();
    CounterBase {
        rejected: server.metrics().rejected_count(),
        deadline_exceeded: agg.deadline_exceeded,
        panics: agg.panics,
        restarts: agg.restarts,
        jobs_failed: agg.jobs_failed,
        tenants: (0..server.tenants().len())
            .map(|id| {
                (
                    server.metrics().tenant(id).snapshot().requests,
                    server.metrics().tenant_rejected_count(id),
                )
            })
            .collect(),
    }
}

/// Drive one closed-loop phase against an already-running pool. With
/// `watchdog: Some(d)`, a client thread that fails to report within `d`
/// of the previous report is treated as hung and the phase errors out —
/// this is the chaos harness's "zero client hangs" assertion.
fn drive_on(
    server: &Server,
    clients: usize,
    requests: usize,
    watchdog: Option<Duration>,
) -> Result<LoadPoint> {
    if clients == 0 {
        bail!("loadtest: client counts must be >= 1");
    }
    let base = counter_base(server);
    let dataset = crate::train::data::synth_images(256, 411);
    let row = dataset.x.len() / dataset.len();
    let mut req_shape = dataset.x.shape().to_vec();
    req_shape[0] = 1;
    let xdata = Arc::new(dataset.x.data().to_vec());
    let names = Arc::new(server.tenants().names());
    let per = (requests / clients).max(1);
    // the deterministic tenant schedule, one id per global request index
    let schedule: Arc<Vec<usize>> = Arc::new(
        (0..clients * per)
            .map(|k| pick_tenant(server.tenants(), k))
            .collect(),
    );
    let (done_tx, done_rx) = sync_channel::<(usize, usize, Vec<f64>)>(clients);
    let t0 = Instant::now();
    let mut threads = Vec::new();
    for c in 0..clients {
        let client = server.client();
        let xdata = xdata.clone();
        let shape = req_shape.clone();
        let names = names.clone();
        let schedule = schedule.clone();
        let done_tx = done_tx.clone();
        threads.push(std::thread::spawn(move || {
            let mut ok = 0usize;
            let mut errors = 0usize;
            let mut lat = Vec::with_capacity(per);
            for i in 0..per {
                let k = c * per + i;
                let idx = k % 256;
                let tenant = names[schedule[k]].as_str();
                let x = TensorF::from_vec(&shape, xdata[idx * row..(idx + 1) * row].to_vec());
                let sent = Instant::now();
                match x
                    .map_err(anyhow::Error::from)
                    .and_then(|x| client.infer_tenant(tenant, x))
                {
                    Ok(logits) if !logits.is_empty() => {
                        ok += 1;
                        lat.push(sent.elapsed().as_secs_f64() * 1e3);
                    }
                    _ => errors += 1,
                }
            }
            let _ = done_tx.send((ok, errors, lat));
        }));
    }
    drop(done_tx);
    let mut ok = 0usize;
    let mut errors = 0usize;
    let mut lat: Vec<f64> = Vec::new();
    for _ in 0..clients {
        let report = match watchdog {
            Some(d) => match done_rx.recv_timeout(d) {
                Ok(r) => Ok(r),
                Err(RecvTimeoutError::Timeout) => {
                    bail!(
                        "chaos loadtest: client hang — no client finished within {d:?} \
                         (a dead worker is stranding requests)"
                    );
                }
                Err(RecvTimeoutError::Disconnected) => Err(()),
            },
            None => done_rx.recv().map_err(|_| ()),
        };
        let (o, e, l) = report.map_err(|_| anyhow!("load client panicked"))?;
        ok += o;
        errors += e;
        lat.extend(l);
    }
    for h in threads {
        h.join().map_err(|_| anyhow!("load client panicked"))?;
    }
    let secs = t0.elapsed().as_secs_f64();
    lat.sort_by(|a, b| a.total_cmp(b));
    let mean_ms = if lat.is_empty() {
        0.0
    } else {
        lat.iter().sum::<f64>() / lat.len() as f64
    };
    let agg = server.metrics().aggregate();
    Ok(LoadPoint {
        clients,
        requests: clients * per,
        ok,
        errors,
        secs,
        rps: ok as f64 / secs.max(1e-9),
        mean_ms,
        p50_ms: percentile_ms(&lat, 0.50),
        p95_ms: percentile_ms(&lat, 0.95),
        p99_ms: percentile_ms(&lat, 0.99),
        rejected: server.metrics().rejected_count() - base.rejected,
        deadline_exceeded: agg.deadline_exceeded - base.deadline_exceeded,
        panics: agg.panics - base.panics,
        restarts: agg.restarts - base.restarts,
        jobs_failed: agg.jobs_failed - base.jobs_failed,
        dead_workers: server.metrics().dead_workers() as u64,
        tenants: (0..server.tenants().len())
            .map(|id| {
                (
                    server.tenants().name(id).to_string(),
                    server.metrics().tenant(id).snapshot().requests - base.tenants[id].0,
                    server.metrics().tenant_rejected_count(id) - base.tenants[id].1,
                )
            })
            .collect(),
    })
}

/// The closed-loop load harness behind `ocs serve --loadtest`: sweep
/// offered load (client concurrency) at a fixed worker count over a
/// tenant mix, print one line per step, report the saturation point
/// (the step with peak throughput), and optionally write a versioned
/// `BENCH_loadtest.json` record for `ocs bench check`/`diff`.
pub fn loadtest(
    factory: Arc<dyn EngineFactory>,
    cfg: &ServeConfig,
    tenants: &[TenantInit],
    clients_sweep: &[usize],
    requests: usize,
    json_out: Option<&Path>,
) -> Result<Vec<LoadPoint>> {
    let sweep: Vec<usize> = if clients_sweep.is_empty() {
        vec![1, 2, 4, 8]
    } else {
        clients_sweep.to_vec()
    };
    let label = factory.label();
    let mut points = Vec::with_capacity(sweep.len());
    for &clients in &sweep {
        let p = run_load_point(factory.clone(), cfg, tenants, clients, requests)?;
        println!(
            "loadtest[clients={clients}]: {}/{} ok in {:.2}s = {:.0} req/s \
             (p50 {:.2} ms, p95 {:.2} ms, p99 {:.2} ms, rejected {}, deadline-exceeded {})",
            p.ok,
            p.requests,
            p.secs,
            p.rps,
            p.p50_ms,
            p.p95_ms,
            p.p99_ms,
            p.rejected,
            p.deadline_exceeded
        );
        points.push(p);
    }
    if let Some(sat) = points.iter().max_by(|a, b| a.rps.total_cmp(&b.rps)) {
        println!(
            "loadtest: saturation ~{:.0} req/s at {} client(s) \
             ({} worker(s), {} tenant(s) in the mix)",
            sat.rps,
            sat.clients,
            cfg.workers,
            tenants.len() + 1
        );
    }
    if let Some(path) = json_out {
        crate::bench_record::BenchRecord::from_loadtest(&label, &points)
            .write(path)
            .with_context(|| format!("write {}", path.display()))?;
        println!("wrote {}", path.display());
    }
    Ok(points)
}

/// The chaos loadtest's three phases plus the fault bookkeeping the
/// assertions (and `BENCH_chaos.json`) are built from.
#[derive(Debug, Clone)]
pub struct ChaosReport {
    /// Baseline phase on a healthy pool (no faults armed).
    pub healthy: LoadPoint,
    /// The phase during which `killed_worker` panics and is respawned.
    pub degraded: LoadPoint,
    /// Same pool after the respawn settled.
    pub recovered: LoadPoint,
    pub killed_worker: usize,
    pub panics: u64,
    pub restarts: u64,
    pub jobs_failed: u64,
}

/// The chaos gate behind `ocs serve --loadtest --chaos`: measure a
/// healthy baseline, then run the same offered load against a pool
/// where one worker is scheduled (via [`faults::FaultPlan`]) to panic
/// mid-sweep, and assert graceful degradation — no client ever hangs
/// (watchdogged), the error burst is bounded by the dead worker's
/// admission share, and throughput after the supervisor's respawn
/// recovers to at least half the healthy baseline.
pub fn chaos_loadtest(
    factory: Arc<dyn EngineFactory>,
    cfg: &ServeConfig,
    tenants: &[TenantInit],
    clients: usize,
    requests: usize,
    json_out: Option<&Path>,
) -> Result<ChaosReport> {
    if cfg.workers < 2 {
        bail!("chaos loadtest: need at least 2 workers (one dies mid-sweep)");
    }
    if cfg.restart_max == 0 {
        bail!("chaos loadtest: restart_max must be >= 1 for the pool to recover");
    }
    let label = factory.label();
    // Phase 1: healthy baseline on its own pool, no faults armed.
    let healthy = run_load_point(factory.clone(), cfg, tenants, clients, requests)?;
    println!(
        "chaos[healthy]: {}/{} ok in {:.2}s = {:.0} req/s (p99 {:.2} ms)",
        healthy.ok, healthy.requests, healthy.secs, healthy.rps, healthy.p99_ms
    );
    // Phases 2+3 share one pool: the highest-id worker panics on its
    // 3rd batch (deep enough into the sweep that the pool is warm).
    let killed = cfg.workers - 1;
    let plan = faults::FaultPlan::new(vec![faults::FaultDirective::PanicOnBatch {
        worker: killed,
        nth: 3,
    }]);
    let server =
        Server::start_tenants(plan.wrap(factory), cfg.clone(), TenantTable::new(tenants)?)?;
    let degraded = drive_on(&server, clients, requests, Some(Duration::from_secs(60)))?;
    println!(
        "chaos[degraded]: {}/{} ok = {:.0} req/s \
         ({} panic(s), {} job(s) failed, {} rejected)",
        degraded.ok, degraded.requests, degraded.rps, degraded.panics, degraded.jobs_failed,
        degraded.rejected
    );
    if degraded.panics == 0 {
        bail!(
            "chaos loadtest: the fault never fired — worker {killed} served fewer than 3 \
             batches; raise --requests"
        );
    }
    if degraded.ok == 0 {
        bail!("chaos loadtest: no request survived the worker kill");
    }
    // Bounded blast radius: the kill can fail at most the dead worker's
    // queue + one in-flight batch; anything above that (plus rejections,
    // which closed-loop clients count as errors) means the failure leaked.
    let blast_cap = cfg.queue_cap + cfg.max_batch + degraded.rejected as usize;
    if degraded.errors > blast_cap {
        bail!(
            "chaos loadtest: {} errors exceed the blast-radius bound {} \
             (queue_cap {} + max_batch {} + {} rejected)",
            degraded.errors,
            blast_cap,
            cfg.queue_cap,
            cfg.max_batch,
            degraded.rejected
        );
    }
    // Wait for the supervisor's respawn before measuring recovery.
    let t0 = Instant::now();
    while server.metrics().aggregate().restarts == 0 {
        if t0.elapsed() > Duration::from_secs(10) {
            bail!("chaos loadtest: supervisor never respawned worker {killed}");
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    // Phase 3: same pool, fault already burned (one-shot), full strength.
    let recovered = drive_on(&server, clients, requests, Some(Duration::from_secs(60)))?;
    let report = server.metrics().report();
    let agg = server.metrics().aggregate();
    let out = ChaosReport {
        killed_worker: killed,
        panics: agg.panics,
        restarts: agg.restarts,
        jobs_failed: agg.jobs_failed,
        healthy,
        degraded,
        recovered,
    };
    println!("{report}");
    server.shutdown()?;
    let ratio = out.recovered.rps / out.healthy.rps.max(1e-9);
    println!(
        "chaos: recovered {:.0} req/s vs healthy {:.0} req/s ({:.0}% — worker {} killed, \
         {} restart(s), {} job(s) failed)",
        out.recovered.rps,
        out.healthy.rps,
        ratio * 100.0,
        out.killed_worker,
        out.restarts,
        out.jobs_failed
    );
    if ratio < 0.5 {
        bail!(
            "chaos loadtest: post-respawn throughput {:.0} req/s is below half the healthy \
             baseline {:.0} req/s",
            out.recovered.rps,
            out.healthy.rps
        );
    }
    if let Some(path) = json_out {
        crate::bench_record::BenchRecord::from_chaos(&label, &out)
            .write(path)
            .with_context(|| format!("write {}", path.display()))?;
        println!("wrote {}", path.display());
    }
    Ok(out)
}

/// The slow-worker drill's three phases plus the knobs that shaped
/// them, for the assertions and `BENCH_slow.json`.
#[derive(Debug, Clone)]
pub struct SlowReport {
    /// Baseline phase on a healthy pool, deadline disarmed.
    pub healthy: LoadPoint,
    /// Every worker slowed by `slow_us` per batch, deadline disarmed —
    /// queueing builds and throughput collapses.
    pub slow: LoadPoint,
    /// Same slow workers with the deadline armed — expired jobs are
    /// answered from the queue without touching the slow engine.
    pub shed: LoadPoint,
    pub slow_us: u64,
    pub deadline_ms: u64,
}

/// The slow-worker gate behind `ocs serve --loadtest --slow-drill`:
/// the `slow:US` fault spec existed since the fault layer landed but
/// nothing gated it. Measure a healthy baseline, collapse the pool by
/// making **every** infer batch sleep `slow_us` (deadline off), then
/// rerun with the configured deadline armed and assert the deadline
/// path *sheds* — expired jobs get fast "deadline exceeded" answers
/// instead of queueing behind the slow engine, so the pool drains the
/// same offered load in less wall time while still completing some
/// requests. Fails loudly when the fault never bit, nothing was shed,
/// every request was shed, or shedding didn't beat the collapse.
pub fn slow_loadtest(
    factory: Arc<dyn EngineFactory>,
    cfg: &ServeConfig,
    tenants: &[TenantInit],
    clients: usize,
    requests: usize,
    slow_us: u64,
    json_out: Option<&Path>,
) -> Result<SlowReport> {
    let deadline = match cfg.deadline {
        Some(d) => d,
        None => bail!("slow drill: a deadline is the path under test — pass --deadline-ms"),
    };
    if slow_us == 0 {
        bail!("slow drill: --slow-us must be >= 1");
    }
    if deadline.as_micros() <= slow_us as u128 {
        bail!(
            "slow drill: deadline {:?} is not above the per-batch slowdown {slow_us} µs — \
             even a freshly dequeued job would expire and nothing could ever complete",
            deadline
        );
    }
    let label = factory.label();
    let mut no_deadline = cfg.clone();
    no_deadline.deadline = None;
    // Phase 1: healthy baseline, deadline disarmed, no faults.
    let healthy = run_load_point(factory.clone(), &no_deadline, tenants, clients, requests)?;
    println!(
        "slow[healthy]: {}/{} ok in {:.2}s = {:.0} req/s (p99 {:.2} ms)",
        healthy.ok, healthy.requests, healthy.secs, healthy.rps, healthy.p99_ms
    );
    let plan = faults::FaultPlan::new(vec![faults::FaultDirective::SlowInfer { micros: slow_us }]);
    let slow_factory = plan.wrap(factory);
    // Phase 2: every batch slowed, deadline still disarmed — the
    // collapse the deadline path exists to prevent.
    let server = Server::start_tenants(
        slow_factory.clone(),
        no_deadline.clone(),
        TenantTable::new(tenants)?,
    )?;
    let slow = drive_on(&server, clients, requests, Some(Duration::from_secs(60)))?;
    println!("{}", server.metrics().report());
    server.shutdown()?;
    println!(
        "slow[slow]: {}/{} ok in {:.2}s = {:.0} req/s (p99 {:.2} ms, +{slow_us} µs/batch)",
        slow.ok, slow.requests, slow.secs, slow.rps, slow.p99_ms
    );
    if slow.rps >= healthy.rps * 0.8 {
        bail!(
            "slow drill: the fault never bit — {:.0} req/s slowed vs {:.0} req/s healthy; \
             raise --slow-us",
            slow.rps,
            healthy.rps
        );
    }
    // Phase 3: same slow workers, deadline armed.
    let server = Server::start_tenants(slow_factory, cfg.clone(), TenantTable::new(tenants)?)?;
    let shed = drive_on(&server, clients, requests, Some(Duration::from_secs(60)))?;
    println!("{}", server.metrics().report());
    server.shutdown()?;
    println!(
        "slow[shed]: {}/{} ok in {:.2}s = {:.0} req/s \
         ({} deadline-exceeded, p99 {:.2} ms, deadline {:?})",
        shed.ok, shed.requests, shed.secs, shed.rps, shed.deadline_exceeded, shed.p99_ms, deadline
    );
    if shed.deadline_exceeded == 0 {
        bail!(
            "slow drill: deadline path never fired — no job outlived {:?} in queue; \
             lower --deadline-ms or raise --slow-us",
            deadline
        );
    }
    if shed.ok == 0 {
        bail!("slow drill: every request was shed — the pool did no work at all");
    }
    let slow_drain = slow.requests as f64 / slow.secs.max(1e-9);
    let shed_drain = shed.requests as f64 / shed.secs.max(1e-9);
    if shed_drain <= slow_drain {
        bail!(
            "slow drill: shedding drained {:.0} req/s offered load, no better than the \
             collapsed {:.0} req/s — the deadline path is queueing behind the slow engine",
            shed_drain,
            slow_drain
        );
    }
    println!(
        "slow: shed drained {:.0} req/s offered vs collapsed {:.0} req/s \
         ({:.1}x — {} of {} shed, {} served)",
        shed_drain,
        slow_drain,
        shed_drain / slow_drain,
        shed.deadline_exceeded,
        shed.requests,
        shed.ok
    );
    let out = SlowReport {
        healthy,
        slow,
        shed,
        slow_us,
        deadline_ms: deadline.as_millis() as u64,
    };
    if let Some(path) = json_out {
        crate::bench_record::BenchRecord::from_slow(&label, &out)
            .write(path)
            .with_context(|| format!("write {}", path.display()))?;
        println!("wrote {}", path.display());
    }
    Ok(out)
}

/// One scenario of the chaos drill matrix: healthy/degraded/recovered
/// phases plus the fault bookkeeping its containment gates are built
/// from.
#[derive(Debug, Clone)]
pub struct ChaosScenario {
    pub name: String,
    pub healthy: LoadPoint,
    pub degraded: LoadPoint,
    pub recovered: LoadPoint,
    pub panics: u64,
    pub restarts: u64,
    pub jobs_failed: u64,
    pub swap_aborts: u64,
    /// Requests rejected (or rerouted) because a tenant was quarantined.
    pub quarantined: u64,
    pub dead_workers: u64,
}

/// The full matrix (`ocs serve --loadtest --chaos-matrix`).
#[derive(Debug, Clone)]
pub struct ChaosMatrixReport {
    pub scenarios: Vec<ChaosScenario>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum MatrixScenario {
    /// PR 8's drill: the highest-id worker panics mid-sweep.
    SingleKill,
    /// Two of the pool's workers panic in the same sweep step.
    MultiKill,
    /// A recipe sync panics mid-hot-swap; the struck worker must roll
    /// back and stay alive.
    SwapCrash,
    /// One tenant panics every batch until the tenant breaker
    /// quarantines it.
    CrashLoop,
}

impl MatrixScenario {
    fn name(self) -> &'static str {
        match self {
            MatrixScenario::SingleKill => "single-kill",
            MatrixScenario::MultiKill => "multi-kill",
            MatrixScenario::SwapCrash => "swap-crash",
            MatrixScenario::CrashLoop => "crash-loop-tenant",
        }
    }
}

/// Capture one fixed image's logits per probed tenant. The matrix
/// compares these bit-for-bit across a scenario (before any fault
/// fires vs after recovery) — the "sibling tenants undisturbed"
/// containment gate.
fn probe_logits(client: &Client, names: &[String]) -> Result<Vec<Vec<f32>>> {
    let dataset = crate::train::data::synth_images(1, 411);
    let mut shape = dataset.x.shape().to_vec();
    shape[0] = 1;
    let mut out = Vec::with_capacity(names.len());
    for name in names {
        let x = TensorF::from_vec(&shape, dataset.x.data().to_vec())?;
        let logits = client
            .infer_tenant(name, x)
            .with_context(|| format!("containment probe for tenant '{name}'"))?;
        out.push(logits);
    }
    Ok(out)
}

/// The chaos drill matrix behind `ocs serve --loadtest --chaos-matrix`:
/// run the single-kill drill plus concurrent multi-worker kills, a
/// fault during a hot-swap, and a crash-looping tenant — each scenario
/// a healthy baseline on a clean pool, then degraded + recovered phases
/// on one shared faulted pool — and gate every scenario on containment:
/// sibling tenants' logits bit-stable across the fault, no client ever
/// hangs (watchdogged), the error burst bounded, and post-fault
/// throughput at least half the healthy baseline. Emits the
/// multi-scenario `BENCH_chaos_matrix.json` when `json_out` is set.
pub fn chaos_matrix(
    factory: Arc<dyn EngineFactory>,
    cfg: &ServeConfig,
    tenants: &[TenantInit],
    clients: usize,
    requests: usize,
    json_out: Option<&Path>,
) -> Result<ChaosMatrixReport> {
    if cfg.workers < 3 {
        bail!("chaos matrix: need at least 3 workers (two die concurrently in multi-kill)");
    }
    if cfg.restart_max == 0 {
        bail!("chaos matrix: restart_max must be >= 1 for the pool to recover");
    }
    // The matrix needs a designated chaos tenant (and at least one
    // sibling beyond default); supply the standard drill mix when the
    // caller configured none.
    let mix: Vec<TenantInit> = if tenants.is_empty() {
        vec![
            TenantInit { name: "gold".into(), weight: 1.0, recipe: None },
            TenantInit { name: "bulk".into(), weight: 2.0, recipe: None },
        ]
    } else {
        tenants.to_vec()
    };
    let faulty = mix[0].name.clone();
    let label = factory.label();
    let kinds = [
        MatrixScenario::SingleKill,
        MatrixScenario::MultiKill,
        MatrixScenario::SwapCrash,
        MatrixScenario::CrashLoop,
    ];
    let mut scenarios = Vec::with_capacity(kinds.len());
    for kind in kinds {
        scenarios.push(run_matrix_scenario(
            kind,
            factory.clone(),
            cfg,
            &mix,
            &faulty,
            clients,
            requests,
        )?);
    }
    let report = ChaosMatrixReport { scenarios };
    println!(
        "chaos matrix: {}/{} scenario(s) contained (tenant '{faulty}' played the faulty party)",
        report.scenarios.len(),
        kinds.len()
    );
    if let Some(path) = json_out {
        crate::bench_record::BenchRecord::from_chaos_matrix(&label, &report)
            .write(path)
            .with_context(|| format!("write {}", path.display()))?;
        println!("wrote {}", path.display());
    }
    Ok(report)
}

fn run_matrix_scenario(
    kind: MatrixScenario,
    factory: Arc<dyn EngineFactory>,
    cfg: &ServeConfig,
    tenants: &[TenantInit],
    faulty: &str,
    clients: usize,
    requests: usize,
) -> Result<ChaosScenario> {
    let name = kind.name();
    let watchdog = Some(Duration::from_secs(60));
    let mut scfg = cfg.clone();
    let (directives, kills) = match kind {
        MatrixScenario::SingleKill => (
            vec![faults::FaultDirective::PanicOnBatch { worker: cfg.workers - 1, nth: 3 }],
            1usize,
        ),
        MatrixScenario::MultiKill => (
            vec![
                faults::FaultDirective::PanicOnBatch { worker: cfg.workers - 1, nth: 3 },
                faults::FaultDirective::PanicOnBatch { worker: cfg.workers - 2, nth: 3 },
            ],
            2,
        ),
        MatrixScenario::SwapCrash => (
            vec![faults::FaultDirective::PanicOnSync { tenant: faulty.to_string(), nth: 1 }],
            0,
        ),
        MatrixScenario::CrashLoop => {
            // The containment under test is the *tenant* breaker, so
            // keep the other two latches out of the picture: a long
            // quarantine stops a half-open probe from re-admitting the
            // still-panicking tenant mid-measurement, and a restart
            // budget above the strike budget stops any single worker
            // from give-up death even if every strike lands on it.
            scfg.quarantine = scfg.quarantine.max(Duration::from_secs(120));
            scfg.restart_max = scfg.restart_max.max(scfg.tenant_restart_max + 1);
            (
                vec![faults::FaultDirective::PanicOnTenant { tenant: faulty.to_string() }],
                0,
            )
        }
    };
    // Phase 1: healthy baseline on its own clean pool.
    let healthy = run_load_point(factory.clone(), &scfg, tenants, clients, requests)?;
    println!(
        "chaos-matrix[{name}/healthy]: {}/{} ok in {:.2}s = {:.0} req/s (p99 {:.2} ms)",
        healthy.ok, healthy.requests, healthy.secs, healthy.rps, healthy.p99_ms
    );
    // Phases 2+3 share one faulted pool.
    let plan = faults::FaultPlan::new(directives);
    let server =
        Server::start_tenants(plan.wrap(factory), scfg.clone(), TenantTable::new(tenants)?)?;
    let client = server.client();
    // Sibling containment probe, before any fault fires. The faulty
    // tenant is excluded: its own answers are *allowed* to change (new
    // recipe after the swap, quarantine rejections in the crash loop).
    let siblings: Vec<String> = server
        .tenants()
        .names()
        .into_iter()
        .filter(|n| n.as_str() != faulty)
        .collect();
    let before = probe_logits(&client, &siblings)?;
    if kind == MatrixScenario::SwapCrash {
        // arm the hot swap the plan is waiting to strike; workers pick
        // it up between batches, racing the degraded phase's load
        server.swap_tenant_recipe(faulty, QuantRecipe::float())?;
    }
    let degraded = drive_on(&server, clients, requests, watchdog)?;
    println!(
        "chaos-matrix[{name}/degraded]: {}/{} ok = {:.0} req/s \
         ({} panic(s), {} job(s) failed, {} rejected)",
        degraded.ok, degraded.requests, degraded.rps, degraded.panics, degraded.jobs_failed,
        degraded.rejected
    );
    if degraded.ok == 0 {
        bail!("chaos matrix [{name}]: no request survived the fault");
    }
    // Scenario-specific settling + gates.
    match kind {
        MatrixScenario::SingleKill | MatrixScenario::MultiKill => {
            if degraded.panics < kills as u64 {
                bail!(
                    "chaos matrix [{name}]: only {} of {kills} kill(s) fired — \
                     raise --requests",
                    degraded.panics
                );
            }
            let blast_cap = kills * (scfg.queue_cap + scfg.max_batch) + degraded.rejected as usize;
            if degraded.errors > blast_cap {
                bail!(
                    "chaos matrix [{name}]: {} errors exceed the blast-radius bound {} \
                     ({kills} kill(s) x (queue_cap {} + max_batch {}) + {} rejected)",
                    degraded.errors,
                    blast_cap,
                    scfg.queue_cap,
                    scfg.max_batch,
                    degraded.rejected
                );
            }
            let t0 = Instant::now();
            while server.metrics().aggregate().restarts < kills as u64 {
                if t0.elapsed() > Duration::from_secs(10) {
                    bail!(
                        "chaos matrix [{name}]: supervisor respawned {} of {kills} \
                         killed worker(s)",
                        server.metrics().aggregate().restarts
                    );
                }
                std::thread::sleep(Duration::from_millis(5));
            }
        }
        MatrixScenario::SwapCrash => {
            // all workers sync within one poll tick; wait for the roll
            // call: one aborted (rolled back), the rest applied
            let t0 = Instant::now();
            loop {
                let agg = server.metrics().aggregate();
                if agg.swap_aborts >= 1 && agg.recipe_swaps >= (scfg.workers - 1) as u64 {
                    break;
                }
                if t0.elapsed() > Duration::from_secs(10) {
                    bail!(
                        "chaos matrix [{name}]: swap never settled — {} abort(s), {} \
                         applied of {} worker(s)",
                        agg.swap_aborts,
                        agg.recipe_swaps,
                        scfg.workers
                    );
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            let agg = server.metrics().aggregate();
            if agg.restarts > 0 || server.dead_workers() > 0 {
                bail!(
                    "chaos matrix [{name}]: the sync panic killed a worker \
                     ({} restart(s), {} dead) — swap containment failed",
                    agg.restarts,
                    server.dead_workers()
                );
            }
        }
        MatrixScenario::CrashLoop => {
            if !server.tenant_quarantined(faulty) {
                bail!(
                    "chaos matrix [{name}]: tenant '{faulty}' was never quarantined \
                     ({} strike(s) of {}) — raise --requests",
                    server
                        .tenants()
                        .id_of(faulty)
                        .map(|id| server.tenant_breaker().strike_count(id))
                        .unwrap_or(0),
                    scfg.tenant_restart_max
                );
            }
            if server.dead_workers() > 0 {
                bail!(
                    "chaos matrix [{name}]: {} worker breaker(s) opened — the tenant \
                     breaker was supposed to contain the crash loop",
                    server.dead_workers()
                );
            }
        }
    }
    // Phase 3: same pool after the fault settled.
    let recovered = drive_on(&server, clients, requests, watchdog)?;
    println!(
        "chaos-matrix[{name}/recovered]: {}/{} ok = {:.0} req/s",
        recovered.ok, recovered.requests, recovered.rps
    );
    let ratio = recovered.rps / healthy.rps.max(1e-9);
    if ratio < 0.5 {
        bail!(
            "chaos matrix [{name}]: post-fault throughput {:.0} req/s is below half the \
             healthy baseline {:.0} req/s",
            recovered.rps,
            healthy.rps
        );
    }
    // Sibling containment: bit-identical logits across the whole drill.
    let after = probe_logits(&client, &siblings)?;
    for (i, tenant) in siblings.iter().enumerate() {
        if before[i] != after[i] {
            bail!(
                "chaos matrix [{name}]: tenant '{tenant}' logits changed across the fault \
                 — containment leaked into a sibling"
            );
        }
    }
    let agg = server.metrics().aggregate();
    let quarantined: u64 = (0..server.tenants().len())
        .map(|id| server.metrics().tenant_quarantined_count(id))
        .sum();
    let out = ChaosScenario {
        name: name.to_string(),
        panics: agg.panics,
        restarts: agg.restarts,
        jobs_failed: agg.jobs_failed,
        swap_aborts: agg.swap_aborts,
        quarantined,
        dead_workers: server.dead_workers() as u64,
        healthy,
        degraded,
        recovered,
    };
    println!("{}", server.metrics().report());
    server.shutdown()?;
    println!(
        "chaos-matrix[{name}]: contained — recovered {:.0}% of healthy \
         ({} restart(s), {} swap abort(s), {} quarantine rejection(s))",
        ratio * 100.0,
        out.restarts,
        out.swap_aborts,
        out.quarantined
    );
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn init(name: &str, weight: f64) -> TenantInit {
        TenantInit {
            name: name.into(),
            weight,
            recipe: None,
        }
    }

    #[test]
    fn tenant_table_basics() {
        let t = TenantTable::default_only();
        assert_eq!(t.len(), 1);
        assert!(!t.is_empty());
        assert_eq!(t.id_of("default"), Some(0));
        let t = TenantTable::new(&[init("gold", 1.0), init("bulk", 3.0)]).unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t.id_of("bulk"), Some(2));
        assert_eq!(t.id_of("nope"), None);
        assert_eq!(t.name(1), "gold");
        assert_eq!(t.weight(2), 3.0);
        assert!(TenantTable::new(&[init("default", 1.0)]).is_err(), "reserved name");
        assert!(TenantTable::new(&[init("a", 1.0), init("a", 1.0)]).is_err());
        assert!(TenantTable::new(&[init("", 1.0)]).is_err());
        assert!(TenantTable::new(&[init("a", 0.0)]).is_err());
        assert!(TenantTable::new(&[init("a", f64::NAN)]).is_err());
    }

    #[test]
    fn tenant_slots_publish_per_tenant() {
        let t = TenantTable::new(&[init("a", 1.0)]).unwrap();
        let (e, r) = t.read(1);
        assert_eq!(e, 0);
        assert!(r.is_none());
        t.publish(1, QuantRecipe::float());
        let (e, r) = t.read(1);
        assert_eq!(e, 1);
        assert!(r.is_some());
        assert_eq!(t.epoch(0), 0, "other slots stay untouched");
    }

    #[test]
    fn tenant_schedule_is_deterministic_and_proportional() {
        let t = TenantTable::new(&[init("gold", 1.0), init("bulk", 2.0)]).unwrap();
        // weights: default 1, gold 1, bulk 2 -> shares 25% / 25% / 50%
        let mut counts = [0usize; 3];
        for k in 0..1000 {
            let a = pick_tenant(&t, k);
            assert_eq!(a, pick_tenant(&t, k), "schedule must be deterministic");
            counts[a] += 1;
        }
        assert!((200..300).contains(&counts[0]), "{counts:?}");
        assert!((200..300).contains(&counts[1]), "{counts:?}");
        assert!((450..550).contains(&counts[2]), "{counts:?}");
    }

    #[test]
    fn percentile_ms_is_ceil_rank() {
        assert_eq!(percentile_ms(&[], 0.5), 0.0);
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_ms(&v, 0.0), 1.0);
        assert_eq!(percentile_ms(&v, 0.5), 2.0);
        assert_eq!(percentile_ms(&v, 0.95), 4.0);
        assert_eq!(percentile_ms(&v, 1.0), 4.0);
    }

    #[test]
    fn respawn_delay_is_jittered_exponential() {
        let base = Duration::from_millis(100);
        // every delay stays inside the ±25% band around the exponential
        for id in 0..8 {
            for attempt in 1..=10u32 {
                let exp = base.saturating_mul(1u32 << (attempt - 1).min(6));
                let d = respawn_delay(base, id, attempt);
                assert!(
                    d >= exp.mul_f64(0.75) && d < exp.mul_f64(1.25),
                    "worker {id} attempt {attempt}: {d:?} outside the jitter band of {exp:?}"
                );
            }
        }
        // deterministic: the same (worker, attempt) always sleeps the same
        assert_eq!(respawn_delay(base, 3, 2), respawn_delay(base, 3, 2));
        // spread: workers killed by the same fault (same attempt count)
        // must not respawn in lockstep
        let at_attempt_1: Vec<Duration> =
            (0..8).map(|id| respawn_delay(base, id, 1)).collect();
        let distinct = at_attempt_1
            .iter()
            .collect::<std::collections::HashSet<_>>()
            .len();
        assert!(
            distinct >= 6,
            "only {distinct} distinct delays across 8 workers: {at_attempt_1:?}"
        );
        // the cap still applies under jitter
        let capped = respawn_delay(base, 0, 40);
        assert!(capped < base.saturating_mul(64).mul_f64(1.25), "{capped:?}");
    }
}
