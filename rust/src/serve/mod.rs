//! Sharded multi-worker inference engine pool.
//!
//! The OCS paper's deployment story (§3.5) is that an OCS-quantized
//! model is a *plain* model — servable on commodity hardware with no
//! custom ops beyond channel duplication, which lives inside the AOT
//! artifact. This module proves it at pool scale.
//!
//! ## Shape
//!
//! ```text
//!             Client::infer ──┐
//!             Client::infer ──┤  least-outstanding-work dispatch,
//!             Client::infer ──┤  bounded queues, reject-not-block
//!                             ▼
//!                   ┌──── Router ────┐
//!              try_send          try_send
//!                   ▼                ▼
//!          [queue cap=Q]      [queue cap=Q]        ... × workers
//!            worker 0           worker 1
//!          Engine+pipeline    Engine+pipeline      (one per thread)
//!          dynamic batcher    dynamic batcher
//! ```
//!
//! PJRT handles are `!Send`, so scaling *cannot* share one engine across
//! threads: the only correct shape is shard-per-thread, each worker
//! owning its whole stack (engine, prepared pipeline, executable cache).
//! Workers build those stacks concurrently at startup; artifact text is
//! read once per process via [`crate::runtime::HloTextCache`], and the
//! prepared quantization pipeline once per distinct recipe via the
//! process-wide [`crate::pipeline::PreparedCache`] — worker 2..N share
//! worker 1's prep through an `Arc`.
//!
//! ## Admission control and deadlines
//!
//! Dispatch walks workers in ascending outstanding-work order and
//! `try_send`s into the first bounded queue with room. When every queue
//! is full the request is **rejected immediately** — clients get an
//! error, never a silent hang. A configured deadline
//! ([`ServeConfig::deadline`]) is checked when a job is pulled into a
//! batch: expired jobs are answered with an error instead of wasting a
//! forward pass.
//!
//! ## Recipe hot-swap
//!
//! [`Server::swap_recipe`] publishes a new [`QuantRecipe`] to every
//! worker without restarting the pool. Workers notice between batches
//! (or within one idle-poll tick, ~50 ms) and re-prepare through the
//! process-wide [`crate::pipeline::PreparedCache`] — so N workers
//! swapping to the same recipe still prepare once. In-flight and
//! already-batched requests drain on the old prep; a worker whose swap
//! fails keeps serving the old prep and counts a `swap_error`. Poll
//! [`Server::swaps_applied`] to observe roll-out across the pool.
//!
//! ## Shutdown
//!
//! [`Server::shutdown`] flips the stop flag: the router rejects new
//! work, each worker drains everything already queued (every admitted
//! job gets a response), then exits; `shutdown` joins them all.

pub mod backend;
pub mod metrics;

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::pipeline::QuantRecipe;
use crate::tensor::TensorF;

use backend::{EngineFactory, PjrtFactory, SimFactory, WorkerEngine};

pub use crate::pipeline::ServeConfig;
pub use metrics::{Metrics, PoolMetrics, Snapshot};

/// The published-recipe slot workers poll between batches. The epoch
/// counter tells a worker *that* something changed without holding the
/// lock; the recipe itself is read under it.
#[derive(Default)]
struct SwapSlot {
    epoch: AtomicU64,
    recipe: Mutex<Option<QuantRecipe>>,
}

/// One queued inference request.
struct Job {
    /// (1, H, W, C) image.
    x: TensorF,
    enqueued: Instant,
    deadline: Option<Instant>,
    resp: SyncSender<Result<Vec<f32>>>,
}

/// One worker's intake, as seen by the router.
struct Shard {
    tx: SyncSender<Job>,
    /// Queued + in-flight gauge (shared with [`PoolMetrics`]).
    outstanding: Arc<AtomicUsize>,
}

/// Shared dispatch state: admission control + shard selection.
struct Router {
    shards: Vec<Shard>,
    queue_cap: usize,
    deadline: Option<Duration>,
    stop: Arc<AtomicBool>,
    metrics: Arc<PoolMetrics>,
}

impl Router {
    /// Admit a request: pick the least-loaded shard with queue room and
    /// hand back the response channel. Errors instead of blocking when
    /// the pool is stopping or every queue is full.
    fn dispatch(&self, x: TensorF) -> Result<Receiver<Result<Vec<f32>>>> {
        if self.stop.load(Ordering::SeqCst) {
            bail!("server is shutting down");
        }
        let (tx, rx) = sync_channel(1);
        let now = Instant::now();
        let mut job = Job {
            x,
            enqueued: now,
            deadline: self.deadline.map(|d| now + d),
            resp: tx,
        };
        // least-outstanding-work dispatch, allocation-free on the hot
        // path: start at the least-loaded shard, walk the rest as
        // fallback when its queue is full
        let n = self.shards.len();
        let mut start = 0usize;
        let mut least = usize::MAX;
        for (i, shard) in self.shards.iter().enumerate() {
            let o = shard.outstanding.load(Ordering::Relaxed);
            if o < least {
                least = o;
                start = i;
            }
        }
        for offset in 0..n {
            let i = (start + offset) % n;
            let shard = &self.shards[i];
            // count before send: the worker may answer (and decrement)
            // before try_send even returns
            shard.outstanding.fetch_add(1, Ordering::Relaxed);
            match shard.tx.try_send(job) {
                Ok(()) => {
                    self.metrics.dispatched.fetch_add(1, Ordering::Relaxed);
                    return Ok(rx);
                }
                Err(TrySendError::Full(j)) | Err(TrySendError::Disconnected(j)) => {
                    shard.outstanding.fetch_sub(1, Ordering::Relaxed);
                    job = j;
                }
            }
        }
        self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
        bail!(
            "server overloaded: all {} worker queues full (cap {} each)",
            self.shards.len(),
            self.queue_cap
        )
    }
}

/// Client handle (cheaply cloneable, shareable across threads).
#[derive(Clone)]
pub struct Client {
    router: Arc<Router>,
    metrics: Arc<PoolMetrics>,
}

impl Client {
    /// Synchronous single-image inference; returns the logits row.
    pub fn infer(&self, x: TensorF) -> Result<Vec<f32>> {
        let rx = self.router.dispatch(x)?;
        rx.recv().context("server dropped the request")?
    }

    pub fn metrics(&self) -> &PoolMetrics {
        &self.metrics
    }
}

/// Running pool: N worker threads + router + client factory.
pub struct Server {
    router: Arc<Router>,
    handles: Vec<JoinHandle<()>>,
    metrics: Arc<PoolMetrics>,
    stop: Arc<AtomicBool>,
    swap: Arc<SwapSlot>,
}

impl Server {
    /// Production entry point: PJRT engines over the AOT artifacts.
    /// `recipe` may be uniform (`QuantConfig::to_recipe()`) or carry
    /// per-layer overrides.
    pub fn start(
        artifacts_dir: &str,
        model: &str,
        recipe: QuantRecipe,
        cfg: ServeConfig,
    ) -> Result<Server> {
        let factory = Arc::new(PjrtFactory {
            artifacts_dir: artifacts_dir.to_string(),
            model: model.to_string(),
            recipe,
            max_batch: cfg.max_batch,
        });
        Server::start_with(factory, cfg)
    }

    /// Start the pool over any backend (tests/CI use [`SimFactory`]).
    ///
    /// All workers build their engines concurrently; startup fails as a
    /// whole (with every thread joined) if any worker fails to come up.
    pub fn start_with(factory: Arc<dyn EngineFactory>, cfg: ServeConfig) -> Result<Server> {
        cfg.validate()?;
        let metrics = Arc::new(PoolMetrics::new(cfg.workers));
        let stop = Arc::new(AtomicBool::new(false));
        let swap = Arc::new(SwapSlot::default());
        let mut shards = Vec::with_capacity(cfg.workers);
        let mut handles = Vec::with_capacity(cfg.workers);
        let mut readies = Vec::with_capacity(cfg.workers);
        for id in 0..cfg.workers {
            let (tx, rx) = sync_channel::<Job>(cfg.queue_cap);
            let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);
            let outstanding = metrics.outstanding_handle(id);
            let worker_metrics = metrics.worker(id).clone();
            let worker_outstanding = outstanding.clone();
            let worker_factory = factory.clone();
            let worker_stop = stop.clone();
            let worker_swap = swap.clone();
            let worker_cfg = cfg.clone();
            let handle = std::thread::Builder::new()
                .name(format!("ocs-worker-{id}"))
                .spawn(move || {
                    worker_loop(
                        id,
                        worker_factory,
                        worker_cfg,
                        rx,
                        worker_metrics,
                        worker_outstanding,
                        worker_stop,
                        worker_swap,
                        ready_tx,
                    )
                })
                .context("spawn worker thread")?;
            shards.push(Shard { tx, outstanding });
            handles.push(handle);
            readies.push(ready_rx);
        }
        // readiness gate: surface any worker's setup error to the caller
        let mut first_err: Option<anyhow::Error> = None;
        for (id, ready) in readies.into_iter().enumerate() {
            let status = match ready.recv() {
                Ok(Ok(())) => Ok(()),
                Ok(Err(e)) => Err(e.context(format!("worker {id} setup"))),
                Err(_) => Err(anyhow!("worker {id} died during startup")),
            };
            if let Err(e) = status {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
        if let Some(e) = first_err {
            stop.store(true, Ordering::SeqCst);
            drop(shards); // disconnect every queue
            for h in handles {
                let _ = h.join();
            }
            return Err(e);
        }
        crate::info!(
            "engine pool up: {} × {} (queue cap {}/worker, max batch {}, deadline {:?})",
            cfg.workers,
            factory.label(),
            cfg.queue_cap,
            cfg.max_batch,
            cfg.deadline
        );
        let router = Arc::new(Router {
            shards,
            queue_cap: cfg.queue_cap,
            deadline: cfg.deadline,
            stop: stop.clone(),
            metrics: metrics.clone(),
        });
        Ok(Server {
            router,
            handles,
            metrics,
            stop,
            swap,
        })
    }

    /// Publish a new quantization recipe to every worker without
    /// restarting the pool. Workers apply it between batches (idle
    /// workers within one poll tick); requests already admitted or in
    /// flight drain on the old prep. Re-preparation goes through the
    /// process-wide [`crate::pipeline::PreparedCache`], so the pool
    /// pays one prepare per distinct recipe. A worker whose backend
    /// rejects the swap (or whose re-prepare fails) keeps serving the
    /// old prep and records a swap error.
    ///
    /// Returns immediately; poll [`Server::swaps_applied`] (against
    /// [`Server::worker_count`]) to observe the roll-out.
    ///
    /// Every distinct recipe ever served stays in the prepared-model
    /// cache (that is what makes swap-back instant); an operator cycling
    /// through many recipes on a long-lived process can reclaim the
    /// memory with [`crate::pipeline::PreparedCache::clear`] — in-flight
    /// preps stay alive through their `Arc`s.
    pub fn swap_recipe(&self, recipe: QuantRecipe) {
        crate::info!("publishing recipe swap: {}", recipe.label());
        let mut slot = self.swap.recipe.lock().expect("swap slot poisoned");
        *slot = Some(recipe);
        // bump after the recipe is in place: a worker that sees the new
        // epoch always reads the new recipe (it locks to read)
        self.swap.epoch.fetch_add(1, Ordering::Release);
    }

    /// Total recipe swaps applied across all workers (each successful
    /// [`Server::swap_recipe`] roll-out adds `worker_count()`).
    pub fn swaps_applied(&self) -> u64 {
        self.metrics.aggregate().recipe_swaps
    }

    pub fn client(&self) -> Client {
        Client {
            router: self.router.clone(),
            metrics: self.metrics.clone(),
        }
    }

    pub fn metrics(&self) -> &PoolMetrics {
        &self.metrics
    }

    pub fn worker_count(&self) -> usize {
        self.metrics.worker_count()
    }

    /// Graceful shutdown: reject new work, drain every admitted job,
    /// join all workers. Safe while `Client` handles are still alive —
    /// workers watch the stop flag, not just channel disconnection.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop.store(true, Ordering::SeqCst);
        let mut panicked = 0usize;
        for h in self.handles.drain(..) {
            if h.join().is_err() {
                panicked += 1;
            }
        }
        if panicked > 0 {
            bail!("{panicked} worker(s) panicked");
        }
        Ok(())
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One worker: build the engine on this thread, then batch-and-serve
/// until stopped (draining the queue first) or disconnected.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    id: usize,
    factory: Arc<dyn EngineFactory>,
    cfg: ServeConfig,
    rx: Receiver<Job>,
    metrics: Arc<Metrics>,
    outstanding: Arc<AtomicUsize>,
    stop: Arc<AtomicBool>,
    swap: Arc<SwapSlot>,
    ready: SyncSender<Result<()>>,
) {
    let mut engine = match factory.build(id) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    // epoch 0 = "no recipe ever published": starting from 0 (not the
    // current value) means a swap published while this worker was still
    // building is applied on its first loop iteration, not missed
    let mut swap_epoch = 0u64;
    loop {
        // apply any published recipe swap strictly between batches, so
        // in-flight work always completes on the prep it started with
        let epoch = swap.epoch.load(Ordering::Acquire);
        if epoch != swap_epoch {
            let (epoch, recipe) = {
                let slot = swap.recipe.lock().expect("swap slot poisoned");
                // re-read under the lock: the slot a worker acts on is
                // always at least as new as the epoch it records
                (swap.epoch.load(Ordering::Acquire), slot.clone())
            };
            swap_epoch = epoch;
            if let Some(recipe) = recipe {
                match engine.swap(&recipe) {
                    Ok(()) => {
                        metrics.record_recipe_swap();
                        crate::debugln!("worker {id}: recipe swapped to {}", recipe.label());
                    }
                    Err(e) => {
                        metrics.record_swap_error();
                        crate::warnln!(
                            "worker {id}: recipe swap failed, keeping the old prep: {e:#}"
                        );
                    }
                }
            }
        }
        // wait for the first job of a batch; wake periodically to honour
        // the stop flag (and recipe swaps) even while clients keep the
        // channel open. Jobs still queued at stop are returned by
        // recv_timeout before it ever times out, so the queue fully
        // drains first.
        let first = match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(j) => j,
            Err(RecvTimeoutError::Timeout) => {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => break, // all clients gone
        };
        let mut jobs = vec![first];
        let top_up_until = Instant::now() + cfg.max_wait;
        while jobs.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= top_up_until {
                break;
            }
            match rx.recv_timeout(top_up_until - now) {
                Ok(j) => jobs.push(j),
                Err(_) => break,
            }
        }
        run_batch(engine.as_mut(), jobs, &metrics, &outstanding);
    }
    // Final sweep: a dispatch that passed its stop check can still land
    // a job between our last empty recv and the channel teardown below;
    // answer it rather than dropping it with the queue.
    while let Ok(job) = rx.try_recv() {
        outstanding.fetch_sub(1, Ordering::Relaxed);
        let _ = job.resp.send(Err(anyhow!("server is shutting down")));
    }
    crate::debugln!("worker {id}: drained, exiting");
}

/// Answer expired jobs, execute the rest as one fused batch, respond to
/// every job, and keep the outstanding gauge exact.
fn run_batch(
    engine: &mut dyn WorkerEngine,
    jobs: Vec<Job>,
    metrics: &Metrics,
    outstanding: &AtomicUsize,
) {
    let now = Instant::now();
    let mut live = Vec::with_capacity(jobs.len());
    for job in jobs {
        match job.deadline {
            Some(d) if now >= d => {
                metrics.record_deadline_exceeded();
                let waited_ms = job.enqueued.elapsed().as_millis();
                let err = anyhow!("deadline exceeded after {waited_ms} ms in queue");
                // gauge drops before the send: the client unblocks on
                // the send, and must never observe a stale depth
                outstanding.fetch_sub(1, Ordering::Relaxed);
                let _ = job.resp.send(Err(err));
            }
            _ => live.push(job),
        }
    }
    if live.is_empty() {
        return;
    }
    let n = live.len();
    let result = (|| -> Result<TensorF> {
        for j in &live[1..] {
            if j.x.shape() != live[0].x.shape() {
                bail!(
                    "mixed input shapes in one batch: {:?} vs {:?}",
                    j.x.shape(),
                    live[0].x.shape()
                );
            }
        }
        let mut data = Vec::with_capacity(n * live[0].x.len());
        for j in &live {
            data.extend_from_slice(j.x.data());
        }
        let mut shape = live[0].x.shape().to_vec();
        shape[0] = n;
        let xb = TensorF::from_vec(&shape, data)?;
        let t0 = Instant::now();
        let out = engine.infer(&xb)?;
        metrics.record_batch(n, t0.elapsed().as_micros() as u64);
        Ok(out)
    })();
    match result {
        Ok(logits) => {
            let classes = logits.shape().get(1).copied().unwrap_or(0);
            for (row, job) in live.into_iter().enumerate() {
                let resp = if classes == 0 || (row + 1) * classes > logits.len() {
                    Err(anyhow!("engine returned too few logit rows"))
                } else {
                    Ok(logits.data()[row * classes..(row + 1) * classes].to_vec())
                };
                if resp.is_ok() {
                    metrics.record_request(job.enqueued.elapsed());
                }
                outstanding.fetch_sub(1, Ordering::Relaxed);
                let _ = job.resp.send(resp);
            }
        }
        Err(e) => {
            metrics.record_exec_error();
            let msg = format!("{e:#}");
            for job in live {
                outstanding.fetch_sub(1, Ordering::Relaxed);
                let _ = job.resp.send(Err(anyhow!(msg.clone())));
            }
        }
    }
}

/// One worker-sweep measurement (a row of `BENCH_serving.json`).
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub workers: usize,
    pub requests: usize,
    pub ok: usize,
    pub errors: usize,
    pub secs: f64,
    pub rps: f64,
    pub mean_latency_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub mean_batch: f64,
    pub rejected: u64,
    pub deadline_exceeded: u64,
}

/// Start a pool at `workers` shards, drive `requests` synthetic-image
/// requests through closed-loop clients, and collect the measurements.
pub fn run_point(
    factory: Arc<dyn EngineFactory>,
    cfg: &ServeConfig,
    workers: usize,
    requests: usize,
) -> Result<SweepPoint> {
    let server = Server::start_with(factory, cfg.clone().with_workers(workers))?;
    let dataset = crate::train::data::synth_images(256, 411);
    let row = dataset.x.len() / dataset.len();
    let mut req_shape = dataset.x.shape().to_vec();
    req_shape[0] = 1;
    let xdata = Arc::new(dataset.x.data().to_vec());
    let clients = (workers * 4).clamp(4, 32);
    let per = (requests / clients).max(1);
    let t0 = Instant::now();
    let mut client_threads = Vec::new();
    for c in 0..clients {
        let client = server.client();
        let xdata = xdata.clone();
        let shape = req_shape.clone();
        client_threads.push(std::thread::spawn(move || -> (usize, usize) {
            let mut ok = 0usize;
            let mut errors = 0usize;
            for i in 0..per {
                let idx = (c * per + i) % 256;
                let x = TensorF::from_vec(&shape, xdata[idx * row..(idx + 1) * row].to_vec());
                match x.map_err(anyhow::Error::from).and_then(|x| client.infer(x)) {
                    Ok(logits) if !logits.is_empty() => ok += 1,
                    _ => errors += 1,
                }
            }
            (ok, errors)
        }));
    }
    let mut ok = 0usize;
    let mut errors = 0usize;
    for h in client_threads {
        let (o, e) = h.join().map_err(|_| anyhow!("client thread panicked"))?;
        ok += o;
        errors += e;
    }
    let secs = t0.elapsed().as_secs_f64();
    let agg = server.metrics().aggregate();
    let point = SweepPoint {
        workers,
        requests: clients * per,
        ok,
        errors,
        secs,
        rps: ok as f64 / secs.max(1e-9),
        mean_latency_ms: agg.mean_latency_us() / 1e3,
        p50_ms: agg.latency_percentile_us(0.5) as f64 / 1e3,
        p99_ms: agg.latency_percentile_us(0.99) as f64 / 1e3,
        mean_batch: agg.mean_batch(),
        rejected: server.metrics().rejected_count(),
        deadline_exceeded: agg.deadline_exceeded,
    };
    println!("{}", server.metrics().report());
    server.shutdown()?;
    Ok(point)
}

/// Serialize sweep results as a versioned [`BenchRecord`] (`serving`
/// tag) — the format `ocs bench diff`/`check` read back; one row per
/// swept worker count with throughput as the gated metric.
///
/// [`BenchRecord`]: crate::bench_record::BenchRecord
pub fn sweep_json(backend_label: &str, points: &[SweepPoint]) -> String {
    crate::bench_record::BenchRecord::from_sweep(backend_label, points).to_json()
}

/// Drive a worker sweep over any backend; prints one line per point and
/// optionally writes `BENCH_serving.json`-style output.
pub fn self_test_with(
    factory: Arc<dyn EngineFactory>,
    cfg: &ServeConfig,
    requests: usize,
    sweep: &[usize],
    json_out: Option<&Path>,
) -> Result<Vec<SweepPoint>> {
    let sweep: Vec<usize> = if sweep.is_empty() {
        vec![cfg.workers]
    } else {
        sweep.to_vec()
    };
    let label = factory.label();
    let mut points = Vec::with_capacity(sweep.len());
    for &workers in &sweep {
        let p = run_point(factory.clone(), cfg, workers, requests)?;
        println!(
            "self-test[workers={workers}]: {}/{} ok in {:.2}s = {:.0} req/s \
             (p50 {:.2} ms, p99 {:.2} ms, mean batch {:.1})",
            p.ok, p.requests, p.secs, p.rps, p.p50_ms, p.p99_ms, p.mean_batch
        );
        points.push(p);
    }
    if let Some(path) = json_out {
        std::fs::write(path, sweep_json(&label, &points))
            .with_context(|| format!("write {}", path.display()))?;
        println!("wrote {}", path.display());
    }
    Ok(points)
}

/// End-to-end self-test over the real PJRT stack (used by `ocs serve`).
pub fn self_test(
    artifacts_dir: &str,
    model: &str,
    recipe: QuantRecipe,
    requests: usize,
    cfg: &ServeConfig,
    sweep: &[usize],
    json_out: Option<&Path>,
) -> Result<()> {
    let factory = Arc::new(PjrtFactory {
        artifacts_dir: artifacts_dir.to_string(),
        model: model.to_string(),
        recipe,
        max_batch: cfg.max_batch,
    });
    self_test_with(factory, cfg, requests, sweep, json_out).map(|_| ())
}

/// Self-test over the synthetic backend — no artifacts or PJRT needed
/// (this is what CI's serving smoke job runs).
pub fn self_test_sim(
    requests: usize,
    cfg: &ServeConfig,
    sweep: &[usize],
    json_out: Option<&Path>,
) -> Result<()> {
    let factory = Arc::new(SimFactory::default());
    self_test_with(factory, cfg, requests, sweep, json_out).map(|_| ())
}
