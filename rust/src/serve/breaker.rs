//! Per-tenant circuit breaker: fault isolation on the tenant axis.
//!
//! PR 8's supervisor isolates faults on the *worker* axis — a panicking
//! engine is respawned, and after `--restart-max` give-ups that shard's
//! breaker opens. But the pool partitions every batch into
//! single-tenant groups, so a tenant whose recipe reliably panics the
//! engine (a bad autotune artifact, a pathological hot-swap) will burn
//! each worker's restart budget in turn and take the whole fleet down,
//! one shard at a time. The [`TenantBreaker`] classifies contained
//! failures by the tenant group that was executing and quarantines the
//! *tenant* at the router long before any worker breaker opens.
//!
//! Mechanics, per tenant:
//!
//! - **Strikes with windowed decay.** Every contained failure
//!   attributed to the tenant (panicking batch group, aborted recipe
//!   sync) records a timestamped strike; strikes older than the decay
//!   window are dropped before counting, so a long-lived tenant with a
//!   rare fault never accumulates its way into quarantine.
//! - **Quarantine.** At `max_strikes` live strikes the breaker opens:
//!   the router rejects the tenant's requests with a `tenant
//!   quarantined` error (or reroutes them to the default prep under
//!   `--tenant-fallback`) for the configured quarantine window.
//! - **Half-open probe.** Once the window elapses, exactly one request
//!   is re-admitted as a probe on the tenant's own prep. If it is
//!   answered `Ok` the breaker closes and traffic resumes; any failure
//!   (engine error, contained panic, deadline) re-arms the full
//!   quarantine window.
//!
//! The admit fast path is a single relaxed atomic load for healthy
//! tenants; the per-tenant mutex is only touched while a breaker is
//! open or a strike is being recorded.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Router-side admission decision for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Breaker closed: dispatch normally.
    Admit,
    /// Breaker half-open: this request is the single re-admission
    /// probe. Dispatch it on the tenant's own prep and report the
    /// outcome via [`TenantBreaker::resolve_probe`].
    Probe,
    /// Breaker open: reject (or reroute to the default prep).
    Quarantined,
}

#[derive(Debug, Default)]
struct TenantState {
    /// Timestamps of live strikes (decayed lazily on record).
    strikes: Vec<Instant>,
    /// While `Some`, the tenant is quarantined until the deadline; a
    /// deadline in the past means half-open (awaiting a probe).
    until: Option<Instant>,
    probe_in_flight: bool,
}

#[derive(Debug)]
struct Slot {
    /// Fast-path flag mirroring `state.until.is_some()`.
    open: AtomicBool,
    state: Mutex<TenantState>,
}

/// Windowed-decay strike counter + quarantine latch per tenant. Shared
/// between the router (admission) and every worker (strike recording).
#[derive(Debug)]
pub struct TenantBreaker {
    max_strikes: u32,
    window: Duration,
    quarantine: Duration,
    slots: Vec<Slot>,
}

impl TenantBreaker {
    /// `max_strikes` live strikes inside `window` quarantine a tenant
    /// for `quarantine`.
    pub fn new(
        tenants: usize,
        max_strikes: u32,
        window: Duration,
        quarantine: Duration,
    ) -> TenantBreaker {
        assert!(max_strikes >= 1, "max_strikes must be >= 1");
        TenantBreaker {
            max_strikes,
            window,
            quarantine,
            slots: (0..tenants)
                .map(|_| Slot {
                    open: AtomicBool::new(false),
                    state: Mutex::new(TenantState::default()),
                })
                .collect(),
        }
    }

    pub fn tenant_count(&self) -> usize {
        self.slots.len()
    }

    /// Record one contained failure attributed to `tenant`. Returns
    /// `true` when this strike newly opened the breaker (the caller
    /// logs the quarantine once instead of per strike).
    pub fn record_strike(&self, tenant: usize) -> bool {
        let slot = &self.slots[tenant];
        let mut st = slot.state.lock().unwrap_or_else(|e| e.into_inner());
        let now = Instant::now();
        if st.until.is_some() {
            // Already quarantined (e.g. in-flight jobs from before the
            // trip still failing): the open window is deliberately NOT
            // extended, so a burst of queued failures can't push the
            // half-open probe out indefinitely.
            return false;
        }
        st.strikes.retain(|t| now.duration_since(*t) < self.window);
        st.strikes.push(now);
        if st.strikes.len() >= self.max_strikes as usize {
            st.strikes.clear();
            st.until = Some(now + self.quarantine);
            st.probe_in_flight = false;
            slot.open.store(true, Ordering::Release);
            return true;
        }
        false
    }

    /// Admission decision for one request from `tenant`.
    pub fn admit(&self, tenant: usize) -> Admission {
        let slot = &self.slots[tenant];
        if !slot.open.load(Ordering::Acquire) {
            return Admission::Admit;
        }
        let mut st = slot.state.lock().unwrap_or_else(|e| e.into_inner());
        let Some(until) = st.until else {
            // Raced with a concurrent close: the breaker shut between
            // the fast-path load and the lock.
            return Admission::Admit;
        };
        if Instant::now() < until || st.probe_in_flight {
            return Admission::Quarantined;
        }
        st.probe_in_flight = true;
        Admission::Probe
    }

    /// Report the outcome of a half-open probe: `ok` closes the breaker
    /// and resumes traffic; a failed probe re-arms the full quarantine
    /// window.
    pub fn resolve_probe(&self, tenant: usize, ok: bool) {
        let slot = &self.slots[tenant];
        let mut st = slot.state.lock().unwrap_or_else(|e| e.into_inner());
        st.probe_in_flight = false;
        if ok {
            st.until = None;
            st.strikes.clear();
            slot.open.store(false, Ordering::Release);
        } else {
            st.until = Some(Instant::now() + self.quarantine);
        }
    }

    /// Whether `tenant`'s breaker is currently open (quarantined or
    /// half-open awaiting a probe).
    pub fn is_open(&self, tenant: usize) -> bool {
        self.slots[tenant].open.load(Ordering::Acquire)
    }

    /// Live (undecayed) strike count — observability only.
    pub fn strike_count(&self, tenant: usize) -> usize {
        let slot = &self.slots[tenant];
        let st = slot.state.lock().unwrap_or_else(|e| e.into_inner());
        let now = Instant::now();
        st.strikes
            .iter()
            .filter(|t| now.duration_since(**t) < self.window)
            .count()
    }

    /// Tenants whose breaker is currently open.
    pub fn open_count(&self) -> usize {
        (0..self.slots.len()).filter(|&t| self.is_open(t)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread::sleep;

    fn breaker(max: u32, window_ms: u64, quarantine_ms: u64) -> TenantBreaker {
        TenantBreaker::new(
            2,
            max,
            Duration::from_millis(window_ms),
            Duration::from_millis(quarantine_ms),
        )
    }

    #[test]
    fn strikes_below_threshold_keep_admitting() {
        let b = breaker(3, 1_000, 50);
        assert!(!b.record_strike(1));
        assert!(!b.record_strike(1));
        assert_eq!(b.strike_count(1), 2);
        assert_eq!(b.admit(1), Admission::Admit);
        assert_eq!(b.admit(0), Admission::Admit, "siblings unaffected");
        assert_eq!(b.open_count(), 0);
    }

    #[test]
    fn threshold_trips_once_and_quarantines() {
        let b = breaker(2, 1_000, 10_000);
        assert!(!b.record_strike(1));
        assert!(b.record_strike(1), "the tripping strike reports the trip");
        assert!(!b.record_strike(1), "strikes while open don't re-trip");
        assert!(b.is_open(1));
        assert_eq!(b.admit(1), Admission::Quarantined);
        assert_eq!(b.admit(0), Admission::Admit, "siblings unaffected");
        assert_eq!(b.open_count(), 1);
    }

    #[test]
    fn strikes_decay_outside_the_window() {
        let b = breaker(2, 30, 10_000);
        assert!(!b.record_strike(1));
        sleep(Duration::from_millis(40));
        assert_eq!(b.strike_count(1), 0, "old strike decayed");
        // the decayed strike no longer counts toward the threshold
        assert!(!b.record_strike(1));
        assert!(!b.is_open(1));
    }

    #[test]
    fn half_open_admits_exactly_one_probe() {
        let b = breaker(1, 1_000, 20);
        assert!(b.record_strike(1));
        assert_eq!(b.admit(1), Admission::Quarantined);
        sleep(Duration::from_millis(30));
        assert_eq!(b.admit(1), Admission::Probe, "window elapsed: half-open");
        assert_eq!(b.admit(1), Admission::Quarantined, "only one probe at a time");
        // a successful probe closes the breaker for good
        b.resolve_probe(1, true);
        assert!(!b.is_open(1));
        assert_eq!(b.admit(1), Admission::Admit);
    }

    #[test]
    fn failed_probe_rearms_the_quarantine() {
        let b = breaker(1, 1_000, 25);
        assert!(b.record_strike(1));
        sleep(Duration::from_millis(35));
        assert_eq!(b.admit(1), Admission::Probe);
        b.resolve_probe(1, false);
        assert!(b.is_open(1));
        assert_eq!(b.admit(1), Admission::Quarantined, "window re-armed");
        sleep(Duration::from_millis(35));
        assert_eq!(b.admit(1), Admission::Probe, "and re-opens half-way again");
    }
}
