//! Deterministic fault injection for the serving pool.
//!
//! A [`FaultPlan`] is a small schedule of failures — build failures,
//! panics, slowdowns, per-tenant errors — that wraps any
//! [`EngineFactory`] ([`FaultPlan::wrap`]) so the *same* supervision
//! and containment machinery can be exercised on every backend (sim,
//! quant-sim, native, PJRT) without teaching the backends anything
//! about failure. The schedule is fully deterministic: a directive
//! names the worker/batch/tenant it strikes, and one-shot directives
//! fire exactly once pool-wide (shared across respawns of the same
//! worker), so a killed worker's replacement serves cleanly — which is
//! what lets the chaos loadtest assert *recovery*, not just failure.
//!
//! Plans parse from `--fault` (comma-separated directives) or the TOML
//! `[serve] fault = "..."` key:
//!
//! ```text
//! build-fail:W[@N]     worker W's Nth engine build fails (default N=1,
//!                      i.e. startup; N=2 is the first respawn rebuild)
//! panic:W@N            worker W panics on its Nth forward batch
//! slow:US              every forward batch sleeps US microseconds first
//! error-tenant:NAME    every batch for tenant NAME returns an error
//! panic-tenant:NAME    every batch for tenant NAME panics (persistent:
//!                      the crash-looping-tenant drill — stops hurting
//!                      only once the tenant breaker quarantines NAME)
//! panic-on-sync:NAME@N the Nth recipe sync for tenant NAME (counted
//!                      pool-wide across workers) panics mid-swap —
//!                      the transactional-swap drill
//! ```
//!
//! An empty plan wraps to the inner factory unchanged, so the
//! fault-free serving path is bit-identical to a build without this
//! module in the loop.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::cli::Args;
use crate::pipeline::QuantRecipe;
use crate::tensor::TensorF;
use crate::util::toml::Config;

use super::backend::{EngineFactory, TenantCtx, WorkerEngine};

/// One scheduled failure. `worker` indexes the pool's shards; `nth`
/// counts from 1 on the directive's own clock (builds for
/// [`FaultDirective::BuildFail`], forward batches for
/// [`FaultDirective::PanicOnBatch`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FaultDirective {
    /// Worker `worker`'s `nth` engine build fails (fires once).
    BuildFail { worker: usize, nth: u64 },
    /// Worker `worker` panics on its `nth` forward batch (fires once,
    /// pool-wide: the respawned worker serves cleanly).
    PanicOnBatch { worker: usize, nth: u64 },
    /// Every forward batch sleeps this long before executing.
    SlowInfer { micros: u64 },
    /// Every batch for this tenant returns an error (siblings
    /// untouched).
    ErrorOnTenant { tenant: String },
    /// Every batch for this tenant *panics* (persistent, killing the
    /// executing worker each time): the crash-looping tenant that only
    /// the per-tenant breaker can stop.
    PanicOnTenant { tenant: String },
    /// The `nth` recipe sync for this tenant — counted pool-wide
    /// across workers — panics mid-`swap_tenant` (fires once). Drills
    /// the hot-swap transaction: the struck worker must roll back to
    /// its previous executable, not die or serve a half-applied prep.
    PanicOnSync { tenant: String, nth: u64 },
}

impl FaultDirective {
    fn parse(entry: &str) -> Result<FaultDirective> {
        let (kind, rest) = entry
            .split_once(':')
            .with_context(|| format!("fault '{entry}': expected KIND:ARGS"))?;
        match kind {
            "build-fail" => {
                let (worker, nth) = parse_worker_at(rest, 1)
                    .with_context(|| format!("fault '{entry}': expected build-fail:W[@N]"))?;
                Ok(FaultDirective::BuildFail { worker, nth })
            }
            "panic" => {
                let (worker, nth) = parse_worker_at(rest, 0)
                    .with_context(|| format!("fault '{entry}': expected panic:W@N"))?;
                if nth == 0 {
                    bail!("fault '{entry}': panic needs an explicit batch, panic:W@N with N >= 1");
                }
                Ok(FaultDirective::PanicOnBatch { worker, nth })
            }
            "slow" => {
                let micros: u64 = rest
                    .parse()
                    .with_context(|| format!("fault '{entry}': expected slow:MICROS"))?;
                Ok(FaultDirective::SlowInfer { micros })
            }
            "error-tenant" => {
                if rest.is_empty() {
                    bail!("fault '{entry}': expected error-tenant:NAME");
                }
                Ok(FaultDirective::ErrorOnTenant {
                    tenant: rest.to_string(),
                })
            }
            "panic-tenant" => {
                if rest.is_empty() {
                    bail!("fault '{entry}': expected panic-tenant:NAME");
                }
                Ok(FaultDirective::PanicOnTenant {
                    tenant: rest.to_string(),
                })
            }
            "panic-on-sync" => {
                let (tenant, nth) = rest.split_once('@').with_context(|| {
                    format!("fault '{entry}': expected panic-on-sync:TENANT@N")
                })?;
                let nth: u64 = nth
                    .parse()
                    .with_context(|| format!("fault '{entry}': N must be an integer"))?;
                if tenant.is_empty() {
                    bail!("fault '{entry}': expected panic-on-sync:TENANT@N, empty tenant");
                }
                if nth == 0 {
                    bail!("fault '{entry}': panic-on-sync counts syncs from 1, N >= 1");
                }
                Ok(FaultDirective::PanicOnSync {
                    tenant: tenant.to_string(),
                    nth,
                })
            }
            other => bail!(
                "unknown fault kind '{other}' \
                 (build-fail:W[@N] | panic:W@N | slow:US | error-tenant:NAME \
                  | panic-tenant:NAME | panic-on-sync:TENANT@N)"
            ),
        }
    }

    fn label(&self) -> String {
        match self {
            FaultDirective::BuildFail { worker, nth } => format!("build-fail:{worker}@{nth}"),
            FaultDirective::PanicOnBatch { worker, nth } => format!("panic:{worker}@{nth}"),
            FaultDirective::SlowInfer { micros } => format!("slow:{micros}"),
            FaultDirective::ErrorOnTenant { tenant } => format!("error-tenant:{tenant}"),
            FaultDirective::PanicOnTenant { tenant } => format!("panic-tenant:{tenant}"),
            FaultDirective::PanicOnSync { tenant, nth } => {
                format!("panic-on-sync:{tenant}@{nth}")
            }
        }
    }
}

/// `W` or `W@N`; `default_nth` of 0 means `@N` is required.
fn parse_worker_at(s: &str, default_nth: u64) -> Result<(usize, u64)> {
    match s.split_once('@') {
        Some((w, n)) => Ok((w.parse()?, n.parse()?)),
        None => Ok((s.parse()?, default_nth)),
    }
}

/// A deterministic failure schedule for one pool run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    directives: Vec<FaultDirective>,
}

impl FaultPlan {
    pub fn new(directives: Vec<FaultDirective>) -> FaultPlan {
        FaultPlan { directives }
    }

    /// Parse a comma-separated directive list (see the module docs).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut directives = Vec::new();
        for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            directives.push(FaultDirective::parse(entry)?);
        }
        Ok(FaultPlan { directives })
    }

    /// The `--fault SPECS` CLI knob (absent = empty plan).
    pub fn from_args(args: &Args) -> Result<FaultPlan> {
        match args.str("fault") {
            Some(spec) => FaultPlan::parse(spec).context("bad --fault"),
            None => Ok(FaultPlan::default()),
        }
    }

    /// The TOML `fault = "..."` key of a `[serve]`-style section.
    pub fn from_toml(c: &Config, section: &str) -> Result<FaultPlan> {
        let key = if section.is_empty() {
            "fault".to_string()
        } else {
            format!("{section}.fault")
        };
        match c.get(&key) {
            Some(_) => FaultPlan::parse(c.str(&key)?).with_context(|| format!("bad {key}")),
            None => Ok(FaultPlan::default()),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.directives.is_empty()
    }

    pub fn label(&self) -> String {
        self.directives
            .iter()
            .map(FaultDirective::label)
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Wrap a factory so its engines fail on this schedule. An empty
    /// plan returns the inner factory untouched — the fault-free path
    /// never pays for (or risks) the wrapper.
    pub fn wrap(self, inner: Arc<dyn EngineFactory>) -> Arc<dyn EngineFactory> {
        if self.is_empty() {
            return inner;
        }
        let fired = Arc::new(FaultState {
            fired: (0..self.directives.len()).map(|_| AtomicBool::new(false)).collect(),
            builds: Mutex::new(HashMap::new()),
            syncs: Mutex::new(HashMap::new()),
        });
        Arc::new(FaultFactory {
            inner,
            plan: self,
            state: fired,
        })
    }
}

/// Pool-wide firing state shared by every worker (and every respawn):
/// one-shot directives consult `fired`, build-count directives consult
/// the per-worker `builds` clock.
struct FaultState {
    fired: Vec<AtomicBool>,
    builds: Mutex<HashMap<usize, u64>>,
    /// Pool-wide recipe-sync clock per tenant name (every worker's
    /// `swap_tenant` for the tenant ticks the same counter).
    syncs: Mutex<HashMap<String, u64>>,
}

impl FaultState {
    /// True exactly once per directive index.
    fn fire_once(&self, i: usize) -> bool {
        !self.fired[i].swap(true, Ordering::SeqCst)
    }
}

/// [`EngineFactory`] wrapper that injects the plan's build failures and
/// hands out [`FaultWorker`]s for the rest.
struct FaultFactory {
    inner: Arc<dyn EngineFactory>,
    plan: FaultPlan,
    state: Arc<FaultState>,
}

impl EngineFactory for FaultFactory {
    fn build(&self, worker_id: usize) -> Result<Box<dyn WorkerEngine>> {
        let build_no = {
            let mut builds = self.state.builds.lock().unwrap_or_else(|e| e.into_inner());
            let n = builds.entry(worker_id).or_insert(0);
            *n += 1;
            *n
        };
        for (i, d) in self.plan.directives.iter().enumerate() {
            if let FaultDirective::BuildFail { worker, nth } = d {
                if *worker == worker_id && build_no == *nth && self.state.fire_once(i) {
                    bail!("fault injection: worker {worker_id} build #{build_no} fails");
                }
            }
        }
        let inner = self.inner.build(worker_id)?;
        Ok(Box::new(FaultWorker {
            inner,
            worker_id,
            batches: 0,
            plan: self.plan.clone(),
            state: self.state.clone(),
        }))
    }

    fn label(&self) -> String {
        format!("{}+fault[{}]", self.inner.label(), self.plan.label())
    }
}

/// [`WorkerEngine`] wrapper executing the plan's runtime directives.
/// `batches` is this *engine instance*'s forward count — a respawned
/// worker starts a fresh clock, but one-shot panics are spent
/// pool-wide, so it serves cleanly.
struct FaultWorker {
    inner: Box<dyn WorkerEngine>,
    worker_id: usize,
    batches: u64,
    plan: FaultPlan,
    state: Arc<FaultState>,
}

impl FaultWorker {
    fn before_batch(&mut self, tenant: Option<&TenantCtx>) -> Result<()> {
        self.batches += 1;
        for (i, d) in self.plan.directives.iter().enumerate() {
            match d {
                FaultDirective::PanicOnBatch { worker, nth }
                    if *worker == self.worker_id
                        && self.batches >= *nth
                        && self.state.fire_once(i) =>
                {
                    panic!(
                        "fault injection: worker {} panics on batch {}",
                        self.worker_id, self.batches
                    );
                }
                FaultDirective::SlowInfer { micros } => {
                    std::thread::sleep(Duration::from_micros(*micros));
                }
                FaultDirective::ErrorOnTenant { tenant: name } => {
                    if tenant.is_some_and(|t| t.name == name.as_str()) {
                        bail!("fault injection: tenant '{name}' errors");
                    }
                }
                FaultDirective::PanicOnTenant { tenant: name } => {
                    if tenant.is_some_and(|t| t.name == name.as_str()) {
                        panic!(
                            "fault injection: tenant '{name}' panics worker {}",
                            self.worker_id
                        );
                    }
                }
                _ => {}
            }
        }
        Ok(())
    }
}

impl WorkerEngine for FaultWorker {
    fn infer(&mut self, batch: &TensorF) -> Result<TensorF> {
        self.before_batch(None)?;
        self.inner.infer(batch)
    }

    fn infer_tenant(&mut self, t: &TenantCtx, batch: &TensorF) -> Result<TensorF> {
        self.before_batch(Some(t))?;
        self.inner.infer_tenant(t, batch)
    }

    fn swap(&mut self, recipe: &QuantRecipe) -> Result<()> {
        self.inner.swap(recipe)
    }

    fn swap_tenant(&mut self, t: &TenantCtx, recipe: &QuantRecipe) -> Result<()> {
        let sync_no = {
            let mut syncs = self.state.syncs.lock().unwrap_or_else(|e| e.into_inner());
            let n = syncs.entry(t.name.to_string()).or_insert(0);
            *n += 1;
            *n
        };
        for (i, d) in self.plan.directives.iter().enumerate() {
            if let FaultDirective::PanicOnSync { tenant, nth } = d {
                if tenant.as_str() == t.name && sync_no >= *nth && self.state.fire_once(i) {
                    panic!(
                        "fault injection: tenant '{}' sync #{sync_no} panics on worker {}",
                        t.name, self.worker_id
                    );
                }
            }
        }
        self.inner.swap_tenant(t, recipe)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::backend::SimFactory;

    #[test]
    fn parse_round_trips() {
        let p = FaultPlan::parse(
            "build-fail:0, panic:2@5, slow:300, error-tenant:gold, panic-tenant:lead, \
             panic-on-sync:gold@2",
        )
        .unwrap();
        assert_eq!(
            p,
            FaultPlan::new(vec![
                FaultDirective::BuildFail { worker: 0, nth: 1 },
                FaultDirective::PanicOnBatch { worker: 2, nth: 5 },
                FaultDirective::SlowInfer { micros: 300 },
                FaultDirective::ErrorOnTenant { tenant: "gold".into() },
                FaultDirective::PanicOnTenant { tenant: "lead".into() },
                FaultDirective::PanicOnSync { tenant: "gold".into(), nth: 2 },
            ])
        );
        assert_eq!(
            p.label(),
            "build-fail:0@1,panic:2@5,slow:300,error-tenant:gold,panic-tenant:lead,\
             panic-on-sync:gold@2"
        );
        // label parses back to the same plan
        assert_eq!(FaultPlan::parse(&p.label()).unwrap(), p);
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert_eq!(
            FaultPlan::parse("build-fail:3@2").unwrap(),
            FaultPlan::new(vec![FaultDirective::BuildFail { worker: 3, nth: 2 }])
        );
    }

    #[test]
    fn parse_rejects_malformed_entries() {
        for bad in [
            "panic:1",             // panic needs @N
            "panic:",              // empty args
            "panic:x@1",           // bad worker
            "panic:1@x",           // bad batch
            "panic:1@",            // empty batch
            "panic:0@0",           // batches count from 1
            "slow:abc",            // bad micros
            "slow:",               // empty micros
            "slow:-5",             // negative micros
            "error-tenant:",       // empty name
            "panic-tenant:",       // empty name
            "explode:1",           // unknown kind
            "panic",               // no args
            "build-fail:x",        // bad worker
            "build-fail:1@x",      // bad build clock
            "panic-on-sync:gold",  // sync needs @N
            "panic-on-sync:@2",    // empty tenant
            "panic-on-sync:gold@", // empty sync clock
            "panic-on-sync:gold@0", // syncs count from 1
            "panic-on-sync:gold@x", // bad sync clock
        ] {
            let err = FaultPlan::parse(bad);
            assert!(err.is_err(), "'{bad}' should not parse");
            // errors are actionable: they name the offending entry
            let msg = format!("{:#}", err.unwrap_err());
            let head = bad.split(':').next().unwrap();
            assert!(msg.contains(head), "error for '{bad}' names the entry: {msg}");
        }
    }

    #[test]
    fn empty_plan_wraps_to_inner() {
        let inner: Arc<dyn EngineFactory> = Arc::new(SimFactory::default());
        let label = inner.label();
        let wrapped = FaultPlan::default().wrap(inner);
        assert_eq!(wrapped.label(), label, "no wrapper on the fault-free path");
        let faulty = FaultPlan::parse("slow:10").unwrap().wrap(wrapped);
        assert!(faulty.label().contains("+fault[slow:10]"), "{}", faulty.label());
    }

    #[test]
    fn build_fail_hits_the_named_build_once() {
        let plan = FaultPlan::parse("build-fail:1@2").unwrap();
        let f = plan.wrap(Arc::new(SimFactory::default()));
        assert!(f.build(0).is_ok(), "other workers untouched");
        assert!(f.build(1).is_ok(), "build #1 is clean");
        let err = f.build(1).unwrap_err().to_string();
        assert!(err.contains("fault injection"), "{err}");
        assert!(f.build(1).is_ok(), "fires once: build #3 is clean");
    }

    #[test]
    fn error_on_tenant_spares_siblings() {
        let plan = FaultPlan::parse("error-tenant:gold").unwrap();
        let f = plan.wrap(Arc::new(SimFactory::default()));
        let mut e = f.build(0).unwrap();
        let x = TensorF::zeros(&[1, 4]);
        let gold = TenantCtx { id: 1, name: "gold", recipe: None };
        let bulk = TenantCtx { id: 2, name: "bulk", recipe: None };
        assert!(e.infer_tenant(&gold, &x).is_err());
        assert!(e.infer_tenant(&bulk, &x).is_ok());
        assert!(e.infer_tenant(&gold, &x).is_err(), "persistent, not one-shot");
    }

    #[test]
    fn panic_tenant_is_persistent_and_spares_siblings() {
        let plan = FaultPlan::parse("panic-tenant:gold").unwrap();
        let f = plan.wrap(Arc::new(SimFactory::default()));
        let mut e = f.build(0).unwrap();
        let x = TensorF::zeros(&[1, 4]);
        let gold = TenantCtx { id: 1, name: "gold", recipe: None };
        let bulk = TenantCtx { id: 2, name: "bulk", recipe: None };
        assert!(e.infer_tenant(&bulk, &x).is_ok(), "siblings untouched");
        let p = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            e.infer_tenant(&gold, &x)
        }));
        assert!(p.is_err(), "gold batch panics");
        // persistent across respawns: the replacement engine panics too
        let mut e2 = f.build(0).unwrap();
        let p = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            e2.infer_tenant(&gold, &x)
        }));
        assert!(p.is_err(), "not one-shot: only the tenant breaker stops it");
        assert!(e2.infer_tenant(&bulk, &x).is_ok());
    }

    #[test]
    fn panic_on_batch_fires_once_pool_wide() {
        let plan = FaultPlan::parse("panic:0@2").unwrap();
        let f = plan.wrap(Arc::new(SimFactory::default()));
        let mut e = f.build(0).unwrap();
        let x = TensorF::zeros(&[1, 4]);
        assert!(e.infer(&x).is_ok(), "batch 1 clean");
        let p = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| e.infer(&x)));
        assert!(p.is_err(), "batch 2 panics");
        // the respawned engine shares the spent one-shot state
        let mut e2 = f.build(0).unwrap();
        assert!(e2.infer(&x).is_ok());
        assert!(e2.infer(&x).is_ok(), "replacement never re-fires");
    }

    #[test]
    fn panic_on_sync_hits_the_named_tenant_sync_once() {
        use crate::pipeline::QuantRecipe;
        let plan = FaultPlan::parse("panic-on-sync:gold@2").unwrap();
        let f = plan.wrap(Arc::new(SimFactory::default()));
        let mut e = f.build(0).unwrap();
        let r = QuantRecipe::default();
        let gold = TenantCtx { id: 1, name: "gold", recipe: None };
        let bulk = TenantCtx { id: 2, name: "bulk", recipe: None };
        assert!(e.swap_tenant(&gold, &r).is_ok(), "sync #1 clean");
        assert!(e.swap_tenant(&bulk, &r).is_ok(), "siblings have their own clock");
        let p = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            e.swap_tenant(&gold, &r)
        }));
        assert!(p.is_err(), "gold sync #2 panics");
        // one-shot pool-wide: another worker's engine syncs cleanly
        let mut e2 = f.build(1).unwrap();
        assert!(e2.swap_tenant(&gold, &r).is_ok(), "fires once pool-wide");
    }
}
