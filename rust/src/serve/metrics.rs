//! Lock-free serving metrics, sharded per worker with an aggregate view.
//!
//! Each worker owns an `Arc<Metrics>` it alone writes (plain relaxed
//! atomics — no locks on the request path); the pool-level
//! [`PoolMetrics`] holds all of them plus router-side counters
//! (rejections, dispatch count, per-shard queue-depth gauges) and
//! produces a summed [`Snapshot`] on demand by reading every shard.
//!
//! Batch accounting is kept honest by recording at two ranks:
//! [`Metrics::record_batch`] once per forward pass and
//! [`Metrics::record_request`] once per answered request. That yields
//! two distinct means — see [`Snapshot::mean_batch`] (per-batch) vs
//! [`Snapshot::mean_batch_weighted`] (what a random *request* saw) —
//! which the previous single-counter scheme conflated.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Exponential latency buckets in µs: <64, <128, ..., <2^25 (~33 s).
const BUCKETS: usize = 20;
const BASE_US: u64 = 64;
/// Exponential batch-size buckets: <=1, <=2, <=4, ..., <=2048.
const BATCH_BUCKETS: usize = 12;

/// One shard's counters. Worker shards are written by exactly one
/// thread; the per-tenant shards in [`PoolMetrics`] reuse this struct
/// with multiple writers — every counter is a plain atomic, so that is
/// merely contended, never racy. Read by any thread.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub exec_us_total: AtomicU64,
    pub latency_us_total: AtomicU64,
    pub latency_us_max: AtomicU64,
    /// Σ batch size over batches (== requests that went through a pass).
    pub batch_items_total: AtomicU64,
    /// Σ batch size² over batches (request-weighted mean numerator).
    pub batch_items_sq_total: AtomicU64,
    pub deadline_exceeded: AtomicU64,
    pub exec_errors: AtomicU64,
    /// Recipe hot-swaps this worker applied (see `serve::Server::swap_recipe`).
    pub recipe_swaps: AtomicU64,
    /// Hot-swaps this worker failed to apply (kept serving the old prep).
    pub swap_errors: AtomicU64,
    /// Hot-swaps that *panicked* mid-sync and were rolled back — the
    /// worker stayed alive on its previous lowered executable (a subset
    /// of neither `swap_errors` nor `panics`: counted separately so the
    /// transactional-swap drill can assert on it).
    pub swap_aborts: AtomicU64,
    /// Engine panics contained on this worker (build or infer).
    pub panics: AtomicU64,
    /// Supervisor respawn attempts for this worker.
    pub restarts: AtomicU64,
    /// Jobs answered with an error because this worker died (in-flight
    /// at the panic, queued behind it, or drained at give-up).
    pub jobs_failed: AtomicU64,
    latency_buckets: [AtomicU64; BUCKETS],
    batch_buckets: [AtomicU64; BATCH_BUCKETS],
}

fn latency_bucket(us: u64) -> usize {
    let mut b = 0usize;
    let mut edge = BASE_US;
    while b + 1 < BUCKETS && us >= edge {
        edge *= 2;
        b += 1;
    }
    b
}

fn batch_bucket(n: usize) -> usize {
    let mut b = 0usize;
    let mut edge = 1usize;
    while b + 1 < BATCH_BUCKETS && n > edge {
        edge *= 2;
        b += 1;
    }
    b
}

impl Metrics {
    /// One request answered successfully; `latency` is enqueue→response.
    pub fn record_request(&self, latency: Duration) {
        let us = latency.as_micros() as u64;
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.latency_us_total.fetch_add(us, Ordering::Relaxed);
        self.latency_us_max.fetch_max(us, Ordering::Relaxed);
        self.latency_buckets[latency_bucket(us)].fetch_add(1, Ordering::Relaxed);
    }

    /// One forward pass over `n` fused requests.
    pub fn record_batch(&self, n: usize, exec_us: u64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.exec_us_total.fetch_add(exec_us, Ordering::Relaxed);
        self.batch_items_total.fetch_add(n as u64, Ordering::Relaxed);
        let sq = (n as u64) * (n as u64);
        self.batch_items_sq_total.fetch_add(sq, Ordering::Relaxed);
        self.batch_buckets[batch_bucket(n)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_deadline_exceeded(&self) {
        self.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_exec_error(&self) {
        self.exec_errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_recipe_swap(&self) {
        self.recipe_swaps.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_swap_error(&self) {
        self.swap_errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_swap_abort(&self) {
        self.swap_aborts.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_panic(&self) {
        self.panics.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_restart(&self) {
        self.restarts.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_job_failed(&self) {
        self.jobs_failed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn request_count(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    /// Point-in-time copy of every counter (all relaxed loads).
    pub fn snapshot(&self) -> Snapshot {
        let mut s = Snapshot {
            requests: self.requests.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            exec_us_total: self.exec_us_total.load(Ordering::Relaxed),
            latency_us_total: self.latency_us_total.load(Ordering::Relaxed),
            latency_us_max: self.latency_us_max.load(Ordering::Relaxed),
            batch_items_total: self.batch_items_total.load(Ordering::Relaxed),
            batch_items_sq_total: self.batch_items_sq_total.load(Ordering::Relaxed),
            deadline_exceeded: self.deadline_exceeded.load(Ordering::Relaxed),
            exec_errors: self.exec_errors.load(Ordering::Relaxed),
            recipe_swaps: self.recipe_swaps.load(Ordering::Relaxed),
            swap_errors: self.swap_errors.load(Ordering::Relaxed),
            swap_aborts: self.swap_aborts.load(Ordering::Relaxed),
            panics: self.panics.load(Ordering::Relaxed),
            restarts: self.restarts.load(Ordering::Relaxed),
            jobs_failed: self.jobs_failed.load(Ordering::Relaxed),
            ..Snapshot::default()
        };
        for (dst, src) in s.latency_buckets.iter_mut().zip(&self.latency_buckets) {
            *dst = src.load(Ordering::Relaxed);
        }
        for (dst, src) in s.batch_buckets.iter_mut().zip(&self.batch_buckets) {
            *dst = src.load(Ordering::Relaxed);
        }
        s
    }
}

/// Plain-number view of one worker — or, after [`Snapshot::merge`], of
/// the whole pool.
#[derive(Debug, Clone, Default)]
pub struct Snapshot {
    pub requests: u64,
    pub batches: u64,
    pub exec_us_total: u64,
    pub latency_us_total: u64,
    pub latency_us_max: u64,
    pub batch_items_total: u64,
    pub batch_items_sq_total: u64,
    pub deadline_exceeded: u64,
    pub exec_errors: u64,
    pub recipe_swaps: u64,
    pub swap_errors: u64,
    pub swap_aborts: u64,
    pub panics: u64,
    pub restarts: u64,
    pub jobs_failed: u64,
    latency_buckets: [u64; BUCKETS],
    batch_buckets: [u64; BATCH_BUCKETS],
}

impl Snapshot {
    pub fn merge(&mut self, other: &Snapshot) {
        self.requests += other.requests;
        self.batches += other.batches;
        self.exec_us_total += other.exec_us_total;
        self.latency_us_total += other.latency_us_total;
        self.latency_us_max = self.latency_us_max.max(other.latency_us_max);
        self.batch_items_total += other.batch_items_total;
        self.batch_items_sq_total += other.batch_items_sq_total;
        self.deadline_exceeded += other.deadline_exceeded;
        self.exec_errors += other.exec_errors;
        self.recipe_swaps += other.recipe_swaps;
        self.swap_errors += other.swap_errors;
        self.swap_aborts += other.swap_aborts;
        self.panics += other.panics;
        self.restarts += other.restarts;
        self.jobs_failed += other.jobs_failed;
        for (dst, src) in self.latency_buckets.iter_mut().zip(&other.latency_buckets) {
            *dst += src;
        }
        for (dst, src) in self.batch_buckets.iter_mut().zip(&other.batch_buckets) {
            *dst += src;
        }
    }

    pub fn mean_latency_us(&self) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        self.latency_us_total as f64 / self.requests as f64
    }

    /// Approximate percentile from the exponential buckets (upper edge
    /// of the bucket holding the rank-`ceil(p*total)` sample).
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        let total: u64 = self.latency_buckets.iter().sum();
        if total == 0 {
            return 0;
        }
        // clamp the rank to >= 1: p = 0.0 used to yield target 0, which
        // the first (possibly empty) bucket trivially satisfied — the
        // function reported 64 µs regardless of the data. Empty buckets
        // are skipped outright so an answer always names a bucket that
        // actually holds samples.
        let target = ((p.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut acc = 0u64;
        let mut edge = BASE_US;
        for b in &self.latency_buckets {
            if *b > 0 {
                acc += b;
                if acc >= target {
                    return edge;
                }
            }
            edge *= 2;
        }
        edge
    }

    /// Mean requests fused per forward pass — the batching win. Every
    /// batch counts once regardless of size.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.batch_items_total as f64 / self.batches as f64
    }

    /// Mean batch size experienced by a random *request*. Weighted by
    /// batch size (a 32-batch carries 32 requests), so it is >= the
    /// per-batch mean; the gap measures batch-size skew.
    pub fn mean_batch_weighted(&self) -> f64 {
        if self.batch_items_total == 0 {
            return 0.0;
        }
        self.batch_items_sq_total as f64 / self.batch_items_total as f64
    }

    /// `(upper_edge, count)` pairs for the non-empty batch-size buckets.
    pub fn batch_histogram(&self) -> Vec<(usize, u64)> {
        let mut out = Vec::new();
        let mut edge = 1usize;
        for (i, &c) in self.batch_buckets.iter().enumerate() {
            if c > 0 {
                out.push((edge, c));
            }
            if i + 1 < BATCH_BUCKETS {
                edge *= 2;
            }
        }
        out
    }

    pub fn report_line(&self) -> String {
        let mut line = format!(
            "requests {} | batches {} | mean batch {:.1} (weighted {:.1}) | \
             latency mean {:.2} ms p50 ~{:.2} ms p99 ~{:.2} ms max {:.2} ms | \
             deadline-exceeded {} | exec errors {}",
            self.requests,
            self.batches,
            self.mean_batch(),
            self.mean_batch_weighted(),
            self.mean_latency_us() / 1e3,
            self.latency_percentile_us(0.5) as f64 / 1e3,
            self.latency_percentile_us(0.99) as f64 / 1e3,
            self.latency_us_max as f64 / 1e3,
            self.deadline_exceeded,
            self.exec_errors,
        );
        if self.recipe_swaps > 0 || self.swap_errors > 0 || self.swap_aborts > 0 {
            line.push_str(&format!(
                " | recipe swaps {} ({} failed, {} aborted)",
                self.recipe_swaps, self.swap_errors, self.swap_aborts
            ));
        }
        if self.panics > 0 || self.restarts > 0 || self.jobs_failed > 0 {
            line.push_str(&format!(
                " | faults: {} panic(s), {} restart(s), {} job(s) failed",
                self.panics, self.restarts, self.jobs_failed
            ));
        }
        line
    }
}

/// Pool-level metrics: one [`Metrics`] shard per worker, one per
/// tenant (an orthogonal cut of the same traffic — worker shards sum to
/// the pool aggregate, tenant shards attribute it), router-side
/// admission counters, and shared queue-depth gauges.
#[derive(Debug)]
pub struct PoolMetrics {
    workers: Vec<Arc<Metrics>>,
    /// Queued + in-flight jobs per worker; the router increments on
    /// dispatch, the worker decrements on response. Doubles as the
    /// least-outstanding-work dispatch key.
    outstanding: Vec<Arc<AtomicUsize>>,
    /// Breaker state per worker: set by the supervisor when it gives up
    /// respawning a worker; the router skips dead shards.
    dead: Vec<Arc<AtomicBool>>,
    /// Per-tenant request/latency/deadline shards (index = tenant id;
    /// 0 = the default tenant). Written by every worker.
    tenants: Vec<Arc<Metrics>>,
    tenant_names: Vec<String>,
    /// Router-side per-tenant rejection counters.
    tenant_rejected: Vec<AtomicU64>,
    /// Queued + in-flight jobs per tenant (the quota admission gauge —
    /// an orthogonal cut of the same jobs the worker gauges count).
    tenant_outstanding: Vec<Arc<AtomicUsize>>,
    /// Rejections caused specifically by the per-tenant admission quota
    /// (a subset of `tenant_rejected`).
    tenant_quota_rejected: Vec<AtomicU64>,
    /// Requests rejected (or rerouted, under `--tenant-fallback`)
    /// because the tenant's circuit breaker was open.
    tenant_quarantined: Vec<AtomicU64>,
    /// Requests that named a tenant the pool does not know (served on
    /// the default recipe, counted under tenant 0).
    pub unknown_tenant: AtomicU64,
    pub dispatched: AtomicU64,
    pub rejected: AtomicU64,
}

impl PoolMetrics {
    pub fn new(n: usize) -> PoolMetrics {
        Self::with_tenants(n, vec!["default".to_string()])
    }

    /// `tenant_names[0]` is the default tenant every request without an
    /// explicit (or with an unknown) tenant key lands on.
    pub fn with_tenants(n: usize, tenant_names: Vec<String>) -> PoolMetrics {
        assert!(!tenant_names.is_empty(), "tenant 0 (default) is required");
        PoolMetrics {
            workers: (0..n).map(|_| Arc::new(Metrics::default())).collect(),
            outstanding: (0..n).map(|_| Arc::new(AtomicUsize::new(0))).collect(),
            dead: (0..n).map(|_| Arc::new(AtomicBool::new(false))).collect(),
            tenants: tenant_names
                .iter()
                .map(|_| Arc::new(Metrics::default()))
                .collect(),
            tenant_rejected: tenant_names.iter().map(|_| AtomicU64::new(0)).collect(),
            tenant_outstanding: tenant_names
                .iter()
                .map(|_| Arc::new(AtomicUsize::new(0)))
                .collect(),
            tenant_quota_rejected: tenant_names.iter().map(|_| AtomicU64::new(0)).collect(),
            tenant_quarantined: tenant_names.iter().map(|_| AtomicU64::new(0)).collect(),
            tenant_names,
            unknown_tenant: AtomicU64::new(0),
            dispatched: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        }
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    pub fn tenant_count(&self) -> usize {
        self.tenants.len()
    }

    pub fn tenant(&self, id: usize) -> &Arc<Metrics> {
        &self.tenants[id]
    }

    pub fn tenant_name(&self, id: usize) -> &str {
        &self.tenant_names[id]
    }

    pub fn record_tenant_rejected(&self, id: usize) {
        self.tenant_rejected[id].fetch_add(1, Ordering::Relaxed);
    }

    pub fn tenant_rejected_count(&self, id: usize) -> u64 {
        self.tenant_rejected[id].load(Ordering::Relaxed)
    }

    /// Count a rejection caused by the per-tenant admission quota (also
    /// counted in the tenant's plain rejection counter).
    pub fn record_tenant_quota_rejected(&self, id: usize) {
        self.tenant_quota_rejected[id].fetch_add(1, Ordering::Relaxed);
        self.record_tenant_rejected(id);
    }

    pub fn tenant_quota_rejected_count(&self, id: usize) -> u64 {
        self.tenant_quota_rejected[id].load(Ordering::Relaxed)
    }

    /// Count a request that hit the tenant's open circuit breaker. A
    /// rejection also counts in the tenant's plain rejection counter; a
    /// fallback-served request counts here only (it *was* answered).
    pub fn record_tenant_quarantined(&self, id: usize, rejected: bool) {
        self.tenant_quarantined[id].fetch_add(1, Ordering::Relaxed);
        if rejected {
            self.record_tenant_rejected(id);
        }
    }

    pub fn tenant_quarantined_count(&self, id: usize) -> u64 {
        self.tenant_quarantined[id].load(Ordering::Relaxed)
    }

    /// Shared per-tenant queued+in-flight gauge (quota admission).
    pub fn tenant_outstanding_handle(&self, id: usize) -> Arc<AtomicUsize> {
        self.tenant_outstanding[id].clone()
    }

    /// Borrowed view of the same gauge (hot paths that already hold the
    /// pool metrics skip the `Arc` bump).
    pub fn tenant_outstanding_gauge(&self, id: usize) -> &AtomicUsize {
        &self.tenant_outstanding[id]
    }

    pub fn tenant_outstanding_count(&self, id: usize) -> usize {
        self.tenant_outstanding[id].load(Ordering::Relaxed)
    }

    /// Shared breaker flag for worker `id` (set at supervisor give-up).
    pub fn dead_handle(&self, id: usize) -> Arc<AtomicBool> {
        self.dead[id].clone()
    }

    pub fn is_dead(&self, id: usize) -> bool {
        self.dead[id].load(Ordering::SeqCst)
    }

    /// Workers whose breaker is open (given up on, no longer dispatched).
    pub fn dead_workers(&self) -> usize {
        self.dead.iter().filter(|d| d.load(Ordering::SeqCst)).count()
    }

    pub fn record_unknown_tenant(&self) {
        self.unknown_tenant.fetch_add(1, Ordering::Relaxed);
    }

    pub fn unknown_tenant_count(&self) -> u64 {
        self.unknown_tenant.load(Ordering::Relaxed)
    }

    pub fn worker(&self, id: usize) -> &Arc<Metrics> {
        &self.workers[id]
    }

    pub fn outstanding_handle(&self, id: usize) -> Arc<AtomicUsize> {
        self.outstanding[id].clone()
    }

    /// Queue-depth gauge: jobs admitted but not yet answered, pool-wide.
    pub fn queue_depth(&self) -> usize {
        self.outstanding.iter().map(|o| o.load(Ordering::Relaxed)).sum()
    }

    pub fn queue_depth_of(&self, id: usize) -> usize {
        self.outstanding[id].load(Ordering::Relaxed)
    }

    pub fn dispatched_count(&self) -> u64 {
        self.dispatched.load(Ordering::Relaxed)
    }

    pub fn rejected_count(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Lock-free summed view across all workers.
    pub fn aggregate(&self) -> Snapshot {
        let mut agg = Snapshot::default();
        for w in &self.workers {
            agg.merge(&w.snapshot());
        }
        agg
    }

    pub fn request_count(&self) -> u64 {
        self.workers.iter().map(|w| w.request_count()).sum()
    }

    /// Aggregate per-batch mean (see [`Snapshot::mean_batch`]).
    pub fn mean_batch(&self) -> f64 {
        self.aggregate().mean_batch()
    }

    pub fn report(&self) -> String {
        let agg = self.aggregate();
        let mut out = format!(
            "pool[{} workers]: {} | queue depth {} | dispatched {} | rejected {}",
            self.workers.len(),
            agg.report_line(),
            self.queue_depth(),
            self.dispatched_count(),
            self.rejected_count(),
        );
        if self.dead_workers() > 0 {
            out.push_str(&format!(" | dead workers {}", self.dead_workers()));
        }
        if self.workers.len() > 1 {
            for (i, w) in self.workers.iter().enumerate() {
                out.push_str(&format!(
                    "\n  worker {i}{}: {}",
                    if self.is_dead(i) { " [dead]" } else { "" },
                    w.snapshot().report_line()
                ));
            }
        }
        if self.tenants.len() > 1 {
            for (id, t) in self.tenants.iter().enumerate() {
                out.push_str(&format!(
                    "\n  tenant {}: {} | rejected {}",
                    self.tenant_names[id],
                    t.snapshot().report_line(),
                    self.tenant_rejected_count(id),
                ));
                if self.tenant_quota_rejected_count(id) > 0 {
                    out.push_str(&format!(
                        " ({} over quota)",
                        self.tenant_quota_rejected_count(id)
                    ));
                }
                if self.tenant_quarantined_count(id) > 0 {
                    out.push_str(&format!(
                        " | quarantined {}",
                        self.tenant_quarantined_count(id)
                    ));
                }
            }
            if self.unknown_tenant_count() > 0 {
                out.push_str(&format!(
                    "\n  unknown tenants -> default: {}",
                    self.unknown_tenant_count()
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_percentiles() {
        let m = Metrics::default();
        for i in 1..=100u64 {
            m.record_request(Duration::from_micros(i * 100));
        }
        for _ in 0..25 {
            m.record_batch(4, 50);
        }
        let s = m.snapshot();
        assert_eq!(s.requests, 100);
        assert_eq!(s.batches, 25);
        assert_eq!(s.mean_batch(), 4.0);
        assert_eq!(s.exec_us_total, 25 * 50);
        let p50 = s.latency_percentile_us(0.5);
        let p99 = s.latency_percentile_us(0.99);
        assert!(p50 >= 4_000 && p50 <= 8_192, "p50 {p50}");
        assert!(p99 >= p50);
        assert!(s.mean_latency_us() > 4_000.0);
        assert_eq!(s.latency_us_max, 10_000);
    }

    #[test]
    fn percentile_skips_empty_buckets_and_clamps_rank() {
        // regression: one slow request at 10 ms. p=0.0 used to produce
        // target 0, which the empty first bucket satisfied (acc 0 >= 0)
        // — every percentile of this snapshot reported 64 µs.
        let m = Metrics::default();
        m.record_request(Duration::from_micros(10_000));
        let s = m.snapshot();
        // 10_000 µs lands in the (8192, 16384] bucket; its upper edge is
        // the only honest answer at every p.
        assert_eq!(s.latency_percentile_us(0.0), 16_384);
        assert_eq!(s.latency_percentile_us(0.5), 16_384);
        assert_eq!(s.latency_percentile_us(1.0), 16_384);
    }

    #[test]
    fn mean_batch_weighted_vs_unweighted() {
        let m = Metrics::default();
        // one lonely request, one full batch of 9
        m.record_batch(1, 10);
        m.record_batch(9, 10);
        let s = m.snapshot();
        // per-batch mean: (1 + 9) / 2
        assert_eq!(s.mean_batch(), 5.0);
        // per-request mean: (1*1 + 9*9) / 10 — most requests rode the 9
        assert!((s.mean_batch_weighted() - 8.2).abs() < 1e-9);
        assert!(s.mean_batch_weighted() > s.mean_batch());
        // uniform batches: the two means agree
        let u = Metrics::default();
        u.record_batch(4, 1);
        u.record_batch(4, 1);
        let us = u.snapshot();
        assert_eq!(us.mean_batch(), 4.0);
        assert_eq!(us.mean_batch_weighted(), 4.0);
    }

    #[test]
    fn batch_histogram_edges() {
        let m = Metrics::default();
        m.record_batch(1, 0);
        m.record_batch(2, 0);
        m.record_batch(3, 0);
        m.record_batch(32, 0);
        let h = m.snapshot().batch_histogram();
        assert_eq!(h, vec![(1, 1), (2, 1), (4, 1), (32, 1)]);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.mean_latency_us(), 0.0);
        assert_eq!(s.latency_percentile_us(0.99), 0);
        assert_eq!(s.mean_batch(), 0.0);
        assert_eq!(s.mean_batch_weighted(), 0.0);
    }

    #[test]
    fn swap_counters_aggregate_and_report() {
        let pool = PoolMetrics::new(2);
        pool.worker(0).record_recipe_swap();
        pool.worker(1).record_recipe_swap();
        pool.worker(1).record_swap_error();
        let agg = pool.aggregate();
        assert_eq!(agg.recipe_swaps, 2);
        assert_eq!(agg.swap_errors, 1);
        assert!(agg.report_line().contains("recipe swaps 2 (1 failed, 0 aborted)"));
        // aborted (panicked + rolled back) swaps are counted separately
        pool.worker(1).record_swap_abort();
        let agg = pool.aggregate();
        assert_eq!(agg.swap_aborts, 1);
        assert!(agg.report_line().contains("(1 failed, 1 aborted)"));
        // silent when no swap ever happened
        assert!(!Metrics::default().snapshot().report_line().contains("recipe swaps"));
    }

    #[test]
    fn quarantine_counters_attribute_rejections_and_fallbacks() {
        let pool = PoolMetrics::with_tenants(1, vec!["default".into(), "bad".into()]);
        // a rejected request counts in both the quarantine and the plain
        // rejection counters; a fallback-served one only in quarantine
        pool.record_tenant_quarantined(1, true);
        pool.record_tenant_quarantined(1, false);
        assert_eq!(pool.tenant_quarantined_count(1), 2);
        assert_eq!(pool.tenant_rejected_count(1), 1);
        assert_eq!(pool.tenant_quarantined_count(0), 0);
        assert!(pool.report().contains("quarantined 2"), "{}", pool.report());
    }

    #[test]
    fn fault_counters_aggregate_and_report() {
        let pool = PoolMetrics::new(2);
        pool.worker(0).record_panic();
        pool.worker(0).record_restart();
        pool.worker(0).record_job_failed();
        pool.worker(0).record_job_failed();
        let agg = pool.aggregate();
        assert_eq!(agg.panics, 1);
        assert_eq!(agg.restarts, 1);
        assert_eq!(agg.jobs_failed, 2);
        assert!(
            agg.report_line().contains("faults: 1 panic(s), 1 restart(s), 2 job(s) failed"),
            "{}",
            agg.report_line()
        );
        // silent on a healthy pool
        assert!(!Metrics::default().snapshot().report_line().contains("faults:"));
        // breaker state is per worker and reflected in the report
        assert_eq!(pool.dead_workers(), 0);
        pool.dead_handle(1).store(true, Ordering::SeqCst);
        assert!(pool.is_dead(1) && !pool.is_dead(0));
        assert_eq!(pool.dead_workers(), 1);
        let r = pool.report();
        assert!(r.contains("dead workers 1"), "{r}");
        assert!(r.contains("worker 1 [dead]"), "{r}");
    }

    #[test]
    fn quota_counters_are_a_subset_of_rejections() {
        let pool = PoolMetrics::with_tenants(1, vec!["default".into(), "bulk".into()]);
        pool.record_tenant_quota_rejected(1);
        pool.record_tenant_rejected(1);
        assert_eq!(pool.tenant_quota_rejected_count(1), 1);
        assert_eq!(pool.tenant_rejected_count(1), 2, "quota rejects count in both");
        assert_eq!(pool.tenant_quota_rejected_count(0), 0);
        let h = pool.tenant_outstanding_handle(1);
        h.fetch_add(3, Ordering::Relaxed);
        assert_eq!(pool.tenant_outstanding_count(1), 3);
        assert!(pool.report().contains("(1 over quota)"), "{}", pool.report());
    }

    #[test]
    fn tenant_shards_attribute_traffic() {
        let pool =
            PoolMetrics::with_tenants(2, vec!["default".into(), "gold".into(), "bulk".into()]);
        assert_eq!(pool.tenant_count(), 3);
        assert_eq!(pool.tenant_name(1), "gold");
        // worker 0 serves one default and one gold request; worker 1
        // serves a bulk request — tenant shards cut across workers
        pool.worker(0).record_request(Duration::from_micros(100));
        pool.tenant(0).record_request(Duration::from_micros(100));
        pool.worker(0).record_request(Duration::from_micros(200));
        pool.tenant(1).record_request(Duration::from_micros(200));
        pool.worker(1).record_request(Duration::from_micros(900));
        pool.tenant(2).record_request(Duration::from_micros(900));
        pool.tenant(2).record_deadline_exceeded();
        pool.record_tenant_rejected(2);
        pool.record_unknown_tenant();
        // the pool aggregate (worker shards) is unchanged by tenant shards
        assert_eq!(pool.aggregate().requests, 3);
        assert_eq!(pool.tenant(1).snapshot().requests, 1);
        assert_eq!(pool.tenant(2).snapshot().deadline_exceeded, 1);
        assert_eq!(pool.tenant_rejected_count(2), 1);
        assert_eq!(pool.tenant_rejected_count(0), 0);
        assert_eq!(pool.unknown_tenant_count(), 1);
        let r = pool.report();
        assert!(r.contains("tenant gold:"), "{r}");
        assert!(r.contains("tenant bulk:"), "{r}");
        assert!(r.contains("unknown tenants -> default: 1"), "{r}");
        // a single-tenant pool keeps the old report shape
        let plain = PoolMetrics::new(1);
        assert_eq!(plain.tenant_count(), 1);
        assert!(!plain.report().contains("tenant "), "{}", plain.report());
    }

    #[test]
    fn pool_aggregates_across_workers() {
        let pool = PoolMetrics::new(2);
        pool.worker(0).record_request(Duration::from_micros(100));
        pool.worker(0).record_batch(1, 10);
        pool.worker(1).record_request(Duration::from_micros(300));
        pool.worker(1).record_request(Duration::from_micros(300));
        pool.worker(1).record_batch(2, 20);
        pool.worker(1).record_deadline_exceeded();
        let agg = pool.aggregate();
        assert_eq!(agg.requests, 3);
        assert_eq!(agg.batches, 2);
        assert_eq!(agg.deadline_exceeded, 1);
        assert_eq!(agg.latency_us_max, 300);
        assert_eq!(pool.request_count(), 3);
        // queue-depth gauge is shared with the router via handles
        let h = pool.outstanding_handle(1);
        h.fetch_add(5, Ordering::Relaxed);
        assert_eq!(pool.queue_depth(), 5);
        assert_eq!(pool.queue_depth_of(0), 0);
        assert!(pool.report().contains("queue depth 5"));
    }
}
