//! Lock-free serving metrics: request/batch counters, end-to-end latency
//! (exponential buckets), batch-size distribution.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Exponential latency buckets in µs: <64, <128, ..., <2^25 (~33 s).
const BUCKETS: usize = 20;
const BASE_US: u64 = 64;

#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub exec_us_total: AtomicU64,
    pub latency_us_total: AtomicU64,
    pub latency_us_max: AtomicU64,
    pub batch_items_total: AtomicU64,
    latency_buckets: [AtomicU64; BUCKETS],
}

impl Metrics {
    pub fn record(&self, latency: Duration, exec_us: u64, batch: usize) {
        let us = latency.as_micros() as u64;
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.latency_us_total.fetch_add(us, Ordering::Relaxed);
        self.latency_us_max.fetch_max(us, Ordering::Relaxed);
        self.exec_us_total.fetch_add(exec_us, Ordering::Relaxed);
        self.batch_items_total
            .fetch_add(batch as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        let mut b = 0usize;
        let mut edge = BASE_US;
        while b + 1 < BUCKETS && us >= edge {
            edge *= 2;
            b += 1;
        }
        self.latency_buckets[b].fetch_add(1, Ordering::Relaxed);
    }

    pub fn request_count(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.request_count();
        if n == 0 {
            return 0.0;
        }
        self.latency_us_total.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Approximate percentile from the exponential buckets (upper edge).
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        let total: u64 = self
            .latency_buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .sum();
        if total == 0 {
            return 0;
        }
        let target = (p.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut acc = 0u64;
        let mut edge = BASE_US;
        for b in &self.latency_buckets {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return edge;
            }
            edge *= 2;
        }
        edge
    }

    /// requests per batch on average — the batching win.
    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        // batch_items_total counts each request's batch size; dividing by
        // requests gives the request-weighted mean batch
        let n = self.request_count();
        self.batch_items_total.load(Ordering::Relaxed) as f64 / n.max(1) as f64
    }

    pub fn report(&self) -> String {
        format!(
            "requests {} | batches {} | mean batch {:.1} | latency mean {:.2} ms p50 ~{:.2} ms p99 ~{:.2} ms max {:.2} ms",
            self.request_count(),
            self.batches.load(Ordering::Relaxed),
            self.mean_batch(),
            self.mean_latency_us() / 1e3,
            self.latency_percentile_us(0.5) as f64 / 1e3,
            self.latency_percentile_us(0.99) as f64 / 1e3,
            self.latency_us_max.load(Ordering::Relaxed) as f64 / 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_percentiles() {
        let m = Metrics::default();
        for i in 1..=100u64 {
            m.record(Duration::from_micros(i * 100), 50, 4);
        }
        assert_eq!(m.request_count(), 100);
        assert_eq!(m.mean_batch(), 4.0);
        let p50 = m.latency_percentile_us(0.5);
        let p99 = m.latency_percentile_us(0.99);
        assert!(p50 >= 4_000 && p50 <= 8_192, "p50 {p50}");
        assert!(p99 >= p50);
        assert!(m.mean_latency_us() > 4_000.0);
        assert_eq!(m.latency_us_max.load(Ordering::Relaxed), 10_000);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::default();
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.latency_percentile_us(0.99), 0);
        assert_eq!(m.mean_batch(), 0.0);
    }
}
