//! Engine backends for the sharded server.
//!
//! PJRT handles are `!Send`, so an engine can never migrate between
//! threads. The pool therefore hands every worker thread an
//! [`EngineFactory`] (which *is* `Send + Sync` — it holds only plain
//! config) and the worker calls [`EngineFactory::build`] on its own
//! thread, producing a thread-local [`WorkerEngine`] that stays put.
//!
//! Two factories ship:
//! * [`PjrtFactory`] — the real stack: model spec + weights + quant
//!   pipeline + PJRT engine per worker. Artifact HLO text is shared
//!   across workers through [`crate::runtime::HloTextCache`].
//! * [`SimFactory`] — a synthetic CPU-burning model. Deterministic
//!   logits, tunable per-batch/per-item cost. This is what CI and the
//!   router tests run on: it needs no artifacts and no PJRT, but still
//!   occupies a core the way a real engine does, so worker-scaling
//!   measurements remain meaningful.

use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::eval::pad_rows;
use crate::model::store::WeightStore;
use crate::model::ModelSpec;
use crate::pipeline::{self, QuantConfig};
use crate::runtime::{Engine, Input, Inputs};
use crate::tensor::TensorF;

/// One worker's engine. Built and used on that worker's thread only; the
/// trait object never crosses threads, so it need not be `Send`.
pub trait WorkerEngine {
    /// Run one forward pass over `batch` (shape `(n, ...)`). Returns
    /// logits of shape `(m, classes)` with `m >= n`; callers ignore the
    /// padding rows beyond `n`.
    fn infer(&mut self, batch: &TensorF) -> Result<TensorF>;
}

/// Thread-safe recipe for building per-worker engines.
pub trait EngineFactory: Send + Sync + 'static {
    /// Called on the worker thread itself (never the router thread).
    fn build(&self, worker_id: usize) -> Result<Box<dyn WorkerEngine>>;

    /// Human-readable tag for logs and bench records.
    fn label(&self) -> String;
}

/// The production backend: full quantization pipeline + PJRT engine.
pub struct PjrtFactory {
    pub artifacts_dir: String,
    pub model: String,
    pub quant: QuantConfig,
    /// Pre-compile every fwd artifact up to twice this batch.
    pub max_batch: usize,
}

impl EngineFactory for PjrtFactory {
    fn build(&self, worker_id: usize) -> Result<Box<dyn WorkerEngine>> {
        let spec = ModelSpec::load_named(&self.artifacts_dir, &self.model)?;
        if spec.is_lm() {
            bail!("serving targets the CNN models");
        }
        let (ws, _) = WeightStore::load_best(&spec)?;
        let engine = Engine::cpu()?;
        let calib = if self.quant.a_bits.is_some() {
            let calib_set = crate::train::data::synth_images(64, 929);
            Some(crate::calib::calibrate(&engine, &spec, &ws, &calib_set.x, 32)?)
        } else {
            None
        };
        let prep = pipeline::prepare(&spec, &ws, calib.as_ref(), &self.quant)?;
        let mut base: Inputs = Default::default();
        prep.insert_inputs(&mut base);
        // pre-compile every batch size this worker may route to
        for b in spec.fwd_batches() {
            if b <= self.max_batch.max(1) * 2 {
                engine.load(spec.fwd_for_batch(b)?)?;
            }
        }
        crate::debugln!(
            "worker {worker_id}: PJRT engine ready ({} executables cached)",
            engine.cached_count()
        );
        Ok(Box::new(PjrtWorker { spec, engine, base }))
    }

    fn label(&self) -> String {
        format!("pjrt:{} [{}]", self.model, self.quant.label())
    }
}

struct PjrtWorker {
    spec: ModelSpec,
    engine: Engine,
    base: Inputs,
}

impl WorkerEngine for PjrtWorker {
    fn infer(&mut self, batch: &TensorF) -> Result<TensorF> {
        let n = batch.shape()[0];
        let art = self.spec.fwd_for_batch(n)?;
        let exe = self.engine.load(art)?;
        let xb = if n == art.batch {
            batch.clone()
        } else {
            pad_rows(batch, art.batch)?
        };
        self.base.insert("x".into(), Input::F32(xb));
        let mut out = exe.execute(&self.base)?;
        out.take("logits")
    }
}

/// Synthetic backend: deterministic logits plus a calibrated CPU burn.
///
/// The burn is a busy-spin, not a sleep — it occupies a core exactly as
/// a compute-bound engine would, so throughput scales with workers only
/// when real parallel hardware exists. That property is what the
/// worker-sweep integration test asserts.
pub struct SimFactory {
    pub classes: usize,
    /// Fixed cost per forward pass (kernel launch / dispatch overhead).
    pub cost_per_batch: Duration,
    /// Additional cost per batched row (per-image compute).
    pub cost_per_item: Duration,
}

impl Default for SimFactory {
    fn default() -> Self {
        SimFactory {
            classes: 10,
            cost_per_batch: Duration::from_micros(200),
            cost_per_item: Duration::from_micros(100),
        }
    }
}

impl EngineFactory for SimFactory {
    fn build(&self, _worker_id: usize) -> Result<Box<dyn WorkerEngine>> {
        if self.classes == 0 {
            bail!("sim backend needs classes >= 1");
        }
        Ok(Box::new(SimWorker {
            classes: self.classes,
            cost_per_batch: self.cost_per_batch,
            cost_per_item: self.cost_per_item,
        }))
    }

    fn label(&self) -> String {
        format!(
            "sim:{}c {}us/batch {}us/item",
            self.classes,
            self.cost_per_batch.as_micros(),
            self.cost_per_item.as_micros()
        )
    }
}

struct SimWorker {
    classes: usize,
    cost_per_batch: Duration,
    cost_per_item: Duration,
}

/// Busy-spin for `d` (occupies the core, unlike `sleep`).
fn burn(d: Duration) {
    let end = Instant::now() + d;
    while Instant::now() < end {
        std::hint::spin_loop();
    }
}

impl WorkerEngine for SimWorker {
    fn infer(&mut self, batch: &TensorF) -> Result<TensorF> {
        let n = batch.shape().first().copied().unwrap_or(0);
        if n == 0 || batch.len() % n != 0 {
            bail!("sim backend: bad batch shape {:?}", batch.shape());
        }
        let row = batch.len() / n;
        burn(self.cost_per_batch + self.cost_per_item * n as u32);
        let mut data = Vec::with_capacity(n * self.classes);
        for i in 0..n {
            let s: f32 = batch.data()[i * row..(i + 1) * row].iter().sum();
            for c in 0..self.classes {
                data.push(s + c as f32);
            }
        }
        Ok(TensorF::from_vec(&[n, self.classes], data)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_logits_deterministic_and_shaped() {
        let f = SimFactory {
            classes: 4,
            cost_per_batch: Duration::ZERO,
            cost_per_item: Duration::ZERO,
        };
        let mut w = f.build(0).unwrap();
        let x = TensorF::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let a = w.infer(&x).unwrap();
        let b = w.infer(&x).unwrap();
        assert_eq!(a.shape(), &[2, 4]);
        assert_eq!(a.data(), b.data(), "sim must be deterministic");
        // row 0 sums to 6, row 1 to 15; class c adds c
        assert_eq!(a.data()[0], 6.0);
        assert_eq!(a.data()[4 + 1], 16.0);
    }

    #[test]
    fn sim_rejects_degenerate_config() {
        let f = SimFactory {
            classes: 0,
            ..SimFactory::default()
        };
        assert!(f.build(0).is_err());
        let mut w = SimFactory::default().build(0).unwrap();
        assert!(w.infer(&TensorF::zeros(&[0, 3])).is_err());
    }

    #[test]
    fn burn_occupies_at_least_requested_time() {
        let t0 = Instant::now();
        burn(Duration::from_millis(2));
        assert!(t0.elapsed() >= Duration::from_millis(2));
    }

    #[test]
    fn labels_are_informative() {
        assert!(SimFactory::default().label().starts_with("sim:"));
        let p = PjrtFactory {
            artifacts_dir: "artifacts".into(),
            model: "minivgg".into(),
            quant: QuantConfig::float(),
            max_batch: 8,
        };
        assert!(p.label().contains("minivgg"));
    }
}
