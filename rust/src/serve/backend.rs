//! Engine backends for the sharded server.
//!
//! PJRT handles are `!Send`, so an engine can never migrate between
//! threads. The pool therefore hands every worker thread an
//! [`EngineFactory`] (which *is* `Send + Sync` — it holds only plain
//! config) and the worker calls [`EngineFactory::build`] on its own
//! thread, producing a thread-local [`WorkerEngine`] that stays put.
//!
//! Four factories ship:
//! * [`PjrtFactory`] — the real stack: model spec + weights + quant
//!   recipe + PJRT engine per worker. Artifact HLO text is shared
//!   across workers through [`crate::runtime::HloTextCache`], and the
//!   prepared quantization pipeline through the process-wide
//!   [`PreparedCache`] — N workers, one prepare.
//! * [`SimFactory`] — a synthetic CPU-burning model. Deterministic
//!   logits, tunable per-batch/per-item cost. This is what the router
//!   tests run on: it needs no artifacts and no PJRT, but still
//!   occupies a core the way a real engine does, so worker-scaling
//!   measurements remain meaningful.
//! * [`QuantSimFactory`] — the quantization pipeline *without* PJRT: it
//!   runs the full recipe prepare (through a [`PreparedCache`]) over an
//!   in-memory model and serves logits deterministically derived from
//!   the prepared weights. CI uses it to exercise recipe serving,
//!   cache sharing, and hot-swap end-to-end on a clean checkout.
//! * [`NativeFactory`] — **real quantized compute, no PJRT and no
//!   artifacts**: each worker executes the model on the native integer
//!   backend ([`crate::runtime::native`]) — packed i8 GEMM with a
//!   fused per-channel dequant epilogue. Works over an artifacts-dir
//!   model (stub builds serve real logits this way) or the built-in
//!   synthetic MLP (`ocs serve --backend native --sim-free`).
//!
//! Recipe hot-swap: [`WorkerEngine::swap`] re-prepares the worker's
//! pipeline under a new [`QuantRecipe`] without tearing the engine
//! down. The default implementation refuses (backends that hold no
//! prep have nothing to swap); `PjrtWorker` and `QuantSimWorker`
//! rebuild their prepared inputs through the cache.
//!
//! Tenant routing: the worker loop partitions every pull into
//! single-tenant batches and executes them through
//! [`WorkerEngine::infer_tenant`] with a [`TenantCtx`] naming the
//! tenant and its current recipe. Recipe-aware backends
//! (`QuantSimWorker`, `NativeWorker`) keep one prep per tenant, built
//! lazily on the tenant's first request through the shared
//! [`PreparedCache`]; [`WorkerEngine::swap_tenant`] rebuilds exactly
//! one tenant's prep, leaving every other tenant undisturbed.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::calib::Calibration;
use crate::eval::pad_rows;
use crate::model::store::WeightStore;
use crate::model::ModelSpec;
use crate::pipeline::{self, PreparedCache, PreparedModel, QuantRecipe};
use crate::runtime::{Engine, Input, Inputs};
use crate::tensor::TensorF;

/// Per-tenant view the worker loop hands to engines: the tenant's
/// stable id (its index in the pool's tenant table), its name (logs
/// only), and its *current* recipe. `recipe` is `None` for tenant 0 —
/// the default tenant serves whatever the factory built (including any
/// pool-wide hot-swap applied through [`WorkerEngine::swap`]) — and for
/// backends that carry no per-tenant recipes.
#[derive(Debug)]
pub struct TenantCtx<'a> {
    pub id: usize,
    pub name: &'a str,
    pub recipe: Option<&'a QuantRecipe>,
}

/// One worker's engine. Built and used on that worker's thread only; the
/// trait object never crosses threads, so it need not be `Send`.
pub trait WorkerEngine {
    /// Run one forward pass over `batch` (shape `(n, ...)`). Returns
    /// logits of shape `(m, classes)` with `m >= n`; callers ignore the
    /// padding rows beyond `n`.
    fn infer(&mut self, batch: &TensorF) -> Result<TensorF>;

    /// Run one forward pass for tenant `t` (batches are always
    /// single-tenant — the worker loop partitions mixed pulls). The
    /// default ignores the tenant and serves the pool recipe: backends
    /// without per-tenant state still route, meter, and admission-control
    /// per tenant, they just execute everything on one prep. Recipe-aware
    /// backends ([`QuantSimWorker`], [`NativeWorker`]) build and cache a
    /// prep per tenant lazily, on that tenant's first request.
    fn infer_tenant(&mut self, t: &TenantCtx, batch: &TensorF) -> Result<TensorF> {
        let _ = t;
        self.infer(batch)
    }

    /// Re-prepare this worker's quantization pipeline under `recipe`
    /// without rebuilding the engine. Called by the worker loop between
    /// batches (never mid-batch), so in-flight work always completes on
    /// the prep it started with. Backends that carry no prepared state
    /// refuse by default; on error the worker keeps serving the old
    /// prep.
    fn swap(&mut self, recipe: &QuantRecipe) -> Result<()> {
        let _ = recipe;
        bail!("this backend does not support recipe hot-swap")
    }

    /// Apply a published per-tenant recipe swap. Tenant 0 is the
    /// pool-wide swap ([`WorkerEngine::swap`]); for other tenants the
    /// default succeeds as a no-op — a backend with no per-tenant state
    /// has nothing to rebuild, and one with *lazy* per-tenant state
    /// picks the new recipe up from the [`TenantCtx`] on the tenant's
    /// next request. Only eager rebuilds of existing state can fail; on
    /// error the worker keeps the tenant's old prep.
    fn swap_tenant(&mut self, t: &TenantCtx, recipe: &QuantRecipe) -> Result<()> {
        if t.id == 0 {
            return self.swap(recipe);
        }
        Ok(())
    }
}

/// Thread-safe recipe for building per-worker engines.
pub trait EngineFactory: Send + Sync + 'static {
    /// Called on the worker thread itself (never the router thread).
    fn build(&self, worker_id: usize) -> Result<Box<dyn WorkerEngine>>;

    /// Human-readable tag for logs and bench records.
    fn label(&self) -> String;
}

/// The production backend: full quantization pipeline + PJRT engine.
pub struct PjrtFactory {
    pub artifacts_dir: String,
    pub model: String,
    pub recipe: QuantRecipe,
    /// Pre-compile every fwd artifact up to twice this batch.
    pub max_batch: usize,
}

/// Build the calibration a recipe needs (or `None`): the serve-side
/// fixed synthetic calibration set, probed through this worker's engine.
fn serve_calibration(
    engine: &Engine,
    spec: &ModelSpec,
    ws: &WeightStore,
    recipe: &QuantRecipe,
) -> Result<Option<Calibration>> {
    if !recipe.needs_calibration(spec) {
        return Ok(None);
    }
    let calib_set = crate::train::data::synth_images(64, 929);
    Ok(Some(crate::calib::calibrate(engine, spec, ws, &calib_set.x, 32)?))
}

impl EngineFactory for PjrtFactory {
    fn build(&self, worker_id: usize) -> Result<Box<dyn WorkerEngine>> {
        let spec = ModelSpec::load_named(&self.artifacts_dir, &self.model)?;
        if spec.is_lm() {
            bail!("serving targets the CNN models");
        }
        let (ws, _) = WeightStore::load_best(&spec)?;
        let engine = Engine::cpu()?;
        let calib = serve_calibration(&engine, &spec, &ws, &self.recipe)?;
        // the process-wide cache: the first worker prepares, the rest share
        let prep = pipeline::prepare_cached(&spec, &ws, calib.as_ref(), &self.recipe)?;
        let mut base: Inputs = Default::default();
        prep.insert_inputs(&mut base);
        // pre-compile every batch size this worker may route to
        for b in spec.fwd_batches() {
            if b <= self.max_batch.max(1) * 2 {
                engine.load(spec.fwd_for_batch(b)?)?;
            }
        }
        crate::debugln!(
            "worker {worker_id}: PJRT engine ready ({} executables cached)",
            engine.cached_count()
        );
        Ok(Box::new(PjrtWorker {
            spec,
            ws,
            engine,
            base,
            calib,
        }))
    }

    fn label(&self) -> String {
        format!("pjrt:{} [{}]", self.model, self.recipe.label())
    }
}

/// The spec/ws/calib are retained past startup so [`WorkerEngine::swap`]
/// can re-prepare without reloading; the calibration (fixed-seed probe)
/// is computed at most once per worker and reused across swaps.
struct PjrtWorker {
    spec: ModelSpec,
    ws: WeightStore,
    engine: Engine,
    base: Inputs,
    calib: Option<Calibration>,
}

impl WorkerEngine for PjrtWorker {
    fn infer(&mut self, batch: &TensorF) -> Result<TensorF> {
        let n = batch.shape()[0];
        let art = self.spec.fwd_for_batch(n)?;
        let exe = self.engine.load(art)?;
        let xb = if n == art.batch {
            batch.clone()
        } else {
            pad_rows(batch, art.batch)?
        };
        self.base.insert("x".into(), Input::F32(xb));
        let mut out = exe.execute(&self.base)?;
        out.take("logits")
    }

    fn swap(&mut self, recipe: &QuantRecipe) -> Result<()> {
        let needs_calib = recipe.needs_calibration(&self.spec);
        if needs_calib && self.calib.is_none() {
            // first activation-quantizing recipe on this worker: probe
            // once, reuse for every later swap (the calib set is fixed)
            self.calib = serve_calibration(&self.engine, &self.spec, &self.ws, recipe)?;
        }
        let calib = if needs_calib { self.calib.as_ref() } else { None };
        let prep = pipeline::prepare_cached(&self.spec, &self.ws, calib, recipe)?;
        let mut base: Inputs = Default::default();
        prep.insert_inputs(&mut base);
        self.base = base;
        Ok(())
    }
}

/// Synthetic backend: deterministic logits plus a calibrated CPU burn.
///
/// The burn is a busy-spin, not a sleep — it occupies a core exactly as
/// a compute-bound engine would, so throughput scales with workers only
/// when real parallel hardware exists. That property is what the
/// worker-sweep integration test asserts.
pub struct SimFactory {
    pub classes: usize,
    /// Fixed cost per forward pass (kernel launch / dispatch overhead).
    pub cost_per_batch: Duration,
    /// Additional cost per batched row (per-image compute).
    pub cost_per_item: Duration,
}

impl Default for SimFactory {
    fn default() -> Self {
        SimFactory {
            classes: 10,
            cost_per_batch: Duration::from_micros(200),
            cost_per_item: Duration::from_micros(100),
        }
    }
}

impl EngineFactory for SimFactory {
    fn build(&self, _worker_id: usize) -> Result<Box<dyn WorkerEngine>> {
        if self.classes == 0 {
            bail!("sim backend needs classes >= 1");
        }
        Ok(Box::new(SimWorker {
            classes: self.classes,
            cost_per_batch: self.cost_per_batch,
            cost_per_item: self.cost_per_item,
        }))
    }

    fn label(&self) -> String {
        format!(
            "sim:{}c {}us/batch {}us/item",
            self.classes,
            self.cost_per_batch.as_micros(),
            self.cost_per_item.as_micros()
        )
    }
}

struct SimWorker {
    classes: usize,
    cost_per_batch: Duration,
    cost_per_item: Duration,
}

/// Busy-spin for `d` (occupies the core, unlike `sleep`).
fn burn(d: Duration) {
    let end = Instant::now() + d;
    while Instant::now() < end {
        std::hint::spin_loop();
    }
}

impl WorkerEngine for SimWorker {
    fn infer(&mut self, batch: &TensorF) -> Result<TensorF> {
        let n = batch.shape().first().copied().unwrap_or(0);
        if n == 0 || batch.len() % n != 0 {
            bail!("sim backend: bad batch shape {:?}", batch.shape());
        }
        let row = batch.len() / n;
        burn(self.cost_per_batch + self.cost_per_item * n as u32);
        let mut data = Vec::with_capacity(n * self.classes);
        for i in 0..n {
            let s: f32 = batch.data()[i * row..(i + 1) * row].iter().sum();
            for c in 0..self.classes {
                data.push(s + c as f32);
            }
        }
        Ok(TensorF::from_vec(&[n, self.classes], data)?)
    }
}

/// Artifact-free recipe serving: the *real* quantization pipeline (OCS,
/// clip, fake-quant, recipe resolution, [`PreparedCache`] sharing) over
/// an in-memory model, with logits computed deterministically from the
/// prepared weights — so tests and CI observe which prep a worker is
/// serving, including across hot-swaps, without PJRT.
pub struct QuantSimFactory {
    pub spec: Arc<ModelSpec>,
    pub ws: Arc<WeightStore>,
    pub calib: Option<Arc<Calibration>>,
    pub recipe: QuantRecipe,
    /// A shared cache instance for the pool (`Arc::new(PreparedCache::
    /// new())`, cloned into every factory that should share preps) —
    /// tests use a private one to assert hit/miss counts in isolation.
    /// (The `&'static` process-global of [`PreparedCache::global`] is
    /// what the PJRT path uses via `prepare_cached`; this field wants an
    /// owned `Arc` so sim pools can be torn down with their cache.)
    pub cache: Arc<PreparedCache>,
}

/// A scalar that pins down the prepared weights: changing any quantized
/// value, grid, or threshold moves it (so swapped recipes are visible in
/// the served logits).
fn weight_signature(prep: &PreparedModel) -> f32 {
    let mut sig = 0.0f64;
    for l in &prep.layers {
        for &v in l.w.data() {
            sig += v as f64;
        }
        sig += l.adelta as f64 + l.w_threshold as f64 + l.splits as f64;
    }
    sig as f32
}

impl EngineFactory for QuantSimFactory {
    fn build(&self, _worker_id: usize) -> Result<Box<dyn WorkerEngine>> {
        if self.spec.num_classes == 0 {
            bail!("quant-sim backend needs num_classes >= 1");
        }
        let prep = self
            .cache
            .get_or_prepare(&self.spec, &self.ws, self.calib.as_deref(), &self.recipe)?;
        Ok(Box::new(QuantSimWorker {
            spec: self.spec.clone(),
            ws: self.ws.clone(),
            calib: self.calib.clone(),
            cache: self.cache.clone(),
            classes: self.spec.num_classes,
            wsig: weight_signature(prep.as_ref()),
            tenant_wsigs: BTreeMap::new(),
        }))
    }

    fn label(&self) -> String {
        format!("qsim:{} [{}]", self.spec.name, self.recipe.label())
    }
}

struct QuantSimWorker {
    spec: Arc<ModelSpec>,
    ws: Arc<WeightStore>,
    calib: Option<Arc<Calibration>>,
    cache: Arc<PreparedCache>,
    classes: usize,
    wsig: f32,
    /// Per-tenant signatures, built lazily on a tenant's first request
    /// (tenant id -> signature of its prepared weights).
    tenant_wsigs: BTreeMap<usize, f32>,
}

impl QuantSimWorker {
    fn logits(&self, batch: &TensorF, wsig: f32) -> Result<TensorF> {
        let n = batch.shape().first().copied().unwrap_or(0);
        if n == 0 || batch.len() % n != 0 {
            bail!("quant-sim backend: bad batch shape {:?}", batch.shape());
        }
        let row = batch.len() / n;
        let mut data = Vec::with_capacity(n * self.classes);
        for i in 0..n {
            let s: f32 = batch.data()[i * row..(i + 1) * row].iter().sum();
            for c in 0..self.classes {
                data.push(s + wsig + c as f32);
            }
        }
        Ok(TensorF::from_vec(&[n, self.classes], data)?)
    }

    fn prepare_sig(&self, recipe: &QuantRecipe) -> Result<f32> {
        let prep = self
            .cache
            .get_or_prepare(&self.spec, &self.ws, self.calib.as_deref(), recipe)?;
        Ok(weight_signature(prep.as_ref()))
    }
}

impl WorkerEngine for QuantSimWorker {
    fn infer(&mut self, batch: &TensorF) -> Result<TensorF> {
        self.logits(batch, self.wsig)
    }

    fn infer_tenant(&mut self, t: &TenantCtx, batch: &TensorF) -> Result<TensorF> {
        let recipe = match (t.id, t.recipe) {
            (0, _) | (_, None) => return self.infer(batch),
            (_, Some(r)) => r,
        };
        let wsig = match self.tenant_wsigs.get(&t.id) {
            Some(w) => *w,
            None => {
                let w = self.prepare_sig(recipe)?;
                self.tenant_wsigs.insert(t.id, w);
                crate::debugln!("quant-sim prep for tenant {} built on first request", t.name);
                w
            }
        };
        self.logits(batch, wsig)
    }

    fn swap(&mut self, recipe: &QuantRecipe) -> Result<()> {
        self.wsig = self.prepare_sig(recipe)?;
        Ok(())
    }

    fn swap_tenant(&mut self, t: &TenantCtx, recipe: &QuantRecipe) -> Result<()> {
        if t.id == 0 {
            return self.swap(recipe);
        }
        // eager rebuild only where state exists; a failure keeps the
        // tenant's old prep, and untouched tenants build lazily later
        if self.tenant_wsigs.contains_key(&t.id) {
            let w = self.prepare_sig(recipe)?;
            self.tenant_wsigs.insert(t.id, w);
        }
        Ok(())
    }
}

/// The native integer backend: every worker runs real quantized compute
/// on the packed i8 GEMM kernels — the same `Engine`-shaped surface as
/// PJRT, with no artifacts, no HLO, and no `pjrt` feature. The prepared
/// pipeline is shared across workers through `cache` exactly like the
/// other recipe-carrying factories, and hot-swap re-lowers the packed
/// weights per worker.
pub struct NativeFactory {
    pub spec: Arc<ModelSpec>,
    pub ws: Arc<WeightStore>,
    /// The pool's shared calibration slot: the fixed-seed native probe
    /// runs at most once per pool, however many workers build on (or
    /// hot-swap to) an activation-quantizing recipe.
    pub calib: Arc<Mutex<Option<Arc<Calibration>>>>,
    pub recipe: QuantRecipe,
    /// Shared prepared-model cache for the pool (see
    /// [`QuantSimFactory::cache`] for the owned-vs-global trade-off).
    /// Inherits the process-wide capacity (`--prep-cache-cap`) at
    /// construction.
    pub cache: Arc<PreparedCache>,
    /// Kernel-pool width for each worker's GEMMs. Default 1: the pool
    /// already runs one worker per core, so per-worker serial GEMMs
    /// keep worker scaling clean; a single-worker deployment can widen.
    pub gemm_threads: usize,
}

/// The pool's calibration, computed through the native float probe on
/// first need and shared ever after (serializing racers on the slot
/// lock, like [`PreparedCache`] — the losers would only redo identical
/// fixed-seed work).
fn native_calibration(
    slot: &Mutex<Option<Arc<Calibration>>>,
    spec: &ModelSpec,
    ws: &WeightStore,
) -> Result<Arc<Calibration>> {
    // poison-tolerant: a worker that panicked mid-build must not wedge
    // every other worker's calibration
    let mut guard = slot.lock().unwrap_or_else(|e| e.into_inner());
    if let Some(c) = guard.as_ref() {
        return Ok(c.clone());
    }
    let calib_set = crate::train::data::synth_images(64, 929);
    let c = Arc::new(crate::runtime::native::native_calibrate(
        spec,
        ws,
        &calib_set.x,
        32,
    )?);
    *guard = Some(c.clone());
    Ok(c)
}

impl NativeFactory {
    /// Over an explicit in-memory model (tests, embedded serving).
    /// Runs the native calibration probe up front when the recipe
    /// quantizes activations.
    pub fn over(spec: ModelSpec, ws: WeightStore, recipe: QuantRecipe) -> Result<NativeFactory> {
        let calib = Arc::new(Mutex::new(None));
        if recipe.needs_calibration(&spec) {
            native_calibration(&calib, &spec, &ws)?;
        }
        let cache = Arc::new(PreparedCache::new());
        cache.set_capacity(PreparedCache::global().capacity());
        Ok(NativeFactory {
            spec: Arc::new(spec),
            ws: Arc::new(ws),
            calib,
            recipe,
            cache,
            gemm_threads: 1,
        })
    }

    /// The built-in synthetic MLP — fully artifact-free serving
    /// (`ocs serve --backend native --sim-free`).
    pub fn synthetic(recipe: QuantRecipe) -> Result<NativeFactory> {
        let (spec, ws) = crate::runtime::native::synthetic_mlp(2027);
        Self::over(spec, ws, recipe)
    }

    /// A real artifacts-dir model executed natively (no PJRT: the spec
    /// and weights are read, the HLO never is).
    pub fn from_artifacts(
        artifacts_dir: &str,
        model: &str,
        recipe: QuantRecipe,
    ) -> Result<NativeFactory> {
        let spec = ModelSpec::load_named(artifacts_dir, model)?;
        let (ws, trained) = WeightStore::load_best(&spec)?;
        if !trained {
            crate::warnln!("no trained weights for {model}; serving the init seed");
        }
        Self::over(spec, ws, recipe)
    }
}

impl EngineFactory for NativeFactory {
    fn build(&self, worker_id: usize) -> Result<Box<dyn WorkerEngine>> {
        let calib = if self.recipe.needs_calibration(&self.spec) {
            Some(native_calibration(&self.calib, &self.spec, &self.ws)?)
        } else {
            None
        };
        let prep =
            self.cache
                .get_or_prepare(&self.spec, &self.ws, calib.as_deref(), &self.recipe)?;
        let exe = crate::runtime::native::NativeExecutable::build(&self.spec, &prep)?
            .with_threads(self.gemm_threads);
        crate::debugln!(
            "worker {worker_id}: native engine ready ({} int / {} f32 layers)",
            exe.int_layers(),
            exe.float_layers()
        );
        Ok(Box::new(NativeWorker {
            spec: self.spec.clone(),
            ws: self.ws.clone(),
            calib: self.calib.clone(),
            cache: self.cache.clone(),
            gemm_threads: self.gemm_threads,
            exe,
            tenant_exes: BTreeMap::new(),
            scratch: crate::runtime::Scratch::default(),
        }))
    }

    fn label(&self) -> String {
        format!("native:{} [{}]", self.spec.name, self.recipe.label())
    }
}

struct NativeWorker {
    spec: Arc<ModelSpec>,
    ws: Arc<WeightStore>,
    /// Shared with the factory and every sibling worker: a swap to the
    /// pool's first activation-quantizing recipe probes once, pool-wide.
    calib: Arc<Mutex<Option<Arc<Calibration>>>>,
    cache: Arc<PreparedCache>,
    gemm_threads: usize,
    /// Tenant 0's executable (the pool recipe).
    exe: crate::runtime::native::NativeExecutable,
    /// Per-tenant executables, built lazily on a tenant's first request
    /// so cold tenants cost nothing; the *prepared models* behind them
    /// still come from the shared [`PreparedCache`], so N workers pay
    /// one prepare per tenant recipe (each worker re-lowers the packed
    /// weights, which is the cheap half).
    tenant_exes: BTreeMap<usize, crate::runtime::native::NativeExecutable>,
    /// Worker-owned im2col / activation-quant / packing arenas, shared
    /// by every executable this worker runs (tenant 0 and all tenant
    /// overrides serve the same model shapes, so one high-water mark
    /// covers them all). Bit-identical to the allocating path.
    scratch: crate::runtime::Scratch,
}

impl NativeWorker {
    fn build_exe(&self, recipe: &QuantRecipe) -> Result<crate::runtime::native::NativeExecutable> {
        let calib = if recipe.needs_calibration(&self.spec) {
            Some(native_calibration(&self.calib, &self.spec, &self.ws)?)
        } else {
            None
        };
        let prep = self
            .cache
            .get_or_prepare(&self.spec, &self.ws, calib.as_deref(), recipe)?;
        Ok(crate::runtime::native::NativeExecutable::build(&self.spec, &prep)?
            .with_threads(self.gemm_threads))
    }
}

impl WorkerEngine for NativeWorker {
    fn infer(&mut self, batch: &TensorF) -> Result<TensorF> {
        self.exe.infer_with(batch, &mut self.scratch)
    }

    fn infer_tenant(&mut self, t: &TenantCtx, batch: &TensorF) -> Result<TensorF> {
        let recipe = match (t.id, t.recipe) {
            (0, _) | (_, None) => return self.infer(batch),
            (_, Some(r)) => r,
        };
        if !self.tenant_exes.contains_key(&t.id) {
            let exe = self.build_exe(recipe)?;
            crate::debugln!("native prep for tenant {} built on first request", t.name);
            self.tenant_exes.insert(t.id, exe);
        }
        self.tenant_exes[&t.id].infer_with(batch, &mut self.scratch)
    }

    fn swap(&mut self, recipe: &QuantRecipe) -> Result<()> {
        self.exe = self.build_exe(recipe)?;
        Ok(())
    }

    fn swap_tenant(&mut self, t: &TenantCtx, recipe: &QuantRecipe) -> Result<()> {
        if t.id == 0 {
            return self.swap(recipe);
        }
        // rebuild eagerly only if this worker already serves the tenant;
        // on failure the old executable keeps serving
        if self.tenant_exes.contains_key(&t.id) {
            let exe = self.build_exe(recipe)?;
            self.tenant_exes.insert(t.id, exe);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clip::ClipMethod;
    use crate::model::{LayerKind, LayerSpec};
    use crate::pipeline::QuantConfig;
    use crate::util::rng::Rng;

    #[test]
    fn sim_logits_deterministic_and_shaped() {
        let f = SimFactory {
            classes: 4,
            cost_per_batch: Duration::ZERO,
            cost_per_item: Duration::ZERO,
        };
        let mut w = f.build(0).unwrap();
        let x = TensorF::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        let a = w.infer(&x).unwrap();
        let b = w.infer(&x).unwrap();
        assert_eq!(a.shape(), &[2, 4]);
        assert_eq!(a.data(), b.data(), "sim must be deterministic");
        // row 0 sums to 6, row 1 to 15; class c adds c
        assert_eq!(a.data()[0], 6.0);
        assert_eq!(a.data()[4 + 1], 16.0);
        // the plain sim holds no prep, so hot-swap refuses
        assert!(w.swap(&QuantRecipe::float()).is_err());
    }

    #[test]
    fn sim_rejects_degenerate_config() {
        let f = SimFactory {
            classes: 0,
            ..SimFactory::default()
        };
        assert!(f.build(0).is_err());
        let mut w = SimFactory::default().build(0).unwrap();
        assert!(w.infer(&TensorF::zeros(&[0, 3])).is_err());
    }

    #[test]
    fn burn_occupies_at_least_requested_time() {
        let t0 = Instant::now();
        burn(Duration::from_millis(2));
        assert!(t0.elapsed() >= Duration::from_millis(2));
    }

    #[test]
    fn labels_are_informative() {
        assert!(SimFactory::default().label().starts_with("sim:"));
        let p = PjrtFactory {
            artifacts_dir: "artifacts".into(),
            model: "minivgg".into(),
            recipe: QuantRecipe::float(),
            max_batch: 8,
        };
        assert!(p.label().contains("minivgg"));
    }

    fn qsim(recipe: QuantRecipe, cache: Arc<PreparedCache>) -> QuantSimFactory {
        let layers = vec![LayerSpec {
            name: "f1".into(),
            kind: LayerKind::Fc,
            cin: 8,
            cin_pad: 10,
            cout: 4,
            ksize: 0,
            stride: 1,
            quantized: true,
            w_cin_axis: 0,
            w_shape: vec![8, 4],
            w_shape_pad: vec![10, 4],
        }];
        let spec = ModelSpec {
            name: "qsim".into(),
            dir: std::path::PathBuf::new(),
            pad_factor: 1.25,
            num_classes: 4,
            img_hw: 0,
            img_c: 0,
            vocab: 0,
            seq_len: 0,
            momentum: 0.9,
            layers,
            artifacts: Default::default(),
        };
        let mut rng = Rng::new(11);
        let mut wdata = rng.normal_vec(32);
        wdata[5 * 4] = 9.0; // outlier channel
        let ws = WeightStore::from_leaves(vec![
            ("f1.W".into(), TensorF::from_vec(&[8, 4], wdata).unwrap()),
            ("f1.b".into(), TensorF::zeros(&[4])),
        ]);
        QuantSimFactory {
            spec: Arc::new(spec),
            ws: Arc::new(ws),
            calib: None,
            recipe,
            cache,
        }
    }

    #[test]
    fn native_factory_serves_and_swaps() {
        let recipe = QuantConfig::weights_only(5, ClipMethod::Mse, 0.05).to_recipe();
        let f = NativeFactory::synthetic(recipe).unwrap();
        assert!(f.label().starts_with("native:"), "{}", f.label());
        let mut w = f.build(0).unwrap();
        let x = crate::train::data::synth_images(2, 5).x;
        let a = w.infer(&x).unwrap();
        assert_eq!(a.shape(), &[2, 10]);
        assert!(a.data().iter().all(|v| v.is_finite()));
        // hot-swap to float: the served logits must move
        w.swap(&QuantRecipe::float()).unwrap();
        let b = w.infer(&x).unwrap();
        assert_ne!(a.data(), b.data(), "swap must be observable");
        // swap back: a cache hit, identical logits again
        w.swap(&f.recipe).unwrap();
        assert_eq!(w.infer(&x).unwrap().data(), a.data());
        assert_eq!(f.cache.misses(), 2, "swap-back re-lowers from the cache");
        assert!(f.cache.hits() >= 1);
    }

    #[test]
    fn tenants_get_their_own_preps_lazily() {
        let cache = Arc::new(PreparedCache::new());
        let r4 = QuantConfig::weights_only(4, ClipMethod::None, 0.0).to_recipe();
        let r8 = QuantConfig::weights_only(8, ClipMethod::Mse, 0.1).to_recipe();
        let f = qsim(r4.clone(), cache.clone());
        let mut w = f.build(0).unwrap();
        let x = TensorF::from_vec(&[1, 3], vec![0.5, 0.25, 0.25]).unwrap();
        let base = w.infer(&x).unwrap();
        // a recipe-less tenant ctx serves the default prep, no extra prepare
        let t_none = TenantCtx { id: 3, name: "plain", recipe: None };
        assert_eq!(w.infer_tenant(&t_none, &x).unwrap().data(), base.data());
        assert_eq!(cache.misses(), 1);
        // a recipe-carrying tenant builds its prep on first request only
        let t8 = TenantCtx { id: 1, name: "gold", recipe: Some(&r8) };
        let gold = w.infer_tenant(&t8, &x).unwrap();
        assert_ne!(gold.data(), base.data(), "tenant prep must be observable");
        assert_eq!(cache.misses(), 2);
        assert_eq!(w.infer_tenant(&t8, &x).unwrap().data(), gold.data());
        assert_eq!(cache.misses(), 2, "second request reuses the tenant prep");
        // swapping a tenant this worker never served is free (lazy pickup)
        let cold = TenantCtx { id: 2, name: "cold", recipe: Some(&r4) };
        w.swap_tenant(&cold, &r4).unwrap();
        assert_eq!(cache.misses(), 2);
        // swapping the served tenant rebuilds it; tenant 0 is untouched
        w.swap_tenant(&t8, &r4).unwrap();
        assert_eq!(w.infer_tenant(&t8, &x).unwrap().data(), base.data());
        assert_eq!(w.infer(&x).unwrap().data(), base.data());
        assert_eq!(cache.misses(), 2, "swap to an already-prepared recipe hits");
    }

    #[test]
    fn native_worker_serves_per_tenant_executables() {
        let r5 = QuantConfig::weights_only(5, ClipMethod::Mse, 0.05).to_recipe();
        let f = NativeFactory::synthetic(r5).unwrap();
        let mut w = f.build(0).unwrap();
        let x = crate::train::data::synth_images(2, 5).x;
        let a = w.infer(&x).unwrap();
        let rf = QuantRecipe::float();
        let t = TenantCtx { id: 1, name: "gold", recipe: Some(&rf) };
        let g = w.infer_tenant(&t, &x).unwrap();
        assert_ne!(a.data(), g.data(), "tenant recipe must be observable");
        // tenant 0 keeps serving the pool recipe, bit-identical
        let t0 = TenantCtx { id: 0, name: "default", recipe: None };
        assert_eq!(w.infer_tenant(&t0, &x).unwrap().data(), a.data());
        assert_eq!(f.cache.misses(), 2, "one prepare per distinct tenant recipe");
    }

    #[test]
    fn quant_sim_serves_prep_and_hot_swaps() {
        let cache = Arc::new(PreparedCache::new());
        let r4 = QuantConfig::weights_only(4, ClipMethod::None, 0.0).to_recipe();
        let r8 = QuantConfig::weights_only(8, ClipMethod::Mse, 0.1).to_recipe();
        let f = qsim(r4.clone(), cache.clone());
        let mut w = f.build(0).unwrap();
        assert!(f.label().starts_with("qsim:"), "{}", f.label());
        let x = TensorF::from_vec(&[1, 3], vec![0.5, 0.25, 0.25]).unwrap();
        let before = w.infer(&x).unwrap();
        // same recipe again: cache hit, identical logits
        let mut w2 = f.build(1).unwrap();
        assert_eq!(w2.infer(&x).unwrap().data(), before.data());
        assert_eq!((cache.misses(), cache.hits()), (1, 1));
        // hot-swap to a different recipe: logits must move
        w.swap(&r8).unwrap();
        let after = w.infer(&x).unwrap();
        assert_ne!(before.data(), after.data(), "swap must be observable");
        assert_eq!(cache.misses(), 2);
        // swapping back reuses the cached original prep
        w.swap(&r4).unwrap();
        assert_eq!(w.infer(&x).unwrap().data(), before.data());
        assert_eq!(cache.misses(), 2, "swap-back is a cache hit");
    }
}
