//! Mini property-testing framework (proptest is unavailable offline).
//!
//! A property is a closure over a seeded [`Rng`]; the runner executes it
//! for `cases` independent seeds and reports the first failing seed so a
//! failure is reproducible with [`check_one`]. No shrinking — generators
//! here are small enough that the failing seed is directly debuggable.

use crate::util::rng::Rng;

pub const DEFAULT_CASES: usize = 64;

/// Run `prop` for `cases` seeds derived from `base_seed`. Panics with the
/// failing seed + message on the first counterexample.
pub fn check_n<F>(name: &str, base_seed: u64, cases: usize, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (reproduce with \
                 miniprop::check_one(\"{name}\", {seed}, ..)): {msg}"
            );
        }
    }
}

/// Default-case-count runner.
pub fn check<F>(name: &str, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    check_n(name, 0x0C5_u64 ^ 0x5EED, DEFAULT_CASES, prop)
}

/// Re-run a single failing seed.
pub fn check_one<F>(name: &str, seed: u64, prop: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' failed for seed {seed}: {msg}");
    }
}

// ---------------------------------------------------------------------------
// Generators
// ---------------------------------------------------------------------------

/// Uniform usize in [lo, hi].
pub fn gen_usize(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

/// Vec of normals with random length in [min_len, max_len].
pub fn gen_normal_vec(rng: &mut Rng, min_len: usize, max_len: usize, sigma: f32) -> Vec<f32> {
    let n = gen_usize(rng, min_len, max_len);
    (0..n).map(|_| rng.normal() * sigma).collect()
}

/// Heavy-tailed vector: mostly N(0, sigma) with a few big outliers —
/// the weight-distribution shape OCS targets.
pub fn gen_outlier_vec(rng: &mut Rng, min_len: usize, max_len: usize) -> Vec<f32> {
    let n = gen_usize(rng, min_len, max_len);
    (0..n)
        .map(|_| {
            if rng.next_f32() < 0.02 {
                rng.normal() * 8.0
            } else {
                rng.normal()
            }
        })
        .collect()
}

/// Small random tensor shape with bounded rank/size.
pub fn gen_shape(rng: &mut Rng, max_rank: usize, max_dim: usize) -> Vec<usize> {
    let rank = gen_usize(rng, 1, max_rank);
    (0..rank).map(|_| gen_usize(rng, 1, max_dim)).collect()
}

/// Assertion helpers for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_close(a: f64, b: f64, tol: f64, ctx: &str) -> Result<(), String> {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{ctx}: {a} != {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("sum-commutes", |rng| {
            let a = rng.normal();
            let b = rng.normal();
            ensure((a + b - (b + a)).abs() < 1e-9, "commutativity")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_panics_with_seed() {
        check_n("always-fails", 1, 4, |_| Err("nope".into()));
    }

    #[test]
    fn generators_respect_bounds() {
        check("gen-bounds", |rng| {
            let n = gen_usize(rng, 3, 9);
            ensure((3..=9).contains(&n), format!("usize {n}"))?;
            let v = gen_normal_vec(rng, 1, 5, 1.0);
            ensure((1..=5).contains(&v.len()), "vec len")?;
            let s = gen_shape(rng, 4, 6);
            ensure(s.iter().all(|&d| (1..=6).contains(&d)), "shape dims")
        });
    }
}
