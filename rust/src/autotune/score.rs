//! Candidate scoring for `ocs autotune`: accuracy on the native
//! backend, packed-model footprint, and a measured per-layer GEMM
//! latency model.
//!
//! One [`Scorer`] owns everything a search needs to evaluate a
//! [`QuantRecipe`]: the model + weights, a held-out image set, a float
//! reference executable (for logit agreement), a lazily-computed
//! activation [`Calibration`], and a *private* [`PreparedCache`] — the
//! search deliberately does not share [`PreparedCache::global`] so its
//! hit/miss/eviction counters describe the search alone and capacity
//! experiments cannot disturb a colocated server.
//!
//! Scores are memoized by recipe fingerprint, so drivers revisit states
//! for free and the journal can report memo hits separately from prep
//! cache hits.

use std::collections::BTreeMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::Result;

use crate::calib::Calibration;
use crate::eval::{accuracy_native, agreement_native};
use crate::kernels::gemm::{gemm_f32, gemm_i8, PackedB};
use crate::model::store::WeightStore;
use crate::model::ModelSpec;
use crate::pipeline::{PreparedCache, QuantRecipe};
use crate::quant::pack::{pack_prepared, PackedModel};
use crate::runtime::native::{native_calibrate, NativeEngine, NativeExecutable};
use crate::tensor::TensorF;
use crate::train::data::synth_images;

/// Scorer knobs — sizes, seeds, and the prep-cache bound.
#[derive(Debug, Clone)]
pub struct ScorerCfg {
    /// Calibration images (probed once, on demand).
    pub calib_images: usize,
    pub calib_batch: usize,
    /// Held-out images every candidate is scored on.
    pub test_images: usize,
    pub eval_batch: usize,
    /// Base seed: calibration and test sets derive from it, so equal
    /// seeds make the whole search replayable.
    pub seed: u64,
    /// Prep-cache entry bound (0 = unbounded).
    pub cache_cap: usize,
    /// Threads for the latency-model GEMM probes.
    pub gemm_threads: usize,
}

impl Default for ScorerCfg {
    fn default() -> ScorerCfg {
        ScorerCfg {
            calib_images: 256,
            calib_batch: 32,
            test_images: 512,
            eval_batch: 128,
            seed: 29,
            cache_cap: 0,
            gemm_threads: 1,
        }
    }
}

/// What one candidate costs and buys.
#[derive(Debug, Clone)]
pub struct Score {
    /// Top-1 accuracy on the held-out set.
    pub accuracy: f64,
    /// Top-1 agreement with the float reference on the same set.
    pub agreement: f64,
    /// Packed-model wire footprint in bytes.
    pub footprint: usize,
    /// Modeled per-sample GEMM latency (µs) — measured, so **not**
    /// deterministic; drivers only use it against an explicit
    /// `--latency-budget-us`, never for default winner selection.
    pub est_latency_us: f64,
    pub fingerprint: String,
    pub label: String,
}

/// Measured per-shape GEMM cost, memoized by `(K, cout, int)`. The
/// probe times the real kernels ([`gemm_i8`] / [`gemm_f32`]) on
/// synthetic payloads and charges each packed layer one GEMM row per
/// sample — an MLP-grade model (conv layers amortize over spatial
/// positions, which this deliberately does not simulate).
#[derive(Debug, Default)]
struct LatencyModel {
    per_row_us: BTreeMap<(usize, usize, bool), f64>,
}

const PROBE_ROWS: usize = 8;
const PROBE_REPS: usize = 3;

impl LatencyModel {
    fn layer_us(&mut self, k: usize, n: usize, int: bool, threads: usize) -> f64 {
        if let Some(&us) = self.per_row_us.get(&(k, n, int)) {
            return us;
        }
        let us = if int {
            let ints = vec![1i8; k * n];
            let pb = PackedB::pack(&ints, k, n);
            let a = vec![1i8; PROBE_ROWS * k];
            let mut best = f64::INFINITY;
            for _ in 0..PROBE_REPS {
                let t = Instant::now();
                let acc = gemm_i8(&a, &pb, PROBE_ROWS, threads);
                let dt = t.elapsed().as_secs_f64();
                assert_eq!(acc.len(), PROBE_ROWS * n);
                best = best.min(dt);
            }
            best * 1e6 / PROBE_ROWS as f64
        } else {
            let w = vec![0.5f32; k * n];
            let a = vec![0.5f32; PROBE_ROWS * k];
            let mut best = f64::INFINITY;
            for _ in 0..PROBE_REPS {
                let t = Instant::now();
                let out = gemm_f32(&a, &w, PROBE_ROWS, k, n, None, threads);
                let dt = t.elapsed().as_secs_f64();
                assert_eq!(out.len(), PROBE_ROWS * n);
                best = best.min(dt);
            }
            best * 1e6 / PROBE_ROWS as f64
        };
        self.per_row_us.insert((k, n, int), us);
        us
    }

    fn model_us(&mut self, packed: &PackedModel, threads: usize) -> f64 {
        packed
            .layers
            .values()
            .map(|l| self.layer_us(l.gemm_k(), l.cout, l.is_int(), threads))
            .sum()
    }
}

/// Evaluates candidate recipes against one model + dataset. See the
/// module docs for what it owns and why the cache is private.
pub struct Scorer {
    spec: ModelSpec,
    ws: WeightStore,
    cfg: ScorerCfg,
    cache: PreparedCache,
    engine: NativeEngine,
    calib: Option<Calibration>,
    test_x: TensorF,
    test_y: Vec<i32>,
    float_exe: Rc<NativeExecutable>,
    /// Float-reference accuracy — the ceiling `--acc-drop` floors are
    /// relative to.
    pub float_accuracy: f64,
    latency: LatencyModel,
    memo: BTreeMap<String, Score>,
    evals: usize,
    scored_total: usize,
}

impl Scorer {
    pub fn new(spec: ModelSpec, ws: WeightStore, cfg: ScorerCfg) -> Result<Scorer> {
        let test = synth_images(cfg.test_images, cfg.seed.wrapping_add(31));
        let cache = PreparedCache::new();
        cache.set_capacity(cfg.cache_cap);
        let engine = NativeEngine::new(spec.clone());
        let float_prep = cache.get_or_prepare(&spec, &ws, None, &QuantRecipe::float())?;
        let float_exe = engine.load(&float_prep)?;
        let float_accuracy = accuracy_native(&float_exe, &test.x, &test.y, cfg.eval_batch)?;
        Ok(Scorer {
            spec,
            ws,
            cache,
            engine,
            calib: None,
            test_x: test.x,
            test_y: test.y,
            float_exe,
            float_accuracy,
            latency: LatencyModel::default(),
            memo: BTreeMap::new(),
            evals: 0,
            scored_total: 0,
            cfg,
        })
    }

    /// Score one candidate (memoized by fingerprint).
    pub fn score(&mut self, recipe: &QuantRecipe) -> Result<Score> {
        self.scored_total += 1;
        let fp = recipe.fingerprint();
        if let Some(s) = self.memo.get(&fp) {
            return Ok(s.clone());
        }
        self.evals += 1;
        if recipe.needs_calibration(&self.spec) && self.calib.is_none() {
            let images = synth_images(self.cfg.calib_images, self.cfg.seed.wrapping_add(29));
            self.calib = Some(native_calibrate(
                &self.spec,
                &self.ws,
                &images.x,
                self.cfg.calib_batch,
            )?);
        }
        let prep = self
            .cache
            .get_or_prepare(&self.spec, &self.ws, self.calib.as_ref(), recipe)?;
        let exe = self.engine.load(&prep)?;
        let accuracy = accuracy_native(&exe, &self.test_x, &self.test_y, self.cfg.eval_batch)?;
        let agreement =
            agreement_native(&exe, &self.float_exe, &self.test_x, self.cfg.eval_batch)?;
        let packed = pack_prepared(&self.spec, &prep)?;
        let est_latency_us = self.latency.model_us(&packed, self.cfg.gemm_threads);
        let score = Score {
            accuracy,
            agreement,
            footprint: packed.footprint_bytes(),
            est_latency_us,
            fingerprint: fp.clone(),
            label: recipe.label(),
        };
        self.memo.insert(fp, score.clone());
        Ok(score)
    }

    /// Distinct recipes actually prepared + evaluated (memo misses).
    pub fn evals(&self) -> usize {
        self.evals
    }

    /// Total `score` calls, memo hits included.
    pub fn scored_total(&self) -> usize {
        self.scored_total
    }

    /// The private prep cache (hit/miss/eviction counters).
    pub fn cache(&self) -> &PreparedCache {
        &self.cache
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clip::ClipMethod;
    use crate::pipeline::QuantConfig;
    use crate::runtime::native::synthetic_mlp;

    fn small_cfg() -> ScorerCfg {
        ScorerCfg {
            calib_images: 64,
            calib_batch: 32,
            test_images: 96,
            eval_batch: 32,
            seed: 5,
            cache_cap: 0,
            gemm_threads: 1,
        }
    }

    #[test]
    fn scoring_memoizes_by_fingerprint() {
        let (spec, ws) = synthetic_mlp(2027);
        let mut scorer = Scorer::new(spec, ws, small_cfg()).unwrap();
        let recipe = QuantConfig::weights_with_a8(5, ClipMethod::Mse, 0.02).to_recipe();
        let a = scorer.score(&recipe).unwrap();
        let b = scorer.score(&recipe).unwrap();
        assert_eq!(a.fingerprint, b.fingerprint);
        assert_eq!(a.accuracy, b.accuracy);
        assert_eq!(scorer.evals(), 1, "second call must hit the memo");
        assert_eq!(scorer.scored_total(), 2);
        assert!(a.footprint > 0);
        assert!(a.est_latency_us > 0.0);
        assert!(a.agreement > 0.0 && a.agreement <= 1.0);
        // w5a8+mse should track the float net closely on most samples
        assert!(a.agreement > 0.5, "agreement {} too low", a.agreement);
    }

    #[test]
    fn float_recipe_scores_at_reference() {
        let (spec, ws) = synthetic_mlp(2027);
        let mut scorer = Scorer::new(spec, ws, small_cfg()).unwrap();
        let s = scorer.score(&QuantRecipe::float()).unwrap();
        assert_eq!(s.accuracy, scorer.float_accuracy);
        assert_eq!(s.agreement, 1.0, "float candidate IS the reference");
    }

    #[test]
    fn lower_bits_shrink_footprint() {
        let (spec, ws) = synthetic_mlp(2027);
        let mut scorer = Scorer::new(spec, ws, small_cfg()).unwrap();
        let w8 = QuantConfig::weights_with_a8(8, ClipMethod::None, 0.0).to_recipe();
        let w4 = QuantConfig::weights_with_a8(4, ClipMethod::None, 0.0).to_recipe();
        let s8 = scorer.score(&w8).unwrap();
        let s4 = scorer.score(&w4).unwrap();
        assert!(
            s4.footprint < s8.footprint,
            "4-bit {} must undercut 8-bit {}",
            s4.footprint,
            s8.footprint
        );
    }
}
