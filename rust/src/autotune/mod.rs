//! `ocs autotune` — budgeted mixed-precision recipe search over the
//! per-layer [`LayerRecipe`](crate::pipeline::LayerRecipe) space.
//!
//! The paper's central trade (OCS ratio vs clipping vs bit width,
//! layer by layer) is a per-layer policy search. This module drives it
//! end to end: a [`SearchSpace`] names the candidate lists and layer
//! grouping, a [`Scorer`] prices each candidate (native-backend
//! accuracy + logit agreement, packed wire footprint, measured GEMM
//! latency) through a private [`PreparedCache`](crate::pipeline::PreparedCache)
//! so revisits are free, and [`search::run`] descends the bit ladder —
//! greedy by default, `--beam N` for a wider frontier — under an
//! accuracy floor and optional footprint/latency budgets.
//!
//! The winner leaves as a `[[quant.layer]]` TOML
//! ([`QuantRecipe::to_toml`](crate::pipeline::QuantRecipe::to_toml))
//! that `ocs serve --recipe` and `ocs tables` consume unmodified, and
//! the search itself is journaled as a versioned `BENCH_autotune.json`
//! ([`BenchRecord::from_autotune`](crate::bench_record::BenchRecord::from_autotune))
//! so CI regression-gates candidate counts, cache behavior, and the
//! Pareto frontier like every other trajectory.
//!
//! Determinism contract: same seed + same model ⇒ identical winning
//! fingerprint. Everything on the selection path (synthetic data,
//! calibration, accuracy, footprint) is seed-deterministic; the one
//! measured quantity (the latency model) only gates candidates when an
//! explicit `--latency-budget-us` asks for it.

pub mod score;
pub mod search;
pub mod space;

pub use score::{Score, Scorer, ScorerCfg};
pub use search::{run, Candidate, SearchCfg, SearchOutcome};
pub use space::{GroupChoice, LayerGroup, SearchSpace};
