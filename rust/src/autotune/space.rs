//! The candidate space `ocs autotune` searches: per-dimension candidate
//! lists plus a layer grouping, lowered into concrete [`QuantRecipe`]s.
//!
//! A [`SearchSpace`] is the cross product of a weight-bit ladder, an
//! activation-bit ladder, a weight-clip list, and an OCS-ratio list,
//! instantiated independently per [`LayerGroup`]. A group is a named
//! [`LayerMatch`] — one per quantized layer by default, or one per
//! layer kind with `--group-by kind` — and every group's current pick
//! is a [`GroupChoice`] of indices into the candidate lists. Index 0 of
//! each list is the *start* point: the uniform baseline the search
//! descends from, and the recipe the winner is compared against.

use anyhow::{bail, Result};

use crate::clip::ClipMethod;
use crate::model::{LayerKind, ModelSpec};
use crate::pipeline::{LayerMatch, LayerOverride, LayerPolicy, QuantRecipe};

/// One searchable unit: a display name plus the match that binds its
/// policy to model layers.
#[derive(Debug, Clone)]
pub struct LayerGroup {
    pub name: String,
    pub matches: LayerMatch,
}

/// Per-dimension candidate lists. Every index-0 entry is the uniform
/// starting point of the search.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// Weight-bit candidates, strictly descending (e.g. `8,6,5,4,3`).
    pub ladder: Vec<u32>,
    /// Activation-bit candidates, descending; `0` = float activations
    /// and is only meaningful as a single entry (there is no point
    /// descending *to* float).
    pub a_bits: Vec<u32>,
    /// Weight-clip candidates re-chosen at every bit drop.
    pub clips: Vec<ClipMethod>,
    /// Activation clip, fixed across the search.
    pub a_clip: ClipMethod,
    /// OCS ratio candidates re-chosen at every bit drop, each in
    /// `[0, 1)`.
    pub ocs_ratios: Vec<f64>,
    /// Whether the search may rescue an infeasible state by keeping a
    /// group float entirely.
    pub allow_skip: bool,
    pub groups: Vec<LayerGroup>,
}

/// One group's current pick: indices into the [`SearchSpace`] lists.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct GroupChoice {
    pub w_idx: usize,
    pub a_idx: usize,
    pub clip_idx: usize,
    pub ocs_idx: usize,
    pub skipped: bool,
}

impl GroupChoice {
    /// The uniform start point: index 0 on every dimension.
    pub fn start() -> GroupChoice {
        GroupChoice {
            w_idx: 0,
            a_idx: 0,
            clip_idx: 0,
            ocs_idx: 0,
            skipped: false,
        }
    }
}

impl SearchSpace {
    /// One group per quantized layer, matched by exact name.
    pub fn per_layer(spec: &ModelSpec) -> Vec<LayerGroup> {
        spec.quantized_layers()
            .map(|l| LayerGroup {
                name: l.name.clone(),
                matches: LayerMatch::name(l.name.clone()),
            })
            .collect()
    }

    /// One group per layer kind present among the quantized layers —
    /// coarser, so deep models stay searchable.
    pub fn by_kind(spec: &ModelSpec) -> Vec<LayerGroup> {
        let mut kinds: Vec<LayerKind> = Vec::new();
        for l in spec.quantized_layers() {
            if !kinds.contains(&l.kind) {
                kinds.push(l.kind);
            }
        }
        kinds
            .into_iter()
            .map(|k| {
                let name = match k {
                    LayerKind::Conv => "conv",
                    LayerKind::Fc => "fc",
                    LayerKind::Embed => "embed",
                };
                LayerGroup {
                    name: name.to_string(),
                    matches: LayerMatch::kind(k),
                }
            })
            .collect()
    }

    /// Reject malformed spaces before any candidate is prepared.
    pub fn validate(&self) -> Result<()> {
        if self.groups.is_empty() {
            bail!("search space has no layer groups");
        }
        if self.ladder.is_empty() {
            bail!("empty w_bits ladder");
        }
        for &b in &self.ladder {
            if !(2..=16).contains(&b) {
                bail!("ladder bit width {b} outside 2..=16");
            }
        }
        if !self.ladder.windows(2).all(|w| w[0] > w[1]) {
            bail!("w_bits ladder must be strictly descending: {:?}", self.ladder);
        }
        if self.a_bits.is_empty() {
            bail!("empty a_bits list");
        }
        for &b in &self.a_bits {
            if b != 0 && !(2..=16).contains(&b) {
                bail!("a_bits candidate {b} outside {{0, 2..=16}}");
            }
        }
        if self.a_bits.len() > 1 && self.a_bits.contains(&0) {
            bail!("a_bits 0 (float) only makes sense as the sole candidate");
        }
        if !self.a_bits.windows(2).all(|w| w[0] > w[1]) {
            bail!("a_bits ladder must be strictly descending: {:?}", self.a_bits);
        }
        if self.clips.is_empty() {
            bail!("empty clip candidate list");
        }
        for r in &self.ocs_ratios {
            if !(0.0..1.0).contains(r) {
                bail!("ocs ratio {r} outside [0, 1)");
            }
        }
        if self.ocs_ratios.is_empty() {
            bail!("empty ocs ratio list");
        }
        Ok(())
    }

    /// Number of distinct assignments one group can take (the skip
    /// option included when allowed) — the journal reports
    /// `per_group ^ groups` as the nominal space size.
    pub fn per_group_candidates(&self) -> usize {
        let dense =
            self.ladder.len() * self.a_bits.len() * self.clips.len() * self.ocs_ratios.len();
        dense + usize::from(self.allow_skip)
    }

    /// Lower an assignment into the concrete [`QuantRecipe`] the
    /// pipeline prepares. Defaults carry the index-0 start point, and
    /// every group gets one explicit override, so the emitted TOML is
    /// self-describing layer by layer.
    pub fn recipe_for(&self, choices: &[GroupChoice]) -> QuantRecipe {
        assert_eq!(choices.len(), self.groups.len(), "one choice per group");
        let mut recipe = QuantRecipe::float();
        recipe.w_bits = Some(self.ladder[0]);
        recipe.a_bits = self.a_bits.first().copied().filter(|&b| b > 0);
        recipe.w_clip = self.clips[0].into();
        recipe.a_clip = self.a_clip.into();
        recipe.ocs_ratio = self.ocs_ratios[0];
        for (group, c) in self.groups.iter().zip(choices) {
            let policy = if c.skipped {
                LayerPolicy::skip()
            } else {
                LayerPolicy::w_bits(self.ladder[c.w_idx])
                    .with_a_bits(self.a_bits[c.a_idx])
                    .with_w_clip(self.clips[c.clip_idx])
                    .with_a_clip(self.a_clip)
                    .with_ocs_ratio(self.ocs_ratios[c.ocs_idx])
            };
            recipe.push_override(LayerOverride {
                matches: group.matches.clone(),
                policy,
            });
        }
        recipe
    }

    /// Human tag for one assignment, e.g. `f1=w4/mse/ocs0.02 f2=skip`.
    pub fn describe(&self, choices: &[GroupChoice]) -> String {
        self.groups
            .iter()
            .zip(choices)
            .map(|(g, c)| {
                if c.skipped {
                    format!("{}=skip", g.name)
                } else {
                    format!(
                        "{}=w{}a{}/{}/ocs{}",
                        g.name,
                        self.ladder[c.w_idx],
                        self.a_bits[c.a_idx],
                        self.clips[c.clip_idx].name(),
                        self.ocs_ratios[c.ocs_idx]
                    )
                }
            })
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::synthetic_mlp;

    fn space_for(spec: &ModelSpec) -> SearchSpace {
        SearchSpace {
            ladder: vec![8, 5, 4],
            a_bits: vec![8],
            clips: vec![ClipMethod::None, ClipMethod::Mse],
            a_clip: ClipMethod::Mse,
            ocs_ratios: vec![0.0, 0.05],
            allow_skip: true,
            groups: SearchSpace::per_layer(spec),
        }
    }

    #[test]
    fn per_layer_groups_cover_quantized_layers() {
        let (spec, _) = synthetic_mlp(11);
        let groups = SearchSpace::per_layer(&spec);
        assert_eq!(groups.len(), spec.quantized_layers().count());
        for (g, l) in groups.iter().zip(spec.quantized_layers()) {
            assert!(g.matches.matches(l, false, false));
        }
    }

    #[test]
    fn by_kind_dedupes() {
        let (spec, _) = synthetic_mlp(12);
        let groups = SearchSpace::by_kind(&spec);
        assert_eq!(groups.len(), 1, "synthetic mlp is all-fc");
        assert_eq!(groups[0].name, "fc");
    }

    #[test]
    fn validation_rejects_malformed_spaces() {
        let (spec, _) = synthetic_mlp(13);
        let good = space_for(&spec);
        good.validate().unwrap();
        let mut bad = good.clone();
        bad.ladder = vec![8, 8];
        assert!(bad.validate().is_err(), "non-descending ladder");
        let mut bad = good.clone();
        bad.ladder = vec![8, 1];
        assert!(bad.validate().is_err(), "1-bit weights");
        let mut bad = good.clone();
        bad.ocs_ratios = vec![1.0];
        assert!(bad.validate().is_err(), "ratio 1.0");
        let mut bad = good.clone();
        bad.a_bits = vec![8, 0];
        assert!(bad.validate().is_err(), "float acts mixed into a ladder");
        let mut bad = good;
        bad.groups.clear();
        assert!(bad.validate().is_err(), "no groups");
    }

    #[test]
    fn start_assignment_is_uniform() {
        let (spec, _) = synthetic_mlp(14);
        let space = space_for(&spec);
        let start = vec![GroupChoice::start(); space.groups.len()];
        let recipe = space.recipe_for(&start);
        // every override restates the defaults, so resolution matches
        // the plain uniform recipe layer by layer
        let mut uniform = QuantRecipe::float();
        uniform.w_bits = Some(8);
        uniform.a_bits = Some(8);
        uniform.w_clip = ClipMethod::None.into();
        uniform.a_clip = ClipMethod::Mse.into();
        for l in spec.quantized_layers() {
            let got = recipe.resolve(l, false, false);
            let want = uniform.resolve(l, false, false);
            assert_eq!(got.w_bits, want.w_bits);
            assert_eq!(got.a_bits, want.a_bits);
            assert_eq!(got.quantize, want.quantize);
        }
    }

    #[test]
    fn skip_choice_lowers_to_float_layer() {
        let (spec, _) = synthetic_mlp(15);
        let space = space_for(&spec);
        let mut choices = vec![GroupChoice::start(); space.groups.len()];
        choices[1].skipped = true;
        let recipe = space.recipe_for(&choices);
        let layers: Vec<_> = spec.quantized_layers().collect();
        assert!(recipe.resolve(layers[0], false, false).quantize);
        assert!(!recipe.resolve(layers[1], false, false).quantize);
        assert_eq!(space.per_group_candidates(), 3 * 1 * 2 * 2 + 1);
        assert!(space.describe(&choices).contains("=skip"));
    }
}
