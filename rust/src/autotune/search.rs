//! Search drivers for `ocs autotune`: greedy bit-ladder descent
//! (default) and width-`N` beam search, both over per-group
//! [`GroupChoice`] assignments.
//!
//! Both drivers start at the uniform index-0 assignment — which **is**
//! the uniform-bits baseline the acceptance criterion compares against
//! — and repeatedly apply single-group moves:
//!
//! * *descend w*: drop one group to the next ladder rung, re-choosing
//!   its clip and OCS ratio from the full candidate lists at the lower
//!   width (the paper's trade: more OCS or a better clip can buy a bit
//!   back);
//! * *descend a*: drop one group to the next activation rung;
//! * *skip*: keep one group float entirely (only with `--allow-skip`,
//!   and only as an accuracy rescue — a float body is *larger*).
//!
//! A state is **feasible** when its accuracy meets the floor and, when
//! set, its modeled latency meets `--latency-budget-us`. Greedy accepts
//! the feasible move with the largest footprint reduction (ties: higher
//! accuracy, then move order — fully deterministic); beam keeps the `N`
//! best feasible frontier states each round. Search stops when the
//! footprint budget is met, no feasible move remains, or the eval
//! budget runs out. Every scored state feeds the Pareto frontier the
//! journal reports.

use anyhow::{bail, Result};

use crate::autotune::score::{Score, Scorer};
use crate::autotune::space::{GroupChoice, SearchSpace};
use crate::pipeline::QuantRecipe;

/// Budgets + driver knobs for one search run.
#[derive(Debug, Clone)]
pub struct SearchCfg {
    /// Absolute accuracy floor (fraction). Build it from the float
    /// reference minus `--acc-drop`.
    pub acc_floor: f64,
    /// Stop descending once the winner's packed footprint is at or
    /// under this many bytes.
    pub footprint_budget: Option<usize>,
    /// Reject candidates whose modeled per-sample GEMM latency exceeds
    /// this (µs). Measured, hence nondeterministic — leave unset for
    /// replayable winners.
    pub latency_budget_us: Option<f64>,
    /// Beam width; 1 = greedy descent.
    pub beam: usize,
    /// Hard cap on distinct candidate evaluations.
    pub max_evals: usize,
}

impl Default for SearchCfg {
    fn default() -> SearchCfg {
        SearchCfg {
            acc_floor: 0.0,
            footprint_budget: None,
            latency_budget_us: None,
            beam: 1,
            max_evals: 512,
        }
    }
}

/// One scored assignment.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub choices: Vec<GroupChoice>,
    pub recipe: QuantRecipe,
    pub score: Score,
}

/// Everything a search run produced — winner, baseline, bookkeeping
/// for the journal.
#[derive(Debug)]
pub struct SearchOutcome {
    pub winner: Candidate,
    /// The uniform start state (ladder\[0\] everywhere).
    pub baseline: Candidate,
    pub float_accuracy: f64,
    pub acc_floor: f64,
    /// Distinct candidates prepared + evaluated.
    pub evaluated: usize,
    /// Total score calls, memo hits included.
    pub scored_total: usize,
    /// `(footprint, accuracy)` of every non-dominated scored state,
    /// footprint-ascending.
    pub pareto: Vec<(usize, f64)>,
    pub beam: usize,
    pub groups: usize,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
}

impl SearchOutcome {
    /// Fraction of prep lookups the cache answered (0 when none ran).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

fn feasible(score: &Score, cfg: &SearchCfg) -> bool {
    score.accuracy >= cfg.acc_floor
        && cfg
            .latency_budget_us
            .map(|b| score.est_latency_us <= b)
            .unwrap_or(true)
}

/// All single-group successors of `state`, in a fixed deterministic
/// order (group-major, then move kind, then clip × ocs).
fn moves(space: &SearchSpace, state: &[GroupChoice]) -> Vec<Vec<GroupChoice>> {
    let mut out = Vec::new();
    for (g, c) in state.iter().enumerate() {
        if c.skipped {
            continue;
        }
        if c.w_idx + 1 < space.ladder.len() {
            for clip_idx in 0..space.clips.len() {
                for ocs_idx in 0..space.ocs_ratios.len() {
                    let mut next = state.to_vec();
                    next[g] = GroupChoice {
                        w_idx: c.w_idx + 1,
                        clip_idx,
                        ocs_idx,
                        ..*c
                    };
                    out.push(next);
                }
            }
        }
        if c.a_idx + 1 < space.a_bits.len() {
            let mut next = state.to_vec();
            next[g].a_idx = c.a_idx + 1;
            out.push(next);
        }
        if space.allow_skip {
            let mut next = state.to_vec();
            next[g] = GroupChoice {
                skipped: true,
                ..GroupChoice::start()
            };
            out.push(next);
        }
    }
    out
}

/// Non-dominated `(footprint, accuracy)` rows, footprint-ascending.
fn pareto_frontier(points: &[(usize, f64)]) -> Vec<(usize, f64)> {
    let mut sorted: Vec<(usize, f64)> = points.to_vec();
    sorted.sort_by(|a, b| a.0.cmp(&b.0).then(b.1.total_cmp(&a.1)));
    let mut out: Vec<(usize, f64)> = Vec::new();
    for p in sorted {
        // sort order guarantees same-footprint points arrive accuracy-
        // descending, so a strict accuracy improvement implies a strict
        // footprint increase
        if out.last().map(|l| p.1 > l.1).unwrap_or(true) {
            out.push(p);
        }
    }
    out
}

/// Run the search. `cfg.beam == 1` is greedy descent; larger beams keep
/// the `N` lowest-footprint feasible states each round.
pub fn run(space: &SearchSpace, scorer: &mut Scorer, cfg: &SearchCfg) -> Result<SearchOutcome> {
    space.validate()?;
    if cfg.beam == 0 {
        bail!("beam width must be >= 1");
    }
    let mut all_points: Vec<(usize, f64)> = Vec::new();
    let mut eval = |scorer: &mut Scorer,
                    choices: &[GroupChoice],
                    points: &mut Vec<(usize, f64)>|
     -> Result<Candidate> {
        let recipe = space.recipe_for(choices);
        let score = scorer.score(&recipe)?;
        points.push((score.footprint, score.accuracy));
        Ok(Candidate {
            choices: choices.to_vec(),
            recipe,
            score,
        })
    };

    let start = vec![GroupChoice::start(); space.groups.len()];
    let baseline = eval(scorer, &start, &mut all_points)?;
    let mut current = baseline.clone();

    // Rescue an infeasible start: greedily skip the group whose float
    // fallback buys the most accuracy until the floor holds.
    while !feasible(&current.score, cfg) {
        if !space.allow_skip {
            bail!(
                "uniform start ({}) misses the accuracy floor {:.4} (got {:.4}); \
                 lower the floor, raise the ladder start, or pass --allow-skip",
                current.score.label,
                cfg.acc_floor,
                current.score.accuracy
            );
        }
        let mut best: Option<Candidate> = None;
        for (g, c) in current.choices.iter().enumerate() {
            if c.skipped {
                continue;
            }
            let mut next = current.choices.clone();
            next[g] = GroupChoice {
                skipped: true,
                ..GroupChoice::start()
            };
            let cand = eval(scorer, &next, &mut all_points)?;
            if best
                .as_ref()
                .map(|b| cand.score.accuracy > b.score.accuracy)
                .unwrap_or(true)
            {
                best = Some(cand);
            }
        }
        match best {
            Some(b) => current = b,
            None => bail!(
                "accuracy floor {:.4} unreachable even with every group skipped",
                cfg.acc_floor
            ),
        }
        if scorer.evals() >= cfg.max_evals {
            bail!("eval budget {} exhausted during rescue", cfg.max_evals);
        }
    }

    // `current` is now feasible. Beam of feasible frontier states.
    let mut frontier = vec![current.clone()];
    let mut best = current;
    let mut visited = std::collections::BTreeSet::new();
    visited.insert(best.score.fingerprint.clone());
    'search: loop {
        if cfg
            .footprint_budget
            .map(|b| best.score.footprint <= b)
            .unwrap_or(false)
        {
            break; // budget met — stop descending
        }
        let mut next_frontier: Vec<Candidate> = Vec::new();
        for state in &frontier {
            for mv in moves(space, &state.choices) {
                let fp = space.recipe_for(&mv).fingerprint();
                if !visited.insert(fp) {
                    continue;
                }
                if scorer.evals() >= cfg.max_evals {
                    break 'search;
                }
                let cand = eval(scorer, &mv, &mut all_points)?;
                if feasible(&cand.score, cfg) {
                    next_frontier.push(cand);
                }
            }
        }
        if next_frontier.is_empty() {
            break; // no feasible descent left
        }
        // deterministic ranking: footprint up, accuracy down, then the
        // canonical recipe string as the final tiebreak
        next_frontier.sort_by(|a, b| {
            a.score
                .footprint
                .cmp(&b.score.footprint)
                .then(b.score.accuracy.total_cmp(&a.score.accuracy))
                .then(a.recipe.canonical().cmp(&b.recipe.canonical()))
        });
        next_frontier.truncate(cfg.beam);
        if next_frontier[0].score.footprint < best.score.footprint
            || (next_frontier[0].score.footprint == best.score.footprint
                && next_frontier[0].score.accuracy > best.score.accuracy)
        {
            best = next_frontier[0].clone();
        } else if cfg.beam == 1 {
            break; // greedy: no improving move
        }
        frontier = next_frontier;
    }

    Ok(SearchOutcome {
        winner: best,
        baseline,
        float_accuracy: scorer.float_accuracy,
        acc_floor: cfg.acc_floor,
        evaluated: scorer.evals(),
        scored_total: scorer.scored_total(),
        pareto: pareto_frontier(&all_points),
        beam: cfg.beam,
        groups: space.groups.len(),
        cache_hits: scorer.cache().hits(),
        cache_misses: scorer.cache().misses(),
        cache_evictions: scorer.cache().evictions(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::space::LayerGroup;
    use crate::clip::ClipMethod;
    use crate::runtime::native::synthetic_mlp;

    fn tiny_scorer(seed: u64, cap: usize) -> Scorer {
        let (spec, ws) = synthetic_mlp(2027);
        let cfg = crate::autotune::score::ScorerCfg {
            calib_images: 64,
            calib_batch: 32,
            test_images: 96,
            eval_batch: 32,
            seed,
            cache_cap: cap,
            gemm_threads: 1,
        };
        Scorer::new(spec, ws, cfg).unwrap()
    }

    fn tiny_space(groups: Vec<LayerGroup>) -> SearchSpace {
        SearchSpace {
            ladder: vec![8, 4],
            a_bits: vec![8],
            clips: vec![ClipMethod::None, ClipMethod::Mse],
            a_clip: ClipMethod::Mse,
            ocs_ratios: vec![0.0, 0.05],
            allow_skip: true,
            groups,
        }
    }

    #[test]
    fn greedy_descends_below_uniform_baseline() {
        let mut scorer = tiny_scorer(5, 0);
        let space = tiny_space(SearchSpace::per_layer(scorer.spec()));
        let cfg = SearchCfg {
            acc_floor: scorer.float_accuracy - 0.10,
            ..SearchCfg::default()
        };
        let out = run(&space, &mut scorer, &cfg).unwrap();
        assert!(out.winner.score.accuracy >= cfg.acc_floor);
        assert!(
            out.winner.score.footprint <= out.baseline.score.footprint,
            "winner {} must not exceed baseline {}",
            out.winner.score.footprint,
            out.baseline.score.footprint
        );
        assert!(out.evaluated >= 2);
        assert!(!out.pareto.is_empty());
        // frontier is footprint-ascending and accuracy-ascending
        for w in out.pareto.windows(2) {
            assert!(w[0].0 < w[1].0);
            assert!(w[0].1 < w[1].1);
        }
    }

    #[test]
    fn impossible_floor_without_skip_errors() {
        let mut scorer = tiny_scorer(5, 0);
        let mut space = tiny_space(SearchSpace::per_layer(scorer.spec()));
        space.allow_skip = false;
        let cfg = SearchCfg {
            acc_floor: 1.01, // unreachable by construction
            ..SearchCfg::default()
        };
        let err = run(&space, &mut scorer, &cfg).unwrap_err();
        assert!(err.to_string().contains("accuracy floor"), "{err:#}");
    }

    #[test]
    fn footprint_budget_stops_descent_early() {
        let mut scorer = tiny_scorer(5, 0);
        let space = tiny_space(SearchSpace::per_layer(scorer.spec()));
        // a budget the uniform start already meets: no descent at all
        let cfg = SearchCfg {
            acc_floor: 0.0,
            footprint_budget: Some(usize::MAX),
            ..SearchCfg::default()
        };
        let out = run(&space, &mut scorer, &cfg).unwrap();
        assert_eq!(
            out.winner.score.fingerprint, out.baseline.score.fingerprint,
            "budget met at start — winner is the baseline"
        );
    }

    #[test]
    fn pareto_frontier_drops_dominated_points() {
        let pts = vec![(100, 0.9), (100, 0.8), (50, 0.7), (60, 0.65), (200, 0.95)];
        let f = pareto_frontier(&pts);
        assert_eq!(f, vec![(50, 0.7), (100, 0.9), (200, 0.95)]);
    }

    #[test]
    fn beam_search_matches_or_beats_greedy() {
        let mut g = tiny_scorer(5, 0);
        let space = tiny_space(SearchSpace::per_layer(g.spec()));
        let floor = g.float_accuracy - 0.10;
        let greedy = run(
            &space,
            &mut g,
            &SearchCfg {
                acc_floor: floor,
                ..SearchCfg::default()
            },
        )
        .unwrap();
        let mut b = tiny_scorer(5, 0);
        let beam = run(
            &space,
            &mut b,
            &SearchCfg {
                acc_floor: floor,
                beam: 3,
                ..SearchCfg::default()
            },
        )
        .unwrap();
        assert!(beam.winner.score.footprint <= greedy.winner.score.footprint);
    }
}
