//! TOML-subset parser for experiment/serving config files.
//!
//! Supports the subset this project's configs use: `[section]` headers,
//! `[[section]]` array-of-tables headers (each occurrence opens a new
//! table addressed as `section.<index>.<key>`; see [`Config::array_len`])
//! — the shape `[[quant.layer]]` per-layer recipe overrides use —
//! `key = value` with string / integer / float / bool / homogeneous
//! array values, `#` comments. No inline tables, no dates.

use std::collections::BTreeMap;

use thiserror::Error;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

#[derive(Debug, Error)]
pub enum TomlError {
    #[error("line {0}: {1}")]
    Line(usize, String),
    #[error("missing key '{0}'")]
    Missing(String),
    #[error("key '{0}': expected {1}")]
    Type(String, &'static str),
}

/// A parsed config: `section.key -> value`; keys before any section
/// header live in the "" section. `[[name]]` array-of-tables entries are
/// flattened to `name.<index>.<key>` keys, with the occurrence count
/// kept in `arrays` so callers can iterate without probing.
#[derive(Debug, Default, Clone)]
pub struct Config {
    values: BTreeMap<String, TomlValue>,
    arrays: BTreeMap<String, usize>,
}

impl Config {
    pub fn parse(src: &str) -> Result<Config, TomlError> {
        let mut values = BTreeMap::new();
        let mut arrays: BTreeMap<String, usize> = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("[[") {
                let name = rest
                    .strip_suffix("]]")
                    .ok_or_else(|| TomlError::Line(ln + 1, "unterminated [[section]]".into()))?
                    .trim()
                    .to_string();
                if name.is_empty() {
                    return Err(TomlError::Line(ln + 1, "empty [[section]] name".into()));
                }
                let idx = arrays.entry(name.clone()).or_insert(0);
                section = format!("{name}.{idx}");
                *idx += 1;
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| TomlError::Line(ln + 1, "unterminated [section]".into()))?;
                section = name.trim().to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| TomlError::Line(ln + 1, "expected key = value".into()))?;
            let key = line[..eq].trim();
            let vs = line[eq + 1..].trim();
            let value = parse_value(vs).map_err(|e| TomlError::Line(ln + 1, e))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            values.insert(full, value);
        }
        Ok(Config { values, arrays })
    }

    pub fn load(path: &str) -> anyhow::Result<Config> {
        let src = std::fs::read_to_string(path)?;
        Ok(Self::parse(&src)?)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.values.get(key)
    }
    pub fn str(&self, key: &str) -> Result<&str, TomlError> {
        match self.values.get(key) {
            Some(TomlValue::Str(s)) => Ok(s),
            Some(_) => Err(TomlError::Type(key.into(), "string")),
            None => Err(TomlError::Missing(key.into())),
        }
    }
    pub fn int(&self, key: &str) -> Result<i64, TomlError> {
        match self.values.get(key) {
            Some(TomlValue::Int(i)) => Ok(*i),
            Some(_) => Err(TomlError::Type(key.into(), "integer")),
            None => Err(TomlError::Missing(key.into())),
        }
    }
    pub fn float(&self, key: &str) -> Result<f64, TomlError> {
        match self.values.get(key) {
            Some(TomlValue::Float(f)) => Ok(*f),
            Some(TomlValue::Int(i)) => Ok(*i as f64),
            Some(_) => Err(TomlError::Type(key.into(), "float")),
            None => Err(TomlError::Missing(key.into())),
        }
    }
    pub fn bool(&self, key: &str) -> Result<bool, TomlError> {
        match self.values.get(key) {
            Some(TomlValue::Bool(b)) => Ok(*b),
            Some(_) => Err(TomlError::Type(key.into(), "bool")),
            None => Err(TomlError::Missing(key.into())),
        }
    }
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.bool(key).unwrap_or(default)
    }
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.str(key).unwrap_or(default)
    }
    pub fn int_or(&self, key: &str, default: i64) -> i64 {
        self.int(key).unwrap_or(default)
    }
    pub fn float_or(&self, key: &str, default: f64) -> f64 {
        self.float(key).unwrap_or(default)
    }
    /// Array of floats (accepts ints).
    pub fn floats(&self, key: &str) -> Result<Vec<f64>, TomlError> {
        match self.values.get(key) {
            Some(TomlValue::Arr(a)) => a
                .iter()
                .map(|v| match v {
                    TomlValue::Float(f) => Ok(*f),
                    TomlValue::Int(i) => Ok(*i as f64),
                    _ => Err(TomlError::Type(key.into(), "float array")),
                })
                .collect(),
            Some(_) => Err(TomlError::Type(key.into(), "array")),
            None => Err(TomlError::Missing(key.into())),
        }
    }
    pub fn strs(&self, key: &str) -> Result<Vec<String>, TomlError> {
        match self.values.get(key) {
            Some(TomlValue::Arr(a)) => a
                .iter()
                .map(|v| match v {
                    TomlValue::Str(s) => Ok(s.clone()),
                    _ => Err(TomlError::Type(key.into(), "string array")),
                })
                .collect(),
            Some(_) => Err(TomlError::Type(key.into(), "array")),
            None => Err(TomlError::Missing(key.into())),
        }
    }
    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.values.keys()
    }
    /// How many `[[name]]` tables the file declared; table `i`'s keys
    /// live under `name.<i>.<key>`.
    pub fn array_len(&self, name: &str) -> usize {
        self.arrays.get(name).copied().unwrap_or(0)
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    let s = s.trim();
    if s.starts_with('"') {
        let inner = s
            .strip_prefix('"')
            .and_then(|r| r.strip_suffix('"'))
            .ok_or_else(|| format!("unterminated string: {s}"))?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if s.starts_with('[') {
        let inner = s
            .strip_prefix('[')
            .and_then(|r| r.strip_suffix(']'))
            .ok_or_else(|| format!("unterminated array: {s}"))?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("cannot parse value: {s}"))
}

/// Split on commas that are not inside quotes (arrays of strings may
/// contain commas).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
name = "table2"
bits = [8, 7, 6, 5, 4]

[ocs]
ratios = [0.01, 0.02, 0.05]
qa_split = true

[serve]
max_batch = 32
timeout_ms = 5.5
model = "miniresnet"
"#;

    #[test]
    fn parse_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str("name").unwrap(), "table2");
        assert_eq!(c.floats("bits").unwrap(), vec![8.0, 7.0, 6.0, 5.0, 4.0]);
        assert_eq!(c.floats("ocs.ratios").unwrap(), vec![0.01, 0.02, 0.05]);
        assert!(c.bool_or("ocs.qa_split", false));
        assert_eq!(c.int("serve.max_batch").unwrap(), 32);
        assert_eq!(c.float("serve.timeout_ms").unwrap(), 5.5);
        assert_eq!(c.str("serve.model").unwrap(), "miniresnet");
    }

    #[test]
    fn defaults() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.int_or("zzz", 7), 7);
        assert_eq!(c.str_or("zzz", "d"), "d");
        assert!(!c.bool_or("zzz", false));
    }

    #[test]
    fn comments_and_strings_with_hash() {
        let c = Config::parse("a = \"x # y\" # trailing\nb = 2 # c = 3").unwrap();
        assert_eq!(c.str("a").unwrap(), "x # y");
        assert_eq!(c.int("b").unwrap(), 2);
        assert!(c.get("c").is_none());
    }

    #[test]
    fn string_arrays() {
        let c = Config::parse(r#"models = ["a", "b,c"]"#).unwrap();
        assert_eq!(c.strs("models").unwrap(), vec!["a", "b,c"]);
    }

    #[test]
    fn array_of_tables() {
        let c = Config::parse(
            r#"
[quant]
w_bits = 5

[[quant.layer]]
match = "fc*"
w_bits = 4

[[quant.layer]]
kind = "conv"
ocs_ratio = 0.05

[other]
x = 1
"#,
        )
        .unwrap();
        assert_eq!(c.array_len("quant.layer"), 2);
        assert_eq!(c.array_len("missing"), 0);
        assert_eq!(c.int("quant.w_bits").unwrap(), 5);
        assert_eq!(c.str("quant.layer.0.match").unwrap(), "fc*");
        assert_eq!(c.int("quant.layer.0.w_bits").unwrap(), 4);
        assert_eq!(c.str("quant.layer.1.kind").unwrap(), "conv");
        assert_eq!(c.float("quant.layer.1.ocs_ratio").unwrap(), 0.05);
        assert_eq!(c.int("other.x").unwrap(), 1);
        assert!(Config::parse("[[nope]").is_err());
        assert!(Config::parse("[[]]").is_err());
    }

    #[test]
    fn errors() {
        assert!(Config::parse("[oops").is_err());
        assert!(Config::parse("novalue").is_err());
        assert!(Config::parse("x = @@").is_err());
        let c = Config::parse("x = 1").unwrap();
        assert!(c.str("x").is_err());
        assert!(c.int("missing").is_err());
    }
}
