//! Incremental FNV-1a 64 — the one hash this crate uses for stable,
//! dependency-free content fingerprints (recipe fingerprints, the
//! prepared-model cache's inputs token). Stable across platforms and
//! processes; NOT cryptographic — identity for caching, not integrity.

/// Incremental FNV-1a 64 hasher.
pub struct Fnv1a(u64);

impl Default for Fnv1a {
    fn default() -> Self {
        Fnv1a::new()
    }
}

impl Fnv1a {
    pub fn new() -> Fnv1a {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }

    #[inline]
    pub fn byte(&mut self, b: u8) {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
    }

    pub fn bytes(&mut self, bs: &[u8]) {
        for &b in bs {
            self.byte(b);
        }
    }

    pub fn u32(&mut self, v: u32) {
        self.bytes(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }

    /// Hash a string with a terminator so `("ab","c") != ("a","bc")`.
    pub fn str(&mut self, s: &str) {
        self.bytes(s.as_bytes());
        self.byte(0xff);
    }

    pub fn finish(&self) -> u64 {
        self.0
    }

    /// One-shot convenience.
    pub fn hash_bytes(bs: &[u8]) -> u64 {
        let mut h = Fnv1a::new();
        h.bytes(bs);
        h.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors_and_separation() {
        // FNV-1a 64 reference vectors
        assert_eq!(Fnv1a::hash_bytes(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(Fnv1a::hash_bytes(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(Fnv1a::hash_bytes(b"foobar"), 0x8594_4171_f738_77b8);
        // str() terminators keep field boundaries distinct
        let mut a = Fnv1a::new();
        a.str("ab");
        a.str("c");
        let mut b = Fnv1a::new();
        b.str("a");
        b.str("bc");
        assert_ne!(a.finish(), b.finish());
        // incremental == one-shot
        let mut inc = Fnv1a::new();
        inc.bytes(b"foo");
        inc.bytes(b"bar");
        assert_eq!(inc.finish(), Fnv1a::hash_bytes(b"foobar"));
    }
}
