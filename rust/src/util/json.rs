//! Minimal JSON parser + writer (serde_json is unavailable offline).
//!
//! Parses the `meta.json` files emitted by `python/compile/aot.py` and
//! writes result files under `results/`. Supports the full JSON grammar
//! except exotic number forms beyond f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use thiserror::Error;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

#[derive(Debug, Error)]
pub enum JsonError {
    #[error("unexpected end of input at byte {0}")]
    Eof(usize),
    #[error("unexpected character '{0}' at byte {1}")]
    Unexpected(char, usize),
    #[error("invalid number at byte {0}")]
    BadNumber(usize),
    #[error("invalid escape '\\{0}' at byte {1}")]
    BadEscape(char, usize),
    #[error("trailing garbage at byte {0}")]
    Trailing(usize),
    #[error("type error: expected {0}")]
    Type(&'static str),
    #[error("missing key '{0}'")]
    Missing(String),
}

impl Value {
    pub fn parse(s: &str) -> Result<Value, JsonError> {
        let b = s.as_bytes();
        let mut pos = 0;
        let v = parse_value(b, &mut pos)?;
        skip_ws(b, &mut pos);
        if pos != b.len() {
            return Err(JsonError::Trailing(pos));
        }
        Ok(v)
    }

    // ---- typed accessors --------------------------------------------------
    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => Err(JsonError::Type("number")),
        }
    }
    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_f64()? as usize)
    }
    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Value::Str(s) => Ok(s),
            _ => Err(JsonError::Type("string")),
        }
    }
    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => Err(JsonError::Type("bool")),
        }
    }
    pub fn as_arr(&self) -> Result<&[Value], JsonError> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => Err(JsonError::Type("array")),
        }
    }
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>, JsonError> {
        match self {
            Value::Obj(o) => Ok(o),
            _ => Err(JsonError::Type("object")),
        }
    }
    /// `obj["key"]` with a proper error.
    pub fn get(&self, key: &str) -> Result<&Value, JsonError> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| JsonError::Missing(key.to_string()))
    }
    pub fn get_opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(o) => o.get(key),
            _ => None,
        }
    }
    /// Array of usize (shape lists).
    pub fn as_shape(&self) -> Result<Vec<usize>, JsonError> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- writer -----------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building result JSON.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Value {
    Value::Num(n)
}
pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}
pub fn arr(v: Vec<Value>) -> Value {
    Value::Arr(v)
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> Result<(), JsonError> {
    if b.len() < *pos + lit.len() || &b[*pos..*pos + lit.len()] != lit.as_bytes() {
        return Err(JsonError::Unexpected(
            b.get(*pos).map(|&c| c as char).unwrap_or('?'),
            *pos,
        ));
    }
    *pos += lit.len();
    Ok(())
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Value, JsonError> {
    skip_ws(b, pos);
    let c = *b.get(*pos).ok_or(JsonError::Eof(*pos))?;
    match c {
        b'n' => {
            expect(b, pos, "null")?;
            Ok(Value::Null)
        }
        b't' => {
            expect(b, pos, "true")?;
            Ok(Value::Bool(true))
        }
        b'f' => {
            expect(b, pos, "false")?;
            Ok(Value::Bool(false))
        }
        b'"' => Ok(Value::Str(parse_string(b, pos)?)),
        b'[' => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Value::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Value::Arr(items));
                    }
                    Some(&c) => return Err(JsonError::Unexpected(c as char, *pos)),
                    None => return Err(JsonError::Eof(*pos)),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut map = BTreeMap::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Value::Obj(map));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(JsonError::Unexpected(
                        b.get(*pos).map(|&c| c as char).unwrap_or('?'),
                        *pos,
                    ));
                }
                *pos += 1;
                let v = parse_value(b, pos)?;
                map.insert(key, v);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Value::Obj(map));
                    }
                    Some(&c) => return Err(JsonError::Unexpected(c as char, *pos)),
                    None => return Err(JsonError::Eof(*pos)),
                }
            }
        }
        b'-' | b'0'..=b'9' => parse_number(b, pos),
        c => Err(JsonError::Unexpected(c as char, *pos)),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, JsonError> {
    if b.get(*pos) != Some(&b'"') {
        return Err(JsonError::Unexpected(
            b.get(*pos).map(|&c| c as char).unwrap_or('?'),
            *pos,
        ));
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        let c = *b.get(*pos).ok_or(JsonError::Eof(*pos))?;
        *pos += 1;
        match c {
            b'"' => return Ok(out),
            b'\\' => {
                let e = *b.get(*pos).ok_or(JsonError::Eof(*pos))?;
                *pos += 1;
                match e {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 > b.len() {
                            return Err(JsonError::Eof(*pos));
                        }
                        let hex = std::str::from_utf8(&b[*pos..*pos + 4])
                            .map_err(|_| JsonError::BadEscape('u', *pos))?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| JsonError::BadEscape('u', *pos))?;
                        *pos += 4;
                        out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                    }
                    e => return Err(JsonError::BadEscape(e as char, *pos)),
                }
            }
            c => {
                // re-assemble UTF-8 multibyte sequences
                if c < 0x80 {
                    out.push(c as char);
                } else {
                    let start = *pos - 1;
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    if start + len > b.len() {
                        return Err(JsonError::Eof(*pos));
                    }
                    let s = std::str::from_utf8(&b[start..start + len])
                        .map_err(|_| JsonError::Unexpected(c as char, start))?;
                    out.push_str(s);
                    *pos = start + len;
                }
            }
        }
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Value, JsonError> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos]).map_err(|_| JsonError::BadNumber(start))?;
    s.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| JsonError::BadNumber(start))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse(" -3.5e2 ").unwrap(), Value::Num(-350.0));
        assert_eq!(Value::parse(r#""hi""#).unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_bool().unwrap(), false);
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[1].as_usize().unwrap(), 2);
        assert_eq!(a[2].get("b").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Value::parse(r#""a\nb\t\"q\" A é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A é");
    }

    #[test]
    fn roundtrip_writer() {
        let src = r#"{"arr":[1,2.5,null,true],"s":"x\ny"}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn errors_are_reported() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("12 34").is_err());
        assert!(Value::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn shape_accessor() {
        let v = Value::parse("[3, 3, 30, 32]").unwrap();
        assert_eq!(v.as_shape().unwrap(), vec![3, 3, 30, 32]);
    }

    #[test]
    fn real_meta_json_parses() {
        // shape of the aot.py output
        let src = r#"{"model": "m", "layers": [{"name": "c1", "quantized": false,
            "w_shape": [3,3,3,24], "cin": 3}], "artifacts": {"fwd_b8":
            {"file": "fwd_b8.hlo.txt", "inputs": [{"name":"x","dtype":"f32","shape":[8,16,16,3]}]}}}"#;
        let v = Value::parse(src).unwrap();
        assert_eq!(
            v.get("artifacts").unwrap().get("fwd_b8").unwrap()
                .get("file").unwrap().as_str().unwrap(),
            "fwd_b8.hlo.txt"
        );
    }
}
