//! Leveled stderr logger with wall-clock timestamps (env_logger stand-in).
//!
//! Level comes from `OCS_LOG` (error|warn|info|debug|trace), default info.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(255);
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

#[derive(Clone, Copy, PartialEq, PartialOrd, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

fn level() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != 255 {
        return v;
    }
    let parsed = match std::env::var("OCS_LOG").as_deref() {
        Ok("error") => 0,
        Ok("warn") => 1,
        Ok("debug") => 3,
        Ok("trace") => 4,
        _ => 2,
    };
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

pub fn log(l: Level, args: std::fmt::Arguments) {
    if !enabled(l) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag}] {args}");
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! warnln {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! debugln {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! errorln {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
